//! Stochastic reconfiguration on a transverse-field Ising chain — the
//! paper's quantum-Monte-Carlo application (§1, §3), exercising the
//! complex-S variants of Algorithm 1.
//!
//! ```text
//! cargo run --release --example vmc_sr                 # 8 sites, complex SR
//! cargo run --release --example vmc_sr -- --sites 10 --variant real_part
//! ```
//!
//! The run converges the RBM variational energy to the exact ground
//! state (exact-diagonalization oracle) — recorded in EXPERIMENTS.md §E2E.

use dngd::data::rng::Rng;
use dngd::ngd::DampingSchedule;
use dngd::vmc::{ground_state_energy, IsingChain, MetropolisSampler, Rbm, SrDriver, SrVariant};

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut sites = 8usize;
    let mut iterations = 200usize;
    let mut variant = SrVariant::FullComplex;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--sites" => {
                sites = args[i + 1].parse().map_err(|_| "bad --sites")?;
                i += 1;
            }
            "--iters" => {
                iterations = args[i + 1].parse().map_err(|_| "bad --iters")?;
                i += 1;
            }
            "--variant" => {
                variant = match args[i + 1].as_str() {
                    "complex" => SrVariant::FullComplex,
                    "real_part" => SrVariant::RealPart,
                    other => return Err(format!("unknown variant {other}")),
                };
                i += 1;
            }
            other => return Err(format!("unknown arg {other}")),
        }
        i += 1;
    }

    let chain = IsingChain::new(sites, 1.0, 1.0); // critical point
    let exact = ground_state_energy(&chain, 60_000, 1e-12);
    println!(
        "TFIM chain: {sites} sites at criticality (J = h = 1), SR variant {variant:?}"
    );
    println!("exact ground state: E₀ = {exact:.6} ({:.6}/site)", exact / sites as f64);
    println!(
        "thermodynamic limit: {:.6}/site (Pfeuty)",
        chain.thermodynamic_energy_per_site()
    );

    let mut rng = Rng::seed_from(7);
    let hidden = 2 * sites; // α = 2 RBM
    let mut rbm = Rbm::init(sites, hidden, 0.05, &mut rng);
    println!(
        "RBM: {} visible × {} hidden = {} complex parameters ({} real)\n",
        sites,
        hidden,
        rbm.num_params(),
        2 * rbm.num_params()
    );
    let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
    for _ in 0..100 {
        sampler.sweep(&rbm, &mut rng);
    }

    let mut driver = SrDriver::new(chain, 400, 0.08, 0.05).with_variant(variant);
    driver.damping = DampingSchedule::ExponentialDecay { initial: 0.05, decay: 0.97, min: 1e-4 };

    println!("{:>6} | {:>12} | {:>9} | {:>8} | {:>6}", "iter", "energy", "σ(E)", "rel err", "acc");
    let mut best = f64::INFINITY;
    for it in 0..iterations {
        let rep = driver
            .step(&mut rbm, &mut sampler, &mut rng)
            .map_err(|e| e.to_string())?;
        best = best.min(rep.energy);
        if it % 10 == 0 || it + 1 == iterations {
            println!(
                "{it:>6} | {:>12.6} | {:>9.4} | {:>+8.4} | {:>5.1}%",
                rep.energy,
                rep.energy_std,
                (rep.energy - exact) / exact.abs(),
                rep.acceptance * 100.0
            );
        }
    }
    let rel = (best - exact).abs() / exact.abs();
    println!("\nbest variational energy: {best:.6} (exact {exact:.6}, rel err {rel:.4})");
    if rel > 0.05 {
        return Err(format!("SR failed to converge: rel err {rel:.4} > 5%"));
    }
    println!("converged within 5% of the exact ground state ✓");
    Ok(())
}
