//! Quickstart: solve one damped Fisher system with every method and
//! verify they agree — Algorithm 1 in five minutes.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::solver::{make_solver, residual_norm, RvbSolver, SolverKind};

fn main() {
    // A tall-skinny problem in the paper's regime (scaled to demo size):
    // n samples ≪ m parameters.
    let (n, m) = (128usize, 4096usize);
    let lambda = 1e-3;
    let mut rng = Rng::seed_from(2023);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();

    println!("(SᵀS + λI)x = v with S: {n}×{m}, λ = {lambda}\n");
    println!("{:>8} | {:>12} | {:>12} | agreement vs chol", "solver", "time", "residual");

    let mut x_ref: Option<Vec<f64>> = None;
    for &kind in SolverKind::all() {
        let solver = make_solver(kind);
        let t0 = std::time::Instant::now();
        match solver.solve(&s, &v, lambda) {
            Ok(x) => {
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let r = residual_norm(&s, &x, &v, lambda);
                let agree = match &x_ref {
                    None => {
                        x_ref = Some(x);
                        "— (reference)".to_string()
                    }
                    Some(xr) => {
                        let maxdiff =
                            x.iter().zip(xr).fold(0.0f64, |a, (p, q)| a.max((p - q).abs()));
                        format!("max|Δ| = {maxdiff:.2e}")
                    }
                };
                println!("{:>8} | {ms:>10.2}ms | {r:>12.3e} | {agree}", kind.as_str());
            }
            Err(e) => println!("{:>8} | {:>12} | {:>12} | {e}", kind.as_str(), "N/A", "—"),
        }
    }

    // The RVB least-squares identity (Appendix B): when v = Sᵀf the two
    // methods coincide exactly.
    let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let v_ls = s.t_matvec(&f);
    let x_chol = make_solver(SolverKind::Chol).solve(&s, &v_ls, lambda).unwrap();
    let x_rvb = RvbSolver::default().solve_ls(&s, &f, lambda).unwrap();
    let maxdiff = x_chol.iter().zip(&x_rvb).fold(0.0f64, |a, (p, q)| a.max((p - q).abs()));
    println!("\nAppendix B (v = Sᵀf): max|x_chol − x_rvb| = {maxdiff:.2e}");

    // Complexity story (§2): model FLOPs at the paper's scale.
    let (pn, pm) = (1000usize, 1_000_000usize);
    let f_chol = dngd::solver::flops(SolverKind::Chol, pn, pm);
    let f_naive = dngd::solver::flops(SolverKind::Naive, pn, pm);
    println!(
        "at the paper's scale (n=10³, m=10⁶): naive/chol FLOP ratio = {:.1e}",
        f_naive / f_chol
    );
}
