//! **End-to-end validation run** (DESIGN.md §End-to-end): train a
//! transformer language model on a synthetic corpus with damped natural
//! gradient descent (Algorithm 1) vs SGD, and log both loss curves.
//!
//! ```text
//! cargo run --release --example train_lm            # default (~60k params)
//! cargo run --release --example train_lm -- --steps 300 --batch 128
//! cargo run --release --example train_lm -- --preset paper   # m ≈ 10⁶ regime (slow on CPU)
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use dngd::config::Config;
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::Trainer;
use dngd::metrics::MetricsLog;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut steps = 200usize;
    let mut batch = 128usize;
    let mut preset = "default";
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--steps" => {
                steps = args[i + 1].parse().map_err(|_| "bad --steps")?;
                i += 1;
            }
            "--batch" => {
                batch = args[i + 1].parse().map_err(|_| "bad --batch")?;
                i += 1;
            }
            "--preset" => {
                preset = Box::leak(args[i + 1].clone().into_boxed_str());
                i += 1;
            }
            other => return Err(format!("unknown arg {other}")),
        }
        i += 1;
    }

    // Model scale: default is CPU-friendly; `paper` pushes toward the
    // paper's m ~ 10⁶, n ~ 10³ regime.
    let (dim, heads, layers, context, mlp_hidden) = match preset {
        "default" => (24usize, 3usize, 2usize, 24usize, 96usize),
        "paper" => (128, 8, 6, 64, 512),
        other => return Err(format!("unknown preset {other}")),
    };
    if preset == "paper" {
        batch = batch.max(512);
    }

    // NGD hyperparameters (tuned; see EXPERIMENTS.md §E2E): LM-adaptive
    // damping stabilizes mini-batch NGD — with n ≪ m the per-batch Fisher
    // is noisy, and a fixed small λ lets late-training steps chase that
    // noise.
    let overrides = vec![
        format!("model.dim={dim}"),
        format!("model.heads={heads}"),
        format!("model.layers={layers}"),
        format!("model.context={context}"),
        format!("model.mlp_hidden={mlp_hidden}"),
        format!("train.steps={steps}"),
        format!("train.batch_size={batch}"),
        "train.learning_rate=0.5".to_string(),
        "train.momentum=0.5".to_string(),
        "train.corpus_len=200000".to_string(),
        "solver.lambda=0.2".to_string(),
        "solver.adaptive=true".to_string(),
        "coordinator.workers=8".to_string(),
    ];
    let cfg = Config::load(None, &overrides)?;

    println!("=== NGD (Algorithm 1) run ===");
    let mut ngd_trainer = Trainer::new(&cfg, OptimizerChoice::Ngd)?;
    println!(
        "model: {} params | vocab {} | backend {}",
        ngd_trainer.model.num_params(),
        ngd_trainer.tokenizer.vocab_size(),
        ngd_trainer.backend()
    );
    let mut ngd_log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let ngd_report = ngd_trainer.run(&mut ngd_log).map_err(|e| e.to_string())?;

    println!("\n=== SGD baseline (same model, same data, tuned lr) ===");
    let mut sgd_overrides = overrides.clone();
    sgd_overrides.push("train.learning_rate=0.3".to_string());
    sgd_overrides.push("train.momentum=0.9".to_string());
    let sgd_cfg = Config::load(None, &sgd_overrides)?;
    let mut sgd_trainer = Trainer::new(&sgd_cfg, OptimizerChoice::Sgd)?;
    let mut sgd_log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let sgd_report = sgd_trainer.run(&mut sgd_log).map_err(|e| e.to_string())?;

    // Loss curves, decimated to ~20 lines.
    println!("\n{:>6} | {:>10} | {:>10}", "step", "NGD loss", "SGD loss");
    let ngd_losses = ngd_log.column("loss").unwrap();
    let sgd_losses = sgd_log.column("loss").unwrap();
    let stride = (steps / 20).max(1);
    for k in (0..steps).step_by(stride) {
        println!("{:>6} | {:>10.4} | {:>10.4}", k, ngd_losses[k], sgd_losses[k]);
    }
    let uniform = (ngd_trainer.tokenizer.vocab_size() as f64).ln();
    println!("\nuniform-distribution loss: {uniform:.4} nats ({:.3} bits/char)", uniform / std::f64::consts::LN_2);
    println!(
        "NGD : {:.4} → {:.4} ({:.3} bits/char) in {:.1}s [{}]",
        ngd_report.initial_loss,
        ngd_report.final_loss,
        ngd_report.final_bits_per_char,
        ngd_report.wall_secs,
        ngd_report.backend
    );
    println!(
        "SGD : {:.4} → {:.4} ({:.3} bits/char) in {:.1}s",
        sgd_report.initial_loss, sgd_report.final_loss, sgd_report.final_bits_per_char, sgd_report.wall_secs
    );

    // Write both curves for EXPERIMENTS.md.
    std::fs::create_dir_all("results").ok();
    ngd_log.write_csv(std::path::Path::new("results/train_lm_ngd.csv")).map_err(|e| e.to_string())?;
    sgd_log.write_csv(std::path::Path::new("results/train_lm_sgd.csv")).map_err(|e| e.to_string())?;
    println!("\nloss curves written to results/train_lm_{{ngd,sgd}}.csv");

    if ngd_report.final_loss >= uniform {
        return Err("NGD failed to learn anything (loss ≥ uniform)".into());
    }
    Ok(())
}
