//! Ridge regression via Algorithm 1 — the statistics application §3
//! names (Hoerl & Kennard 1970). A wide-feature regression (m ≫ n) where
//! the ridge solution `(XᵀX + λI)⁻¹Xᵀy` is exactly Eq. 1 with `v = Xᵀy`,
//! i.e. the least-squares structured case where the RVB fast path also
//! applies.
//!
//! ```text
//! cargo run --release --example ridge_regression
//! ```

use dngd::data::rng::Rng;
use dngd::data::tasks::regression_task;
use dngd::solver::{CholSolver, DampedSolver, NaiveSolver, RvbSolver};

fn main() {
    let (n, m) = (200usize, 5000usize);
    let noise = 0.5;
    let mut rng = Rng::seed_from(1970);
    let task = regression_task(n, m, noise, 0.02, &mut rng);
    println!("ridge regression: {n} samples × {m} features, noise σ = {noise}");
    println!("planted model: {} nonzero coefficients\n", task.w_true.iter().filter(|w| **w != 0.0).count());

    // v = Xᵀy (least-squares gradient at w = 0).
    let v = task.x.t_matvec(&task.y);

    println!("{:>10} | {:>12} | {:>12} | {:>12}", "λ", "train RMSE", "coef RMSE", "time");
    let mut best = (f64::INFINITY, 0.0);
    for lambda in [1e-2, 1e0, 1e2, 1e4] {
        let t0 = std::time::Instant::now();
        let w = CholSolver::default().solve(&task.x, &v, lambda).unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let pred = task.x.matvec(&w);
        let train_rmse = (pred
            .iter()
            .zip(&task.y)
            .map(|(p, y)| (p - y) * (p - y))
            .sum::<f64>()
            / n as f64)
            .sqrt();
        let coef_rmse = (w
            .iter()
            .zip(&task.w_true)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / m as f64)
            .sqrt();
        if coef_rmse < best.0 {
            best = (coef_rmse, lambda);
        }
        println!("{lambda:>10.0e} | {train_rmse:>12.4} | {coef_rmse:>12.5} | {ms:>10.2}ms");
    }
    println!("\nbest coefficient recovery at λ = {:.0e} (bias–variance tradeoff)", best.1);

    // Cross-check the three equivalent routes at one λ.
    let lambda = 1.0;
    let x_chol = CholSolver::default().solve(&task.x, &v, lambda).unwrap();
    let x_rvb = RvbSolver::default().solve_ls(&task.x, &task.y, lambda).unwrap();
    let maxdiff = x_chol.iter().zip(&x_rvb).fold(0.0f64, |a, (p, q)| a.max((p - q).abs()));
    println!("chol vs RVB identity (Appendix B): max|Δ| = {maxdiff:.2e}");

    // The naive O(m³) route refuses this shape on a modeled 80 GB device
    // budget only above ~100k features; here it is merely catastrophically
    // slower. Demonstrate on a reduced slice instead.
    let small = task.x.slice_cols(0, 600);
    let v_small = small.t_matvec(&task.y);
    let t0 = std::time::Instant::now();
    let x_naive = NaiveSolver::default().solve(&small, &v_small, lambda).unwrap();
    let naive_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t0 = std::time::Instant::now();
    let x_fast = CholSolver::default().solve(&small, &v_small, lambda).unwrap();
    let fast_ms = t0.elapsed().as_secs_f64() * 1e3;
    let maxdiff = x_naive.iter().zip(&x_fast).fold(0.0f64, |a, (p, q)| a.max((p - q).abs()));
    println!(
        "naive m×m solve on a 600-feature slice: {naive_ms:.1}ms vs Algorithm 1 {fast_ms:.1}ms \
         ({:.0}× speedup), max|Δ| = {maxdiff:.2e}",
        naive_ms / fast_ms
    );
}
