//! Damped least squares (Levenberg–Marquardt) with Algorithm 1 as the
//! trust-region subproblem solver — the optimization application §3
//! names. Fits a sum-of-Gaussians curve with many more parameters than
//! residuals would classically allow, using adaptive damping.
//!
//! Session usage (PR 2): LM's whole control flow is λ-retries against a
//! *fixed* Jacobian — exactly what the factor/redamp session amortizes.
//! Each outer iteration factors the Jacobian once; rejected steps grow λ
//! and re-damp the cached Gram (O(p³), zero O(p²n) rework) instead of
//! re-solving from scratch.
//!
//! ```text
//! cargo run --release --example levenberg_marquardt
//! ```

use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::ngd::DampingSchedule;
use dngd::solver::{CholSolver, DampedSolver};

/// Model: y(t) = Σ_k a_k · exp(−(t − μ_k)²/(2σ_k²)) with K components,
/// parameters θ = [a | μ | σ] (3K).
struct GaussMix {
    k: usize,
}

impl GaussMix {
    fn eval(&self, theta: &[f64], t: f64) -> f64 {
        let k = self.k;
        (0..k)
            .map(|i| {
                let (a, mu, sg) = (theta[i], theta[k + i], theta[2 * k + i]);
                a * (-(t - mu) * (t - mu) / (2.0 * sg * sg)).exp()
            })
            .sum()
    }

    /// Jacobian row ∂y/∂θ at t.
    fn jac_row(&self, theta: &[f64], t: f64, out: &mut [f64]) {
        let k = self.k;
        for i in 0..k {
            let (a, mu, sg) = (theta[i], theta[k + i], theta[2 * k + i]);
            let d = t - mu;
            let e = (-d * d / (2.0 * sg * sg)).exp();
            out[i] = e;
            out[k + i] = a * e * d / (sg * sg);
            out[2 * k + i] = a * e * d * d / (sg * sg * sg);
        }
    }
}

fn main() {
    let mix = GaussMix { k: 4 };
    let p = 3 * mix.k;
    let n_obs = 60usize;
    let mut rng = Rng::seed_from(1963); // Levenberg's year… close enough (1944/1963)

    // Ground truth + noisy observations.
    let theta_true: Vec<f64> = vec![
        1.5, -0.8, 1.0, 0.6, // amplitudes
        -3.0, -1.0, 1.0, 3.0, // means
        0.5, 0.8, 0.6, 1.0, // widths
    ];
    let ts: Vec<f64> = (0..n_obs).map(|i| -5.0 + 10.0 * i as f64 / (n_obs - 1) as f64).collect();
    let ys: Vec<f64> = ts.iter().map(|&t| mix.eval(&theta_true, t) + 0.02 * rng.normal()).collect();

    // Start from a deliberately poor guess.
    let mut theta: Vec<f64> = vec![
        1.0, -1.0, 1.0, 1.0, //
        -2.0, -0.5, 0.5, 2.0, //
        1.0, 1.0, 1.0, 1.0,
    ];

    let mut damping =
        DampingSchedule::LevenbergMarquardt { lambda: 1.0, grow: 3.0, shrink: 0.5, min: 1e-10, max: 1e8 };
    let solver = CholSolver::default();

    let sse = |theta: &[f64]| -> f64 {
        ts.iter().zip(&ys).map(|(&t, &y)| (mix.eval(theta, t) - y).powi(2)).sum()
    };

    println!("LM curve fit: {} observations, {p} parameters, 4-Gaussian mixture", n_obs);
    println!("{:>5} | {:>12} | {:>10} | retries", "iter", "SSE", "λ");
    let mut current = sse(&theta);
    for it in 0..60 {
        // Jacobian (n×p) and residual — the expensive model evaluation.
        let mut jac = Mat::zeros(n_obs, p);
        let mut resid = vec![0.0; n_obs];
        for (i, (&t, &y)) in ts.iter().zip(&ys).enumerate() {
            mix.jac_row(&theta, t, jac.row_mut(i));
            resid[i] = mix.eval(&theta, t) - y;
        }
        // LM step: (JᵀJ + λI)δ = Jᵀr — exactly Eq. 1 with S = J, v = Jᵀr.
        // Factor the Jacobian once; λ-retries re-damp the cached Gram.
        let v = jac.t_matvec(&resid);
        let mut fact = solver.begin(&jac);
        let mut lambda = damping.lambda();
        let mut retries = 0usize;
        loop {
            fact.redamp(lambda).expect("LM subproblem redamp");
            let delta = fact.solve(&v).expect("LM subproblem solve");
            let candidate: Vec<f64> = theta.iter().zip(&delta).map(|(a, d)| a - d).collect();
            let cand_sse = sse(&candidate);
            if cand_sse < current {
                theta = candidate;
                current = cand_sse;
                damping.advance(true);
                break;
            }
            damping.advance(false);
            retries += 1;
            if retries > 8 || damping.lambda() <= lambda {
                break; // λ saturated — re-evaluate the Jacobian instead.
            }
            lambda = damping.lambda();
        }
        if it % 5 == 0 {
            println!("{it:>5} | {current:>12.6} | {lambda:>10.2e} | {retries:>7}");
        }
        if current < 1e-4 * n_obs as f64 {
            break;
        }
    }

    // Report recovery quality (amplitude/mean recovery up to permutation —
    // the init preserves ordering, so direct comparison is fine).
    println!("\n{:>10} | {:>10} | {:>10}", "param", "true", "fitted");
    let labels = ["a1", "a2", "a3", "a4", "μ1", "μ2", "μ3", "μ4", "σ1", "σ2", "σ3", "σ4"];
    for (i, l) in labels.iter().enumerate() {
        println!("{l:>10} | {:>10.3} | {:>10.3}", theta_true[i], theta[i]);
    }
    let final_rmse = (current / n_obs as f64).sqrt();
    println!("\nfinal RMSE: {final_rmse:.4} (noise floor 0.02)");
    assert!(final_rmse < 0.05, "LM failed to fit");
    println!("fit OK ✓");
}
