//! Micro-benchmark runner — the in-tree criterion stand-in.
//!
//! Auto-calibrates the iteration count to a target measurement time,
//! warms up, then reports a [`Summary`] over per-iteration wall times.
//! Used by every `benches/*.rs` harness.

use super::stats::Summary;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    /// Median time in milliseconds — the unit of the paper's Table 1.
    pub fn median_ms(&self) -> f64 {
        self.summary.median * 1e3
    }
}

/// Benchmark a closure: warm up, then collect ≥ `min_samples` timed runs
/// or until `budget_secs` of measurement, whichever is later-bounded.
pub fn bench(name: &str, min_samples: usize, budget_secs: f64, mut f: impl FnMut()) -> BenchResult {
    // Warm-up: one run, untimed (page-faults, caches, lazy allocs).
    f();
    let mut samples = Vec::with_capacity(min_samples);
    let started = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        let done_samples = samples.len() >= min_samples;
        let done_budget = started.elapsed().as_secs_f64() >= budget_secs;
        if done_samples && (done_budget || samples.len() >= 4 * min_samples) {
            break;
        }
        if done_budget && samples.len() >= 3 {
            break;
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::from_samples(&samples) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_min_samples() {
        let r = bench("noop", 5, 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.summary.count >= 5);
        assert!(r.median_ms() >= 0.0);
    }

    #[test]
    fn measures_sleeps_approximately() {
        let r = bench("sleep", 3, 0.05, || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.median_ms() >= 1.5, "median {}", r.median_ms());
        assert!(r.median_ms() < 50.0);
    }
}
