//! Power-law fitting — the dotted "ideal scaling" overlays of Fig. 1.
//!
//! Fig. 1 plots time against n (fixed m) and against m (fixed n) on
//! log–log axes with dotted ideal lines; the harness fits
//! `t = c·xᵃ` by least squares in log space and reports the exponent,
//! which the reproduction compares against the theoretical 2 (n-sweep)
//! and 1 (m-sweep).

/// Fit `y = c·xᵃ`; returns `(a, c)`. Requires ≥ 2 positive points.
pub fn fit_power_law(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        assert!(x > 0.0 && y > 0.0, "power-law fit needs positive data");
        let lx = x.ln();
        let ly = y.ln();
        sx += lx;
        sy += ly;
        sxx += lx * lx;
        sxy += lx * ly;
    }
    let a = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let logc = (sy - a * sx) / n;
    (a, logc.exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_power_laws() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        // y = 3·x²
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x * x).collect();
        let (a, c) = fit_power_law(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((c - 3.0).abs() < 1e-10);
        // y = 0.5·x
        let ys: Vec<f64> = xs.iter().map(|x| 0.5 * x).collect();
        let (a, c) = fit_power_law(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tolerates_noise() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64 * 100.0).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 2e-6 * x * x * (1.0 + 0.05 * ((i as f64).sin())))
            .collect();
        let (a, _) = fit_power_law(&xs, &ys);
        assert!((a - 2.0).abs() < 0.1, "a = {a}");
    }
}
