//! Metric sinks: in-memory training log with CSV/JSON export.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Append-only training log: one row per step, named float columns.
#[derive(Debug, Default, Clone)]
pub struct MetricsLog {
    columns: Vec<String>,
    rows: Vec<Vec<f64>>,
}

impl MetricsLog {
    pub fn new(columns: &[&str]) -> Self {
        MetricsLog { columns: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(row.to_vec());
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Column by name.
    pub fn column(&self, name: &str) -> Option<Vec<f64>> {
        let idx = self.columns.iter().position(|c| c == name)?;
        Some(self.rows.iter().map(|r| r[idx]).collect())
    }

    /// Render as CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
            out.push_str(&cells.join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Streaming CSV writer for bench harnesses.
pub struct CsvSink {
    file: std::fs::File,
}

impl CsvSink {
    pub fn create(path: &Path, header: &[&str]) -> std::io::Result<CsvSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvSink { file })
    }

    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        writeln!(self.file, "{}", cells.join(","))
    }
}

/// Tiny JSON object writer (flat string→number maps; enough for
/// EXPERIMENTS.md artifacts without a serde dependency).
pub fn to_json(map: &BTreeMap<String, f64>) -> String {
    let fields: Vec<String> = map.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
    format!("{{{}}}", fields.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_roundtrip() {
        let mut log = MetricsLog::new(&["step", "loss"]);
        log.push(&[0.0, 2.5]);
        log.push(&[1.0, 1.25]);
        assert_eq!(log.len(), 2);
        assert_eq!(log.column("loss"), Some(vec![2.5, 1.25]));
        assert_eq!(log.column("nope"), None);
        let csv = log.to_csv();
        assert!(csv.starts_with("step,loss\n0,2.5\n"));
    }

    #[test]
    fn csv_file_write() {
        let dir = std::env::temp_dir().join("dngd_test_metrics");
        let path = dir.join("log.csv");
        let mut log = MetricsLog::new(&["a"]);
        log.push(&[1.0]);
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\n1\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_writer() {
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), 1.5);
        m.insert("y".to_string(), 2.0);
        assert_eq!(to_json(&m), "{\"x\": 1.5, \"y\": 2}");
    }
}
