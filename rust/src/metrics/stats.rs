//! Robust summary statistics over timing samples.

/// Summary of a sample set (times in seconds, or any positive metric).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p90: f64,
    /// Tail latency for the serving bench (PR 7) — with < 100 samples
    /// this interpolates toward the max, so treat it as a ceiling
    /// estimate at small n.
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty());
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = sorted.len();
        let mean = sorted.iter().sum::<f64>() / count as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (count.max(2) - 1) as f64;
        Summary {
            count,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: percentile(&sorted, 0.5),
            p90: percentile(&sorted, 0.9),
            p99: percentile(&sorted, 0.99),
            max: sorted[count - 1],
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponentially weighted moving average (trainer dashboards).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_samples() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.count, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.p90 - 4.6).abs() < 1e-12);
        assert!((s.p99 - 4.96).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples(&[7.0]);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p90, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.update(10.0);
        assert_eq!(e.get(), Some(10.0));
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-9);
    }
}
