//! Metrics & measurement: wall-clock timers with robust statistics, a
//! micro-benchmark runner (the repo's criterion stand-in — the build is
//! offline), counters, histograms, power-law fits for the Fig.-1 scaling
//! overlays, and CSV/JSON sinks.

pub mod bench;
pub mod fit;
pub mod sink;
pub mod stats;

pub use bench::{bench, BenchResult};
pub use fit::fit_power_law;
pub use sink::{CsvSink, MetricsLog};
pub use stats::Summary;
