//! `dngd` — leader entrypoint / CLI.
//!
//! ```text
//! dngd solve  --n 256 --m 8192 [--lambda 1e-3] [--solver chol|eigh|svda|naive|cg|all]
//! dngd train  [--config cfg.toml] [--set section.key=value]… [--optimizer ngd|sgd]
//! dngd vmc    [--config cfg.toml] [--set section.key=value]…
//! dngd bench  --table1 | --scaling | --cg | --kernels [--scale small|paper] [--json out.json]
//! dngd artifacts [--dir artifacts]
//! ```
//!
//! Arg parsing is in-tree (offline build — no clap); unknown flags are
//! hard errors, not silent ignores.

use dngd::config::Config;
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::Trainer;
use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::metrics::MetricsLog;
use dngd::solver::{make_solver, residual_norm, SolverKind};
use std::process::ExitCode;

mod cli {
    //! Tiny flag parser: `--key value`, `--key=value`, repeated flags.
    use std::collections::BTreeMap;

    pub struct Args {
        pub flags: BTreeMap<String, Vec<String>>,
    }

    pub fn parse(args: &[String]) -> Result<Args, String> {
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.entry(name.to_string()).or_default().push(args[i + 1].clone());
                    i += 1;
                } else {
                    flags.entry(name.to_string()).or_default().push(String::new());
                }
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
            i += 1;
        }
        Ok(Args { flags })
    }

    impl Args {
        pub fn get(&self, key: &str) -> Option<&str> {
            self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
        }

        pub fn get_all(&self, key: &str) -> Vec<String> {
            self.flags.get(key).cloned().unwrap_or_default()
        }

        pub fn has(&self, key: &str) -> bool {
            self.flags.contains_key(key)
        }

        pub fn parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
            match self.get(key) {
                None => Ok(default),
                Some(s) => s.parse().map_err(|_| format!("--{key}: cannot parse {s:?}")),
            }
        }

        /// Error on flags not in the allow-list.
        pub fn expect_only(&self, allowed: &[&str]) -> Result<(), String> {
            for k in self.flags.keys() {
                if !allowed.contains(&k.as_str()) {
                    return Err(format!("unknown flag --{k} (allowed: {})", allowed.join(", ")));
                }
            }
            Ok(())
        }
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "solve" => cmd_solve(rest),
        "train" => cmd_train(rest),
        "vmc" => cmd_vmc(rest),
        "bench" => cmd_bench(rest),
        "artifacts" => cmd_artifacts(rest),
        "--help" | "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "dngd — damped natural gradient descent at scale (Chen, Xie & Wang 2023)

USAGE:
  dngd solve  --n N --m M [--lambda L] [--solver chol|eigh|svda|naive|cg|all] [--threads T]
  dngd train  [--config cfg.toml] [--set section.key=value]... [--optimizer ngd|sgd] [--csv out.csv]
  dngd vmc    [--config cfg.toml] [--set section.key=value]... [--csv out.csv]
  dngd bench  (--table1 | --scaling | --cg | --kernels) [--scale small|paper] [--json out.json] [--quick]
  dngd artifacts [--dir artifacts]";

fn cmd_solve(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["n", "m", "lambda", "solver", "threads", "seed"])?;
    let n: usize = a.parsed("n", 256)?;
    let m: usize = a.parsed("m", 8192)?;
    let lambda: f64 = a.parsed("lambda", 1e-3)?;
    let threads: usize = a.parsed("threads", 1)?;
    let seed: u64 = a.parsed("seed", 42)?;
    let which = a.get("solver").unwrap_or("chol");

    let mut rng = Rng::seed_from(seed);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    println!("damped Fisher solve: n={n} m={m} λ={lambda}");

    let kinds: Vec<SolverKind> = if which == "all" {
        SolverKind::all().to_vec()
    } else {
        vec![SolverKind::parse(which).ok_or_else(|| format!("unknown solver {which:?}"))?]
    };
    for kind in kinds {
        let solver: Box<dyn dngd::solver::DampedSolver> = if kind == SolverKind::Chol && threads > 1
        {
            Box::new(dngd::solver::CholSolver::with_threads(threads))
        } else {
            make_solver(kind)
        };
        let t0 = std::time::Instant::now();
        match solver.solve(&s, &v, lambda) {
            Ok(x) => {
                let dt = t0.elapsed().as_secs_f64() * 1e3;
                let r = residual_norm(&s, &x, &v, lambda);
                println!("  {:>6}: {dt:>10.2} ms   residual {r:.3e}", kind.as_str());
            }
            Err(e) => println!("  {:>6}: N/A ({e})", kind.as_str()),
        }
    }
    Ok(())
}

fn load_config(a: &cli::Args) -> Result<Config, String> {
    Config::load(a.get("config"), &a.get_all("set"))
}

fn cmd_train(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["config", "set", "optimizer", "csv", "resume"])?;
    let cfg = load_config(&a)?;
    let optimizer = match a.get("optimizer").unwrap_or("ngd") {
        "ngd" => OptimizerChoice::Ngd,
        "sgd" => OptimizerChoice::Sgd,
        other => return Err(format!("unknown optimizer {other:?}")),
    };
    let mut trainer = Trainer::new(&cfg, optimizer)?;
    if let Some(path) = a.get("resume") {
        let step = trainer.load_checkpoint(std::path::Path::new(path))?;
        println!("resumed from {path} (step {step})");
    }
    println!(
        "training: {} params, vocab {}, backend {}, optimizer {optimizer:?}",
        trainer.model.num_params(),
        trainer.tokenizer.vocab_size(),
        trainer.backend(),
    );
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report = trainer.run(&mut log).map_err(|e| e.to_string())?;
    let every = cfg.train.log_every.max(1);
    if let (Some(steps), Some(losses)) = (log.column("step"), log.column("loss")) {
        for (s, l) in steps.iter().zip(&losses) {
            if (*s as usize) % every == 0 {
                println!(
                    "  step {:>5}  loss {:.4}  ({:.3} bits/char)",
                    s,
                    l,
                    l / std::f64::consts::LN_2
                );
            }
        }
    }
    println!(
        "done: loss {:.4} → {:.4} ({:.3} bits/char) in {:.1}s [{}]",
        report.initial_loss,
        report.final_loss,
        report.final_bits_per_char,
        report.wall_secs,
        report.backend
    );
    if let Some(csv) = a.get("csv") {
        log.write_csv(std::path::Path::new(csv)).map_err(|e| e.to_string())?;
        println!("loss curve written to {csv}");
    }
    Ok(())
}

fn cmd_vmc(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["config", "set", "csv"])?;
    let cfg = load_config(&a)?;
    let v = &cfg.vmc;
    let chain = dngd::vmc::IsingChain::new(v.sites, v.coupling_j, v.field_h);
    let exact = if v.sites <= 16 {
        Some(dngd::vmc::ground_state_energy(&chain, 60_000, 1e-12))
    } else {
        None
    };
    let variant = if v.variant == "complex" {
        dngd::vmc::SrVariant::FullComplex
    } else {
        dngd::vmc::SrVariant::RealPart
    };
    let mut rng = Rng::seed_from(v.seed);
    let mut rbm = dngd::vmc::Rbm::init(v.sites, v.hidden, 0.05, &mut rng);
    let mut sampler = dngd::vmc::MetropolisSampler::new(&rbm, &mut rng);
    for _ in 0..100 {
        sampler.sweep(&rbm, &mut rng);
    }
    let mut driver =
        dngd::vmc::SrDriver::new(chain.clone(), v.samples, v.learning_rate, cfg.solver.lambda)
            .with_variant(variant);
    println!(
        "SR on TFIM: {} sites, J={} h={}, RBM hidden {}, {} samples, variant {variant:?}",
        v.sites, v.coupling_j, v.field_h, v.hidden, v.samples
    );
    if let Some(e) = exact {
        println!("exact ground-state energy: {e:.6}");
    }
    let mut log = MetricsLog::new(&["iter", "energy", "energy_std", "lambda", "acceptance"]);
    for it in 0..v.iterations {
        let rep = driver.step(&mut rbm, &mut sampler, &mut rng).map_err(|e| e.to_string())?;
        log.push(&[it as f64, rep.energy, rep.energy_std, rep.lambda, rep.acceptance]);
        if it % 10 == 0 || it + 1 == v.iterations {
            let rel = exact
                .map(|e| format!("  (rel err {:+.4})", (rep.energy - e) / e.abs()))
                .unwrap_or_default();
            println!("  iter {it:>4}  E = {:.6} ± {:.4}{rel}", rep.energy, rep.energy_std);
        }
    }
    if let Some(csv) = a.get("csv") {
        log.write_csv(std::path::Path::new(csv)).map_err(|e| e.to_string())?;
        println!("energy curve written to {csv}");
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["table1", "scaling", "cg", "kernels", "scale", "json", "quick"])?;
    let scale = a.get("scale").filter(|s| !s.is_empty()).unwrap_or("small");
    let paper = match scale {
        "paper" => true,
        "small" => false,
        other => return Err(format!("--scale must be small|paper, got {other:?}")),
    };
    if a.has("table1") {
        dngd::bench_tables::table1(paper);
    } else if a.has("scaling") {
        dngd::bench_tables::scaling(paper);
    } else if a.has("cg") {
        dngd::bench_tables::cg_conditioning();
    } else if a.has("kernels") {
        let json = a.get("json").filter(|s| !s.is_empty()).map(std::path::Path::new);
        dngd::bench_tables::kernel_bench_report(a.has("quick"), json)
            .map_err(|e| e.to_string())?;
    } else {
        return Err("pick one of --table1 | --scaling | --cg | --kernels".into());
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["dir"])?;
    let dir = a.get("dir").unwrap_or("artifacts");
    let reg = dngd::runtime::ArtifactRegistry::scan(std::path::Path::new(dir));
    if reg.is_empty() {
        println!("no artifacts in {dir}/ — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifact(s) in {dir}/:", reg.len());
    for (kind, n, m) in reg.list() {
        println!("  {kind:?} n={n} m={m}");
    }
    Ok(())
}
