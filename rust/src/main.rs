//! `dngd` — leader entrypoint / CLI.
//!
//! ```text
//! dngd solve  --n 256 --m 8192 [--lambda 1e-3] [--solver chol|eigh|svda|naive|cg|rvb|blockdiag|kpsvd|hybrid|all]
//! dngd train  [--config cfg.toml] [--set section.key=value]… [--optimizer ngd|sgd] [--resume [path]]
//! dngd vmc    [--config cfg.toml] [--set section.key=value]…
//! dngd bench  --table1 | --scaling | --cg | --kernels | --precision [--scale small|paper] [--json out.json]
//! dngd serve  [--config cfg.toml] [--set section.key=value]… [--transport channels|socket|both] [--self-test] [--inject-kill]
//! dngd chaos  [--target serve|train] [--schedule S|all] [--transport channels|socket|both] [--seed N] [--kills K]
//! dngd artifacts [--dir artifacts]
//! ```
//!
//! Arg parsing is in-tree (offline build — no clap); unknown flags are
//! hard errors, not silent ignores.

use dngd::config::Config;
use dngd::coordinator::trainer::{OptimizerChoice, TRAIN_LOG_COLUMNS};
use dngd::coordinator::Trainer;
use dngd::data::rng::Rng;
use dngd::linalg::Mat;
use dngd::metrics::{MetricsLog, Summary};
use dngd::serve::{ChaosOptions, FaultSchedule, ServeOptions, Server, TransportKind};
use dngd::solver::{residual_norm, CholSolver, DampedSolver, SolveError, SolverKind, SolverRegistry};
use std::process::ExitCode;

mod cli {
    //! Tiny flag parser: `--key value`, `--key=value`, repeated flags.
    use std::collections::BTreeMap;

    pub struct Args {
        pub flags: BTreeMap<String, Vec<String>>,
    }

    pub fn parse(args: &[String]) -> Result<Args, String> {
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    flags.entry(name.to_string()).or_default().push(args[i + 1].clone());
                    i += 1;
                } else {
                    flags.entry(name.to_string()).or_default().push(String::new());
                }
            } else {
                return Err(format!("unexpected positional argument {a:?}"));
            }
            i += 1;
        }
        Ok(Args { flags })
    }

    impl Args {
        pub fn get(&self, key: &str) -> Option<&str> {
            self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
        }

        pub fn get_all(&self, key: &str) -> Vec<String> {
            self.flags.get(key).cloned().unwrap_or_default()
        }

        pub fn has(&self, key: &str) -> bool {
            self.flags.contains_key(key)
        }

        pub fn parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
            match self.get(key) {
                None => Ok(default),
                Some(s) => s.parse().map_err(|_| format!("--{key}: cannot parse {s:?}")),
            }
        }

        /// Error on flags not in the allow-list.
        pub fn expect_only(&self, allowed: &[&str]) -> Result<(), String> {
            for k in self.flags.keys() {
                if !allowed.contains(&k.as_str()) {
                    return Err(format!("unknown flag --{k} (allowed: {})", allowed.join(", ")));
                }
            }
            Ok(())
        }
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "solve" => cmd_solve(rest),
        "train" => cmd_train(rest),
        "vmc" => cmd_vmc(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "chaos" => cmd_chaos(rest),
        "artifacts" => cmd_artifacts(rest),
        "--help" | "help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "dngd — damped natural gradient descent at scale (Chen, Xie & Wang 2023)

USAGE:
  dngd solve  --n N --m M [--lambda L] [--solver chol|eigh|svda|naive|cg|rvb|blockdiag|kpsvd|hybrid|all]
              [--threads T] [--rhs K] [--lambda-sweep a,b,c] [--set solver.key=value]...
  dngd train  [--config cfg.toml] [--set section.key=value]... [--optimizer ngd|sgd] [--csv out.csv]
              [--resume [path.ckpt]]   (bare --resume scans train.checkpoint_dir, quarantining corrupt files)
  dngd vmc    [--config cfg.toml] [--set section.key=value]... [--csv out.csv]
  dngd bench  (--table1 | --scaling | --cg | --kernels | --sessions | --threads | --streaming | --precision | --serving | --recovery | --structured) [--scale small|paper] [--json out.json] [--json-simd out.json] [--quick]
  dngd serve  [--config cfg.toml] [--set section.key=value]... [--transport channels|socket|both]
              [--tenants T] [--requests R] [--self-test] [--inject-kill]
  dngd chaos  [--config cfg.toml] [--set section.key=value]... [--target serve|train]
              serve: [--schedule kill-during-factor|stall-during-panel|corrupt-frame|respawn-storm|all]
                     [--transport channels|socket|both] [--threads T] [--workers W] [--requests R] [--kill-every K]
              train: [--kills K]   (kill/resume cycles per scenario; resume must be bit-identical)
              [--seed N]
  dngd artifacts [--dir artifacts]";

/// Parse a `--lambda-sweep a,b,c` list.
fn parse_lambda_sweep(spec: &str) -> Result<Vec<f64>, String> {
    let sweep: Result<Vec<f64>, String> = spec
        .split(',')
        .map(|t| {
            let t = t.trim();
            t.parse::<f64>().map_err(|_| format!("--lambda-sweep: cannot parse {t:?}"))
        })
        .collect();
    let sweep = sweep?;
    if sweep.is_empty() || sweep.iter().any(|&l| l <= 0.0) {
        return Err("--lambda-sweep needs a non-empty list of positive λ values".into());
    }
    Ok(sweep)
}

fn cmd_solve(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&[
        "n", "m", "lambda", "lambda-sweep", "solver", "threads", "seed", "rhs", "set",
    ])?;
    let n: usize = a.parsed("n", 256)?;
    let m: usize = a.parsed("m", 8192)?;
    let lambda: f64 = a.parsed("lambda", 1e-3)?;
    let threads: usize = a.parsed("threads", 1)?;
    let seed: u64 = a.parsed("seed", 42)?;
    let rhs: usize = a.parsed("rhs", 1)?;
    if rhs == 0 {
        return Err("--rhs must be ≥ 1".into());
    }
    let which = a.get("solver").unwrap_or("chol");
    if a.has("lambda") && a.has("lambda-sweep") {
        // No-silent-ignore: the sweep would discard --lambda.
        return Err("--lambda and --lambda-sweep are mutually exclusive; put every λ in the sweep"
            .into());
    }
    let sweep: Vec<f64> = match a.get("lambda-sweep").filter(|s| !s.is_empty()) {
        Some(spec) => parse_lambda_sweep(spec)?,
        None => vec![lambda],
    };

    // Per-solver options: --threads T is shorthand for
    // --set solver.threads=T (prepended, so an explicit --set wins).
    // Unknown keys are hard errors (no-silent-ignore).
    let mut overrides = Vec::new();
    if threads > 1 {
        overrides.push(format!("solver.threads={threads}"));
    }
    overrides.extend(a.get_all("set"));
    let registry = SolverRegistry::from_overrides(&overrides)?;

    let mut rng = Rng::seed_from(seed);
    let s = Mat::randn(n, m, &mut rng);
    println!("damped Fisher solve: n={n} m={m} k={rhs} RHS, λ sweep {sweep:?}");
    println!(
        "{:>6} | {:>12} | {:>12} | {:>8} | residual",
        "solver", "cold (ms)", "session (ms)", "speedup"
    );

    let kinds: Vec<SolverKind> = if which == "all" {
        SolverKind::all().to_vec()
    } else {
        vec![SolverKind::parse(which).ok_or_else(|| format!("unknown solver {which:?}"))?]
    };
    // Per-kind option compatibility (no-silent-ignore): e.g.
    // `--solver cg --set solver.precision=mixed` is a hard error naming
    // the kinds that do support the mode, not a silent f64 downgrade.
    for kind in &kinds {
        registry.opts.validate_for(*kind)?;
    }
    for kind in kinds {
        // rvb requires v = Sᵀf; give it its native structured input so the
        // row documents the fast path instead of always printing N/A.
        let vs = if kind == SolverKind::Rvb {
            let mut vs = Mat::zeros(rhs, m);
            for r in 0..rhs {
                let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                vs.row_mut(r).copy_from_slice(&s.t_matvec(&f));
            }
            vs
        } else {
            Mat::randn(rhs, m, &mut rng)
        };
        let solver = registry.build(kind);

        // Cold: one full one-shot solve per (λ, RHS) pair — the pre-PR-2
        // behaviour every consumer used to pay.
        let t0 = std::time::Instant::now();
        let mut cold_err = None;
        'cold: for &l in &sweep {
            for r in 0..rhs {
                if let Err(e) = solver.solve(&s, vs.row(r), l) {
                    cold_err = Some(e);
                    break 'cold;
                }
            }
        }
        let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
        if let Some(e) = cold_err {
            println!("{:>6} | {:>25} N/A ({e})", kind.as_str(), "");
            continue;
        }

        // Session: stage once (the λ-independent state is computed by the
        // first redamp — no double-factorization), resweep λ on the
        // cached Gram, blocked multi-RHS back-substitution. Any session
        // failure prints N/A like the cold path, so `--solver all` always
        // emits every row.
        let t0 = std::time::Instant::now();
        let session: Result<(f64, Mat), SolveError> = (|| {
            let mut fact = solver.begin(&s);
            let mut last = None;
            for &l in &sweep {
                fact.redamp(l)?;
                last = Some((l, fact.solve_many(&vs)?));
            }
            Ok(last.expect("non-empty sweep"))
        })();
        let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
        match session {
            Ok((l_last, x)) => {
                let r = residual_norm(&s, x.row(0), vs.row(0), l_last);
                println!(
                    "{:>6} | {cold_ms:>12.2} | {warm_ms:>12.2} | {:>7.2}× | {r:.3e}",
                    kind.as_str(),
                    cold_ms / warm_ms.max(1e-9)
                );
            }
            Err(e) => println!("{:>6} | {:>25} N/A ({e})", kind.as_str(), ""),
        }
    }
    Ok(())
}

fn load_config(a: &cli::Args) -> Result<Config, String> {
    Config::load(a.get("config"), &a.get_all("set"))
}

fn cmd_train(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["config", "set", "optimizer", "csv", "resume"])?;
    let cfg = load_config(&a)?;
    let optimizer = match a.get("optimizer").unwrap_or("ngd") {
        "ngd" => OptimizerChoice::Ngd,
        "sgd" => OptimizerChoice::Sgd,
        other => return Err(format!("unknown optimizer {other:?}")),
    };
    let mut trainer = Trainer::new(&cfg, optimizer)?;
    match a.get("resume") {
        // Bare `--resume`: scan train.checkpoint_dir for the newest
        // loadable checkpoint (quarantining corrupt files).
        Some("") => match trainer.resume_latest().map_err(|e| e.to_string())? {
            Some(step) => println!(
                "resumed from {} (step {step})",
                dngd::checkpoint::checkpoint_path(
                    std::path::Path::new(&cfg.train.checkpoint_dir),
                    step
                )
                .display()
            ),
            None => println!(
                "no usable checkpoint under {} — starting fresh",
                cfg.train.checkpoint_dir
            ),
        },
        Some(path) => {
            let step = trainer
                .load_checkpoint(std::path::Path::new(path))
                .map_err(|e| e.to_string())?;
            println!("resumed from {path} (step {step})");
        }
        None => {}
    }
    let recovery = trainer.stats().clone();
    if recovery.quarantined > 0 || recovery.version_skipped > 0 {
        println!(
            "recovery: quarantined {} corrupt checkpoint(s), skipped {} from other versions",
            recovery.quarantined, recovery.version_skipped
        );
    }
    println!(
        "training: {} params, vocab {}, backend {}, optimizer {optimizer:?}",
        trainer.model.num_params(),
        trainer.tokenizer.vocab_size(),
        trainer.backend(),
    );
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    let report = trainer.run(&mut log).map_err(|e| e.to_string())?;
    let every = cfg.train.log_every.max(1);
    if let (Some(steps), Some(losses)) = (log.column("step"), log.column("loss")) {
        for (s, l) in steps.iter().zip(&losses) {
            if (*s as usize) % every == 0 {
                println!(
                    "  step {:>5}  loss {:.4}  ({:.3} bits/char)",
                    s,
                    l,
                    l / std::f64::consts::LN_2
                );
            }
        }
    }
    println!(
        "done: loss {:.4} → {:.4} ({:.3} bits/char) in {:.1}s [{}]",
        report.initial_loss,
        report.final_loss,
        report.final_bits_per_char,
        report.wall_secs,
        report.backend
    );
    let st = &report.stats;
    if st.nan_trips + st.divergence_trips + st.lambda_runaway_trips + st.rollbacks > 0 {
        println!(
            "sentinel: {} nan trip(s), {} divergence trip(s), {} λ-runaway trip(s), \
             {} rollback(s), {} λ escalation(s)",
            st.nan_trips,
            st.divergence_trips,
            st.lambda_runaway_trips,
            st.rollbacks,
            st.lambda_escalations
        );
    }
    if let Some(csv) = a.get("csv") {
        log.write_csv(std::path::Path::new(csv)).map_err(|e| e.to_string())?;
        println!("loss curve written to {csv}");
    }
    Ok(())
}

fn cmd_vmc(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["config", "set", "csv"])?;
    let cfg = load_config(&a)?;
    let v = &cfg.vmc;
    let chain = dngd::vmc::IsingChain::new(v.sites, v.coupling_j, v.field_h);
    let exact = if v.sites <= 16 {
        Some(dngd::vmc::ground_state_energy(&chain, 60_000, 1e-12))
    } else {
        None
    };
    let variant = if v.variant == "complex" {
        dngd::vmc::SrVariant::FullComplex
    } else {
        dngd::vmc::SrVariant::RealPart
    };
    let mut rng = Rng::seed_from(v.seed);
    let mut rbm = dngd::vmc::Rbm::init(v.sites, v.hidden, 0.05, &mut rng);
    let mut sampler = dngd::vmc::MetropolisSampler::new(&rbm, &mut rng);
    for _ in 0..100 {
        sampler.sweep(&rbm, &mut rng);
    }
    let mut driver =
        dngd::vmc::SrDriver::new(chain.clone(), v.samples, v.learning_rate, cfg.solver.lambda)
            .with_variant(variant);
    println!(
        "SR on TFIM: {} sites, J={} h={}, RBM hidden {}, {} samples, variant {variant:?}",
        v.sites, v.coupling_j, v.field_h, v.hidden, v.samples
    );
    if let Some(e) = exact {
        println!("exact ground-state energy: {e:.6}");
    }
    let mut log = MetricsLog::new(&["iter", "energy", "energy_std", "lambda", "acceptance"]);
    for it in 0..v.iterations {
        let rep = driver.step(&mut rbm, &mut sampler, &mut rng).map_err(|e| e.to_string())?;
        log.push(&[it as f64, rep.energy, rep.energy_std, rep.lambda, rep.acceptance]);
        if it % 10 == 0 || it + 1 == v.iterations {
            let rel = exact
                .map(|e| format!("  (rel err {:+.4})", (rep.energy - e) / e.abs()))
                .unwrap_or_default();
            println!("  iter {it:>4}  E = {:.6} ± {:.4}{rel}", rep.energy, rep.energy_std);
        }
    }
    if let Some(csv) = a.get("csv") {
        log.write_csv(std::path::Path::new(csv)).map_err(|e| e.to_string())?;
        println!("energy curve written to {csv}");
    }
    Ok(())
}

fn cmd_bench(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&[
        "table1", "scaling", "cg", "kernels", "sessions", "threads", "streaming", "precision",
        "serving", "recovery", "structured", "scale", "json", "json-simd", "quick",
    ])?;
    let scale = a.get("scale").filter(|s| !s.is_empty()).unwrap_or("small");
    let paper = match scale {
        "paper" => true,
        "small" => false,
        other => return Err(format!("--scale must be small|paper, got {other:?}")),
    };
    if a.has("table1") {
        dngd::bench_tables::table1(paper);
    } else if a.has("scaling") {
        dngd::bench_tables::scaling(paper);
    } else if a.has("cg") {
        dngd::bench_tables::cg_conditioning();
    } else if a.has("kernels") {
        let json = a.get("json").filter(|s| !s.is_empty()).map(std::path::Path::new);
        dngd::bench_tables::kernel_bench_report(a.has("quick"), json)
            .map_err(|e| e.to_string())?;
        // PR 4: report the active ISA tier + per-stage GF/s at scalar
        // vs best tier, and emit BENCH_PR4.json (no acceptance assert
        // on the CLI path — that lives in `cargo bench --bench gemm`).
        let json4 = a.get("json-simd").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR4.json");
        dngd::bench_tables::simd_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json4)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("sessions") {
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR2.json");
        dngd::bench_tables::session_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("threads") {
        // Sweeps pool thread counts {1, 2, 4, 8} over every pipeline
        // stage plus the end-to-end session; bit-identity asserted
        // always, the ≥3× acceptance bar only in the bench harness's
        // full mode. The sweep is fixed — reject a value rather than
        // silently ignoring it (no-silent-ignore policy).
        if let Some(v) = a.get("threads").filter(|s| !s.is_empty()) {
            return Err(format!(
                "--threads takes no value for `bench` (got {v:?}): the harness always sweeps \
                 1/2/4/8 pool threads"
            ));
        }
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR3.json");
        dngd::bench_tables::thread_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("streaming") {
        // PR 5: sliding-window rotation vs cold factor per step; the
        // ≥5× acceptance assert lives in `cargo bench --bench
        // streaming` full mode, not the CLI path.
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR5.json");
        dngd::bench_tables::streaming_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("precision") {
        // PR 6: f32 vs f64 GEMM/SYRK kernel throughput per tier plus the
        // mixed-vs-f64 end-to-end session; the ≥1.5× kernel acceptance
        // assert lives in `cargo bench --bench gemm` full mode, not the
        // CLI path.
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR6.json");
        dngd::bench_tables::precision_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("serving") {
        // PR 7: multi-tenant serving throughput, coalesced vs serial;
        // the ≥2× acceptance assert lives in `cargo bench --bench
        // serving` full mode, not the CLI path.
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR7.json");
        dngd::bench_tables::serving_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("recovery") {
        // PR 8: recovery latency under injected worker kills — p50/p99
        // with ~1 kill per 100 requests vs a fault-free baseline, plus
        // the respawn/replay counters and the 1e-9 correctness gate.
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR8.json");
        dngd::bench_tables::recovery_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else if a.has("structured") {
        // PR 10: exact chol vs the structured family (blockdiag, kpsvd,
        // hybrid) across block counts {1, 4, 16, 64}, plus hybrid-PCG vs
        // plain-CG iteration counts on a blocked Fisher. The acceptance
        // asserts (single-block ≡ chol, PCG iters < CG iters) live in
        // strict mode, exercised by tests/structured.rs.
        let json = a.get("json").filter(|s| !s.is_empty()).unwrap_or("BENCH_PR10.json");
        dngd::bench_tables::structured_bench_report(
            a.has("quick"),
            Some(std::path::Path::new(json)),
            false,
        )
        .map_err(|e| e.to_string())?;
    } else {
        return Err(
            "pick one of --table1 | --scaling | --cg | --kernels | --sessions | --threads | \
             --streaming | --precision | --serving | --recovery | --structured"
                .into(),
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["dir"])?;
    let dir = a.get("dir").unwrap_or("artifacts");
    let reg = dngd::runtime::ArtifactRegistry::scan(std::path::Path::new(dir));
    if reg.is_empty() {
        println!("no artifacts in {dir}/ — run `make artifacts`");
        return Ok(());
    }
    println!("{} artifact(s) in {dir}/:", reg.len());
    for (kind, n, m) in reg.list() {
        println!("  {kind:?} n={n} m={m}");
    }
    Ok(())
}

/// Fixed `dngd serve --self-test` workload data, regenerated
/// identically for the serial reference and every transport so the
/// answers are comparable bit-for-bit.
fn serve_test_data() -> (Mat, Vec<f64>, Vec<f64>, Mat) {
    let mut rng = Rng::seed_from(99);
    let s = Mat::randn(16, 128, &mut rng);
    let v1: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
    let v2: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
    let added = Mat::randn(2, 128, &mut rng);
    (s, v1, v2, added)
}

/// Run the fixed session workload (cold solve, λ-resweep, second RHS,
/// rotate + solve) through one server and collect the answers. With
/// `inject_kill` a worker dies right after the first answer; the
/// supervisor must re-materialize the session so the remaining answers
/// still come out right (PR-8 recovery contract).
fn serve_workload(opts: ServeOptions, inject_kill: bool) -> Result<Vec<Vec<f64>>, String> {
    let (s, v1, v2, added) = serve_test_data();
    let server = Server::start(opts).map_err(|e| format!("server start: {e}"))?;
    let client = server.client().map_err(|e| e.to_string())?;
    let sid = client.open_session(s, 0.05).map_err(|e| e.to_string())?;
    let mut answers = Vec::new();
    answers.push(client.solve(sid, 0.05, &v1).map_err(|e| e.to_string())?);
    if inject_kill {
        server.inject_kill(0);
    }
    // λ-resweep on the cached staging.
    answers.push(client.solve(sid, 0.01, &v1).map_err(|e| e.to_string())?);
    answers.push(client.solve(sid, 0.01, &v2).map_err(|e| e.to_string())?);
    // Streaming rotation, then solve against the rotated window.
    client.rotate(sid, &[0, 1], added).map_err(|e| e.to_string())?;
    answers.push(client.solve(sid, 0.01, &v1).map_err(|e| e.to_string())?);
    client.close_session(sid).map_err(|e| e.to_string())?;
    drop(client);
    server.shutdown();
    Ok(answers)
}

/// `dngd serve --self-test`: every requested transport must reproduce
/// the serial solver to 1e-9, and when both transports run they must
/// agree bit-for-bit (the PR-7 equivalence contract).
fn serve_self_test(
    base: &ServeOptions,
    transports: &[TransportKind],
    inject_kill: bool,
) -> Result<(), String> {
    let (s, v1, v2, added) = serve_test_data();
    let serial = CholSolver::default();
    let rotated = {
        let (n, m) = (s.rows(), s.cols());
        let mut w = Mat::zeros(n, m);
        for i in 2..n {
            w.row_mut(i - 2).copy_from_slice(s.row(i));
        }
        for r in 0..2 {
            w.row_mut(n - 2 + r).copy_from_slice(added.row(r));
        }
        w
    };
    let refs = vec![
        serial.solve(&s, &v1, 0.05).map_err(|e| e.to_string())?,
        serial.solve(&s, &v1, 0.01).map_err(|e| e.to_string())?,
        serial.solve(&s, &v2, 0.01).map_err(|e| e.to_string())?,
        serial.solve(&rotated, &v1, 0.01).map_err(|e| e.to_string())?,
    ];

    let mut per_transport: Vec<Vec<Vec<f64>>> = Vec::new();
    for &tk in transports {
        let opts = ServeOptions { transport: tk, ..base.clone() };
        let answers = serve_workload(opts, inject_kill)?;
        for (i, (x, x_ref)) in answers.iter().zip(&refs).enumerate() {
            let scale = dngd::linalg::mat::norm2(x_ref).max(1.0);
            for (a, b) in x.iter().zip(x_ref) {
                if (a - b).abs() > 1e-9 * scale {
                    return Err(format!(
                        "self-test: {tk} transport diverged from the serial solver on answer \
                         {i}: {a} vs {b}"
                    ));
                }
            }
        }
        let suffix = if inject_kill { " (recovered from an injected worker kill)" } else { "" };
        println!("self-test [{tk}]: 4 answers match the serial solver to 1e-9{suffix} ✓");
        per_transport.push(answers);
    }
    if let [a, b] = per_transport.as_slice() {
        let bit_identical = a
            .iter()
            .zip(b)
            .all(|(xa, xb)| xa.iter().zip(xb).all(|(p, q)| p.to_bits() == q.to_bits()));
        if !bit_identical {
            return Err("self-test: channels and socket transports are not bit-identical".into());
        }
        println!("self-test: channels ≡ socket bit-identically ✓");
    }
    Ok(())
}

/// `dngd serve` without `--self-test`: a small sustained-traffic demo
/// printing requests/sec and client-observed p50/p99 per transport.
fn serve_demo(
    base: &ServeOptions,
    transports: &[TransportKind],
    requests: usize,
) -> Result<(), String> {
    for &tk in transports {
        let opts = ServeOptions { transport: tk, ..base.clone() };
        let mut rng = Rng::seed_from(101);
        let (n, m) = (32usize, 512usize);
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let server = Server::start(opts.clone()).map_err(|e| format!("server start: {e}"))?;
        let sid = {
            let setup = server.client().map_err(|e| e.to_string())?;
            setup.open_session(s, 1e-3).map_err(|e| e.to_string())?
        };
        let per = (requests / opts.tenants).max(1);
        let started = std::time::Instant::now();
        let mut lats: Vec<f64> = Vec::new();
        std::thread::scope(|scope| -> Result<(), String> {
            let mut handles = Vec::new();
            for _ in 0..opts.tenants {
                let client = server.client().map_err(|e| e.to_string())?;
                let v = &v;
                handles.push(scope.spawn(move || -> Result<Vec<f64>, String> {
                    let mut l = Vec::with_capacity(per);
                    for _ in 0..per {
                        let t0 = std::time::Instant::now();
                        loop {
                            match client.solve(sid, 1e-3, v) {
                                Ok(_) => break,
                                Err(e) if e.is_retryable() => {
                                    std::thread::sleep(std::time::Duration::from_millis(1));
                                }
                                Err(e) => return Err(e.to_string()),
                            }
                        }
                        l.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    Ok(l)
                }));
            }
            for h in handles {
                lats.extend(h.join().map_err(|_| "tenant thread panicked".to_string())??);
            }
            Ok(())
        })?;
        let elapsed = started.elapsed().as_secs_f64();
        let stats = server.shutdown();
        let sum = Summary::from_samples(&lats);
        println!(
            "serve [{tk}]: {} tenants × {per} requests → {:.1} req/s, p50 {:.2} ms, \
             p99 {:.2} ms, {} panels ({} coalesced rows)",
            opts.tenants,
            lats.len() as f64 / elapsed.max(1e-9),
            sum.median,
            sum.p99,
            stats.panels,
            stats.coalesced_rows
        );
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&["config", "set", "self-test", "transport", "tenants", "requests", "inject-kill"])?;
    let cfg = Config::load(a.get("config"), &a.get_all("set"))?;
    let mut opts = ServeOptions::from_config(&cfg)?;
    if let Some(t) = a.get("tenants").filter(|s| !s.is_empty()) {
        // --tenants T is shorthand for --set serve.tenants=T, with the
        // queue deepened to keep the ≥-tenants cross-check satisfied.
        opts.tenants = t.parse().map_err(|_| format!("--tenants: cannot parse {t:?}"))?;
        opts.queue_depth = opts.queue_depth.max(opts.tenants);
        opts.validate()?;
    }
    let transports: Vec<TransportKind> = match a.get("transport").filter(|s| !s.is_empty()) {
        None => vec![opts.transport],
        Some("both") => vec![TransportKind::Channels, TransportKind::Socket],
        Some(s) => vec![TransportKind::parse(s)?],
    };
    if a.has("self-test") {
        serve_self_test(&opts, &transports, a.has("inject-kill"))
    } else {
        if a.has("inject-kill") {
            // No-silent-ignore: the demo path has no reference answers
            // to judge a recovery against.
            return Err("--inject-kill requires --self-test".into());
        }
        let requests: usize = a.parsed("requests", 64)?;
        if requests == 0 {
            return Err("--requests must be ≥ 1".into());
        }
        serve_demo(&opts, &transports, requests)
    }
}

/// `dngd chaos --target train`: kill a training run at randomized step
/// boundaries, resume a fresh trainer from the latest durable
/// checkpoint, and demand the final parameters match the unfailed run
/// bit for bit — across classic sharded, streaming-window (chol + rvb)
/// and mixed-precision modes — plus corrupt-checkpoint quarantine and
/// version-skew recovery drills.
fn cmd_chaos_train(a: &cli::Args, cfg: &Config) -> Result<(), String> {
    for flag in ["schedule", "transport", "threads", "workers", "requests", "kill-every"] {
        if a.get(flag).is_some() {
            return Err(format!("--{flag} applies to --target serve only"));
        }
    }
    let mut opts = dngd::coordinator::TrainChaosOptions {
        seed: cfg.chaos.seed,
        kills: cfg.chaos.kills,
    };
    opts.seed = a.parsed("seed", opts.seed)?;
    opts.kills = a.parsed("kills", opts.kills)?;
    if opts.kills == 0 {
        return Err("--kills must be ≥ 1".into());
    }
    let mut failed = 0usize;
    for r in dngd::coordinator::chaos::run_all(&opts)? {
        let verdict = if r.passed { "PASS" } else { "FAIL" };
        let detail =
            if r.detail.is_empty() { String::new() } else { format!("  ({})", r.detail) };
        println!(
            "chaos [   train] {:<22} kills {}  resumes {}  quarantined {}  skew-skipped {}  \
             {verdict}{detail}",
            r.scenario, r.kills, r.resumes, r.quarantined, r.version_skipped
        );
        if !r.passed {
            failed += 1;
        }
    }
    if failed > 0 {
        return Err(format!("{failed} train chaos scenario(s) failed"));
    }
    println!(
        "chaos: every kill/resume cycle rejoined the reference trajectory bit-identically ✓"
    );
    Ok(())
}

/// `dngd chaos`: run scripted fault schedules against a live server and
/// judge each run (correct answers, zero leaks, pinned recovery
/// counters). Any failing schedule is a hard error after all runs are
/// reported, so one red row never hides another.
fn cmd_chaos(args: &[String]) -> Result<(), String> {
    let a = cli::parse(args)?;
    a.expect_only(&[
        "config", "set", "schedule", "transport", "threads", "workers", "seed", "requests",
        "kill-every", "target", "kills",
    ])?;
    let cfg = Config::load(a.get("config"), &a.get_all("set"))?;
    let target = a.get("target").filter(|s| !s.is_empty()).unwrap_or(cfg.chaos.target.as_str());
    match target {
        "train" => return cmd_chaos_train(&a, &cfg),
        "serve" => {
            // `--kills` belongs to the train target; refuse rather than
            // silently ignore it (the CLI policy).
            if a.get("kills").is_some() {
                return Err("--kills applies to --target train only".into());
            }
        }
        other => return Err(format!("unknown chaos target {other:?} (serve|train)")),
    }
    // Flags override `chaos.*` config keys, which override the defaults.
    let mut opts = ChaosOptions {
        seed: cfg.chaos.seed,
        requests: cfg.chaos.requests,
        kill_every: cfg.chaos.kill_every,
        ..ChaosOptions::default()
    };
    opts.seed = a.parsed("seed", opts.seed)?;
    opts.requests = a.parsed("requests", opts.requests)?;
    opts.kill_every = a.parsed("kill-every", opts.kill_every)?;
    opts.threads = a.parsed("threads", opts.threads)?;
    opts.workers = a.parsed("workers", opts.workers)?;
    if opts.requests == 0 || opts.kill_every == 0 {
        return Err("--requests and --kill-every must be ≥ 1".into());
    }
    if opts.workers == 0 || opts.threads == 0 {
        return Err("--workers and --threads must be ≥ 1".into());
    }
    let spec = a
        .get("schedule")
        .filter(|s| !s.is_empty())
        .unwrap_or(cfg.chaos.schedule.as_str());
    let schedules: Vec<FaultSchedule> = if spec == "all" {
        FaultSchedule::all().to_vec()
    } else {
        vec![FaultSchedule::parse(spec)?]
    };
    let transports: Vec<TransportKind> = match a.get("transport").filter(|s| !s.is_empty()) {
        None => vec![opts.transport],
        Some("both") => vec![TransportKind::Channels, TransportKind::Socket],
        Some(s) => vec![TransportKind::parse(s)?],
    };
    let mut failed = 0usize;
    for &tk in &transports {
        opts.transport = tk;
        for &sch in &schedules {
            let r = dngd::serve::chaos::run_schedule(sch, &opts)?;
            let verdict = if r.passed { "PASS" } else { "FAIL" };
            let detail =
                if r.detail.is_empty() { String::new() } else { format!("  ({})", r.detail) };
            println!(
                "chaos [{:>8}] {:<18} {:>4} req  err {:.2e}  respawns {}  replays {}  \
                 refactors {}  fallbacks {}  {verdict}{detail}",
                r.transport,
                r.schedule,
                r.requests,
                r.max_rel_err,
                r.stats.worker_respawns,
                r.stats.session_replays,
                r.stats.session_refactors,
                r.stats.local_fallbacks,
            );
            if !r.passed {
                failed += 1;
            }
        }
    }
    if failed > 0 {
        return Err(format!("{failed} chaos schedule run(s) failed"));
    }
    println!("chaos: every schedule recovered with correct answers and zero leaks ✓");
    Ok(())
}
