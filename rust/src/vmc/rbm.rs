//! Complex RBM wavefunction (Carleo–Troyer neural quantum state).
//!
//! ```text
//! log ψ(s) = Σ_j a_j s_j + Σ_k log cosh(θ_k(s)),   θ_k = b_k + Σ_j W_kj s_j
//! ```
//!
//! with complex parameters `a ∈ ℂᴺ`, `b ∈ ℂᴹ`, `W ∈ ℂᴹˣᴺ` — the paper's
//! complex-S case. Log-derivatives (one row of the `O` matrix):
//!
//! ```text
//! O_a_j = s_j,   O_b_k = tanh θ_k,   O_W_kj = tanh(θ_k)·s_j
//! ```
//!
//! The hidden angles θ are cached and updated in O(M) per single-spin
//! flip, giving O(M) Metropolis ratios instead of O(MN).

use crate::data::rng::Rng;
use crate::linalg::c64;

/// Complex RBM over `n_visible` spins with `n_hidden` hidden units.
#[derive(Clone, Debug)]
pub struct Rbm {
    pub n_visible: usize,
    pub n_hidden: usize,
    /// Visible biases (length N).
    pub a: Vec<c64>,
    /// Hidden biases (length M).
    pub b: Vec<c64>,
    /// Couplings, row-major M×N.
    pub w: Vec<c64>,
}

impl Rbm {
    /// Small random complex init (scale keeps tanh in its linear regime
    /// at the start, standard for SR warm-up).
    pub fn init(n_visible: usize, n_hidden: usize, scale: f64, rng: &mut Rng) -> Self {
        let cplx = |r: &mut Rng| c64::new(scale * r.normal(), scale * r.normal());
        Rbm {
            n_visible,
            n_hidden,
            a: (0..n_visible).map(|_| cplx(rng)).collect(),
            b: (0..n_hidden).map(|_| cplx(rng)).collect(),
            w: (0..n_hidden * n_visible).map(|_| cplx(rng)).collect(),
        }
    }

    /// Total complex parameter count N + M + M·N.
    pub fn num_params(&self) -> usize {
        self.n_visible + self.n_hidden + self.n_hidden * self.n_visible
    }

    /// Hidden angles θ_k(s).
    pub fn angles(&self, spins: &[i8]) -> Vec<c64> {
        assert_eq!(spins.len(), self.n_visible);
        let mut theta = self.b.clone();
        for k in 0..self.n_hidden {
            let row = &self.w[k * self.n_visible..(k + 1) * self.n_visible];
            let mut acc = c64::ZERO;
            for j in 0..self.n_visible {
                acc += row[j] * f64::from(spins[j]);
            }
            theta[k] += acc;
        }
        theta
    }

    /// log ψ(s) given precomputed angles.
    pub fn log_psi_from_angles(&self, spins: &[i8], theta: &[c64]) -> c64 {
        let mut lp = c64::ZERO;
        for j in 0..self.n_visible {
            lp += self.a[j] * f64::from(spins[j]);
        }
        for t in theta {
            lp += t.cosh().ln();
        }
        lp
    }

    /// log ψ(s).
    pub fn log_psi(&self, spins: &[i8]) -> c64 {
        let theta = self.angles(spins);
        self.log_psi_from_angles(spins, &theta)
    }

    /// Amplitude ratio ψ(flip_i s)/ψ(s), O(M) using cached angles.
    pub fn flip_ratio(&self, spins: &[i8], theta: &[c64], i: usize) -> c64 {
        let si = f64::from(spins[i]);
        // Δlog = −2 a_i s_i + Σ_k [log cosh(θ_k − 2 W_ki s_i) − log cosh θ_k]
        let mut dlog = -(self.a[i] * (2.0 * si));
        for k in 0..self.n_hidden {
            let wki = self.w[k * self.n_visible + i];
            let new_t = theta[k] - wki * (2.0 * si);
            dlog += new_t.cosh().ln() - theta[k].cosh().ln();
        }
        dlog.exp()
    }

    /// Update cached angles after flipping spin `i` (call *before*
    /// mutating `spins[i]`).
    pub fn update_angles(&self, spins: &[i8], theta: &mut [c64], i: usize) {
        let si = f64::from(spins[i]);
        for k in 0..self.n_hidden {
            theta[k] -= self.w[k * self.n_visible + i] * (2.0 * si);
        }
    }

    /// One row of the `O` matrix: ∂ log ψ/∂θ_p for every complex parameter,
    /// ordered `[a | b | W (row-major)]`.
    pub fn log_derivatives(&self, spins: &[i8], theta: &[c64], out: &mut [c64]) {
        assert_eq!(out.len(), self.num_params());
        let n = self.n_visible;
        let mh = self.n_hidden;
        for j in 0..n {
            out[j] = c64::from_re(f64::from(spins[j]));
        }
        let mut tanh_t = vec![c64::ZERO; mh];
        for k in 0..mh {
            tanh_t[k] = theta[k].tanh();
            out[n + k] = tanh_t[k];
        }
        for k in 0..mh {
            for j in 0..n {
                out[n + mh + k * n + j] = tanh_t[k] * f64::from(spins[j]);
            }
        }
    }

    /// Apply a complex parameter update `θ ← θ − δ` in the `[a|b|W]` layout.
    pub fn apply_update(&mut self, delta: &[c64]) {
        assert_eq!(delta.len(), self.num_params());
        let n = self.n_visible;
        let mh = self.n_hidden;
        for j in 0..n {
            self.a[j] -= delta[j];
        }
        for k in 0..mh {
            self.b[k] -= delta[n + k];
        }
        for i in 0..mh * n {
            self.w[i] -= delta[n + mh + i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spins(bits: &[i8]) -> Vec<i8> {
        bits.to_vec()
    }

    #[test]
    fn flip_ratio_matches_direct_recomputation() {
        let mut rng = Rng::seed_from(300);
        let rbm = Rbm::init(6, 8, 0.3, &mut rng);
        let s = spins(&[1, -1, 1, 1, -1, -1]);
        let theta = rbm.angles(&s);
        for i in 0..6 {
            let fast = rbm.flip_ratio(&s, &theta, i);
            let mut s2 = s.clone();
            s2[i] = -s2[i];
            let direct = (rbm.log_psi(&s2) - rbm.log_psi(&s)).exp();
            assert!((fast - direct).abs() < 1e-10, "site {i}");
        }
    }

    #[test]
    fn angle_update_consistent() {
        let mut rng = Rng::seed_from(301);
        let rbm = Rbm::init(5, 7, 0.2, &mut rng);
        let mut s = spins(&[1, 1, -1, 1, -1]);
        let mut theta = rbm.angles(&s);
        rbm.update_angles(&s, &mut theta, 2);
        s[2] = -s[2];
        let fresh = rbm.angles(&s);
        for (a, b) in theta.iter().zip(&fresh) {
            assert!((*a - *b).abs() < 1e-12);
        }
    }

    #[test]
    fn log_derivatives_match_finite_differences() {
        let mut rng = Rng::seed_from(302);
        let mut rbm = Rbm::init(4, 3, 0.25, &mut rng);
        let s = spins(&[1, -1, -1, 1]);
        let theta = rbm.angles(&s);
        let mut o = vec![c64::ZERO; rbm.num_params()];
        rbm.log_derivatives(&s, &theta, &mut o);
        let eps = 1e-6;
        // Perturb each parameter's real part: d(log ψ)/d(Re θ_p) = O_p
        // (holomorphic), check a sample of indices.
        for p in [0usize, 3, 4, 6, 7, 10, 18] {
            let base = rbm.log_psi(&s);
            perturb(&mut rbm, p, c64::from_re(eps));
            let plus = rbm.log_psi(&s);
            perturb(&mut rbm, p, c64::from_re(-eps));
            let fd = (plus - base) / eps;
            assert!((fd - o[p]).abs() < 1e-5, "param {p}: fd {fd:?} vs {:?}", o[p]);
        }
    }

    fn perturb(rbm: &mut Rbm, p: usize, dz: c64) {
        let n = rbm.n_visible;
        let mh = rbm.n_hidden;
        if p < n {
            rbm.a[p] += dz;
        } else if p < n + mh {
            rbm.b[p - n] += dz;
        } else {
            rbm.w[p - n - mh] += dz;
        }
    }

    #[test]
    fn apply_update_roundtrip() {
        let mut rng = Rng::seed_from(303);
        let mut rbm = Rbm::init(3, 2, 0.1, &mut rng);
        let before = rbm.clone();
        let delta: Vec<c64> =
            (0..rbm.num_params()).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        rbm.apply_update(&delta);
        let neg: Vec<c64> = delta.iter().map(|d| -*d).collect();
        rbm.apply_update(&neg);
        for (x, y) in rbm.a.iter().zip(&before.a) {
            assert!((*x - *y).abs() < 1e-12);
        }
        for (x, y) in rbm.w.iter().zip(&before.w) {
            assert!((*x - *y).abs() < 1e-12);
        }
    }
}
