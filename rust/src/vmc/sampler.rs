//! Metropolis–Hastings sampler over spin configurations with |ψ(s)|²
//! weight — single-spin-flip proposals, O(M) acceptance ratios via the
//! RBM angle cache.

use super::rbm::Rbm;
use crate::data::rng::Rng;
use crate::linalg::c64;

/// Markov-chain sampler state.
pub struct MetropolisSampler {
    pub spins: Vec<i8>,
    theta: Vec<c64>,
    pub accepted: u64,
    pub proposed: u64,
}

impl MetropolisSampler {
    /// Start from a uniformly random configuration.
    pub fn new(rbm: &Rbm, rng: &mut Rng) -> Self {
        let spins: Vec<i8> = (0..rbm.n_visible)
            .map(|_| if rng.bernoulli(0.5) { 1 } else { -1 })
            .collect();
        let theta = rbm.angles(&spins);
        MetropolisSampler { spins, theta, accepted: 0, proposed: 0 }
    }

    /// One sweep ≈ `n_visible` single-flip proposals. The count is
    /// randomized by ±1 proposal: with deterministic sweep lengths and
    /// near-unit acceptance (e.g. a nearly uniform |ψ|²), observing the
    /// chain only at sweep boundaries aliases with the spin-parity of the
    /// flip count and some parity sector is never sampled.
    pub fn sweep(&mut self, rbm: &Rbm, rng: &mut Rng) {
        let proposals = rbm.n_visible + usize::from(rng.bernoulli(0.5));
        for _ in 0..proposals {
            let i = rng.below(rbm.n_visible);
            let ratio = rbm.flip_ratio(&self.spins, &self.theta, i);
            let p = ratio.norm_sqr().min(1.0);
            self.proposed += 1;
            if rng.uniform() < p {
                rbm.update_angles(&self.spins, &mut self.theta, i);
                self.spins[i] = -self.spins[i];
                self.accepted += 1;
            }
        }
    }

    /// Current cached hidden angles (consistent with `spins`).
    pub fn angles(&self) -> &[c64] {
        &self.theta
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// With all parameters zero, |ψ|² is uniform: every configuration is
    /// equally likely and acceptance is 100%.
    #[test]
    fn uniform_wavefunction_samples_uniformly() {
        let mut rng = Rng::seed_from(310);
        let rbm = Rbm::init(4, 2, 0.0, &mut rng); // scale 0 ⇒ ψ ≡ 1
        let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
        let mut counts: HashMap<Vec<i8>, usize> = HashMap::new();
        let sweeps = 4000;
        for _ in 0..sweeps {
            sampler.sweep(&rbm, &mut rng);
            *counts.entry(sampler.spins.clone()).or_default() += 1;
        }
        assert!((sampler.acceptance_rate() - 1.0).abs() < 1e-12);
        // All 16 configs should appear with roughly equal frequency.
        assert_eq!(counts.len(), 16);
        for (_, c) in counts {
            let expect = sweeps as f64 / 16.0;
            assert!((c as f64 - expect).abs() < 6.0 * expect.sqrt() + 20.0);
        }
    }

    /// Detailed balance check against exact |ψ|²: sampled marginals must
    /// match brute-force enumeration.
    #[test]
    fn matches_exact_distribution() {
        let mut rng = Rng::seed_from(311);
        let rbm = Rbm::init(4, 3, 0.3, &mut rng);
        // Exact probabilities by enumeration.
        let n = 4;
        let mut probs = HashMap::new();
        let mut z = 0.0;
        for mask in 0..(1u32 << n) {
            let spins: Vec<i8> =
                (0..n).map(|b| if mask >> b & 1 == 1 { 1 } else { -1 }).collect();
            let w = (rbm.log_psi(&spins).re * 2.0).exp();
            z += w;
            probs.insert(spins, w);
        }
        for w in probs.values_mut() {
            *w /= z;
        }
        // Sample.
        let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
        for _ in 0..200 {
            sampler.sweep(&rbm, &mut rng); // burn-in
        }
        let mut counts: HashMap<Vec<i8>, usize> = HashMap::new();
        let total = 30_000;
        for _ in 0..total {
            sampler.sweep(&rbm, &mut rng);
            *counts.entry(sampler.spins.clone()).or_default() += 1;
        }
        for (spins, p_exact) in &probs {
            let p_emp = counts.get(spins).copied().unwrap_or(0) as f64 / total as f64;
            let sigma = (p_exact * (1.0 - p_exact) / total as f64).sqrt();
            // Autocorrelation inflates variance; allow a generous band.
            assert!(
                (p_emp - p_exact).abs() < 12.0 * sigma + 0.01,
                "config {spins:?}: exact {p_exact:.4} vs sampled {p_emp:.4}"
            );
        }
    }
}
