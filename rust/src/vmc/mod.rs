//! Variational quantum Monte Carlo substrate — the paper's *stochastic
//! reconfiguration* application domain (§1, §3).
//!
//! The paper's production context is neural-network quantum states
//! optimized by SR, where the score matrix is the centered log-derivative
//! of the wavefunction, `S = (O − Ō)/√n`, complex in general. We build the
//! full pipeline from scratch:
//!
//! * [`ising`] — transverse-field Ising chain Hamiltonian + local energy;
//! * [`rbm`] — complex restricted-Boltzmann-machine wavefunction with
//!   analytic log-derivatives (the `O` matrix);
//! * [`sampler`] — Metropolis–Hastings |ψ|² sampler with O(1) ratio
//!   updates through the RBM's hidden-angle cache;
//! * [`exact`] — exact ground-state oracle (power iteration on the shifted
//!   sparse Hamiltonian) for chains up to ~16 sites;
//! * [`sr`] — the SR optimization driver wiring the above into
//!   Algorithm 1's complex variants
//!   ([`crate::solver::solve_sr_complex`] / [`solve_sr_real_part`]).

pub mod exact;
pub mod ising;
pub mod rbm;
pub mod sampler;
pub mod sr;

pub use exact::ground_state_energy;
pub use ising::IsingChain;
pub use rbm::Rbm;
pub use sampler::MetropolisSampler;
pub use sr::{SrDriver, SrVariant};
