//! Exact ground-state oracle for small TFIM chains.
//!
//! Builds nothing dense: the Hamiltonian is applied matrix-free over the
//! 2ᴺ computational basis (σᶻσᶻ diagonal + N single-flip terms), and the
//! ground state is found by power iteration on the spectrally shifted
//! operator `σI − H` (σ an upper bound on ‖H‖), which converges to the
//! lowest eigenvector. Used to validate the SR example's converged energy.

use super::ising::IsingChain;

/// Apply H to a state vector over the 2ᴺ basis (index bit b = spin b up).
fn apply_h(chain: &IsingChain, psi: &[f64], out: &mut [f64]) {
    let n = chain.n;
    let dim = 1usize << n;
    assert_eq!(psi.len(), dim);
    for (state, o) in out.iter_mut().enumerate() {
        // Diagonal σᶻσᶻ term.
        let mut diag = 0.0;
        for i in 0..n {
            let jn = (i + 1) % n;
            let si = if state >> i & 1 == 1 { 1.0 } else { -1.0 };
            let sj = if state >> jn & 1 == 1 { 1.0 } else { -1.0 };
            diag -= chain.j * si * sj;
        }
        let mut acc = diag * psi[state];
        // Off-diagonal σˣ flips.
        for i in 0..n {
            acc -= chain.h * psi[state ^ (1 << i)];
        }
        *o = acc;
    }
}

/// Ground-state energy by shifted power iteration. `N ≤ 20` is practical;
/// tolerance is on the Rayleigh-quotient increment.
pub fn ground_state_energy(chain: &IsingChain, max_iters: usize, tol: f64) -> f64 {
    let n = chain.n;
    let dim = 1usize << n;
    // Shift: ‖H‖₁ ≤ J·n + h·n.
    let sigma = (chain.j.abs() + chain.h.abs()) * n as f64 + 1.0;
    // Deterministic pseudo-random start with nonzero overlap.
    let mut psi: Vec<f64> = (0..dim)
        .map(|i| ((i as f64 * 0.7548776662466927 + 0.1).fract()) - 0.5 + 1e-3)
        .collect();
    normalize(&mut psi);
    let mut hpsi = vec![0.0; dim];
    let mut energy = 0.0;
    for it in 0..max_iters {
        apply_h(chain, &psi, &mut hpsi);
        // Rayleigh quotient.
        let e: f64 = psi.iter().zip(&hpsi).map(|(a, b)| a * b).sum();
        if it > 0 && (e - energy).abs() < tol {
            return e;
        }
        energy = e;
        // psi ← normalize(σ·psi − H·psi)
        for i in 0..dim {
            psi[i] = sigma * psi[i] - hpsi[i];
        }
        normalize(&mut psi);
    }
    energy
}

fn normalize(v: &mut [f64]) {
    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in v {
        *x /= norm;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_site_closed_form() {
        // N=2 periodic: bonds double (0-1 twice) ⇒ H = −2J σᶻσᶻ − h(σˣ₁+σˣ₂).
        // Ground energy = −√(4J² + 4h²) for J=h=1: −2√2.
        let chain = IsingChain::new(2, 1.0, 1.0);
        let e = ground_state_energy(&chain, 20_000, 1e-12);
        assert!((e + 2.0 * 2f64.sqrt()).abs() < 1e-8, "e = {e}");
    }

    #[test]
    fn classical_limit() {
        // h = 0: ground state all-aligned, E = −J·N.
        let chain = IsingChain::new(6, 1.0, 0.0);
        let e = ground_state_energy(&chain, 20_000, 1e-12);
        assert!((e + 6.0).abs() < 1e-8);
    }

    #[test]
    fn free_spin_limit() {
        // J = 0: each spin independently in the x-field, E = −h·N.
        let chain = IsingChain::new(5, 0.0, 1.5);
        let e = ground_state_energy(&chain, 20_000, 1e-12);
        assert!((e + 7.5).abs() < 1e-8);
    }

    #[test]
    fn critical_point_matches_finite_size_exact() {
        // N=8, J=h=1: exact via Jordan–Wigner,
        // E = −Σ_k Λ(k)/… ; we cross-check against the known finite-size
        // value E₈ ≈ −10.2516617910 (antiperiodic fermion sector).
        let chain = IsingChain::new(8, 1.0, 1.0);
        let e = ground_state_energy(&chain, 60_000, 1e-13);
        assert!((e + 10.2516617910).abs() < 1e-6, "e = {e}");
    }

    #[test]
    fn energy_below_thermodynamic_bound_times_n() {
        // Finite ring at criticality: per-site energy below the
        // thermodynamic value (finite-size correction is negative).
        let chain = IsingChain::new(10, 1.0, 1.0);
        let e = ground_state_energy(&chain, 60_000, 1e-12);
        let per_site = e / 10.0;
        let thermo = chain.thermodynamic_energy_per_site();
        assert!(per_site < thermo + 1e-6, "{per_site} vs {thermo}");
    }
}
