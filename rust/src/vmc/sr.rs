//! Stochastic-reconfiguration driver: ties sampler + RBM + Hamiltonian to
//! the paper's complex Algorithm-1 variants.
//!
//! Per iteration:
//! 1. draw n configurations from |ψ|² (Metropolis);
//! 2. build the raw log-derivative matrix `O` (n×p) and local energies;
//! 3. center: `S = (O − Ō)/√n`, `e = (E_loc − Ē)/√n`;
//! 4. force `v = S†e` (the quantum geometric-tensor gradient);
//! 5. solve `(S†S + λI) δ = v` with [`solve_sr_complex`] (full-complex
//!    Fisher) or the real-part variant via `Concat[ℜS, ℑS]` (§3);
//! 6. `θ ← θ − η·δ`.

use super::ising::IsingChain;
use super::rbm::Rbm;
use super::sampler::MetropolisSampler;
use crate::data::rng::Rng;
use crate::linalg::complex::{c64, CMat};
use crate::ngd::DampingSchedule;
use crate::solver::{
    center_scores, solve_with_backoff, stack_real_part, CholSolver, ComplexSrFactor,
    DampedSolver, SolveError,
};

/// Which Fisher-matrix convention to use (§3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SrVariant {
    /// `F = S†S` — every transpose becomes a Hermitian conjugate.
    FullComplex,
    /// `F = ℜ[S†S]` via `S ← Concat[ℜS, ℑS]` — "more commonly employed".
    RealPart,
}

/// SR optimization driver.
pub struct SrDriver {
    pub chain: IsingChain,
    pub n_samples: usize,
    /// Sweeps between retained samples (decorrelation).
    pub thin: usize,
    pub damping: DampingSchedule,
    pub learning_rate: f64,
    pub variant: SrVariant,
    last_energy: Option<f64>,
}

/// Per-iteration report.
#[derive(Debug, Clone)]
pub struct SrStepReport {
    pub energy: f64,
    pub energy_per_site: f64,
    pub energy_std: f64,
    pub update_norm: f64,
    pub lambda: f64,
    pub acceptance: f64,
}

impl SrDriver {
    pub fn new(chain: IsingChain, n_samples: usize, learning_rate: f64, lambda: f64) -> Self {
        SrDriver {
            chain,
            n_samples,
            thin: 2,
            damping: DampingSchedule::ExponentialDecay { initial: lambda, decay: 0.98, min: 1e-4 },
            learning_rate,
            variant: SrVariant::FullComplex,
            last_energy: None,
        }
    }

    pub fn with_variant(mut self, v: SrVariant) -> Self {
        self.variant = v;
        self
    }

    /// One SR iteration: sample, estimate, solve, update.
    pub fn step(
        &mut self,
        rbm: &mut Rbm,
        sampler: &mut MetropolisSampler,
        rng: &mut Rng,
    ) -> Result<SrStepReport, SolveError> {
        let n = self.n_samples;
        let p = rbm.num_params();
        let sites = self.chain.n;

        let mut o = CMat::zeros(n, p);
        let mut e_loc = vec![c64::ZERO; n];
        let mut ratios = vec![c64::ZERO; sites];
        let acc0 = sampler.accepted;
        let prop0 = sampler.proposed;
        for i in 0..n {
            for _ in 0..self.thin {
                sampler.sweep(rbm, rng);
            }
            let theta = sampler.angles().to_vec();
            rbm.log_derivatives(&sampler.spins, &theta, o.row_mut(i));
            for (site, r) in ratios.iter_mut().enumerate() {
                *r = rbm.flip_ratio(&sampler.spins, &theta, site);
            }
            e_loc[i] = self.chain.local_energy(&sampler.spins, &ratios);
        }
        let acceptance = if sampler.proposed > prop0 {
            (sampler.accepted - acc0) as f64 / (sampler.proposed - prop0) as f64
        } else {
            0.0
        };

        // Energy statistics (E_loc of a Hermitian H has real mean; the
        // imaginary part is a pure Monte-Carlo fluctuation).
        let mean_e = e_loc.iter().fold(c64::ZERO, |a, &b| a + b) / n as f64;
        let var_e = e_loc.iter().map(|e| (*e - mean_e).norm_sqr()).sum::<f64>() / n as f64;

        // Centered score matrix and force.
        let s = center_scores(&o);
        let inv_sqrt_n = 1.0 / (n as f64).sqrt();
        let e_centered: Vec<c64> = e_loc.iter().map(|e| (*e - mean_e) * inv_sqrt_n).collect();
        let force = s.dagger_matvec(&e_centered); // v = S† e  (length p)

        let improved = self.last_energy.map(|prev| mean_e.re < prev).unwrap_or(true);
        self.damping.advance(improved);
        self.last_energy = Some(mean_e.re);
        let mut lambda = self.damping.lambda();

        // Solve through the session API (PR 2): the Gram is staged once;
        // a Cholesky breakdown at small λ re-damps the cached Gram
        // (×10 backoff) instead of recomputing the O(p²n) product.
        const PD_RETRIES: usize = 3;
        let update_norm;
        match self.variant {
            SrVariant::FullComplex => {
                let mut fact = ComplexSrFactor::new(&s);
                let delta = {
                    let mut retries = 0;
                    loop {
                        match fact.redamp(lambda).and_then(|()| fact.solve(&force)) {
                            Ok(d) => break d,
                            Err(SolveError::NotPositiveDefinite(_)) if retries < PD_RETRIES => {
                                retries += 1;
                                lambda *= 10.0;
                            }
                            Err(e) => return Err(e),
                        }
                    }
                };
                update_norm =
                    delta.iter().map(|d| d.norm_sqr()).sum::<f64>().sqrt() * self.learning_rate;
                let scaled: Vec<c64> = delta.iter().map(|d| *d * self.learning_rate).collect();
                rbm.apply_update(&scaled);
            }
            SrVariant::RealPart => {
                let force_re: Vec<f64> = force.iter().map(|f| f.re).collect();
                // ℜ[S†S] = S̃ᵀS̃ with S̃ = Concat[ℜS, ℑS] (§3), then the
                // real Algorithm-1 session verbatim.
                let stacked = stack_real_part(&s);
                let solver = CholSolver::default();
                let mut fact = solver.begin(&stacked);
                let (delta, lambda_used, _) =
                    solve_with_backoff(fact.as_mut(), &force_re, lambda, PD_RETRIES)?;
                lambda = lambda_used;
                update_norm =
                    delta.iter().map(|d| d * d).sum::<f64>().sqrt() * self.learning_rate;
                let scaled: Vec<c64> =
                    delta.iter().map(|d| c64::from_re(d * self.learning_rate)).collect();
                rbm.apply_update(&scaled);
            }
        }

        Ok(SrStepReport {
            energy: mean_e.re,
            energy_per_site: mean_e.re / sites as f64,
            energy_std: (var_e / n as f64).sqrt(),
            update_norm,
            lambda,
            acceptance,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vmc::exact::ground_state_energy;

    fn run_sr(variant: SrVariant, iters: usize, seed: u64) -> (f64, f64) {
        let sites = 6;
        let chain = IsingChain::new(sites, 1.0, 1.0);
        let exact = ground_state_energy(&chain, 40_000, 1e-12);
        let mut rng = Rng::seed_from(seed);
        let mut rbm = Rbm::init(sites, 2 * sites, 0.05, &mut rng);
        let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
        for _ in 0..50 {
            sampler.sweep(&rbm, &mut rng); // burn-in
        }
        let mut driver = SrDriver::new(chain, 300, 0.08, 0.05).with_variant(variant);
        let mut last = f64::INFINITY;
        for _ in 0..iters {
            let rep = driver.step(&mut rbm, &mut sampler, &mut rng).unwrap();
            last = rep.energy;
        }
        (last, exact)
    }

    #[test]
    fn full_complex_sr_converges_to_ground_state() {
        let (energy, exact) = run_sr(SrVariant::FullComplex, 120, 320);
        let rel = (energy - exact).abs() / exact.abs();
        assert!(rel < 0.03, "energy {energy:.4} vs exact {exact:.4} (rel {rel:.4})");
    }

    #[test]
    fn real_part_sr_also_converges() {
        let (energy, exact) = run_sr(SrVariant::RealPart, 150, 321);
        let rel = (energy - exact).abs() / exact.abs();
        assert!(rel < 0.05, "energy {energy:.4} vs exact {exact:.4} (rel {rel:.4})");
    }

    #[test]
    fn report_fields_sane() {
        let chain = IsingChain::new(4, 1.0, 0.8);
        let mut rng = Rng::seed_from(322);
        let mut rbm = Rbm::init(4, 8, 0.05, &mut rng);
        let mut sampler = MetropolisSampler::new(&rbm, &mut rng);
        let mut driver = SrDriver::new(chain, 100, 0.05, 0.02);
        let rep = driver.step(&mut rbm, &mut sampler, &mut rng).unwrap();
        assert!(rep.energy.is_finite());
        assert!(rep.energy_std >= 0.0);
        assert!(rep.update_norm > 0.0);
        assert!(rep.acceptance > 0.0 && rep.acceptance <= 1.0);
        assert_eq!(rep.lambda, 0.02 * 0.98);
    }
}
