//! Transverse-field Ising chain:
//! `H = −J Σ_i σᶻ_i σᶻ_{i+1} − h Σ_i σˣ_i` (periodic boundary).
//!
//! σᶻ is diagonal in the computational basis; σˣ flips one spin, so the
//! local energy of configuration `s` under wavefunction ψ is
//!
//! ```text
//! E_loc(s) = −J Σ_i s_i s_{i+1} − h Σ_i ψ(flip_i s)/ψ(s)
//! ```

use crate::linalg::c64;

/// TFIM on a ring of `n` spins.
#[derive(Clone, Debug)]
pub struct IsingChain {
    pub n: usize,
    pub j: f64,
    pub h: f64,
}

impl IsingChain {
    pub fn new(n: usize, j: f64, h: f64) -> Self {
        assert!(n >= 2);
        IsingChain { n, j, h }
    }

    /// Diagonal (σᶻσᶻ) part of the energy for spins ∈ {−1, +1}.
    pub fn diagonal_energy(&self, spins: &[i8]) -> f64 {
        assert_eq!(spins.len(), self.n);
        let mut e = 0.0;
        for i in 0..self.n {
            let jn = (i + 1) % self.n;
            e -= self.j * f64::from(spins[i]) * f64::from(spins[jn]);
        }
        e
    }

    /// Local energy given the wavefunction's amplitude ratios
    /// `ratios[i] = ψ(flip_i s)/ψ(s)`.
    pub fn local_energy(&self, spins: &[i8], ratios: &[c64]) -> c64 {
        assert_eq!(ratios.len(), self.n);
        let mut e = c64::from_re(self.diagonal_energy(spins));
        for r in ratios {
            e -= *r * self.h;
        }
        e
    }

    /// Exact ground-state energy per site in the thermodynamic limit
    /// (Pfeuty 1970): `e₀ = −(1/2π)∫ Λ(k) dk` with
    /// `Λ(k) = 2√(J² + h² − 2Jh·cos k)`. Used as a sanity anchor for
    /// large chains where exact diagonalization is unavailable.
    pub fn thermodynamic_energy_per_site(&self) -> f64 {
        let steps = 20_000;
        let mut acc = 0.0;
        for i in 0..steps {
            let k = std::f64::consts::PI * (2.0 * (i as f64 + 0.5) / steps as f64 - 1.0);
            let lam =
                2.0 * (self.j * self.j + self.h * self.h - 2.0 * self.j * self.h * k.cos()).sqrt();
            acc += lam;
        }
        -acc / steps as f64 / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_energy_ferromagnet() {
        let chain = IsingChain::new(4, 1.0, 0.0);
        // All-up: every bond aligned, E = −J·n.
        assert_eq!(chain.diagonal_energy(&[1, 1, 1, 1]), -4.0);
        // Néel (period 2): every bond anti-aligned, E = +J·n.
        assert_eq!(chain.diagonal_energy(&[1, -1, 1, -1]), 4.0);
    }

    #[test]
    fn local_energy_combines_offdiagonal() {
        let chain = IsingChain::new(3, 1.0, 0.5);
        let ratios = vec![c64::from_re(0.2); 3];
        let e = chain.local_energy(&[1, 1, 1], &ratios);
        // diag = −3, offdiag = −0.5·(0.2·3) = −0.3
        assert!((e.re + 3.3).abs() < 1e-12);
        assert!(e.im.abs() < 1e-12);
    }

    #[test]
    fn pfeuty_known_points() {
        // h = J (critical): e₀ = −4/π per site.
        let crit = IsingChain::new(10, 1.0, 1.0);
        assert!((crit.thermodynamic_energy_per_site() + 4.0 / std::f64::consts::PI).abs() < 1e-4);
        // h = 0: classical ferromagnet, e₀ = −J.
        let classical = IsingChain::new(10, 1.0, 0.0);
        assert!((classical.thermodynamic_energy_per_site() + 1.0).abs() < 1e-6);
        // J = 0: free spins in x-field, e₀ = −h.
        let free = IsingChain::new(10, 0.0, 2.0);
        assert!((free.thermodynamic_energy_per_site() + 2.0).abs() < 1e-6);
    }
}
