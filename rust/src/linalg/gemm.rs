//! Matrix-multiplication front-ends over the packed kernel engine.
//!
//! Everything is row-major; since PR 1 all of these are thin shape-checked
//! wrappers around the packed, register-blocked engine in [`kernel`]
//! (BLIS-style MR×NR micro-kernel with KC/MC/NC cache blocking — see the
//! module docs there and EXPERIMENTS.md §Perf for measured numbers):
//!
//! * [`gemm`]    `C = α·A·B + β·C`
//! * [`gemm_nt`] `C = α·A·Bᵀ + β·C`
//! * [`gemm_tn`] `C = α·Aᵀ·B + β·C`
//! * [`syrk`]    `W = A·Aᵀ + λI`        — the Gram matrix of Algorithm 1
//!   line 1; lower-triangle-aware (half the FLOPs), mirrored at the end.
//! * [`syrk_parallel`] — SYRK with MC-row panels dealt round-robin to the
//!   persistent [`kernel::global_pool`] workers; bit-identical to the
//!   serial sweep for every thread count (each panel is a pure function
//!   of `(A, panel range)`).
//!
//! Since PR 3 the general products are threaded too: [`gemm_threaded`],
//! [`gemm_nt_threaded`] and [`gemm_tn_threaded`] route through
//! [`kernel::dgemm_threaded`], which deals contiguous MC-row bands of C
//! to the same persistent pool — also bit-identical to serial at every
//! thread count (the C-partition never changes a per-element summation
//! order; see the determinism notes in [`kernel`]). The sessions use
//! these for their multi-RHS panel products (`S·Vᵀ`, `Sᵀ·Z`, `SᵀS`, the
//! eigh `V = SᵀUΣ⁻¹` tall GEMM), so `solver.threads` reaches every
//! stage of Algorithm 1, not just the Gram.
//!
//! Since PR 4 every front-end dispatches on the process's [`KernelIsa`]
//! tier (explicit AVX2/AVX-512/NEON micro-kernels, scalar fallback —
//! see [`kernel`] and [`simd`](super::simd)): within a fixed tier the
//! threaded products stay bit-identical to serial at every thread count
//! (the parallel dispatchers re-establish the caller's tier inside
//! their pool jobs); across tiers results are only tolerance-equal,
//! with [`reference`] as the oracle.
//!
//! Since PR 6 the Gram has an f32 twin — [`syrk_f32`] /
//! [`syrk_parallel_f32`] over [`kernel::syrk_panel_f32`] — feeding the
//! mixed-precision sessions (f32 factorization + f64 iterative
//! refinement; see `solver/chol.rs`). The f32 sweep keeps the same
//! MC-panel partition, so the threaded variant is bit-identical to
//! serial within a tier, exactly like the f64 one.
//!
//! The seed's scalar dot/axpy kernels live on in [`reference`] as test
//! oracles and as the before/after baseline for the kernel benchmarks
//! (`benches/gemm.rs` → `BENCH_PR1.json`, `BENCH_PR4.json`).

use super::kernel::{self, Trans};
use super::mat::Mat;

pub use super::kernel::{KernelConfig, KernelIsa, KC, MC, MR, NR};

/// `C = alpha * A * B + beta * C`, shapes `(p×q)·(q×r) → p×r`.
pub fn gemm(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (p, q) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm inner dims {q} vs {q2}");
    assert_eq!(c.shape(), (p, r), "gemm output shape");
    kernel::dgemm(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        q,
        Trans::N,
        b.as_slice(),
        r,
        Trans::N,
        beta,
        c.as_mut_slice(),
        r,
    );
}

/// `C = alpha * A * Bᵀ + beta * C`, shapes `(p×q)·(r×q)ᵀ → p×r`.
///
/// The packing stage absorbs the transpose (B is read column-panel-wise),
/// so unlike the seed's row-dot implementation this no longer degrades to
/// quadratic cache thrashing at square bench sizes.
pub fn gemm_nt(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (p, q) = a.shape();
    let (r, q2) = b.shape();
    assert_eq!(q, q2, "gemm_nt inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_nt output shape");
    kernel::dgemm(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        q,
        Trans::N,
        b.as_slice(),
        q,
        Trans::T,
        beta,
        c.as_mut_slice(),
        r,
    );
}

/// `C = alpha * Aᵀ * B + beta * C`, shapes `(q×p)ᵀ·(q×r) → p×r`.
///
/// Never materializes `Aᵀ` — the A-packing reads the buffer transposed.
/// This is the memory-access pattern of Algorithm-1 line 4's `Sᵀ(L⁻ᵀu)`
/// when u is a block of vectors.
pub fn gemm_tn(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (q, p) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm_tn inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_tn output shape");
    kernel::dgemm(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        p,
        Trans::T,
        b.as_slice(),
        r,
        Trans::N,
        beta,
        c.as_mut_slice(),
        r,
    );
}

/// Threaded [`gemm`]: `C = alpha · A · B + beta · C` with MC-row bands
/// of C dealt across the persistent kernel pool. Bit-identical to the
/// serial product for every thread count.
pub fn gemm_threaded(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat, threads: usize) {
    let (p, q) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm inner dims {q} vs {q2}");
    assert_eq!(c.shape(), (p, r), "gemm output shape");
    kernel::dgemm_threaded(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        q,
        Trans::N,
        b.as_slice(),
        r,
        Trans::N,
        beta,
        c.as_mut_slice(),
        r,
        threads,
    );
}

/// Threaded [`gemm_nt`]: `C = alpha · A · Bᵀ + beta · C` on the pool,
/// bit-identical to serial at every thread count.
pub fn gemm_nt_threaded(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat, threads: usize) {
    let (p, q) = a.shape();
    let (r, q2) = b.shape();
    assert_eq!(q, q2, "gemm_nt inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_nt output shape");
    kernel::dgemm_threaded(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        q,
        Trans::N,
        b.as_slice(),
        q,
        Trans::T,
        beta,
        c.as_mut_slice(),
        r,
        threads,
    );
}

/// Threaded [`gemm_tn`]: `C = alpha · Aᵀ · B + beta · C` on the pool,
/// bit-identical to serial at every thread count. This is the shape of
/// the sessions' `Sᵀ·Z` multi-RHS pass and the naive solver's `SᵀS`.
pub fn gemm_tn_threaded(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat, threads: usize) {
    let (q, p) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm_tn inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_tn output shape");
    kernel::dgemm_threaded(
        p,
        r,
        q,
        alpha,
        a.as_slice(),
        p,
        Trans::T,
        b.as_slice(),
        r,
        Trans::N,
        beta,
        c.as_mut_slice(),
        r,
        threads,
    );
}

/// Mirror the computed lower triangle into the upper one and damp the
/// diagonal — the tail step shared by serial and parallel SYRK.
fn mirror_and_damp(w: &mut Mat, lambda: f64) {
    let n = w.rows();
    for i in 0..n {
        for j in 0..i {
            w[(j, i)] = w[(i, j)];
        }
        w[(i, i)] += lambda;
    }
}

/// Symmetric rank-k update: `W = A·Aᵀ + lambda·I` for `A: n×m`.
///
/// This is **line 1 of Algorithm 1** — the only O(n²m) step — so it gets
/// the most care: MC row panels of W are produced by the packed engine's
/// triangle-aware [`kernel::syrk_panel`] (only micro-tiles touching the
/// lower triangle are computed), and the upper triangle is mirrored at
/// the end. The serial sweep visits exactly the panels the parallel
/// version deals out, so both produce bit-identical results.
pub fn syrk(a: &Mat, lambda: f64) -> Mat {
    kernel::counters::record_syrk();
    let (n, m) = a.shape();
    let mut w = Mat::zeros(n, n);
    if n > 0 && m > 0 {
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            let wrows = &mut w.as_mut_slice()[i0 * n..i1 * n];
            kernel::syrk_panel(a.as_slice(), n, m, i0, i1, wrows);
            i0 = i1;
        }
    }
    mirror_and_damp(&mut w, lambda);
    w
}

use super::kernel::{SendConst, SendMut};

/// Multi-threaded SYRK on the persistent kernel pool.
///
/// MC-row panels of W are dealt round-robin across `threads` jobs (work
/// per panel grows with the row index, so round-robin balances the
/// triangular load). Each job computes its panels with the same
/// [`kernel::syrk_panel`] the serial path uses, writing disjoint row
/// ranges of W — the result is **bit-identical** for every thread count,
/// including 1 (pinned by a test). Workers are persistent
/// ([`kernel::global_pool`]): repeated solves do not respawn threads the
/// way the seed's per-call `std::thread::scope` did.
pub fn syrk_parallel(a: &Mat, lambda: f64, threads: usize) -> Mat {
    let (n, m) = a.shape();
    if threads <= 1 || n < 64 {
        return syrk(a, lambda);
    }
    kernel::counters::record_syrk();
    let panels: Vec<(usize, usize)> = {
        let mut v = Vec::new();
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            v.push((i0, i1));
            i0 = i1;
        }
        v
    };
    let threads = threads.min(panels.len()).max(1);
    let mut w = Mat::zeros(n, n);
    {
        // Captured once: every job re-establishes the caller's tier so
        // a scoped with_isa override stays bit-identical to serial.
        let isa = kernel::active_isa();
        let aptr = SendConst(a.as_slice().as_ptr());
        let wptr = SendMut(w.as_mut_slice().as_mut_ptr());
        let mut jobs: Vec<kernel::KernelJob> = Vec::with_capacity(threads);
        for t in 0..threads {
            let mine: Vec<(usize, usize)> = panels
                .iter()
                .enumerate()
                .filter(|(idx, _)| idx % threads == t)
                .map(|(_, &p)| p)
                .collect();
            if mine.is_empty() {
                continue;
            }
            jobs.push(Box::new(move || {
                // SAFETY: A is only read; each job's W rows are disjoint
                // from every other job's; run() below blocks until all
                // jobs complete, so the caller's borrows stay live.
                kernel::with_isa(isa, || {
                    let adata = unsafe { std::slice::from_raw_parts(aptr.0, n * m) };
                    for &(i0, i1) in &mine {
                        let wrows = unsafe {
                            std::slice::from_raw_parts_mut(wptr.0.add(i0 * n), (i1 - i0) * n)
                        };
                        kernel::syrk_panel(adata, n, m, i0, i1, wrows);
                    }
                });
            }));
        }
        kernel::global_pool().run(jobs);
    }
    mirror_and_damp(&mut w, lambda);
    w
}

/// Mirror/damp tail step for the f32 Gram (raw row-major slice — the
/// f32 path has no `Mat` wrapper).
fn mirror_and_damp_f32(w: &mut [f32], n: usize, lambda: f32) {
    for i in 0..n {
        for j in 0..i {
            w[j * n + i] = w[i * n + j];
        }
        w[i * n + i] += lambda;
    }
}

/// f32 symmetric rank-k update: `W = A·Aᵀ + lambda·I` for row-major
/// `A: n×m` (PR 6 — the mixed-precision Gram of Algorithm 1 line 1).
///
/// Same structure as [`syrk`]: MC row panels through the
/// triangle-aware [`kernel::syrk_panel_f32`], upper triangle mirrored
/// at the end. The mixed-precision sessions pass `lambda = 0` and
/// overwrite the diagonal with an f64-accumulated damped diagonal
/// afterwards (see `solver/chol.rs`), so single-precision cancellation
/// never touches the damping term.
pub fn syrk_f32(a: &[f32], n: usize, m: usize, lambda: f32, w: &mut [f32]) {
    assert_eq!(a.len(), n * m, "syrk_f32 A shape");
    assert_eq!(w.len(), n * n, "syrk_f32 W shape");
    kernel::counters::record_syrk();
    w.fill(0.0);
    if n > 0 && m > 0 {
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            kernel::syrk_panel_f32(a, n, m, i0, i1, &mut w[i0 * n..i1 * n]);
            i0 = i1;
        }
    }
    mirror_and_damp_f32(w, n, lambda);
}

use super::kernel::{SendConstF32, SendMutF32};

/// Multi-threaded [`syrk_f32`] on the persistent kernel pool — the same
/// round-robin MC-panel deal as [`syrk_parallel`], so it is likewise
/// **bit-identical** to the serial sweep for every thread count within
/// a fixed ISA tier (each job re-establishes the caller's tier).
pub fn syrk_parallel_f32(a: &[f32], n: usize, m: usize, lambda: f32, w: &mut [f32], threads: usize) {
    assert_eq!(a.len(), n * m, "syrk_parallel_f32 A shape");
    assert_eq!(w.len(), n * n, "syrk_parallel_f32 W shape");
    if threads <= 1 || n < 64 {
        return syrk_f32(a, n, m, lambda, w);
    }
    kernel::counters::record_syrk();
    w.fill(0.0);
    let panels: Vec<(usize, usize)> = {
        let mut v = Vec::new();
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            v.push((i0, i1));
            i0 = i1;
        }
        v
    };
    let threads = threads.min(panels.len()).max(1);
    {
        let isa = kernel::active_isa();
        let aptr = SendConstF32(a.as_ptr());
        let wptr = SendMutF32(w.as_mut_ptr());
        let mut jobs: Vec<kernel::KernelJob> = Vec::with_capacity(threads);
        for t in 0..threads {
            let mine: Vec<(usize, usize)> = panels
                .iter()
                .enumerate()
                .filter(|(idx, _)| idx % threads == t)
                .map(|(_, &p)| p)
                .collect();
            if mine.is_empty() {
                continue;
            }
            jobs.push(Box::new(move || {
                // SAFETY: A is only read; each job's W rows are disjoint
                // from every other job's; run() below blocks until all
                // jobs complete, so the caller's borrows stay live.
                kernel::with_isa(isa, || {
                    let adata = unsafe { std::slice::from_raw_parts(aptr.0, n * m) };
                    for &(i0, i1) in &mine {
                        let wrows = unsafe {
                            std::slice::from_raw_parts_mut(wptr.0.add(i0 * n), (i1 - i0) * n)
                        };
                        kernel::syrk_panel_f32(adata, n, m, i0, i1, wrows);
                    }
                });
            }));
        }
        kernel::global_pool().run(jobs);
    }
    mirror_and_damp_f32(w, n, lambda);
}

/// The seed's scalar kernels, kept verbatim as independent test oracles
/// and as the pre-PR1 baseline for the kernel benchmarks. Do not use on
/// hot paths.
pub mod reference {
    use crate::linalg::mat::Mat;
    use crate::linalg::simd::{dot_isa, KernelIsa};

    /// The seed's 16-way-unrolled scalar dot, pinned to the scalar tier
    /// so the reference stays tier-independent (PR 4: `mat::dot` now
    /// dispatches on the active ISA tier — an oracle that varied with
    /// the ambient tier would no longer be the seed arithmetic, and the
    /// PR-1 baseline bench rows would silently vectorize).
    fn dot(a: &[f64], b: &[f64]) -> f64 {
        dot_isa(KernelIsa::Scalar, a, b)
    }

    /// Scalar KC-tiled SYRK (the seed implementation of Algorithm 1
    /// line 1): per-element row dots, LLVM-autovectorized only.
    pub fn syrk_scalar(a: &Mat, lambda: f64) -> Mat {
        let (n, m) = a.shape();
        let mut w = Mat::zeros(n, n);
        let mut k0 = 0;
        while k0 < m {
            let k1 = (k0 + super::KC).min(m);
            let mut i0 = 0;
            while i0 < n {
                let i1 = (i0 + super::MC).min(n);
                for i in i0..i1 {
                    let arow_i = &a.row(i)[k0..k1];
                    for j in 0..=i {
                        let arow_j = &a.row(j)[k0..k1];
                        w[(i, j)] += dot(arow_i, arow_j);
                    }
                }
                i0 = i1;
            }
            k0 = k1;
        }
        for i in 0..n {
            for j in 0..i {
                w[(j, i)] = w[(i, j)];
            }
            w[(i, i)] += lambda;
        }
        w
    }

    /// Scalar untiled NT product (the seed `gemm_nt`): row-pair dots,
    /// quadratic cache behaviour at square sizes.
    pub fn gemm_nt_scalar(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
        let (p, q) = a.shape();
        let (r, q2) = b.shape();
        assert_eq!(q, q2, "gemm_nt inner dims");
        assert_eq!(c.shape(), (p, r), "gemm_nt output shape");
        for i in 0..p {
            let arow = a.row(i);
            for j in 0..r {
                let v = alpha * dot(arow, b.row(j));
                let cij = &mut c.row_mut(i)[j];
                *cij = v + beta * *cij;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn naive_gemm(a: &Mat, b: &Mat) -> Mat {
        let (p, q) = a.shape();
        let (_, r) = b.shape();
        Mat::from_fn(p, r, |i, j| (0..q).map(|k| a[(i, k)] * b[(k, j)]).sum())
    }

    fn assert_close(got: &Mat, want: &Mat, tol: f64, what: &str) {
        assert_eq!(got.shape(), want.shape(), "{what} shape");
        let (rows, cols) = got.shape();
        for i in 0..rows {
            for j in 0..cols {
                let (x, y) = (got[(i, j)], want[(i, j)]);
                assert!(
                    (x - y).abs() < tol,
                    "{what}: mismatch at ({i},{j}) of {rows}x{cols}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::seed_from(10);
        for &(p, q, r) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 300, 16)] {
            let a = Mat::randn(p, q, &mut rng);
            let b = Mat::randn(q, r, &mut rng);
            let mut c = Mat::zeros(p, r);
            gemm(1.0, &a, &b, 0.0, &mut c);
            let expect = naive_gemm(&a, &b);
            assert_close(&c, &expect, 1e-10, &format!("gemm ({p},{q},{r})"));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Rng::seed_from(11);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let c0 = Mat::randn(4, 3, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, -1.0, &mut c);
        let expect = {
            let mut e = naive_gemm(&a, &b);
            e.scale(2.0);
            e.axpy(-1.0, &c0);
            e
        };
        assert_close(&c, &expect, 1e-10, "gemm alpha/beta");
    }

    #[test]
    fn gemm_nt_matches_gemm_with_transpose() {
        let mut rng = Rng::seed_from(12);
        let a = Mat::randn(5, 7, &mut rng);
        let b = Mat::randn(9, 7, &mut rng);
        let mut c = Mat::zeros(5, 9);
        gemm_nt(1.0, &a, &b, 0.0, &mut c);
        let expect = naive_gemm(&a, &b.transpose());
        assert_close(&c, &expect, 1e-10, "gemm_nt");
    }

    #[test]
    fn gemm_tn_matches_gemm_with_transpose() {
        let mut rng = Rng::seed_from(13);
        let a = Mat::randn(7, 5, &mut rng);
        let b = Mat::randn(7, 4, &mut rng);
        let mut c = Mat::zeros(5, 4);
        gemm_tn(1.0, &a, &b, 0.0, &mut c);
        let expect = naive_gemm(&a.transpose(), &b);
        assert_close(&c, &expect, 1e-10, "gemm_tn");
    }

    /// Non-multiples of (MR, NR, KC, MC): primes, 1s, and ±1 around every
    /// blocking parameter, driven through all three layout front-ends.
    #[test]
    fn packed_engine_edge_shapes_match_naive() {
        let mut rng = Rng::seed_from(18);
        let dims =
            [1, 2, MR - 1, MR + 1, NR + 1, 13, 31, MC - 1, MC + 1, KC - 1, KC + 1];
        for (t, &(p, q, r)) in [
            (dims[0], dims[4], dims[5]),
            (dims[2], dims[9], dims[3]),
            (dims[7], dims[10], dims[1]),
            (dims[6], dims[8], dims[4]),
            (1, KC + 1, 1),
            (MC + 1, 3, NR + 1),
        ]
        .iter()
        .enumerate()
        {
            let a = Mat::randn(p, q, &mut rng);
            let b = Mat::randn(q, r, &mut rng);
            let expect = naive_gemm(&a, &b);

            let mut c = Mat::zeros(p, r);
            gemm(1.0, &a, &b, 0.0, &mut c);
            assert_close(&c, &expect, 1e-9, &format!("edge gemm #{t} ({p},{q},{r})"));

            let bt = b.transpose();
            let mut c = Mat::zeros(p, r);
            gemm_nt(1.0, &a, &bt, 0.0, &mut c);
            assert_close(&c, &expect, 1e-9, &format!("edge gemm_nt #{t} ({p},{q},{r})"));

            let at = a.transpose();
            let mut c = Mat::zeros(p, r);
            gemm_tn(1.0, &at, &b, 0.0, &mut c);
            assert_close(&c, &expect, 1e-9, &format!("edge gemm_tn #{t} ({p},{q},{r})"));
        }
    }

    #[test]
    fn syrk_matches_a_at_plus_lambda() {
        let mut rng = Rng::seed_from(14);
        for &(n, m) in &[(1, 1), (5, 3), (8, 1000), (70, 130), (KC + 1, KC - 1)] {
            let a = Mat::randn(n, m, &mut rng);
            let w = syrk(&a, 0.5);
            let mut expect = naive_gemm(&a, &a.transpose());
            expect.add_diag(0.5);
            assert_close(&w, &expect, 1e-8, &format!("syrk n={n} m={m}"));
        }
    }

    #[test]
    fn syrk_matches_scalar_reference() {
        let mut rng = Rng::seed_from(19);
        for &(n, m) in &[(3, 17), (65, 129), (150, KC + 7)] {
            let a = Mat::randn(n, m, &mut rng);
            let packed = syrk(&a, 0.25);
            let scalar = reference::syrk_scalar(&a, 0.25);
            assert_close(&packed, &scalar, 1e-9, &format!("syrk vs scalar n={n} m={m}"));
        }
    }

    #[test]
    fn gemm_nt_matches_scalar_reference() {
        let mut rng = Rng::seed_from(23);
        let a = Mat::randn(33, 71, &mut rng);
        let b = Mat::randn(29, 71, &mut rng);
        let c0 = Mat::randn(33, 29, &mut rng);
        let mut packed = c0.clone();
        gemm_nt(1.5, &a, &b, 0.5, &mut packed);
        let mut scalar = c0.clone();
        reference::gemm_nt_scalar(1.5, &a, &b, 0.5, &mut scalar);
        assert_close(&packed, &scalar, 1e-10, "gemm_nt vs scalar");
    }

    #[test]
    fn syrk_is_symmetric() {
        let mut rng = Rng::seed_from(15);
        let a = Mat::randn(33, 77, &mut rng);
        let w = syrk(&a, 1e-3);
        for i in 0..33 {
            for j in 0..33 {
                assert_eq!(w[(i, j)], w[(j, i)]);
            }
        }
    }

    #[test]
    fn syrk_parallel_matches_serial() {
        let mut rng = Rng::seed_from(16);
        for &threads in &[2, 3, 8] {
            let a = Mat::randn(150, 220, &mut rng);
            let serial = syrk(&a, 0.1);
            let par = syrk_parallel(&a, 0.1, threads);
            assert_close(&par, &serial, 1e-9, &format!("syrk_parallel t={threads}"));
        }
    }

    /// Threaded SYRK is deterministic: bit-identical output for every
    /// thread count, because each MC panel's computation is a pure
    /// function of (A, panel range) with a fixed accumulation order.
    #[test]
    fn syrk_parallel_bit_identical_across_thread_counts() {
        let mut rng = Rng::seed_from(24);
        // n > 64 with a non-multiple-of-MC panel tail; m off the KC grid.
        let a = Mat::randn(MC + 37, KC + 13, &mut rng);
        let baseline = syrk_parallel(&a, 1e-3, 1);
        assert_eq!(baseline.as_slice(), syrk(&a, 1e-3).as_slice(), "threads=1 vs serial");
        for &threads in &[2usize, 8] {
            let w = syrk_parallel(&a, 1e-3, threads);
            assert_eq!(
                w.as_slice(),
                baseline.as_slice(),
                "threads={threads} not bit-identical to threads=1"
            );
        }
    }

    #[test]
    fn syrk_f32_tracks_f64_within_single_precision() {
        let mut rng = Rng::seed_from(25);
        for &(n, m) in &[(1, 1), (5, 3), (70, 130), (150, KC + 7)] {
            let a = Mat::randn(n, m, &mut rng);
            let a32: Vec<f32> = a.as_slice().iter().map(|&x| x as f32).collect();
            let mut w32 = vec![0.0f32; n * n];
            syrk_f32(&a32, n, m, 0.5, &mut w32);
            let w64 = syrk(&a, 0.5);
            // Entries are sums of m products of O(1) values: absolute
            // error scales like eps32 · m.
            let tol = 1e-5 * (m as f64) + 1e-5;
            for i in 0..n {
                for j in 0..n {
                    let (x, y) = (w32[i * n + j] as f64, w64[(i, j)]);
                    assert!(
                        (x - y).abs() < tol,
                        "syrk_f32 n={n} m={m} at ({i},{j}): {x} vs {y}"
                    );
                }
            }
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(w32[i * n + j].to_bits(), w32[j * n + i].to_bits());
                }
            }
        }
    }

    #[test]
    fn syrk_parallel_f32_bit_identical_across_thread_counts() {
        let mut rng = Rng::seed_from(26);
        let a = Mat::randn(MC + 37, KC + 13, &mut rng);
        let (n, m) = a.shape();
        let a32: Vec<f32> = a.as_slice().iter().map(|&x| x as f32).collect();
        let mut baseline = vec![0.0f32; n * n];
        syrk_f32(&a32, n, m, 1e-3, &mut baseline);
        for &threads in &[1usize, 2, 8] {
            let mut w = vec![0.0f32; n * n];
            syrk_parallel_f32(&a32, n, m, 1e-3, &mut w, threads);
            assert!(
                w.iter().zip(&baseline).all(|(x, y)| x.to_bits() == y.to_bits()),
                "syrk_parallel_f32 threads={threads} not bit-identical to serial"
            );
        }
    }

    #[test]
    fn syrk_parallel_small_falls_back() {
        let mut rng = Rng::seed_from(17);
        let a = Mat::randn(10, 20, &mut rng);
        let par = syrk_parallel(&a, 0.0, 4);
        let ser = syrk(&a, 0.0);
        assert_eq!(par, ser);
    }
}
