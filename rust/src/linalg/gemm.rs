//! Blocked matrix-multiplication kernels.
//!
//! Everything is row-major, so each kernel picks the loop order that keeps
//! the inner loop streaming over contiguous rows:
//!
//! * [`gemm`]    `C = α·A·B + β·C`      — i,k,j order (axpy over C rows)
//! * [`gemm_nt`] `C = α·A·Bᵀ + β·C`     — dot products of row pairs
//! * [`gemm_tn`] `C = α·Aᵀ·B + β·C`     — rank-1 updates over C rows
//! * [`syrk`]    `W = A·Aᵀ + λI`        — the Gram matrix of Algorithm 1
//!   line 1; exploits symmetry (computes the lower triangle, mirrors).
//!
//! Cache blocking: the k (reduction) dimension is tiled with [`KC`] so a
//! panel of `A` stays resident in L2 while it sweeps `B`. The micro-kernel
//! level is left to LLVM auto-vectorization of the unrolled
//! [`dot`](super::mat::dot) / axpy bodies, which reaches within ~2× of
//! hand-written AVX2 for f64 on this testbed (see EXPERIMENTS.md §Perf).

use super::mat::{axpy, dot, Mat};

/// Reduction-dimension tile: KC·8 bytes · (row of A + row of B) per
/// iteration ≈ 4 KiB, comfortably inside L1 alongside the C row.
pub const KC: usize = 256;

/// Row tile for the packed SYRK/NT kernels (panel of MC rows of A in L2).
pub const MC: usize = 64;

/// `C = alpha * A * B + beta * C`, shapes `(p×q)·(q×r) → p×r`.
pub fn gemm(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (p, q) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm inner dims {q} vs {q2}");
    assert_eq!(c.shape(), (p, r), "gemm output shape");
    if beta != 1.0 {
        c.scale(beta);
    }
    // Tile the reduction so B's working set per sweep is KC rows.
    let mut k0 = 0;
    while k0 < q {
        let k1 = (k0 + KC).min(q);
        for i in 0..p {
            let arow = &a.row(i)[k0..k1];
            let crow = c.row_mut(i);
            for (kk, &aik) in arow.iter().enumerate() {
                if aik != 0.0 {
                    axpy(alpha * aik, b.row(k0 + kk), crow);
                }
            }
        }
        k0 = k1;
    }
}

/// `C = alpha * A * Bᵀ + beta * C`, shapes `(p×q)·(r×q)ᵀ → p×r`.
///
/// Row-major heaven: every entry is a dot product of two contiguous rows.
pub fn gemm_nt(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (p, q) = a.shape();
    let (r, q2) = b.shape();
    assert_eq!(q, q2, "gemm_nt inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_nt output shape");
    for i in 0..p {
        let arow = a.row(i);
        for j in 0..r {
            let v = alpha * dot(arow, b.row(j));
            let cij = &mut c.row_mut(i)[j];
            *cij = v + beta * *cij;
        }
    }
}

/// `C = alpha * Aᵀ * B + beta * C`, shapes `(q×p)ᵀ·(q×r) → p×r`.
///
/// Never materializes `Aᵀ`: streams A and B row-by-row doing rank-1
/// updates of C. This is the memory-access pattern of Algorithm-1 line 4's
/// `Sᵀ(L⁻ᵀu)` when u is a block of vectors.
pub fn gemm_tn(alpha: f64, a: &Mat, b: &Mat, beta: f64, c: &mut Mat) {
    let (q, p) = a.shape();
    let (q2, r) = b.shape();
    assert_eq!(q, q2, "gemm_tn inner dims");
    assert_eq!(c.shape(), (p, r), "gemm_tn output shape");
    if beta != 1.0 {
        c.scale(beta);
    }
    for i in 0..q {
        let arow = a.row(i);
        let brow = b.row(i);
        for j in 0..p {
            let aij = alpha * arow[j];
            if aij != 0.0 {
                axpy(aij, brow, c.row_mut(j));
            }
        }
    }
}

/// Symmetric rank-k update: `W = A·Aᵀ + lambda·I` for `A: n×m`.
///
/// This is **line 1 of Algorithm 1** — the only O(n²m) step — so it gets
/// the most care: only the lower triangle is computed (half the FLOPs of a
/// general NT product), the reduction is KC-tiled, and row panels of MC
/// rows keep the A panel hot in L2 while it is reused n/2 times on
/// average. The upper triangle is mirrored at the end.
pub fn syrk(a: &Mat, lambda: f64) -> Mat {
    let (n, m) = a.shape();
    let mut w = Mat::zeros(n, n);
    let mut k0 = 0;
    while k0 < m {
        let k1 = (k0 + KC).min(m);
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            for i in i0..i1 {
                let arow_i = &a.row(i)[k0..k1];
                for j in 0..=i {
                    let arow_j = &a.row(j)[k0..k1];
                    w[(i, j)] += dot(arow_i, arow_j);
                }
            }
            i0 = i1;
        }
        k0 = k1;
    }
    // Mirror lower → upper and damp the diagonal.
    for i in 0..n {
        for j in 0..i {
            w[(j, i)] = w[(i, j)];
        }
        w[(i, i)] += lambda;
    }
    w
}

/// Multi-threaded SYRK: partitions the *row panels* of W across `threads`
/// OS threads (std::thread::scope — no pool dependency). Work per panel i
/// is proportional to i, so panels are dealt round-robin to balance load.
pub fn syrk_parallel(a: &Mat, lambda: f64, threads: usize) -> Mat {
    let (n, m) = a.shape();
    if threads <= 1 || n < 64 {
        return syrk(a, lambda);
    }
    let mut w = Mat::zeros(n, n);
    {
        // Each thread owns a disjoint set of rows of W (round-robin by
        // MC-panel so triangular work is balanced). Rows are handed out
        // via raw pointers into disjoint row ranges — safe because the
        // panels never overlap.
        let wptr = SendPtr(w.as_mut_slice().as_mut_ptr());
        std::thread::scope(|scope| {
            for t in 0..threads {
                let a_ref = &a;
                scope.spawn(move || {
                    let wp = wptr; // capture the Send wrapper by copy
                    let mut panel = 0usize;
                    let mut i0 = 0usize;
                    while i0 < n {
                        let i1 = (i0 + MC).min(n);
                        if panel % threads == t {
                            let mut k0 = 0;
                            while k0 < m {
                                let k1 = (k0 + KC).min(m);
                                for i in i0..i1 {
                                    let arow_i = &a_ref.row(i)[k0..k1];
                                    for j in 0..=i {
                                        let arow_j = &a_ref.row(j)[k0..k1];
                                        // SAFETY: row i of W is owned
                                        // exclusively by this thread.
                                        unsafe {
                                            *wp.0.add(i * n + j) += dot(arow_i, arow_j);
                                        }
                                    }
                                }
                                k0 = k1;
                            }
                        }
                        panel += 1;
                        i0 = i1;
                    }
                });
            }
        });
    }
    for i in 0..n {
        for j in 0..i {
            w[(j, i)] = w[(i, j)];
        }
        w[(i, i)] += lambda;
    }
    w
}

#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
// SAFETY: threads write disjoint rows; synchronization is the scope join.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn naive_gemm(a: &Mat, b: &Mat) -> Mat {
        let (p, q) = a.shape();
        let (_, r) = b.shape();
        Mat::from_fn(p, r, |i, j| (0..q).map(|k| a[(i, k)] * b[(k, j)]).sum())
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Rng::seed_from(10);
        for &(p, q, r) in &[(1, 1, 1), (3, 4, 5), (17, 33, 9), (64, 300, 16)] {
            let a = Mat::randn(p, q, &mut rng);
            let b = Mat::randn(q, r, &mut rng);
            let mut c = Mat::zeros(p, r);
            gemm(1.0, &a, &b, 0.0, &mut c);
            let expect = naive_gemm(&a, &b);
            assert!((&c.as_slice().iter().zip(expect.as_slice()))
                .clone()
                .all(|(x, y)| (x - y).abs() < 1e-10));
        }
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Rng::seed_from(11);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let c0 = Mat::randn(4, 3, &mut rng);
        let mut c = c0.clone();
        gemm(2.0, &a, &b, -1.0, &mut c);
        let expect = {
            let mut e = naive_gemm(&a, &b);
            e.scale(2.0);
            e.axpy(-1.0, &c0);
            e
        };
        for (x, y) in c.as_slice().iter().zip(expect.as_slice()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn gemm_nt_matches_gemm_with_transpose() {
        let mut rng = Rng::seed_from(12);
        let a = Mat::randn(5, 7, &mut rng);
        let b = Mat::randn(9, 7, &mut rng);
        let mut c = Mat::zeros(5, 9);
        gemm_nt(1.0, &a, &b, 0.0, &mut c);
        let expect = naive_gemm(&a, &b.transpose());
        for (x, y) in c.as_slice().iter().zip(expect.as_slice()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn gemm_tn_matches_gemm_with_transpose() {
        let mut rng = Rng::seed_from(13);
        let a = Mat::randn(7, 5, &mut rng);
        let b = Mat::randn(7, 4, &mut rng);
        let mut c = Mat::zeros(5, 4);
        gemm_tn(1.0, &a, &b, 0.0, &mut c);
        let expect = naive_gemm(&a.transpose(), &b);
        for (x, y) in c.as_slice().iter().zip(expect.as_slice()) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn syrk_matches_a_at_plus_lambda() {
        let mut rng = Rng::seed_from(14);
        for &(n, m) in &[(1, 1), (5, 3), (8, 1000), (70, 130)] {
            let a = Mat::randn(n, m, &mut rng);
            let w = syrk(&a, 0.5);
            let mut expect = naive_gemm(&a, &a.transpose());
            expect.add_diag(0.5);
            for (x, y) in w.as_slice().iter().zip(expect.as_slice()) {
                assert!((x - y).abs() < 1e-8, "syrk mismatch at n={n} m={m}");
            }
        }
    }

    #[test]
    fn syrk_is_symmetric() {
        let mut rng = Rng::seed_from(15);
        let a = Mat::randn(33, 77, &mut rng);
        let w = syrk(&a, 1e-3);
        for i in 0..33 {
            for j in 0..33 {
                assert_eq!(w[(i, j)], w[(j, i)]);
            }
        }
    }

    #[test]
    fn syrk_parallel_matches_serial() {
        let mut rng = Rng::seed_from(16);
        for &threads in &[2, 3, 8] {
            let a = Mat::randn(150, 220, &mut rng);
            let serial = syrk(&a, 0.1);
            let par = syrk_parallel(&a, 0.1, threads);
            for (x, y) in par.as_slice().iter().zip(serial.as_slice()) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn syrk_parallel_small_falls_back() {
        let mut rng = Rng::seed_from(17);
        let a = Mat::randn(10, 20, &mut rng);
        let par = syrk_parallel(&a, 0.0, 4);
        let ser = syrk(&a, 0.0);
        assert_eq!(par, ser);
    }
}
