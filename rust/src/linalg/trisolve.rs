//! Triangular solves — lines 3–4 of Algorithm 1.
//!
//! The paper stresses that `Q = L⁻¹S` should **not** be materialized;
//! instead `QᵀQv = SᵀL⁻ᵀL⁻¹Sv` is evaluated right-to-left:
//!
//! ```text
//! u  = S v          (matvec, O(nm))
//! y  = L⁻¹ u        (forward substitution, O(n²))
//! z  = L⁻ᵀ y        (backward substitution, O(n²))
//! out = Sᵀ z        (transposed matvec, O(nm))
//! ```
//!
//! This module provides the two substitutions for vectors and the blocked
//! multi-RHS variants (`trsm`) used when solving for a block of gradient
//! vectors at once (the KFAC baseline and the coordinator's batched
//! update path). Since PR 1 the multi-RHS solves are blocked: a TB×TB
//! diagonal block is solved unblocked, then the update of the remaining
//! right-hand-side rows is one panel product on the packed kernel engine
//! ([`kernel::dgemm`](super::kernel::dgemm)) — O(n²k) FLOPs run at GEMM
//! speed instead of axpy speed.
//!
//! Since PR 3 the multi-RHS solves are also threaded
//! ([`solve_lower_multi_threaded`] / [`solve_lower_transpose_multi_threaded`]):
//! every RHS *column* evolves independently through the blocked
//! substitution, so the columns are partitioned into contiguous panels,
//! one persistent-pool job per panel, each running the identical serial
//! core on a gathered copy of its panel — **bit-identical to the serial
//! sweep for every thread count** within a fixed ISA tier (no
//! cross-column arithmetic exists to reorder; each panel job
//! re-establishes the caller's tier). The gathered copies also keep
//! each job's writes on disjoint cache-friendly buffers instead of
//! interleaved columns.
//!
//! Since PR 4 the unblocked diagonal sweeps of the cores run on the
//! ISA-dispatched [`axpy`](super::mat::axpy)/[`dot`](super::mat::dot)
//! primitives (the panel updates were already packed-engine GEMMs), the
//! gather panels come from the thread-local [`arena`](super::arena)
//! (zero steady-state allocation), and the front-ends feed the
//! [`kernel::counters`] TRSM invocation counter.

use super::arena::{self, Slot};
use super::kernel::{self, SendConst, SendMut, Trans};
use super::mat::{dot, Mat};
use super::simd::{self, axpy_isa};

/// Diagonal-block size for the blocked multi-RHS solves. Matches the
/// Cholesky panel width so a factor solved panel-by-panel streams
/// through the same cache footprint.
pub const TB: usize = 64;

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let s = dot(&row[..i], &y[..i]);
        y[i] = (y[i] - s) / row[i];
    }
    y
}

/// Solve `Lᵀ z = y` for lower-triangular `L` (backward substitution on the
/// transpose, without materializing `Lᵀ`): column-oriented sweep that
/// reads `L` row-by-row from the bottom.
pub fn solve_lower_transpose(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(y.len(), n);
    let mut z = y.to_vec();
    for i in (0..n).rev() {
        let row = l.row(i);
        let zi = z[i] / row[i];
        z[i] = zi;
        // Eliminate z[i] from all earlier equations: z[j] -= L[i][j]·zi.
        for j in 0..i {
            z[j] -= row[j] * zi;
        }
    }
    z
}

/// The blocked forward-substitution core: solves `L Y = Y` in place for
/// an `nb × nb` lower-triangular `L` stored with leading dimension
/// `ldl` (so sub-blocks of a larger factor work — the Cholesky panel
/// solve passes its NB×NB diagonal block), against a contiguous
/// row-major `nb × k` RHS buffer.
///
/// Shared verbatim by the serial multi-RHS solve, the per-panel pool
/// jobs of [`solve_lower_multi_threaded`], and the Cholesky panel TRSM
/// — one arithmetic, every caller bit-identical.
pub(crate) fn fwd_multi_core(l: &[f64], ldl: usize, nb: usize, y: &mut [f64], k: usize) {
    let isa = simd::active_isa();
    let mut j0 = 0;
    while j0 < nb {
        let j1 = (j0 + TB).min(nb);
        // Unblocked solve of the diagonal block rows: one ISA-dispatched
        // axpy per (i, j) pair, vectorized over the RHS columns.
        for i in j0..j1 {
            let (head, tail) = y.split_at_mut(i * k);
            let yi = &mut tail[..k];
            for j in j0..i {
                let lij = l[i * ldl + j];
                if lij != 0.0 {
                    axpy_isa(isa, -lij, &head[j * k..(j + 1) * k], yi);
                }
            }
            let inv = 1.0 / l[i * ldl + i];
            for v in yi.iter_mut() {
                *v *= inv;
            }
        }
        // Panel update of everything below the block.
        if j1 < nb {
            let (head, tail) = y.split_at_mut(j1 * k);
            kernel::dgemm(
                nb - j1,
                k,
                j1 - j0,
                -1.0,
                &l[j1 * ldl + j0..],
                ldl,
                Trans::N,
                &head[j0 * k..],
                k,
                Trans::N,
                1.0,
                tail,
                k,
            );
        }
        j0 = j1;
    }
}

/// The blocked backward-substitution core: solves `Lᵀ Z = Z` in place —
/// the transpose counterpart of [`fwd_multi_core`], same sharing and
/// bit-identity contract.
pub(crate) fn bwd_multi_core(l: &[f64], ldl: usize, nb: usize, z: &mut [f64], k: usize) {
    let isa = simd::active_isa();
    let mut j1 = nb;
    while j1 > 0 {
        let j0 = j1.saturating_sub(TB);
        // Unblocked backward solve within the diagonal block, axpy over
        // the RHS columns like the forward core.
        for i in (j0..j1).rev() {
            let (head, tail) = z.split_at_mut(i * k);
            let zi = &mut tail[..k];
            let inv = 1.0 / l[i * ldl + i];
            for v in zi.iter_mut() {
                *v *= inv;
            }
            for j in j0..i {
                let lij = l[i * ldl + j];
                if lij != 0.0 {
                    axpy_isa(isa, -lij, &*zi, &mut head[j * k..(j + 1) * k]);
                }
            }
        }
        // Panel update of everything above the block.
        if j0 > 0 {
            let (head, tail) = z.split_at_mut(j0 * k);
            kernel::dgemm(
                j0,
                k,
                j1 - j0,
                -1.0,
                &l[j0 * ldl..],
                ldl,
                Trans::T,
                &tail[..(j1 - j0) * k],
                k,
                Trans::N,
                1.0,
                head,
                k,
            );
        }
        j1 = j0;
    }
}

// ---------------------------------------------------------------------------
// f32 substitution cores (PR 6 — mixed-precision path)
// ---------------------------------------------------------------------------
//
// The mixed-precision sessions run the triangular solves in f32 against
// the f32 Cholesky factor, then correct the result with f64 iterative
// refinement (`solver::chol`) — each sweep contracts the error by
// ≈ κ·u₃₂, so the f32 substitution only needs to be a contraction, not
// exact. The unblocked sweeps here are plain scalar f32 (identical on
// every tier — only the sgemm panel updates dispatch on the ISA), and
// the cores are serial: the within-tier "threaded ≡ serial" contract
// holds trivially, and the O(n²k) panel FLOPs already run at f32 GEMM
// speed.

/// Scalar f32 `y += alpha · x`, 8-way unrolled — the f32 counterpart of
/// [`axpy_isa`]'s scalar tier (kept tier-independent on purpose: the
/// substitution arithmetic is then identical across tiers, and only the
/// GEMM panel updates carry tier-specific rounding).
fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    let mut cx = x.chunks_exact(8);
    let mut cy = y.chunks_exact_mut(8);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        for l in 0..8 {
            ys[l] += alpha * xs[l];
        }
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += alpha * x;
    }
}

/// Solve `L y = y` in place for a row-major n×n f32 lower factor.
pub fn solve_lower_f32(l: &[f32], n: usize, y: &mut [f32]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(y.len(), n);
    for i in 0..n {
        let row = &l[i * n..i * n + i + 1];
        let mut s = 0.0f32;
        for (lij, yj) in row[..i].iter().zip(y[..i].iter()) {
            s += lij * yj;
        }
        y[i] = (y[i] - s) / row[i];
    }
}

/// Solve `Lᵀ z = z` in place for a row-major n×n f32 lower factor.
pub fn solve_lower_transpose_f32(l: &[f32], n: usize, z: &mut [f32]) {
    debug_assert_eq!(l.len(), n * n);
    debug_assert_eq!(z.len(), n);
    for i in (0..n).rev() {
        let row = &l[i * n..i * n + i + 1];
        let zi = z[i] / row[i];
        z[i] = zi;
        for (lij, zj) in row[..i].iter().zip(z[..i].iter_mut()) {
            *zj -= lij * zi;
        }
    }
}

/// [`fwd_multi_core`] at f32: blocked in-place forward solve of
/// `L Y = Y` for an `nb × nb` f32 lower block (leading dimension `ldl`)
/// against a contiguous row-major `nb × k` RHS. Panel updates run on
/// [`kernel::sgemm`]. Shared by the f32 Cholesky panel solve and the
/// mixed-precision multi-RHS session solve.
pub(crate) fn fwd_multi_core_f32(l: &[f32], ldl: usize, nb: usize, y: &mut [f32], k: usize) {
    let mut j0 = 0;
    while j0 < nb {
        let j1 = (j0 + TB).min(nb);
        for i in j0..j1 {
            let (head, tail) = y.split_at_mut(i * k);
            let yi = &mut tail[..k];
            for j in j0..i {
                let lij = l[i * ldl + j];
                if lij != 0.0 {
                    axpy_f32(-lij, &head[j * k..(j + 1) * k], yi);
                }
            }
            let inv = 1.0 / l[i * ldl + i];
            for v in yi.iter_mut() {
                *v *= inv;
            }
        }
        if j1 < nb {
            let (head, tail) = y.split_at_mut(j1 * k);
            kernel::sgemm(
                nb - j1,
                k,
                j1 - j0,
                -1.0,
                &l[j1 * ldl + j0..],
                ldl,
                Trans::N,
                &head[j0 * k..],
                k,
                Trans::N,
                1.0,
                tail,
                k,
            );
        }
        j0 = j1;
    }
}

/// [`bwd_multi_core`] at f32: blocked in-place solve of `Lᵀ Z = Z`.
pub(crate) fn bwd_multi_core_f32(l: &[f32], ldl: usize, nb: usize, z: &mut [f32], k: usize) {
    let mut j1 = nb;
    while j1 > 0 {
        let j0 = j1.saturating_sub(TB);
        for i in (j0..j1).rev() {
            let (head, tail) = z.split_at_mut(i * k);
            let zi = &mut tail[..k];
            let inv = 1.0 / l[i * ldl + i];
            for v in zi.iter_mut() {
                *v *= inv;
            }
            for j in j0..i {
                let lij = l[i * ldl + j];
                if lij != 0.0 {
                    axpy_f32(-lij, &*zi, &mut head[j * k..(j + 1) * k]);
                }
            }
        }
        if j0 > 0 {
            let (head, tail) = z.split_at_mut(j0 * k);
            kernel::sgemm(
                j0,
                k,
                j1 - j0,
                -1.0,
                &l[j0 * ldl..],
                ldl,
                Trans::T,
                &tail[..(j1 - j0) * k],
                k,
                Trans::N,
                1.0,
                head,
                k,
            );
        }
        j1 = j0;
    }
}

/// Multi-RHS forward solve: `L Y = B` where `B` is n×k.
///
/// Blocked: rows `[j0, j1)` are solved unblocked against the diagonal
/// block, then all remaining rows are updated at once with
/// `Y[j1.., :] -= L[j1.., j0..j1] · Y[j0..j1, :]` on the packed engine.
pub fn solve_lower_multi(l: &Mat, b: &Mat) -> Mat {
    kernel::counters::record_trsm();
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let k = b.cols();
    let mut y = b.clone();
    fwd_multi_core(l.as_slice(), n, n, y.as_mut_slice(), k);
    y
}

/// Multi-RHS transposed solve: `Lᵀ Z = Y` where `Y` is n×k.
///
/// Blocked from the bottom: the diagonal block is back-substituted
/// unblocked, then the rows above it are updated in one panel product
/// `Z[..j0, :] -= L[j0..j1, ..j0]ᵀ · Z[j0..j1, :]` on the packed engine.
pub fn solve_lower_transpose_multi(l: &Mat, yy: &Mat) -> Mat {
    kernel::counters::record_trsm();
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(yy.rows(), n);
    let k = yy.cols();
    let mut z = yy.clone();
    bwd_multi_core(l.as_slice(), n, n, z.as_mut_slice(), k);
    z
}

/// Minimum RHS columns per panel job (and, ×2, the width below which
/// the threaded solves stay serial). Half an NR micro-tile row: any
/// narrower and most packed-engine lanes in the panel GEMM are padding,
/// so extra jobs would shred the work without adding throughput.
const PAR_MIN_COLS: usize = 4;

/// Minimum order for the threaded solves — below TB there is no panel
/// GEMM to speed up and the substitution is latency-bound.
const PAR_MIN_N: usize = TB;

/// Run `op` over the RHS columns of `b` split into `threads` contiguous
/// panels on the kernel pool: each job gathers its columns into a
/// contiguous n×kc buffer, applies the serial core, and scatters the
/// result into `out`. Columns are arithmetically independent, so the
/// result is bit-identical to the serial full-width solve.
fn solve_multi_panels(
    l: &Mat,
    b: &Mat,
    threads: usize,
    core: fn(&[f64], usize, usize, &mut [f64], usize),
) -> Mat {
    kernel::counters::record_trsm();
    let n = l.rows();
    let k = b.cols();
    let mut out = Mat::zeros(n, k);
    let jobs_n = threads.min(k.div_ceil(PAR_MIN_COLS)).max(1);
    let chunk = k.div_ceil(jobs_n);
    {
        // Captured once so every panel job substitutes on the caller's
        // tier — required for the within-tier bit-identity contract.
        let isa = simd::active_isa();
        let lptr = SendConst(l.as_slice().as_ptr());
        let llen = l.as_slice().len();
        let bptr = SendConst(b.as_slice().as_ptr());
        let optr = SendMut(out.as_mut_slice().as_mut_ptr());
        let mut jobs: Vec<kernel::KernelJob> = Vec::with_capacity(jobs_n);
        let mut c0 = 0usize;
        while c0 < k {
            let c1 = (c0 + chunk).min(k);
            let kc = c1 - c0;
            jobs.push(Box::new(move || {
                // SAFETY: L and B are only read; each job scatters into
                // the disjoint column range [c0, c1) of `out` (disjoint
                // element ranges per row). The caller blocks in `run`
                // until every job is accounted for.
                kernel::with_isa(isa, || {
                    let ldata = unsafe { std::slice::from_raw_parts(lptr.0, llen) };
                    let bdata = unsafe { std::slice::from_raw_parts(bptr.0, n * k) };
                    // Worker-thread arena gather: the core's dgemm panel
                    // updates use the (distinct) pack slots.
                    let mut panelbuf = arena::take(Slot::Gather);
                    let panel = panelbuf.ensure(n * kc);
                    for i in 0..n {
                        panel[i * kc..(i + 1) * kc]
                            .copy_from_slice(&bdata[i * k + c0..i * k + c1]);
                    }
                    core(ldata, n, n, panel, kc);
                    for i in 0..n {
                        let dst =
                            unsafe { std::slice::from_raw_parts_mut(optr.0.add(i * k + c0), kc) };
                        dst.copy_from_slice(&panel[i * kc..(i + 1) * kc]);
                    }
                    arena::put(Slot::Gather, panelbuf);
                });
            }));
            c0 = c1;
        }
        kernel::global_pool().run(jobs);
    }
    out
}

/// Threaded multi-RHS forward solve — [`solve_lower_multi`] with the
/// RHS columns partitioned into contiguous panels across the persistent
/// kernel pool. **Bit-identical to the serial solve for every thread
/// count**: each column's substitution arithmetic is independent of
/// every other column, so panelization cannot reorder a single sum.
pub fn solve_lower_multi_threaded(l: &Mat, b: &Mat, threads: usize) -> Mat {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    if threads <= 1 || b.cols() < 2 * PAR_MIN_COLS || n < PAR_MIN_N {
        return solve_lower_multi(l, b);
    }
    solve_multi_panels(l, b, threads, fwd_multi_core)
}

/// Threaded multi-RHS transposed solve — the
/// [`solve_lower_transpose_multi`] counterpart of
/// [`solve_lower_multi_threaded`], same partitioning and bit-identity
/// contract.
pub fn solve_lower_transpose_multi_threaded(l: &Mat, yy: &Mat, threads: usize) -> Mat {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(yy.rows(), n);
    if threads <= 1 || yy.cols() < 2 * PAR_MIN_COLS || n < PAR_MIN_N {
        return solve_lower_transpose_multi(l, yy);
    }
    solve_multi_panels(l, yy, threads, bwd_multi_core)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::cholesky::cholesky;
    use crate::linalg::gemm::syrk;

    fn random_lower(n: usize, rng: &mut Rng) -> Mat {
        // Cholesky factor of an SPD matrix: well-conditioned lower L.
        let a = Mat::randn(n, n + 5, rng);
        cholesky(&syrk(&a, 1.0)).unwrap()
    }

    #[test]
    fn forward_solve_roundtrip() {
        let mut rng = Rng::seed_from(30);
        for &n in &[1, 2, 7, 40, 129] {
            let l = random_lower(n, &mut rng);
            let y_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = l.matvec(&y_true);
            let y = solve_lower(&l, &b);
            for (a, c) in y.iter().zip(&y_true) {
                assert!((a - c).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn transpose_solve_roundtrip() {
        let mut rng = Rng::seed_from(31);
        for &n in &[1, 3, 11, 64] {
            let l = random_lower(n, &mut rng);
            let z_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = l.transpose().matvec(&z_true); // Lᵀ z
            let z = solve_lower_transpose(&l, &y);
            for (a, c) in z.iter().zip(&z_true) {
                assert!((a - c).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn transpose_solve_matches_explicit_transpose() {
        let mut rng = Rng::seed_from(32);
        let l = random_lower(23, &mut rng);
        let y: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
        let fast = solve_lower_transpose(&l, &y);
        // Oracle: upper-triangular back substitution on the explicit Lᵀ.
        let u = l.transpose();
        let mut z = y.clone();
        for i in (0..23).rev() {
            let mut s = z[i];
            for j in i + 1..23 {
                s -= u[(i, j)] * z[j];
            }
            z[i] = s / u[(i, i)];
        }
        for (a, c) in fast.iter().zip(&z) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn multi_rhs_matches_columnwise_vector_solves() {
        let mut rng = Rng::seed_from(33);
        let n = 31;
        let k = 9;
        let l = random_lower(n, &mut rng);
        let b = Mat::randn(n, k, &mut rng);
        let y_multi = solve_lower_multi(&l, &b);
        let z_multi = solve_lower_transpose_multi(&l, &b);
        for col in 0..k {
            let bcol = b.col(col);
            let ycol = solve_lower(&l, &bcol);
            let zcol = solve_lower_transpose(&l, &bcol);
            for i in 0..n {
                assert!((y_multi[(i, col)] - ycol[i]).abs() < 1e-11);
                assert!((z_multi[(i, col)] - zcol[i]).abs() < 1e-11);
            }
        }
    }

    /// The blocked path (n > TB) at awkward sizes: n off the TB grid and
    /// k off the NR grid, checked against the per-column vector solves.
    #[test]
    fn blocked_multi_rhs_edge_shapes_match_columnwise() {
        let mut rng = Rng::seed_from(35);
        for &(n, k) in &[(TB + 1, 1), (2 * TB + 7, 5), (151, 17)] {
            let l = random_lower(n, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let y_multi = solve_lower_multi(&l, &b);
            let z_multi = solve_lower_transpose_multi(&l, &b);
            for col in 0..k {
                let bcol = b.col(col);
                let ycol = solve_lower(&l, &bcol);
                let zcol = solve_lower_transpose(&l, &bcol);
                for i in 0..n {
                    assert!(
                        (y_multi[(i, col)] - ycol[i]).abs() < 1e-9,
                        "fwd (n={n},k={k}) at ({i},{col})"
                    );
                    assert!(
                        (z_multi[(i, col)] - zcol[i]).abs() < 1e-9,
                        "adj (n={n},k={k}) at ({i},{col})"
                    );
                }
            }
        }
    }

    /// Round-trip through both blocked solves: L (Lᵀ Z) = B recovers
    /// W⁻¹-ish behaviour on a full multi-RHS normal-equation solve.
    #[test]
    fn blocked_multi_rhs_roundtrip() {
        let mut rng = Rng::seed_from(36);
        let n = 140;
        let k = 6;
        let l = random_lower(n, &mut rng);
        let x_true = Mat::randn(n, k, &mut rng);
        // B = L·(Lᵀ·X)
        let mut ltx = Mat::zeros(n, k);
        crate::linalg::gemm::gemm_tn(1.0, &l, &x_true, 0.0, &mut ltx);
        let mut b = Mat::zeros(n, k);
        crate::linalg::gemm::gemm(1.0, &l, &ltx, 0.0, &mut b);
        let x = solve_lower_transpose_multi(&l, &solve_lower_multi(&l, &b));
        for i in 0..n {
            for j in 0..k {
                assert!((x[(i, j)] - x_true[(i, j)]).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn full_normal_equation_solve_via_two_substitutions() {
        // (L Lᵀ) x = b  ⇒  x = L⁻ᵀ (L⁻¹ b): the exact composition used in
        // Algorithm 1 line 4.
        let mut rng = Rng::seed_from(34);
        let n = 50;
        let a = Mat::randn(n, n + 8, &mut rng);
        let w = syrk(&a, 0.7);
        let l = cholesky(&w).unwrap();
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = w.matvec(&x_true);
        let x = solve_lower_transpose(&l, &solve_lower(&l, &b));
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn f32_solves_track_f64_within_single_precision() {
        let mut rng = Rng::seed_from(37);
        for &n in &[1usize, 7, TB, TB + 9, 2 * TB + 5] {
            let l = random_lower(n, &mut rng);
            let l32: Vec<f32> = l.as_slice().iter().map(|&x| x as f32).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // Vector forward + transpose solves vs the f64 reference.
            let y64 = solve_lower(&l, &b);
            let z64 = solve_lower_transpose(&l, &y64);
            let mut y32: Vec<f32> = b.iter().map(|&x| x as f32).collect();
            solve_lower_f32(&l32, n, &mut y32);
            let mut z32 = y32.clone();
            solve_lower_transpose_f32(&l32, n, &mut z32);
            let scale = z64.iter().fold(1.0f64, |m, &v| m.max(v.abs()));
            for i in 0..n {
                assert!(
                    (z32[i] as f64 - z64[i]).abs() <= 1e-3 * scale * (n as f64).sqrt(),
                    "n={n} i={i}: {} vs {}",
                    z32[i],
                    z64[i]
                );
            }
            // Blocked multi-RHS cores agree with the vector solves.
            let k = 3;
            let bm: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut ym: Vec<f32> = bm.iter().map(|&x| x as f32).collect();
            fwd_multi_core_f32(&l32, n, n, &mut ym, k);
            bwd_multi_core_f32(&l32, n, n, &mut ym, k);
            for col in 0..k {
                let bcol: Vec<f64> = (0..n).map(|i| bm[i * k + col]).collect();
                let mut vcol: Vec<f32> = bcol.iter().map(|&x| x as f32).collect();
                solve_lower_f32(&l32, n, &mut vcol);
                solve_lower_transpose_f32(&l32, n, &mut vcol);
                let scale = vcol.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
                for i in 0..n {
                    assert!(
                        (ym[i * k + col] - vcol[i]).abs() <= 1e-3 * scale,
                        "multi n={n} ({i},{col})"
                    );
                }
            }
        }
    }
}
