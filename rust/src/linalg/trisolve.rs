//! Triangular solves — lines 3–4 of Algorithm 1.
//!
//! The paper stresses that `Q = L⁻¹S` should **not** be materialized;
//! instead `QᵀQv = SᵀL⁻ᵀL⁻¹Sv` is evaluated right-to-left:
//!
//! ```text
//! u  = S v          (matvec, O(nm))
//! y  = L⁻¹ u        (forward substitution, O(n²))
//! z  = L⁻ᵀ y        (backward substitution, O(n²))
//! out = Sᵀ z        (transposed matvec, O(nm))
//! ```
//!
//! This module provides the two substitutions for vectors and the blocked
//! multi-RHS variants (`trsm`) used when solving for a block of gradient
//! vectors at once (the KFAC baseline and the coordinator's batched
//! update path). Since PR 1 the multi-RHS solves are blocked: a TB×TB
//! diagonal block is solved unblocked, then the update of the remaining
//! right-hand-side rows is one panel product on the packed kernel engine
//! ([`kernel::dgemm`](super::kernel::dgemm)) — O(n²k) FLOPs run at GEMM
//! speed instead of axpy speed.

use super::kernel::{self, Trans};
use super::mat::{dot, Mat};

/// Diagonal-block size for the blocked multi-RHS solves. Matches the
/// Cholesky panel width so a factor solved panel-by-panel streams
/// through the same cache footprint.
pub const TB: usize = 64;

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.len(), n);
    let mut y = b.to_vec();
    for i in 0..n {
        let row = l.row(i);
        let s = dot(&row[..i], &y[..i]);
        y[i] = (y[i] - s) / row[i];
    }
    y
}

/// Solve `Lᵀ z = y` for lower-triangular `L` (backward substitution on the
/// transpose, without materializing `Lᵀ`): column-oriented sweep that
/// reads `L` row-by-row from the bottom.
pub fn solve_lower_transpose(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(y.len(), n);
    let mut z = y.to_vec();
    for i in (0..n).rev() {
        let row = l.row(i);
        let zi = z[i] / row[i];
        z[i] = zi;
        // Eliminate z[i] from all earlier equations: z[j] -= L[i][j]·zi.
        for j in 0..i {
            z[j] -= row[j] * zi;
        }
    }
    z
}

/// Multi-RHS forward solve: `L Y = B` where `B` is n×k.
///
/// Blocked: rows `[j0, j1)` are solved unblocked against the diagonal
/// block, then all remaining rows are updated at once with
/// `Y[j1.., :] -= L[j1.., j0..j1] · Y[j0..j1, :]` on the packed engine.
pub fn solve_lower_multi(l: &Mat, b: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(b.rows(), n);
    let k = b.cols();
    let mut y = b.clone();
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + TB).min(n);
        // Unblocked solve of the diagonal block rows.
        for i in j0..j1 {
            for j in j0..i {
                let lij = l[(i, j)];
                if lij != 0.0 {
                    let (yi, yj) = y.rows_mut2(i, j);
                    for (a, c) in yi.iter_mut().zip(yj.iter()) {
                        *a -= lij * c;
                    }
                }
            }
            let inv = 1.0 / l[(i, i)];
            for v in y.row_mut(i) {
                *v *= inv;
            }
        }
        // Panel update of everything below the block.
        if j1 < n {
            let (head, tail) = y.as_mut_slice().split_at_mut(j1 * k);
            kernel::dgemm(
                n - j1,
                k,
                j1 - j0,
                -1.0,
                &l.as_slice()[j1 * n + j0..],
                n,
                Trans::N,
                &head[j0 * k..],
                k,
                Trans::N,
                1.0,
                tail,
                k,
            );
        }
        j0 = j1;
    }
    y
}

/// Multi-RHS transposed solve: `Lᵀ Z = Y` where `Y` is n×k.
///
/// Blocked from the bottom: the diagonal block is back-substituted
/// unblocked, then the rows above it are updated in one panel product
/// `Z[..j0, :] -= L[j0..j1, ..j0]ᵀ · Z[j0..j1, :]` on the packed engine.
pub fn solve_lower_transpose_multi(l: &Mat, yy: &Mat) -> Mat {
    let n = l.rows();
    assert_eq!(l.cols(), n);
    assert_eq!(yy.rows(), n);
    let k = yy.cols();
    let mut z = yy.clone();
    let mut j1 = n;
    while j1 > 0 {
        let j0 = j1.saturating_sub(TB);
        // Unblocked backward solve within the diagonal block.
        for i in (j0..j1).rev() {
            let inv = 1.0 / l[(i, i)];
            for v in z.row_mut(i) {
                *v *= inv;
            }
            for j in j0..i {
                let lij = l[(i, j)];
                if lij != 0.0 {
                    let (zj, zi) = z.rows_mut2(j, i);
                    for (a, c) in zj.iter_mut().zip(zi.iter()) {
                        *a -= lij * c;
                    }
                }
            }
        }
        // Panel update of everything above the block.
        if j0 > 0 {
            let (head, tail) = z.as_mut_slice().split_at_mut(j0 * k);
            kernel::dgemm(
                j0,
                k,
                j1 - j0,
                -1.0,
                &l.as_slice()[j0 * n..],
                n,
                Trans::T,
                &tail[..(j1 - j0) * k],
                k,
                Trans::N,
                1.0,
                head,
                k,
            );
        }
        j1 = j0;
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::cholesky::cholesky;
    use crate::linalg::gemm::syrk;

    fn random_lower(n: usize, rng: &mut Rng) -> Mat {
        // Cholesky factor of an SPD matrix: well-conditioned lower L.
        let a = Mat::randn(n, n + 5, rng);
        cholesky(&syrk(&a, 1.0)).unwrap()
    }

    #[test]
    fn forward_solve_roundtrip() {
        let mut rng = Rng::seed_from(30);
        for &n in &[1, 2, 7, 40, 129] {
            let l = random_lower(n, &mut rng);
            let y_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = l.matvec(&y_true);
            let y = solve_lower(&l, &b);
            for (a, c) in y.iter().zip(&y_true) {
                assert!((a - c).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn transpose_solve_roundtrip() {
        let mut rng = Rng::seed_from(31);
        for &n in &[1, 3, 11, 64] {
            let l = random_lower(n, &mut rng);
            let z_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y = l.transpose().matvec(&z_true); // Lᵀ z
            let z = solve_lower_transpose(&l, &y);
            for (a, c) in z.iter().zip(&z_true) {
                assert!((a - c).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn transpose_solve_matches_explicit_transpose() {
        let mut rng = Rng::seed_from(32);
        let l = random_lower(23, &mut rng);
        let y: Vec<f64> = (0..23).map(|_| rng.normal()).collect();
        let fast = solve_lower_transpose(&l, &y);
        // Oracle: upper-triangular back substitution on the explicit Lᵀ.
        let u = l.transpose();
        let mut z = y.clone();
        for i in (0..23).rev() {
            let mut s = z[i];
            for j in i + 1..23 {
                s -= u[(i, j)] * z[j];
            }
            z[i] = s / u[(i, i)];
        }
        for (a, c) in fast.iter().zip(&z) {
            assert!((a - c).abs() < 1e-12);
        }
    }

    #[test]
    fn multi_rhs_matches_columnwise_vector_solves() {
        let mut rng = Rng::seed_from(33);
        let n = 31;
        let k = 9;
        let l = random_lower(n, &mut rng);
        let b = Mat::randn(n, k, &mut rng);
        let y_multi = solve_lower_multi(&l, &b);
        let z_multi = solve_lower_transpose_multi(&l, &b);
        for col in 0..k {
            let bcol = b.col(col);
            let ycol = solve_lower(&l, &bcol);
            let zcol = solve_lower_transpose(&l, &bcol);
            for i in 0..n {
                assert!((y_multi[(i, col)] - ycol[i]).abs() < 1e-11);
                assert!((z_multi[(i, col)] - zcol[i]).abs() < 1e-11);
            }
        }
    }

    /// The blocked path (n > TB) at awkward sizes: n off the TB grid and
    /// k off the NR grid, checked against the per-column vector solves.
    #[test]
    fn blocked_multi_rhs_edge_shapes_match_columnwise() {
        let mut rng = Rng::seed_from(35);
        for &(n, k) in &[(TB + 1, 1), (2 * TB + 7, 5), (151, 17)] {
            let l = random_lower(n, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let y_multi = solve_lower_multi(&l, &b);
            let z_multi = solve_lower_transpose_multi(&l, &b);
            for col in 0..k {
                let bcol = b.col(col);
                let ycol = solve_lower(&l, &bcol);
                let zcol = solve_lower_transpose(&l, &bcol);
                for i in 0..n {
                    assert!(
                        (y_multi[(i, col)] - ycol[i]).abs() < 1e-9,
                        "fwd (n={n},k={k}) at ({i},{col})"
                    );
                    assert!(
                        (z_multi[(i, col)] - zcol[i]).abs() < 1e-9,
                        "adj (n={n},k={k}) at ({i},{col})"
                    );
                }
            }
        }
    }

    /// Round-trip through both blocked solves: L (Lᵀ Z) = B recovers
    /// W⁻¹-ish behaviour on a full multi-RHS normal-equation solve.
    #[test]
    fn blocked_multi_rhs_roundtrip() {
        let mut rng = Rng::seed_from(36);
        let n = 140;
        let k = 6;
        let l = random_lower(n, &mut rng);
        let x_true = Mat::randn(n, k, &mut rng);
        // B = L·(Lᵀ·X)
        let mut ltx = Mat::zeros(n, k);
        crate::linalg::gemm::gemm_tn(1.0, &l, &x_true, 0.0, &mut ltx);
        let mut b = Mat::zeros(n, k);
        crate::linalg::gemm::gemm(1.0, &l, &ltx, 0.0, &mut b);
        let x = solve_lower_transpose_multi(&l, &solve_lower_multi(&l, &b));
        for i in 0..n {
            for j in 0..k {
                assert!((x[(i, j)] - x_true[(i, j)]).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn full_normal_equation_solve_via_two_substitutions() {
        // (L Lᵀ) x = b  ⇒  x = L⁻ᵀ (L⁻¹ b): the exact composition used in
        // Algorithm 1 line 4.
        let mut rng = Rng::seed_from(34);
        let n = 50;
        let a = Mat::randn(n, n + 8, &mut rng);
        let w = syrk(&a, 0.7);
        let l = cholesky(&w).unwrap();
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = w.matvec(&x_true);
        let x = solve_lower_transpose(&l, &solve_lower(&l, &b));
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }
}
