//! Packed register-blocked GEMM engine — the shared micro-kernel under
//! every dense O(n³)/O(n²m) hot path in the crate.
//!
//! Layout follows the BLIS decomposition: the operands are repacked into
//! contiguous panels sized for the cache hierarchy, and all FLOPs run
//! through one MR×NR register-blocked micro-kernel:
//!
//! ```text
//! for jc in n  step NC:              (B column block, stays in L3)
//!   for pc in k step KC:             (reduction block)
//!     pack B[pc.., jc..]  → bp       (KC×NC, NR-wide column micro-panels)
//!     for ic in m step MC:           (A row block, stays in L2)
//!       pack A[ic.., pc..] → ap      (MC×KC, MR-tall row micro-panels)
//!       for each (MR × NR) tile: micro-kernel over kc
//! ```
//!
//! Since PR 4 the micro-kernel is **runtime-dispatched** on a
//! [`KernelIsa`] tier selected once per process (CPUID detection or the
//! `DNGD_KERNEL=scalar|avx2|avx512|neon` override — see
//! [`simd`](super::simd)): explicit `std::arch` AVX2+FMA (4×8 tile),
//! AVX-512F (8×8 tile over paired row panels) and NEON (4×8) kernels,
//! with the seed scalar kernel as the guaranteed fallback. Packing
//! absorbs transposition, so one driver ([`dgemm`]) serves `A·B`,
//! `A·Bᵀ` and `Aᵀ·B`, and edge tiles are handled by zero-padding the
//! packed panels — the micro-kernels have no tail cases, the write-back
//! just clips to the valid `mr_eff × nr_eff` region.
//!
//! Also since PR 4, the packing panels live in thread-local, 64-byte
//! aligned **arenas** ([`arena`](super::arena)) instead of per-call
//! `Vec`s: grown monotonically and reused across calls and pool jobs,
//! so steady-state training iterations perform zero pack-buffer
//! allocation ([`counters::arena_allocs`] pins this in
//! `rust/tests/session_api.rs`).
//!
//! [`syrk_panel`] is the lower-triangle-aware variant used by the Gram
//! stage `W = SSᵀ` (Algorithm 1 line 1): it skips micro-tiles strictly
//! above the diagonal and is a pure function of the row-panel range, so
//! threaded SYRK is bit-identical for every thread count.
//!
//! ## Determinism of the threaded engine (PR 3)
//!
//! Since PR 3 the *whole* engine is threaded, not just SYRK:
//! [`dgemm_threaded`] deals contiguous MC-row bands of C to the
//! persistent pool, and the blocked Cholesky / multi-RHS TRSM drivers
//! (in [`cholesky`](super::cholesky) / [`trisolve`](super::trisolve))
//! partition their trailing updates and RHS column panels the same way.
//! Every scheme is **bit-identical to serial for every thread count
//! *within a fixed ISA tier*** because of one invariant of the packed
//! driver: each C element accumulates `alpha · Σ_p a[i][p]·b[p][j]`
//! with `p` swept in strictly increasing order inside each KC block and
//! KC blocks applied in increasing order — the partitioning of C into
//! tiles/bands/panels changes which packed buffer a value lands in,
//! never the per-element summation order, and the lane-blocked order
//! inside a micro-kernel is a pure function of the tier (PR 4), never
//! of the partitioning. Only the reduction (k) dimension must not be
//! split differently, and no threaded path in this crate splits k.
//! Every threaded dispatcher captures the caller's [`active_isa`] and
//! re-establishes it inside its pool jobs, so a scoped
//! [`with_isa`] override keeps caller and workers on one tier.
//! *Across* tiers results are only tolerance-equal (FMA vs the scalar
//! tier's two-rounding arithmetic); `gemm::reference` stays the oracle.
//!
//! [`KernelPool`] is the persistent worker pool behind the threaded
//! kernels: spawned once per process (lazily), fed closures over
//! channels, so repeated solves do not pay thread spawn/join on every
//! call the way the seed `std::thread::scope` implementation did.
//! [`KernelPool::run`] blocks until a batch completes;
//! [`KernelPool::submit`] returns a [`BatchGuard`] so a caller can
//! overlap its own critical-path work with in-flight jobs (the blocked
//! Cholesky's one-panel lookahead). Pool jobs must only call *serial*
//! kernels — a job that re-entered the pool could deadlock behind its
//! own worker.

use super::arena::{self, Slot};
use super::simd::{microkernel_16x8_f32, microkernel_4x8, microkernel_8x8, microkernel_8x8_f32};
use std::sync::mpsc::{channel, sync_channel, SyncSender};
use std::sync::{Mutex, OnceLock};

pub use super::simd::{active_isa, process_default_isa, with_isa, with_isa_opt, KernelIsa};

/// Thread-local kernel-invocation counters.
///
/// The session API (PR 2) promises that re-damping a cached
/// [`Factorization`](crate::solver::Factorization) with a new λ performs
/// **zero** Gram-forming GEMM work; these counters make that promise
/// testable. Counts are per-thread so concurrently running tests cannot
/// pollute each other's deltas; work dispatched to pool workers (threaded
/// SYRK panels, coordinator shards) is counted on the worker threads, not
/// the caller's — the counters track front-end *invocations* on the
/// current thread, not FLOPs.
pub mod counters {
    use std::cell::Cell;

    thread_local! {
        static DGEMM: Cell<u64> = Cell::new(0);
        static SGEMM: Cell<u64> = Cell::new(0);
        static SYRK: Cell<u64> = Cell::new(0);
        static CHOLESKY: Cell<u64> = Cell::new(0);
        static TRSM: Cell<u64> = Cell::new(0);
    }

    pub(crate) fn record_dgemm() {
        DGEMM.with(|c| c.set(c.get() + 1));
    }

    pub(crate) fn record_sgemm() {
        SGEMM.with(|c| c.set(c.get() + 1));
    }

    pub(crate) fn record_syrk() {
        SYRK.with(|c| c.set(c.get() + 1));
    }

    pub(crate) fn record_cholesky() {
        CHOLESKY.with(|c| c.set(c.get() + 1));
    }

    pub(crate) fn record_trsm() {
        TRSM.with(|c| c.set(c.get() + 1));
    }

    /// [`dgemm`](super::dgemm) invocations on this thread since start.
    pub fn dgemm_calls() -> u64 {
        DGEMM.with(|c| c.get())
    }

    /// f32 [`sgemm`](super::sgemm) invocations on this thread since
    /// start (the PR 6 mixed-precision kernel path).
    pub fn sgemm_calls() -> u64 {
        SGEMM.with(|c| c.get())
    }

    /// Gram-stage front-end invocations
    /// ([`syrk`](crate::linalg::gemm::syrk) /
    /// [`syrk_parallel`](crate::linalg::gemm::syrk_parallel)) on this
    /// thread since start.
    pub fn syrk_calls() -> u64 {
        SYRK.with(|c| c.get())
    }

    /// Blocked-Cholesky front-end invocations
    /// ([`cholesky_in_place_threaded`](crate::linalg::cholesky::cholesky_in_place_threaded)
    /// and its wrappers) on this thread since start.
    pub fn cholesky_calls() -> u64 {
        CHOLESKY.with(|c| c.get())
    }

    /// Blocked multi-RHS TRSM front-end invocations
    /// ([`solve_lower_multi`](crate::linalg::trisolve::solve_lower_multi),
    /// [`solve_lower_transpose_multi`](crate::linalg::trisolve::solve_lower_transpose_multi)
    /// and their threaded variants) on this thread since start.
    pub fn trsm_calls() -> u64 {
        TRSM.with(|c| c.get())
    }

    /// Packing-arena (re)allocations on this thread since start —
    /// growth events of the thread-local
    /// [`arena`](crate::linalg::arena) buffers. In steady state
    /// (repeated solves at the same shapes) this must not advance; the
    /// session zero-allocation test pins it.
    pub fn arena_allocs() -> u64 {
        crate::linalg::arena::allocs()
    }
}

/// Micro-kernel rows: accumulator height. 4 rows × 8 lanes = 32 f64
/// accumulators ≈ half the AVX-512 (or all the AVX2-ymm) register file,
/// leaving room for the broadcast and B-row temporaries.
pub const MR: usize = 4;

/// Micro-kernel columns: one cache line of f64 per accumulator row.
pub const NR: usize = 8;

/// f32 micro-kernel rows (PR 6): 8 rows × 8 lanes doubles the f64
/// tile's row count at the same ymm register budget (one 8-float ymm
/// accumulator per row on AVX2; AVX-512 pairs two panels into 16×8).
pub const MR32: usize = 8;

/// f32 micro-kernel columns: half a cache line of f32 per accumulator
/// row — kept equal to [`NR`] so the f32 and f64 packed B layouts share
/// panel arithmetic (and the arena slots, sized in elements, reuse the
/// same byte capacity).
pub const NR32: usize = 8;

/// Reduction-dimension block: one `ap` micro-panel (KC×MR) plus one `bp`
/// micro-panel (KC×NR) is 24 KiB — resident in L1 across the tile sweep.
pub const KC: usize = 256;

/// Row block: the packed MC×KC A-panel is 256 KiB, sized for L2.
pub const MC: usize = 128;

/// Column block: bounds the packed KC×NC B-panel at 8 MiB (L3-resident)
/// so huge right-hand sides do not blow out the packing buffer.
pub const NC: usize = 4096;

/// Whether an operand buffer is stored as the logical matrix (`N`) or as
/// its transpose (`T`). Packing absorbs the difference; the micro-kernel
/// always sees the same canonical panels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    N,
    T,
}

/// Shared kernel configuration plumbed through the solvers, the
/// coordinator workers and the bench harness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Worker threads for the threaded dense pipeline — GEMM, SYRK, the
    /// blocked Cholesky and the multi-RHS TRSM all partition their work
    /// across this many pool jobs. 1 = serial. Every threaded kernel is
    /// bit-identical to its serial result at every thread count within
    /// a fixed ISA tier (see the module docs), so this is purely a
    /// throughput knob.
    pub threads: usize,
    /// ISA tier override for the dense kernels (`solver.isa` in
    /// configs). `None` (the default) dispatches on the process tier —
    /// CPUID detection or `DNGD_KERNEL`; `Some(tier)` scopes the
    /// consumer's kernel calls to that tier via [`with_isa`]. Changing
    /// the tier changes low-order result bits (FMA vs scalar
    /// arithmetic), so runs only replay exactly at the same tier.
    pub isa: Option<KernelIsa>,
}

impl KernelConfig {
    /// Single-threaded config on the process ISA tier — the
    /// deterministic default.
    pub const fn serial() -> KernelConfig {
        KernelConfig { threads: 1, isa: None }
    }

    pub fn with_threads(threads: usize) -> KernelConfig {
        KernelConfig { threads: threads.max(1), isa: None }
    }

    /// Builder: pin the ISA tier (`None` = process default).
    pub fn with_isa(mut self, isa: Option<KernelIsa>) -> KernelConfig {
        self.isa = isa;
        self
    }

    /// The tier this config's kernels dispatch on when run through
    /// [`KernelConfig::run`] (the override, else the ambient tier).
    pub fn resolved_isa(&self) -> KernelIsa {
        self.isa.unwrap_or_else(active_isa)
    }

    /// Run `f` with this config's ISA override established on the
    /// calling thread (no-op when `isa` is `None`). The threaded
    /// kernels propagate the tier into their pool jobs themselves.
    pub fn run<R>(&self, f: impl FnOnce() -> R) -> R {
        with_isa_opt(self.isa, f)
    }

    /// `DNGD_THREADS` env override, else every available core. (The ISA
    /// tier has its own env knob, `DNGD_KERNEL`, which sets the process
    /// default rather than this per-config override.)
    pub fn from_env() -> KernelConfig {
        let threads = std::env::var("DNGD_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            });
        KernelConfig::with_threads(threads)
    }
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig::serial()
    }
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Packed length of an A block: `mb` rows in MR-tall panels over a `kc`
/// reduction block. The [`arena`] buffer for a pack destination is
/// sized with this before packing.
#[inline]
fn packed_a_len(mb: usize, kc: usize) -> usize {
    mb.div_ceil(MR) * kc * MR
}

/// Packed length of a B block: `nb` columns in NR-wide panels.
#[inline]
fn packed_b_len(nb: usize, kc: usize) -> usize {
    nb.div_ceil(NR) * kc * NR
}

/// Pack an `mb × kc` block of a row-major buffer (element `(i, p)` at
/// `src[i * lda + p]`) into MR-tall, k-major micro-panels. `dst` must be
/// [`packed_a_len`]-sized (an arena view); it is zero-filled first so
/// tail rows are zero-padded and the micro-kernel never branches.
fn pack_a_n(dst: &mut [f64], src: &[f64], lda: usize, mb: usize, kc: usize) {
    let panels = mb.div_ceil(MR);
    debug_assert_eq!(dst.len(), panels * kc * MR);
    dst.fill(0.0);
    for ip in 0..panels {
        let i0 = ip * MR;
        let rows = MR.min(mb - i0);
        let panel = &mut dst[ip * kc * MR..(ip + 1) * kc * MR];
        for r in 0..rows {
            let srow = &src[(i0 + r) * lda..(i0 + r) * lda + kc];
            for (p, &v) in srow.iter().enumerate() {
                panel[p * MR + r] = v;
            }
        }
    }
}

/// Same as [`pack_a_n`] but the buffer holds the transpose: logical
/// element `(i, p)` lives at `src[p * lda + i]`. The packed layout is
/// identical, so the micro-kernel is oblivious to the source layout.
fn pack_a_t(dst: &mut [f64], src: &[f64], lda: usize, mb: usize, kc: usize) {
    let panels = mb.div_ceil(MR);
    debug_assert_eq!(dst.len(), panels * kc * MR);
    dst.fill(0.0);
    for ip in 0..panels {
        let i0 = ip * MR;
        let rows = MR.min(mb - i0);
        let panel = &mut dst[ip * kc * MR..(ip + 1) * kc * MR];
        for p in 0..kc {
            let srow = &src[p * lda + i0..p * lda + i0 + rows];
            for (r, &v) in srow.iter().enumerate() {
                panel[p * MR + r] = v;
            }
        }
    }
}

/// Pack a `kc × nb` block of B (element `(p, j)` at `src[p * ldb + j]`)
/// into NR-wide, k-major micro-panels with zero-padded tail columns.
/// `dst` must be [`packed_b_len`]-sized.
fn pack_b_n(dst: &mut [f64], src: &[f64], ldb: usize, kc: usize, nb: usize) {
    let panels = nb.div_ceil(NR);
    debug_assert_eq!(dst.len(), panels * kc * NR);
    dst.fill(0.0);
    for jp in 0..panels {
        let j0 = jp * NR;
        let cols = NR.min(nb - j0);
        let panel = &mut dst[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let srow = &src[p * ldb + j0..p * ldb + j0 + cols];
            for (c, &v) in srow.iter().enumerate() {
                panel[p * NR + c] = v;
            }
        }
    }
}

/// Same as [`pack_b_n`] but the buffer holds the transpose: logical
/// element `(p, j)` lives at `src[j * ldb + p]`.
fn pack_b_t(dst: &mut [f64], src: &[f64], ldb: usize, kc: usize, nb: usize) {
    let panels = nb.div_ceil(NR);
    debug_assert_eq!(dst.len(), panels * kc * NR);
    dst.fill(0.0);
    for jp in 0..panels {
        let j0 = jp * NR;
        let cols = NR.min(nb - j0);
        let panel = &mut dst[jp * kc * NR..(jp + 1) * kc * NR];
        for c in 0..cols {
            let scol = &src[(j0 + c) * ldb..(j0 + c) * ldb + kc];
            for (p, &v) in scol.iter().enumerate() {
                panel[p * NR + c] = v;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Macro-kernel (micro-kernels live in `simd`, dispatched per tier)
// ---------------------------------------------------------------------------

/// Accumulate `alpha ·` the first `nrows` rows of a micro-tile into C
/// at block-relative origin `(i0, j0)` (plus the `(ic, jc)` block
/// origin), clipping to `ncols` valid columns.
#[allow(clippy::too_many_arguments)]
#[inline]
fn writeback_tile(
    acc: &[[f64; NR]],
    nrows: usize,
    ncols: usize,
    alpha: f64,
    c: &mut [f64],
    ldc: usize,
    row0: usize,
    col0: usize,
) {
    for (r, accrow) in acc.iter().enumerate().take(nrows) {
        let off = (row0 + r) * ldc + col0;
        let crow = &mut c[off..off + ncols];
        for (cv, av) in crow.iter_mut().zip(&accrow[..ncols]) {
            *cv += alpha * av;
        }
    }
}

/// Sweep the packed panels over an `mc × nc` block of C, accumulating
/// `C += alpha * A_pack · B_pack` on the `isa` tier's micro-kernel.
/// `c` element `(i, j)` (block-relative plus the `(ic, jc)` block
/// origin) lives at `c[(ic+i)*ldc + jc+j]`.
///
/// On the AVX-512 tier adjacent MR-panels are paired into one 8×8 zmm
/// tile (identical per-element arithmetic to two 4×8 FMA tiles — see
/// [`simd::microkernel_8x8`] — so the pairing cannot perturb the
/// threaded band-partition bit-identity); the odd tail panel and every
/// other tier run the 4×8 kernel.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    isa: KernelIsa,
    mc: usize,
    nc: usize,
    kc: usize,
    alpha: f64,
    ap: &[f64],
    bp: &[f64],
    c: &mut [f64],
    ldc: usize,
    ic: usize,
    jc: usize,
) {
    let apanels = mc.div_ceil(MR);
    let bpanels = nc.div_ceil(NR);
    let pair = isa == KernelIsa::Avx512;
    for jp in 0..bpanels {
        let j0 = jp * NR;
        let ncols = NR.min(nc - j0);
        let bpan = &bp[jp * kc * NR..(jp + 1) * kc * NR];
        let mut ip = 0;
        while ip < apanels {
            let i0 = ip * MR;
            if pair && ip + 1 < apanels {
                let apan0 = &ap[ip * kc * MR..(ip + 1) * kc * MR];
                let apan1 = &ap[(ip + 1) * kc * MR..(ip + 2) * kc * MR];
                let acc = microkernel_8x8(isa, apan0, apan1, bpan);
                let nrows = (2 * MR).min(mc - i0);
                writeback_tile(&acc, nrows, ncols, alpha, c, ldc, ic + i0, jc + j0);
                ip += 2;
            } else {
                let apan = &ap[ip * kc * MR..(ip + 1) * kc * MR];
                let acc = microkernel_4x8(isa, apan, bpan);
                let nrows = MR.min(mc - i0);
                writeback_tile(&acc, nrows, ncols, alpha, c, ldc, ic + i0, jc + j0);
                ip += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// General packed GEMM: `C = alpha · op(A) · op(B) + beta · C` with
/// logical shapes `op(A): m×k`, `op(B): k×n`, `C: m×n`.
///
/// Operands are raw row-major slices with explicit leading dimensions so
/// the same driver serves whole matrices and sub-blocks (the Cholesky
/// trailing update and the blocked TRSM pass strided sub-views of the
/// factor). `ta`/`tb` describe the *storage*: `Trans::T` means the buffer
/// holds the transpose of the logical operand and packing untransposes
/// it on the fly.
#[allow(clippy::too_many_arguments)]
pub fn dgemm(
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    ta: Trans,
    b: &[f64],
    ldb: usize,
    tb: Trans,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    counters::record_dgemm();
    dgemm_core(active_isa(), m, n, k, alpha, a, lda, ta, b, ldb, tb, beta, c, ldc);
}

/// The counter-free serial driver body, shared by [`dgemm`] and the
/// per-band pool jobs of [`dgemm_threaded`]. Runs on the explicit `isa`
/// tier; packing panels come from the calling thread's arena slots
/// (zero allocation once warm).
#[allow(clippy::too_many_arguments)]
fn dgemm_core(
    isa: KernelIsa,
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    ta: Trans,
    b: &[f64],
    ldb: usize,
    tb: Trans,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
) {
    if beta != 1.0 {
        for i in 0..m {
            for cv in &mut c[i * ldc..i * ldc + n] {
                *cv *= beta;
            }
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    let mut apbuf = arena::take(Slot::PackA);
    let mut bpbuf = arena::take(Slot::PackB);
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let bp = bpbuf.ensure(packed_b_len(nc, kc));
            match tb {
                Trans::N => pack_b_n(bp, &b[pc * ldb + jc..], ldb, kc, nc),
                Trans::T => pack_b_t(bp, &b[jc * ldb + pc..], ldb, kc, nc),
            }
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                let ap = apbuf.ensure(packed_a_len(mc, kc));
                match ta {
                    Trans::N => pack_a_n(ap, &a[ic * lda + pc..], lda, mc, kc),
                    Trans::T => pack_a_t(ap, &a[pc * lda + ic..], lda, mc, kc),
                }
                macro_kernel(isa, mc, nc, kc, alpha, ap, bp, c, ldc, ic, jc);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
    arena::put(Slot::PackA, apbuf);
    arena::put(Slot::PackB, bpbuf);
}

/// Raw-pointer Send wrappers for smuggling borrowed buffers into
/// `'static` pool jobs. SAFETY contract: the submitting call must not
/// return (or otherwise end the underlying borrow) before every job is
/// accounted for — [`KernelPool::run`] / [`BatchGuard`] enforce this.
#[derive(Clone, Copy)]
pub(crate) struct SendMut(pub(crate) *mut f64);
unsafe impl Send for SendMut {}

#[derive(Clone, Copy)]
pub(crate) struct SendConst(pub(crate) *const f64);
unsafe impl Send for SendConst {}

/// Minimum FLOP count (2mnk) below which [`dgemm_threaded`] stays
/// serial: splitting pays two pool round-trips (~µs) plus duplicated
/// B-packing, which small products never recover.
const PAR_MIN_FLOPS: usize = 1 << 22;

/// Multi-threaded GEMM on the persistent kernel pool: `C = alpha ·
/// op(A) · op(B) + beta · C`, **bit-identical to [`dgemm`] for every
/// thread count**.
///
/// The m dimension is split into contiguous bands of whole MC row
/// blocks, one pool job per band; each job beta-scales and accumulates
/// only its own C rows, running the same packed driver over the same KC
/// reduction blocks as the serial sweep (see the module docs for why
/// any C-partitioning is bit-exact). Unlike SYRK's triangular load,
/// GEMM load is uniform in rows, so contiguous bands balance and keep
/// each job's C region a single disjoint slice.
///
/// Falls back to the serial driver when `threads ≤ 1`, when there are
/// not at least two MC bands to deal, or when the product is too small
/// to amortize the pool round-trip.
#[allow(clippy::too_many_arguments)]
pub fn dgemm_threaded(
    m: usize,
    n: usize,
    k: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    ta: Trans,
    b: &[f64],
    ldb: usize,
    tb: Trans,
    beta: f64,
    c: &mut [f64],
    ldc: usize,
    threads: usize,
) {
    let blocks = m.div_ceil(MC.max(1));
    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if threads <= 1 || blocks < 2 || flops < PAR_MIN_FLOPS {
        dgemm(m, n, k, alpha, a, lda, ta, b, ldb, tb, beta, c, ldc);
        return;
    }
    counters::record_dgemm();
    // One tier for the whole call: captured here, passed into every
    // band job, so a caller-side `with_isa` override (thread-local)
    // cannot desynchronize the workers from the serial reference.
    let isa = active_isa();
    let jobs_n = threads.min(blocks);
    let chunk_blocks = blocks.div_ceil(jobs_n);
    let aptr = SendConst(a.as_ptr());
    let alen = a.len();
    let bptr = SendConst(b.as_ptr());
    let blen = b.len();
    let cptr = SendMut(c.as_mut_ptr());
    let clen = c.len();
    let mut jobs: Vec<KernelJob> = Vec::with_capacity(jobs_n);
    let mut r0 = 0usize;
    while r0 < m {
        let r1 = (r0 + chunk_blocks * MC).min(m);
        jobs.push(Box::new(move || {
            // SAFETY: rows [r0, r1) of C form the contiguous region
            // [r0*ldc, r1*ldc) (clipped to the buffer for the last
            // band), disjoint from every other job's region; A and B
            // are only read. The caller blocks in `run` below until all
            // jobs are accounted for, keeping the borrows alive.
            let a = unsafe { std::slice::from_raw_parts(aptr.0, alen) };
            let b = unsafe { std::slice::from_raw_parts(bptr.0, blen) };
            let cend = (r1 * ldc).min(clen);
            let cband =
                unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * ldc), cend - r0 * ldc) };
            let asub = match ta {
                Trans::N => &a[r0 * lda..],
                Trans::T => &a[r0..],
            };
            dgemm_core(isa, r1 - r0, n, k, alpha, asub, lda, ta, b, ldb, tb, beta, cband, ldc);
        }));
        r0 = r1;
    }
    global_pool().run(jobs);
}

/// Lower-triangle SYRK row panel: accumulates rows `[i0, i1)` of
/// `W += A·Aᵀ` for `A: n×m` into `wrows` (the contiguous row-major rows
/// `i0..i1` of an n×n W). Only columns `0..i1` are touched — micro-tiles
/// strictly above the diagonal are skipped, which halves the FLOPs of the
/// Gram stage versus a general NT product.
///
/// The computation is a pure function of `(a, i0, i1)` *and the active
/// ISA tier* — the packing, tile order and accumulation order never
/// depend on what other panels are doing — so any panel-parallel
/// schedule is bit-identical to the serial sweep within a tier
/// ([`syrk_parallel`](super::gemm::syrk_parallel) re-establishes the
/// caller's tier inside its jobs). The SYRK determinism test pins this
/// property. All tiers use the 4×8 micro-kernel here: the diagonal
/// skip is decided per MR-panel, so the AVX-512 8×8 pairing would
/// complicate the triangle logic for no arithmetic difference.
pub fn syrk_panel(a: &[f64], n: usize, m: usize, i0: usize, i1: usize, wrows: &mut [f64]) {
    debug_assert!(i0 < i1 && i1 <= n);
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(wrows.len(), (i1 - i0) * n);
    let isa = active_isa();
    let mb = i1 - i0;
    let jb = i1;
    let mut apbuf = arena::take(Slot::PackA);
    let mut bpbuf = arena::take(Slot::PackB);
    let mut pc = 0;
    while pc < m {
        let kc = KC.min(m - pc);
        // B = Aᵀ block: logical (p, j) ↦ A[j][pc+p], columns 0..i1 only.
        let bp = bpbuf.ensure(packed_b_len(jb, kc));
        pack_b_t(bp, &a[pc..], m, kc, jb);
        let ap = apbuf.ensure(packed_a_len(mb, kc));
        pack_a_n(ap, &a[i0 * m + pc..], m, mb, kc);
        let apanels = mb.div_ceil(MR);
        let bpanels = jb.div_ceil(NR);
        for ip in 0..apanels {
            let r0 = ip * MR;
            let nrows = MR.min(mb - r0);
            let glast = i0 + r0 + nrows - 1;
            let apan = &ap[ip * kc * MR..(ip + 1) * kc * MR];
            for jp in 0..bpanels {
                let j0 = jp * NR;
                if j0 > glast {
                    break;
                }
                let ncols = NR.min(jb - j0);
                let bpan = &bp[jp * kc * NR..(jp + 1) * kc * NR];
                let acc = microkernel_4x8(isa, apan, bpan);
                for (r, accrow) in acc.iter().enumerate().take(nrows) {
                    let off = (r0 + r) * n + j0;
                    let crow = &mut wrows[off..off + ncols];
                    for (cv, av) in crow.iter_mut().zip(&accrow[..ncols]) {
                        *cv += av;
                    }
                }
            }
        }
        pc += kc;
    }
    arena::put(Slot::PackA, apbuf);
    arena::put(Slot::PackB, bpbuf);
}

// ---------------------------------------------------------------------------
// f32 drivers (PR 6 — mixed-precision path)
// ---------------------------------------------------------------------------
//
// Structural mirror of the f64 driver stack above at the f32 tile
// shape MR32×NR32 (AVX-512 pairs panels into 16×8): same BLIS
// blocking (KC/MC/NC element counts, so the f32 packed panels occupy
// half the bytes of the f64 ones and reuse the same warm arena slots),
// same `p`-increasing per-element accumulation, same band-partition
// threading — so the determinism contract carries over verbatim:
// f32 threaded ≡ f32 serial bitwise at every thread count within a
// fixed ISA tier. These drivers feed the mixed-precision sessions,
// whose f64 iterative refinement (see `solver::chol`) converges
// whenever κ(λI + SᵀS/m)·u₃₂ ≪ 1.

/// Packed length of an f32 A block: `mb` rows in MR32-tall panels.
#[inline]
fn packed_a_len_f32(mb: usize, kc: usize) -> usize {
    mb.div_ceil(MR32) * kc * MR32
}

/// Packed length of an f32 B block: `nb` columns in NR32-wide panels.
#[inline]
fn packed_b_len_f32(nb: usize, kc: usize) -> usize {
    nb.div_ceil(NR32) * kc * NR32
}

/// [`pack_a_n`] at f32: MR32-tall, k-major micro-panels, zero-padded
/// tail rows.
fn pack_a_n_f32(dst: &mut [f32], src: &[f32], lda: usize, mb: usize, kc: usize) {
    let panels = mb.div_ceil(MR32);
    debug_assert_eq!(dst.len(), panels * kc * MR32);
    dst.fill(0.0);
    for ip in 0..panels {
        let i0 = ip * MR32;
        let rows = MR32.min(mb - i0);
        let panel = &mut dst[ip * kc * MR32..(ip + 1) * kc * MR32];
        for r in 0..rows {
            let srow = &src[(i0 + r) * lda..(i0 + r) * lda + kc];
            for (p, &v) in srow.iter().enumerate() {
                panel[p * MR32 + r] = v;
            }
        }
    }
}

/// [`pack_a_t`] at f32: the buffer holds the transpose, the packed
/// layout is identical.
fn pack_a_t_f32(dst: &mut [f32], src: &[f32], lda: usize, mb: usize, kc: usize) {
    let panels = mb.div_ceil(MR32);
    debug_assert_eq!(dst.len(), panels * kc * MR32);
    dst.fill(0.0);
    for ip in 0..panels {
        let i0 = ip * MR32;
        let rows = MR32.min(mb - i0);
        let panel = &mut dst[ip * kc * MR32..(ip + 1) * kc * MR32];
        for p in 0..kc {
            let srow = &src[p * lda + i0..p * lda + i0 + rows];
            for (r, &v) in srow.iter().enumerate() {
                panel[p * MR32 + r] = v;
            }
        }
    }
}

/// [`pack_b_n`] at f32: NR32-wide, k-major micro-panels, zero-padded
/// tail columns.
fn pack_b_n_f32(dst: &mut [f32], src: &[f32], ldb: usize, kc: usize, nb: usize) {
    let panels = nb.div_ceil(NR32);
    debug_assert_eq!(dst.len(), panels * kc * NR32);
    dst.fill(0.0);
    for jp in 0..panels {
        let j0 = jp * NR32;
        let cols = NR32.min(nb - j0);
        let panel = &mut dst[jp * kc * NR32..(jp + 1) * kc * NR32];
        for p in 0..kc {
            let srow = &src[p * ldb + j0..p * ldb + j0 + cols];
            for (c, &v) in srow.iter().enumerate() {
                panel[p * NR32 + c] = v;
            }
        }
    }
}

/// [`pack_b_t`] at f32.
fn pack_b_t_f32(dst: &mut [f32], src: &[f32], ldb: usize, kc: usize, nb: usize) {
    let panels = nb.div_ceil(NR32);
    debug_assert_eq!(dst.len(), panels * kc * NR32);
    dst.fill(0.0);
    for jp in 0..panels {
        let j0 = jp * NR32;
        let cols = NR32.min(nb - j0);
        let panel = &mut dst[jp * kc * NR32..(jp + 1) * kc * NR32];
        for c in 0..cols {
            let scol = &src[(j0 + c) * ldb..(j0 + c) * ldb + kc];
            for (p, &v) in scol.iter().enumerate() {
                panel[p * NR32 + c] = v;
            }
        }
    }
}

/// [`writeback_tile`] at f32.
#[allow(clippy::too_many_arguments)]
#[inline]
fn writeback_tile_f32(
    acc: &[[f32; NR32]],
    nrows: usize,
    ncols: usize,
    alpha: f32,
    c: &mut [f32],
    ldc: usize,
    row0: usize,
    col0: usize,
) {
    for (r, accrow) in acc.iter().enumerate().take(nrows) {
        let off = (row0 + r) * ldc + col0;
        let crow = &mut c[off..off + ncols];
        for (cv, av) in crow.iter_mut().zip(&accrow[..ncols]) {
            *cv += alpha * av;
        }
    }
}

/// [`macro_kernel`] at f32: sweep the packed panels over an `mc × nc`
/// block of C on the `isa` tier's 8×8 micro-kernel, pairing adjacent
/// MR32-panels into the native 16×8 tile on AVX-512 (value-preserving —
/// see [`simd::microkernel_16x8_f32`]).
#[allow(clippy::too_many_arguments)]
fn macro_kernel_f32(
    isa: KernelIsa,
    mc: usize,
    nc: usize,
    kc: usize,
    alpha: f32,
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    ic: usize,
    jc: usize,
) {
    let apanels = mc.div_ceil(MR32);
    let bpanels = nc.div_ceil(NR32);
    let pair = isa == KernelIsa::Avx512;
    for jp in 0..bpanels {
        let j0 = jp * NR32;
        let ncols = NR32.min(nc - j0);
        let bpan = &bp[jp * kc * NR32..(jp + 1) * kc * NR32];
        let mut ip = 0;
        while ip < apanels {
            let i0 = ip * MR32;
            if pair && ip + 1 < apanels {
                let apan0 = &ap[ip * kc * MR32..(ip + 1) * kc * MR32];
                let apan1 = &ap[(ip + 1) * kc * MR32..(ip + 2) * kc * MR32];
                let acc = microkernel_16x8_f32(isa, apan0, apan1, bpan);
                let nrows = (2 * MR32).min(mc - i0);
                writeback_tile_f32(&acc, nrows, ncols, alpha, c, ldc, ic + i0, jc + j0);
                ip += 2;
            } else {
                let apan = &ap[ip * kc * MR32..(ip + 1) * kc * MR32];
                let acc = microkernel_8x8_f32(isa, apan, bpan);
                let nrows = MR32.min(mc - i0);
                writeback_tile_f32(&acc, nrows, ncols, alpha, c, ldc, ic + i0, jc + j0);
                ip += 1;
            }
        }
    }
}

/// f32 packed GEMM: `C = alpha · op(A) · op(B) + beta · C` — the
/// [`dgemm`] driver at f32 (same blocking, same packing-absorbed
/// transposition, same arena slots).
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    ta: Trans,
    b: &[f32],
    ldb: usize,
    tb: Trans,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) {
    counters::record_sgemm();
    sgemm_core(active_isa(), m, n, k, alpha, a, lda, ta, b, ldb, tb, beta, c, ldc);
}

/// The counter-free serial f32 driver body, shared by [`sgemm`] and the
/// per-band pool jobs of [`sgemm_threaded`].
#[allow(clippy::too_many_arguments)]
fn sgemm_core(
    isa: KernelIsa,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    ta: Trans,
    b: &[f32],
    ldb: usize,
    tb: Trans,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
) {
    if beta != 1.0 {
        for i in 0..m {
            for cv in &mut c[i * ldc..i * ldc + n] {
                *cv *= beta;
            }
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    let mut apbuf = arena::take(Slot::PackA);
    let mut bpbuf = arena::take(Slot::PackB);
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            let bp = bpbuf.ensure_f32(packed_b_len_f32(nc, kc));
            match tb {
                Trans::N => pack_b_n_f32(bp, &b[pc * ldb + jc..], ldb, kc, nc),
                Trans::T => pack_b_t_f32(bp, &b[jc * ldb + pc..], ldb, kc, nc),
            }
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                let ap = apbuf.ensure_f32(packed_a_len_f32(mc, kc));
                match ta {
                    Trans::N => pack_a_n_f32(ap, &a[ic * lda + pc..], lda, mc, kc),
                    Trans::T => pack_a_t_f32(ap, &a[pc * lda + ic..], lda, mc, kc),
                }
                macro_kernel_f32(isa, mc, nc, kc, alpha, ap, bp, c, ldc, ic, jc);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
    arena::put(Slot::PackA, apbuf);
    arena::put(Slot::PackB, bpbuf);
}

/// f32 raw-pointer Send wrappers — see [`SendMut`]/[`SendConst`] for
/// the safety contract (the submitting call must outlive every job).
#[derive(Clone, Copy)]
pub(crate) struct SendMutF32(pub(crate) *mut f32);
unsafe impl Send for SendMutF32 {}

#[derive(Clone, Copy)]
pub(crate) struct SendConstF32(pub(crate) *const f32);
unsafe impl Send for SendConstF32 {}

/// Multi-threaded f32 GEMM on the persistent kernel pool —
/// [`dgemm_threaded`]'s MC-band partition at f32, **bit-identical to
/// [`sgemm`] for every thread count** within a fixed ISA tier (the
/// band partition changes packing locality, never the per-element
/// summation order, and k is never split).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_threaded(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    lda: usize,
    ta: Trans,
    b: &[f32],
    ldb: usize,
    tb: Trans,
    beta: f32,
    c: &mut [f32],
    ldc: usize,
    threads: usize,
) {
    let blocks = m.div_ceil(MC.max(1));
    let flops = 2usize.saturating_mul(m).saturating_mul(n).saturating_mul(k);
    if threads <= 1 || blocks < 2 || flops < PAR_MIN_FLOPS {
        sgemm(m, n, k, alpha, a, lda, ta, b, ldb, tb, beta, c, ldc);
        return;
    }
    counters::record_sgemm();
    let isa = active_isa();
    let jobs_n = threads.min(blocks);
    let chunk_blocks = blocks.div_ceil(jobs_n);
    let aptr = SendConstF32(a.as_ptr());
    let alen = a.len();
    let bptr = SendConstF32(b.as_ptr());
    let blen = b.len();
    let cptr = SendMutF32(c.as_mut_ptr());
    let clen = c.len();
    let mut jobs: Vec<KernelJob> = Vec::with_capacity(jobs_n);
    let mut r0 = 0usize;
    while r0 < m {
        let r1 = (r0 + chunk_blocks * MC).min(m);
        jobs.push(Box::new(move || {
            // SAFETY: as in `dgemm_threaded` — rows [r0, r1) of C are a
            // contiguous region disjoint from every other job's; A and
            // B are only read; the caller blocks in `run` below.
            let a = unsafe { std::slice::from_raw_parts(aptr.0, alen) };
            let b = unsafe { std::slice::from_raw_parts(bptr.0, blen) };
            let cend = (r1 * ldc).min(clen);
            let cband =
                unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * ldc), cend - r0 * ldc) };
            let asub = match ta {
                Trans::N => &a[r0 * lda..],
                Trans::T => &a[r0..],
            };
            sgemm_core(isa, r1 - r0, n, k, alpha, asub, lda, ta, b, ldb, tb, beta, cband, ldc);
        }));
        r0 = r1;
    }
    global_pool().run(jobs);
}

/// Lower-triangle f32 SYRK row panel — [`syrk_panel`] at f32:
/// accumulates rows `[i0, i1)` of `W += A·Aᵀ` for `A: n×m`, touching
/// only columns `0..i1`. A pure function of `(a, i0, i1)` and the
/// active tier, so any panel-parallel schedule is bit-identical to the
/// serial sweep within a tier. All tiers use the 8×8 micro-kernel here
/// (the per-MR32-panel diagonal skip keeps the triangle logic simple,
/// and pairing would not change a value anyway).
pub fn syrk_panel_f32(a: &[f32], n: usize, m: usize, i0: usize, i1: usize, wrows: &mut [f32]) {
    debug_assert!(i0 < i1 && i1 <= n);
    debug_assert_eq!(a.len(), n * m);
    debug_assert_eq!(wrows.len(), (i1 - i0) * n);
    let isa = active_isa();
    let mb = i1 - i0;
    let jb = i1;
    let mut apbuf = arena::take(Slot::PackA);
    let mut bpbuf = arena::take(Slot::PackB);
    let mut pc = 0;
    while pc < m {
        let kc = KC.min(m - pc);
        let bp = bpbuf.ensure_f32(packed_b_len_f32(jb, kc));
        pack_b_t_f32(bp, &a[pc..], m, kc, jb);
        let ap = apbuf.ensure_f32(packed_a_len_f32(mb, kc));
        pack_a_n_f32(ap, &a[i0 * m + pc..], m, mb, kc);
        let apanels = mb.div_ceil(MR32);
        let bpanels = jb.div_ceil(NR32);
        for ip in 0..apanels {
            let r0 = ip * MR32;
            let nrows = MR32.min(mb - r0);
            let glast = i0 + r0 + nrows - 1;
            let apan = &ap[ip * kc * MR32..(ip + 1) * kc * MR32];
            for jp in 0..bpanels {
                let j0 = jp * NR32;
                if j0 > glast {
                    break;
                }
                let ncols = NR32.min(jb - j0);
                let bpan = &bp[jp * kc * NR32..(jp + 1) * kc * NR32];
                let acc = microkernel_8x8_f32(isa, apan, bpan);
                for (r, accrow) in acc.iter().enumerate().take(nrows) {
                    let off = (r0 + r) * n + j0;
                    let crow = &mut wrows[off..off + ncols];
                    for (cv, av) in crow.iter_mut().zip(&accrow[..ncols]) {
                        *cv += av;
                    }
                }
            }
        }
        pc += kc;
    }
    arena::put(Slot::PackA, apbuf);
    arena::put(Slot::PackB, bpbuf);
}

// ---------------------------------------------------------------------------
// Persistent kernel worker pool
// ---------------------------------------------------------------------------

/// A boxed kernel job. Jobs are `'static`: callers that need to touch
/// borrowed matrices smuggle raw pointers in (see
/// [`syrk_parallel`](super::gemm::syrk_parallel)) and rely on
/// [`KernelPool::run`] blocking until every job has acknowledged
/// completion, which keeps the borrows alive across execution.
pub type KernelJob = Box<dyn FnOnce() + Send + 'static>;

/// Persistent worker pool for the threaded kernels.
///
/// Spawned once per process ([`global_pool`]), the workers park on their
/// channels between calls — repeated `syrk_parallel` invocations reuse
/// the same OS threads instead of paying spawn/join per solve as the
/// seed `std::thread::scope` version did (tens of microseconds per call,
/// which dominated small-n Gram steps in the training loop).
pub struct KernelPool {
    senders: Mutex<Vec<SyncSender<KernelJob>>>,
    size: usize,
}

/// Per-job completion beacon: reports on drop, so a job is accounted
/// for whether it returned normally (`ok = true`), panicked mid-run, or
/// its closure was dropped unexecuted — [`KernelPool::run`] must never
/// return while any raw-pointer job could still be live.
struct DoneGuard {
    tx: std::sync::mpsc::Sender<bool>,
    ok: bool,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(self.ok);
    }
}

impl KernelPool {
    /// Spawn `size` detached workers. Workers live for the process —
    /// they exit only when the channel closes at teardown. A panicking
    /// job is caught (`catch_unwind`) so it can never kill its worker
    /// and poison the process-wide pool for later, unrelated solves.
    fn spawn(size: usize) -> KernelPool {
        let size = size.max(1);
        let mut senders = Vec::with_capacity(size);
        for id in 0..size {
            let (tx, rx) = sync_channel::<KernelJob>(64);
            std::thread::Builder::new()
                .name(format!("dngd-kernel-{id}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    }
                })
                .expect("spawn kernel worker");
            senders.push(tx);
        }
        KernelPool { senders: Mutex::new(senders), size }
    }

    /// Number of persistent workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run a batch of jobs to completion, dealing them round-robin
    /// across the workers.
    ///
    /// Blocks until every submitted job is *accounted for* — completed,
    /// panicked, or provably never-will-run — before returning or
    /// panicking. This is the safety contract callers like
    /// [`syrk_parallel`](super::gemm::syrk_parallel) rely on: their jobs
    /// hold raw pointers into caller-owned buffers, so `run` must never
    /// unwind while a sibling job could still be executing. Panics
    /// (afterwards, safely) if any job failed.
    pub fn run(&self, jobs: Vec<KernelJob>) {
        self.submit(jobs).wait();
    }

    /// Submit a batch without blocking, returning a [`BatchGuard`] that
    /// must be waited on (and waits on drop regardless, so an early
    /// return or unwind can never leave a raw-pointer job live). This
    /// is the lookahead primitive: the blocked Cholesky submits the
    /// trailing downdate, factors the next diagonal panel on the caller
    /// thread, then waits.
    pub fn submit(&self, jobs: Vec<KernelJob>) -> BatchGuard {
        let total = jobs.len();
        let (done_tx, done_rx) = channel::<bool>();
        let mut submitted = 0usize;
        {
            let senders = self.senders.lock().expect("kernel pool poisoned");
            for (i, job) in jobs.into_iter().enumerate() {
                let guard_tx = done_tx.clone();
                let wrapped: KernelJob = Box::new(move || {
                    let mut guard = DoneGuard { tx: guard_tx, ok: false };
                    job();
                    guard.ok = true;
                });
                // A failed send returns (and drops) the wrapped job —
                // its guard channel clone just closes, nothing runs.
                if senders[i % senders.len()].send(wrapped).is_err() {
                    break;
                }
                submitted += 1;
            }
        }
        drop(done_tx);
        BatchGuard { done_rx, submitted, total, acked: 0, failed: false, drained: false }
    }
}

/// Handle for an in-flight [`KernelPool::submit`] batch.
///
/// Dropping the guard blocks until every submitted job is accounted for
/// (completed, panicked, or provably never-will-run) — the same safety
/// contract as [`KernelPool::run`] — so raw-pointer jobs can never
/// outlive the borrows they capture, even on an unwinding path.
/// [`BatchGuard::wait`] additionally surfaces job failures as a panic;
/// the drop path stays silent to avoid a double panic during unwind.
#[must_use = "the batch is only known complete after wait()"]
pub struct BatchGuard {
    done_rx: std::sync::mpsc::Receiver<bool>,
    submitted: usize,
    total: usize,
    acked: usize,
    failed: bool,
    drained: bool,
}

impl BatchGuard {
    /// Drain one ack per submitted job. Disconnection means every
    /// outstanding wrapped job has been destroyed (all guard senders
    /// dropped), so no job can still be running — safe to stop.
    fn drain(&mut self) {
        if self.drained {
            return;
        }
        while self.acked < self.submitted {
            match self.done_rx.recv() {
                Ok(true) => self.acked += 1,
                Ok(false) => {
                    self.acked += 1;
                    self.failed = true;
                }
                Err(_) => {
                    self.failed = true;
                    break;
                }
            }
        }
        self.drained = true;
    }

    /// Block until the batch completes; panic if any job failed.
    pub fn wait(mut self) {
        self.drain();
        assert!(
            !self.failed && self.submitted == self.total,
            "kernel pool batch incomplete ({}/{} ok): worker panic or dead worker",
            self.acked,
            self.total
        );
    }
}

impl Drop for BatchGuard {
    fn drop(&mut self) {
        self.drain();
    }
}

/// The process-wide pool, lazily spawned with one worker per available
/// core (capped at 16 — SYRK saturates memory bandwidth well before
/// that on the shapes this crate targets).
pub fn global_pool() -> &'static KernelPool {
    static POOL: OnceLock<KernelPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let size = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(16);
        KernelPool::spawn(size)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(m: usize, n: usize, k: usize, a: &dyn Fn(usize, usize) -> f64, b: &dyn Fn(usize, usize) -> f64) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a(i, p) * b(p, j);
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        // Tiny LCG — enough for kernel shape tests, no Mat dependency.
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
            })
            .collect()
    }

    #[test]
    fn dgemm_nn_odd_shapes_match_naive() {
        for &(m, n, k) in
            &[(1, 1, 1), (3, 5, 7), (MR, NR, KC), (MR + 1, NR + 1, KC + 1), (13, 17, 300)]
        {
            let a = fill(m * k, 1);
            let b = fill(k * n, 2);
            let mut c = vec![0.0; m * n];
            dgemm(m, n, k, 1.0, &a, k, Trans::N, &b, n, Trans::N, 0.0, &mut c, n);
            let want = naive(m, n, k, &|i, p| a[i * k + p], &|p, j| b[p * n + j]);
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert!((x - y).abs() < 1e-12, "({m},{n},{k}) idx {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn dgemm_transposed_layouts_match_naive() {
        let (m, n, k) = (9, 11, 37);
        let at = fill(k * m, 3); // buffer k×m: logical A[i][p] = at[p*m + i]
        let bt = fill(n * k, 4); // buffer n×k: logical B[p][j] = bt[j*k + p]
        let want = naive(m, n, k, &|i, p| at[p * m + i], &|p, j| bt[j * k + p]);
        let mut c = vec![0.0; m * n];
        dgemm(m, n, k, 1.0, &at, m, Trans::T, &bt, k, Trans::T, 0.0, &mut c, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn dgemm_respects_alpha_beta_and_ldc() {
        let (m, n, k) = (4, 3, 5);
        let a = fill(m * k, 5);
        let b = fill(k * n, 6);
        // C embedded in a wider buffer: ldc = n + 2.
        let ldc = n + 2;
        let mut c = fill(m * ldc, 7);
        let c0 = c.clone();
        dgemm(m, n, k, 2.0, &a, k, Trans::N, &b, n, Trans::N, -1.0, &mut c, ldc);
        let prod = naive(m, n, k, &|i, p| a[i * k + p], &|p, j| b[p * n + j]);
        for i in 0..m {
            for j in 0..ldc {
                let got = c[i * ldc + j];
                if j < n {
                    let want = 2.0 * prod[i * n + j] - c0[i * ldc + j];
                    assert!((got - want).abs() < 1e-12);
                } else {
                    // Padding columns are untouched.
                    assert_eq!(got, c0[i * ldc + j]);
                }
            }
        }
    }

    #[test]
    fn syrk_panel_matches_naive_lower_triangle() {
        let (n, m) = (KC - 1, 2 * KC + 3);
        let a = fill(n * m, 8);
        let mut w = vec![0.0; n * n];
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            syrk_panel(&a, n, m, i0, i1, &mut w[i0 * n..i1 * n]);
            i0 = i1;
        }
        for i in 0..n {
            for j in 0..=i {
                let mut s = 0.0;
                for p in 0..m {
                    s += a[i * m + p] * a[j * m + p];
                }
                assert!((w[i * n + j] - s).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn pool_runs_jobs_and_is_reusable() {
        let pool = global_pool();
        assert!(pool.size() >= 1);
        for round in 0..3 {
            let counter = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
            let jobs: Vec<KernelJob> = (0..8)
                .map(|_| {
                    let c = counter.clone();
                    Box::new(move || {
                        c.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }) as KernelJob
                })
                .collect();
            pool.run(jobs);
            assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 8, "round {round}");
        }
    }

    #[test]
    fn submit_overlaps_caller_work_and_waits() {
        // The lookahead primitive: jobs run while the caller computes;
        // wait() establishes the barrier.
        let pool = global_pool();
        let flag = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let jobs: Vec<KernelJob> = (0..4)
            .map(|_| {
                let f = flag.clone();
                Box::new(move || {
                    f.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }) as KernelJob
            })
            .collect();
        let guard = pool.submit(jobs);
        // Caller-side "critical path" work while jobs are in flight.
        let mut acc = 0u64;
        for i in 0..1000u64 {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        guard.wait();
        assert_eq!(flag.load(std::sync::atomic::Ordering::SeqCst), 4);
    }

    #[test]
    fn dgemm_threaded_bit_identical_to_serial() {
        // Cross-checked at scale in tests/threading.rs; this in-module
        // case keeps the invariant pinned next to the implementation.
        // Big enough that the threaded path engages (≥ 2 MC bands and
        // above the PAR_MIN_FLOPS fallback) with every dim off-grid.
        let (m, n, k) = (2 * MC + 9, 8 * NR + 3, KC / 2 + 1);
        let a = fill(m * k, 40);
        let b = fill(k * n, 41);
        let mut c1 = fill(m * n, 42);
        let mut c2 = c1.clone();
        dgemm(m, n, k, 1.5, &a, k, Trans::N, &b, n, Trans::N, 0.5, &mut c1, n);
        dgemm_threaded(m, n, k, 1.5, &a, k, Trans::N, &b, n, Trans::N, 0.5, &mut c2, n, 4);
        assert_eq!(c1, c2);
    }

    #[test]
    fn kernel_config_defaults_and_env_shape() {
        assert_eq!(KernelConfig::default(), KernelConfig::serial());
        assert_eq!(KernelConfig::with_threads(0).threads, 1);
        assert!(KernelConfig::from_env().threads >= 1);
        assert_eq!(KernelConfig::serial().isa, None);
        assert_eq!(KernelConfig::serial().resolved_isa(), active_isa());
        let pinned = KernelConfig::serial().with_isa(Some(KernelIsa::Scalar));
        assert_eq!(pinned.resolved_isa(), KernelIsa::Scalar);
        pinned.run(|| assert_eq!(active_isa(), KernelIsa::Scalar));
    }

    #[test]
    fn dgemm_steady_state_is_arena_allocation_free() {
        let (m, n, k) = (MC + 3, NR + 5, KC + 9);
        let a = fill(m * k, 50);
        let b = fill(k * n, 51);
        let mut c = vec![0.0; m * n];
        // Warm the pack slots at this shape…
        dgemm(m, n, k, 1.0, &a, k, Trans::N, &b, n, Trans::N, 0.0, &mut c, n);
        // …then repeat: zero arena growth.
        let a0 = counters::arena_allocs();
        for _ in 0..3 {
            dgemm(m, n, k, 1.0, &a, k, Trans::N, &b, n, Trans::N, 0.0, &mut c, n);
        }
        assert_eq!(counters::arena_allocs() - a0, 0, "steady-state dgemm must not allocate");
    }

    fn fill_f32(len: usize, seed: u64) -> Vec<f32> {
        fill(len, seed).iter().map(|&x| x as f32).collect()
    }

    #[test]
    fn sgemm_odd_shapes_and_layouts_match_naive() {
        for &(m, n, k) in
            &[(1, 1, 1), (3, 5, 7), (MR32, NR32, KC), (MR32 + 1, NR32 + 1, KC + 1), (13, 17, 300)]
        {
            let a = fill_f32(m * k, 11);
            let b = fill_f32(k * n, 12);
            let mut c = vec![0.0f32; m * n];
            sgemm(m, n, k, 1.0, &a, k, Trans::N, &b, n, Trans::N, 0.0, &mut c, n);
            // f64 oracle over the f32 inputs.
            let want = naive(m, n, k, &|i, p| a[i * k + p] as f64, &|p, j| b[p * n + j] as f64);
            let tol = 1e-4 * (k as f64).max(1.0) as f32;
            for (i, (x, y)) in c.iter().zip(&want).enumerate() {
                assert!((x - *y as f32).abs() < tol, "({m},{n},{k}) idx {i}: {x} vs {y}");
            }
        }
        // Transposed storage layouts pack to the same panels.
        let (m, n, k) = (9, 11, 37);
        let at = fill_f32(k * m, 13);
        let bt = fill_f32(n * k, 14);
        let want = naive(m, n, k, &|i, p| at[p * m + i] as f64, &|p, j| bt[j * k + p] as f64);
        let mut c = vec![0.0f32; m * n];
        sgemm(m, n, k, 1.0, &at, m, Trans::T, &bt, k, Trans::T, 0.0, &mut c, n);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - *y as f32).abs() < 1e-3);
        }
    }

    #[test]
    fn sgemm_threaded_bit_identical_to_serial() {
        let (m, n, k) = (2 * MC + 9, 8 * NR32 + 3, KC / 2 + 1);
        let a = fill_f32(m * k, 43);
        let b = fill_f32(k * n, 44);
        let mut c1 = fill_f32(m * n, 45);
        let mut c2 = c1.clone();
        sgemm(m, n, k, 1.5, &a, k, Trans::N, &b, n, Trans::N, 0.5, &mut c1, n);
        sgemm_threaded(m, n, k, 1.5, &a, k, Trans::N, &b, n, Trans::N, 0.5, &mut c2, n, 4);
        assert_eq!(c1, c2);
    }

    #[test]
    fn syrk_panel_f32_matches_naive_lower_triangle() {
        let (n, m) = (KC - 1, KC + 3);
        let a = fill_f32(n * m, 15);
        let mut w = vec![0.0f32; n * n];
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + MC).min(n);
            syrk_panel_f32(&a, n, m, i0, i1, &mut w[i0 * n..i1 * n]);
            i0 = i1;
        }
        let tol = 1e-3 * (m as f32);
        for i in 0..n {
            for j in 0..=i {
                let mut s = 0.0f64;
                for p in 0..m {
                    s += a[i * m + p] as f64 * a[j * m + p] as f64;
                }
                assert!((w[i * n + j] - s as f32).abs() < tol, "({i},{j})");
            }
        }
    }

    #[test]
    fn f32_and_f64_paths_share_warm_arena_slots() {
        // The element-typed arena (PR 6): alternating f64 and f32 GEMMs
        // at byte-compatible shapes must not grow the slots once warm.
        let (m, n, k) = (MC + 3, NR + 5, KC + 9);
        let a64 = fill(m * k, 60);
        let b64 = fill(k * n, 61);
        let mut c64 = vec![0.0; m * n];
        let a32 = fill_f32(m * k, 62);
        let b32 = fill_f32(k * n, 63);
        let mut c32 = vec![0.0f32; m * n];
        dgemm(m, n, k, 1.0, &a64, k, Trans::N, &b64, n, Trans::N, 0.0, &mut c64, n);
        sgemm(m, n, k, 1.0, &a32, k, Trans::N, &b32, n, Trans::N, 0.0, &mut c32, n);
        let a0 = counters::arena_allocs();
        for _ in 0..3 {
            dgemm(m, n, k, 1.0, &a64, k, Trans::N, &b64, n, Trans::N, 0.0, &mut c64, n);
            sgemm(m, n, k, 1.0, &a32, k, Trans::N, &b32, n, Trans::N, 0.0, &mut c32, n);
        }
        assert_eq!(counters::arena_allocs() - a0, 0, "alternating precisions must not allocate");
    }
}
