//! O(n²) Cholesky factor updates — the streaming/sliding-window engine
//! behind the PR-5 row-rotation subsystem.
//!
//! The paper's separability argument (the Gram `W = SSᵀ + λĨ` is
//! RHS-independent, and `SSᵀ` is λ-independent) extends across *steps*
//! of an online consumer: when successive minibatches overlap in all but
//! k of their n sample rows, the new Gram differs from the old one by k
//! symmetric row/column deletions and k bordered appends. Both have
//! classical O(n²) factor updates, so a k-row rotation costs O(kn²)
//! against the O(n³) of a fresh `Chol(W)` — and, crucially, **zero**
//! O(n²m) Gram SYRKs (the cached Gram is patched with O(knm) panel
//! products, not re-formed).
//!
//! Primitives:
//!
//! * [`UpdatableChol::delete_row`] — symmetric row/column **delete**:
//!   removing row r of `L` leaves an (n−1)×n matrix `M` with
//!   `MMᵀ = W∖{r}` (row products are unchanged); a right-applied sweep
//!   of Givens rotations on column pairs (j, j+1), j = r…n−2,
//!   annihilates the one stray super-diagonal per row and restores
//!   lower-triangularity. Orthogonal rotations preserve `MMᵀ`, so the
//!   result is exactly `Chol(W∖{r})` — no breakdown mode exists.
//! * [`UpdatableChol::append_row`] — symmetric **append** by bordering:
//!   solve `L y = w` (forward substitution, O(n²)), set
//!   `δ = √(d − ‖y‖²)`, and the factor of the bordered matrix is
//!   `[[L, 0], [yᵀ, δ]]`. The pivot `δ²` can lose positivity (the
//!   appended sample makes the damped Gram numerically singular);
//!   that breakdown surfaces as [`CholeskyError`] so consumers reuse
//!   the same λ-backoff / refactor rescue as a cold factorization.
//! * [`chol_update_rank1`] / [`chol_downdate_rank1`] — the classical
//!   rank-one Givens update and its **hyperbolic** downdate
//!   counterpart for `W ± xxᵀ` perturbations that keep the sample set
//!   fixed. The hyperbolic rotations are not orthogonal, so the
//!   downdate has the same breakdown mode as the bordered append
//!   (`L[k][k]² − x[k]² ≤ 0`), surfaced as [`CholeskyError`].
//!
//! [`UpdatableChol`] holds the factor in a fixed-leading-dimension
//! buffer (`ld = capacity`), so deletes and appends move O(n²) data at
//! worst and **zero** reallocation happens in steady state (a sliding
//! window rotates k rows out and k rows in, returning to the same
//! order). The session layer (`solver/chol.rs`, `solver/rvb.rs`) drives
//! these primitives from `Factorization::update_rows` and keeps a full
//! refactor of the patched Gram as the drift/breakdown backstop.

use super::cholesky::CholeskyError;
use super::mat::Mat;

/// A Cholesky factor held in a fixed-leading-dimension buffer so its
/// order can shrink (row/column delete) and grow (bordered append)
/// without repacking. Row i lives at `data[i*ld .. i*ld + n]`; entries
/// above the diagonal (and beyond the current order) are kept zero.
pub struct UpdatableChol {
    data: Vec<f64>,
    /// Current order (the factor is n×n).
    n: usize,
    /// Fixed leading dimension (= allocated max order).
    ld: usize,
}

impl UpdatableChol {
    /// Wrap an existing lower-triangular factor, reserving capacity for
    /// orders up to `cap` (so a rotation that appends before deleting —
    /// or a growing fill-up window — never reallocates mid-update).
    pub fn from_factor(l: &Mat, cap: usize) -> UpdatableChol {
        let n = l.rows();
        assert_eq!(l.cols(), n, "factor must be square");
        let ld = cap.max(n).max(1);
        let mut data = vec![0.0; ld * ld];
        for i in 0..n {
            data[i * ld..i * ld + i + 1].copy_from_slice(&l.row(i)[..i + 1]);
        }
        UpdatableChol { data, n, ld }
    }

    /// Current order of the factor.
    pub fn order(&self) -> usize {
        self.n
    }

    /// Capacity (maximum order without reallocation).
    pub fn capacity(&self) -> usize {
        self.ld
    }

    /// Grow the capacity to at least `cap`, repacking once. No-op when
    /// the current capacity suffices (the steady-state case).
    pub fn ensure_capacity(&mut self, cap: usize) {
        if cap <= self.ld {
            return;
        }
        let new_ld = cap;
        let mut data = vec![0.0; new_ld * new_ld];
        for i in 0..self.n {
            data[i * new_ld..i * new_ld + i + 1]
                .copy_from_slice(&self.data[i * self.ld..i * self.ld + i + 1]);
        }
        self.data = data;
        self.ld = new_ld;
    }

    /// Materialize the current factor as a dense lower-triangular
    /// [`Mat`] (strict upper zeroed), reusing `out`'s allocation when
    /// the shape already matches.
    pub fn write_to(&self, out: &mut Mat) {
        if out.shape() != (self.n, self.n) {
            *out = Mat::zeros(self.n, self.n);
        }
        for i in 0..self.n {
            let row = out.row_mut(i);
            row[..i + 1].copy_from_slice(&self.data[i * self.ld..i * self.ld + i + 1]);
            row[i + 1..].fill(0.0);
        }
    }

    /// Delete row/column `r` of the underlying symmetric matrix:
    /// after this call the factor has order n−1 and satisfies
    /// `L'L'ᵀ = W` with row and column `r` removed. O((n−r)·n) for the
    /// row shift plus O((n−r)²) for the Givens sweep; cannot break down
    /// (the rotations are orthogonal).
    pub fn delete_row(&mut self, r: usize) {
        let (n, ld) = (self.n, self.ld);
        assert!(r < n, "delete_row: row {r} out of range (order {n})");
        // 1. Shift rows r+1..n up by one. Row i+1 of a lower-triangular
        //    factor has nonzeros through column i+1, so the shifted
        //    block is lower-Hessenberg: one stray super-diagonal entry
        //    per shifted row.
        for i in r..n - 1 {
            let (src0, dst0) = ((i + 1) * ld, i * ld);
            self.data.copy_within(src0..src0 + i + 2, dst0);
            // Keep the zero invariant above the Hessenberg band.
            self.data[dst0 + i + 2..dst0 + n].fill(0.0);
        }
        let n = n - 1;
        self.data[n * ld..n * ld + n + 1].fill(0.0);
        // 2. Right-applied Givens sweep: for each j, rotate columns
        //    (j, j+1) so the stray entry at (j, j+1) vanishes; the
        //    rotation touches only rows ≥ j (rows above are already
        //    triangular with zeros in both columns).
        for j in r..n {
            let a = self.data[j * ld + j];
            let b = self.data[j * ld + j + 1];
            if b == 0.0 {
                continue;
            }
            let rho = a.hypot(b);
            let (c, s) = (a / rho, b / rho);
            for i in j..n {
                let x = self.data[i * ld + j];
                let y = self.data[i * ld + j + 1];
                self.data[i * ld + j] = c * x + s * y;
                self.data[i * ld + j + 1] = c * y - s * x;
            }
            // Exact zero at the annihilated position (the arithmetic
            // above leaves rounding dust there).
            self.data[j * ld + j + 1] = 0.0;
            // The diagonal came out as ±ρ with ρ > 0; flip the column
            // sign if needed so the factor keeps a positive diagonal
            // (LLᵀ is invariant under column sign flips).
            if self.data[j * ld + j] < 0.0 {
                for i in j..n {
                    self.data[i * ld + j] = -self.data[i * ld + j];
                }
            }
        }
        self.n = n;
    }

    /// Append a row/column to the underlying symmetric matrix by
    /// bordering: `col` is the new off-diagonal column (length n, the
    /// inner products of the new sample against the current window) and
    /// `diag` its diagonal entry (‖new sample‖² + λ). O(n²).
    ///
    /// `rel_floor` rejects pivots that survive in exact arithmetic but
    /// are numerically meaningless: breakdown is declared when
    /// `δ² ≤ rel_floor·|diag|` (pass 0.0 for the exact-arithmetic
    /// criterion δ² ≤ 0). On breakdown the factor is left unchanged and
    /// the caller falls back to a full refactor of the patched Gram.
    pub fn append_row(
        &mut self,
        col: &[f64],
        diag: f64,
        rel_floor: f64,
    ) -> Result<(), CholeskyError> {
        let (n, ld) = (self.n, self.ld);
        assert_eq!(col.len(), n, "append_row: column must match the current order");
        assert!(n < ld, "append_row: capacity exhausted (ensure_capacity first)");
        // y = L⁻¹ col, written straight into the new row's slot.
        let (head, tail) = self.data.split_at_mut(n * ld);
        let y = &mut tail[..n + 1];
        let mut ynorm2 = 0.0;
        for i in 0..n {
            let li = &head[i * ld..i * ld + i];
            let mut acc = col[i];
            for (j, &lij) in li.iter().enumerate() {
                acc -= lij * y[j];
            }
            let yi = acc / head[i * ld + i];
            y[i] = yi;
            ynorm2 += yi * yi;
        }
        let delta2 = diag - ynorm2;
        if !delta2.is_finite() || delta2 <= rel_floor * diag.abs() {
            // Leave the factor untouched (the new row slot holds only
            // scratch below the current order).
            y.fill(0.0);
            return Err(CholeskyError { pivot: n, value: delta2 });
        }
        y[n] = delta2.sqrt();
        tail[n + 1..ld].fill(0.0);
        self.n = n + 1;
        Ok(())
    }
}

/// Rank-one update `W ← W + xxᵀ` applied to the factor in place via a
/// sweep of Givens rotations — O(n²), never breaks down (the updated
/// matrix is SPD whenever W was). `x` is consumed as workspace.
pub fn chol_update_rank1(l: &mut Mat, x: &mut [f64]) {
    let n = l.rows();
    assert_eq!(l.cols(), n, "factor must be square");
    assert_eq!(x.len(), n, "x must match the factor order");
    for k in 0..n {
        let lkk = l[(k, k)];
        let xk = x[k];
        let r = lkk.hypot(xk);
        let c = r / lkk;
        let s = xk / lkk;
        l[(k, k)] = r;
        for i in k + 1..n {
            let lik = (l[(i, k)] + s * x[i]) / c;
            l[(i, k)] = lik;
            x[i] = c * x[i] - s * lik;
        }
    }
}

/// Rank-one **hyperbolic downdate** `W ← W − xxᵀ`: the same sweep with
/// hyperbolic instead of circular rotations — O(n²), and it breaks down
/// (`L[k][k]² − x[k]² ≤ 0`) exactly when the downdated matrix stops
/// being positive definite. On breakdown the factor is left partially
/// rotated and must be discarded (callers refactor from the patched
/// Gram — the same rescue as a bordered-append breakdown). `x` is
/// consumed as workspace.
pub fn chol_downdate_rank1(l: &mut Mat, x: &mut [f64]) -> Result<(), CholeskyError> {
    let n = l.rows();
    assert_eq!(l.cols(), n, "factor must be square");
    assert_eq!(x.len(), n, "x must match the factor order");
    for k in 0..n {
        let lkk = l[(k, k)];
        let xk = x[k];
        let r2 = lkk * lkk - xk * xk;
        if r2 <= 0.0 || !r2.is_finite() {
            return Err(CholeskyError { pivot: k, value: r2 });
        }
        let r = r2.sqrt();
        let c = r / lkk;
        let s = xk / lkk;
        l[(k, k)] = r;
        for i in k + 1..n {
            let lik = (l[(i, k)] - s * x[i]) / c;
            l[(i, k)] = lik;
            x[i] = c * x[i] - s * lik;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::cholesky::cholesky;
    use crate::linalg::gemm::{gemm_nt, syrk};

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        let a = Mat::randn(n, n + 4, rng);
        syrk(&a, 1.0)
    }

    fn assert_factor_of(l: &UpdatableChol, w: &Mat, tol: f64, what: &str) {
        let n = l.order();
        assert_eq!(w.shape(), (n, n), "{what}: order mismatch");
        let mut lm = Mat::zeros(0, 0);
        l.write_to(&mut lm);
        let mut recon = Mat::zeros(n, n);
        gemm_nt(1.0, &lm, &lm, 0.0, &mut recon);
        let scale = w.max_abs().max(1.0);
        for i in 0..n {
            assert!(lm[(i, i)] > 0.0, "{what}: non-positive diagonal at {i}");
            for j in 0..n {
                assert!(
                    (recon[(i, j)] - w[(i, j)]).abs() < tol * scale,
                    "{what}: LLᵀ mismatch at ({i},{j}): {} vs {}",
                    recon[(i, j)],
                    w[(i, j)]
                );
            }
        }
    }

    /// W with row/col `r` removed.
    fn sym_delete(w: &Mat, r: usize) -> Mat {
        let n = w.rows();
        Mat::from_fn(n - 1, n - 1, |i, j| {
            let oi = if i < r { i } else { i + 1 };
            let oj = if j < r { j } else { j + 1 };
            w[(oi, oj)]
        })
    }

    #[test]
    fn delete_every_row_position_matches_fresh_factor() {
        let mut rng = Rng::seed_from(50);
        for &n in &[2usize, 5, 17, 40] {
            let w = spd(n, &mut rng);
            let l0 = cholesky(&w).unwrap();
            for r in 0..n {
                let mut u = UpdatableChol::from_factor(&l0, n);
                u.delete_row(r);
                assert_eq!(u.order(), n - 1);
                assert_factor_of(&u, &sym_delete(&w, r), 1e-11, &format!("delete r={r} n={n}"));
            }
        }
    }

    #[test]
    fn append_matches_fresh_factor() {
        let mut rng = Rng::seed_from(51);
        let n = 20;
        let w = spd(n + 1, &mut rng);
        // Factor the leading n×n block, then append the last row/col.
        let wl = Mat::from_fn(n, n, |i, j| w[(i, j)]);
        let l0 = cholesky(&wl).unwrap();
        let mut u = UpdatableChol::from_factor(&l0, n + 1);
        let col: Vec<f64> = (0..n).map(|i| w[(n, i)]).collect();
        u.append_row(&col, w[(n, n)], 0.0).unwrap();
        assert_eq!(u.order(), n + 1);
        assert_factor_of(&u, &w, 1e-11, "append");
    }

    #[test]
    fn rotation_roundtrip_delete_then_append() {
        // Delete a middle row, append a new one: the net rotation that
        // the sliding-window session performs, checked against a cold
        // factor of the rotated matrix.
        let mut rng = Rng::seed_from(52);
        let n = 30;
        let s = Mat::randn(n + 1, n + 40, &mut rng);
        let window = s.slice_rows(0, n);
        let w = syrk(&window, 0.5);
        let mut u = UpdatableChol::from_factor(&cholesky(&w).unwrap(), n);
        let r = 11;
        u.delete_row(r);
        // Rotated window: rows of `window` minus r, plus the last row of s.
        let kept: Vec<usize> = (0..n).filter(|&i| i != r).collect();
        let mut rotated = Mat::zeros(n, n + 40);
        for (i, &oi) in kept.iter().enumerate() {
            rotated.row_mut(i).copy_from_slice(window.row(oi));
        }
        rotated.row_mut(n - 1).copy_from_slice(s.row(n));
        let col: Vec<f64> = (0..n - 1)
            .map(|i| crate::linalg::mat::dot(rotated.row(i), rotated.row(n - 1)))
            .collect();
        let d = crate::linalg::mat::dot(rotated.row(n - 1), rotated.row(n - 1)) + 0.5;
        u.append_row(&col, d, 0.0).unwrap();
        assert_factor_of(&u, &syrk(&rotated, 0.5), 1e-10, "rotation");
    }

    #[test]
    fn append_breakdown_leaves_factor_usable() {
        // A column incompatible with positive-definiteness: y = L⁻¹c has
        // ‖y‖² > d, so the bordered pivot is negative.
        let l0 = Mat::eye(2);
        let mut u = UpdatableChol::from_factor(&l0, 3);
        let err = u.append_row(&[10.0, 0.0], 1.0, 0.0).unwrap_err();
        assert_eq!(err.pivot, 2);
        assert!(err.value <= 0.0);
        // The factor is untouched and still accepts a good append.
        assert_eq!(u.order(), 2);
        u.append_row(&[0.5, 0.5], 4.0, 0.0).unwrap();
        assert_eq!(u.order(), 3);
    }

    #[test]
    fn append_relative_floor_rejects_tiny_pivots() {
        let l0 = Mat::eye(2);
        let mut u = UpdatableChol::from_factor(&l0, 3);
        // δ² = 4 − (1+1) = 2, ratio δ²/d = 0.5: fine at floor 0.1,
        // breakdown at floor 0.6.
        assert!(u.append_row(&[1.0, 1.0], 4.0, 0.6).is_err());
        assert_eq!(u.order(), 2);
        u.append_row(&[1.0, 1.0], 4.0, 0.1).unwrap();
        assert_eq!(u.order(), 3);
    }

    #[test]
    fn capacity_growth_repacks_and_steady_state_rotation_is_allocation_free() {
        let mut rng = Rng::seed_from(53);
        let n = 12;
        let w = spd(n, &mut rng);
        let mut u = UpdatableChol::from_factor(&cholesky(&w).unwrap(), n);
        assert_eq!(u.capacity(), n);
        u.ensure_capacity(n + 3);
        assert_eq!(u.capacity(), n + 3);
        assert_factor_of(&u, &w, 1e-12, "repack");
        // A steady-state rotation (delete + append at constant order)
        // needs no further capacity.
        u.ensure_capacity(n + 3);
        assert_eq!(u.capacity(), n + 3);
    }

    #[test]
    fn rank1_update_then_downdate_roundtrips() {
        let mut rng = Rng::seed_from(54);
        let n = 16;
        let w = spd(n, &mut rng);
        let l0 = cholesky(&w).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // Update W + xxᵀ matches a fresh factor…
        let mut l = l0.clone();
        let mut xbuf = x.clone();
        chol_update_rank1(&mut l, &mut xbuf);
        let mut wx = w.clone();
        for i in 0..n {
            for j in 0..n {
                wx[(i, j)] += x[i] * x[j];
            }
        }
        let fresh = cholesky(&wx).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!((l[(i, j)] - fresh[(i, j)]).abs() < 1e-9, "update ({i},{j})");
            }
        }
        // …and the hyperbolic downdate undoes it.
        let mut xbuf = x.clone();
        chol_downdate_rank1(&mut l, &mut xbuf).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!((l[(i, j)] - l0[(i, j)]).abs() < 1e-8, "downdate ({i},{j})");
            }
        }
    }

    #[test]
    fn hyperbolic_downdate_breaks_down_when_not_pd() {
        // W − xxᵀ with x too large is indefinite: the hyperbolic sweep
        // must report the non-positive pivot instead of emitting NaNs.
        let mut l = Mat::eye(3);
        let mut x = vec![2.0, 0.0, 0.0];
        let err = chol_downdate_rank1(&mut l, &mut x).unwrap_err();
        assert_eq!(err.pivot, 0);
        assert!(err.value <= 0.0);
        assert!(err.to_string().contains("damping"));
    }
}
