//! Householder QR — independent test oracle.
//!
//! Not on any hot path: the solvers are validated against QR-based
//! least-squares / normal-equation solutions computed by a *different*
//! algorithm family than Cholesky or Jacobi, which protects the test
//! suite against a shared-bug false pass.

use super::mat::{dot, Mat};

/// Reduced QR of `a: p×q` with `p ≥ q`: returns `(Q: p×q, R: q×q)` with
/// `a = Q·R`, Q having orthonormal columns and R upper triangular.
pub fn qr(a: &Mat) -> (Mat, Mat) {
    let (p, q) = a.shape();
    assert!(p >= q, "qr expects p ≥ q (got {p}×{q})");
    let mut r = a.clone(); // will be reduced in place
    // Store Householder vectors (unit-normalized, v[0..k] = 0 implicit).
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(q);

    for k in 0..q {
        // Build the Householder vector for column k below row k.
        let mut v: Vec<f64> = (k..p).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * dot(&v, &v).sqrt();
        if alpha.abs() < 1e-300 {
            // Zero column — identity reflector.
            vs.push(vec![0.0; p - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm = dot(&v, &v).sqrt();
        if vnorm > 0.0 {
            for x in &mut v {
                *x /= vnorm;
            }
        }
        // Apply (I − 2vvᵀ) to the trailing block of R.
        for j in k..q {
            let mut s = 0.0;
            for i in k..p {
                s += v[i - k] * r[(i, j)];
            }
            let s2 = 2.0 * s;
            for i in k..p {
                r[(i, j)] -= s2 * v[i - k];
            }
        }
        vs.push(v);
    }

    // Extract the q×q upper triangle as R.
    let rq = Mat::from_fn(q, q, |i, j| if j >= i { r[(i, j)] } else { 0.0 });

    // Form Q by applying the reflectors to the first q columns of I,
    // in reverse order.
    let mut qm = Mat::zeros(p, q);
    for j in 0..q {
        qm[(j, j)] = 1.0;
    }
    for k in (0..q).rev() {
        let v = &vs[k];
        if v.iter().all(|&x| x == 0.0) {
            continue;
        }
        for j in 0..q {
            let mut s = 0.0;
            for i in k..p {
                s += v[i - k] * qm[(i, j)];
            }
            let s2 = 2.0 * s;
            for i in k..p {
                qm[(i, j)] -= s2 * v[i - k];
            }
        }
    }
    (qm, rq)
}

/// Least-squares oracle: minimize ‖Aᵀx − b‖² + λ‖x‖² for tall-skinny
/// problems via QR of the *augmented* matrix — used only in tests to
/// cross-check the damped solvers. Solves `(AAᵀ+λI)x = A b_aug` style
/// systems by QR on `[Aᵀ; √λ·I]`.
pub fn ridge_qr_oracle(st: &Mat, v: &[f64], lambda: f64) -> Vec<f64> {
    // Solve (SᵀS + λI) x = v exactly, by QR of the (n+m)×m stacked matrix
    // K = [S; √λ·I]: KᵀK = SᵀS + λI, so x = R⁻¹R⁻ᵀ v with K = QR.
    let (n, m) = st.shape();
    assert_eq!(v.len(), m);
    let sq = lambda.sqrt();
    let mut k = Mat::zeros(n + m, m);
    for i in 0..n {
        k.row_mut(i).copy_from_slice(st.row(i));
    }
    for j in 0..m {
        k[(n + j, j)] = sq;
    }
    let (_q, r) = qr(&k);
    // Solve Rᵀ y = v (forward), then R x = y (backward).
    let mut y = v.to_vec();
    for i in 0..m {
        let mut s = y[i];
        for j in 0..i {
            s -= r[(j, i)] * y[j];
        }
        y[i] = s / r[(i, i)];
    }
    let mut x = y;
    for i in (0..m).rev() {
        let mut s = x[i];
        for j in i + 1..m {
            s -= r[(i, j)] * x[j];
        }
        x[i] = s / r[(i, i)];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::gemm::gemm;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::seed_from(60);
        for &(p, q) in &[(1, 1), (5, 3), (10, 10), (50, 12)] {
            let a = Mat::randn(p, q, &mut rng);
            let (qm, r) = qr(&a);
            let mut recon = Mat::zeros(p, q);
            gemm(1.0, &qm, &r, 0.0, &mut recon);
            for i in 0..p {
                for j in 0..q {
                    assert!((recon[(i, j)] - a[(i, j)]).abs() < 1e-10, "({p},{q})");
                }
            }
            // Q orthonormal columns.
            let qt = qm.transpose();
            let mut qtq = Mat::zeros(q, q);
            gemm(1.0, &qt, &qm, 0.0, &mut qtq);
            for i in 0..q {
                for j in 0..q {
                    let e = if i == j { 1.0 } else { 0.0 };
                    assert!((qtq[(i, j)] - e).abs() < 1e-10);
                }
            }
            // R upper triangular.
            for i in 0..q {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn ridge_oracle_satisfies_normal_equations() {
        let mut rng = Rng::seed_from(61);
        let (n, m) = (6, 25);
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let lambda = 0.37;
        let x = ridge_qr_oracle(&s, &v, lambda);
        // residual = SᵀS x + λx − v
        let sx = s.matvec(&x);
        let mut resid = s.t_matvec(&sx);
        for j in 0..m {
            resid[j] += lambda * x[j] - v[j];
        }
        for r in resid {
            assert!(r.abs() < 1e-9);
        }
    }
}
