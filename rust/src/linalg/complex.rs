//! Complex scalar and matrix support for the stochastic-reconfiguration
//! variants (§3 of the paper).
//!
//! When the wavefunction is complex, `S` is complex and every transpose in
//! Algorithm 1 becomes a Hermitian conjugate: `W = SS† + λĨ` is Hermitian
//! positive definite, `W = LL†` is the complex Cholesky factorization, and
//! the solves run in ℂ. This module provides exactly those primitives:
//! [`c64`], [`CMat`], [`CMat::herk`], [`cholesky_complex`], and the
//! forward/adjoint substitutions.

use crate::data::rng::Rng;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub, SubAssign};

/// Complex double — built from scratch (no external num crate).
/// Named `c64` to match the NumPy/JAX dtype family it mirrors.
#[allow(non_camel_case_types)]
#[derive(Clone, Copy, PartialEq, Default)]
pub struct c64 {
    pub re: f64,
    pub im: f64,
}

#[allow(non_upper_case_globals)]
impl c64 {
    pub const ZERO: c64 = c64 { re: 0.0, im: 0.0 };
    pub const ONE: c64 = c64 { re: 1.0, im: 0.0 };
    pub const I: c64 = c64 { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        c64 { re, im }
    }

    #[inline]
    pub fn from_re(re: f64) -> Self {
        c64 { re, im: 0.0 }
    }

    /// Complex conjugate.
    #[inline]
    pub fn conj(self) -> Self {
        c64 { re: self.re, im: -self.im }
    }

    /// Squared modulus |z|².
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Modulus |z|.
    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Principal square root.
    pub fn sqrt(self) -> Self {
        let r = self.abs();
        let re = ((r + self.re) / 2.0).max(0.0).sqrt();
        let im_mag = ((r - self.re) / 2.0).max(0.0).sqrt();
        c64 { re, im: if self.im >= 0.0 { im_mag } else { -im_mag } }
    }

    /// Complex exponential.
    pub fn exp(self) -> Self {
        let e = self.re.exp();
        c64 { re: e * self.im.cos(), im: e * self.im.sin() }
    }

    /// Principal natural logarithm.
    pub fn ln(self) -> Self {
        c64 { re: self.abs().ln(), im: self.im.atan2(self.re) }
    }

    /// Complex hyperbolic cosine (needed by the RBM log-wavefunction).
    pub fn cosh(self) -> Self {
        // cosh(a+bi) = cosh a · cos b + i sinh a · sin b
        c64 {
            re: self.re.cosh() * self.im.cos(),
            im: self.re.sinh() * self.im.sin(),
        }
    }

    /// Complex hyperbolic tangent (derivative of ln cosh).
    pub fn tanh(self) -> Self {
        // tanh(a+bi) = (tanh a + i tan b) / (1 + i tanh a · tan b),
        // guarded for large |a| where tanh a → ±1.
        let ta = self.re.tanh();
        if self.re.abs() > 20.0 {
            // cos/sin(b) terms vanish relative to e^{2|a|}.
            return c64 { re: ta, im: 0.0 };
        }
        let tb = self.im.tan();
        let denom = c64::new(1.0, ta * tb);
        c64::new(ta, tb) / denom
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.re.is_finite() && self.im.is_finite()
    }
}

impl Add for c64 {
    type Output = c64;
    #[inline]
    fn add(self, o: c64) -> c64 {
        c64::new(self.re + o.re, self.im + o.im)
    }
}
impl Sub for c64 {
    type Output = c64;
    #[inline]
    fn sub(self, o: c64) -> c64 {
        c64::new(self.re - o.re, self.im - o.im)
    }
}
impl Mul for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: c64) -> c64 {
        c64::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
}
impl Mul<f64> for c64 {
    type Output = c64;
    #[inline]
    fn mul(self, o: f64) -> c64 {
        c64::new(self.re * o, self.im * o)
    }
}
impl Div for c64 {
    type Output = c64;
    #[inline]
    fn div(self, o: c64) -> c64 {
        let d = o.norm_sqr();
        c64::new(
            (self.re * o.re + self.im * o.im) / d,
            (self.im * o.re - self.re * o.im) / d,
        )
    }
}
impl Div<f64> for c64 {
    type Output = c64;
    #[inline]
    fn div(self, o: f64) -> c64 {
        c64::new(self.re / o, self.im / o)
    }
}
impl Neg for c64 {
    type Output = c64;
    #[inline]
    fn neg(self) -> c64 {
        c64::new(-self.re, -self.im)
    }
}
impl AddAssign for c64 {
    #[inline]
    fn add_assign(&mut self, o: c64) {
        self.re += o.re;
        self.im += o.im;
    }
}
impl SubAssign for c64 {
    #[inline]
    fn sub_assign(&mut self, o: c64) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl fmt::Debug for c64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.im >= 0.0 {
            write!(f, "{:.6}+{:.6}i", self.re, self.im)
        } else {
            write!(f, "{:.6}-{:.6}i", self.re, -self.im)
        }
    }
}

/// Conjugated dot product `Σ conj(a_i)·b_i`.
#[inline]
pub fn cdot(a: &[c64], b: &[c64]) -> c64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = c64::ZERO;
    for (x, y) in a.iter().zip(b) {
        s += x.conj() * *y;
    }
    s
}

/// Plain (unconjugated) dot product.
#[inline]
pub fn udot(a: &[c64], b: &[c64]) -> c64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = c64::ZERO;
    for (x, y) in a.iter().zip(b) {
        s += *x * *y;
    }
    s
}

/// Row-major dense complex matrix.
#[derive(Clone, PartialEq)]
pub struct CMat {
    data: Vec<c64>,
    rows: usize,
    cols: usize,
}

impl CMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        CMat { data: vec![c64::ZERO; rows * cols], rows, cols }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = CMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = c64::ONE;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> c64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        CMat { data, rows, cols }
    }

    /// Complex standard normal (independent re/im ~ N(0, 1)).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        CMat::from_fn(rows, cols, |_, _| c64::new(rng.normal(), rng.normal()))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[c64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [c64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Real part as a [`super::Mat`].
    pub fn real(&self) -> super::Mat {
        super::Mat::from_fn(self.rows, self.cols, |i, j| self[(i, j)].re)
    }

    /// Imaginary part as a [`super::Mat`].
    pub fn imag(&self) -> super::Mat {
        super::Mat::from_fn(self.rows, self.cols, |i, j| self[(i, j)].im)
    }

    /// Conjugate transpose (copies; tests/oracles only).
    pub fn dagger(&self) -> CMat {
        CMat::from_fn(self.cols, self.rows, |i, j| self[(j, i)].conj())
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[c64]) -> Vec<c64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| udot(self.row(i), x)).collect()
    }

    /// `y = A† x` without materializing `A†`.
    pub fn dagger_matvec(&self, x: &[c64]) -> Vec<c64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![c64::ZERO; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            let row = self.row(i);
            for j in 0..self.cols {
                y[j] += row[j].conj() * xi;
            }
        }
        y
    }

    /// Hermitian rank-k update `W = A·A† + λI` — line 1 of Algorithm 1 in
    /// the complex SR variant. W is Hermitian positive definite for λ>0.
    pub fn herk(&self, lambda: f64) -> CMat {
        let n = self.rows;
        let mut w = CMat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // W[i][j] = Σ_k A[i][k]·conj(A[j][k]) = cdot(row_j, row_i)…
                let v = cdot(self.row(j), self.row(i));
                w[(i, j)] = v;
                if i != j {
                    w[(j, i)] = v.conj();
                }
            }
        }
        for i in 0..n {
            w[(i, i)] += c64::from_re(lambda);
        }
        w
    }

    /// Add `lambda` to the (real part of the) diagonal — re-damping a
    /// cached un-damped Hermitian Gram in the complex SR session.
    pub fn add_diag(&mut self, lambda: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += c64::from_re(lambda);
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, z| a.max(z.abs()))
    }
}

impl std::ops::Index<(usize, usize)> for CMat {
    type Output = c64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &c64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for CMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut c64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for CMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "CMat {}x{}", self.rows, self.cols)
    }
}

/// Complex (Hermitian) Cholesky: `W = L·L†` with `L` lower triangular and
/// real positive diagonal. Errors mirror the real case.
pub fn cholesky_complex(w: &CMat) -> Result<CMat, super::CholeskyError> {
    let n = w.rows();
    assert_eq!(w.cols(), n);
    let mut l = w.clone();
    for j in 0..n {
        let mut d = l[(j, j)].re;
        for p in 0..j {
            d -= l[(j, p)].norm_sqr();
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(super::CholeskyError { pivot: j, value: d });
        }
        let djj = d.sqrt();
        l[(j, j)] = c64::from_re(djj);
        for i in j + 1..n {
            let mut s = l[(i, j)];
            for p in 0..j {
                s -= l[(i, p)] * l[(j, p)].conj();
            }
            l[(i, j)] = s / djj;
        }
    }
    for i in 0..n {
        for j in i + 1..n {
            l[(i, j)] = c64::ZERO;
        }
    }
    Ok(l)
}

/// Solve `L y = b` (forward substitution, complex).
pub fn solve_lower_c(l: &CMat, b: &[c64]) -> Vec<c64> {
    let n = l.rows();
    let mut y = b.to_vec();
    for i in 0..n {
        let mut s = y[i];
        let row = l.row(i);
        for j in 0..i {
            s -= row[j] * y[j];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve `L† z = y` (adjoint backward substitution, complex).
pub fn solve_lower_dagger_c(l: &CMat, y: &[c64]) -> Vec<c64> {
    let n = l.rows();
    let mut z = y.to_vec();
    for i in (0..n).rev() {
        let zi = z[i] / l.row(i)[i].conj();
        z[i] = zi;
        let row = l.row(i);
        for j in 0..i {
            z[j] -= row[j].conj() * zi;
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c64_field_axioms_spot_checks() {
        let a = c64::new(1.0, 2.0);
        let b = c64::new(-3.0, 0.5);
        assert_eq!(a + b, c64::new(-2.0, 2.5));
        assert_eq!(a * c64::ONE, a);
        let q = a / b;
        let back = q * b;
        assert!((back - a).abs() < 1e-14);
        assert_eq!(a.conj().conj(), a);
        assert!((a * a.conj()).im.abs() < 1e-15);
        assert!(((a * a.conj()).re - a.norm_sqr()).abs() < 1e-15);
    }

    #[test]
    fn c64_transcendentals() {
        // exp(iπ) = −1
        let e = (c64::I * std::f64::consts::PI).exp();
        assert!((e - c64::new(-1.0, 0.0)).abs() < 1e-14);
        // ln(exp(z)) = z for principal branch inputs
        let z = c64::new(0.3, -0.7);
        assert!((z.exp().ln() - z).abs() < 1e-14);
        // cosh matches the defining series via exp
        let ch = z.cosh();
        let via_exp = (z.exp() + (-z).exp()) / 2.0;
        assert!((ch - via_exp).abs() < 1e-14);
        // tanh = sinh/cosh via exp
        let sh = (z.exp() - (-z).exp()) / 2.0;
        assert!((z.tanh() - sh / ch).abs() < 1e-12);
        // tanh saturates without NaN for large real part
        let big = c64::new(400.0, 1.3).tanh();
        assert!(big.is_finite());
        assert!((big.re - 1.0).abs() < 1e-12);
        // sqrt(z)² = z
        let r = c64::new(-2.0, 0.8).sqrt();
        assert!((r * r - c64::new(-2.0, 0.8)).abs() < 1e-14);
    }

    #[test]
    fn herk_is_hermitian_pd() {
        let mut rng = Rng::seed_from(70);
        let a = CMat::randn(8, 30, &mut rng);
        let w = a.herk(0.2);
        for i in 0..8 {
            for j in 0..8 {
                let wij = w[(i, j)];
                let wji = w[(j, i)];
                assert!((wij - wji.conj()).abs() < 1e-12);
            }
            assert!(w[(i, i)].re > 0.0);
            assert!(w[(i, i)].im.abs() < 1e-12);
        }
        // Matches the naive A·A† + λI.
        let ad = a.dagger();
        for i in 0..8 {
            for j in 0..8 {
                let mut s = c64::ZERO;
                for k in 0..30 {
                    s += a[(i, k)] * ad[(k, j)];
                }
                if i == j {
                    s += c64::from_re(0.2);
                }
                assert!((w[(i, j)] - s).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn complex_cholesky_reconstructs() {
        let mut rng = Rng::seed_from(71);
        for &n in &[1, 2, 5, 20] {
            let a = CMat::randn(n, n + 4, &mut rng);
            let w = a.herk(0.5);
            let l = cholesky_complex(&w).unwrap();
            // L·L† == W
            for i in 0..n {
                for j in 0..n {
                    let mut s = c64::ZERO;
                    for k in 0..n {
                        s += l[(i, k)] * l[(j, k)].conj();
                    }
                    assert!((s - w[(i, j)]).abs() < 1e-9, "n={n} ({i},{j})");
                }
            }
            // Diagonal real positive, upper zero.
            for i in 0..n {
                assert!(l[(i, i)].im == 0.0 && l[(i, i)].re > 0.0);
                for j in i + 1..n {
                    assert_eq!(l[(i, j)], c64::ZERO);
                }
            }
        }
    }

    #[test]
    fn complex_solves_roundtrip() {
        let mut rng = Rng::seed_from(72);
        let n = 12;
        let a = CMat::randn(n, n + 4, &mut rng);
        let w = a.herk(1.0);
        let l = cholesky_complex(&w).unwrap();
        let x_true: Vec<c64> = (0..n).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        // b = W x = L (L† x)
        let b = w.matvec(&x_true);
        let x = solve_lower_dagger_c(&l, &solve_lower_c(&l, &b));
        for (u, v) in x.iter().zip(&x_true) {
            assert!((*u - *v).abs() < 1e-9);
        }
    }

    #[test]
    fn dagger_matvec_matches_explicit() {
        let mut rng = Rng::seed_from(73);
        let a = CMat::randn(5, 9, &mut rng);
        let x: Vec<c64> = (0..5).map(|_| c64::new(rng.normal(), rng.normal())).collect();
        let fast = a.dagger_matvec(&x);
        let slow = a.dagger().matvec(&x);
        for (u, v) in fast.iter().zip(&slow) {
            assert!((*u - *v).abs() < 1e-12);
        }
    }

    #[test]
    fn real_imag_split() {
        let z = CMat::from_fn(2, 2, |i, j| c64::new((i + j) as f64, (i * j) as f64 + 0.5));
        assert_eq!(z.real()[(1, 1)], 2.0);
        assert_eq!(z.imag()[(1, 1)], 1.5);
    }
}
