//! Runtime-dispatched SIMD tier under the packed kernel engine (PR 4).
//!
//! The packed micro-kernel of [`kernel`](super::kernel) used to be plain
//! scalar Rust that only vectorized if LLVM felt like it at the default
//! `target-cpu`. This module makes the instruction set explicit: one
//! [`KernelIsa`] tier is selected per process (CPUID detection, or the
//! `DNGD_KERNEL` env override) and every FLOP of the dense pipeline —
//! the GEMM/SYRK micro-kernels, the [`dot`](super::mat::dot)/
//! [`axpy`](super::mat::axpy) primitives under the CG solver, the
//! Cholesky diagonal factor and the blocked-TRSM inner cores — runs on
//! that tier's `std::arch` kernel:
//!
//! | tier | f64 micro-tile | f32 micro-tile (PR 6) | dot/axpy width | requires |
//! |------|---------------|-----------------------|----------------|----------|
//! | `scalar` | 4×8 (LLVM autovec) | 8×8 oracle | 16-way unrolled | nothing — guaranteed fallback |
//! | `avx2`   | 4×8, 8 ymm accumulators, FMA | 8×8, 8 ymm | 4×4 f64 lanes | x86-64 AVX2+FMA |
//! | `avx512` | 8×8, 8 zmm accumulators, FMA (4×8 edge tiles) | 16×8, 8 zmm (8×8 edges) | 4×8 f64 lanes | x86-64 AVX-512F (+AVX2/FMA) |
//! | `neon`   | 4×8, 16 q-register accumulators, FMA | 8×8, 16 q-registers | 8×2 f64 lanes | aarch64 (always on) |
//!
//! ## Determinism contract (amended in PR 4)
//!
//! *Within a fixed tier*, every threaded kernel remains **bit-identical
//! to serial at every thread count**: each tier's accumulation order is
//! a pure function of the tier and the operand shapes, never of the
//! thread partitioning — threaded dispatchers capture the caller's
//! active tier and re-establish it inside every pool job
//! ([`with_isa`]), so a scoped override cannot desynchronize caller and
//! workers. *Across tiers* results are only tolerance-equal (FMA
//! contracts the multiply-add into one rounding; the scalar tier keeps
//! the seed's two-rounding arithmetic), with
//! [`gemm::reference`](super::gemm::reference) remaining the oracle.
//!
//! ## Selection
//!
//! The process default is the best supported tier
//! ([`KernelIsa::detect`]), overridable with
//! `DNGD_KERNEL=scalar|avx2|avx512|neon` (unknown or unsupported values
//! are hard errors — a forced tier that silently fell back would
//! invalidate the CI scalar job). [`with_isa`] scopes a tier to a
//! closure on the current thread (tests sweep every supported tier in
//! one process); `solver.isa` reaches the chol/rvb sessions through
//! [`KernelConfig::isa`](super::kernel::KernelConfig).

use super::kernel::{MR, MR32, NR, NR32};
use std::cell::Cell;
use std::sync::OnceLock;

/// Instruction-set tier for the dense kernels. See the module docs for
/// the per-tier micro-kernel shapes and the determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable Rust loops (the seed arithmetic) — always available.
    Scalar,
    /// x86-64 AVX2 + FMA: 256-bit lanes, 4×8 micro-tile.
    Avx2,
    /// x86-64 AVX-512F: 512-bit lanes, 8×8 micro-tile (4×8 on edges).
    Avx512,
    /// aarch64 NEON: 128-bit lanes, 4×8 micro-tile.
    Neon,
}

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(target_arch = "x86_64")]
fn have_avx512() -> bool {
    have_avx2() && std::arch::is_x86_feature_detected!("avx512f")
}

impl KernelIsa {
    pub fn as_str(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Avx512 => "avx512",
            KernelIsa::Neon => "neon",
        }
    }

    /// Parse the `DNGD_KERNEL` / `solver.isa` spelling.
    pub fn parse(s: &str) -> Option<KernelIsa> {
        Some(match s {
            "scalar" => KernelIsa::Scalar,
            "avx2" => KernelIsa::Avx2,
            "avx512" => KernelIsa::Avx512,
            "neon" => KernelIsa::Neon,
            _ => return None,
        })
    }

    /// Whether this host can execute the tier's kernels.
    pub fn supported(self) -> bool {
        match self {
            KernelIsa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx2 => have_avx2(),
            #[cfg(target_arch = "x86_64")]
            KernelIsa::Avx512 => have_avx512(),
            #[cfg(target_arch = "aarch64")]
            KernelIsa::Neon => true,
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// Every tier this host supports, worst to best. Always starts with
    /// [`KernelIsa::Scalar`]; [`KernelIsa::detect`] is the last entry.
    pub fn supported_tiers() -> Vec<KernelIsa> {
        [KernelIsa::Scalar, KernelIsa::Neon, KernelIsa::Avx2, KernelIsa::Avx512]
            .into_iter()
            .filter(|isa| isa.supported())
            .collect()
    }

    /// The best tier this host supports (CPUID / target detection).
    pub fn detect() -> KernelIsa {
        *KernelIsa::supported_tiers().last().expect("scalar tier is always supported")
    }
}

impl std::fmt::Display for KernelIsa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The process-wide default tier: `DNGD_KERNEL` if set (hard error on
/// unknown or unsupported values — no silent fallback), else
/// [`KernelIsa::detect`]. Resolved once and cached.
pub fn process_default_isa() -> KernelIsa {
    static DEFAULT: OnceLock<KernelIsa> = OnceLock::new();
    *DEFAULT.get_or_init(|| match std::env::var("DNGD_KERNEL") {
        Err(_) => KernelIsa::detect(),
        Ok(spec) => {
            let isa = KernelIsa::parse(&spec).unwrap_or_else(|| {
                panic!("DNGD_KERNEL={spec:?} unknown (expected scalar|avx2|avx512|neon)")
            });
            assert!(
                isa.supported(),
                "DNGD_KERNEL={spec} requests a tier this CPU does not support (supported: {})",
                KernelIsa::supported_tiers()
                    .iter()
                    .map(|i| i.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            isa
        }
    })
}

thread_local! {
    /// Scoped per-thread override, set by [`with_isa`]. Threaded kernel
    /// dispatchers capture [`active_isa`] at entry and re-establish it
    /// inside each pool job so the whole call runs one tier.
    static ISA_OVERRIDE: Cell<Option<KernelIsa>> = const { Cell::new(None) };
}

/// The tier the calling thread's kernels dispatch on: the innermost
/// [`with_isa`] override, else the process default.
pub fn active_isa() -> KernelIsa {
    ISA_OVERRIDE.with(|c| c.get()).unwrap_or_else(process_default_isa)
}

/// Run `f` with `isa` as the calling thread's active tier, restoring
/// the previous tier afterwards (panic-safe). The override is
/// thread-local; the threaded kernels propagate it into their pool jobs
/// themselves, so a `with_isa` scope still produces within-tier
/// bit-identical results at every thread count.
///
/// Panics if this host cannot execute `isa` — the gate that keeps a
/// hand-built [`KernelConfig::isa`](super::kernel::KernelConfig)
/// override (which bypasses the validated `DNGD_KERNEL` / `solver.isa`
/// parsers) from reaching `#[target_feature]` kernels the CPU lacks
/// (undefined behavior). The check is a cached feature lookup — noise
/// against any kernel call.
pub fn with_isa<R>(isa: KernelIsa, f: impl FnOnce() -> R) -> R {
    assert!(isa.supported(), "with_isa({isa}): tier not supported by this CPU");
    struct Restore(Option<KernelIsa>);
    impl Drop for Restore {
        fn drop(&mut self) {
            ISA_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(ISA_OVERRIDE.with(|c| c.replace(Some(isa))));
    f()
}

/// [`with_isa`] when the override is optional (`KernelConfig.isa` /
/// `solver.isa` plumbing): `None` runs `f` on the ambient tier.
pub fn with_isa_opt<R>(isa: Option<KernelIsa>, f: impl FnOnce() -> R) -> R {
    match isa {
        Some(isa) => with_isa(isa, f),
        None => f(),
    }
}

// ---------------------------------------------------------------------------
// 4×8 micro-kernels
// ---------------------------------------------------------------------------

/// The scalar MR×NR micro-kernel — the seed arithmetic (separate
/// multiply and add roundings), kept verbatim as the guaranteed
/// fallback. Constant-sized inner loops; LLVM may autovectorize but the
/// summation order per C element is fixed: `p` strictly increasing.
fn mk4x8_scalar(ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    let mut acc = [[0.0f64; NR]; MR];
    for (a, b) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        let a: &[f64; MR] = a.try_into().unwrap();
        let b: &[f64; NR] = b.try_into().unwrap();
        for r in 0..MR {
            let ar = a[r];
            for j in 0..NR {
                acc[r][j] += ar * b[j];
            }
        }
    }
    acc
}

/// AVX2+FMA 4×8: 8 ymm accumulators (4 rows × 2 lanes-of-4), 2 B loads
/// and 4 broadcasts per k-step. Per C element the sum is a single FMA
/// chain with `p` strictly increasing — same order as scalar, one
/// rounding per step instead of two.
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk4x8_avx2(ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    use core::arch::x86_64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap.len(), kc * MR);
    let mut acc = [[_mm256_setzero_pd(); 2]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_pd(b);
        let b1 = _mm256_loadu_pd(b.add(4));
        for r in 0..MR {
            let ar = _mm256_set1_pd(*a.add(r));
            acc[r][0] = _mm256_fmadd_pd(ar, b0, acc[r][0]);
            acc[r][1] = _mm256_fmadd_pd(ar, b1, acc[r][1]);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    let mut out = [[0.0f64; NR]; MR];
    for r in 0..MR {
        _mm256_storeu_pd(out[r].as_mut_ptr(), acc[r][0]);
        _mm256_storeu_pd(out[r].as_mut_ptr().add(4), acc[r][1]);
    }
    out
}

/// NEON 4×8: 16 q-register accumulators (4 rows × 4 lanes-of-2), FMA
/// via `vfmaq_f64`. Same per-element `p`-increasing FMA chain as the
/// x86 tiers.
///
/// # Safety
/// Caller must be on aarch64 with NEON (baseline for the arch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk4x8_neon(ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    use core::arch::aarch64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap.len(), kc * MR);
    let mut acc = [[vdupq_n_f64(0.0); 4]; MR];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = vld1q_f64(b);
        let b1 = vld1q_f64(b.add(2));
        let b2 = vld1q_f64(b.add(4));
        let b3 = vld1q_f64(b.add(6));
        for r in 0..MR {
            let ar = vdupq_n_f64(*a.add(r));
            acc[r][0] = vfmaq_f64(acc[r][0], ar, b0);
            acc[r][1] = vfmaq_f64(acc[r][1], ar, b1);
            acc[r][2] = vfmaq_f64(acc[r][2], ar, b2);
            acc[r][3] = vfmaq_f64(acc[r][3], ar, b3);
        }
        a = a.add(MR);
        b = b.add(NR);
    }
    let mut out = [[0.0f64; NR]; MR];
    for r in 0..MR {
        for l in 0..4 {
            vst1q_f64(out[r].as_mut_ptr().add(2 * l), acc[r][l]);
        }
    }
    out
}

/// Dispatch the 4×8 micro-kernel for `isa`. The AVX-512 tier uses the
/// AVX2 4×8 kernel here (AVX-512F detection implies AVX2+FMA) — its
/// native 8×8 tile lives in [`microkernel_8x8`] and is only engaged by
/// the GEMM macro-kernel when two adjacent row panels are available.
#[inline]
pub(crate) fn microkernel_4x8(isa: KernelIsa, ap: &[f64], bp: &[f64]) -> [[f64; NR]; MR] {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: tier selection guarantees AVX2+FMA on this host.
        return unsafe { mk4x8_avx2(ap, bp) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { mk4x8_neon(ap, bp) };
    }
    let _ = isa;
    mk4x8_scalar(ap, bp)
}

// ---------------------------------------------------------------------------
// 8×8 micro-kernel (AVX-512)
// ---------------------------------------------------------------------------

/// AVX-512F 8×8 over two adjacent MR-panels: 8 zmm accumulators (one
/// full C row each), 1 B load and 8 broadcasts per k-step — eight
/// independent FMA chains hide the FMA latency without touching the
/// MR=4 packed layout. Per C element the arithmetic is the *same*
/// `p`-increasing FMA chain as the 4×8 FMA kernels, so pairing panels
/// never changes a value (and therefore cannot break the threaded
/// band-partition bit-identity).
///
/// # Safety
/// Caller must ensure AVX-512F is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn mk8x8_avx512(ap0: &[f64], ap1: &[f64], bp: &[f64]) -> [[f64; NR]; 2 * MR] {
    use core::arch::x86_64::*;
    let kc = bp.len() / NR;
    debug_assert_eq!(ap0.len(), kc * MR);
    debug_assert_eq!(ap1.len(), kc * MR);
    let mut acc = [_mm512_setzero_pd(); 2 * MR];
    let mut a0 = ap0.as_ptr();
    let mut a1 = ap1.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let bv = _mm512_loadu_pd(b);
        for r in 0..MR {
            acc[r] = _mm512_fmadd_pd(_mm512_set1_pd(*a0.add(r)), bv, acc[r]);
            acc[MR + r] = _mm512_fmadd_pd(_mm512_set1_pd(*a1.add(r)), bv, acc[MR + r]);
        }
        a0 = a0.add(MR);
        a1 = a1.add(MR);
        b = b.add(NR);
    }
    let mut out = [[0.0f64; NR]; 2 * MR];
    for (row, acc) in out.iter_mut().zip(acc) {
        _mm512_storeu_pd(row.as_mut_ptr(), acc);
    }
    out
}

/// Two stacked 4×8 tiles (`ap0` rows on top of `ap1` rows) in one call.
/// On the AVX-512 tier this is the native 8×8 zmm kernel; every other
/// tier computes the two 4×8 tiles back to back (identical arithmetic,
/// so the macro-kernel may pair unconditionally).
#[inline]
pub(crate) fn microkernel_8x8(
    isa: KernelIsa,
    ap0: &[f64],
    ap1: &[f64],
    bp: &[f64],
) -> [[f64; NR]; 2 * MR] {
    #[cfg(target_arch = "x86_64")]
    if isa == KernelIsa::Avx512 {
        // SAFETY: tier selection guarantees AVX-512F on this host.
        return unsafe { mk8x8_avx512(ap0, ap1, bp) };
    }
    let top = microkernel_4x8(isa, ap0, bp);
    let bot = microkernel_4x8(isa, ap1, bp);
    let mut out = [[0.0f64; NR]; 2 * MR];
    out[..MR].copy_from_slice(&top);
    out[MR..].copy_from_slice(&bot);
    out
}

// ---------------------------------------------------------------------------
// f32 micro-kernels (PR 6 — mixed-precision path)
// ---------------------------------------------------------------------------
//
// The f32 tiles double every lane count of the f64 tiles at the same
// register budget: the base tile is MR32×NR32 = 8×8 on every tier
// (scalar oracle, AVX2 ymm, NEON q-registers), and the AVX-512 tier
// pairs two adjacent 8-row panels into a native 16×8 zmm kernel — the
// same panel-pairing design as the f64 4×8 → 8×8 promotion, so the
// macro-kernel logic is shared in shape.
//
// These kernels feed `kernel::sgemm`/`ssyrk` under the mixed-precision
// sessions (`solver.precision = "mixed"`): the Gram and Cholesky factor
// are computed here in f32 (unit roundoff u₃₂ ≈ 6·10⁻⁸) and the solve
// is corrected by f64 iterative refinement. Convergence condition: one
// refinement sweep contracts the error by ≈ κ(λI + SᵀS/m)·u₃₂ per
// iteration, so the loop converges to f64-grade answers whenever
// κ·u₃₂ ≪ 1 (κ ≲ 10⁶); beyond that the sessions detect stagnation and
// fall back to the f64 factorization.
//
// Determinism: per C element every FMA tier computes one `p`-strictly-
// increasing FMA chain (the scalar oracle keeps two-rounding seed
// arithmetic), so AVX-512 panel pairing never changes a value and the
// threaded band partition stays bitwise-deterministic within a tier.

/// The scalar MR32×NR32 f32 micro-kernel — the f32 oracle tier.
/// Separate multiply and add roundings (seed arithmetic), `p` strictly
/// increasing per C element.
fn mk8x8_scalar_f32(ap: &[f32], bp: &[f32]) -> [[f32; NR32]; MR32] {
    let mut acc = [[0.0f32; NR32]; MR32];
    for (a, b) in ap.chunks_exact(MR32).zip(bp.chunks_exact(NR32)) {
        let a: &[f32; MR32] = a.try_into().unwrap();
        let b: &[f32; NR32] = b.try_into().unwrap();
        for r in 0..MR32 {
            let ar = a[r];
            for j in 0..NR32 {
                acc[r][j] += ar * b[j];
            }
        }
    }
    acc
}

/// AVX2+FMA 8×8 f32: 8 ymm accumulators (one full C row of 8 floats
/// each), 1 B load and 8 broadcasts per k-step. Single FMA chain per C
/// element, `p` strictly increasing.
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk8x8_avx2_f32(ap: &[f32], bp: &[f32]) -> [[f32; NR32]; MR32] {
    use core::arch::x86_64::*;
    let kc = bp.len() / NR32;
    debug_assert_eq!(ap.len(), kc * MR32);
    let mut acc = [_mm256_setzero_ps(); MR32];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let bv = _mm256_loadu_ps(b);
        for (r, acc) in acc.iter_mut().enumerate() {
            *acc = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(r)), bv, *acc);
        }
        a = a.add(MR32);
        b = b.add(NR32);
    }
    let mut out = [[0.0f32; NR32]; MR32];
    for (row, acc) in out.iter_mut().zip(acc) {
        _mm256_storeu_ps(row.as_mut_ptr(), acc);
    }
    out
}

/// NEON 8×8 f32: 16 q-register accumulators (8 rows × 2 lanes-of-4),
/// FMA via `vfmaq_f32`. Same per-element `p`-increasing FMA chain as
/// the x86 tiers.
///
/// # Safety
/// Caller must be on aarch64 with NEON (baseline for the arch).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn mk8x8_neon_f32(ap: &[f32], bp: &[f32]) -> [[f32; NR32]; MR32] {
    use core::arch::aarch64::*;
    let kc = bp.len() / NR32;
    debug_assert_eq!(ap.len(), kc * MR32);
    let mut acc = [[vdupq_n_f32(0.0); 2]; MR32];
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = vld1q_f32(b);
        let b1 = vld1q_f32(b.add(4));
        for (r, acc) in acc.iter_mut().enumerate() {
            let ar = vdupq_n_f32(*a.add(r));
            acc[0] = vfmaq_f32(acc[0], ar, b0);
            acc[1] = vfmaq_f32(acc[1], ar, b1);
        }
        a = a.add(MR32);
        b = b.add(NR32);
    }
    let mut out = [[0.0f32; NR32]; MR32];
    for (row, acc) in out.iter_mut().zip(acc) {
        vst1q_f32(row.as_mut_ptr(), acc[0]);
        vst1q_f32(row.as_mut_ptr().add(4), acc[1]);
    }
    out
}

/// AVX-512F 16×8 f32 over two adjacent MR32-panels, column-major
/// accumulators: `acc[j]` is one zmm holding C[0..16][j]. Per k-step
/// the two 8-row A panels are fused into one zmm
/// (`_mm512_shuffle_f32x4`, an AVX-512F op — `insertf32x8` would need
/// AVX-512DQ) and FMA'd against 8 broadcasts of the B row. Per C
/// element this is the *same* single `p`-increasing FMA chain as the
/// 8×8 f32 kernels, so pairing panels never changes a value.
///
/// # Safety
/// Caller must ensure AVX-512F is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn mk16x8_avx512_f32(ap0: &[f32], ap1: &[f32], bp: &[f32]) -> [[f32; NR32]; 2 * MR32] {
    use core::arch::x86_64::*;
    let kc = bp.len() / NR32;
    debug_assert_eq!(ap0.len(), kc * MR32);
    debug_assert_eq!(ap1.len(), kc * MR32);
    let mut acc = [_mm512_setzero_ps(); NR32];
    let mut a0 = ap0.as_ptr();
    let mut a1 = ap1.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        // av = [ap0 row (8 f32), ap1 row (8 f32)] — 0x44 selects 128-bit
        // lanes [x0, x1, y0, y1], i.e. the low 256 bits of each operand.
        let lo = _mm512_castps256_ps512(_mm256_loadu_ps(a0));
        let hi = _mm512_castps256_ps512(_mm256_loadu_ps(a1));
        let av = _mm512_shuffle_f32x4(lo, hi, 0x44);
        for (j, acc) in acc.iter_mut().enumerate() {
            *acc = _mm512_fmadd_ps(av, _mm512_set1_ps(*b.add(j)), *acc);
        }
        a0 = a0.add(MR32);
        a1 = a1.add(MR32);
        b = b.add(NR32);
    }
    let mut out = [[0.0f32; NR32]; 2 * MR32];
    for (j, acc) in acc.iter().enumerate() {
        let mut col = [0.0f32; 2 * MR32];
        _mm512_storeu_ps(col.as_mut_ptr(), *acc);
        for (r, c) in col.iter().enumerate() {
            out[r][j] = *c;
        }
    }
    out
}

/// Dispatch the 8×8 f32 micro-kernel for `isa`. The AVX-512 tier uses
/// the AVX2 8×8 kernel here (AVX-512F detection implies AVX2+FMA) —
/// its native 16×8 tile lives in [`microkernel_16x8_f32`] and is only
/// engaged when two adjacent row panels are available.
#[inline]
pub(crate) fn microkernel_8x8_f32(isa: KernelIsa, ap: &[f32], bp: &[f32]) -> [[f32; NR32]; MR32] {
    #[cfg(target_arch = "x86_64")]
    if matches!(isa, KernelIsa::Avx2 | KernelIsa::Avx512) {
        // SAFETY: tier selection guarantees AVX2+FMA on this host.
        return unsafe { mk8x8_avx2_f32(ap, bp) };
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { mk8x8_neon_f32(ap, bp) };
    }
    let _ = isa;
    mk8x8_scalar_f32(ap, bp)
}

/// Two stacked 8×8 f32 tiles (`ap0` rows on top of `ap1` rows) in one
/// call. On the AVX-512 tier this is the native 16×8 zmm kernel; every
/// other tier computes the two 8×8 tiles back to back (identical
/// arithmetic, so the macro-kernel may pair unconditionally).
#[inline]
pub(crate) fn microkernel_16x8_f32(
    isa: KernelIsa,
    ap0: &[f32],
    ap1: &[f32],
    bp: &[f32],
) -> [[f32; NR32]; 2 * MR32] {
    #[cfg(target_arch = "x86_64")]
    if isa == KernelIsa::Avx512 {
        // SAFETY: tier selection guarantees AVX-512F on this host.
        return unsafe { mk16x8_avx512_f32(ap0, ap1, bp) };
    }
    let top = microkernel_8x8_f32(isa, ap0, bp);
    let bot = microkernel_8x8_f32(isa, ap1, bp);
    let mut out = [[0.0f32; NR32]; 2 * MR32];
    out[..MR32].copy_from_slice(&top);
    out[MR32..].copy_from_slice(&bot);
    out
}

// ---------------------------------------------------------------------------
// dot / axpy
// ---------------------------------------------------------------------------

/// The seed 16-way-unrolled scalar dot (two groups of 8 lane
/// accumulators hide the add latency), kept verbatim as the scalar
/// tier.
fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    let mut acc0 = [0.0f64; 8];
    let mut acc1 = [0.0f64; 8];
    let mut ca = a.chunks_exact(16);
    let mut cb = b.chunks_exact(16);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..8 {
            acc0[l] += xa[l] * xb[l];
            acc1[l] += xa[8 + l] * xb[8 + l];
        }
    }
    let mut s = 0.0;
    for l in 0..8 {
        s += acc0[l] + acc1[l];
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// AVX2+FMA dot: 4 ymm accumulators over 16-element chunks, fixed-order
/// horizontal reduction, scalar tail.
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 16;
    let mut acc = [_mm256_setzero_pd(); 4];
    let mut pa = a.as_ptr();
    let mut pb = b.as_ptr();
    for _ in 0..chunks {
        for (l, acc) in acc.iter_mut().enumerate() {
            *acc = _mm256_fmadd_pd(
                _mm256_loadu_pd(pa.add(4 * l)),
                _mm256_loadu_pd(pb.add(4 * l)),
                *acc,
            );
        }
        pa = pa.add(16);
        pb = pb.add(16);
    }
    let v = _mm256_add_pd(_mm256_add_pd(acc[0], acc[1]), _mm256_add_pd(acc[2], acc[3]));
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), v);
    let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
    for i in chunks * 16..n {
        s += a[i] * b[i];
    }
    s
}

/// AVX-512F dot: 4 zmm accumulators over 32-element chunks.
///
/// # Safety
/// Caller must ensure AVX-512F is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn dot_avx512(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 32;
    let mut acc = [_mm512_setzero_pd(); 4];
    let mut pa = a.as_ptr();
    let mut pb = b.as_ptr();
    for _ in 0..chunks {
        for (l, acc) in acc.iter_mut().enumerate() {
            *acc = _mm512_fmadd_pd(
                _mm512_loadu_pd(pa.add(8 * l)),
                _mm512_loadu_pd(pb.add(8 * l)),
                *acc,
            );
        }
        pa = pa.add(32);
        pb = pb.add(32);
    }
    let v = _mm512_add_pd(_mm512_add_pd(acc[0], acc[1]), _mm512_add_pd(acc[2], acc[3]));
    let mut lanes = [0.0f64; 8];
    _mm512_storeu_pd(lanes.as_mut_ptr(), v);
    let mut s = 0.0;
    for l in lanes {
        s += l;
    }
    for i in chunks * 32..n {
        s += a[i] * b[i];
    }
    s
}

/// NEON dot: 8 q-register accumulators over 16-element chunks.
///
/// # Safety
/// Caller must be on aarch64 with NEON.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::aarch64::*;
    let n = a.len();
    let chunks = n / 16;
    let mut acc = [vdupq_n_f64(0.0); 8];
    let mut pa = a.as_ptr();
    let mut pb = b.as_ptr();
    for _ in 0..chunks {
        for (l, acc) in acc.iter_mut().enumerate() {
            *acc = vfmaq_f64(*acc, vld1q_f64(pa.add(2 * l)), vld1q_f64(pb.add(2 * l)));
        }
        pa = pa.add(16);
        pb = pb.add(16);
    }
    let mut s = 0.0;
    for acc in acc {
        s += vaddvq_f64(acc);
    }
    for i in chunks * 16..n {
        s += a[i] * b[i];
    }
    s
}

/// `⟨a, b⟩` on an explicit tier. [`super::mat::dot`] wraps this with
/// [`active_isa`]; the unblocked Cholesky/TRSM cores capture the tier
/// once per call instead.
#[inline]
pub(crate) fn dot_isa(isa: KernelIsa, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY (both arms): tier selection guarantees the features.
        if isa == KernelIsa::Avx512 {
            return unsafe { dot_avx512(a, b) };
        }
        if isa == KernelIsa::Avx2 {
            return unsafe { dot_avx2(a, b) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { dot_neon(a, b) };
    }
    let _ = isa;
    dot_scalar(a, b)
}

/// Scalar `y += alpha · x`, 8-way unrolled through `chunks_exact` (no
/// bounds checks in the hot loop) — the scalar-tier counterpart of
/// [`dot_scalar`]'s unrolling.
fn axpy_scalar(alpha: f64, x: &[f64], y: &mut [f64]) {
    let mut cx = x.chunks_exact(8);
    let mut cy = y.chunks_exact_mut(8);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        for l in 0..8 {
            ys[l] += alpha * xs[l];
        }
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += alpha * x;
    }
}

/// AVX2+FMA `y += alpha · x` over 8-element chunks (2 ymm per step).
///
/// # Safety
/// Caller must ensure AVX2 and FMA are available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    use core::arch::x86_64::*;
    let n = x.len();
    let chunks = n / 8;
    let av = _mm256_set1_pd(alpha);
    let mut px = x.as_ptr();
    let mut py = y.as_mut_ptr();
    for _ in 0..chunks {
        let y0 = _mm256_fmadd_pd(av, _mm256_loadu_pd(px), _mm256_loadu_pd(py));
        let y1 = _mm256_fmadd_pd(av, _mm256_loadu_pd(px.add(4)), _mm256_loadu_pd(py.add(4)));
        _mm256_storeu_pd(py, y0);
        _mm256_storeu_pd(py.add(4), y1);
        px = px.add(8);
        py = py.add(8);
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// AVX-512F `y += alpha · x` over 16-element chunks (2 zmm per step).
///
/// # Safety
/// Caller must ensure AVX-512F is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn axpy_avx512(alpha: f64, x: &[f64], y: &mut [f64]) {
    use core::arch::x86_64::*;
    let n = x.len();
    let chunks = n / 16;
    let av = _mm512_set1_pd(alpha);
    let mut px = x.as_ptr();
    let mut py = y.as_mut_ptr();
    for _ in 0..chunks {
        let y0 = _mm512_fmadd_pd(av, _mm512_loadu_pd(px), _mm512_loadu_pd(py));
        let y1 = _mm512_fmadd_pd(av, _mm512_loadu_pd(px.add(8)), _mm512_loadu_pd(py.add(8)));
        _mm512_storeu_pd(py, y0);
        _mm512_storeu_pd(py.add(8), y1);
        px = px.add(16);
        py = py.add(16);
    }
    for i in chunks * 16..n {
        y[i] += alpha * x[i];
    }
}

/// NEON `y += alpha · x` over 8-element chunks (4 q-registers per step).
///
/// # Safety
/// Caller must be on aarch64 with NEON.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn axpy_neon(alpha: f64, x: &[f64], y: &mut [f64]) {
    use core::arch::aarch64::*;
    let n = x.len();
    let chunks = n / 8;
    let av = vdupq_n_f64(alpha);
    let mut px = x.as_ptr();
    let mut py = y.as_mut_ptr();
    for _ in 0..chunks {
        for l in 0..4 {
            let yv = vfmaq_f64(vld1q_f64(py.add(2 * l)), av, vld1q_f64(px.add(2 * l)));
            vst1q_f64(py.add(2 * l), yv);
        }
        px = px.add(8);
        py = py.add(8);
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// `y += alpha · x` on an explicit tier — see [`dot_isa`].
#[inline]
pub(crate) fn axpy_isa(isa: KernelIsa, alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        // SAFETY (both arms): tier selection guarantees the features.
        if isa == KernelIsa::Avx512 {
            return unsafe { axpy_avx512(alpha, x, y) };
        }
        if isa == KernelIsa::Avx2 {
            return unsafe { axpy_avx2(alpha, x, y) };
        }
    }
    #[cfg(target_arch = "aarch64")]
    if isa == KernelIsa::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { axpy_neon(alpha, x, y) };
    }
    let _ = isa;
    axpy_scalar(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(len: usize, seed: u64) -> Vec<f64> {
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0
            })
            .collect()
    }

    #[test]
    fn parse_roundtrip_and_detect() {
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2, KernelIsa::Avx512, KernelIsa::Neon] {
            assert_eq!(KernelIsa::parse(isa.as_str()), Some(isa));
        }
        assert_eq!(KernelIsa::parse("sse9"), None);
        let tiers = KernelIsa::supported_tiers();
        assert_eq!(tiers[0], KernelIsa::Scalar);
        assert_eq!(*tiers.last().unwrap(), KernelIsa::detect());
        assert!(KernelIsa::detect().supported());
        assert!(active_isa().supported());
    }

    #[test]
    fn with_isa_scopes_and_restores() {
        let ambient = active_isa();
        with_isa(KernelIsa::Scalar, || {
            assert_eq!(active_isa(), KernelIsa::Scalar);
            for &tier in &KernelIsa::supported_tiers() {
                with_isa(tier, || assert_eq!(active_isa(), tier));
            }
            assert_eq!(active_isa(), KernelIsa::Scalar);
        });
        assert_eq!(active_isa(), ambient);
        // Panic inside the scope still restores the ambient tier.
        let caught = std::panic::catch_unwind(|| {
            with_isa(KernelIsa::Scalar, || panic!("boom"));
        });
        assert!(caught.is_err());
        assert_eq!(active_isa(), ambient);
    }

    #[test]
    fn every_tier_dot_matches_scalar() {
        for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 257] {
            let a = fill(n, 1);
            let b = fill(n, 2);
            let want = dot_scalar(&a, &b);
            for &isa in &KernelIsa::supported_tiers() {
                let got = dot_isa(isa, &a, &b);
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "dot[{isa}] n={n}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn every_tier_axpy_matches_scalar() {
        for n in [0usize, 1, 7, 8, 9, 16, 17, 63, 130] {
            let x = fill(n, 3);
            let y0 = fill(n, 4);
            let mut want = y0.clone();
            axpy_scalar(0.37, &x, &mut want);
            for &isa in &KernelIsa::supported_tiers() {
                let mut got = y0.clone();
                axpy_isa(isa, 0.37, &x, &mut got);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() <= 1e-14, "axpy[{isa}] n={n}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn every_tier_microkernels_match_scalar_tile() {
        for kc in [1usize, 2, 3, 8, 37] {
            let ap0 = fill(kc * MR, 5);
            let ap1 = fill(kc * MR, 6);
            let bp = fill(kc * NR, 7);
            let want4 = mk4x8_scalar(&ap0, &bp);
            let want8 = {
                let mut w = [[0.0; NR]; 2 * MR];
                w[..MR].copy_from_slice(&mk4x8_scalar(&ap0, &bp));
                w[MR..].copy_from_slice(&mk4x8_scalar(&ap1, &bp));
                w
            };
            for &isa in &KernelIsa::supported_tiers() {
                let got4 = microkernel_4x8(isa, &ap0, &bp);
                let got8 = microkernel_8x8(isa, &ap0, &ap1, &bp);
                for r in 0..MR {
                    for j in 0..NR {
                        assert!(
                            (got4[r][j] - want4[r][j]).abs() <= 1e-12 * (kc as f64),
                            "4x8[{isa}] kc={kc} ({r},{j})"
                        );
                    }
                }
                for r in 0..2 * MR {
                    for j in 0..NR {
                        assert!(
                            (got8[r][j] - want8[r][j]).abs() <= 1e-12 * (kc as f64),
                            "8x8[{isa}] kc={kc} ({r},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_tier_f32_microkernels_match_scalar_tile() {
        for kc in [1usize, 2, 3, 8, 37] {
            let ap0: Vec<f32> = fill(kc * MR32, 8).iter().map(|&x| x as f32).collect();
            let ap1: Vec<f32> = fill(kc * MR32, 9).iter().map(|&x| x as f32).collect();
            let bp: Vec<f32> = fill(kc * NR32, 10).iter().map(|&x| x as f32).collect();
            let want8 = mk8x8_scalar_f32(&ap0, &bp);
            let want16 = {
                let mut w = [[0.0f32; NR32]; 2 * MR32];
                w[..MR32].copy_from_slice(&mk8x8_scalar_f32(&ap0, &bp));
                w[MR32..].copy_from_slice(&mk8x8_scalar_f32(&ap1, &bp));
                w
            };
            let tol = 1e-4 * (kc as f32).max(1.0);
            for &isa in &KernelIsa::supported_tiers() {
                let got8 = microkernel_8x8_f32(isa, &ap0, &bp);
                let got16 = microkernel_16x8_f32(isa, &ap0, &ap1, &bp);
                for r in 0..MR32 {
                    for j in 0..NR32 {
                        assert!(
                            (got8[r][j] - want8[r][j]).abs() <= tol,
                            "f32 8x8[{isa}] kc={kc} ({r},{j}): {} vs {}",
                            got8[r][j],
                            want8[r][j]
                        );
                    }
                }
                for r in 0..2 * MR32 {
                    for j in 0..NR32 {
                        assert!(
                            (got16[r][j] - want16[r][j]).abs() <= tol,
                            "f32 16x8[{isa}] kc={kc} ({r},{j})"
                        );
                    }
                }
                // Panel pairing is value-preserving within a tier: the
                // 16×8 tile must equal the two 8×8 tiles bitwise (the
                // paired kernel runs the same per-element FMA chain).
                let top = microkernel_8x8_f32(isa, &ap0, &bp);
                let bot = microkernel_8x8_f32(isa, &ap1, &bp);
                for r in 0..MR32 {
                    for j in 0..NR32 {
                        assert_eq!(
                            got16[r][j].to_bits(),
                            top[r][j].to_bits(),
                            "f32 pairing changed a value [{isa}] kc={kc} ({r},{j})"
                        );
                        assert_eq!(got16[MR32 + r][j].to_bits(), bot[r][j].to_bits());
                    }
                }
            }
        }
    }
}
