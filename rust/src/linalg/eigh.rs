//! Symmetric eigensolvers.
//!
//! Backs the paper's **"eigh" SVD baseline** (Appendix C): the fastest
//! pre-existing method, which diagonalizes the n×n Gram matrix
//! `SSᵀ = U Σ² Uᵀ` and finishes the thin SVD with `V = SᵀUΣ⁻¹`.
//!
//! Two implementations:
//!
//! * [`eigh`] — Householder tridiagonalization + implicit-shift QL
//!   (the tred2/tqli pair): ~3n³ FLOPs total, the same algorithm family
//!   as the cuSOLVER `syevd` the paper's baseline calls. This is the
//!   default used by [`super::svd::svd_eigh`]; with it, the measured
//!   eigh/chol gap matches the paper's 2.5–5× (EXPERIMENTS.md §Perf).
//! * [`eigh_jacobi`] — cyclic Jacobi: slower (O(n³·sweeps), bigger
//!   constant) but unconditionally stable and independently derived, so
//!   it serves as the cross-validation oracle in tests.

use super::mat::Mat;

/// Maximum number of cyclic sweeps before giving up (converges in ≤ ~12
/// for any symmetric matrix at f64 precision in practice).
const MAX_SWEEPS: usize = 30;

/// Eigendecomposition of a symmetric matrix: returns `(eigvals, U)` with
/// `A = U · diag(eigvals) · Uᵀ`, eigenvalues ascending, `U` orthogonal
/// with eigenvectors in **columns**. Householder + implicit QL.
pub fn eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "eigh needs a square symmetric matrix");
    if n <= 2 {
        // Tiny cases: the Jacobi path is exact and simpler.
        return eigh_jacobi(a);
    }
    let (mut d, mut e, mut z) = tred2(a);
    tqli(&mut d, &mut e, &mut z);
    // Sort ascending, permuting columns of z.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap());
    let eigvals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut u = Mat::zeros(n, n);
    for (newcol, &old) in order.iter().enumerate() {
        for r in 0..n {
            u[(r, newcol)] = z[(r, old)];
        }
    }
    (eigvals, u)
}

/// Householder reduction of a real symmetric matrix to tridiagonal form
/// (tred2): returns (diagonal d, sub-diagonal e with e[0] unused, and the
/// accumulated orthogonal transform Z with A = Z·T·Zᵀ).
fn tred2(a: &Mat) -> (Vec<f64>, Vec<f64>, Mat) {
    let n = a.rows();
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];

    for i in (1..n).rev() {
        let l = i; // columns 0..l of row i participate
        let mut h = 0.0;
        if l > 1 {
            let mut scale = 0.0;
            for k in 0..l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l - 1)];
            } else {
                for k in 0..l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let f = z[(i, l - 1)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l - 1)] = f - g;
                let mut tau = 0.0;
                for j in 0..l {
                    // Store u/H in column i for the Q accumulation.
                    z[(j, i)] = z[(i, j)] / h;
                    // g = A·u (row j partial)
                    let mut gg = 0.0;
                    for k in 0..=j {
                        gg += z[(j, k)] * z[(i, k)];
                    }
                    for k in j + 1..l {
                        gg += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = gg / h;
                    tau += e[j] * z[(i, j)];
                }
                let hh = tau / (h + h);
                for j in 0..l {
                    let f = z[(i, j)];
                    let gj = e[j] - hh * f;
                    e[j] = gj;
                    for k in 0..=j {
                        let upd = f * e[k] + gj * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l - 1)];
        }
        d[i] = h;
    }

    d[0] = 0.0;
    e[0] = 0.0;
    // Accumulate the transformation matrix.
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..l {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..l {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
    (d, e, z)
}

/// Implicit-shift QL on a symmetric tridiagonal matrix with eigenvector
/// accumulation (tqli). On return `d` holds eigenvalues and the columns
/// of `z` the eigenvectors.
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut Mat) {
    let n = d.len();
    // Renumber sub-diagonal.
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small sub-diagonal element to split.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter <= 50, "tqli: too many iterations (matrix not symmetric?)");
            // Form the implicit shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Eigenvector rotation.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
}

/// Eigendecomposition by cyclic Jacobi rotations (cross-validation oracle
/// and tiny-n path). Same contract as [`eigh`].
pub fn eigh_jacobi(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(a.cols(), n, "eigh needs a square symmetric matrix");
    let mut w = a.clone();
    let mut u = Mat::eye(n);
    if n <= 1 {
        return (vec![if n == 1 { w[(0, 0)] } else { 0.0 }; n], u);
    }

    let scale = w.max_abs().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * scale;

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n - 1 {
            for q in p + 1..n {
                off = off.max(w[(p, q)].abs());
            }
        }
        if off < tol {
            break;
        }
        for p in 0..n - 1 {
            for q in p + 1..n {
                let apq = w[(p, q)];
                if apq.abs() < tol * 1e-2 {
                    continue;
                }
                // 2×2 symmetric Schur decomposition: find c, s zeroing
                // the (p,q) entry.
                let (c, s) = {
                    let tau = (w[(q, q)] - w[(p, p)]) / (2.0 * apq);
                    let t = if tau >= 0.0 {
                        1.0 / (tau + (1.0 + tau * tau).sqrt())
                    } else {
                        -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                    };
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    (c, t * c)
                };
                // Apply the rotation J(p,q,θ): W ← JᵀWJ, U ← UJ.
                rotate_sym(&mut w, p, q, c, s);
                rotate_cols(&mut u, p, q, c, s);
            }
        }
    }

    // Extract eigenvalues, sort ascending, permute U's columns to match.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (w[(i, i)], i)).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let eigvals: Vec<f64> = pairs.iter().map(|(v, _)| *v).collect();
    let mut usorted = Mat::zeros(n, n);
    for (newcol, &(_, oldcol)) in pairs.iter().enumerate() {
        for i in 0..n {
            usorted[(i, newcol)] = u[(i, oldcol)];
        }
    }
    (eigvals, usorted)
}

/// Symmetric two-sided rotation on rows/cols p and q.
fn rotate_sym(w: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let n = w.rows();
    let wpp = w[(p, p)];
    let wqq = w[(q, q)];
    let wpq = w[(p, q)];
    for k in 0..n {
        if k != p && k != q {
            let wkp = w[(k, p)];
            let wkq = w[(k, q)];
            let np = c * wkp - s * wkq;
            let nq = s * wkp + c * wkq;
            w[(k, p)] = np;
            w[(p, k)] = np;
            w[(k, q)] = nq;
            w[(q, k)] = nq;
        }
    }
    w[(p, p)] = c * c * wpp - 2.0 * s * c * wpq + s * s * wqq;
    w[(q, q)] = s * s * wpp + 2.0 * s * c * wpq + c * c * wqq;
    w[(p, q)] = 0.0;
    w[(q, p)] = 0.0;
}

/// Right-multiply by the rotation: columns p, q of U mix.
fn rotate_cols(u: &mut Mat, p: usize, q: usize, c: f64, s: f64) {
    let n = u.rows();
    for i in 0..n {
        let up = u[(i, p)];
        let uq = u[(i, q)];
        u[(i, p)] = c * up - s * uq;
        u[(i, q)] = s * up + c * uq;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::gemm::{gemm, gemm_nt, syrk};

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut d = Mat::zeros(4, 4);
        for (i, v) in [3.0, -1.0, 2.0, 0.5].iter().enumerate() {
            d[(i, i)] = *v;
        }
        let (vals, _u) = eigh(&d);
        assert_eq!(vals, vec![-1.0, 0.5, 2.0, 3.0]); // ascending
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        let mut rng = Rng::seed_from(40);
        for &n in &[1, 2, 3, 10, 33, 80] {
            let a = Mat::randn(n, n + 2, &mut rng);
            let w = syrk(&a, 0.3);
            let (vals, u) = eigh(&w);
            // UᵀU = I
            let mut utu = Mat::zeros(n, n);
            gemm(1.0, &u.transpose(), &u, 0.0, &mut utu);
            for i in 0..n {
                for j in 0..n {
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!((utu[(i, j)] - expect).abs() < 1e-10, "orthogonality n={n}");
                }
            }
            // U diag(vals) Uᵀ = W
            let mut ud = u.clone();
            for i in 0..n {
                for j in 0..n {
                    ud[(i, j)] *= vals[j];
                }
            }
            let mut recon = Mat::zeros(n, n);
            gemm_nt(1.0, &ud, &u, 0.0, &mut recon);
            let scale = w.max_abs().max(1.0);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - w[(i, j)]).abs() < 1e-9 * scale,
                        "reconstruction n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn gram_eigenvalues_nonnegative() {
        let mut rng = Rng::seed_from(41);
        let a = Mat::randn(20, 100, &mut rng);
        let w = syrk(&a, 0.0);
        let (vals, _) = eigh(&w);
        for v in vals {
            assert!(v > -1e-9);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1, 3.
        let w = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let (vals, _) = eigh(&w);
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ql_matches_jacobi_oracle() {
        let mut rng = Rng::seed_from(43);
        for &n in &[3usize, 4, 10, 33, 64] {
            let a = Mat::randn(n, n + 2, &mut rng);
            let w = syrk(&a, 0.3);
            let (vq, _) = eigh(&w);
            let (vj, _) = eigh_jacobi(&w);
            let scale = w.max_abs().max(1.0);
            for (x, y) in vq.iter().zip(&vj) {
                assert!((x - y).abs() < 1e-9 * scale, "n={n}: ql {x} vs jacobi {y}");
            }
        }
    }

    #[test]
    fn ql_handles_degenerate_spectra() {
        // Repeated eigenvalues: I (all equal) and a rank-1 update.
        let (vals, u) = eigh(&Mat::eye(8));
        for v in &vals {
            assert!((v - 1.0).abs() < 1e-12);
        }
        // U must still be orthogonal.
        let mut utu = Mat::zeros(8, 8);
        gemm(1.0, &u.transpose(), &u, 0.0, &mut utu);
        for i in 0..8 {
            for j in 0..8 {
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((utu[(i, j)] - e).abs() < 1e-10);
            }
        }
        // Zero matrix.
        let (vals, _) = eigh(&Mat::zeros(5, 5));
        assert!(vals.iter().all(|v| v.abs() < 1e-14));
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::seed_from(42);
        let a = Mat::randn(15, 15, &mut rng);
        // Symmetrize.
        let mut w = a.clone();
        let at = a.transpose();
        w.axpy(1.0, &at);
        w.scale(0.5);
        let trace: f64 = (0..15).map(|i| w[(i, i)]).sum();
        let (vals, _) = eigh(&w);
        let sum: f64 = vals.iter().sum();
        assert!((trace - sum).abs() < 1e-9);
    }
}
