//! Thin SVD of the tall-skinny score matrix — the paper's two baselines.
//!
//! For `S: n×m` with `n ≤ m`, the thin SVD is `S = U Σ Vᵀ` with `U: n×n`
//! orthogonal, `Σ: n` non-negative, `V: m×n` with orthonormal columns.
//!
//! * [`svd_eigh`] — the paper's **"eigh"** method (Appendix C): eigendecompose
//!   the n×n Gram matrix `SSᵀ = U Σ² Uᵀ`, then `V = SᵀUΣ⁻¹`. Previously the
//!   fastest method known to the authors.
//! * [`svd_jacobi`] — stand-in for the CUDA **"gesvda"** kernel (the `svda`
//!   baseline). `gesvda` is NVIDIA's blocked one-sided-Jacobi routine for
//!   tall-skinny batches; this is the same algorithm family: one-sided
//!   Jacobi sweeps orthogonalizing the *rows* of S (row-major friendly),
//!   accumulating U, with `Σ Vᵀ` read off the converged rows. Like the real
//!   gesvda it costs O(n²m) *per sweep* with several sweeps, which is why
//!   the paper measures it as the slowest method — behaviour preserved.

use super::eigh::eigh;
use super::mat::{dot, Mat};

/// Thin SVD `S = U Σ Vᵀ`.
pub struct ThinSvd {
    /// Left singular vectors, n×n, orthogonal, columns are vectors.
    pub u: Mat,
    /// Singular values, descending. May contain (numerical) zeros.
    pub sigma: Vec<f64>,
    /// Right singular vectors, **n×m row-major** storing `Vᵀ` (row j is
    /// the j-th right singular vector). Rows whose singular value is
    /// numerically zero are zeroed out — see [`ThinSvd::rank`].
    pub vt: Mat,
}

impl ThinSvd {
    /// Numerical rank: number of singular values above `tol·σ_max`.
    pub fn rank(&self, tol: f64) -> usize {
        let smax = self.sigma.first().copied().unwrap_or(0.0);
        self.sigma.iter().filter(|&&s| s > tol * smax).count()
    }

    /// Reconstruct `S` (tests only).
    pub fn reconstruct(&self) -> Mat {
        let n = self.u.rows();
        let m = self.vt.cols();
        let mut s = Mat::zeros(n, m);
        for i in 0..n {
            for k in 0..n {
                let c = self.u[(i, k)] * self.sigma[k];
                if c != 0.0 {
                    for j in 0..m {
                        s[(i, j)] += c * self.vt[(k, j)];
                    }
                }
            }
        }
        s
    }
}

/// Relative cutoff below which a singular value is treated as zero.
const SIGMA_TOL: f64 = 1e-12;

/// Tall-skinny SVD via the Gram-matrix eigendecomposition (Appendix C,
/// the `"eigh"` baseline): `SSᵀ = U Σ² Uᵀ`, `V = SᵀUΣ⁻¹`.
pub fn svd_eigh(s: &Mat) -> ThinSvd {
    svd_eigh_threaded(s, 1)
}

/// [`svd_eigh`] with its two O(n²m) passes threaded on the persistent
/// kernel pool: the Gram SYRK and the `Vᵀ = (UΣ⁻¹)ᵀ·S` tall GEMM — the
/// stages that dominate the eigh baseline in the tall-skinny regime.
/// The O(n³) Jacobi eigendecomposition itself is inherently sequential
/// (each rotation feeds the next) and stays on the caller. Bit-identical
/// to the serial path at every thread count.
pub fn svd_eigh_threaded(s: &Mat, threads: usize) -> ThinSvd {
    let (n, m) = s.shape();
    assert!(n <= m, "svd_eigh expects tall-skinny Sᵀ, i.e. n ≤ m (got {n}×{m})");
    let w = super::gemm::syrk_parallel(s, 0.0, threads);
    let (vals, u_asc) = eigh(&w);
    // eigh returns ascending; we want σ descending.
    let mut u = Mat::zeros(n, n);
    let mut sigma = vec![0.0; n];
    for k in 0..n {
        let src = n - 1 - k;
        sigma[k] = vals[src].max(0.0).sqrt();
        for i in 0..n {
            u[(i, k)] = u_asc[(i, src)];
        }
    }
    // Vᵀ = (U·Σ⁻¹)ᵀ · S as one tall GEMM (zeroed columns for numerically
    // zero σ keep those vt rows exactly zero: the direction is handled
    // by the λ branch of Eq. 5).
    let smax = sigma[0].max(f64::MIN_POSITIVE);
    let mut uscaled = Mat::zeros(n, n);
    for k in 0..n {
        if sigma[k] <= SIGMA_TOL * smax {
            continue;
        }
        let inv = 1.0 / sigma[k];
        for i in 0..n {
            uscaled[(i, k)] = inv * u[(i, k)];
        }
    }
    let mut vt = Mat::zeros(n, m);
    super::gemm::gemm_tn_threaded(1.0, &uscaled, s, 0.0, &mut vt, threads);
    ThinSvd { u, sigma, vt }
}

/// Maximum one-sided Jacobi sweeps.
const MAX_SWEEPS: usize = 30;

/// Tall-skinny SVD via one-sided Jacobi on the rows of `S` — the `svda`
/// stand-in. Rotates row pairs of a working copy of `S` until all rows are
/// mutually orthogonal; converged rows are `Σ·Vᵀ` and the accumulated
/// rotations are `U`.
pub fn svd_jacobi(s: &Mat) -> ThinSvd {
    let (n, m) = s.shape();
    assert!(n <= m, "svd_jacobi expects n ≤ m (got {n}×{m})");
    let mut b = s.clone(); // rows will converge to σ_k v_kᵀ
    let mut u = Mat::eye(n);

    let fro = s.fro_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * fro * fro;

    for _sweep in 0..MAX_SWEEPS {
        let mut rotated = false;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                let (app, aqq, apq) = {
                    let rp = b.row(p);
                    let rq = b.row(q);
                    (dot(rp, rp), dot(rq, rq), dot(rp, rq))
                };
                if apq.abs() <= tol || apq.abs() <= 1e-15 * (app * aqq).sqrt() {
                    continue;
                }
                rotated = true;
                // Jacobi rotation zeroing the off-diagonal of the 2×2 Gram
                // block [[app, apq], [apq, aqq]].
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let sn = t * c;
                // Rotate rows p, q of B.
                {
                    let (rp, rq) = b.rows_mut2(p, q);
                    for (x, y) in rp.iter_mut().zip(rq.iter_mut()) {
                        let xp = *x;
                        let xq = *y;
                        *x = c * xp - sn * xq;
                        *y = sn * xp + c * xq;
                    }
                }
                // Accumulate the same rotation into U's columns p, q
                // (S = U·B throughout: B ← JᵀB requires U ← U·J).
                for i in 0..n {
                    let up = u[(i, p)];
                    let uq = u[(i, q)];
                    u[(i, p)] = c * up - sn * uq;
                    u[(i, q)] = sn * up + c * uq;
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // Row norms are the singular values; sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n).map(|i| dot(b.row(i), b.row(i)).sqrt()).collect();
    order.sort_by(|&a, &c| norms[c].partial_cmp(&norms[a]).unwrap());

    let smax = order.first().map(|&i| norms[i]).unwrap_or(0.0).max(f64::MIN_POSITIVE);
    let mut sigma = vec![0.0; n];
    let mut vt = Mat::zeros(n, m);
    let mut usorted = Mat::zeros(n, n);
    for (k, &src) in order.iter().enumerate() {
        sigma[k] = norms[src];
        if sigma[k] > SIGMA_TOL * smax {
            let inv = 1.0 / sigma[k];
            let brow = b.row(src);
            let vrow = vt.row_mut(k);
            for j in 0..m {
                vrow[j] = inv * brow[j];
            }
        }
        for i in 0..n {
            usorted[(i, k)] = u[(i, src)];
        }
    }
    ThinSvd { u: usorted, sigma, vt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::gemm::gemm_nt;

    fn check_svd(s: &Mat, svd: &ThinSvd, label: &str) {
        let (n, m) = s.shape();
        // Reconstruction.
        let recon = svd.reconstruct();
        let scale = s.max_abs().max(1.0);
        for i in 0..n {
            for j in 0..m {
                assert!(
                    (recon[(i, j)] - s[(i, j)]).abs() < 1e-8 * scale,
                    "{label}: reconstruction ({i},{j})"
                );
            }
        }
        // U orthogonal.
        let mut utu = Mat::zeros(n, n);
        gemm_nt(1.0, &svd.u.transpose(), &svd.u.transpose(), 0.0, &mut utu);
        for i in 0..n {
            for j in 0..n {
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((utu[(i, j)] - e).abs() < 1e-9, "{label}: UᵀU ({i},{j})");
            }
        }
        // V columns orthonormal (rows of vt), where σ > 0.
        let r = svd.rank(1e-10);
        let mut vvt = Mat::zeros(n, n);
        gemm_nt(1.0, &svd.vt, &svd.vt, 0.0, &mut vvt);
        for i in 0..r {
            for j in 0..r {
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((vvt[(i, j)] - e).abs() < 1e-9, "{label}: VᵀV ({i},{j})");
            }
        }
        // Descending σ.
        for k in 1..n {
            assert!(svd.sigma[k - 1] >= svd.sigma[k] - 1e-12, "{label}: σ ordering");
        }
    }

    #[test]
    fn both_methods_valid_svd_random() {
        let mut rng = Rng::seed_from(50);
        for &(n, m) in &[(1, 1), (2, 5), (7, 7), (13, 200), (40, 160)] {
            let s = Mat::randn(n, m, &mut rng);
            check_svd(&s, &svd_eigh(&s), &format!("eigh {n}x{m}"));
            check_svd(&s, &svd_jacobi(&s), &format!("jacobi {n}x{m}"));
        }
    }

    #[test]
    fn methods_agree_on_singular_values() {
        let mut rng = Rng::seed_from(51);
        let s = Mat::randn(12, 90, &mut rng);
        let a = svd_eigh(&s);
        let b = svd_jacobi(&s);
        for (x, y) in a.sigma.iter().zip(&b.sigma) {
            assert!((x - y).abs() < 1e-8 * a.sigma[0]);
        }
    }

    #[test]
    fn rank_deficient_handled() {
        // Duplicate rows ⇒ rank n-1.
        let mut rng = Rng::seed_from(52);
        let mut s = Mat::randn(6, 40, &mut rng);
        let row0 = s.row(0).to_vec();
        s.row_mut(5).copy_from_slice(&row0);
        for (svd, label) in [(svd_eigh(&s), "eigh"), (svd_jacobi(&s), "jacobi")] {
            assert_eq!(svd.rank(1e-8), 5, "{label}");
            // Reconstruction still exact.
            let recon = svd.reconstruct();
            for i in 0..6 {
                for j in 0..40 {
                    assert!((recon[(i, j)] - s[(i, j)]).abs() < 1e-8, "{label}");
                }
            }
        }
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2) padded to 2×4: σ = {3, 2}.
        let mut s = Mat::zeros(2, 4);
        s[(0, 0)] = 3.0;
        s[(1, 1)] = 2.0;
        for svd in [svd_eigh(&s), svd_jacobi(&s)] {
            assert!((svd.sigma[0] - 3.0).abs() < 1e-12);
            assert!((svd.sigma[1] - 2.0).abs() < 1e-12);
        }
    }
}
