//! Thread-local, 64-byte-aligned packing arenas (PR 4).
//!
//! Every `dgemm`/SYRK call used to allocate fresh `ap`/`bp` packing
//! panels, and the blocked Cholesky / multi-RHS TRSM allocated panel
//! copies and gather buffers per call — microseconds of allocator
//! traffic on every hot-path invocation, paid again inside every pool
//! job. This module replaces all of them with per-thread arena slots:
//!
//! * each slot holds one [`ArenaBuf`] — a raw 64-byte-aligned `f64`
//!   allocation (cache-line / AVX-512-register aligned) that grows
//!   **monotonically** and is reused forever after;
//! * a kernel *checks a slot out* (`take`), sizes it with
//!   [`ArenaBuf::ensure`], and returns it (`put`) when done — the
//!   checkout pattern keeps nested kernels (a TRSM gather whose core
//!   calls `dgemm`, which needs the pack slots) from aliasing a buffer;
//! * growth is counted in a thread-local counter surfaced as
//!   [`kernel::counters::arena_allocs`](super::kernel::counters::arena_allocs),
//!   which pins the steady-state promise: once warmed, a redamp+solve
//!   iteration performs **zero** pack-buffer allocations
//!   (`rust/tests/session_api.rs` s8).
//!
//! Slots are thread-local, so pool workers each warm their own arenas;
//! [`KernelPool::submit`](super::kernel::KernelPool::submit) deals jobs
//! round-robin from worker 0 on every batch, so a repeated workload
//! lands each job on the same (already-warm) worker. A panic while a
//! slot is checked out drops the buffer (its slot re-warms on next
//! use); nothing leaks and no pointer outlives its allocation.
//!
//! Retained footprint per thread is bounded by the largest shapes seen:
//! the B-pack slot tops out at KC×NC f64 = 8 MiB, the others well
//! below it.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::cell::Cell;

/// Alignment of every arena allocation: one cache line, which is also
/// the AVX-512 register width — packed panels never split a vector
/// load across lines.
pub const ARENA_ALIGN: usize = 64;

thread_local! {
    static ARENA_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Arena (re)allocations performed by the calling thread since start —
/// the growth events of [`ArenaBuf::ensure`]. Steady-state kernels stop
/// incrementing this once their shapes have been seen.
pub fn allocs() -> u64 {
    ARENA_ALLOCS.with(|c| c.get())
}

/// A 64-byte-aligned, monotonically-grown `f64` buffer. Contents are
/// zeroed on (re)allocation and *stale* on reuse — callers either
/// overwrite the whole slice or zero-fill (the packing routines do the
/// latter, which they needed for edge-tile padding anyway).
pub struct ArenaBuf {
    ptr: *mut f64,
    cap: usize,
}

// SAFETY: ArenaBuf owns its allocation exclusively; moving it between
// threads moves ownership of raw memory, which has no thread affinity.
unsafe impl Send for ArenaBuf {}

impl Default for ArenaBuf {
    fn default() -> Self {
        ArenaBuf { ptr: std::ptr::null_mut(), cap: 0 }
    }
}

impl ArenaBuf {
    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<f64>(), ARENA_ALIGN)
            .expect("arena layout")
    }

    /// Current capacity in f64 elements.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// A `len`-element view, growing the allocation if needed (to at
    /// least double the old capacity, so repeated mild growth is
    /// amortized). Never shrinks. Growth zero-initializes and bumps the
    /// thread's arena-allocation counter.
    pub fn ensure(&mut self, len: usize) -> &mut [f64] {
        if len == 0 {
            return &mut [];
        }
        if self.cap < len {
            let new_cap = len.max(self.cap * 2).next_multiple_of(ARENA_ALIGN / 8);
            // SAFETY: layout is non-zero-sized here (len ≥ 1); the old
            // pointer (if any) was allocated with Self::layout(old cap).
            unsafe {
                let new_ptr = alloc_zeroed(Self::layout(new_cap)) as *mut f64;
                if new_ptr.is_null() {
                    handle_alloc_error(Self::layout(new_cap));
                }
                if !self.ptr.is_null() {
                    dealloc(self.ptr as *mut u8, Self::layout(self.cap));
                }
                self.ptr = new_ptr;
                self.cap = new_cap;
            }
            ARENA_ALLOCS.with(|c| c.set(c.get() + 1));
        }
        // SAFETY: ptr is a live allocation of cap ≥ len f64s, zeroed at
        // allocation time (so never uninitialized), exclusively owned.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, len) }
    }
}

impl Drop for ArenaBuf {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            // SAFETY: allocated with exactly this layout.
            unsafe { dealloc(self.ptr as *mut u8, Self::layout(self.cap)) }
        }
    }
}

/// The per-thread arena slots. Co-checkouts that must never share a
/// slot: `PackA` + `PackB` inside one `dgemm`/SYRK; `Strip` (the
/// Cholesky panel copy) across a trailing downdate whose lookahead
/// solves use `Gather`; `Gather` inside a pool job whose core calls
/// `dgemm` (which uses the pack slots).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Slot {
    /// MR-tall A micro-panels (≤ MC×KC f64).
    PackA,
    /// NR-wide B micro-panels (≤ KC×NC f64).
    PackB,
    /// Gather/compute copies: TRSM RHS panels, Cholesky strip copies,
    /// the panel-solve transposed RHS.
    Gather,
    /// The Cholesky solved-panel copy that trailing-downdate jobs read.
    Strip,
}

thread_local! {
    static SLOTS: [Cell<ArenaBuf>; 4] = Default::default();
}

/// Check a slot's buffer out of the thread-local arena. While checked
/// out, a re-take of the same slot sees an empty buffer and would
/// allocate — keep each slot to one live checkout (see [`Slot`]).
pub(crate) fn take(slot: Slot) -> ArenaBuf {
    SLOTS.with(|s| s[slot as usize].take())
}

/// Return a checked-out buffer so the next kernel on this thread reuses
/// its allocation.
pub(crate) fn put(slot: Slot, buf: ArenaBuf) {
    SLOTS.with(|s| s[slot as usize].set(buf));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_grows_monotonically_and_counts() {
        let mut buf = ArenaBuf::default();
        let a0 = allocs();
        assert_eq!(buf.ensure(0).len(), 0);
        assert_eq!(allocs() - a0, 0, "zero-length view must not allocate");
        {
            let s = buf.ensure(100);
            assert_eq!(s.len(), 100);
            assert!(s.iter().all(|&x| x == 0.0), "fresh memory is zeroed");
            s[99] = 7.0;
        }
        assert_eq!(allocs() - a0, 1);
        let cap = buf.capacity();
        assert!(cap >= 100 && cap % (ARENA_ALIGN / 8) == 0);
        assert_eq!(buf.ptr as usize % ARENA_ALIGN, 0, "64-byte aligned");
        // Shrinking and equal-size views reuse the allocation…
        buf.ensure(40);
        buf.ensure(100);
        assert_eq!(allocs() - a0, 1);
        assert_eq!(buf.capacity(), cap);
        // …and stale contents survive (callers overwrite or zero-fill).
        assert_eq!(buf.ensure(100)[99], 7.0);
        // Growth reallocates once, at least doubling.
        buf.ensure(cap + 1);
        assert_eq!(allocs() - a0, 2);
        assert!(buf.capacity() >= 2 * cap);
    }

    #[test]
    fn slots_check_out_and_back_in() {
        let mut buf = take(Slot::Gather);
        buf.ensure(64);
        let cap = buf.capacity();
        put(Slot::Gather, buf);
        let a0 = allocs();
        let mut again = take(Slot::Gather);
        assert_eq!(again.capacity(), cap, "returned buffer is reused");
        again.ensure(64);
        assert_eq!(allocs() - a0, 0, "warm slot must not allocate");
        put(Slot::Gather, again);
    }
}
