//! Thread-local, 64-byte-aligned packing arenas (PR 4; element-typed
//! views since PR 6).
//!
//! Every `dgemm`/SYRK call used to allocate fresh `ap`/`bp` packing
//! panels, and the blocked Cholesky / multi-RHS TRSM allocated panel
//! copies and gather buffers per call — microseconds of allocator
//! traffic on every hot-path invocation, paid again inside every pool
//! job. This module replaces all of them with per-thread arena slots:
//!
//! * each slot holds one [`ArenaBuf`] — a raw 64-byte-aligned
//!   allocation (cache-line / AVX-512-register aligned) that grows
//!   **monotonically** and is reused forever after. The allocation is
//!   untyped underneath; callers size it in **elements** of the type
//!   they need ([`ArenaBuf::ensure`] for `f64`,
//!   [`ArenaBuf::ensure_f32`] for `f32`), so the f64 and f32 kernel
//!   paths (PR 6) share one warm buffer per slot instead of doubling
//!   the retained footprint;
//! * a kernel *checks a slot out* (`take`), sizes it with an
//!   `ensure_*` call, and returns it (`put`) when done — the checkout
//!   pattern keeps nested kernels (a TRSM gather whose core calls
//!   `dgemm`, which needs the pack slots) from aliasing a buffer;
//! * growth is counted in a thread-local counter surfaced as
//!   [`kernel::counters::arena_allocs`](super::kernel::counters::arena_allocs),
//!   which pins the steady-state promise: once warmed, a redamp+solve
//!   iteration performs **zero** pack-buffer allocations
//!   (`rust/tests/session_api.rs` s8).
//!
//! Slots are thread-local, so pool workers each warm their own arenas;
//! [`KernelPool::submit`](super::kernel::KernelPool::submit) deals jobs
//! round-robin from worker 0 on every batch, so a repeated workload
//! lands each job on the same (already-warm) worker. A panic while a
//! slot is checked out drops the buffer (its slot re-warms on next
//! use); nothing leaks and no pointer outlives its allocation.
//!
//! Retained footprint per thread is bounded by the largest shapes seen:
//! the B-pack slot tops out at KC×NC f64 = 8 MiB, the others well
//! below it.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::cell::Cell;

/// Alignment of every arena allocation: one cache line, which is also
/// the AVX-512 register width — packed panels never split a vector
/// load across lines.
pub const ARENA_ALIGN: usize = 64;

thread_local! {
    static ARENA_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Arena (re)allocations performed by the calling thread since start —
/// the growth events of the `ensure_*` calls. Steady-state kernels stop
/// incrementing this once their shapes have been seen.
pub fn allocs() -> u64 {
    ARENA_ALLOCS.with(|c| c.get())
}

/// A 64-byte-aligned, monotonically-grown buffer, viewed as `f64` or
/// `f32` elements per call. Contents are zeroed on (re)allocation and
/// *stale* on reuse — callers either overwrite the whole slice or
/// zero-fill (the packing routines do the latter, which they needed
/// for edge-tile padding anyway). Stale bytes may even be a view of
/// the *other* element type from an earlier checkout; every consumer
/// already treats the contents as garbage until written.
pub struct ArenaBuf {
    ptr: *mut u8,
    /// Capacity in bytes (always a multiple of [`ARENA_ALIGN`]).
    cap: usize,
}

// SAFETY: ArenaBuf owns its allocation exclusively; moving it between
// threads moves ownership of raw memory, which has no thread affinity.
unsafe impl Send for ArenaBuf {}

impl Default for ArenaBuf {
    fn default() -> Self {
        ArenaBuf { ptr: std::ptr::null_mut(), cap: 0 }
    }
}

impl ArenaBuf {
    fn layout(cap_bytes: usize) -> Layout {
        Layout::from_size_align(cap_bytes, ARENA_ALIGN).expect("arena layout")
    }

    /// Current capacity in f64 elements (the coarser of the two views).
    pub fn capacity(&self) -> usize {
        self.cap / std::mem::size_of::<f64>()
    }

    /// Grow the raw allocation to at least `bytes` (doubling, so
    /// repeated mild growth is amortized). Never shrinks.
    fn ensure_bytes(&mut self, bytes: usize) {
        if self.cap >= bytes {
            return;
        }
        let new_cap = bytes.max(self.cap * 2).next_multiple_of(ARENA_ALIGN);
        // SAFETY: layout is non-zero-sized here (bytes ≥ 1); the old
        // pointer (if any) was allocated with Self::layout(old cap).
        unsafe {
            let new_ptr = alloc_zeroed(Self::layout(new_cap));
            if new_ptr.is_null() {
                handle_alloc_error(Self::layout(new_cap));
            }
            if !self.ptr.is_null() {
                dealloc(self.ptr, Self::layout(self.cap));
            }
            self.ptr = new_ptr;
            self.cap = new_cap;
        }
        ARENA_ALLOCS.with(|c| c.set(c.get() + 1));
    }

    /// A `len`-element `f64` view, growing the allocation if needed.
    /// Growth zero-initializes and bumps the thread's arena-allocation
    /// counter.
    pub fn ensure(&mut self, len: usize) -> &mut [f64] {
        if len == 0 {
            return &mut [];
        }
        self.ensure_bytes(len * std::mem::size_of::<f64>());
        // SAFETY: ptr is a live allocation of ≥ len f64s, 64-byte
        // aligned (≥ align_of::<f64>()), zeroed at allocation time (so
        // never uninitialized; any bit pattern is a valid f64),
        // exclusively owned.
        unsafe { std::slice::from_raw_parts_mut(self.ptr as *mut f64, len) }
    }

    /// A `len`-element `f32` view over the same allocation (PR 6 —
    /// the f32 kernel path packs into the same warm slots).
    pub fn ensure_f32(&mut self, len: usize) -> &mut [f32] {
        if len == 0 {
            return &mut [];
        }
        self.ensure_bytes(len * std::mem::size_of::<f32>());
        // SAFETY: as `ensure`, and any bit pattern is a valid f32.
        unsafe { std::slice::from_raw_parts_mut(self.ptr as *mut f32, len) }
    }
}

impl Drop for ArenaBuf {
    fn drop(&mut self) {
        if !self.ptr.is_null() {
            // SAFETY: allocated with exactly this layout.
            unsafe { dealloc(self.ptr, Self::layout(self.cap)) }
        }
    }
}

/// The per-thread arena slots. Co-checkouts that must never share a
/// slot: `PackA` + `PackB` inside one `dgemm`/SYRK; `Strip` (the
/// Cholesky panel copy) across a trailing downdate whose lookahead
/// solves use `Gather`; `Gather` inside a pool job whose core calls
/// `dgemm` (which uses the pack slots).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Slot {
    /// MR-tall A micro-panels (≤ MC×KC elements).
    PackA,
    /// NR-wide B micro-panels (≤ KC×NC elements).
    PackB,
    /// Gather/compute copies: TRSM RHS panels, Cholesky strip copies,
    /// the panel-solve transposed RHS.
    Gather,
    /// The Cholesky solved-panel copy that trailing-downdate jobs read.
    Strip,
}

thread_local! {
    static SLOTS: [Cell<ArenaBuf>; 4] = Default::default();
}

/// Check a slot's buffer out of the thread-local arena. While checked
/// out, a re-take of the same slot sees an empty buffer and would
/// allocate — keep each slot to one live checkout (see [`Slot`]).
pub(crate) fn take(slot: Slot) -> ArenaBuf {
    SLOTS.with(|s| s[slot as usize].take())
}

/// Return a checked-out buffer so the next kernel on this thread reuses
/// its allocation.
pub(crate) fn put(slot: Slot, buf: ArenaBuf) {
    SLOTS.with(|s| s[slot as usize].set(buf));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_grows_monotonically_and_counts() {
        let mut buf = ArenaBuf::default();
        let a0 = allocs();
        assert_eq!(buf.ensure(0).len(), 0);
        assert_eq!(allocs() - a0, 0, "zero-length view must not allocate");
        {
            let s = buf.ensure(100);
            assert_eq!(s.len(), 100);
            assert!(s.iter().all(|&x| x == 0.0), "fresh memory is zeroed");
            s[99] = 7.0;
        }
        assert_eq!(allocs() - a0, 1);
        let cap = buf.capacity();
        assert!(cap >= 100 && (cap * 8) % ARENA_ALIGN == 0);
        assert_eq!(buf.ptr as usize % ARENA_ALIGN, 0, "64-byte aligned");
        // Shrinking and equal-size views reuse the allocation…
        buf.ensure(40);
        buf.ensure(100);
        assert_eq!(allocs() - a0, 1);
        assert_eq!(buf.capacity(), cap);
        // …and stale contents survive (callers overwrite or zero-fill).
        assert_eq!(buf.ensure(100)[99], 7.0);
        // Growth reallocates once, at least doubling.
        buf.ensure(cap + 1);
        assert_eq!(allocs() - a0, 2);
        assert!(buf.capacity() >= 2 * cap);
    }

    #[test]
    fn f32_views_share_the_allocation() {
        let mut buf = ArenaBuf::default();
        let a0 = allocs();
        // 100 f32 = 400 bytes; a following 50-f64 view (400 bytes)
        // must reuse the same allocation.
        assert_eq!(buf.ensure_f32(100).len(), 100);
        assert_eq!(allocs() - a0, 1);
        let cap = buf.capacity();
        buf.ensure(cap);
        assert_eq!(allocs() - a0, 1, "f64 view within capacity must not grow");
        // An f32 view twice as long as the f64 capacity also fits.
        buf.ensure_f32(cap * 2);
        assert_eq!(allocs() - a0, 1);
        // Growing past the byte capacity reallocates once.
        buf.ensure_f32(cap * 2 + 1);
        assert_eq!(allocs() - a0, 2);
    }

    #[test]
    fn slots_check_out_and_back_in() {
        let mut buf = take(Slot::Gather);
        buf.ensure(64);
        let cap = buf.capacity();
        put(Slot::Gather, buf);
        let a0 = allocs();
        let mut again = take(Slot::Gather);
        assert_eq!(again.capacity(), cap, "returned buffer is reused");
        again.ensure(64);
        assert_eq!(allocs() - a0, 0, "warm slot must not allocate");
        put(Slot::Gather, again);
    }
}
