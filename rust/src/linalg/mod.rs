//! Dense linear-algebra substrate, built from scratch.
//!
//! The paper assumes cuBLAS/cuSOLVER under JAX on an A100; this crate's
//! native execution path needs the same primitives on CPU without external
//! dependencies, so they are implemented here:
//!
//! * [`kernel`] — the packed, register-blocked GEMM engine (BLIS-style
//!   MR×NR micro-kernel, KC/MC/NC cache blocking, persistent worker
//!   pool) that every dense hot path below routes through since PR 1.
//! * [`simd`] — the runtime-dispatched ISA tier under the engine
//!   (PR 4): explicit AVX2/AVX-512/NEON `std::arch` micro-kernels and
//!   `dot`/`axpy` primitives, scalar fallback, `DNGD_KERNEL` override.
//! * [`arena`] — thread-local 64-byte-aligned packing arenas (PR 4):
//!   `ap`/`bp` panels, TRSM gathers and Cholesky strip buffers grown
//!   monotonically and reused, so steady-state solves perform zero
//!   pack-buffer allocation.
//! * [`Mat`] — row-major dense `f64` matrix with matrix–vector kernels;
//!   the GEMM/SYRK front-ends live in [`gemm`] on top of the engine.
//! * [`cholesky`] — blocked right-looking Cholesky factorization
//!   (the `potrf` the paper leans on), trailing update on the engine.
//! * [`chol_update`] — O(n²) factor updates for the streaming
//!   subsystem (PR 5): symmetric row/column delete (Givens restoration
//!   of triangularity), bordered append, and the rank-one
//!   circular/hyperbolic update pair.
//! * [`trisolve`] — forward/backward substitution for vectors and blocked
//!   multi-RHS `trsm` (panel updates on the engine), the `L⁻¹S` /
//!   `L⁻ᵀ(·)` of Algorithm 1 line 3–4.
//! * [`eigh`] — cyclic Jacobi symmetric eigensolver (backs the paper's
//!   `"eigh"` SVD baseline, Appendix C).
//! * [`svd`] — one-sided Jacobi SVD (stand-in for CUDA `gesvda`, which is
//!   itself a blocked Jacobi method) and the eigh-based tall-skinny SVD.
//! * [`qr`] — Householder QR, used as an independent test oracle.
//! * [`complex`] — `c64` scalar and [`CMat`] with Hermitian Gram,
//!   complex Cholesky and triangular solves for the SR variants (§3).
//!
//! Since PR 6 the engine carries an **f32 twin** of the Gram→factor→
//! solve chain (`sgemm`, `syrk_f32`, `cholesky_in_place_f32`, f32
//! triangular solves) for the mixed-precision sessions: factor in
//! single precision, then recover f64 accuracy by iterative refinement
//! against the f64 matvec (converges when κ(W)·u₃₂ ≪ 1; the sessions
//! fall back to the f64 path otherwise — see `solver/chol.rs`).

pub mod arena;
pub mod chol_update;
pub mod cholesky;
pub mod complex;
pub mod eigh;
pub mod gemm;
pub mod kernel;
pub mod mat;
pub mod qr;
pub mod simd;
pub mod svd;
pub mod trisolve;

pub use chol_update::{chol_downdate_rank1, chol_update_rank1, UpdatableChol};
pub use cholesky::{
    cholesky, cholesky_in_place, cholesky_in_place_f32, cholesky_in_place_threaded,
    cholesky_threaded, CholeskyError,
};
pub use complex::{c64, CMat};
pub use eigh::eigh;
pub use gemm::{
    gemm, gemm_nt, gemm_nt_threaded, gemm_threaded, gemm_tn, gemm_tn_threaded, syrk, syrk_f32,
    syrk_parallel, syrk_parallel_f32,
};
pub use kernel::KernelConfig;
pub use mat::Mat;
pub use simd::{active_isa, with_isa, KernelIsa};
pub use qr::qr;
pub use svd::{svd_eigh, svd_eigh_threaded, svd_jacobi, ThinSvd};
pub use trisolve::{
    solve_lower, solve_lower_f32, solve_lower_multi, solve_lower_multi_threaded,
    solve_lower_transpose, solve_lower_transpose_f32, solve_lower_transpose_multi,
    solve_lower_transpose_multi_threaded,
};
