//! Blocked right-looking Cholesky factorization — the `Chol(W)` of
//! Algorithm 1 line 2.
//!
//! `W = L·Lᵀ` with `L` lower-triangular. The blocked variant factors an
//! NB×NB diagonal panel unblocked, triangular-solves the panel below it,
//! and applies a symmetric rank-NB downdate to the trailing submatrix —
//! exactly the `potrf` decomposition cuSOLVER runs on the paper's A100.
//! The trailing update is the GEMM-shaped bulk of the O(n³) work, so
//! since PR 1 it runs on the packed kernel engine
//! ([`kernel::dgemm`](super::kernel::dgemm) in NT form over the copied
//! panel) in MC-row strips that cover only the lower triangle, instead
//! of the seed's per-element row dots.

use super::kernel::{self, Trans};
use super::mat::{dot, Mat};

/// Panel width. A multiple of the micro-kernel tile (MR=4, NR=8) so the
/// packed trailing update runs on full tiles; the O(n·NB²) unblocked
/// panel work stays under ~10% of total FLOPs up to n ≈ 4096.
pub const NB: usize = 64;

/// Failure: the matrix was not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
    /// The non-positive diagonal value encountered.
    pub value: f64,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Cholesky breakdown at pivot {}: diagonal {:.3e} ≤ 0 (matrix not positive definite; \
             increase damping λ)",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Cholesky-factor `w` (symmetric positive definite), returning lower `L`.
pub fn cholesky(w: &Mat) -> Result<Mat, CholeskyError> {
    let mut l = w.clone();
    cholesky_in_place(&mut l)?;
    Ok(l)
}

/// In-place blocked Cholesky. On success the lower triangle (incl.
/// diagonal) of `w` holds `L` and the strict upper triangle is zeroed.
pub fn cholesky_in_place(w: &mut Mat) -> Result<(), CholeskyError> {
    let (n, n2) = w.shape();
    assert_eq!(n, n2, "cholesky needs a square matrix");
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + NB).min(n);
        // 1. Unblocked factorization of the diagonal block W[k0..k1, k0..k1].
        factor_diagonal_block(w, k0, k1)?;
        // 2. Panel solve: L[k1.., k0..k1] = W[k1.., k0..k1] · L_d⁻ᵀ
        //    (forward substitution against the rows of the diagonal block).
        for i in k1..n {
            for j in k0..k1 {
                let s = {
                    let ri = w.row(i);
                    let rj = w.row(j);
                    ri[j] - dot(&ri[k0..j], &rj[k0..j])
                };
                w[(i, j)] = s / w[(j, j)];
            }
        }
        // 3. Trailing symmetric downdate on the packed engine:
        //    W[k1.., k1..] -= P·Pᵀ with P = L[k1.., k0..k1], applied in
        //    MC-row strips whose column span stops at the strip's last
        //    row — covers the lower triangle (plus the tiny in-strip
        //    upper wedge, overwritten by the final zeroing) at half the
        //    FLOPs of a full square update.
        if k1 < n {
            let nb = k1 - k0;
            let rows = n - k1;
            let mut panel = vec![0.0; rows * nb];
            for i in k1..n {
                panel[(i - k1) * nb..(i - k1 + 1) * nb].copy_from_slice(&w.row(i)[k0..k1]);
            }
            let wdata = w.as_mut_slice();
            let mut i0 = k1;
            while i0 < n {
                let i1 = (i0 + kernel::MC).min(n);
                let cols = i1 - k1;
                kernel::dgemm(
                    i1 - i0,
                    cols,
                    nb,
                    -1.0,
                    &panel[(i0 - k1) * nb..],
                    nb,
                    Trans::N,
                    &panel,
                    nb,
                    Trans::T,
                    1.0,
                    &mut wdata[i0 * n + k1..],
                    n,
                );
                i0 = i1;
            }
        }
        k0 = k1;
    }
    // Zero the strict upper triangle so the result is exactly L.
    for i in 0..n {
        for j in i + 1..n {
            w[(i, j)] = 0.0;
        }
    }
    Ok(())
}

fn factor_diagonal_block(w: &mut Mat, k0: usize, k1: usize) -> Result<(), CholeskyError> {
    for j in k0..k1 {
        let d = {
            let rj = &w.row(j)[k0..j];
            w[(j, j)] - dot(rj, rj)
        };
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError { pivot: j, value: d });
        }
        let djj = d.sqrt();
        w[(j, j)] = djj;
        for i in j + 1..k1 {
            let s = {
                let ri = w.row(i);
                let rj = w.row(j);
                ri[j] - dot(&ri[k0..j], &rj[k0..j])
            };
            w[(i, j)] = s / djj;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::gemm::{gemm_nt, syrk};

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        // A·Aᵀ + I is SPD for any A.
        let a = Mat::randn(n, n + 3, rng);
        syrk(&a, 1.0)
    }

    #[test]
    fn reconstructs_llt() {
        let mut rng = Rng::seed_from(20);
        for &n in &[1, 2, 5, 17, 48, 49, NB, NB + 1, 100, 131, 2 * NB + 7] {
            let w = spd(n, &mut rng);
            let l = cholesky(&w).unwrap();
            let mut recon = Mat::zeros(n, n);
            gemm_nt(1.0, &l, &l, 0.0, &mut recon);
            let scale = w.max_abs().max(1.0);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - w[(i, j)]).abs() < 1e-9 * scale,
                        "LLᵀ mismatch at n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn lower_triangular_output() {
        let mut rng = Rng::seed_from(21);
        let w = spd(60, &mut rng);
        let l = cholesky(&w).unwrap();
        for i in 0..60 {
            for j in i + 1..60 {
                assert_eq!(l[(i, j)], 0.0);
            }
            assert!(l[(i, i)] > 0.0);
        }
    }

    #[test]
    fn identity_factors_to_identity() {
        let l = cholesky(&Mat::eye(7)).unwrap();
        assert_eq!(l, Mat::eye(7));
    }

    #[test]
    fn rejects_indefinite() {
        let mut w = Mat::eye(3);
        w[(2, 2)] = -1.0;
        let err = cholesky(&w).unwrap_err();
        assert_eq!(err.pivot, 2);
        assert!(err.value <= 0.0);
        assert!(err.to_string().contains("damping"));
    }

    #[test]
    fn rejects_rank_deficient_without_damping() {
        // S with n > rank ⇒ SSᵀ singular ⇒ breakdown at λ=0…
        let mut rng = Rng::seed_from(22);
        let a = Mat::randn(5, 3, &mut rng); // rank ≤ 3 < 5
        let w = syrk(&a, 0.0);
        assert!(cholesky(&w).is_err());
        // …but fine with damping, which is the paper's whole point.
        let wd = syrk(&a, 1e-6);
        assert!(cholesky(&wd).is_ok());
    }

    #[test]
    fn matches_scalar_reference_small() {
        // Hand-checkable 2×2: [[4,2],[2,3]] = [[2,0],[1,√2]]·(·)ᵀ
        let w = Mat::from_vec(2, 2, vec![4., 2., 2., 3.]);
        let l = cholesky(&w).unwrap();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-15);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-15);
        assert!((l[(1, 1)] - 2f64.sqrt()).abs() < 1e-15);
    }
}
