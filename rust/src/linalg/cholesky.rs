//! Blocked right-looking Cholesky factorization — the `Chol(W)` of
//! Algorithm 1 line 2.
//!
//! `W = L·Lᵀ` with `L` lower-triangular. The blocked variant factors an
//! NB×NB diagonal panel unblocked, triangular-solves the panel below it,
//! and applies a symmetric rank-NB downdate to the trailing submatrix —
//! exactly the `potrf` decomposition cuSOLVER runs on the paper's A100.
//! The trailing update is the GEMM-shaped bulk of the O(n³) work, so
//! since PR 1 it runs on the packed kernel engine
//! ([`kernel::dgemm`](super::kernel::dgemm) in NT form over the copied
//! panel) in MC-row strips that cover only the lower triangle, instead
//! of the seed's per-element row dots.
//!
//! Since PR 3 the factorization is threaded end-to-end
//! ([`cholesky_in_place_threaded`]) with a **one-panel lookahead**: the
//! rank-NB downdate of the *next* panel's column slab runs first on the
//! caller, the rest of the trailing downdate is dealt as MC-row-strip
//! jobs to the persistent kernel pool, and while those run the caller
//! factors the next diagonal block and triangular-solves the panel
//! below it — the serial critical path overlaps the previous downdate.
//! The panel solve itself goes through the blocked kernel TRSM core
//! ([`trisolve::fwd_multi_core`](super::trisolve)), so it vectorizes
//! over the panel rows even at `threads: 1` instead of the pre-PR-3
//! per-element scalar dots. Every strip/slab decomposition keeps the
//! rank-NB reduction unsplit and panels are applied in pivot order, so
//! the factor is **bit-identical for every thread count** within a
//! fixed ISA tier (see the determinism notes in
//! [`kernel`](super::kernel)).
//!
//! Since PR 4 every FLOP here is ISA-dispatched (the trailing downdate
//! and panel TRSM through the tiered packed engine, the unblocked
//! diagonal factor through the tiered `dot`), strip jobs re-establish
//! the caller's tier, and the panel copy / gather buffers live in the
//! thread-local [`arena`](super::arena) — a λ-resweep refactor in
//! steady state performs zero pack-buffer allocations.

use super::arena::{self, Slot};
use super::kernel::{self, SendConst, SendMut, Trans};
use super::mat::Mat;
use super::simd::{self, dot_isa};
use super::trisolve::{fwd_multi_core, fwd_multi_core_f32};

/// Panel width. A multiple of the micro-kernel tile (MR=4, NR=8) so the
/// packed trailing update runs on full tiles; the O(n·NB²) unblocked
/// panel work stays under ~10% of total FLOPs up to n ≈ 4096.
pub const NB: usize = 64;

/// Failure: the matrix was not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct CholeskyError {
    /// Pivot index at which the factorization broke down.
    pub pivot: usize,
    /// The non-positive diagonal value encountered.
    pub value: f64,
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Cholesky breakdown at pivot {}: diagonal {:.3e} ≤ 0 (matrix not positive definite; \
             increase damping λ)",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholeskyError {}

/// Cholesky-factor `w` (symmetric positive definite), returning lower `L`.
pub fn cholesky(w: &Mat) -> Result<Mat, CholeskyError> {
    cholesky_threaded(w, 1)
}

/// Like [`cholesky`] but with the trailing downdates dealt across
/// `threads` persistent-pool jobs (bit-identical to serial).
pub fn cholesky_threaded(w: &Mat, threads: usize) -> Result<Mat, CholeskyError> {
    let mut l = w.clone();
    cholesky_in_place_threaded(&mut l, threads)?;
    Ok(l)
}

/// In-place blocked Cholesky. On success the lower triangle (incl.
/// diagonal) of `w` holds `L` and the strict upper triangle is zeroed.
pub fn cholesky_in_place(w: &mut Mat) -> Result<(), CholeskyError> {
    cholesky_in_place_threaded(w, 1)
}

/// In-place blocked Cholesky with a threaded, lookahead-pipelined
/// trailing downdate. `threads = 1` runs everything on the caller; any
/// thread count produces a bit-identical factor (pinned by tests).
///
/// Per panel `[k0, k1)` the schedule is:
///
/// 1. copy the solved panel `P = L[k1.., k0..k1]` out of the matrix;
/// 2. downdate the *next* panel's column slab `W[k1.., k1..k2)` inline
///    (cheap — O((n−k1)·NB²) — and it unblocks the critical path);
/// 3. deal the rest of the downdate (`W[k2.., k2..]`, lower strips of
///    MC rows) round-robin to the kernel pool;
/// 4. while those run, factor the next diagonal block and
///    triangular-solve the next panel (they touch only the slab columns
///    finished in step 2 — disjoint from every in-flight strip);
/// 5. wait for the strips, advance.
pub fn cholesky_in_place_threaded(w: &mut Mat, threads: usize) -> Result<(), CholeskyError> {
    kernel::counters::record_cholesky();
    let (n, n2) = w.shape();
    assert_eq!(n, n2, "cholesky needs a square matrix");
    let threads = threads.max(1);
    if n == 0 {
        return Ok(());
    }
    let mut k1 = NB.min(n);
    factor_diagonal_block(w, 0, k1)?;
    panel_solve(w, 0, k1);
    let mut k0 = 0;
    while k1 < n {
        let k2 = (k1 + NB).min(n);
        let nb = k1 - k0;
        let rows = n - k1;
        // 1. Copy the panel (arena `Strip` slot — reused every panel and
        //    across factorizations): the downdate reads it while step 4
        //    below overwrites neighbouring columns of the same rows.
        let mut panelbuf = arena::take(Slot::Strip);
        let panel = panelbuf.ensure(rows * nb);
        for i in k1..n {
            panel[(i - k1) * nb..(i - k1 + 1) * nb].copy_from_slice(&w.row(i)[k0..k1]);
        }
        let panel: &[f64] = panel;
        // 2. Downdate the next panel's column slab (all trailing rows):
        //    W[k1.., k1..k2) -= P · P[..k2-k1, :]ᵀ. Covers the slab's
        //    upper wedge too — never read, zeroed at the end — which
        //    keeps it one rectangular product.
        kernel::dgemm(
            rows,
            k2 - k1,
            nb,
            -1.0,
            panel,
            nb,
            Trans::N,
            &panel[..(k2 - k1) * nb],
            nb,
            Trans::T,
            1.0,
            &mut w.as_mut_slice()[k1 * n + k1..],
            n,
        );
        // 3. Rest of the trailing downdate, W[k2.., k2..]: MC-row strips
        //    whose column span stops at the strip's last row (covers the
        //    lower triangle plus the tiny in-strip wedge at half the
        //    FLOPs of a square update), dealt round-robin so the
        //    triangular strip loads balance.
        let strips: Vec<(usize, usize)> = {
            let mut v = Vec::new();
            let mut i0 = k2;
            while i0 < n {
                let i1 = (i0 + kernel::MC).min(n);
                v.push((i0, i1));
                i0 = i1;
            }
            v
        };
        let diag;
        if threads > 1 && !strips.is_empty() {
            // One raw pointer serves both the strip jobs and the
            // lookahead work below, so no safe re-borrow of `w` can
            // overlap an in-flight job.
            let wp = w.as_mut_slice().as_mut_ptr();
            let wptr = SendMut(wp);
            let pptr = SendConst(panel.as_ptr());
            let plen = panel.len();
            let isa = simd::active_isa();
            let jobs_n = threads.min(strips.len());
            let mut jobs: Vec<kernel::KernelJob> = Vec::with_capacity(jobs_n);
            for t in 0..jobs_n {
                let mine: Vec<(usize, usize)> = strips
                    .iter()
                    .enumerate()
                    .filter(|(idx, _)| idx % jobs_n == t)
                    .map(|(_, &s)| s)
                    .collect();
                jobs.push(Box::new(move || {
                    // Each strip is gathered into a contiguous buffer
                    // (the worker's arena `Gather` slot — the downdate's
                    // dgemm uses the distinct pack slots), downdated
                    // there, and scattered back, so every reference this
                    // job creates is restricted per row to columns
                    // [k2, i1) — byte-disjoint from the other strips
                    // (different rows) AND from the caller's concurrent
                    // lookahead (columns < k2). A single wide
                    // W[i0.., k2..] slice would wrap around row ends and
                    // alias the lookahead's panel columns, which is UB
                    // even with disjoint writes. The gather/scatter is
                    // O(rows·cols) against the downdate's
                    // O(rows·cols·NB) — noise. Identical per-element
                    // arithmetic (dgemm sums are invariant to the output
                    // leading dimension) on the caller's captured ISA
                    // tier, so this stays bit-identical to the serial
                    // in-place strips.
                    // SAFETY: per-row ranges as argued above; the panel
                    // copy is only read; the guard blocks before
                    // `panel`/`w` go out of scope.
                    kernel::with_isa(isa, || {
                        let p = unsafe { std::slice::from_raw_parts(pptr.0, plen) };
                        let max_len =
                            mine.iter().map(|&(i0, i1)| (i1 - i0) * (i1 - k2)).max().unwrap_or(0);
                        let mut localbuf = arena::take(Slot::Gather);
                        let scratch = localbuf.ensure(max_len);
                        for &(i0, i1) in &mine {
                            let cols = i1 - k2;
                            let rows_s = i1 - i0;
                            let local = &mut scratch[..rows_s * cols];
                            for r in 0..rows_s {
                                let src = unsafe {
                                    std::slice::from_raw_parts(wptr.0.add((i0 + r) * n + k2), cols)
                                };
                                local[r * cols..(r + 1) * cols].copy_from_slice(src);
                            }
                            downdate_strip(p, nb, k1, k2, i0, i1, local, cols);
                            for r in 0..rows_s {
                                let dst = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        wptr.0.add((i0 + r) * n + k2),
                                        cols,
                                    )
                                };
                                dst.copy_from_slice(&local[r * cols..(r + 1) * cols]);
                            }
                        }
                        arena::put(Slot::Gather, localbuf);
                    });
                }));
            }
            let guard = kernel::global_pool().submit(jobs);
            // 4. Lookahead: factor the next diagonal block and solve the
            //    next panel on the caller while the strips run. Both
            //    touch only columns [k1, k2) — finished in step 2,
            //    untouched by any in-flight job.
            // SAFETY: disjointness argued in the job above; the guard
            // (waited or dropped on an unwinding path) pins every job
            // before `panel`/`w` can be released.
            diag = unsafe { factor_diagonal_block_raw(wp, n, k1, k2) };
            if diag.is_ok() {
                unsafe { panel_solve_raw(wp, n, k1, k2) };
            }
            guard.wait();
        } else {
            {
                let wdata = w.as_mut_slice();
                for &(i0, i1) in &strips {
                    downdate_strip(panel, nb, k1, k2, i0, i1, &mut wdata[i0 * n + k2..], n);
                }
            }
            diag = factor_diagonal_block(w, k1, k2);
            if diag.is_ok() {
                panel_solve(w, k1, k2);
            }
        }
        // Return the panel buffer before any early error exit so the
        // next factorization (a λ backoff retry) finds a warm slot.
        arena::put(Slot::Strip, panelbuf);
        diag?;
        k0 = k1;
        k1 = k2;
    }
    // Zero the strict upper triangle so the result is exactly L.
    for i in 0..n {
        for j in i + 1..n {
            w[(i, j)] = 0.0;
        }
    }
    Ok(())
}

/// One MC-row strip of the trailing symmetric downdate:
/// `W[i0..i1, k2..i1) -= P[i0-k1.., :] · P[k2-k1.., :]ᵀ` with `c`
/// pointing at `W[i0][k2]` (leading dimension `ldc`).
fn downdate_strip(
    panel: &[f64],
    nb: usize,
    k1: usize,
    k2: usize,
    i0: usize,
    i1: usize,
    c: &mut [f64],
    ldc: usize,
) {
    kernel::dgemm(
        i1 - i0,
        i1 - k2,
        nb,
        -1.0,
        &panel[(i0 - k1) * nb..],
        nb,
        Trans::N,
        &panel[(k2 - k1) * nb..],
        nb,
        Trans::T,
        1.0,
        c,
        ldc,
    );
}

/// Panel solve below a factored diagonal block:
/// `L[k1.., k0..k1] = W[k1.., k0..k1] · L_d⁻ᵀ`, i.e. the forward solve
/// `L_d · Xᵀ = Bᵀ` run through the blocked kernel-TRSM core on a
/// transposed gather of the panel — vectorized over the panel rows
/// (the RHS columns) instead of the pre-PR-3 per-element scalar dots.
fn panel_solve(w: &mut Mat, k0: usize, k1: usize) {
    let n = w.rows();
    // SAFETY: exclusive access through the &mut borrow; no jobs in
    // flight on this path.
    unsafe { panel_solve_raw(w.as_mut_slice().as_mut_ptr(), n, k0, k1) }
}

/// Raw-pointer core of [`panel_solve`], safe to run while pool jobs
/// write columns ≥ `k1 + NB` of rows ≥ `k1 + NB` (the lookahead): every
/// access here stays inside columns `[k0, k1)` plus the diagonal block.
///
/// # Safety
/// `wp` must point at an n×n row-major buffer; no other thread may
/// concurrently access rows `k0..k1` or columns `[k0, k1)`.
unsafe fn panel_solve_raw(wp: *mut f64, n: usize, k0: usize, k1: usize) {
    if k1 >= n || k1 == k0 {
        return;
    }
    let nb = k1 - k0;
    let rows = n - k1;
    // Gather Bᵀ: bt[j][i] = W[k1+i][k0+j]  (nb × rows, row-major) into
    // the caller thread's arena `Gather` slot (distinct from the
    // `Strip` panel copy held across this call, and from the pack slots
    // the TRSM core's dgemm uses).
    let mut btbuf = arena::take(Slot::Gather);
    let bt = btbuf.ensure(nb * rows);
    for i in 0..rows {
        let wrow = std::slice::from_raw_parts(wp.add((k1 + i) * n + k0), nb);
        for (j, &v) in wrow.iter().enumerate() {
            bt[j * rows + i] = v;
        }
    }
    // The diagonal block as an ldl = n view covering only rows k0..k1
    // (those rows are never touched by trailing-downdate jobs).
    let ld = std::slice::from_raw_parts(wp.add(k0 * n + k0), (nb - 1) * n + nb);
    fwd_multi_core(ld, n, nb, bt, rows);
    // Scatter Xᵀ back into the panel.
    for i in 0..rows {
        let wrow = std::slice::from_raw_parts_mut(wp.add((k1 + i) * n + k0), nb);
        for (j, v) in wrow.iter_mut().enumerate() {
            *v = bt[j * rows + i];
        }
    }
    arena::put(Slot::Gather, btbuf);
}

fn factor_diagonal_block(w: &mut Mat, k0: usize, k1: usize) -> Result<(), CholeskyError> {
    let n = w.rows();
    // SAFETY: exclusive access through the &mut borrow; no jobs in
    // flight on this path.
    unsafe { factor_diagonal_block_raw(w.as_mut_slice().as_mut_ptr(), n, k0, k1) }
}

/// Raw-pointer core of [`factor_diagonal_block`] — unblocked Cholesky of
/// `W[k0..k1, k0..k1]`, touching only that block (reads columns
/// `[k0, j)` of its own rows), so it can overlap trailing-downdate jobs
/// that write columns ≥ `k1`.
///
/// # Safety
/// `wp` must point at an n×n row-major buffer; no other thread may
/// concurrently access the `[k0, k1)²` block.
unsafe fn factor_diagonal_block_raw(
    wp: *mut f64,
    n: usize,
    k0: usize,
    k1: usize,
) -> Result<(), CholeskyError> {
    // One tier for the whole block: the row dots below run on the
    // ISA-dispatched kernel captured here (identical on the caller's
    // lookahead path and the serial path — same thread, same tier).
    let isa = simd::active_isa();
    for j in k0..k1 {
        let d = {
            let rj = std::slice::from_raw_parts(wp.add(j * n + k0), j - k0);
            *wp.add(j * n + j) - dot_isa(isa, rj, rj)
        };
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError { pivot: j, value: d });
        }
        let djj = d.sqrt();
        *wp.add(j * n + j) = djj;
        for i in j + 1..k1 {
            let s = {
                let ri = std::slice::from_raw_parts(wp.add(i * n + k0), j - k0);
                let rj = std::slice::from_raw_parts(wp.add(j * n + k0), j - k0);
                *wp.add(i * n + j) - dot_isa(isa, ri, rj)
            };
            *wp.add(i * n + j) = s / djj;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// f32 factorization (PR 6 — mixed-precision path)
// ---------------------------------------------------------------------------

/// In-place blocked right-looking Cholesky of a row-major n×n f32
/// buffer — the factorization stage of the mixed-precision sessions
/// (`solver.precision = "mixed"`). On success the lower triangle holds
/// `L` in f32 and the strict upper triangle is zeroed.
///
/// Per NB panel: the diagonal block factors unblocked in plain scalar
/// f32 (tier-independent, so the f32 factor is identical across ISA
/// tiers up to the GEMM-shaped stages), the panel below solves through
/// [`fwd_multi_core_f32`], and the trailing downdate runs on
/// [`kernel::sgemm`] in MC-row lower-triangle strips. The routine is
/// serial by design — the f64 refinement downstream re-checks the true
/// residual, the O(n²m) Gram dominates the mixed pipeline and *is*
/// threaded (`ssyrk_parallel`), and a serial factor makes the
/// "f32 threaded ≡ f32 serial bitwise" contract hold trivially here.
///
/// Breakdown (`d ≤ 0` or non-finite — the f32 overflow case) reports
/// the same [`CholeskyError`] as the f64 path; the mixed session treats
/// it as a fallback trigger rather than retrying in f32. Refinement
/// convergence: with κ = κ(λI + SᵀS/m) and f32 unit roundoff u₃₂, each
/// f64 refinement sweep against this factor contracts the error by
/// ≈ κ·u₃₂, so the pipeline reaches f64-grade answers iff κ·u₃₂ ≪ 1.
pub fn cholesky_in_place_f32(w: &mut [f32], n: usize) -> Result<(), CholeskyError> {
    kernel::counters::record_cholesky();
    assert_eq!(w.len(), n * n, "cholesky_in_place_f32 needs a square matrix");
    if n == 0 {
        return Ok(());
    }
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + NB).min(n);
        factor_diagonal_block_f32(w, n, k0, k1)?;
        if k1 < n {
            let nb = k1 - k0;
            let rows = n - k1;
            // Panel solve: L[k1.., k0..k1] = W[k1.., k0..k1] · L_d⁻ᵀ via
            // the forward solve L_d · Xᵀ = Bᵀ on a transposed gather.
            {
                let (head, tail) = w.split_at_mut(k1 * n);
                let mut btbuf = arena::take(Slot::Gather);
                let bt = btbuf.ensure_f32(nb * rows);
                for i in 0..rows {
                    for j in 0..nb {
                        bt[j * rows + i] = tail[i * n + k0 + j];
                    }
                }
                let ld = &head[k0 * n + k0..(k1 - 1) * n + k1];
                fwd_multi_core_f32(ld, n, nb, bt, rows);
                for i in 0..rows {
                    for j in 0..nb {
                        tail[i * n + k0 + j] = bt[j * rows + i];
                    }
                }
                arena::put(Slot::Gather, btbuf);
            }
            // Copy the solved panel, then downdate the trailing lower
            // triangle in MC-row strips whose column span stops at the
            // strip's last row (half the FLOPs of a square update).
            let mut panelbuf = arena::take(Slot::Strip);
            let panel = panelbuf.ensure_f32(rows * nb);
            for i in 0..rows {
                panel[i * nb..(i + 1) * nb]
                    .copy_from_slice(&w[(k1 + i) * n + k0..(k1 + i) * n + k1]);
            }
            let panel: &[f32] = panel;
            let mut i0 = k1;
            while i0 < n {
                let i1 = (i0 + kernel::MC).min(n);
                kernel::sgemm(
                    i1 - i0,
                    i1 - k1,
                    nb,
                    -1.0,
                    &panel[(i0 - k1) * nb..],
                    nb,
                    Trans::N,
                    panel,
                    nb,
                    Trans::T,
                    1.0,
                    &mut w[i0 * n + k1..],
                    n,
                );
                i0 = i1;
            }
            arena::put(Slot::Strip, panelbuf);
        }
        k0 = k1;
    }
    // Zero the strict upper triangle so the result is exactly L.
    for i in 0..n {
        for j in i + 1..n {
            w[i * n + j] = 0.0;
        }
    }
    Ok(())
}

/// Unblocked f32 Cholesky of `W[k0..k1, k0..k1]` — plain scalar f32
/// accumulation (the block is ≤ NB wide, so the O(NB³) work is noise
/// and tier independence keeps the factor reproducible everywhere).
fn factor_diagonal_block_f32(
    w: &mut [f32],
    n: usize,
    k0: usize,
    k1: usize,
) -> Result<(), CholeskyError> {
    for j in k0..k1 {
        let mut s = 0.0f32;
        for p in k0..j {
            let v = w[j * n + p];
            s += v * v;
        }
        let d = w[j * n + j] - s;
        if d <= 0.0 || !d.is_finite() {
            return Err(CholeskyError { pivot: j, value: d as f64 });
        }
        let djj = d.sqrt();
        w[j * n + j] = djj;
        for i in j + 1..k1 {
            let mut s = 0.0f32;
            for p in k0..j {
                s += w[i * n + p] * w[j * n + p];
            }
            w[i * n + j] = (w[i * n + j] - s) / djj;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::gemm::{gemm_nt, syrk};

    fn spd(n: usize, rng: &mut Rng) -> Mat {
        // A·Aᵀ + I is SPD for any A.
        let a = Mat::randn(n, n + 3, rng);
        syrk(&a, 1.0)
    }

    #[test]
    fn reconstructs_llt() {
        let mut rng = Rng::seed_from(20);
        for &n in &[1, 2, 5, 17, 48, 49, NB, NB + 1, 100, 131, 2 * NB + 7] {
            let w = spd(n, &mut rng);
            let l = cholesky(&w).unwrap();
            let mut recon = Mat::zeros(n, n);
            gemm_nt(1.0, &l, &l, 0.0, &mut recon);
            let scale = w.max_abs().max(1.0);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - w[(i, j)]).abs() < 1e-9 * scale,
                        "LLᵀ mismatch at n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn lower_triangular_output() {
        let mut rng = Rng::seed_from(21);
        let w = spd(60, &mut rng);
        let l = cholesky(&w).unwrap();
        for i in 0..60 {
            for j in i + 1..60 {
                assert_eq!(l[(i, j)], 0.0);
            }
            assert!(l[(i, i)] > 0.0);
        }
    }

    #[test]
    fn identity_factors_to_identity() {
        let l = cholesky(&Mat::eye(7)).unwrap();
        assert_eq!(l, Mat::eye(7));
    }

    #[test]
    fn rejects_indefinite() {
        let mut w = Mat::eye(3);
        w[(2, 2)] = -1.0;
        let err = cholesky(&w).unwrap_err();
        assert_eq!(err.pivot, 2);
        assert!(err.value <= 0.0);
        assert!(err.to_string().contains("damping"));
    }

    #[test]
    fn rejects_rank_deficient_without_damping() {
        // S with n > rank ⇒ SSᵀ singular ⇒ breakdown at λ=0…
        let mut rng = Rng::seed_from(22);
        let a = Mat::randn(5, 3, &mut rng); // rank ≤ 3 < 5
        let w = syrk(&a, 0.0);
        assert!(cholesky(&w).is_err());
        // …but fine with damping, which is the paper's whole point.
        let wd = syrk(&a, 1e-6);
        assert!(cholesky(&wd).is_ok());
    }

    #[test]
    fn threaded_breakdown_in_late_panel_is_clean() {
        // Indefiniteness far into the matrix: the lookahead discovers it
        // while downdate jobs for the previous panel are in flight — the
        // guard must drain them and the error must surface with the
        // right pivot, bit-for-bit the same as the serial path reports.
        let mut rng = Rng::seed_from(25);
        let mut w = spd(300, &mut rng);
        let pivot = 233;
        w[(pivot, pivot)] = -1e6;
        let serial = cholesky_threaded(&w, 1).unwrap_err();
        assert_eq!(serial.pivot, pivot);
        for threads in [2usize, 4, 8] {
            let err = cholesky_threaded(&w, threads).unwrap_err();
            assert_eq!(err, serial, "threads={threads}");
        }
    }

    #[test]
    fn threaded_matches_serial_bitwise() {
        let mut rng = Rng::seed_from(26);
        for &n in &[NB - 1, NB + 1, 200, 3 * NB + 5] {
            let w = spd(n, &mut rng);
            let reference = cholesky(&w).unwrap();
            for threads in [2usize, 4, 8] {
                let l = cholesky_threaded(&w, threads).unwrap();
                assert_eq!(l, reference, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn matches_scalar_reference_small() {
        // Hand-checkable 2×2: [[4,2],[2,3]] = [[2,0],[1,√2]]·(·)ᵀ
        let w = Mat::from_vec(2, 2, vec![4., 2., 2., 3.]);
        let l = cholesky(&w).unwrap();
        assert!((l[(0, 0)] - 2.0).abs() < 1e-15);
        assert!((l[(1, 0)] - 1.0).abs() < 1e-15);
        assert!((l[(1, 1)] - 2f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn f32_factor_reconstructs_llt_within_single_precision() {
        let mut rng = Rng::seed_from(27);
        for &n in &[1usize, 5, NB - 1, NB, NB + 1, 2 * NB + 7, 150] {
            let w = spd(n, &mut rng);
            let mut l32: Vec<f32> = w.as_slice().iter().map(|&x| x as f32).collect();
            cholesky_in_place_f32(&mut l32, n).unwrap();
            // Strict upper triangle zeroed, diagonal positive.
            for i in 0..n {
                assert!(l32[i * n + i] > 0.0, "n={n} diag {i}");
                for j in i + 1..n {
                    assert_eq!(l32[i * n + j], 0.0, "n={n} ({i},{j})");
                }
            }
            // LLᵀ ≈ W to f32 tolerance (κ-free check: elementwise).
            let scale = w.max_abs().max(1.0);
            for i in 0..n {
                for j in 0..=i {
                    let mut s = 0.0f64;
                    for p in 0..=j {
                        s += l32[i * n + p] as f64 * l32[j * n + p] as f64;
                    }
                    assert!(
                        (s - w[(i, j)]).abs() < 2e-3 * scale * (n as f64).sqrt(),
                        "f32 LLᵀ mismatch at n={n} ({i},{j}): {s} vs {}",
                        w[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn f32_factor_rejects_indefinite_and_non_finite() {
        // Indefinite: same breakdown semantics as the f64 path.
        let mut w = vec![0.0f32; 9];
        for i in 0..3 {
            w[i * 3 + i] = 1.0;
        }
        w[8] = -1.0;
        let err = cholesky_in_place_f32(&mut w, 3).unwrap_err();
        assert_eq!(err.pivot, 2);
        assert!(err.value <= 0.0);
        // An f32 overflow in the Gram (infinite diagonal) is a breakdown,
        // not a garbage factor — the mixed session's fallback trigger.
        let mut w = vec![0.0f32; 4];
        w[0] = 1.0;
        w[3] = f32::INFINITY;
        assert!(cholesky_in_place_f32(&mut w, 2).is_err());
    }
}
