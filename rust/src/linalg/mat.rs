//! Row-major dense `f64` matrix.
//!
//! `Mat` is deliberately simple: a `Vec<f64>` plus `(rows, cols)`. All the
//! performance-sensitive kernels (GEMM, SYRK, triangular solves) live in
//! sibling modules and operate on raw row slices; `Mat` provides safe
//! construction, indexing, views and the handful of whole-matrix helpers
//! the solvers need.

use crate::data::rng::Rng;
use std::fmt;

/// Row-major dense matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Mat {
    /// All-zeros `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { data, rows, cols }
    }

    /// Take ownership of a row-major buffer. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length {} != {rows}x{cols}", data.len());
        Mat { data, rows, cols }
    }

    /// Standard-normal random matrix (used for benchmark workloads; the
    /// paper benchmarks on random score matrices of the same shape).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal());
        }
        Mat { data, rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Two disjoint mutable rows (needed by in-place factorizations).
    #[inline]
    pub fn rows_mut2(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(i, j);
        let c = self.cols;
        if i < j {
            let (a, b) = self.data.split_at_mut(j * c);
            (&mut a[i * c..i * c + c], &mut b[..c])
        } else {
            let (a, b) = self.data.split_at_mut(i * c);
            (&mut b[..c], &mut a[j * c..j * c + c])
        }
    }

    /// Full backing slice (row-major).
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Explicit transpose (copies). The hot paths never materialize
    /// transposes — they use the `gemm_tn`/`gemm_nt` kernels — but tests
    /// and oracles do.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `y = A x` (rows-many dot products).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
        y
    }

    /// `y = Aᵀ x` without materializing `Aᵀ` — [`axpy`] accumulation
    /// over rows (ISA-dispatched since PR 4). This is the `Sᵀu` of
    /// Algorithm 1 line 4 and is memory-bound, so it streams each row
    /// exactly once.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// `out = A x` into caller storage — the allocation-free variant the
    /// session solve path uses on every right-hand side.
    pub fn matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// `out = Aᵀ x` into caller storage (allocation-free
    /// [`Mat::t_matvec`]) — one ISA-dispatched [`axpy`] per row.
    pub fn t_matvec_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            axpy(xi, self.row(i), out);
        }
    }

    /// Column `j` copied out (the substrate is row-major; columns are
    /// strided so this is for tests/oracles only).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |a, &x| a.max(x.abs()))
    }

    /// `self += alpha * other` (same shape) — one ISA-dispatched
    /// [`axpy`] over the whole backing buffer.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        axpy(alpha, &other.data, &mut self.data);
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f64) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Add `lambda` to the diagonal (the damping `+ λĨ` of Algorithm 1
    /// line 1).
    pub fn add_diag(&mut self, lambda: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += lambda;
        }
    }

    /// Horizontal slice `rows [r0, r1)` copied into a new matrix — used by
    /// the coordinator to cut sample-axis shards.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat {
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
            rows: r1 - r0,
            cols: self.cols,
        }
    }

    /// Vertical slice `cols [c0, c1)` copied into a new matrix — used by
    /// the coordinator to cut parameter-axis (m) shards of S.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Stack two matrices vertically (sample-axis concat — the real-part
    /// SR trick `S ← Concat[ℜS, ℑS]` from §3 lands here).
    pub fn vstack(top: &Mat, bottom: &Mat) -> Mat {
        assert_eq!(top.cols, bottom.cols);
        let mut data = Vec::with_capacity((top.rows + bottom.rows) * top.cols);
        data.extend_from_slice(&top.data);
        data.extend_from_slice(&bottom.data);
        Mat { data, rows: top.rows + bottom.rows, cols: top.cols }
    }

    /// Stack two matrices horizontally (parameter-axis concat — used by the
    /// coordinator to reassemble m-shards).
    pub fn hstack(left: &Mat, right: &Mat) -> Mat {
        assert_eq!(left.rows, right.rows);
        let mut out = Mat::zeros(left.rows, left.cols + right.cols);
        for i in 0..left.rows {
            out.row_mut(i)[..left.cols].copy_from_slice(left.row(i));
            out.row_mut(i)[left.cols..].copy_from_slice(right.row(i));
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(8);
        let show_c = self.cols.min(8);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Dot product on the active [`KernelIsa`](super::simd::KernelIsa)
/// tier (PR 4): explicit AVX2/AVX-512/NEON FMA kernels with multiple
/// independent accumulators to hide the FMA latency chain, falling back
/// to the seed's 16-way-unrolled scalar loop on the scalar tier. This
/// is the CG solver's and the unblocked Cholesky panel's inner kernel.
/// The result is a pure function of `(a, b, tier)` — see the
/// determinism notes in [`simd`](super::simd).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    super::simd::dot_isa(super::simd::active_isa(), a, b)
}

/// `y += alpha * x` on the active ISA tier — unrolled/vectorized like
/// [`dot`] (PR 4; it was a plain element loop despite backing the CG
/// update and the forward/backward substitution sweeps).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    super::simd::axpy_isa(super::simd::active_isa(), alpha, x, y);
}

/// Euclidean norm of a vector.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_from_fn() {
        let z = Mat::zeros(3, 4);
        assert_eq!(z.shape(), (3, 4));
        assert!(z.as_slice().iter().all(|&x| x == 0.0));
        let e = Mat::eye(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(e[(i, j)], if i == j { 1.0 } else { 0.0 });
            }
        }
        let f = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(f[(1, 2)], 12.0);
        assert_eq!(f.row(1), &[10.0, 11.0, 12.0]);
    }

    #[test]
    fn matvec_against_hand_computed() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 1., 1.]), vec![6., 15.]);
        assert_eq!(a.t_matvec(&[1., 2.]), vec![9., 12., 15.]);
    }

    #[test]
    fn t_matvec_matches_transpose_matvec() {
        let mut rng = Rng::seed_from(1);
        let a = Mat::randn(7, 13, &mut rng);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let direct = a.t_matvec(&x);
        let via_t = a.transpose().matvec(&x);
        for (d, v) in direct.iter().zip(&via_t) {
            assert!((d - v).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed_from(2);
        let a = Mat::randn(5, 9, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slicing_and_stacking_roundtrip() {
        let mut rng = Rng::seed_from(3);
        let a = Mat::randn(6, 10, &mut rng);
        let top = a.slice_rows(0, 2);
        let bot = a.slice_rows(2, 6);
        assert_eq!(Mat::vstack(&top, &bot), a);
        let l = a.slice_cols(0, 3);
        let r = a.slice_cols(3, 10);
        assert_eq!(Mat::hstack(&l, &r), a);
    }

    #[test]
    fn add_diag_only_touches_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(2.5);
        assert_eq!(a[(0, 0)], 2.5);
        assert_eq!(a[(1, 1)], 2.5);
        assert_eq!(a[(0, 1)], 0.0);
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..9 {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
            let expect: f64 = (0..n).map(|i| (i * (i + 1)) as f64).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }

    #[test]
    fn rows_mut2_disjoint_both_orders() {
        let mut a = Mat::from_fn(4, 2, |i, _| i as f64);
        {
            let (r1, r3) = a.rows_mut2(1, 3);
            r1[0] = -1.0;
            r3[0] = -3.0;
        }
        assert_eq!(a[(1, 0)], -1.0);
        assert_eq!(a[(3, 0)], -3.0);
        {
            let (r3, r0) = a.rows_mut2(3, 0);
            r3[1] = 30.0;
            r0[1] = 0.5;
        }
        assert_eq!(a[(3, 1)], 30.0);
        assert_eq!(a[(0, 1)], 0.5);
    }

    #[test]
    fn fro_and_max_norms() {
        let a = Mat::from_vec(2, 2, vec![3., 0., 0., -4.]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-15);
        assert_eq!(a.max_abs(), 4.0);
    }
}
