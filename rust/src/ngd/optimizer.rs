//! The damped natural-gradient optimizer.

use super::DampingSchedule;
use crate::linalg::mat::norm2;
use crate::linalg::Mat;
use crate::serve::SessionRecord;
use crate::solver::{solve_with_backoff, DampedSolver, Factorization, SolveError};

/// Snapshot cadence handed to the optimizer's durable window records:
/// never auto-refresh. The record's snapshot must coincide exactly with
/// the streaming session's own cold points (open/`refresh()`), so the
/// optimizer rotates the snapshot explicitly instead of letting the
/// record pick its own cadence. `u32::MAX` (not `usize::MAX`) because
/// the cadence rides through the checkpoint's f64 tensor encoding and
/// must round-trip exactly.
const RECORD_NEVER: usize = u32::MAX as usize;

/// Damped NGD/SR optimizer state.
///
/// Each step solves `(SᵀS + λI) x = ∇L` with the configured solver and
/// applies `θ ← θ − η·(x + μ·momentum)`, optionally clipping `x` to a
/// trust-region radius in natural-gradient norm.
pub struct NaturalGradient {
    pub solver: Box<dyn DampedSolver>,
    pub damping: DampingSchedule,
    pub learning_rate: f64,
    /// Momentum coefficient μ (0 disables).
    pub momentum: f64,
    /// Max ‖update‖₂ (None disables clipping).
    pub trust_radius: Option<f64>,
    velocity: Vec<f64>,
    last_loss: Option<f64>,
    steps: usize,
    /// Cholesky retry policy: on `NotPositiveDefinite`, multiply λ by 10
    /// and retry up to this many times (damping is the fix the error
    /// message recommends; the optimizer automates it). Since PR 2 the
    /// retry re-damps the cached session factorization, so each backoff
    /// costs O(n³) instead of repeating the O(n²m) Gram product. In
    /// sliding-window mode the backoff re-damps the **streaming**
    /// session's patched Gram — a breakdown mid-rotation never repeats
    /// the window's Gram either.
    pub pd_retries: usize,
    /// Sliding-window streaming state (PR 5); `None` = classic
    /// per-batch Fisher.
    window: Option<WindowState>,
}

/// State of the sliding-window streaming mode ([`NaturalGradient::with_window`]).
struct WindowState {
    /// Window size W (sample rows in the streamed Fisher).
    size: usize,
    /// Rotations between full refactors (0 = never) — the drift
    /// backstop for the O(n²) factor rotations.
    refresh_every: usize,
    /// Rotations since the last full factor.
    rotations: usize,
    /// Fill-phase accumulator, and the live window in fallback mode
    /// (rows pre-scaled to the window's 1/√W convention). Emptied once
    /// a native owned-window session takes ownership.
    window: Mat,
    /// Owned-window streaming session (`None` while filling, or
    /// permanently in fallback mode).
    fact: Option<Box<dyn Factorization>>,
    /// The solver kind has no owned-window session: rebuild a cold
    /// session on the rotated window every step (the refactor
    /// fallback).
    fallback: bool,
    /// Bit-exact mirror of the native session's owned window,
    /// maintained by the same copy-only row moves the session applies.
    /// Feeds the durable record's snapshot at cold points. Empty until
    /// a native session opens.
    live: Mat,
    /// Durable image of the native session (PR-8 snapshot+rotation-log
    /// machinery): snapshot at the last cold point, rotations since.
    /// `None` until a native session opens.
    record: Option<SessionRecord>,
    /// λ the session held when its last `refresh()` re-damped it
    /// (`None` when the cold point is the session open — a fresh
    /// session starts at λ = 0).
    cold_refresh_lambda: Option<f64>,
    /// λ-backoff retries of the cold-point solve (the solve issued
    /// before any rotation was logged).
    cold_retries: usize,
    /// Per logged rotation, `(λ_first, retries)` of that step's solve:
    /// the schedule's λ and how many ×10 backoffs the solve needed.
    /// Resume replays the identical redamp sequence — a rotated factor
    /// differs bitwise from a refactored one, so landing on the same
    /// bits requires re-issuing the same rotate/redamp interleaving,
    /// failures included. Invariant: `redamps.len() == record.log().len()`
    /// after every completed step.
    redamps: Vec<(f64, usize)>,
    /// Whether the current native session has ever rotated. A
    /// mixed-precision session latches f64 on its first rotation;
    /// replay must reproduce the latch before re-damping (see
    /// [`NaturalGradient::restore_state`]).
    ever_rotated: bool,
}

impl WindowState {
    /// Record a completed solve's `(λ_first, retries)` against the
    /// durable log. The solve at a cold point (empty rotation log)
    /// re-seats the record's base λ; each later solve appends one entry
    /// per logged rotation.
    fn note_solve(&mut self, lambda_first: f64, retries: usize) {
        let Some(rec) = self.record.as_mut() else { return };
        if rec.log().is_empty() {
            rec.set_lambda(lambda_first);
            self.cold_retries = retries;
        } else if self.redamps.len() < rec.log().len() {
            self.redamps.push((lambda_first, retries));
        } else {
            // Re-solve on an unchanged rotation state (unreachable from
            // the one-solve-per-rotation step loop, but harmless): the
            // last redamp decides the factor, so overwrite.
            *self.redamps.last_mut().expect("non-empty by invariant") = (lambda_first, retries);
        }
    }
}

/// Per-step diagnostics.
#[derive(Debug, Clone)]
pub struct NgdReport {
    pub step: usize,
    pub lambda: f64,
    pub grad_norm: f64,
    pub nat_grad_norm: f64,
    pub update_norm: f64,
    pub clipped: bool,
    pub pd_retries_used: usize,
    /// Rows held by the streamed Fisher window after this step
    /// (0 = classic per-batch mode; ramps up while the window fills,
    /// during which the solve still runs on the batch alone).
    pub window_rows: usize,
}

impl NaturalGradient {
    pub fn new(
        solver: Box<dyn DampedSolver>,
        damping: DampingSchedule,
        learning_rate: f64,
    ) -> Self {
        NaturalGradient {
            solver,
            damping,
            learning_rate,
            momentum: 0.0,
            trust_radius: None,
            velocity: Vec::new(),
            last_loss: None,
            steps: 0,
            pd_retries: 3,
            window: None,
        }
    }

    pub fn with_momentum(mut self, mu: f64) -> Self {
        self.momentum = mu;
        self
    }

    pub fn with_trust_radius(mut self, r: f64) -> Self {
        self.trust_radius = Some(r);
        self
    }

    /// Enable sliding-window streaming NGD (PR 5): the Fisher is built
    /// from the last `window` score rows instead of the current batch
    /// alone, and each step rotates the batch through the window —
    /// O(knm + kn²) on the chol/rvb owned-window sessions (zero
    /// full-Gram SYRKs, pinned by tests) versus the O(n²m + n³) cold
    /// factor. Until the window fills, steps run the classic per-batch
    /// path (warm-up). `refresh_every` rotations trigger a full
    /// refactor of the live window (0 = never) — the drift backstop.
    /// Solver kinds without an owned-window session transparently fall
    /// back to a cold refactor of the rotated window per step.
    /// `window = 0` disables.
    pub fn with_window(mut self, window: usize, refresh_every: usize) -> Self {
        assert_ne!(window, 1, "a one-row window has no overlap to amortize");
        self.window = (window > 0).then(|| WindowState {
            size: window,
            refresh_every,
            rotations: 0,
            window: Mat::zeros(0, 0),
            fact: None,
            fallback: false,
            live: Mat::zeros(0, 0),
            record: None,
            cold_refresh_lambda: None,
            cold_retries: 0,
            redamps: Vec::new(),
            ever_rotated: false,
        });
        self
    }

    /// Rows currently held by the streaming window (0 when streaming is
    /// off; ramps up during fill, then stays at the window size).
    pub fn window_rows(&self) -> usize {
        self.window
            .as_ref()
            .map(|ws| {
                if ws.fact.is_some() {
                    ws.size
                } else {
                    ws.window.rows()
                }
            })
            .unwrap_or(0)
    }

    /// The sliding-window solve for one step: ingest the batch, rotate
    /// the streaming session (or rebuild the fallback window), apply
    /// the drift backstop, then solve with the λ backoff. Returns
    /// `(x, λ_used, retries, window_rows)`.
    fn step_windowed(
        &mut self,
        scores: &Mat,
        grad: &[f64],
        lambda: f64,
    ) -> Result<(Vec<f64>, f64, usize, usize), SolveError> {
        let ws = self.window.as_mut().expect("streaming mode is on");
        let (b, m) = scores.shape();
        let w = ws.size;
        // Incoming rows arrive 1/√b-scaled (the paper's convention for
        // a b-row batch); the W-row window Fisher wants 1/√W.
        let mut incoming = scores.clone();
        incoming.scale((b as f64).sqrt() / (w as f64).sqrt());

        if let Some(fact) = ws.fact.as_mut() {
            // Steady state: rotate the oldest k rows out, the batch in.
            let k = b.min(w);
            let added = if b <= w { incoming } else { incoming.slice_rows(b - w, b) };
            let removed: Vec<usize> = (0..k).collect();
            match fact.update_rows(&removed, &added) {
                Ok(()) => {}
                // The rotation's own refactor backstop broke down at
                // the current λ: the window/Gram are already rotated,
                // so the λ backoff below rescues the step in O(n³).
                Err(SolveError::NotPositiveDefinite(_)) => {}
                Err(e) => return Err(e),
            }
            // Mirror the rotation (kept rows keep their order, the
            // batch appends — the session's own layout) and log it in
            // the durable record. Copy-only moves, so the mirror stays
            // bit-exact against the session's owned window.
            ws.live = if k >= ws.live.rows() {
                added.clone()
            } else {
                Mat::vstack(&ws.live.slice_rows(k, ws.live.rows()), &added)
            };
            if let Some(rec) = ws.record.as_mut() {
                rec.record_rotation(&removed, &added, &ws.live);
            }
            ws.ever_rotated = true;
            ws.rotations += 1;
            if ws.refresh_every > 0 && ws.rotations >= ws.refresh_every {
                // Cold point: the session rebuilds its Gram+factor from
                // the live window, keeping its current λ. Restart the
                // durable record here — the λ the session carried into
                // the refresh is part of the replay (the refreshed
                // factor is a cold refactor *at that λ*).
                let lambda_at_refresh = fact.lambda();
                match fact.refresh() {
                    Ok(()) | Err(SolveError::NotPositiveDefinite(_)) => {}
                    Err(e) => return Err(e),
                }
                ws.rotations = 0;
                ws.record = Some(SessionRecord::new(&ws.live, 0.0, RECORD_NEVER));
                ws.cold_refresh_lambda = Some(lambda_at_refresh);
                ws.cold_retries = 0;
                ws.redamps.clear();
            }
            let (x, l, r) = solve_with_backoff(fact.as_mut(), grad, lambda, self.pd_retries)?;
            ws.note_solve(lambda, r);
            return Ok((x, l, r, w));
        }

        if ws.fallback {
            // No owned-window session for this kind: slide the window
            // here and refactor cold every step.
            ws.window = Mat::vstack(&ws.window, &incoming);
            let rows = ws.window.rows();
            ws.window = ws.window.slice_rows(rows - w, rows);
            let mut fact = self.solver.begin(&ws.window);
            let (x, l, r) = solve_with_backoff(fact.as_mut(), grad, lambda, self.pd_retries)?;
            return Ok((x, l, r, w));
        }

        // Fill phase: accumulate until W rows, then open the session.
        ws.window = if ws.window.rows() == 0 {
            incoming
        } else {
            Mat::vstack(&ws.window, &incoming)
        };
        if ws.window.rows() >= w {
            let rows = ws.window.rows();
            let full = ws.window.slice_rows(rows - w, rows);
            match self.solver.begin_window(full.clone()) {
                Some(fact) => {
                    ws.fact = Some(fact);
                    // The session owns the window now; free the copy.
                    ws.window = Mat::zeros(0, m);
                    // Session open = the first cold point: keep the
                    // bit-exact mirror and start the durable record.
                    ws.live = full;
                    ws.record = Some(SessionRecord::new(&ws.live, 0.0, RECORD_NEVER));
                    ws.cold_refresh_lambda = None;
                    ws.cold_retries = 0;
                    ws.redamps.clear();
                    ws.ever_rotated = false;
                    let fact = ws.fact.as_mut().unwrap();
                    let (x, l, r) =
                        solve_with_backoff(fact.as_mut(), grad, lambda, self.pd_retries)?;
                    ws.note_solve(lambda, r);
                    return Ok((x, l, r, w));
                }
                None => {
                    ws.fallback = true;
                    let rows = ws.window.rows();
                    ws.window = ws.window.slice_rows(rows - w, rows);
                    let mut fact = self.solver.begin(&ws.window);
                    let (x, l, r) =
                        solve_with_backoff(fact.as_mut(), grad, lambda, self.pd_retries)?;
                    return Ok((x, l, r, w));
                }
            }
        }
        // Window still filling: classic per-batch solve (warm-up).
        let filled = ws.window.rows();
        let mut fact = self.solver.begin(scores);
        let (x, l, r) = solve_with_backoff(fact.as_mut(), grad, lambda, self.pd_retries)?;
        Ok((x, l, r, filled.min(w)))
    }

    /// One optimization step.
    ///
    /// * `params` — flat parameter vector, updated in place.
    /// * `scores` — the n×m score matrix S for the current batch
    ///   (already 1/√n-scaled, per the paper's definition).
    /// * `grad` — loss gradient v (length m).
    /// * `loss` — current batch loss, drives the LM damping policy.
    pub fn step(
        &mut self,
        params: &mut [f64],
        scores: &Mat,
        grad: &[f64],
        loss: f64,
    ) -> Result<NgdReport, SolveError> {
        assert_eq!(params.len(), grad.len());
        assert_eq!(scores.cols(), params.len());

        let improved = self.last_loss.map(|prev| loss < prev).unwrap_or(true);
        self.damping.advance(improved);
        self.last_loss = Some(loss);

        // Session path: the λ-independent state (Gram/SVD) is staged
        // once; PD backoff re-damps it in place. Sliding-window mode
        // (PR 5) instead rotates the batch through a persistent
        // streaming session — O(knm + kn²) per step once warm.
        let (x, lambda, retries, window_rows) = if self.window.is_some() {
            self.step_windowed(scores, grad, self.damping.lambda())?
        } else {
            let mut fact = self.solver.begin(scores);
            let (x, lambda, retries) =
                solve_with_backoff(fact.as_mut(), grad, self.damping.lambda(), self.pd_retries)?;
            (x, lambda, retries, 0)
        };

        let nat_grad_norm = norm2(&x);
        // Trust region: scale the natural gradient down to the radius.
        let (x, clipped) = match self.trust_radius {
            Some(r) if nat_grad_norm > r => {
                let scale = r / nat_grad_norm;
                (x.iter().map(|v| v * scale).collect::<Vec<_>>(), true)
            }
            _ => (x, false),
        };

        // Momentum buffer.
        if self.velocity.len() != params.len() {
            self.velocity = vec![0.0; params.len()];
        }
        let mu = self.momentum;
        let mut update_sq = 0.0;
        for j in 0..params.len() {
            self.velocity[j] = mu * self.velocity[j] + x[j];
            let u = self.learning_rate * self.velocity[j];
            params[j] -= u;
            update_sq += u * u;
        }

        self.steps += 1;
        Ok(NgdReport {
            step: self.steps,
            lambda,
            grad_norm: norm2(grad),
            nat_grad_norm,
            update_norm: update_sq.sqrt(),
            clipped,
            pd_retries_used: retries,
            window_rows,
        })
    }

    /// Snapshot everything the optimizer evolves across steps that is
    /// not derivable from config — the checkpointable state (PR 9).
    /// Cheap relative to a step: clones of the velocity and (in
    /// streaming mode) the window snapshot + rotation log.
    pub fn export_state(&self) -> NgdState {
        NgdState {
            velocity: self.velocity.clone(),
            last_loss: self.last_loss,
            steps: self.steps,
            lambda: self.damping.state(),
            window: self.window.as_ref().map(|ws| WindowLog {
                fill: ws.window.clone(),
                fallback: ws.fallback,
                rotations: ws.rotations,
                session: ws.fact.is_some().then(|| SessionLog {
                    record: ws.record.clone().expect("open session always has a record"),
                    cold_refresh_lambda: ws.cold_refresh_lambda,
                    cold_retries: ws.cold_retries,
                    redamps: ws.redamps.clone(),
                    ever_rotated: ws.ever_rotated,
                }),
            }),
        }
    }

    /// Rebuild the optimizer at a checkpointed state so the resumed
    /// trajectory is **bit-identical** to the unfailed run.
    ///
    /// The scalar state (velocity/loss/steps/λ) restores directly. The
    /// streaming session cannot be serialized — it holds a live factor
    /// whose bits depend on the exact rotate/redamp history — so it is
    /// *replayed*: reopen the session on the recorded cold-point
    /// snapshot, then re-issue the identical sequence of operations the
    /// live run performed since that cold point (first-rotation
    /// mixed-precision latch, refresh-λ redamp, the cold solve's λ
    /// backoff chain, then each logged rotation followed by its solve's
    /// backoff chain). Every arithmetic input matches the live run's,
    /// so every output bit does too.
    pub fn restore_state(&mut self, st: NgdState) -> Result<(), SolveError> {
        self.velocity = st.velocity;
        self.last_loss = st.last_loss;
        self.steps = st.steps;
        self.damping.restore(st.lambda);
        match (self.window.as_mut(), st.window) {
            (None, None) => Ok(()),
            (Some(_), None) | (None, Some(_)) => Err(SolveError::BadInput(
                "checkpoint streaming-window state does not match the configured solver.window"
                    .into(),
            )),
            (Some(ws), Some(wl)) => {
                ws.window = wl.fill;
                ws.fallback = wl.fallback;
                ws.rotations = wl.rotations;
                ws.fact = None;
                ws.live = Mat::zeros(0, 0);
                ws.record = None;
                ws.cold_refresh_lambda = None;
                ws.cold_retries = 0;
                ws.redamps.clear();
                ws.ever_rotated = false;
                let Some(sl) = wl.session else {
                    // Fill phase or fallback mode: the window matrix is
                    // the whole state.
                    return Ok(());
                };
                if sl.redamps.len() != sl.record.log().len() {
                    return Err(SolveError::BadInput(format!(
                        "corrupt window log: {} rotations but {} redamp entries",
                        sl.record.log().len(),
                        sl.redamps.len()
                    )));
                }
                let snapshot = sl.record.snapshot().clone();
                let mcols = snapshot.cols();
                let mut fact = self.solver.begin_window(snapshot).ok_or_else(|| {
                    SolveError::BadInput(
                        "checkpoint carries a streaming session but the configured solver \
                         kind has no owned-window session"
                            .into(),
                    )
                })?;
                // A mixed-precision session latches f64 on its *first*
                // rotation (and builds its cold f64 Gram there). Replay
                // the latch before any redamp via an empty rotation —
                // an exact-copy no-op on every other configuration — so
                // the replayed redamps take the same arithmetic path
                // the live session's did.
                if sl.ever_rotated {
                    match fact.update_rows(&[], &Mat::zeros(0, mcols)) {
                        Ok(()) | Err(SolveError::NotPositiveDefinite(_)) => {}
                        Err(e) => return Err(e),
                    }
                }
                // If the cold point was a refresh(), the session was
                // re-damped at the λ it carried into the refresh before
                // the step's own solve re-damped it again.
                if let Some(lc) = sl.cold_refresh_lambda {
                    match fact.redamp(lc) {
                        Ok(()) | Err(SolveError::NotPositiveDefinite(_)) => {}
                        Err(e) => return Err(e),
                    }
                }
                replay_redamps(fact.as_mut(), sl.record.lambda(), sl.cold_retries)?;
                // Rebuild λ-independent per-session solve state: rvb
                // freezes its recovery ridge (and factors its recovery
                // Gram) lazily at the first solve after a cold point. A
                // zero-RHS solve is structurally valid for every kind
                // (0 = Sᵀ·0 is in the row space) and leaves the f64
                // factor state untouched.
                let mut scratch = vec![0.0; mcols];
                fact.solve_into(&vec![0.0; mcols], &mut scratch)?;
                for (i, entry) in sl.record.log().iter().enumerate() {
                    match fact.update_rows(&entry.removed, &entry.added) {
                        Ok(()) | Err(SolveError::NotPositiveDefinite(_)) => {}
                        Err(e) => return Err(e),
                    }
                    let (lf, r) = sl.redamps[i];
                    replay_redamps(fact.as_mut(), lf, r)?;
                }
                ws.live = sl
                    .record
                    .materialize_window()
                    .map_err(|e| SolveError::BadInput(format!("window record replay: {e}")))?;
                ws.fact = Some(fact);
                ws.record = Some(sl.record);
                ws.cold_refresh_lambda = sl.cold_refresh_lambda;
                ws.cold_retries = sl.cold_retries;
                ws.redamps = sl.redamps;
                ws.ever_rotated = sl.ever_rotated;
                Ok(())
            }
        }
    }
}

/// Re-issue a recorded solve's λ-backoff sequence: the redamps that
/// failed live fail identically here (each deterministically clears the
/// factor slot), and the final one seats the factor the live run ended
/// the step with. Mirrors `solve_with_backoff`'s ×10 progression.
fn replay_redamps(
    fact: &mut dyn Factorization,
    lambda_first: f64,
    retries: usize,
) -> Result<(), SolveError> {
    let mut l = lambda_first;
    for _ in 0..retries {
        let _ = fact.redamp(l); // failed live; fails identically here
        l *= 10.0;
    }
    fact.redamp(l)
}

/// Checkpointable optimizer state ([`NaturalGradient::export_state`]) —
/// everything the optimizer evolves across steps that is not derivable
/// from config.
#[derive(Debug, Clone)]
pub struct NgdState {
    /// Momentum buffer (empty before the first step).
    pub velocity: Vec<f64>,
    /// Previous batch loss (drives the LM damping policy).
    pub last_loss: Option<f64>,
    /// Steps taken.
    pub steps: usize,
    /// The damping schedule's evolving scalar
    /// ([`DampingSchedule::state`]).
    pub lambda: f64,
    /// Streaming-window state; `None` in classic per-batch mode.
    pub window: Option<WindowLog>,
}

/// Durable image of the sliding-window streaming state.
#[derive(Debug, Clone)]
pub struct WindowLog {
    /// Fill-phase accumulator / fallback-mode live window.
    pub fill: Mat,
    /// Fallback mode latched (solver kind has no owned-window session).
    pub fallback: bool,
    /// Rotations since the last full refactor (drift-backstop counter).
    pub rotations: usize,
    /// Open native session, as a replayable log; `None` while filling
    /// or in fallback mode.
    pub session: Option<SessionLog>,
}

/// Replayable image of a native owned-window session: the PR-8
/// snapshot+rotation-log record plus the per-solve redamp trace. See
/// [`NaturalGradient::restore_state`] for the replay contract.
#[derive(Debug, Clone)]
pub struct SessionLog {
    /// Cold-point snapshot + rotations since (PR-8 machinery).
    pub record: SessionRecord,
    /// λ carried into the cold point's `refresh()` (`None` when the
    /// cold point is the session open).
    pub cold_refresh_lambda: Option<f64>,
    /// λ-backoff retries of the cold-point solve.
    pub cold_retries: usize,
    /// `(λ_first, retries)` per logged rotation.
    pub redamps: Vec<(f64, usize)>,
    /// Mixed-precision f64 latch must be replayed first.
    pub ever_rotated: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{CholSolver, SolverKind};

    /// Quadratic model: loss = ½‖Aθ − b‖², score rows = rows of A/√n.
    /// NGD with exact Fisher ≈ Newton and converges in few steps.
    fn quadratic_setup(n: usize, m: usize, rng: &mut Rng) -> (Mat, Vec<f64>, Vec<f64>) {
        let a = Mat::randn(n, m, rng);
        let theta_star: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let b = a.matvec(&theta_star);
        (a, b, theta_star)
    }

    fn loss_grad(a: &Mat, b: &[f64], theta: &[f64]) -> (f64, Vec<f64>, Mat) {
        let n = a.rows();
        let pred = a.matvec(theta);
        let resid: Vec<f64> = pred.iter().zip(b).map(|(p, t)| p - t).collect();
        let loss = 0.5 * resid.iter().map(|r| r * r).sum::<f64>() / n as f64;
        let mut grad = a.t_matvec(&resid);
        for g in &mut grad {
            *g /= n as f64;
        }
        // Score matrix per the paper: rows scaled by 1/√n.
        let scale = 1.0 / (n as f64).sqrt();
        let mut s = a.clone();
        s.scale(scale);
        (loss, grad, s)
    }

    #[test]
    fn ngd_converges_much_faster_than_sgd_on_ill_conditioned_quadratic() {
        let mut rng = Rng::seed_from(200);
        let (n, m) = (40, 25); // overdetermined so the optimum is exact
        let (mut a, _b, theta_star) = quadratic_setup(n, m, &mut rng);
        // Make it ill-conditioned: scale columns geometrically.
        for i in 0..n {
            for j in 0..m {
                a[(i, j)] *= 10f64.powf(j as f64 / (m - 1) as f64 * 2.0);
            }
        }
        let b = {
            // recompute consistent targets
            a.matvec(&theta_star)
        };

        // NGD
        let mut theta = vec![0.0; m];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-9 },
            1.0,
        );
        for _ in 0..20 {
            let (loss, grad, s) = loss_grad(&a, &b, &theta);
            ngd.step(&mut theta, &s, &grad, loss).unwrap();
        }
        let (ngd_loss, _, _) = loss_grad(&a, &b, &theta);

        // SGD with the best stable fixed lr for this conditioning.
        let mut theta_sgd = vec![0.0; m];
        let lr = 1e-5;
        for _ in 0..20 {
            let (_, grad, _) = loss_grad(&a, &b, &theta_sgd);
            for j in 0..m {
                theta_sgd[j] -= lr * grad[j];
            }
        }
        let (sgd_loss, _, _) = loss_grad(&a, &b, &theta_sgd);
        assert!(
            ngd_loss < 1e-10 && ngd_loss < sgd_loss * 1e-4,
            "ngd={ngd_loss:.3e} sgd={sgd_loss:.3e}"
        );
    }

    #[test]
    fn trust_region_clips() {
        let mut rng = Rng::seed_from(201);
        let (a, b, _) = quadratic_setup(10, 30, &mut rng);
        let mut theta = vec![0.0; 30];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-6 },
            1.0,
        )
        .with_trust_radius(1e-3);
        let (loss, grad, s) = loss_grad(&a, &b, &theta);
        let report = ngd.step(&mut theta, &s, &grad, loss).unwrap();
        assert!(report.clipped);
        assert!(report.update_norm <= 1e-3 * 1.0001);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut rng = Rng::seed_from(202);
        let (a, b, _) = quadratic_setup(8, 16, &mut rng);
        let mut t1 = vec![0.0; 16];
        let mut t2 = vec![0.0; 16];
        let mk = || {
            NaturalGradient::new(
                Box::new(CholSolver::default()),
                DampingSchedule::Constant { lambda: 1e-3 },
                0.1,
            )
        };
        let mut plain = mk();
        let mut momo = mk().with_momentum(0.9);
        for _ in 0..5 {
            let (l1, g1, s1) = loss_grad(&a, &b, &t1);
            plain.step(&mut t1, &s1, &g1, l1).unwrap();
            let (l2, g2, s2) = loss_grad(&a, &b, &t2);
            momo.step(&mut t2, &s2, &g2, l2).unwrap();
        }
        // Momentum must have moved farther from the origin.
        assert!(norm2(&t2) > norm2(&t1));
    }

    #[test]
    fn pd_retry_rescues_breakdown() {
        // λ small + rank-deficient S triggers the retry path. Cholesky
        // breakdown is only possible through rounding here, so instead
        // exercise the path by checking retries stay 0 on a good problem
        // and that an impossible solver budget surfaces as Err.
        let mut rng = Rng::seed_from(203);
        let (a, b, _) = quadratic_setup(6, 20, &mut rng);
        let mut theta = vec![0.0; 20];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-8 },
            0.5,
        );
        let (loss, grad, s) = loss_grad(&a, &b, &theta);
        let r = ngd.step(&mut theta, &s, &grad, loss).unwrap();
        assert_eq!(r.pd_retries_used, 0);
    }

    #[test]
    fn windowed_step_matches_plain_on_repeating_batches() {
        // When every batch carries the same score rows, a W = 2b window
        // of 1/√W-rescaled copies has *exactly* the per-batch Fisher
        // (each of the b base rows appears W/b times at 1/√W scale), so
        // the streaming path must reproduce the plain path to rotation
        // tolerance — including through the fill phase, which solves on
        // the batch alone.
        let mut rng = Rng::seed_from(205);
        let (a, b_t, _) = quadratic_setup(12, 30, &mut rng);
        let mk = |window: usize| {
            let mut ngd = NaturalGradient::new(
                Box::new(CholSolver::default()),
                DampingSchedule::Constant { lambda: 1e-3 },
                0.3,
            );
            if window > 0 {
                ngd = ngd.with_window(window, 0);
            }
            ngd
        };
        let mut plain = mk(0);
        let mut windowed = mk(24); // 2× the batch rows
        let mut tp = vec![0.0; 30];
        let mut tw = vec![0.0; 30];
        for step in 0..6 {
            let (lp, gp, sp) = loss_grad(&a, &b_t, &tp);
            let rp = plain.step(&mut tp, &sp, &gp, lp).unwrap();
            assert_eq!(rp.window_rows, 0);
            let (lw, gw, sw) = loss_grad(&a, &b_t, &tw);
            let rw = windowed.step(&mut tw, &sw, &gw, lw).unwrap();
            // Fill completes on step 1 (12 + 12 rows = 24).
            assert_eq!(rw.window_rows, if step == 0 { 12 } else { 24 });
            // Tolerance: the two paths compute the same Fisher through
            // different Gram orders (24×24 window vs 12×12 batch), and
            // per-step rounding differences amplify by ~κ ≈ ‖G‖/λ
            // through the trajectory — 1e-4 still separates "same
            // operator" from any implementation error, which diverges
            // at O(1).
            for (x, y) in tp.iter().zip(&tw) {
                assert!((x - y).abs() < 1e-4, "step {step}: {x} vs {y}");
            }
        }
        assert_eq!(windowed.window_rows(), 24);
    }

    #[test]
    fn windowed_mode_falls_back_for_kinds_without_native_rotation() {
        // CG has no owned-window session: the driver maintains the
        // window itself and refactors cold per step — and still
        // descends on the quadratic.
        let mut rng = Rng::seed_from(206);
        let (a, b_t, _) = quadratic_setup(10, 20, &mut rng);
        let mut ngd = NaturalGradient::new(
            crate::solver::make_solver(SolverKind::Cg),
            DampingSchedule::Constant { lambda: 1e-3 },
            0.5,
        )
        .with_window(20, 4);
        let mut theta = vec![0.0; 20];
        let (l0, _, _) = loss_grad(&a, &b_t, &theta);
        for _ in 0..6 {
            let (l, g, s) = loss_grad(&a, &b_t, &theta);
            let r = ngd.step(&mut theta, &s, &g, l).unwrap();
            assert!(r.window_rows > 0);
        }
        let (l1, _, _) = loss_grad(&a, &b_t, &theta);
        assert!(l1 < l0, "fallback streaming did not descend: {l0} → {l1}");
    }

    #[test]
    fn windowed_refresh_backstop_fires_and_stays_correct() {
        // refresh_every = 2: every other rotation rebuilds the window's
        // Gram+factor from scratch; the trajectory must stay finite and
        // keep descending (drift backstop is behaviour-preserving).
        let mut rng = Rng::seed_from(207);
        let (a, b_t, _) = quadratic_setup(8, 16, &mut rng);
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-3 },
            0.3,
        )
        .with_window(16, 2);
        let mut theta = vec![0.0; 16];
        let (l0, _, _) = loss_grad(&a, &b_t, &theta);
        for _ in 0..8 {
            let (l, g, s) = loss_grad(&a, &b_t, &theta);
            ngd.step(&mut theta, &s, &g, l).unwrap();
        }
        let (l1, _, _) = loss_grad(&a, &b_t, &theta);
        assert!(l1.is_finite() && l1 < l0);
    }

    /// Run `steps` NGD steps on the quadratic, optionally exporting the
    /// optimizer state at step `save_at` and restoring it into a fresh
    /// optimizer (built by `mk`) before continuing — the kill-anywhere
    /// resume path. Returns the final parameters.
    fn run_with_restore(
        mk: &dyn Fn() -> NaturalGradient,
        a: &Mat,
        b_t: &[f64],
        m: usize,
        steps: usize,
        save_at: Option<usize>,
    ) -> Vec<f64> {
        let mut ngd = mk();
        let mut theta = vec![0.0; m];
        for step in 0..steps {
            if save_at == Some(step) {
                let st = ngd.export_state();
                ngd = mk();
                ngd.restore_state(st).unwrap();
            }
            let (l, g, s) = loss_grad(a, b_t, &theta);
            ngd.step(&mut theta, &s, &g, l).unwrap();
        }
        theta
    }

    #[test]
    fn export_restore_resumes_bit_identically() {
        // The kill-anywhere contract at the optimizer layer: exporting
        // mid-stream and restoring into a *fresh* optimizer must leave
        // the remaining trajectory bit-identical to the uninterrupted
        // run — at every possible save boundary, through fill, session
        // open, rotations, and refresh()-cold-point phases, for both
        // native owned-window kinds and an LM damping schedule.
        let mut rng = Rng::seed_from(208);
        let (a, b_t, _) = quadratic_setup(8, 16, &mut rng);
        for kind in [SolverKind::Chol, SolverKind::Rvb] {
            let mk = move || {
                NaturalGradient::new(
                    crate::solver::make_solver(kind),
                    DampingSchedule::LevenbergMarquardt {
                        lambda: 1e-3,
                        grow: 2.0,
                        shrink: 0.9,
                        min: 1e-10,
                        max: 1e3,
                    },
                    0.3,
                )
                .with_momentum(0.9)
                .with_window(16, 3) // fill completes at step 1; refresh fires
            };
            let steps = 8;
            let reference = run_with_restore(&mk, &a, &b_t, 16, steps, None);
            for save_at in 0..steps {
                let resumed = run_with_restore(&mk, &a, &b_t, 16, steps, Some(save_at));
                for (j, (x, y)) in reference.iter().zip(&resumed).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{kind:?}: save at step {save_at}, param {j}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn export_restore_rejects_window_config_mismatch() {
        let mk_windowed = || {
            NaturalGradient::new(
                Box::new(CholSolver::default()),
                DampingSchedule::Constant { lambda: 1e-3 },
                0.3,
            )
            .with_window(16, 0)
        };
        let st = mk_windowed().export_state();
        let mut classic = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-3 },
            0.3,
        );
        assert!(matches!(classic.restore_state(st), Err(SolveError::BadInput(_))));
        let mut windowed = mk_windowed();
        assert!(matches!(
            windowed.restore_state(classic.export_state()),
            Err(SolveError::BadInput(_))
        ));
    }

    #[test]
    fn works_with_every_solver_kind() {
        let mut rng = Rng::seed_from(204);
        let (a, b, _) = quadratic_setup(8, 24, &mut rng);
        // KpSvd is excluded: it is a deliberate approximation, so a
        // single step need not descend on an unstructured quadratic.
        for &kind in &[
            SolverKind::Chol,
            SolverKind::Eigh,
            SolverKind::Svda,
            SolverKind::Cg,
            SolverKind::BlockDiag,
            SolverKind::Hybrid,
        ] {
            let mut theta = vec![0.0; 24];
            let mut ngd = NaturalGradient::new(
                crate::solver::make_solver(kind),
                DampingSchedule::Constant { lambda: 1e-4 },
                1.0,
            );
            let (l0, g, s) = loss_grad(&a, &b, &theta);
            ngd.step(&mut theta, &s, &g, l0).unwrap();
            let (l1, _, _) = loss_grad(&a, &b, &theta);
            assert!(l1 < l0, "{kind:?} did not descend: {l0} → {l1}");
        }
    }
}
