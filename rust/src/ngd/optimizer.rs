//! The damped natural-gradient optimizer.

use super::DampingSchedule;
use crate::linalg::mat::norm2;
use crate::linalg::Mat;
use crate::solver::{solve_with_backoff, DampedSolver, SolveError};

/// Damped NGD/SR optimizer state.
///
/// Each step solves `(SᵀS + λI) x = ∇L` with the configured solver and
/// applies `θ ← θ − η·(x + μ·momentum)`, optionally clipping `x` to a
/// trust-region radius in natural-gradient norm.
pub struct NaturalGradient {
    pub solver: Box<dyn DampedSolver>,
    pub damping: DampingSchedule,
    pub learning_rate: f64,
    /// Momentum coefficient μ (0 disables).
    pub momentum: f64,
    /// Max ‖update‖₂ (None disables clipping).
    pub trust_radius: Option<f64>,
    velocity: Vec<f64>,
    last_loss: Option<f64>,
    steps: usize,
    /// Cholesky retry policy: on `NotPositiveDefinite`, multiply λ by 10
    /// and retry up to this many times (damping is the fix the error
    /// message recommends; the optimizer automates it). Since PR 2 the
    /// retry re-damps the cached session factorization, so each backoff
    /// costs O(n³) instead of repeating the O(n²m) Gram product.
    pub pd_retries: usize,
}

/// Per-step diagnostics.
#[derive(Debug, Clone)]
pub struct NgdReport {
    pub step: usize,
    pub lambda: f64,
    pub grad_norm: f64,
    pub nat_grad_norm: f64,
    pub update_norm: f64,
    pub clipped: bool,
    pub pd_retries_used: usize,
}

impl NaturalGradient {
    pub fn new(
        solver: Box<dyn DampedSolver>,
        damping: DampingSchedule,
        learning_rate: f64,
    ) -> Self {
        NaturalGradient {
            solver,
            damping,
            learning_rate,
            momentum: 0.0,
            trust_radius: None,
            velocity: Vec::new(),
            last_loss: None,
            steps: 0,
            pd_retries: 3,
        }
    }

    pub fn with_momentum(mut self, mu: f64) -> Self {
        self.momentum = mu;
        self
    }

    pub fn with_trust_radius(mut self, r: f64) -> Self {
        self.trust_radius = Some(r);
        self
    }

    /// One optimization step.
    ///
    /// * `params` — flat parameter vector, updated in place.
    /// * `scores` — the n×m score matrix S for the current batch
    ///   (already 1/√n-scaled, per the paper's definition).
    /// * `grad` — loss gradient v (length m).
    /// * `loss` — current batch loss, drives the LM damping policy.
    pub fn step(
        &mut self,
        params: &mut [f64],
        scores: &Mat,
        grad: &[f64],
        loss: f64,
    ) -> Result<NgdReport, SolveError> {
        assert_eq!(params.len(), grad.len());
        assert_eq!(scores.cols(), params.len());

        let improved = self.last_loss.map(|prev| loss < prev).unwrap_or(true);
        self.damping.advance(improved);
        self.last_loss = Some(loss);

        // Session path: the λ-independent state (Gram/SVD) is staged once;
        // PD backoff re-damps it in place.
        let mut fact = self.solver.begin(scores);
        let (x, lambda, retries) =
            solve_with_backoff(fact.as_mut(), grad, self.damping.lambda(), self.pd_retries)?;
        drop(fact);

        let nat_grad_norm = norm2(&x);
        // Trust region: scale the natural gradient down to the radius.
        let (x, clipped) = match self.trust_radius {
            Some(r) if nat_grad_norm > r => {
                let scale = r / nat_grad_norm;
                (x.iter().map(|v| v * scale).collect::<Vec<_>>(), true)
            }
            _ => (x, false),
        };

        // Momentum buffer.
        if self.velocity.len() != params.len() {
            self.velocity = vec![0.0; params.len()];
        }
        let mu = self.momentum;
        let mut update_sq = 0.0;
        for j in 0..params.len() {
            self.velocity[j] = mu * self.velocity[j] + x[j];
            let u = self.learning_rate * self.velocity[j];
            params[j] -= u;
            update_sq += u * u;
        }

        self.steps += 1;
        Ok(NgdReport {
            step: self.steps,
            lambda,
            grad_norm: norm2(grad),
            nat_grad_norm,
            update_norm: update_sq.sqrt(),
            clipped,
            pd_retries_used: retries,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{CholSolver, SolverKind};

    /// Quadratic model: loss = ½‖Aθ − b‖², score rows = rows of A/√n.
    /// NGD with exact Fisher ≈ Newton and converges in few steps.
    fn quadratic_setup(n: usize, m: usize, rng: &mut Rng) -> (Mat, Vec<f64>, Vec<f64>) {
        let a = Mat::randn(n, m, rng);
        let theta_star: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let b = a.matvec(&theta_star);
        (a, b, theta_star)
    }

    fn loss_grad(a: &Mat, b: &[f64], theta: &[f64]) -> (f64, Vec<f64>, Mat) {
        let n = a.rows();
        let pred = a.matvec(theta);
        let resid: Vec<f64> = pred.iter().zip(b).map(|(p, t)| p - t).collect();
        let loss = 0.5 * resid.iter().map(|r| r * r).sum::<f64>() / n as f64;
        let mut grad = a.t_matvec(&resid);
        for g in &mut grad {
            *g /= n as f64;
        }
        // Score matrix per the paper: rows scaled by 1/√n.
        let scale = 1.0 / (n as f64).sqrt();
        let mut s = a.clone();
        s.scale(scale);
        (loss, grad, s)
    }

    #[test]
    fn ngd_converges_much_faster_than_sgd_on_ill_conditioned_quadratic() {
        let mut rng = Rng::seed_from(200);
        let (n, m) = (40, 25); // overdetermined so the optimum is exact
        let (mut a, _b, theta_star) = quadratic_setup(n, m, &mut rng);
        // Make it ill-conditioned: scale columns geometrically.
        for i in 0..n {
            for j in 0..m {
                a[(i, j)] *= 10f64.powf(j as f64 / (m - 1) as f64 * 2.0);
            }
        }
        let b = {
            // recompute consistent targets
            a.matvec(&theta_star)
        };

        // NGD
        let mut theta = vec![0.0; m];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-9 },
            1.0,
        );
        for _ in 0..20 {
            let (loss, grad, s) = loss_grad(&a, &b, &theta);
            ngd.step(&mut theta, &s, &grad, loss).unwrap();
        }
        let (ngd_loss, _, _) = loss_grad(&a, &b, &theta);

        // SGD with the best stable fixed lr for this conditioning.
        let mut theta_sgd = vec![0.0; m];
        let lr = 1e-5;
        for _ in 0..20 {
            let (_, grad, _) = loss_grad(&a, &b, &theta_sgd);
            for j in 0..m {
                theta_sgd[j] -= lr * grad[j];
            }
        }
        let (sgd_loss, _, _) = loss_grad(&a, &b, &theta_sgd);
        assert!(
            ngd_loss < 1e-10 && ngd_loss < sgd_loss * 1e-4,
            "ngd={ngd_loss:.3e} sgd={sgd_loss:.3e}"
        );
    }

    #[test]
    fn trust_region_clips() {
        let mut rng = Rng::seed_from(201);
        let (a, b, _) = quadratic_setup(10, 30, &mut rng);
        let mut theta = vec![0.0; 30];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-6 },
            1.0,
        )
        .with_trust_radius(1e-3);
        let (loss, grad, s) = loss_grad(&a, &b, &theta);
        let report = ngd.step(&mut theta, &s, &grad, loss).unwrap();
        assert!(report.clipped);
        assert!(report.update_norm <= 1e-3 * 1.0001);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut rng = Rng::seed_from(202);
        let (a, b, _) = quadratic_setup(8, 16, &mut rng);
        let mut t1 = vec![0.0; 16];
        let mut t2 = vec![0.0; 16];
        let mk = || {
            NaturalGradient::new(
                Box::new(CholSolver::default()),
                DampingSchedule::Constant { lambda: 1e-3 },
                0.1,
            )
        };
        let mut plain = mk();
        let mut momo = mk().with_momentum(0.9);
        for _ in 0..5 {
            let (l1, g1, s1) = loss_grad(&a, &b, &t1);
            plain.step(&mut t1, &s1, &g1, l1).unwrap();
            let (l2, g2, s2) = loss_grad(&a, &b, &t2);
            momo.step(&mut t2, &s2, &g2, l2).unwrap();
        }
        // Momentum must have moved farther from the origin.
        assert!(norm2(&t2) > norm2(&t1));
    }

    #[test]
    fn pd_retry_rescues_breakdown() {
        // λ small + rank-deficient S triggers the retry path. Cholesky
        // breakdown is only possible through rounding here, so instead
        // exercise the path by checking retries stay 0 on a good problem
        // and that an impossible solver budget surfaces as Err.
        let mut rng = Rng::seed_from(203);
        let (a, b, _) = quadratic_setup(6, 20, &mut rng);
        let mut theta = vec![0.0; 20];
        let mut ngd = NaturalGradient::new(
            Box::new(CholSolver::default()),
            DampingSchedule::Constant { lambda: 1e-8 },
            0.5,
        );
        let (loss, grad, s) = loss_grad(&a, &b, &theta);
        let r = ngd.step(&mut theta, &s, &grad, loss).unwrap();
        assert_eq!(r.pd_retries_used, 0);
    }

    #[test]
    fn works_with_every_solver_kind() {
        let mut rng = Rng::seed_from(204);
        let (a, b, _) = quadratic_setup(8, 24, &mut rng);
        for &kind in &[SolverKind::Chol, SolverKind::Eigh, SolverKind::Svda, SolverKind::Cg] {
            let mut theta = vec![0.0; 24];
            let mut ngd = NaturalGradient::new(
                crate::solver::make_solver(kind),
                DampingSchedule::Constant { lambda: 1e-4 },
                1.0,
            );
            let (l0, g, s) = loss_grad(&a, &b, &theta);
            ngd.step(&mut theta, &s, &g, l0).unwrap();
            let (l1, _, _) = loss_grad(&a, &b, &theta);
            assert!(l1 < l0, "{kind:?} did not descend: {l0} → {l1}");
        }
    }
}
