//! First-order baselines — SGD (with momentum) and Adam.
//!
//! Used by the end-to-end example to show the NGD-vs-first-order loss
//! curves and by the ablation benches.

/// Plain SGD with optional classical momentum.
pub struct Sgd {
    pub learning_rate: f64,
    pub momentum: f64,
    velocity: Vec<f64>,
}

impl Sgd {
    pub fn new(learning_rate: f64) -> Self {
        Sgd { learning_rate, momentum: 0.0, velocity: Vec::new() }
    }

    pub fn with_momentum(mut self, mu: f64) -> Self {
        self.momentum = mu;
        self
    }

    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), grad.len());
        if self.velocity.len() != params.len() {
            self.velocity = vec![0.0; params.len()];
        }
        for j in 0..params.len() {
            self.velocity[j] = self.momentum * self.velocity[j] + grad[j];
            params[j] -= self.learning_rate * self.velocity[j];
        }
    }

    /// The momentum buffer (empty before the first step) — checkpointed
    /// so a resumed run continues the same velocity trajectory.
    pub fn velocity(&self) -> &[f64] {
        &self.velocity
    }

    /// Restore the momentum buffer from a checkpoint.
    pub fn restore_velocity(&mut self, velocity: Vec<f64>) {
        self.velocity = velocity;
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    pub learning_rate: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u32,
}

impl Adam {
    pub fn new(learning_rate: f64) -> Self {
        Adam { learning_rate, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: Vec::new(), v: Vec::new(), t: 0 }
    }

    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), grad.len());
        if self.m.len() != params.len() {
            self.m = vec![0.0; params.len()];
            self.v = vec![0.0; params.len()];
            self.t = 0;
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for j in 0..params.len() {
            self.m[j] = self.beta1 * self.m[j] + (1.0 - self.beta1) * grad[j];
            self.v[j] = self.beta2 * self.v[j] + (1.0 - self.beta2) * grad[j] * grad[j];
            let mhat = self.m[j] / bc1;
            let vhat = self.v[j] / bc2;
            params[j] -= self.learning_rate * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(theta: &[f64]) -> Vec<f64> {
        // loss = ½ Σ c_j θ_j², c_j = j+1 ⇒ grad = c_j θ_j
        theta.iter().enumerate().map(|(j, t)| (j + 1) as f64 * t).collect()
    }

    fn quad_loss(theta: &[f64]) -> f64 {
        theta.iter().enumerate().map(|(j, t)| 0.5 * (j + 1) as f64 * t * t).sum()
    }

    #[test]
    fn sgd_descends_quadratic() {
        let mut theta = vec![1.0; 10];
        let mut opt = Sgd::new(0.05);
        let l0 = quad_loss(&theta);
        for _ in 0..100 {
            let g = quad_grad(&theta);
            opt.step(&mut theta, &g);
        }
        assert!(quad_loss(&theta) < 1e-3 * l0);
    }

    #[test]
    fn sgd_momentum_faster_than_plain_on_ill_conditioned() {
        let mut plain = vec![1.0; 20];
        let mut heavy = vec![1.0; 20];
        // lr well below the stability limit of the stiffest mode so the
        // plain run is bottlenecked by the flattest mode — the regime
        // where heavy-ball momentum provably accelerates.
        let mut o1 = Sgd::new(0.005);
        let mut o2 = Sgd::new(0.005).with_momentum(0.9);
        for _ in 0..100 {
            let g1 = quad_grad(&plain);
            o1.step(&mut plain, &g1);
            let g2 = quad_grad(&heavy);
            o2.step(&mut heavy, &g2);
        }
        assert!(quad_loss(&heavy) < quad_loss(&plain));
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut theta = vec![1.0; 10];
        let mut opt = Adam::new(0.1);
        let l0 = quad_loss(&theta);
        for _ in 0..300 {
            let g = quad_grad(&theta);
            opt.step(&mut theta, &g);
        }
        assert!(quad_loss(&theta) < 1e-4 * l0);
    }
}
