//! Block-diagonal approximate Fisher — the KFAC-family baseline.
//!
//! §1 motivates the paper: "approximations like KFAC have been introduced
//! to mitigate this burden, [but] they often fall short of replicating the
//! performance of the exact method." This module implements the
//! block-diagonal Fisher (the structural core of KFAC-style methods:
//! cross-layer curvature is dropped) so the ablation bench can measure
//! that gap against the exact Algorithm-1 solve.
//!
//! Each parameter block B_k gets its own damped solve
//! `(S_kᵀS_k + λI) x_k = v_k` where `S_k` is the column shard of S for
//! that block — conveniently *also* accelerated by Algorithm 1.

use crate::linalg::Mat;
use crate::solver::{CholSolver, DampedSolver, SolveError};

/// Block-diagonal Fisher solver over explicit parameter blocks.
pub struct BlockDiagonalFisher {
    /// Half-open column ranges `[start, end)` partitioning the parameters
    /// (typically one per layer).
    pub blocks: Vec<(usize, usize)>,
    inner: CholSolver,
}

impl BlockDiagonalFisher {
    /// Build from block boundaries; validates that blocks partition `m`.
    pub fn new(blocks: Vec<(usize, usize)>, m: usize) -> Result<Self, String> {
        let mut cursor = 0;
        for &(s, e) in &blocks {
            if s != cursor || e <= s {
                return Err(format!("blocks must be a contiguous partition, got {blocks:?}"));
            }
            cursor = e;
        }
        if cursor != m {
            return Err(format!("blocks cover [0,{cursor}) but m = {m}"));
        }
        Ok(BlockDiagonalFisher { blocks, inner: CholSolver::default() })
    }

    /// Uniform partition into `k` blocks.
    pub fn uniform(m: usize, k: usize) -> Self {
        let k = k.max(1).min(m);
        let base = m / k;
        let rem = m % k;
        let mut blocks = Vec::with_capacity(k);
        let mut start = 0;
        for i in 0..k {
            let len = base + usize::from(i < rem);
            blocks.push((start, start + len));
            start += len;
        }
        BlockDiagonalFisher { blocks, inner: CholSolver::default() }
    }

    /// Solve the block-diagonal system: each block solved independently.
    pub fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        let mut x = vec![0.0; v.len()];
        for &(c0, c1) in &self.blocks {
            let s_block = s.slice_cols(c0, c1);
            let xb = self.inner.solve(&s_block, &v[c0..c1], lambda)?;
            x[c0..c1].copy_from_slice(&xb);
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, DampedSolver};

    #[test]
    fn single_block_equals_exact() {
        let mut rng = Rng::seed_from(210);
        let s = Mat::randn(8, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::uniform(40, 1);
        let exact = CholSolver::default().solve(&s, &v, 0.1).unwrap();
        let block = bd.solve(&s, &v, 0.1).unwrap();
        for (a, b) in exact.iter().zip(&block) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn multi_block_differs_from_exact_but_is_consistent_blockwise() {
        let mut rng = Rng::seed_from(211);
        let s = Mat::randn(10, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::uniform(60, 4);
        let exact = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        let approx = bd.solve(&s, &v, 0.05).unwrap();
        // It's an approximation: must differ on random problems...
        let diff: f64 = exact.iter().zip(&approx).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "block-diagonal should not equal exact here");
        // ...but each block's restriction solves its own subproblem exactly.
        for &(c0, c1) in &bd.blocks {
            let sb = s.slice_cols(c0, c1);
            let r = residual_norm(&sb, &approx[c0..c1], &v[c0..c1], 0.05);
            assert!(r < 1e-8);
        }
    }

    #[test]
    fn exact_when_blocks_are_truly_independent() {
        // If S has block structure (no cross-block correlations), the
        // block-diagonal Fisher IS the Fisher.
        let mut rng = Rng::seed_from(212);
        let mut s = Mat::zeros(12, 20);
        // rows 0..6 touch cols 0..10; rows 6..12 touch cols 10..20
        for i in 0..6 {
            for j in 0..10 {
                s[(i, j)] = rng.normal();
            }
        }
        for i in 6..12 {
            for j in 10..20 {
                s[(i, j)] = rng.normal();
            }
        }
        let v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::new(vec![(0, 10), (10, 20)], 20).unwrap();
        let exact = CholSolver::default().solve(&s, &v, 0.2).unwrap();
        let block = bd.solve(&s, &v, 0.2).unwrap();
        for (a, b) in exact.iter().zip(&block) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn validates_partition() {
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (6, 10)], 10).is_err()); // gap
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (5, 9)], 10).is_err()); // short
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (5, 10)], 10).is_ok());
        let u = BlockDiagonalFisher::uniform(10, 3);
        assert_eq!(u.blocks, vec![(0, 4), (4, 7), (7, 10)]);
    }
}
