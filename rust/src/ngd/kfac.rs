//! Block-diagonal approximate Fisher — the KFAC-family baseline.
//!
//! **Deprecated shim.** PR 10 promoted block structure into the solver
//! layer proper: [`crate::solver::BlockPartition`] owns partition
//! validation and [`crate::solver::BlockDiagSolver`] owns the per-block
//! damped sessions (with redamp caching, `solve_many` panels, threading,
//! mixed precision, and streaming row rotation — none of which this
//! one-shot helper ever had). New code should use those directly, or the
//! `blockdiag` / `kpsvd` / `hybrid` entries in
//! [`crate::solver::SolverKind`]. This module remains only so the seed
//! API keeps compiling; it now delegates to the solver layer.
//!
//! §1 motivates the paper: "approximations like KFAC have been introduced
//! to mitigate this burden, [but] they often fall short of replicating the
//! performance of the exact method." The ablation bench measures that gap
//! against the exact Algorithm-1 solve.
//!
//! Migration note (also a seed bugfix): the seed version reported errors
//! as `Result<_, String>`, silently clamped `k` in `uniform`, and
//! accepted `m == 0`. The shim now returns typed
//! [`SolveError::BadInput`](crate::solver::SolveError) for every
//! degenerate partition, matching the rest of the solver layer.

use crate::linalg::Mat;
use crate::solver::{BlockDiagSolver, BlockKind, BlockPartition, DampedSolver, SolveError};

/// Block-diagonal Fisher solver over explicit parameter blocks.
#[deprecated(note = "use crate::solver::{BlockPartition, BlockDiagSolver} or SolverKind::BlockDiag")]
pub struct BlockDiagonalFisher {
    /// Half-open column ranges `[start, end)` partitioning the parameters
    /// (typically one per layer).
    pub blocks: Vec<(usize, usize)>,
    partition: BlockPartition,
}

#[allow(deprecated)]
impl BlockDiagonalFisher {
    /// Build from block boundaries; validates that blocks partition `m`.
    ///
    /// Degenerate partitions (gaps, overlaps, empty blocks, short or long
    /// coverage, `m == 0`) are hard [`SolveError::BadInput`]s.
    pub fn new(blocks: Vec<(usize, usize)>, m: usize) -> Result<Self, SolveError> {
        let partition = BlockPartition::new(blocks.clone(), m)?;
        Ok(BlockDiagonalFisher { blocks, partition })
    }

    /// Uniform partition into `k` blocks.
    ///
    /// Unlike the seed version, `k == 0`, `k > m`, and `m == 0` are hard
    /// errors rather than silently clamped.
    pub fn uniform(m: usize, k: usize) -> Result<Self, SolveError> {
        let partition = BlockPartition::uniform(m, k)?;
        let blocks = partition.ranges().to_vec();
        Ok(BlockDiagonalFisher { blocks, partition })
    }

    /// Solve the block-diagonal system: each block solved independently.
    pub fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        if v.len() != s.cols() {
            return Err(SolveError::BadInput(format!(
                "rhs length {} does not match m = {}",
                v.len(),
                s.cols()
            )));
        }
        let solver = BlockDiagSolver::default()
            .with_partition(self.partition.clone())
            .with_blocks(0, BlockKind::Chol);
        solver.solve(s, v, lambda)
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver, DampedSolver};

    #[test]
    fn single_block_equals_exact() {
        let mut rng = Rng::seed_from(210);
        let s = Mat::randn(8, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::uniform(40, 1).unwrap();
        let exact = CholSolver::default().solve(&s, &v, 0.1).unwrap();
        let block = bd.solve(&s, &v, 0.1).unwrap();
        for (a, b) in exact.iter().zip(&block) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn multi_block_differs_from_exact_but_is_consistent_blockwise() {
        let mut rng = Rng::seed_from(211);
        let s = Mat::randn(10, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::uniform(60, 4).unwrap();
        let exact = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        let approx = bd.solve(&s, &v, 0.05).unwrap();
        // It's an approximation: must differ on random problems...
        let diff: f64 = exact.iter().zip(&approx).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "block-diagonal should not equal exact here");
        // ...but each block's restriction solves its own subproblem exactly.
        for &(c0, c1) in &bd.blocks {
            let sb = s.slice_cols(c0, c1);
            let r = residual_norm(&sb, &approx[c0..c1], &v[c0..c1], 0.05);
            assert!(r < 1e-8);
        }
    }

    #[test]
    fn exact_when_blocks_are_truly_independent() {
        // If S has block structure (no cross-block correlations), the
        // block-diagonal Fisher IS the Fisher.
        let mut rng = Rng::seed_from(212);
        let mut s = Mat::zeros(12, 20);
        // rows 0..6 touch cols 0..10; rows 6..12 touch cols 10..20
        for i in 0..6 {
            for j in 0..10 {
                s[(i, j)] = rng.normal();
            }
        }
        for i in 6..12 {
            for j in 10..20 {
                s[(i, j)] = rng.normal();
            }
        }
        let v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let bd = BlockDiagonalFisher::new(vec![(0, 10), (10, 20)], 20).unwrap();
        let exact = CholSolver::default().solve(&s, &v, 0.2).unwrap();
        let block = bd.solve(&s, &v, 0.2).unwrap();
        for (a, b) in exact.iter().zip(&block) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn validates_partition() {
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (6, 10)], 10).is_err()); // gap
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (5, 9)], 10).is_err()); // short
        assert!(BlockDiagonalFisher::new(vec![(0, 5), (5, 10)], 10).is_ok());
        let u = BlockDiagonalFisher::uniform(10, 3).unwrap();
        assert_eq!(u.blocks, vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn shim_errors_are_typed_and_degenerate_inputs_are_hard() {
        // Seed bugs fixed in PR 10: `uniform` used to clamp silently and
        // `new` accepted m == 0 with an empty block list.
        for bad in [
            BlockDiagonalFisher::uniform(0, 1),
            BlockDiagonalFisher::uniform(10, 0),
            BlockDiagonalFisher::uniform(3, 7),
            BlockDiagonalFisher::new(vec![], 0),
            BlockDiagonalFisher::new(vec![(0, 5), (3, 10)], 10), // overlap
        ] {
            match bad {
                Err(SolveError::BadInput(_)) => {}
                other => panic!("expected BadInput, got {:?}", other.map(|b| b.blocks)),
            }
        }
        // rhs-length mismatch surfaces as BadInput too, not a panic.
        let bd = BlockDiagonalFisher::uniform(10, 2).unwrap();
        let mut rng = Rng::seed_from(213);
        let s = Mat::randn(4, 10, &mut rng);
        assert!(matches!(bd.solve(&s, &[0.0; 9], 0.1), Err(SolveError::BadInput(_))));
    }
}
