//! Natural-gradient optimization layer.
//!
//! Assembles the paper's solver into a production optimizer:
//!
//! * [`NaturalGradient`] — damped NGD/SR update `θ ← θ − η·x` where
//!   `(SᵀS + λI) x = ∇L`, with pluggable [`crate::solver::DampedSolver`],
//!   damping schedule, momentum and trust-region clipping.
//! * [`DampingSchedule`] — constant, exponential-decay, and
//!   Levenberg–Marquardt adaptive damping (§3 relates Eq. 1 to LM).
//! * [`kfac`] — a block-diagonal (KFAC-flavoured) approximate-Fisher
//!   baseline, the approximation family §1 says "often falls short of
//!   replicating the performance of the exact method". Deprecated since
//!   PR 10: the solver layer now owns block structure
//!   ([`crate::solver::BlockDiagSolver`], [`crate::solver::KpSvdSolver`],
//!   [`crate::solver::HybridCgSolver`]); the shim delegates to it.
//! * [`Sgd`] / [`Adam`] — first-order baselines for the end-to-end runs.

pub mod damping;
pub mod first_order;
pub mod kfac;
pub mod optimizer;

pub use damping::DampingSchedule;
pub use first_order::{Adam, Sgd};
#[allow(deprecated)]
pub use kfac::BlockDiagonalFisher;
pub use optimizer::{NaturalGradient, NgdReport, NgdState, SessionLog, WindowLog};
