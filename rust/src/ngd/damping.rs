//! Damping (λ) schedules.
//!
//! §1: "In large-scale scenarios, where the number of samples is typically
//! much smaller than the number of model parameters, a damping term
//! becomes essential." How λ evolves over training is a deployment
//! decision; three standard policies are provided.

/// Policy for the damping strength λ over training.
#[derive(Debug, Clone)]
pub enum DampingSchedule {
    /// Fixed λ.
    Constant { lambda: f64 },
    /// λ_t = max(λ₀·decay^t, λ_min) — common in SR/VMC practice.
    ExponentialDecay { initial: f64, decay: f64, min: f64 },
    /// Levenberg–Marquardt adaptation: shrink λ after a successful step
    /// (loss decreased), grow it after a failed one. §3 identifies Eq. 1
    /// with the damped-least-squares (LM) subproblem.
    LevenbergMarquardt { lambda: f64, grow: f64, shrink: f64, min: f64, max: f64 },
}

impl DampingSchedule {
    /// Current λ.
    pub fn lambda(&self) -> f64 {
        match self {
            DampingSchedule::Constant { lambda } => *lambda,
            DampingSchedule::ExponentialDecay { initial, .. } => *initial,
            DampingSchedule::LevenbergMarquardt { lambda, .. } => *lambda,
        }
    }

    /// Advance one step. `loss_improved` is only consulted by the LM policy.
    pub fn advance(&mut self, loss_improved: bool) {
        match self {
            DampingSchedule::Constant { .. } => {}
            DampingSchedule::ExponentialDecay { initial, decay, min } => {
                *initial = (*initial * *decay).max(*min);
            }
            DampingSchedule::LevenbergMarquardt { lambda, grow, shrink, min, max } => {
                if loss_improved {
                    *lambda = (*lambda * *shrink).max(*min);
                } else {
                    *lambda = (*lambda * *grow).min(*max);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_never_moves() {
        let mut d = DampingSchedule::Constant { lambda: 0.1 };
        for improved in [true, false, true] {
            d.advance(improved);
            assert_eq!(d.lambda(), 0.1);
        }
    }

    #[test]
    fn exponential_decays_to_floor() {
        let mut d = DampingSchedule::ExponentialDecay { initial: 1.0, decay: 0.5, min: 0.1 };
        let mut prev = d.lambda();
        for _ in 0..10 {
            d.advance(true);
            assert!(d.lambda() <= prev);
            prev = d.lambda();
        }
        assert_eq!(d.lambda(), 0.1);
    }

    #[test]
    fn lm_adapts_both_directions() {
        let mut d = DampingSchedule::LevenbergMarquardt {
            lambda: 1.0,
            grow: 3.0,
            shrink: 0.5,
            min: 1e-8,
            max: 1e4,
        };
        d.advance(true);
        assert!((d.lambda() - 0.5).abs() < 1e-15);
        d.advance(false);
        assert!((d.lambda() - 1.5).abs() < 1e-15);
        // Caps respected.
        for _ in 0..100 {
            d.advance(false);
        }
        assert_eq!(d.lambda(), 1e4);
        for _ in 0..100 {
            d.advance(true);
        }
        assert_eq!(d.lambda(), 1e-8);
    }
}
