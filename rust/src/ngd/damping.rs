//! Damping (λ) schedules.
//!
//! §1: "In large-scale scenarios, where the number of samples is typically
//! much smaller than the number of model parameters, a damping term
//! becomes essential." How λ evolves over training is a deployment
//! decision; three standard policies are provided.

/// Policy for the damping strength λ over training.
#[derive(Debug, Clone)]
pub enum DampingSchedule {
    /// Fixed λ.
    Constant { lambda: f64 },
    /// λ_t = max(λ₀·decay^t, λ_min) — common in SR/VMC practice.
    ExponentialDecay { initial: f64, decay: f64, min: f64 },
    /// Levenberg–Marquardt adaptation: shrink λ after a successful step
    /// (loss decreased), grow it after a failed one. §3 identifies Eq. 1
    /// with the damped-least-squares (LM) subproblem.
    LevenbergMarquardt { lambda: f64, grow: f64, shrink: f64, min: f64, max: f64 },
}

impl DampingSchedule {
    /// Current λ.
    pub fn lambda(&self) -> f64 {
        match self {
            DampingSchedule::Constant { lambda } => *lambda,
            DampingSchedule::ExponentialDecay { initial, .. } => *initial,
            DampingSchedule::LevenbergMarquardt { lambda, .. } => *lambda,
        }
    }

    /// The evolving scalar of the schedule — the one piece of state that
    /// is not derivable from config. Checkpoints persist this; the
    /// schedule *shape* (policy + bounds) is rebuilt from config at
    /// resume and [`DampingSchedule::restore`] re-seats the scalar.
    pub fn state(&self) -> f64 {
        self.lambda()
    }

    /// Re-seat the evolving scalar from a checkpoint (see
    /// [`DampingSchedule::state`]). Bounds are *not* re-clamped: the
    /// saved value came from this schedule's own dynamics (or a sentinel
    /// escalation), and resume must reproduce it exactly.
    pub fn restore(&mut self, value: f64) {
        match self {
            DampingSchedule::Constant { lambda } => *lambda = value,
            DampingSchedule::ExponentialDecay { initial, .. } => *initial = value,
            DampingSchedule::LevenbergMarquardt { lambda, .. } => *lambda = value,
        }
    }

    /// Sentinel rescue: multiply λ by `factor` (clamped to the LM upper
    /// bound where one exists). Overrides even the `Constant` policy —
    /// a rollback that restored the exact diverging λ would diverge
    /// again identically.
    pub fn escalate(&mut self, factor: f64) {
        match self {
            DampingSchedule::Constant { lambda } => *lambda *= factor,
            DampingSchedule::ExponentialDecay { initial, .. } => *initial *= factor,
            DampingSchedule::LevenbergMarquardt { lambda, max, .. } => {
                *lambda = (*lambda * factor).min(*max);
            }
        }
    }

    /// λ value at which the schedule is pinned against its ceiling —
    /// the λ-runaway sentinel's trip threshold. Only the LM policy has
    /// one (a decaying or constant λ cannot run away on its own).
    pub fn runaway_threshold(&self) -> Option<f64> {
        match self {
            DampingSchedule::LevenbergMarquardt { max, .. } => Some(*max),
            _ => None,
        }
    }

    /// Advance one step. `loss_improved` is only consulted by the LM policy.
    pub fn advance(&mut self, loss_improved: bool) {
        match self {
            DampingSchedule::Constant { .. } => {}
            DampingSchedule::ExponentialDecay { initial, decay, min } => {
                *initial = (*initial * *decay).max(*min);
            }
            DampingSchedule::LevenbergMarquardt { lambda, grow, shrink, min, max } => {
                if loss_improved {
                    *lambda = (*lambda * *shrink).max(*min);
                } else {
                    *lambda = (*lambda * *grow).min(*max);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_never_moves() {
        let mut d = DampingSchedule::Constant { lambda: 0.1 };
        for improved in [true, false, true] {
            d.advance(improved);
            assert_eq!(d.lambda(), 0.1);
        }
    }

    #[test]
    fn exponential_decays_to_floor() {
        let mut d = DampingSchedule::ExponentialDecay { initial: 1.0, decay: 0.5, min: 0.1 };
        let mut prev = d.lambda();
        for _ in 0..10 {
            d.advance(true);
            assert!(d.lambda() <= prev);
            prev = d.lambda();
        }
        assert_eq!(d.lambda(), 0.1);
    }

    #[test]
    fn state_restore_escalate() {
        let mut d = DampingSchedule::LevenbergMarquardt {
            lambda: 1.0,
            grow: 2.0,
            shrink: 0.5,
            min: 1e-8,
            max: 1e3,
        };
        d.advance(true);
        let saved = d.state();
        d.advance(false);
        d.advance(false);
        d.restore(saved);
        assert_eq!(d.lambda().to_bits(), saved.to_bits());
        d.escalate(10.0);
        assert_eq!(d.lambda(), 5.0);
        d.escalate(1e9);
        assert_eq!(d.lambda(), 1e3, "escalation respects the LM ceiling");
        assert_eq!(d.runaway_threshold(), Some(1e3));

        let mut c = DampingSchedule::Constant { lambda: 0.01 };
        c.escalate(10.0);
        assert!((c.lambda() - 0.1).abs() < 1e-15, "rescue overrides constancy");
        assert_eq!(c.runaway_threshold(), None);
    }

    #[test]
    fn lm_adapts_both_directions() {
        let mut d = DampingSchedule::LevenbergMarquardt {
            lambda: 1.0,
            grow: 3.0,
            shrink: 0.5,
            min: 1e-8,
            max: 1e4,
        };
        d.advance(true);
        assert!((d.lambda() - 0.5).abs() < 1e-15);
        d.advance(false);
        assert!((d.lambda() - 1.5).abs() < 1e-15);
        // Caps respected.
        for _ in 0..100 {
            d.advance(false);
        }
        assert_eq!(d.lambda(), 1e4);
        for _ in 0..100 {
            d.advance(true);
        }
        assert_eq!(d.lambda(), 1e-8);
    }
}
