//! Synthetic supervised tasks for the ridge-regression and
//! Levenberg–Marquardt examples and for optimizer tests.

use super::rng::Rng;
use crate::linalg::Mat;

/// A planted linear-regression task: `y = X wᵀ + ε` with wide features
/// (m ≫ n), the regime the paper targets.
pub struct RegressionTask {
    /// Design matrix, n×m.
    pub x: Mat,
    /// Targets, length n.
    pub y: Vec<f64>,
    /// Planted coefficient vector, length m.
    pub w_true: Vec<f64>,
    /// Noise std used.
    pub noise: f64,
}

/// Generate a wide regression task with `sparsity` fraction of nonzero
/// planted coefficients.
pub fn regression_task(n: usize, m: usize, noise: f64, sparsity: f64, rng: &mut Rng) -> RegressionTask {
    let x = Mat::randn(n, m, rng);
    let mut w_true = vec![0.0; m];
    for w in w_true.iter_mut() {
        if rng.bernoulli(sparsity) {
            *w = rng.normal();
        }
    }
    let mut y = x.matvec(&w_true);
    for yi in &mut y {
        *yi += noise * rng.normal();
    }
    RegressionTask { x, y, w_true, noise }
}

/// Two-class Gaussian-blob classification: returns `(features n×d,
/// labels ±1)`. Used by the NGD-vs-SGD optimizer tests.
pub fn classification_task(n: usize, d: usize, separation: f64, rng: &mut Rng) -> (Mat, Vec<f64>) {
    let mut x = Mat::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let label = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
        y[i] = label;
        for j in 0..d {
            let center = if j == 0 { label * separation } else { 0.0 };
            x[(i, j)] = center + rng.normal();
        }
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regression_shapes_and_noise() {
        let mut rng = Rng::seed_from(80);
        let t = regression_task(20, 100, 0.0, 0.2, &mut rng);
        assert_eq!(t.x.shape(), (20, 100));
        assert_eq!(t.y.len(), 20);
        assert_eq!(t.w_true.len(), 100);
        // Noise-free: y == X w exactly.
        let pred = t.x.matvec(&t.w_true);
        for (p, yi) in pred.iter().zip(&t.y) {
            assert!((p - yi).abs() < 1e-12);
        }
    }

    #[test]
    fn classification_is_separable_in_first_coordinate() {
        let mut rng = Rng::seed_from(81);
        let (x, y) = classification_task(500, 5, 4.0, &mut rng);
        let correct = (0..500)
            .filter(|&i| (x[(i, 0)] > 0.0) == (y[i] > 0.0))
            .count();
        assert!(correct > 480, "separation should make coordinate 0 predictive");
    }
}
