//! Synthetic character-level corpus + tokenizer for the end-to-end
//! language-model training example.
//!
//! The generator emits text from a small stochastic grammar (subject–verb–
//! object sentences over a fixed vocabulary with punctuation and digit
//! "measurements"), giving the LM real low-entropy structure to learn:
//! the loss curve must drop well below the uniform-distribution entropy
//! for the end-to-end NGD run to count as validated.

use super::rng::Rng;
use std::collections::BTreeMap;

/// Character-level tokenizer with a stable, data-derived vocabulary.
#[derive(Clone, Debug)]
pub struct CharTokenizer {
    to_id: BTreeMap<char, u32>,
    to_char: Vec<char>,
}

impl CharTokenizer {
    /// Build the vocabulary from a corpus (sorted for determinism).
    pub fn fit(text: &str) -> Self {
        let mut chars: Vec<char> = {
            let mut set: Vec<char> = text.chars().collect();
            set.sort();
            set.dedup();
            set
        };
        chars.shrink_to_fit();
        let to_id = chars.iter().enumerate().map(|(i, &c)| (c, i as u32)).collect();
        CharTokenizer { to_id, to_char: chars }
    }

    pub fn vocab_size(&self) -> usize {
        self.to_char.len()
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars().filter_map(|c| self.to_id.get(&c).copied()).collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter().map(|&i| self.to_char[i as usize]).collect()
    }
}

/// Stochastic-grammar corpus generator.
pub struct SyntheticCorpus;

const SUBJECTS: &[&str] = &[
    "the fisher matrix", "the score matrix", "the damping term", "the gradient",
    "the optimizer", "the wavefunction", "the sampler", "the cholesky factor",
];
const VERBS: &[&str] = &[
    "conditions", "scales", "dominates", "stabilizes", "precedes", "updates",
    "factorizes", "contracts",
];
const OBJECTS: &[&str] = &[
    "the parameter space", "the natural gradient", "the gram matrix",
    "the triangular solve", "the sample batch", "the energy estimate",
    "the trust region", "the loss landscape",
];

impl SyntheticCorpus {
    /// Generate ~`target_len` characters of grammar text, deterministic in
    /// the RNG state.
    pub fn generate(target_len: usize, rng: &mut Rng) -> String {
        let mut out = String::with_capacity(target_len + 64);
        while out.len() < target_len {
            let s = SUBJECTS[rng.below(SUBJECTS.len())];
            let v = VERBS[rng.below(VERBS.len())];
            let o = OBJECTS[rng.below(OBJECTS.len())];
            out.push_str(s);
            out.push(' ');
            out.push_str(v);
            out.push(' ');
            out.push_str(o);
            if rng.bernoulli(0.25) {
                // Numeric "measurement" clause keeps digits in-vocabulary.
                out.push_str(" by ");
                out.push(char::from(b'0' + rng.below(10) as u8));
                out.push('.');
                out.push(char::from(b'0' + rng.below(10) as u8));
                out.push_str("x");
            }
            out.push_str(". ");
        }
        out.truncate(target_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip() {
        let text = "hello world 0.5x.";
        let tok = CharTokenizer::fit(text);
        let ids = tok.encode(text);
        assert_eq!(tok.decode(&ids), text);
        assert!(tok.vocab_size() <= text.len());
    }

    #[test]
    fn tokenizer_skips_oov() {
        let tok = CharTokenizer::fit("ab");
        assert_eq!(tok.encode("aZb"), vec![0, 1]);
    }

    #[test]
    fn corpus_deterministic_and_sized() {
        let mut r1 = Rng::seed_from(5);
        let mut r2 = Rng::seed_from(5);
        let a = SyntheticCorpus::generate(1000, &mut r1);
        let b = SyntheticCorpus::generate(1000, &mut r2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1000);
    }

    #[test]
    fn corpus_has_low_entropy_structure() {
        let mut rng = Rng::seed_from(6);
        let text = SyntheticCorpus::generate(50_000, &mut rng);
        let tok = CharTokenizer::fit(&text);
        // Unigram entropy must be well below log2(vocab) — i.e. learnable.
        let ids = tok.encode(&text);
        let mut counts = vec![0usize; tok.vocab_size()];
        for &i in &ids {
            counts[i as usize] += 1;
        }
        let n = ids.len() as f64;
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum();
        let hmax = (tok.vocab_size() as f64).log2();
        assert!(h < 0.95 * hmax, "H={h:.3} Hmax={hmax:.3}");
    }
}
