//! Synthetic data substrate: deterministic RNG, corpora, task generators
//! and batching.
//!
//! The paper benchmarks on random score matrices and motivates the method
//! with neural-network training and quantum Monte Carlo workloads; this
//! module provides the deterministic synthetic equivalents used by the
//! examples, benches and the end-to-end trainer.

pub mod batch;
pub mod corpus;
pub mod rng;
pub mod tasks;

pub use batch::BatchIter;
pub use corpus::{CharTokenizer, SyntheticCorpus};
pub use rng::Rng;
pub use tasks::{classification_task, regression_task, RegressionTask};
