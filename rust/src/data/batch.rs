//! Mini-batch iteration over token streams.

use super::rng::Rng;

/// Iterator yielding `(context, target)` windows from a token stream for
/// next-token-prediction training. Sampling is with replacement from
/// uniformly random offsets (standard LM practice), deterministic in the
/// RNG.
pub struct BatchIter<'a> {
    tokens: &'a [u32],
    context: usize,
    batch: usize,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(tokens: &'a [u32], context: usize, batch: usize, rng: Rng) -> Self {
        assert!(tokens.len() > context + 1, "token stream shorter than context");
        BatchIter { tokens, context, batch, rng }
    }

    /// The iterator's RNG — its state *is* the data cursor (offsets are
    /// sampled with replacement straight from the stream), so
    /// checkpointing it via [`Rng::state`] captures the exact batch
    /// sequence position for bit-identical resume.
    pub fn rng(&self) -> &Rng {
        &self.rng
    }

    /// Next batch: `batch` rows of `context` input ids plus the target id
    /// following each window.
    pub fn next_batch(&mut self) -> (Vec<Vec<u32>>, Vec<u32>) {
        let max_start = self.tokens.len() - self.context - 1;
        let mut xs = Vec::with_capacity(self.batch);
        let mut ys = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let s = self.rng.below(max_start + 1);
            xs.push(self.tokens[s..s + self.context].to_vec());
            ys.push(self.tokens[s + self.context]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_valid_windows() {
        let tokens: Vec<u32> = (0..100u32).collect();
        let mut it = BatchIter::new(&tokens, 8, 4, Rng::seed_from(3));
        for _ in 0..10 {
            let (xs, ys) = it.next_batch();
            assert_eq!(xs.len(), 4);
            assert_eq!(ys.len(), 4);
            for (x, &y) in xs.iter().zip(&ys) {
                assert_eq!(x.len(), 8);
                // windows are consecutive and the target follows
                for k in 1..8 {
                    assert_eq!(x[k], x[k - 1] + 1);
                }
                assert_eq!(y, x[7] + 1);
            }
        }
    }

    #[test]
    #[should_panic]
    fn rejects_short_streams() {
        let tokens: Vec<u32> = (0..5u32).collect();
        BatchIter::new(&tokens, 8, 2, Rng::seed_from(0));
    }
}
