//! Deterministic pseudo-random numbers — xoshiro256++ (Blackman & Vigna),
//! implemented from scratch so every experiment in EXPERIMENTS.md is
//! exactly reproducible across platforms with a single `u64` seed.

/// xoshiro256++ generator with SplitMix64 seeding and a cached
/// Box–Muller normal variate.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used only to expand the seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single `u64`.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift (bias < 2⁻⁶⁴·n,
        // negligible for all n used here).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one spare cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Coin flip with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fork an independent stream (for per-worker RNGs in the
    /// coordinator: leader seeds workers with `fork(worker_id)`).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Snapshot the full generator state — the xoshiro words plus the
    /// cached Box–Muller spare. Together with [`Rng::from_state`] this
    /// makes the stream position checkpointable: a training run resumed
    /// from a saved state draws exactly the offsets/variates the
    /// unfailed run would have drawn (the kill-anywhere guarantee).
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.cached_normal)
    }

    /// Rebuild a generator at a saved stream position (see [`Rng::state`]).
    pub fn from_state(s: [u64; 4], cached_normal: Option<f64>) -> Rng {
        Rng { s, cached_normal }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(123);
        let mut b = Rng::seed_from(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::seed_from(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(8);
        let n = 200_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64;
        let kurt = s4 / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis={kurt}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::seed_from(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = rng.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_independent() {
        let root = Rng::seed_from(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
        // Forks are themselves deterministic.
        let mut a2 = root.fork(0);
        let mut a3 = root.fork(0);
        for _ in 0..32 {
            assert_eq!(a2.next_u64(), a3.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream_exactly() {
        let mut a = Rng::seed_from(77);
        // Burn an odd number of normals so the Box–Muller spare is live.
        for _ in 0..7 {
            a.normal();
        }
        let (s, cached) = a.state();
        assert!(cached.is_some(), "odd normal count must leave a cached spare");
        let mut b = Rng::from_state(s, cached);
        for _ in 0..32 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
            assert_eq!(a.below(1000), b.below(1000));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(10);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
