//! Benchmark table generators — the code that regenerates every table and
//! figure in the paper's evaluation section, shared by the `benches/*.rs`
//! harnesses and the `dngd bench` CLI.
//!
//! * [`table1`]   — Table 1 (all ten rows): chol vs eigh vs svda wall
//!   times, including the svda `N/A (mem)` cell from the memory model.
//! * [`scaling`]  — Fig. 1's two panels with fitted exponents against the
//!   dotted ideal lines (2 for the n-sweep, 1 for the m-sweep).
//! * [`cg_conditioning`] — §3's iterative-method remark: CG iteration
//!   blow-up vs condition number while chol stays flat.
//! * [`kernel_bench`] — per-kernel GFLOP/s for the packed engine vs the
//!   seed scalar kernels, emitted as machine-readable JSON
//!   (`BENCH_PR1.json`) so later PRs have a trajectory to beat.
//! * [`session_bench`] — PR 2's amortization table: k one-shot solves vs
//!   factor-once + blocked multi-RHS + λ-resweeps on the cached Gram,
//!   emitted as `BENCH_PR2.json` (`dngd bench --sessions`).
//! * [`thread_bench`] — PR 3's thread-scaling table: every stage of the
//!   dense pipeline (SYRK, GEMM, Cholesky, multi-RHS TRSM) plus the
//!   end-to-end chol session, swept over 1/2/4/8 pool threads with a
//!   bit-identity check against the serial result on every row, emitted
//!   as `BENCH_PR3.json` (`dngd bench --threads`).
//! * [`simd_bench`] — PR 4's ISA-tier roofline table: every stage
//!   single-threaded at the scalar tier vs the best dispatched tier
//!   (AVX-512 / AVX2 / NEON), with per-stage GF/s and speedups, emitted
//!   as `BENCH_PR4.json` (also from `dngd bench --kernels`, which
//!   reports the active tier). Full mode asserts the PR-4 acceptance
//!   bar: best tier ≥ 2× scalar on 512³ DGEMM single-threaded.
//! * [`streaming_bench`] — PR 5's sliding-window table: per-step cost
//!   of rotating k window rows through the chol owned-window session
//!   (Gram patch + O(kn²) factor rotation + solve) vs the cold factor
//!   path, with a reconstruct-and-compare correctness gate, emitted as
//!   `BENCH_PR5.json` (`dngd bench --streaming`). Full mode asserts
//!   the PR-5 acceptance bar: ≥ 5× at ≤10% rotation, n = 512.
//! * [`precision_bench`] — PR 6's mixed-precision table: f32 vs f64
//!   GEMM/SYRK kernel throughput single-threaded on the active tier,
//!   plus the end-to-end mixed session (f32 factor + f64 iterative
//!   refinement) vs the pure-f64 session, with the measured relative
//!   error and refinement sweep count, emitted as `BENCH_PR6.json`
//!   (`dngd bench --precision`). Full mode asserts the PR-6 acceptance
//!   bar: f32 GEMM and SYRK ≥ 1.5× f64 at 512³ single-threaded on the
//!   best tier (skipped when scalar is the active tier).
//! * [`serving_bench`] — PR 7's multi-tenant serving table: requests/sec
//!   and client-observed p50/p99 latency at 1/4/16 concurrent tenants
//!   hammering one cached session, coalesced dispatch (cross-tenant
//!   `solve_many` panels per tick) vs serial per-request dispatch, with
//!   a per-tenant correctness gate against the serial session, emitted
//!   as `BENCH_PR7.json` (`dngd bench --serving`). Full mode asserts
//!   the PR-7 acceptance bar: coalesced ≥ 2× serial req/s at 16
//!   tenants with no worse p99.
//! * [`structured_bench`] — PR 10's structured-Fisher table: factor +
//!   solve wall times for exact chol vs the structured family
//!   (blockdiag, kpsvd, hybrid) at block counts {1, 4, 16, 64} on one
//!   fixed shape, plus hybrid-PCG vs plain-CG iteration counts on a
//!   block-scaled synthetic Fisher, emitted as `BENCH_PR10.json`
//!   (`dngd bench --structured`). Strict mode asserts the PR-10
//!   acceptance bar: single-block blockdiag bit-identical to chol, and
//!   strictly fewer PCG than CG iterations on every multi-block row.
//!
//! `paper=false` runs a proportionally scaled-down grid (CPU testbed);
//! `paper=true` runs the paper's exact shapes (slow on CPU — hours).

use crate::data::rng::Rng;
use crate::linalg::Mat;
use crate::metrics::{bench, fit_power_law};
use crate::solver::{
    flops, make_solver, BlockDiagSolver, BlockKind, CgSolver, CholSolver, DampedSolver,
    HybridCgSolver, KpSvdSolver, SolveError, SolverKind,
};
use std::path::Path;

/// Table-1 shape grid. The scaled-down grid divides the paper's n by 8
/// and m by ~12 so the full table runs in minutes on CPU while keeping
/// the same n-vs-m aspect progression (and the same n·m ordering that
/// triggers the svda memory cell — which is evaluated with the *paper's*
/// shapes regardless, since it is a pure model).
pub fn table1_shapes(paper: bool) -> Vec<(usize, usize)> {
    if paper {
        vec![
            (256, 100_000),
            (512, 100_000),
            (1024, 100_000),
            (2048, 100_000),
            (4096, 100_000),
            (2048, 10_000),
            (2048, 20_000),
            (2048, 50_000),
            (2048, 100_000),
            (2048, 200_000),
        ]
    } else {
        vec![
            (32, 8192),
            (64, 8192),
            (128, 8192),
            (256, 8192),
            (512, 8192),
            (256, 1024),
            (256, 2048),
            (256, 4096),
            (256, 8192),
            (256, 16384),
        ]
    }
}

fn run_method(kind: SolverKind, s: &Mat, v: &[f64], lambda: f64) -> Result<f64, SolveError> {
    let solver = make_solver(kind);
    // Correctness gate before timing: the benchmark must measure a
    // *correct* solver.
    let x = solver.solve(s, v, lambda)?;
    let r = crate::solver::residual_norm(s, &x, v, lambda);
    // Backward-error gate: ‖r‖ ≲ ε·(‖F‖·‖x‖ + ‖v‖) with ‖F‖ ≈ ‖S‖_F².
    // (An absolute gate on ‖r‖/‖v‖ would spuriously fail the SVD methods
    // at small λ, where ‖x‖ ≫ ‖v‖ amplifies benign orthogonality error.)
    let fro = s.fro_norm();
    let scale = fro * fro * crate::linalg::mat::norm2(&x) + crate::linalg::mat::norm2(v);
    assert!(r < 1e-9 * scale.max(1.0), "{} residual {r} (scale {scale:.3e})", kind.as_str());
    let result = bench(kind.as_str(), 3, 1.0, || {
        let _ = std::hint::black_box(solver.solve(s, v, lambda));
    });
    Ok(result.median_ms())
}

/// Print Table 1: per-shape medians for chol / eigh / svda plus speedups.
pub fn table1(paper: bool) {
    let lambda = 1e-3;
    println!("Table 1 reproduction — time per damped solve (median ms)");
    println!("{:>18} | {:>10} | {:>10} | {:>10} | eigh/chol | svda/chol", "shape (n, m)", "chol", "eigh", "svda");
    let mut rng = Rng::seed_from(1234);
    for (n, m) in table1_shapes(paper) {
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let chol = run_method(SolverKind::Chol, &s, &v, lambda).expect("chol");
        let eigh = run_method(SolverKind::Eigh, &s, &v, lambda).expect("eigh");
        // svda carries the paper's 80 GB A100 memory model, evaluated at
        // the PAPER's shape for this row so the N/A cell reproduces even
        // on the scaled grid.
        let paper_shape = paper_shape_for(n, m, paper);
        let svda_mem = crate::solver::memory_bytes(SolverKind::Svda, paper_shape.0, paper_shape.1);
        let budget = crate::solver::MemoryBudget::a100_80gb();
        let svda = if budget.fits(svda_mem) {
            Some(run_method(SolverKind::Svda, &s, &v, lambda).expect("svda"))
        } else {
            None
        };
        match svda {
            Some(sv) => println!(
                "({n:>6},{m:>9}) | {chol:>8.2}ms | {eigh:>8.2}ms | {sv:>8.2}ms | {:>9.2} | {:>9.2}",
                eigh / chol,
                sv / chol
            ),
            None => println!(
                "({n:>6},{m:>9}) | {chol:>8.2}ms | {eigh:>8.2}ms | {:>10} | {:>9.2} |       N/A",
                "N/A (mem)",
                eigh / chol
            ),
        }
    }
    println!("\npaper (A100): chol ≈ 2.5–5× faster than eigh, ≈ 6–40× than svda; svda N/A at (4096, 100000).");
}

/// Map a scaled-grid row back to the paper's corresponding shape (for
/// the memory model). On the paper grid it is the identity.
fn paper_shape_for(n: usize, m: usize, paper: bool) -> (usize, usize) {
    if paper {
        return (n, m);
    }
    let scaled = table1_shapes(false);
    let orig = table1_shapes(true);
    scaled
        .iter()
        .position(|&(a, b)| (a, b) == (n, m))
        .map(|i| orig[i])
        .unwrap_or((n, m))
}

/// Fig. 1 with fitted exponents: time vs n at fixed m, time vs m at
/// fixed n, for all three methods; overlays the ideal-scaling fit.
pub fn scaling(paper: bool) {
    let lambda = 1e-3;
    let (n_sweep, m_sweep): (Vec<(usize, usize)>, Vec<(usize, usize)>) = if paper {
        (table1_shapes(true)[0..5].to_vec(), table1_shapes(true)[5..10].to_vec())
    } else {
        (table1_shapes(false)[0..5].to_vec(), table1_shapes(false)[5..10].to_vec())
    };
    let mut rng = Rng::seed_from(4321);
    for (label, sweep, axis, ideal) in [
        ("Fig 1 left: time vs n (fixed m)", n_sweep, 0usize, 2.0),
        ("Fig 1 right: time vs m (fixed n)", m_sweep, 1usize, 1.0),
    ] {
        println!("\n== {label} ==");
        let mut xs = Vec::new();
        let mut chol_ts = Vec::new();
        println!("{:>18} | {:>10} | {:>10} | {:>10}", "shape", "chol", "eigh", "svda");
        for &(n, m) in &sweep {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let chol = run_method(SolverKind::Chol, &s, &v, lambda).expect("chol");
            let eigh = run_method(SolverKind::Eigh, &s, &v, lambda).expect("eigh");
            let svda = run_method(SolverKind::Svda, &s, &v, lambda).expect("svda");
            println!("({n:>6},{m:>9}) | {chol:>8.2}ms | {eigh:>8.2}ms | {svda:>8.2}ms");
            xs.push(if axis == 0 { n as f64 } else { m as f64 });
            chol_ts.push(chol);
        }
        let (a, _) = fit_power_law(&xs, &chol_ts);
        println!("chol fitted exponent: {a:.2} (ideal {ideal:.0} — the paper's dotted line)");
        // Model-FLOPs ideal line for reference.
        let f0 = flops(SolverKind::Chol, sweep[0].0, sweep[0].1);
        let f1 = flops(SolverKind::Chol, sweep[4].0, sweep[4].1);
        println!(
            "model-FLOP ratio across sweep: {:.1}× (measured {:.1}×)",
            f1 / f0,
            chol_ts[4] / chol_ts[0]
        );
    }
}

/// One row of the kernel benchmark: a named kernel at a shape, with the
/// median wall time and achieved GFLOP/s.
#[derive(Debug, Clone)]
pub struct KernelBenchRow {
    pub kernel: &'static str,
    /// Gram/output order n (or the square size for the GEMM rows).
    pub n: usize,
    /// Reduction dimension m (0 where not applicable).
    pub m: usize,
    /// Right-hand-side count for the TRSM row (0 elsewhere).
    pub k: usize,
    pub threads: usize,
    pub median_ms: f64,
    pub gflops: f64,
}

fn krow(
    kernel: &'static str,
    n: usize,
    m: usize,
    k: usize,
    threads: usize,
    flops: f64,
    run: impl FnMut(),
) -> KernelBenchRow {
    let budget = if flops > 1e10 { 1.0 } else { 0.2 };
    let r = bench(kernel, 3, budget, run);
    let median_s = r.summary.median;
    KernelBenchRow {
        kernel,
        n,
        m,
        k,
        threads,
        median_ms: median_s * 1e3,
        gflops: flops / median_s / 1e9,
    }
}

/// Kernel-level before/after benchmark: the packed engine vs the seed
/// scalar kernels on the Algorithm-1 hot path (SYRK → Cholesky → TRSM),
/// plus the end-to-end `CholSolver` wall time. `quick` shrinks every
/// shape for CI smoke runs.
pub fn kernel_bench(quick: bool) -> Vec<KernelBenchRow> {
    use crate::linalg::gemm::{self, reference};
    use crate::linalg::{cholesky, solve_lower_multi, solve_lower_transpose_multi};

    let mut rng = Rng::seed_from(9);
    let (n, m, sq, rhs) = if quick { (96, 512, 96, 8) } else { (1024, 8192, 1024, 256) };
    let mut rows = Vec::new();

    // --- SYRK (Algorithm 1 line 1, the O(n²m) stage) ---
    let s = Mat::randn(n, m, &mut rng);
    let syrk_fl = (n * n) as f64 * m as f64;
    rows.push(krow("syrk_scalar_seed", n, m, 0, 1, syrk_fl, || {
        std::hint::black_box(reference::syrk_scalar(&s, 1e-3));
    }));
    rows.push(krow("syrk_packed", n, m, 0, 1, syrk_fl, || {
        std::hint::black_box(gemm::syrk(&s, 1e-3));
    }));
    for threads in [2usize, 4, 8] {
        rows.push(krow("syrk_packed", n, m, 0, threads, syrk_fl, || {
            std::hint::black_box(gemm::syrk_parallel(&s, 1e-3, threads));
        }));
    }

    // --- Square GEMM (the trailing-update shape) ---
    let a = Mat::randn(sq, sq, &mut rng);
    let b = Mat::randn(sq, sq, &mut rng);
    let gemm_fl = 2.0 * (sq as f64).powi(3);
    let mut c = Mat::zeros(sq, sq);
    rows.push(krow("gemm_nt_scalar_seed", sq, sq, sq, 1, gemm_fl, || {
        reference::gemm_nt_scalar(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    }));
    let mut c = Mat::zeros(sq, sq);
    rows.push(krow("gemm_nt_packed", sq, sq, sq, 1, gemm_fl, || {
        gemm::gemm_nt(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    }));
    let mut c = Mat::zeros(sq, sq);
    rows.push(krow("gemm_nn_packed", sq, sq, sq, 1, gemm_fl, || {
        gemm::gemm(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    }));

    // --- Cholesky (Algorithm 1 line 2) + blocked multi-RHS TRSM ---
    let w = gemm::syrk(&Mat::randn(n, n + 8, &mut rng), 1.0);
    let chol_fl = (n as f64).powi(3) / 3.0;
    rows.push(krow("cholesky_blocked", n, 0, 0, 1, chol_fl, || {
        std::hint::black_box(cholesky(&w).unwrap());
    }));
    let l = cholesky(&w).unwrap();
    let bmat = Mat::randn(n, rhs, &mut rng);
    let trsm_fl = 2.0 * (n * n) as f64 * rhs as f64;
    rows.push(krow("trsm_multi_fwd_adj", n, 0, rhs, 1, trsm_fl, || {
        let y = solve_lower_multi(&l, &bmat);
        std::hint::black_box(solve_lower_transpose_multi(&l, &y));
    }));

    // --- End-to-end Algorithm 1 ---
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let e2e_fl = syrk_fl + chol_fl;
    for threads in [1usize, 8] {
        let solver = CholSolver::with_threads(threads);
        rows.push(krow("chol_solver_e2e", n, m, 0, threads, e2e_fl, || {
            std::hint::black_box(solver.solve(&s, &v, 1e-3).unwrap());
        }));
    }
    rows
}

/// Render kernel-bench rows as the machine-readable `BENCH_PR1.json`
/// payload (hand-rolled JSON — the build is offline, no serde).
pub fn kernel_bench_json(rows: &[KernelBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 1,\n");
    out.push_str("  \"bench\": \"kernel\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str("  \"unit\": {\"median_ms\": \"milliseconds\", \"gflops\": \"GFLOP/s\"},\n");
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"kernel\": \"{}\", \"n\": {}, \"m\": {}, \"k\": {}, \"threads\": {}, \
                 \"median_ms\": {:.3}, \"gflops\": {:.2}}}",
                r.kernel, r.n, r.m, r.k, r.threads, r.median_ms, r.gflops
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the kernel benchmark, print the table, and (optionally) write the
/// JSON payload.
pub fn kernel_bench_report(quick: bool, json_path: Option<&Path>) -> std::io::Result<()> {
    let rows = kernel_bench(quick);
    println!(
        "{:>22} | {:>6} | {:>6} | {:>4} | {:>3} | {:>10} | {:>8}",
        "kernel", "n", "m", "k", "thr", "median", "GFLOP/s"
    );
    for r in &rows {
        println!(
            "{:>22} | {:>6} | {:>6} | {:>4} | {:>3} | {:>8.2}ms | {:>8.2}",
            r.kernel, r.n, r.m, r.k, r.threads, r.median_ms, r.gflops
        );
    }
    if let Some(path) = json_path {
        std::fs::write(path, kernel_bench_json(&rows, quick))?;
        println!("kernel bench table written to {}", path.display());
    }
    Ok(())
}

/// One row of the session (amortization) benchmark.
#[derive(Debug, Clone)]
pub struct SessionBenchRow {
    pub n: usize,
    pub m: usize,
    /// Right-hand-side count.
    pub k: usize,
    /// k independent one-shot solves (the pre-PR-2 consumer pattern).
    pub cold_ms: f64,
    /// One session factor: Gram (O(n²m)) + Cholesky (O(n³)).
    pub factor_ms: f64,
    /// Blocked k-RHS back-substitution against the cached factor.
    pub solve_many_ms: f64,
    /// One λ-resweep on the cached Gram (O(n³) refactor, zero GEMMs on
    /// the Gram path).
    pub resweep_ms: f64,
    /// `cold_ms / (factor_ms + solve_many_ms)`.
    pub speedup: f64,
}

/// The PR-2 amortization benchmark: cold vs session solve latency for the
/// Algorithm-1 solver at the acceptance shapes (n ∈ {256, 1024},
/// m = 16384, k = 8; `quick` shrinks for CI smoke).
pub fn session_bench(quick: bool) -> Vec<SessionBenchRow> {
    let ns: &[usize] = if quick { &[64, 128] } else { &[256, 1024] };
    let (m, k) = if quick { (2048usize, 8usize) } else { (16384, 8) };
    let lambda = 1e-3;
    let ms = |t0: std::time::Instant| t0.elapsed().as_secs_f64() * 1e3;
    let mut rng = Rng::seed_from(20);
    let mut rows = Vec::new();
    for &n in ns {
        let s = Mat::randn(n, m, &mut rng);
        let vs = Mat::randn(k, m, &mut rng);
        let solver = CholSolver::default();

        // Cold: k independent one-shot solves.
        let t0 = std::time::Instant::now();
        for r in 0..k {
            std::hint::black_box(solver.solve(&s, vs.row(r), lambda).expect("cold solve"));
        }
        let cold_ms = ms(t0);

        // Session: factor once, then one blocked k-RHS solve.
        let t0 = std::time::Instant::now();
        let mut fact = solver.factor(&s, lambda).expect("factor");
        let factor_ms = ms(t0);
        let t0 = std::time::Instant::now();
        let x = fact.solve_many(&vs).expect("solve_many");
        std::hint::black_box(&x);
        let solve_many_ms = ms(t0);

        // Correctness gate: the benchmark must measure a correct session.
        let fro = s.fro_norm();
        for r in 0..k {
            let res = crate::solver::residual_norm(&s, x.row(r), vs.row(r), lambda);
            let scale = fro * fro * crate::linalg::mat::norm2(x.row(r))
                + crate::linalg::mat::norm2(vs.row(r));
            assert!(res < 1e-9 * scale.max(1.0), "session residual {res} (rhs {r})");
        }

        // λ-resweep on the cached Gram.
        let t0 = std::time::Instant::now();
        let sweep = [1e-2, 1e-4, 1e-3];
        for &l in &sweep {
            fact.redamp(l).expect("redamp");
        }
        let resweep_ms = ms(t0) / sweep.len() as f64;

        let speedup = cold_ms / (factor_ms + solve_many_ms).max(1e-9);
        rows.push(SessionBenchRow {
            n,
            m,
            k,
            cold_ms,
            factor_ms,
            solve_many_ms,
            resweep_ms,
            speedup,
        });
    }
    rows
}

/// Render session-bench rows as the `BENCH_PR2.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn session_bench_json(rows: &[SessionBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 2,\n");
    out.push_str("  \"bench\": \"sessions\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(
        "  \"unit\": {\"*_ms\": \"milliseconds\", \"speedup\": \"cold / (factor + solve_many)\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"n\": {}, \"m\": {}, \"k\": {}, \"cold_ms\": {:.3}, \"factor_ms\": {:.3}, \
                 \"solve_many_ms\": {:.3}, \"resweep_ms\": {:.3}, \"speedup\": {:.2}}}",
                r.n, r.m, r.k, r.cold_ms, r.factor_ms, r.solve_many_ms, r.resweep_ms, r.speedup
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the session benchmark, print the table, optionally write JSON.
/// `strict` enforces the PR-2 acceptance bar (amortized ≥ 3× cold) —
/// used by the `cargo bench --bench sessions` harness.
pub fn session_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let rows = session_bench(quick);
    println!(
        "{:>6} | {:>6} | {:>2} | {:>10} | {:>10} | {:>10} | {:>10} | {:>7}",
        "n", "m", "k", "cold", "factor", "solve_many", "resweep/λ", "speedup"
    );
    for r in &rows {
        println!(
            "{:>6} | {:>6} | {:>2} | {:>8.1}ms | {:>8.1}ms | {:>8.1}ms | {:>8.1}ms | {:>6.2}×",
            r.n, r.m, r.k, r.cold_ms, r.factor_ms, r.solve_many_ms, r.resweep_ms, r.speedup
        );
    }
    println!(
        "\namortized = factor once + one blocked {}-RHS solve; resweep/λ = re-damp on the cached \
         Gram (no O(n²m) rework).",
        rows.first().map(|r| r.k).unwrap_or(8)
    );
    if let Some(path) = json_path {
        std::fs::write(path, session_bench_json(&rows, quick))?;
        println!("session bench table written to {}", path.display());
    }
    if strict {
        for r in &rows {
            assert!(
                r.speedup >= 3.0,
                "PR-2 acceptance: amortized path must be ≥3× cold at n={}, got {:.2}×",
                r.n,
                r.speedup
            );
        }
        println!("acceptance: all rows ≥ 3× ✓");
    }
    Ok(())
}

/// One row of the PR-3 thread-scaling benchmark.
#[derive(Debug, Clone)]
pub struct ThreadBenchRow {
    pub stage: &'static str,
    pub n: usize,
    pub m: usize,
    /// Right-hand-side count (TRSM / session rows; 0 elsewhere).
    pub k: usize,
    pub threads: usize,
    pub median_ms: f64,
    pub gflops: f64,
    /// `median(threads=1) / median(threads)`.
    pub speedup: f64,
    /// Output bit-identical to the serial (threads = 1) result.
    pub bit_identical: bool,
}

/// Thread counts swept by [`thread_bench`].
pub const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// The PR-3 thread-scaling benchmark: per-stage and end-to-end medians
/// at [`THREAD_SWEEP`] pool-thread counts (the counts are passed to the
/// kernels directly; `DNGD_THREADS` only sets the env default of
/// [`KernelConfig`](crate::linalg::KernelConfig) and does not affect
/// the sweep), with a bit-identity check of
/// every threaded output against its serial counterpart. The end-to-end row
/// is the acceptance workload: a chol session `begin` (n×m), one
/// `redamp` (Gram + lookahead Cholesky) and one 16-RHS `solve_many`.
/// `quick` shrinks the shapes for CI smoke runs.
pub fn thread_bench(quick: bool) -> Vec<ThreadBenchRow> {
    use crate::linalg::gemm::{self, syrk_parallel};
    use crate::linalg::{
        cholesky_threaded, solve_lower_multi_threaded, solve_lower_transpose_multi_threaded,
    };

    let mut rng = Rng::seed_from(31);
    let (n, m, sq, rhs) = if quick { (256, 1024, 384, 8) } else { (2048, 8192, 1024, 16) };
    let mut rows: Vec<ThreadBenchRow> = Vec::new();
    let push = |rows: &mut Vec<ThreadBenchRow>,
                    stage: &'static str,
                    n: usize,
                    m: usize,
                    k: usize,
                    threads: usize,
                    fl: f64,
                    median_ms: f64,
                    bit_identical: bool| {
        let serial_ms = rows
            .iter()
            .find(|r| r.stage == stage && r.threads == 1)
            .map(|r| r.median_ms)
            .unwrap_or(median_ms);
        rows.push(ThreadBenchRow {
            stage,
            n,
            m,
            k,
            threads,
            median_ms,
            gflops: fl / (median_ms / 1e3) / 1e9,
            speedup: serial_ms / median_ms.max(1e-9),
            bit_identical,
        });
    };

    // --- SYRK (Algorithm 1 line 1) ---
    let s = Mat::randn(n, m, &mut rng);
    let syrk_fl = (n * n) as f64 * m as f64;
    let syrk_ref = syrk_parallel(&s, 1e-3, 1);
    for threads in THREAD_SWEEP {
        let r = bench("syrk", 3, 0.5, || {
            std::hint::black_box(syrk_parallel(&s, 1e-3, threads));
        });
        let bits = syrk_parallel(&s, 1e-3, threads).as_slice() == syrk_ref.as_slice();
        push(&mut rows, "syrk", n, m, 0, threads, syrk_fl, r.median_ms(), bits);
    }

    // --- Square GEMM (trailing-update / panel-product shape) ---
    let a = Mat::randn(sq, sq, &mut rng);
    let b = Mat::randn(sq, sq, &mut rng);
    let gemm_fl = 2.0 * (sq as f64).powi(3);
    let mut gemm_ref = Mat::zeros(sq, sq);
    gemm::gemm_threaded(1.0, &a, &b, 0.0, &mut gemm_ref, 1);
    for threads in THREAD_SWEEP {
        let mut c = Mat::zeros(sq, sq);
        let r = bench("gemm", 3, 0.5, || {
            gemm::gemm_threaded(1.0, &a, &b, 0.0, &mut c, threads);
            std::hint::black_box(&c);
        });
        let mut c = Mat::zeros(sq, sq);
        gemm::gemm_threaded(1.0, &a, &b, 0.0, &mut c, threads);
        let bits = c.as_slice() == gemm_ref.as_slice();
        push(&mut rows, "gemm_nn", sq, sq, 0, threads, gemm_fl, r.median_ms(), bits);
    }

    // --- Cholesky (Algorithm 1 line 2, lookahead-threaded) ---
    let w = gemm::syrk(&Mat::randn(n, n + 8, &mut rng), 1.0);
    let chol_fl = (n as f64).powi(3) / 3.0;
    let chol_ref = cholesky_threaded(&w, 1).unwrap();
    for threads in THREAD_SWEEP {
        let r = bench("cholesky", 3, 0.5, || {
            std::hint::black_box(cholesky_threaded(&w, threads).unwrap());
        });
        let bits = cholesky_threaded(&w, threads).unwrap().as_slice() == chol_ref.as_slice();
        push(&mut rows, "cholesky", n, 0, 0, threads, chol_fl, r.median_ms(), bits);
    }

    // --- Blocked multi-RHS TRSM (fwd + adj), RHS-column panels ---
    let bmat = Mat::randn(n, rhs, &mut rng);
    let trsm_fl = 2.0 * (n * n) as f64 * rhs as f64;
    let trsm_ref = {
        let y = solve_lower_multi_threaded(&chol_ref, &bmat, 1);
        solve_lower_transpose_multi_threaded(&chol_ref, &y, 1)
    };
    for threads in THREAD_SWEEP {
        let r = bench("trsm", 3, 0.5, || {
            let y = solve_lower_multi_threaded(&chol_ref, &bmat, threads);
            std::hint::black_box(solve_lower_transpose_multi_threaded(&chol_ref, &y, threads));
        });
        let y = solve_lower_multi_threaded(&chol_ref, &bmat, threads);
        let z = solve_lower_transpose_multi_threaded(&chol_ref, &y, threads);
        let bits = z.as_slice() == trsm_ref.as_slice();
        push(&mut rows, "trsm", n, 0, rhs, threads, trsm_fl, r.median_ms(), bits);
    }

    // --- End-to-end chol session: begin → redamp → 16-RHS solve_many ---
    let vs = Mat::randn(rhs, m, &mut rng);
    let e2e_fl = syrk_fl + chol_fl + 3.0 * 2.0 * (n * m) as f64 * rhs as f64;
    let session = |threads: usize| -> Mat {
        let solver = CholSolver::with_threads(threads);
        let mut fact = solver.begin(&s);
        fact.redamp(1e-3).expect("redamp");
        fact.solve_many(&vs).expect("solve_many")
    };
    let e2e_ref = session(1);
    for threads in THREAD_SWEEP {
        let r = bench("session", 3, 0.5, || {
            std::hint::black_box(session(threads));
        });
        let bits = session(threads).as_slice() == e2e_ref.as_slice();
        push(&mut rows, "session_e2e", n, m, rhs, threads, e2e_fl, r.median_ms(), bits);
    }
    rows
}

/// Render thread-bench rows as the `BENCH_PR3.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn thread_bench_json(rows: &[ThreadBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 3,\n");
    out.push_str("  \"bench\": \"threads\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(
        "  \"unit\": {\"median_ms\": \"milliseconds\", \"gflops\": \"GFLOP/s\", \
         \"speedup\": \"median(threads=1) / median(threads)\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"stage\": \"{}\", \"n\": {}, \"m\": {}, \"k\": {}, \"threads\": {}, \
                 \"median_ms\": {:.3}, \"gflops\": {:.2}, \"speedup\": {:.2}, \
                 \"bit_identical\": {}}}",
                r.stage, r.n, r.m, r.k, r.threads, r.median_ms, r.gflops, r.speedup,
                r.bit_identical
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the thread-scaling benchmark, print the table, optionally write
/// JSON. Bit-identity is asserted in every mode (it is a correctness
/// property, not a performance one); `strict` additionally enforces the
/// PR-3 acceptance bar — end-to-end session ≥ 3× at 8 threads — which
/// the full-mode `cargo bench --bench threading` harness enables (CI
/// quick smoke skips it: CI boxes have arbitrary core counts).
pub fn thread_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let rows = thread_bench(quick);
    println!(
        "{:>12} | {:>6} | {:>6} | {:>4} | {:>3} | {:>10} | {:>8} | {:>7} | {:>4}",
        "stage", "n", "m", "k", "thr", "median", "GFLOP/s", "speedup", "bits"
    );
    for r in &rows {
        println!(
            "{:>12} | {:>6} | {:>6} | {:>4} | {:>3} | {:>8.2}ms | {:>8.2} | {:>6.2}× | {:>4}",
            r.stage,
            r.n,
            r.m,
            r.k,
            r.threads,
            r.median_ms,
            r.gflops,
            r.speedup,
            if r.bit_identical { "ok" } else { "DIFF" }
        );
    }
    println!(
        "\nspeedup = serial median / threaded median per stage; bits = threaded output \
         bit-identical to serial. Scaling saturates at the machine's core count \
         ({} available here).",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    );
    // Ideal-scaling overlay from the thread-aware cost model: what the
    // e2e session speedup would be with unlimited cores (only the
    // O(nm) streaming passes staying serial) — the dotted line the
    // measured column converges to from below.
    if let Some(e2e1) = rows.iter().find(|r| r.stage == "session_e2e" && r.threads == 1) {
        let ideal: Vec<String> = THREAD_SWEEP
            .iter()
            .map(|&t| {
                let f1 = crate::solver::flops_threaded(SolverKind::Chol, e2e1.n, e2e1.m, 1);
                let ft = crate::solver::flops_threaded(SolverKind::Chol, e2e1.n, e2e1.m, t);
                format!("{t}T {:.2}×", f1 / ft)
            })
            .collect();
        println!("model ideal (flops_threaded, chol): {}", ideal.join(", "));
    }
    if let Some(path) = json_path {
        std::fs::write(path, thread_bench_json(&rows, quick))?;
        println!("thread bench table written to {}", path.display());
    }
    for r in &rows {
        assert!(
            r.bit_identical,
            "determinism violation: {} at {} threads differs from serial",
            r.stage, r.threads
        );
    }
    if strict {
        let e2e8 = rows
            .iter()
            .find(|r| r.stage == "session_e2e" && r.threads == 8)
            .expect("session row");
        assert!(
            e2e8.speedup >= 3.0,
            "PR-3 acceptance: end-to-end session at 8 threads must be ≥3× serial, got {:.2}×",
            e2e8.speedup
        );
        println!("acceptance: session_e2e ≥ 3× at 8 threads ✓");
    }
    Ok(())
}

/// One row of the PR-4 ISA-tier benchmark.
#[derive(Debug, Clone)]
pub struct SimdBenchRow {
    pub stage: &'static str,
    /// Tier label ("scalar", "avx2", "avx512", "neon").
    pub isa: &'static str,
    pub n: usize,
    pub m: usize,
    /// Right-hand-side count (TRSM row; 0 elsewhere).
    pub k: usize,
    pub median_ms: f64,
    pub gflops: f64,
    /// `median(scalar) / median(this tier)` for the same stage.
    pub speedup_vs_scalar: f64,
}

/// The PR-4 ISA roofline benchmark: each dense-pipeline stage
/// **single-threaded** (per-core headroom is the whole point — thread
/// scaling is PR 3's table) at the forced scalar tier and at the best
/// dispatched tier. Stages: square DGEMM (the acceptance stage at 512³
/// in full mode), SYRK, blocked Cholesky, blocked multi-RHS TRSM
/// (fwd+adj), and the end-to-end one-shot chol solve.
pub fn simd_bench(quick: bool) -> Vec<SimdBenchRow> {
    use crate::linalg::gemm;
    use crate::linalg::{
        cholesky, solve_lower_multi, solve_lower_transpose_multi, with_isa, KernelIsa,
    };

    let mut rng = Rng::seed_from(41);
    let (sq, n, m, rhs) = if quick { (128usize, 96usize, 512usize, 8usize) } else { (512, 512, 4096, 128) };
    // The "best" tier is the *active* one, not raw CPUID: a forced
    // DNGD_KERNEL=scalar run (the CI fallback job, or a user avoiding
    // AVX throttling) must never execute SIMD kernels from the bench.
    let best = crate::linalg::active_isa();
    let tiers: Vec<KernelIsa> = if best == KernelIsa::Scalar {
        vec![KernelIsa::Scalar]
    } else {
        vec![KernelIsa::Scalar, best]
    };

    let a = Mat::randn(sq, sq, &mut rng);
    let b = Mat::randn(sq, sq, &mut rng);
    let s = Mat::randn(n, m, &mut rng);
    let w = gemm::syrk(&Mat::randn(n, n + 8, &mut rng), 1.0);
    let l = cholesky(&w).unwrap();
    let bmat = Mat::randn(n, rhs, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();

    let gemm_fl = 2.0 * (sq as f64).powi(3);
    let syrk_fl = (n * n) as f64 * m as f64;
    let chol_fl = (n as f64).powi(3) / 3.0;
    let trsm_fl = 2.0 * (n * n) as f64 * rhs as f64;
    let e2e_fl = syrk_fl + chol_fl;

    let mut rows: Vec<SimdBenchRow> = Vec::new();
    let push = |rows: &mut Vec<SimdBenchRow>,
                stage: &'static str,
                isa: KernelIsa,
                n: usize,
                m: usize,
                k: usize,
                fl: f64,
                r: crate::metrics::BenchResult| {
        let median_ms = r.median_ms();
        let scalar_ms = rows
            .iter()
            .find(|row| row.stage == stage && row.isa == KernelIsa::Scalar.as_str())
            .map(|row| row.median_ms)
            .unwrap_or(median_ms);
        rows.push(SimdBenchRow {
            stage,
            isa: isa.as_str(),
            n,
            m,
            k,
            median_ms,
            gflops: fl / (median_ms / 1e3) / 1e9,
            speedup_vs_scalar: scalar_ms / median_ms.max(1e-9),
        });
    };

    for &isa in &tiers {
        with_isa(isa, || {
            let mut c = Mat::zeros(sq, sq);
            let r = bench("gemm_nn", 3, 0.5, || {
                gemm::gemm(1.0, &a, &b, 0.0, &mut c);
                std::hint::black_box(&c);
            });
            push(&mut rows, "gemm_nn", isa, sq, sq, 0, gemm_fl, r);

            let r = bench("syrk", 3, 0.5, || {
                std::hint::black_box(gemm::syrk(&s, 1e-3));
            });
            push(&mut rows, "syrk", isa, n, m, 0, syrk_fl, r);

            let r = bench("cholesky", 3, 0.5, || {
                std::hint::black_box(cholesky(&w).unwrap());
            });
            push(&mut rows, "cholesky", isa, n, 0, 0, chol_fl, r);

            let r = bench("trsm", 3, 0.3, || {
                let y = solve_lower_multi(&l, &bmat);
                std::hint::black_box(solve_lower_transpose_multi(&l, &y));
            });
            push(&mut rows, "trsm", isa, n, 0, rhs, trsm_fl, r);

            let solver = CholSolver::default();
            let r = bench("e2e", 3, 0.5, || {
                std::hint::black_box(solver.solve(&s, &v, 1e-3).unwrap());
            });
            push(&mut rows, "chol_solver_e2e", isa, n, m, 0, e2e_fl, r);
        });
    }
    rows
}

/// Render SIMD-bench rows as the `BENCH_PR4.json` payload (hand-rolled
/// JSON — the build is offline, no serde).
pub fn simd_bench_json(rows: &[SimdBenchRow], quick: bool) -> String {
    use crate::linalg::KernelIsa;
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 4,\n");
    out.push_str("  \"bench\": \"simd\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"active_isa\": \"{}\",\n", crate::linalg::active_isa()));
    out.push_str(&format!(
        "  \"supported\": [{}],\n",
        KernelIsa::supported_tiers()
            .iter()
            .map(|i| format!("\"{i}\""))
            .collect::<Vec<_>>()
            .join(", ")
    ));
    out.push_str(
        "  \"unit\": {\"median_ms\": \"milliseconds\", \"gflops\": \"GFLOP/s\", \
         \"speedup_vs_scalar\": \"median(scalar) / median(tier)\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"stage\": \"{}\", \"isa\": \"{}\", \"n\": {}, \"m\": {}, \"k\": {}, \
                 \"median_ms\": {:.3}, \"gflops\": {:.2}, \"speedup_vs_scalar\": {:.2}}}",
                r.stage, r.isa, r.n, r.m, r.k, r.median_ms, r.gflops, r.speedup_vs_scalar
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the ISA-tier benchmark, print the table (including the active
/// tier, which `dngd bench --kernels` reports), optionally write
/// `BENCH_PR4.json`. `strict` enforces the PR-4 acceptance bar — best
/// dispatched tier ≥ 2× the scalar tier on the single-threaded square
/// DGEMM — which the full-mode `cargo bench --bench gemm` harness
/// enables (skipped when this host has no SIMD tier: the contract
/// compares dispatched tiers, and scalar-only hosts have one tier).
pub fn simd_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    use crate::linalg::KernelIsa;
    let active = crate::linalg::active_isa();
    let supported: Vec<&str> = KernelIsa::supported_tiers().iter().map(|i| i.as_str()).collect();
    println!(
        "active ISA tier: {active} (supported: {}; override with DNGD_KERNEL or solver.isa)",
        supported.join(", ")
    );
    let rows = simd_bench(quick);
    println!(
        "{:>16} | {:>7} | {:>5} | {:>5} | {:>4} | {:>10} | {:>8} | {:>10}",
        "stage", "isa", "n", "m", "k", "median", "GFLOP/s", "vs scalar"
    );
    for r in &rows {
        println!(
            "{:>16} | {:>7} | {:>5} | {:>5} | {:>4} | {:>8.2}ms | {:>8.2} | {:>9.2}×",
            r.stage, r.isa, r.n, r.m, r.k, r.median_ms, r.gflops, r.speedup_vs_scalar
        );
    }
    if let Some(path) = json_path {
        std::fs::write(path, simd_bench_json(&rows, quick))?;
        println!("simd bench table written to {}", path.display());
    }
    if strict {
        let best = active;
        if best == KernelIsa::Scalar {
            println!(
                "acceptance: skipped (scalar is the active tier — no SIMD tier on this host, \
                 or the process tier is forced to scalar)"
            );
        } else {
            let gemm_best = rows
                .iter()
                .find(|r| r.stage == "gemm_nn" && r.isa == best.as_str())
                .expect("best-tier gemm row");
            assert!(
                gemm_best.speedup_vs_scalar >= 2.0,
                "PR-4 acceptance: {} must be ≥2× the scalar tier on single-threaded square \
                 DGEMM, got {:.2}×",
                best,
                gemm_best.speedup_vs_scalar
            );
            println!(
                "acceptance: gemm_nn {} = {:.2}× scalar (≥ 2× required) ✓",
                best, gemm_best.speedup_vs_scalar
            );
        }
    }
    Ok(())
}

/// One row of the PR-5 streaming (sliding-window) benchmark.
#[derive(Debug, Clone)]
pub struct StreamingBenchRow {
    pub n: usize,
    pub m: usize,
    /// Rows rotated per step (the window overlap is n − k).
    pub k: usize,
    /// Cold path per step: fresh factor (Gram SYRK + Cholesky) on the
    /// rotated window + one solve.
    pub cold_ms: f64,
    /// Streaming update per step: `update_rows` (Gram patch + O(kn²)
    /// factor rotation) + the same-λ `redamp` (a no-op on a rotated
    /// session).
    pub update_ms: f64,
    /// One RHS against the rotated factor.
    pub solve_ms: f64,
    /// `cold_ms / (update_ms + solve_ms)` — the amortization factor.
    pub speedup: f64,
}

/// The PR-5 streaming benchmark: per-step cost of rotating k of the
/// window's n rows through a chol owned-window session (update + redamp
/// + solve) versus the cold factor path (fresh Gram + Cholesky + solve)
/// every consumer paid before. Full mode runs the acceptance shape
/// (n = 512, k = n/16 ≈ 6% ≤ the 10% bar); `quick` shrinks for CI
/// smoke. Fresh random rows rotate in from a cycling pool so the window
/// never degenerates to repeated rows.
pub fn streaming_bench(quick: bool) -> Vec<StreamingBenchRow> {
    let (n, m) = if quick { (96usize, 1024usize) } else { (512, 8192) };
    let k = n / 16;
    let lambda = 1e-3;
    let mut rng = Rng::seed_from(57);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    // Rotation pool: 32 distinct k-row batches; the window holds n/k
    // batches at a time, so cycling keeps it full-rank.
    let pool = Mat::randn(32 * k, m, &mut rng);
    let removed: Vec<usize> = (0..k).collect();
    let solver = CholSolver::default();

    // Cold path: what a per-step consumer pays without streaming —
    // factor the (already-rotated) window from scratch + one solve.
    let rotated = {
        let mut w = Mat::zeros(n, m);
        for i in 0..n - k {
            w.row_mut(i).copy_from_slice(s.row(i + k));
        }
        for j in 0..k {
            w.row_mut(n - k + j).copy_from_slice(pool.row(j));
        }
        w
    };
    let cold = bench("stream_cold", 3, 0.5, || {
        let mut fact = solver.factor(&rotated, lambda).expect("cold factor");
        std::hint::black_box(fact.solve(&v).expect("cold solve"));
    });

    // Warm path: one persistent owned-window session, rotated in place.
    let mut fact = solver
        .begin_window(s.clone())
        .expect("chol has an owned-window session");
    fact.redamp(lambda).expect("redamp");
    let mut batch = 0usize;
    let next_added = |batch: &mut usize| -> Mat {
        let b = *batch % 32;
        *batch += 1;
        pool.slice_rows(b * k, (b + 1) * k)
    };
    let warm = bench("stream_update", 3, 0.5, || {
        let added = next_added(&mut batch);
        fact.update_rows(&removed, &added).expect("update_rows");
        fact.redamp(lambda).expect("redamp");
        std::hint::black_box(fact.solve(&v).expect("warm solve"));
    });
    let solve_only = bench("stream_solve", 3, 0.2, || {
        std::hint::black_box(fact.solve(&v).expect("warm solve"));
    });

    // Correctness gate: reconstruct the session's window from the
    // rotation history (it is deterministic: `batch` rotations, each
    // dropping the k oldest rows and appending pool batch i % 32) and
    // pin the rotated session against a cold factor of that window to
    // the PR-5 acceptance tolerance of 1e-9 — measured, not assumed.
    {
        let mut rows: Vec<(bool, usize)> = (0..n).map(|i| (false, i)).collect();
        for i in 0..batch {
            rows.drain(..k);
            let b = i % 32;
            rows.extend((b * k..(b + 1) * k).map(|r| (true, r)));
        }
        let mut expected = Mat::zeros(n, m);
        for (i, &(from_pool, idx)) in rows.iter().enumerate() {
            let src = if from_pool { pool.row(idx) } else { s.row(idx) };
            expected.row_mut(i).copy_from_slice(src);
        }
        let warm_x = fact.solve(&v).expect("warm solve");
        let cold_x = solver.solve(&expected, &v, lambda).expect("cold check");
        let scale = crate::linalg::mat::norm2(&cold_x).max(1.0);
        for (a, b) in warm_x.iter().zip(&cold_x) {
            assert!(
                (a - b).abs() < 1e-9 * scale,
                "rotated session diverged from the cold factor: {a} vs {b}"
            );
        }
    }

    let cold_ms = cold.median_ms();
    let warm_ms = warm.median_ms();
    let solve_ms = solve_only.median_ms();
    let update_ms = (warm_ms - solve_ms).max(0.0);
    vec![StreamingBenchRow {
        n,
        m,
        k,
        cold_ms,
        update_ms,
        solve_ms,
        speedup: cold_ms / warm_ms.max(1e-9),
    }]
}

/// Render streaming-bench rows as the `BENCH_PR5.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn streaming_bench_json(rows: &[StreamingBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 5,\n");
    out.push_str("  \"bench\": \"streaming\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(
        "  \"unit\": {\"*_ms\": \"milliseconds\", \"speedup\": \"cold / (update + solve)\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"n\": {}, \"m\": {}, \"k\": {}, \"cold_ms\": {:.3}, \
                 \"update_ms\": {:.3}, \"solve_ms\": {:.3}, \"speedup\": {:.2}}}",
                r.n, r.m, r.k, r.cold_ms, r.update_ms, r.solve_ms, r.speedup
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the streaming benchmark, print the table, optionally write
/// `BENCH_PR5.json`. `strict` enforces the PR-5 acceptance bar —
/// rotating ≤10% of a 512-row window end-to-end (update + redamp +
/// solve) ≥ 5× faster than the cold factor path — enabled by the
/// full-mode `cargo bench --bench streaming` harness (quick mode skips
/// it: tiny shapes under-amortize the fixed per-call overheads).
pub fn streaming_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let rows = streaming_bench(quick);
    println!(
        "{:>6} | {:>6} | {:>4} | {:>10} | {:>10} | {:>10} | {:>7}",
        "n", "m", "k", "cold", "update", "solve", "speedup"
    );
    for r in &rows {
        println!(
            "{:>6} | {:>6} | {:>4} | {:>8.2}ms | {:>8.2}ms | {:>8.2}ms | {:>6.2}×",
            r.n, r.m, r.k, r.cold_ms, r.update_ms, r.solve_ms, r.speedup
        );
    }
    println!(
        "\ncold = fresh Gram+Cholesky+solve per step; update = update_rows (Gram patch + \
         O(kn²) factor rotation) + same-λ redamp. Model ideal: flops / flops_streaming = {:.1}×.",
        rows.first()
            .map(|r| {
                crate::solver::flops(SolverKind::Chol, r.n, r.m)
                    / crate::solver::flops_streaming(SolverKind::Chol, r.n, r.m, r.k)
            })
            .unwrap_or(0.0)
    );
    if let Some(path) = json_path {
        std::fs::write(path, streaming_bench_json(&rows, quick))?;
        println!("streaming bench table written to {}", path.display());
    }
    if strict {
        for r in &rows {
            assert!(
                r.speedup >= 5.0,
                "PR-5 acceptance: rotating {} of {} window rows must be ≥5× faster than the \
                 cold factor path, got {:.2}×",
                r.k,
                r.n,
                r.speedup
            );
        }
        println!("acceptance: streaming ≥ 5× cold at ≤10% rotation ✓");
    }
    Ok(())
}

/// One row of the PR-6 mixed-precision benchmark.
#[derive(Debug, Clone)]
pub struct PrecisionBenchRow {
    pub stage: &'static str,
    /// Data type of the timed path: "f64", "f32", or "mixed".
    pub dtype: &'static str,
    pub n: usize,
    pub m: usize,
    pub median_ms: f64,
    pub gflops: f64,
    /// `median(f64) / median(this row)` for the same stage.
    pub speedup_vs_f64: f64,
}

/// Summary of the PR-6 precision benchmark: kernel + session rows plus
/// the measured accuracy of the mixed path.
#[derive(Debug, Clone)]
pub struct PrecisionBenchReport {
    pub rows: Vec<PrecisionBenchRow>,
    /// max_i |x_mixed[i] − x_f64[i]| / max(‖x_f64‖, 1) over the e2e RHS.
    pub max_rel_err: f64,
    /// Refinement sweeps the e2e mixed solve needed (per RHS).
    pub refine_sweeps: u64,
    /// Precision fallbacks recorded during the run (0 = the f32 path
    /// held for the whole benchmark).
    pub fallbacks: u64,
}

/// The PR-6 mixed-precision benchmark: single-threaded f32 vs f64 on
/// the two O(·³)-class kernels the mixed sessions move to single
/// precision (square GEMM at 512³, SYRK at the Gram shape), then the
/// end-to-end chol session in both modes. Everything runs on the
/// *active* tier (forced-scalar runs stay scalar); thread scaling is
/// PR 3's table and tier scaling PR 4's — this table isolates the
/// precision axis. `quick` shrinks the shapes for CI smoke runs.
pub fn precision_bench(quick: bool) -> PrecisionBenchReport {
    use crate::linalg::gemm;
    use crate::linalg::kernel::{self, Trans};
    use crate::solver::Precision;

    let mut rng = Rng::seed_from(61);
    let (sq, n, m) = if quick { (128usize, 96usize, 512usize) } else { (512, 512, 4096) };
    let mut rows: Vec<PrecisionBenchRow> = Vec::new();
    let push = |rows: &mut Vec<PrecisionBenchRow>,
                stage: &'static str,
                dtype: &'static str,
                n: usize,
                m: usize,
                fl: f64,
                r: crate::metrics::BenchResult| {
        let median_ms = r.median_ms();
        let f64_ms = rows
            .iter()
            .find(|row| row.stage == stage && row.dtype == "f64")
            .map(|row| row.median_ms)
            .unwrap_or(median_ms);
        rows.push(PrecisionBenchRow {
            stage,
            dtype,
            n,
            m,
            median_ms,
            gflops: fl / (median_ms / 1e3) / 1e9,
            speedup_vs_f64: f64_ms / median_ms.max(1e-9),
        });
    };

    // --- Square GEMM, f64 vs f32 (the acceptance stage) ---
    let a = Mat::randn(sq, sq, &mut rng);
    let b = Mat::randn(sq, sq, &mut rng);
    let a32: Vec<f32> = a.as_slice().iter().map(|&x| x as f32).collect();
    let b32: Vec<f32> = b.as_slice().iter().map(|&x| x as f32).collect();
    let gemm_fl = 2.0 * (sq as f64).powi(3);
    let mut c = Mat::zeros(sq, sq);
    let r = bench("gemm_f64", 3, 0.5, || {
        gemm::gemm(1.0, &a, &b, 0.0, &mut c);
        std::hint::black_box(&c);
    });
    push(&mut rows, "gemm_nn", "f64", sq, sq, gemm_fl, r);
    let mut c32 = vec![0.0f32; sq * sq];
    let r = bench("gemm_f32", 3, 0.5, || {
        kernel::sgemm(sq, sq, sq, 1.0, &a32, sq, Trans::N, &b32, sq, Trans::N, 0.0, &mut c32, sq);
        std::hint::black_box(&c32);
    });
    push(&mut rows, "gemm_nn", "f32", sq, sq, gemm_fl, r);

    // --- SYRK at the Gram shape, f64 vs f32 ---
    let s = Mat::randn(n, m, &mut rng);
    let s32: Vec<f32> = s.as_slice().iter().map(|&x| x as f32).collect();
    let syrk_fl = (n * n) as f64 * m as f64;
    let r = bench("syrk_f64", 3, 0.5, || {
        std::hint::black_box(gemm::syrk(&s, 1e-3));
    });
    push(&mut rows, "syrk", "f64", n, m, syrk_fl, r);
    let mut w32 = vec![0.0f32; n * n];
    let r = bench("syrk_f32", 3, 0.5, || {
        gemm::syrk_f32(&s32, n, m, 1e-3, &mut w32);
        std::hint::black_box(&w32);
    });
    push(&mut rows, "syrk", "f32", n, m, syrk_fl, r);

    // --- End-to-end chol session: f64 vs mixed (f32 factor + f64
    //     iterative refinement to the default 1e-10 target) ---
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    // λ = 0.1 keeps the refinement contraction ~1e-2 at both shapes
    // (3–4 sweeps; `python/oracle_precision.py` — at λ = 1e-3 the
    // 512×4096 shape crosses the stagnation boundary and the session
    // would latch f64, benchmarking the fallback instead of the f32
    // path).
    let lambda = 0.1;
    let e2e_fl = syrk_fl + (n as f64).powi(3) / 3.0;
    let f64_solver = CholSolver::default();
    let x64 = f64_solver.solve(&s, &v, lambda).expect("f64 solve");
    let r = bench("e2e_f64", 3, 0.5, || {
        std::hint::black_box(f64_solver.solve(&s, &v, lambda).expect("f64 solve"));
    });
    push(&mut rows, "chol_session_e2e", "f64", n, m, e2e_fl, r);

    let mixed_solver = CholSolver::default().with_precision(Precision::Mixed, 1e-10);
    let fb0 = crate::solver::mixed_counters::fallbacks();
    let sw0 = crate::solver::mixed_counters::refine_sweeps();
    let xm = mixed_solver.solve(&s, &v, lambda).expect("mixed solve");
    let refine_sweeps = crate::solver::mixed_counters::refine_sweeps() - sw0;
    let r = bench("e2e_mixed", 3, 0.5, || {
        std::hint::black_box(mixed_solver.solve(&s, &v, lambda).expect("mixed solve"));
    });
    push(&mut rows, "chol_session_e2e", "mixed", n, m, e2e_fl, r);
    let fallbacks = crate::solver::mixed_counters::fallbacks() - fb0;

    // Accuracy gate: the mixed answer must sit at the f64 answer to the
    // refinement target (the ISSUE's ≤1e-10 relative bar).
    let scale = crate::linalg::mat::norm2(&x64).max(1.0);
    let max_rel_err = xm
        .iter()
        .zip(&x64)
        .map(|(a, b)| (a - b).abs() / scale)
        .fold(0.0f64, f64::max);
    assert!(
        fallbacks > 0 || max_rel_err < 1e-9,
        "mixed solve diverged from f64 without falling back: rel err {max_rel_err:.3e}"
    );

    PrecisionBenchReport { rows, max_rel_err, refine_sweeps, fallbacks }
}

/// Render the precision-bench report as the `BENCH_PR6.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn precision_bench_json(report: &PrecisionBenchReport, quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 6,\n");
    out.push_str("  \"bench\": \"precision\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"active_isa\": \"{}\",\n", crate::linalg::active_isa()));
    out.push_str(&format!("  \"max_rel_err_mixed\": {:.3e},\n", report.max_rel_err));
    out.push_str(&format!("  \"refine_sweeps\": {},\n", report.refine_sweeps));
    out.push_str(&format!("  \"fallbacks\": {},\n", report.fallbacks));
    out.push_str(
        "  \"unit\": {\"median_ms\": \"milliseconds\", \"gflops\": \"GFLOP/s\", \
         \"speedup_vs_f64\": \"median(f64) / median(dtype)\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = report
        .rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"stage\": \"{}\", \"dtype\": \"{}\", \"n\": {}, \"m\": {}, \
                 \"median_ms\": {:.3}, \"gflops\": {:.2}, \"speedup_vs_f64\": {:.2}}}",
                r.stage, r.dtype, r.n, r.m, r.median_ms, r.gflops, r.speedup_vs_f64
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the precision benchmark, print the table, optionally write
/// `BENCH_PR6.json`. `strict` enforces the PR-6 acceptance bar — f32
/// GEMM and SYRK ≥ 1.5× their f64 twins single-threaded on the best
/// tier — which the full-mode `cargo bench --bench gemm` harness
/// enables (skipped at the scalar tier, where f32 has no lane
/// advantage; the accuracy gate inside [`precision_bench`] runs in
/// every mode).
pub fn precision_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    use crate::linalg::KernelIsa;
    let active = crate::linalg::active_isa();
    println!("active ISA tier: {active} (precision rows are single-threaded on this tier)");
    let report = precision_bench(quick);
    println!(
        "{:>18} | {:>5} | {:>5} | {:>5} | {:>10} | {:>8} | {:>8}",
        "stage", "dtype", "n", "m", "median", "GFLOP/s", "vs f64"
    );
    for r in &report.rows {
        println!(
            "{:>18} | {:>5} | {:>5} | {:>5} | {:>8.2}ms | {:>8.2} | {:>7.2}×",
            r.stage, r.dtype, r.n, r.m, r.median_ms, r.gflops, r.speedup_vs_f64
        );
    }
    println!(
        "\nmixed e2e: rel err vs f64 {:.2e}, {} refinement sweep(s), {} fallback(s)",
        report.max_rel_err, report.refine_sweeps, report.fallbacks
    );
    if let Some(path) = json_path {
        std::fs::write(path, precision_bench_json(&report, quick))?;
        println!("precision bench table written to {}", path.display());
    }
    if strict {
        if active == KernelIsa::Scalar {
            println!(
                "acceptance: skipped (scalar is the active tier — the f32 kernels have no \
                 SIMD lane advantage to measure)"
            );
        } else {
            for stage in ["gemm_nn", "syrk"] {
                let f32_row = report
                    .rows
                    .iter()
                    .find(|r| r.stage == stage && r.dtype == "f32")
                    .expect("f32 row");
                assert!(
                    f32_row.speedup_vs_f64 >= 1.5,
                    "PR-6 acceptance: f32 {stage} must be ≥1.5× f64 single-threaded on {}, \
                     got {:.2}×",
                    active,
                    f32_row.speedup_vs_f64
                );
            }
            println!("acceptance: f32 gemm_nn and syrk ≥ 1.5× f64 on {active} ✓");
        }
    }
    Ok(())
}

/// §3: CG iterations blow up with condition number; chol time is flat.
pub fn cg_conditioning() {
    println!("CG vs chol under ill-conditioning (n=64, m=4096)");
    println!("{:>10} | {:>12} | {:>12} | {:>10}", "λ", "cg iters", "cg ms", "chol ms");
    let mut rng = Rng::seed_from(77);
    let (n, m) = (64, 4096);
    let mut s = Mat::randn(n, m, &mut rng);
    // Geometric row scaling: σ spread = 1e2 ⇒ κ(SᵀS) ~ 1e4 before damping.
    for i in 0..n {
        let scale = 10f64.powf(i as f64 / (n - 1) as f64 * 2.0);
        for x in s.row_mut(i) {
            *x *= scale;
        }
    }
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    for lambda in [1e2, 1e0, 1e-2, 1e-4, 1e-6] {
        let cg = CgSolver::new(1e-10, 200_000);
        let t0 = std::time::Instant::now();
        let ok = cg.solve(&s, &v, lambda).is_ok();
        let cg_ms = t0.elapsed().as_secs_f64() * 1e3;
        let iters = cg.stats().iterations;
        let t1 = std::time::Instant::now();
        CholSolver::default().solve(&s, &v, lambda).unwrap();
        let chol_ms = t1.elapsed().as_secs_f64() * 1e3;
        println!(
            "{lambda:>10.0e} | {:>12} | {cg_ms:>10.2}ms | {chol_ms:>8.2}ms",
            if ok { iters.to_string() } else { format!("{iters} (fail)") }
        );
    }
    println!("\npaper §3: iterative methods scale linearly but iterations grow when ill-conditioned;\nthe direct chol solve is non-iterative and flat.");
}

/// One row of the PR-7 serving benchmark: sustained traffic from
/// `tenants` concurrent clients against one shared session, coalesced
/// vs serial dispatch.
#[derive(Debug, Clone)]
pub struct ServingBenchRow {
    pub tenants: usize,
    /// Cross-tenant RHS coalescing on (tick gathers a panel) or off
    /// (tick 0, one panel per request — the serial baseline).
    pub coalesced: bool,
    /// Total requests completed across all tenants.
    pub requests: usize,
    /// Requests per second over the whole run.
    pub rps: f64,
    /// Client-observed latency percentiles (submit → answer).
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// `solve_many` panels the dispatcher issued (≪ requests when
    /// coalescing works).
    pub panels: u64,
}

/// The PR-7 serving benchmark: 1/4/16 tenants hammer one cached session
/// with blocking single-RHS solves; coalesced mode gathers a dispatch
/// tick and batches same-(session, λ) requests into one `solve_many`
/// panel, serial mode dispatches each request as its own panel. The
/// panel path turns k memory-bound GEMV-shaped passes over S into one
/// GEMM-shaped pass, which is where the cross-tenant speedup comes
/// from. Every tenant's first answer is gated against the serial
/// single-process session (1e-9), so throughput never comes at the
/// cost of correctness.
pub fn serving_bench(quick: bool) -> Vec<ServingBenchRow> {
    use crate::serve::{ServeOptions, Server};
    use std::time::Instant;

    let (n, m, per_tenant) = if quick { (48usize, 512usize, 8usize) } else { (256, 4096, 32) };
    let workers = if quick { 2 } else { 4 };
    let lambda = 1e-3;
    let mut rng = Rng::seed_from(77);
    let s = Mat::randn(n, m, &mut rng);
    let max_tenants = 16usize;
    let vs: Vec<Vec<f64>> =
        (0..max_tenants).map(|_| (0..m).map(|_| rng.normal()).collect()).collect();
    // Reference answers from the serial session path (one staging,
    // max_tenants cheap solves).
    let refs: Vec<Vec<f64>> = {
        let serial = CholSolver::default();
        let mut fact = serial.factor(&s, lambda).expect("reference factor");
        vs.iter().map(|v| fact.solve(v).expect("reference solve")).collect()
    };

    let mut rows = Vec::new();
    for &tenants in &[1usize, 4, 16] {
        for &coalesced in &[true, false] {
            let opts = ServeOptions {
                tenants,
                queue_depth: 64.max(tenants),
                tick_ms: if coalesced { 2 } else { 0 },
                coalesce: coalesced,
                workers,
                worker_queue_depth: 4,
                ..ServeOptions::default()
            };
            let server = Server::start(opts).expect("server start");
            let sid = {
                let setup = server.client().expect("setup client");
                let sid = setup.open_session(s.clone(), lambda).expect("open session");
                sid // setup client drops here, freeing its tenant slot
            };
            let started = Instant::now();
            let mut latencies: Vec<f64> = Vec::new();
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for t in 0..tenants {
                    let client = server.client().expect("tenant client");
                    let v = &vs[t];
                    let x_ref = &refs[t];
                    handles.push(scope.spawn(move || {
                        let mut lats = Vec::with_capacity(per_tenant);
                        for req in 0..per_tenant {
                            let t0 = Instant::now();
                            let x = loop {
                                match client.solve(sid, lambda, v) {
                                    Ok(x) => break x,
                                    Err(e) if e.is_retryable() => {
                                        std::thread::sleep(std::time::Duration::from_millis(1));
                                    }
                                    Err(e) => panic!("serving bench solve failed: {e}"),
                                }
                            };
                            lats.push(t0.elapsed().as_secs_f64() * 1e3);
                            if req == 0 {
                                // Correctness gate: coalesced panels must
                                // reproduce the serial session's answers.
                                let scale = crate::linalg::mat::norm2(x_ref).max(1.0);
                                for (a, b) in x.iter().zip(x_ref) {
                                    assert!(
                                        (a - b).abs() < 1e-9 * scale,
                                        "serving answer diverged from serial: {a} vs {b}"
                                    );
                                }
                            }
                        }
                        lats
                    }));
                }
                for h in handles {
                    latencies.extend(h.join().expect("tenant thread"));
                }
            });
            let elapsed = started.elapsed().as_secs_f64();
            let stats = server.shutdown();
            let total = tenants * per_tenant;
            assert_eq!(stats.completed, total as u64, "every request must be answered");
            let summary = crate::metrics::Summary::from_samples(&latencies);
            rows.push(ServingBenchRow {
                tenants,
                coalesced,
                requests: total,
                rps: total as f64 / elapsed.max(1e-9),
                p50_ms: summary.median,
                p99_ms: summary.p99,
                panels: stats.panels,
            });
        }
    }
    rows
}

/// Render serving-bench rows as the `BENCH_PR7.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn serving_bench_json(rows: &[ServingBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 7,\n");
    out.push_str("  \"bench\": \"serving\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(
        "  \"unit\": {\"rps\": \"requests/second\", \"p50_ms\": \"milliseconds\", \
         \"p99_ms\": \"milliseconds\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"tenants\": {}, \"coalesced\": {}, \"requests\": {}, \"rps\": {:.1}, \
                 \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"panels\": {}}}",
                r.tenants, r.coalesced, r.requests, r.rps, r.p50_ms, r.p99_ms, r.panels
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the serving benchmark, print the table, optionally write
/// `BENCH_PR7.json`. `strict` enforces the PR-7 acceptance bar —
/// coalesced dispatch at 16 tenants ≥ 2× the serial requests/sec at no
/// worse p99 — enabled by the full-mode `cargo bench --bench serving`
/// harness (quick mode skips it: tiny shapes make the dispatch tick,
/// not the solve, the dominant cost).
pub fn serving_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let rows = serving_bench(quick);
    println!(
        "{:>7} | {:>9} | {:>8} | {:>9} | {:>9} | {:>9} | {:>7}",
        "tenants", "dispatch", "requests", "req/s", "p50", "p99", "panels"
    );
    for r in &rows {
        println!(
            "{:>7} | {:>9} | {:>8} | {:>9.1} | {:>7.2}ms | {:>7.2}ms | {:>7}",
            r.tenants,
            if r.coalesced { "coalesced" } else { "serial" },
            r.requests,
            r.rps,
            r.p50_ms,
            r.p99_ms,
            r.panels
        );
    }
    println!(
        "\ncoalesced = one solve_many panel per (session, λ) per tick; serial = one panel per \
         request. Latency is client-observed (submit → answer), including the gathering tick."
    );
    if let Some(path) = json_path {
        std::fs::write(path, serving_bench_json(&rows, quick))?;
        println!("serving bench table written to {}", path.display());
    }
    if strict {
        let coal = rows
            .iter()
            .find(|r| r.tenants == 16 && r.coalesced)
            .expect("16-tenant coalesced row");
        let serial = rows
            .iter()
            .find(|r| r.tenants == 16 && !r.coalesced)
            .expect("16-tenant serial row");
        assert!(
            coal.rps >= 2.0 * serial.rps,
            "PR-7 acceptance: coalesced dispatch at 16 tenants must be ≥2× serial req/s, got \
             {:.1} vs {:.1}",
            coal.rps,
            serial.rps
        );
        assert!(
            coal.p99_ms <= serial.p99_ms * 1.25,
            "PR-7 acceptance: the coalesced throughput win may not cost p99 ({:.2}ms vs \
             {:.2}ms serial)",
            coal.p99_ms,
            serial.p99_ms
        );
        println!("acceptance: coalesced ≥ 2× serial req/s at 16 tenants, p99 no worse ✓");
    }
    Ok(())
}

/// One row of the PR-8 recovery benchmark: a sustained single-tenant
/// request stream, either fault-free (the baseline) or with a worker
/// killed every `kill_every` requests, forcing the supervisor to
/// respawn it and re-materialize the session mid-stream.
#[derive(Debug, Clone)]
pub struct RecoveryBenchRow {
    pub faulted: bool,
    pub requests: usize,
    /// Kills injected during the run (0 on the baseline row).
    pub kills: u64,
    pub rps: f64,
    /// Client-observed latency percentiles; the faulted p99 absorbs the
    /// respawn + replay cost of the killed requests.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub worker_respawns: u64,
    pub session_replays: u64,
    pub session_refactors: u64,
    pub local_fallbacks: u64,
}

/// The PR-8 recovery benchmark: one tenant streams blocking solves
/// against a cached session; the faulted run kills a rotating worker
/// every `kill_every` requests (~1 per 100 in full mode, ~1 per 20 in
/// quick mode so a short run still sees several). Every answer —
/// including the ones that rode through a recovery — is gated against
/// the serial solver at 1e-9, and every kill must show up as exactly
/// one respawn, so the latency numbers can't be bought with wrong or
/// dropped answers.
pub fn recovery_bench(quick: bool) -> Vec<RecoveryBenchRow> {
    use crate::serve::{ServeOptions, Server};
    use std::time::Instant;

    let (n, m, requests, kill_every) =
        if quick { (48usize, 512usize, 100usize, 20usize) } else { (128, 2048, 1000, 100) };
    let workers = 2usize;
    let lambda = 1e-3;
    let mut rng = Rng::seed_from(78);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let x_ref = CholSolver::default().solve(&s, &v, lambda).expect("reference solve");
    let scale = crate::linalg::mat::norm2(&x_ref).max(1.0);

    let mut rows = Vec::new();
    for &faulted in &[false, true] {
        let opts = ServeOptions {
            workers,
            tick_ms: 0,
            coalesce: false,
            snapshot_every: 8,
            ..ServeOptions::default()
        };
        let server = Server::start(opts).expect("server start");
        let client = server.client().expect("client");
        let sid = client.open_session(s.clone(), lambda).expect("open session");
        let mut kills = 0u64;
        let started = Instant::now();
        let mut lats = Vec::with_capacity(requests);
        for i in 0..requests {
            if faulted && i % kill_every == kill_every - 1 {
                server.inject_kill(i % workers);
                kills += 1;
            }
            let t0 = Instant::now();
            let x = client.solve(sid, lambda, &v).expect("recovery bench solve");
            lats.push(t0.elapsed().as_secs_f64() * 1e3);
            for (a, b) in x.iter().zip(&x_ref) {
                assert!(
                    (a - b).abs() < 1e-9 * scale,
                    "recovered answer diverged from serial: {a} vs {b}"
                );
            }
        }
        let elapsed = started.elapsed().as_secs_f64();
        client.close_session(sid).expect("close session");
        drop(client);
        let stats = server.shutdown();
        assert_eq!(stats.completed, requests as u64, "every request must be answered");
        assert_eq!(stats.worker_respawns, kills, "every kill must be healed exactly once");
        let summary = crate::metrics::Summary::from_samples(&lats);
        rows.push(RecoveryBenchRow {
            faulted,
            requests,
            kills,
            rps: requests as f64 / elapsed.max(1e-9),
            p50_ms: summary.median,
            p99_ms: summary.p99,
            worker_respawns: stats.worker_respawns,
            session_replays: stats.session_replays,
            session_refactors: stats.session_refactors,
            local_fallbacks: stats.local_fallbacks,
        });
    }
    rows
}

/// Render recovery-bench rows as the `BENCH_PR8.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn recovery_bench_json(rows: &[RecoveryBenchRow], quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 8,\n");
    out.push_str("  \"bench\": \"recovery\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(
        "  \"unit\": {\"rps\": \"requests/second\", \"p50_ms\": \"milliseconds\", \
         \"p99_ms\": \"milliseconds\"},\n",
    );
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"faulted\": {}, \"requests\": {}, \"kills\": {}, \"rps\": {:.1}, \
                 \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"worker_respawns\": {}, \
                 \"session_replays\": {}, \"session_refactors\": {}, \"local_fallbacks\": {}}}",
                r.faulted,
                r.requests,
                r.kills,
                r.rps,
                r.p50_ms,
                r.p99_ms,
                r.worker_respawns,
                r.session_replays,
                r.session_refactors,
                r.local_fallbacks
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the recovery benchmark, print the table, optionally write
/// `BENCH_PR8.json`. `strict` enforces the PR-8 acceptance bar — every
/// kill recovered through the *distributed* paths (replay or refactor,
/// zero leader-local fallbacks: the fallback is for deadline pressure,
/// not routine heals) — enabled by the full-mode `cargo bench --bench
/// serving` harness. Correctness and respawn-accounting are asserted
/// inside [`recovery_bench`] in both modes.
pub fn recovery_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let rows = recovery_bench(quick);
    println!(
        "{:>9} | {:>8} | {:>5} | {:>9} | {:>9} | {:>9} | {:>8} | {:>7} | {:>9} | {:>9}",
        "run", "requests", "kills", "req/s", "p50", "p99", "respawns", "replays", "refactors",
        "fallbacks"
    );
    for r in &rows {
        println!(
            "{:>9} | {:>8} | {:>5} | {:>9.1} | {:>7.2}ms | {:>7.2}ms | {:>8} | {:>7} | {:>9} | \
             {:>9}",
            if r.faulted { "faulted" } else { "baseline" },
            r.requests,
            r.kills,
            r.rps,
            r.p50_ms,
            r.p99_ms,
            r.worker_respawns,
            r.session_replays,
            r.session_refactors,
            r.local_fallbacks
        );
    }
    println!(
        "\nfaulted = one worker killed per {} requests; the p99 gap vs baseline is the \
         client-visible recovery cost (respawn + snapshot replay + refactor). Every answer is \
         gated at 1e-9 against the serial solver.",
        if quick { 20 } else { 100 }
    );
    if let Some(path) = json_path {
        std::fs::write(path, recovery_bench_json(&rows, quick))?;
        println!("recovery bench table written to {}", path.display());
    }
    if strict {
        let faulted = rows.iter().find(|r| r.faulted).expect("faulted row");
        assert!(
            faulted.session_replays + faulted.session_refactors >= faulted.kills,
            "PR-8 acceptance: {} kills need ≥ {} distributed recoveries, saw replays {} + \
             refactors {}",
            faulted.kills,
            faulted.kills,
            faulted.session_replays,
            faulted.session_refactors
        );
        assert_eq!(
            faulted.local_fallbacks, 0,
            "PR-8 acceptance: routine heals must stay distributed (leader-local fallback is \
             reserved for deadline pressure)"
        );
        println!("acceptance: every kill recovered via distributed replay/refactor ✓");
    }
    Ok(())
}

/// One timing row of the PR-10 structured-Fisher benchmark: a solver
/// kind at one block count on the fixed (n, m) shape.
#[derive(Debug, Clone)]
pub struct StructuredBenchRow {
    pub solver: &'static str,
    pub blocks: usize,
    /// Staging cost: `begin` + first `redamp` (Gram, factor, caches).
    pub factor_ms: f64,
    /// One `solve_into` on the staged session.
    pub solve_ms: f64,
    /// Relative residual `‖(SᵀS+λI)x − v‖ / ‖v‖` of that solve — kept in
    /// the table so the approximate kinds (kpsvd at blocks where the
    /// Gram has no Kronecker structure) can't look fast for free.
    pub rel_residual: f64,
}

/// One iteration-count row: hybrid PCG vs plain CG on the block-scaled
/// synthetic Fisher at one block count.
#[derive(Debug, Clone)]
pub struct StructuredIterRow {
    pub blocks: usize,
    pub cg_iters: usize,
    pub pcg_iters: usize,
}

/// The full PR-10 report: timing grid + iteration grid + the
/// single-block identity gap (must be exactly 0.0 — bit-identity).
#[derive(Debug, Clone)]
pub struct StructuredBenchReport {
    pub n: usize,
    pub m: usize,
    pub lambda: f64,
    pub rows: Vec<StructuredBenchRow>,
    pub iters: Vec<StructuredIterRow>,
    /// `max|x_blockdiag(1 block, chol inner) − x_chol|` on the shared
    /// dense problem. Bit-identity ⇒ exactly 0.0.
    pub single_block_max_diff: f64,
}

/// Block-scaled synthetic Fisher for the iteration comparison: each
/// block's rows live on that block's columns only, with per-block score
/// scales spread over ~10^1.5 (so the Gram's live spectrum spans ~10³),
/// plus a faint dense coupling term so the block-diagonal preconditioner
/// is merely *good*, not exact. Plain CG pays for the spread; PCG sees
/// the near-identity preconditioned system. The spread is capped so the
/// shared tolerance stays above f64's attainable-residual floor
/// (~ε·κ·‖v‖) — wilder spreads make *both* solvers stall at the cap.
fn block_scaled_scores(n_per: usize, blocks: usize, width: usize, rng: &mut Rng) -> Mat {
    let n = n_per * blocks;
    let m = width * blocks;
    let mut s = Mat::zeros(n, m);
    let denom = (blocks.max(2) - 1) as f64;
    for b in 0..blocks {
        let scale = 10f64.powf(1.5 * b as f64 / denom);
        for i in 0..n_per {
            let r = b * n_per + i;
            for j in 0..width {
                s[(r, b * width + j)] = scale * rng.normal();
            }
        }
    }
    for i in 0..n {
        for j in 0..m {
            s[(i, j)] += 1e-3 * rng.normal();
        }
    }
    s
}

/// The PR-10 structured-Fisher benchmark. Timing grid: chol (the exact
/// baseline, block-count-independent) and blockdiag / kpsvd / hybrid at
/// block counts {1, 4, 16, 64} on one dense (n, m) problem. Iteration
/// grid: hybrid PCG vs plain CG at the same tolerance on the
/// block-scaled Fisher from [`block_scaled_scores`]. Both grids are
/// fully deterministic (fixed seeds).
pub fn structured_bench(quick: bool) -> StructuredBenchReport {
    let (n, m, samples, budget) =
        if quick { (48usize, 768usize, 3usize, 0.1f64) } else { (96, 2048, 5, 0.5) };
    // Timing grid at λ = 0.1: large enough that the hybrid's default
    // 1e-10 inner tolerance sits above the f64 attainable-residual
    // floor on this dense shape (at λ = 1e-3 it would not, and the
    // PCG would stall at the iteration cap instead of timing a solve).
    let lambda = 0.1;
    let block_counts = [1usize, 4, 16, 64];
    let mut rng = Rng::seed_from(100);
    let s = Mat::randn(n, m, &mut rng);
    let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let vnorm = crate::linalg::mat::norm2(&v).max(1e-30);
    let cfg = crate::linalg::KernelConfig::with_threads(1);

    // Single-block identity gap: chol vs blockdiag(1 block, chol inner)
    // under the same kernel configuration must agree to the bit.
    let x_chol = CholSolver::with_config(cfg).solve(&s, &v, lambda).expect("chol solve");
    let x_bd = BlockDiagSolver::with_config(cfg)
        .with_blocks(1, BlockKind::Chol)
        .solve(&s, &v, lambda)
        .expect("blockdiag solve");
    let single_block_max_diff = x_chol
        .iter()
        .zip(&x_bd)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);

    let mut rows = Vec::new();
    let mut push_row = |name: &'static str, blocks: usize, solver: &dyn DampedSolver| {
        let factor = bench(&format!("{name}/k={blocks}/factor"), samples, budget, || {
            let mut fact = solver.begin(&s);
            fact.redamp(lambda).expect("factor");
        });
        let mut fact = solver.begin(&s);
        fact.redamp(lambda).expect("factor");
        let mut x = vec![0.0; m];
        let solve = bench(&format!("{name}/k={blocks}/solve"), samples, budget, || {
            fact.solve_into(&v, &mut x).expect("solve");
        });
        let rel_residual = crate::solver::residual_norm(&s, &x, &v, lambda) / vnorm;
        rows.push(StructuredBenchRow {
            solver: name,
            blocks,
            factor_ms: factor.median_ms(),
            solve_ms: solve.median_ms(),
            rel_residual,
        });
    };
    push_row("chol", 1, &CholSolver::with_config(cfg));
    for &k in &block_counts {
        push_row("blockdiag", k, &BlockDiagSolver::with_config(cfg).with_blocks(k, BlockKind::Auto));
        push_row("kpsvd", k, &KpSvdSolver::with_config(cfg).with_blocks(k));
        push_row(
            "hybrid",
            k,
            &HybridCgSolver::new(1e-10, 10_000)
                .with_config(cfg)
                .with_blocks(k, BlockKind::Auto),
        );
    }

    // Iteration grid: the structured preconditioner's whole point is
    // clustering the spectrum, so the acceptance metric is iteration
    // counts at a shared tolerance, not wall time. Shared tol 1e-7 and
    // λ = 1e-3: above the attainable-residual floor for the capped
    // ~10³ spectrum spread, tight enough that plain CG must resolve it.
    let iter_lambda = 1e-3;
    let mut iters = Vec::new();
    for &k in &block_counts[1..] {
        let width = (m / k).max(2);
        let mut rng = Rng::seed_from(200 + k as u64);
        // 6 rows per block: enough Gram rank that plain CG cannot win on
        // a trivially short Krylov run (at 2 rows/block, rank ≤ 2k lets
        // CG finish in ~2k+1 steps and the preconditioner has nothing
        // left to save at small k).
        let sb = block_scaled_scores(6, k, width, &mut rng);
        let vb: Vec<f64> = (0..sb.cols()).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-7, 10_000);
        cg.solve(&sb, &vb, iter_lambda).expect("cg solve");
        let cg_iters = cg.stats().iterations;
        let hybrid = HybridCgSolver::new(1e-7, 10_000)
            .with_config(cfg)
            .with_blocks(k, BlockKind::Auto);
        hybrid.solve(&sb, &vb, iter_lambda).expect("hybrid solve");
        let pcg_iters = hybrid.stats().iterations;
        iters.push(StructuredIterRow { blocks: k, cg_iters, pcg_iters });
    }

    StructuredBenchReport { n, m, lambda, rows, iters, single_block_max_diff }
}

/// Render the structured-bench report as the `BENCH_PR10.json` payload
/// (hand-rolled JSON — the build is offline, no serde).
pub fn structured_bench_json(report: &StructuredBenchReport, quick: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"pr\": 10,\n");
    out.push_str("  \"bench\": \"structured\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!(
        "  \"shape\": {{\"n\": {}, \"m\": {}, \"lambda\": {}}},\n",
        report.n, report.m, report.lambda
    ));
    out.push_str(&format!(
        "  \"single_block_max_diff\": {:e},\n",
        report.single_block_max_diff
    ));
    out.push_str("  \"unit\": {\"factor_ms\": \"milliseconds\", \"solve_ms\": \"milliseconds\"},\n");
    out.push_str("  \"rows\": [\n");
    let body: Vec<String> = report
        .rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"solver\": \"{}\", \"blocks\": {}, \"factor_ms\": {:.3}, \
                 \"solve_ms\": {:.4}, \"rel_residual\": {:.3e}}}",
                r.solver, r.blocks, r.factor_ms, r.solve_ms, r.rel_residual
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"iterations\": [\n");
    let body: Vec<String> = report
        .iters
        .iter()
        .map(|r| {
            format!(
                "    {{\"blocks\": {}, \"cg_iters\": {}, \"pcg_iters\": {}}}",
                r.blocks, r.cg_iters, r.pcg_iters
            )
        })
        .collect();
    out.push_str(&body.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Run the structured benchmark, print both tables, optionally write
/// `BENCH_PR10.json`. `strict` enforces the PR-10 acceptance bar —
/// single-block blockdiag bit-identical to chol (gap exactly 0.0) and
/// strictly fewer PCG than CG iterations on every multi-block row —
/// exercised by `rust/tests/structured.rs` in quick mode.
pub fn structured_bench_report(
    quick: bool,
    json_path: Option<&Path>,
    strict: bool,
) -> std::io::Result<()> {
    let report = structured_bench(quick);
    println!(
        "structured family at n={} m={} λ={} (chol = exact baseline):",
        report.n, report.m, report.lambda
    );
    println!(
        "{:>9} | {:>6} | {:>11} | {:>10} | rel residual",
        "solver", "blocks", "factor (ms)", "solve (ms)"
    );
    for r in &report.rows {
        println!(
            "{:>9} | {:>6} | {:>11.3} | {:>10.4} | {:.3e}",
            r.solver, r.blocks, r.factor_ms, r.solve_ms, r.rel_residual
        );
    }
    println!(
        "\nsingle-block blockdiag vs chol max |Δx| = {:e} (bit-identity ⇒ 0.0)",
        report.single_block_max_diff
    );
    println!("\nhybrid PCG vs plain CG on the block-scaled Fisher (shared tol 1e-7):");
    println!("{:>6} | {:>8} | {:>9}", "blocks", "cg iters", "pcg iters");
    for r in &report.iters {
        println!("{:>6} | {:>8} | {:>9}", r.blocks, r.cg_iters, r.pcg_iters);
    }
    if let Some(path) = json_path {
        std::fs::write(path, structured_bench_json(&report, quick))?;
        println!("structured bench table written to {}", path.display());
    }
    if strict {
        assert_eq!(
            report.single_block_max_diff, 0.0,
            "PR-10 acceptance: single-block blockdiag must be bit-identical to chol"
        );
        for r in &report.iters {
            assert!(
                r.pcg_iters < r.cg_iters,
                "PR-10 acceptance: hybrid PCG must beat plain CG at {} blocks \
                 (pcg {} vs cg {})",
                r.blocks,
                r.pcg_iters,
                r.cg_iters
            );
        }
        println!("acceptance: bit-identity at 1 block, PCG < CG on every multi-block row ✓");
    }
    Ok(())
}
