//! The plan → factor → solve session layer (PR 2).
//!
//! The paper's speedup comes from the separability of the damped solve:
//! the O(n²m) Gram product and the O(n³) factorization are independent of
//! the right-hand side, and the Gram is independent of λ. Real consumers
//! (the trainer's damping schedule, Levenberg–Marquardt λ-retries,
//! multi-RHS K-FAC-style solves) hit the *same* score matrix repeatedly
//! with varying `v` and λ, so the API stages the work in three tiers:
//!
//! ```text
//! SolverRegistry ── build(kind, options) ──► boxed DampedSolver
//!        │
//!        └─ plan(kind, n, m) ──► SolverPlan        (reusable across steps)
//!                                    │
//!                 plan.factor(&S, λ) ╵──► Factorization   (Gram/SVD cached)
//!                                              │
//!               fact.redamp(λ') ──► O(n³) only ╵(zero Gram GEMMs — tested)
//!               fact.solve_into(&v, &mut x) ──► O(nm) per RHS
//!               fact.solve_many(&V) ──► blocked multi-RHS (TRSM panels)
//! ```
//!
//! Every solver kind implements the session natively (`chol` caches the
//! Gram, `eigh`/`svda` cache the λ-independent SVD, `naive` caches SᵀS,
//! `cg` captures its iteration workspace, `rvb` additionally caches the
//! recovery factor for `v = Sᵀf`), and [`OneShot`] adapts backends with
//! no separable factorization (PJRT executables).
//!
//! **Durability note (PR 9).** Session state is deliberately *not*
//! serialized: a rotated factor is bitwise different from a cold
//! refactor of the same window (`chol_update` and SYRK+Cholesky are
//! different arithmetic), so checkpointing the factor itself could not
//! reproduce a live run anyway. Instead the trainer logs the session's
//! *history* — window snapshot, rotations, and every `redamp` chain
//! including failed λ-backoff attempts — and a resume replays that
//! history through `begin_window`/`update_rows`/`redamp` verbatim,
//! landing on the identical factor bits
//! ([`crate::ngd::NaturalGradient::restore_state`]).

use super::blockdiag::BlockKind;
use super::{DampedSolver, SolveError, SolverKind};
use crate::linalg::{KernelConfig, KernelIsa, Mat};

/// A staged factorization of `(SᵀS + λI)` bound to a borrowed score
/// matrix: the output of [`DampedSolver::begin`] / [`DampedSolver::factor`].
///
/// λ-independent state (Gram matrix, SVD, shard distribution, iteration
/// workspace) is computed on the first [`Factorization::redamp`] and
/// cached for the lifetime of the session; re-damping never repeats the
/// O(n²m) Gram stage.
pub trait Factorization {
    /// Label of the solver that produced this factorization.
    fn name(&self) -> &'static str;

    /// Parameter dimension m (the solution length).
    fn dim(&self) -> usize;

    /// The currently applied damping (0.0 before the first successful
    /// [`Factorization::redamp`]).
    fn lambda(&self) -> f64;

    /// (Re-)damp with `lambda`: refactor `cached_gram + λĨ` in O(n³)
    /// without re-forming the Gram. On error the factorization is left
    /// un-damped; a later `redamp` (e.g. the optimizer's ×10 λ backoff)
    /// may still succeed against the cached state.
    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError>;

    /// Solve one right-hand side into caller storage (`x.len() == dim()`),
    /// allocation-free on the session's hot path.
    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError>;

    /// Solve one right-hand side into a fresh vector.
    fn solve(&mut self, v: &[f64]) -> Result<Vec<f64>, SolveError> {
        let mut x = vec![0.0; self.dim()];
        self.solve_into(v, &mut x)?;
        Ok(x)
    }

    /// Blocked multi-RHS solve: each **row** of `vs` (k×m) is one
    /// right-hand side; returns the k×m solution block. The default
    /// loops [`Factorization::solve_into`]; the Algorithm-1 session
    /// overrides it with panel GEMMs + the blocked TRSM.
    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        assert_eq!(vs.cols(), self.dim(), "each row of vs must be m-dimensional");
        let mut x = Mat::zeros(vs.rows(), vs.cols());
        for r in 0..vs.rows() {
            self.solve_into(vs.row(r), x.row_mut(r))?;
        }
        Ok(x)
    }

    /// Sliding-window row rotation (PR 5): delete the window rows at
    /// `removed` (indices into the *current* window, in any order) and
    /// append the rows of `added` (k×m) at the end of the window.
    ///
    /// Native for the `chol`/`rvb` sessions — and, since PR 7, for the
    /// sharded window session
    /// ([`crate::coordinator::ShardedWindowSession`], where each worker
    /// rotates its own column shard and returns an O(n²) cross panel,
    /// so the serving layer streams rotations without re-sharding).
    /// These patch the cached un-damped Gram with O(knm) panel
    /// products (zero full-Gram
    /// SYRKs) and rotate the Cholesky factor in O(kn²) per the
    /// [`chol_update`](crate::linalg::chol_update) primitives — a
    /// bordered-append breakdown falls back to an O(n³) refactor of
    /// the patched Gram, and only if *that* breaks down does the error
    /// surface (as [`SolveError::NotPositiveDefinite`], so the usual
    /// λ backoff applies).
    ///
    /// The default signals "no native rotation" as
    /// [`SolveError::BadInput`]; streaming drivers treat that as the
    /// cue to rebuild the session cold on the rotated window (the
    /// refactor fallback for kinds with no separable update).
    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        let _ = (removed, added);
        Err(SolveError::BadInput(format!(
            "solver {:?} has no native window rotation — rebuild the session on the rotated \
             window instead",
            self.name()
        )))
    }

    /// Streaming drift backstop (PR 5): rebuild every cached
    /// λ-independent object (Gram, factor) from the session's current
    /// window from scratch — the periodic full refactor that bounds
    /// rounding drift accumulated by O(n²) rotations. Supported by the
    /// sessions that support [`Factorization::update_rows`]; the
    /// default signals unsupported as [`SolveError::BadInput`].
    fn refresh(&mut self) -> Result<(), SolveError> {
        Err(SolveError::BadInput(format!(
            "solver {:?} has no streaming session to refresh",
            self.name()
        )))
    }
}

/// Shared λ validation for every session implementation.
pub(crate) fn check_lambda(lambda: f64) -> Result<(), SolveError> {
    if lambda <= 0.0 {
        return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
    }
    Ok(())
}

/// Error for solving through a factorization whose `redamp` never
/// succeeded.
pub(crate) fn undamped_err() -> SolveError {
    SolveError::BadInput("factorization is not damped — call redamp(λ) first".to_string())
}

/// The shared redamp kernel of the direct-method sessions: re-damp a
/// cached λ-independent matrix (`SSᵀ` for chol/rvb/sharded, `SᵀS` for
/// naive) and Cholesky-factor it — O(n³), zero Gram GEMMs. The
/// factorization runs on `threads` kernel-pool jobs (lookahead-blocked
/// Cholesky, bit-identical to serial), so a λ-resweep scales with the
/// session's `solver.threads` like every other stage.
pub(crate) fn refactor_damped(
    cached: &Mat,
    lambda: f64,
    threads: usize,
) -> Result<Mat, SolveError> {
    let mut w = cached.clone();
    w.add_diag(lambda);
    crate::linalg::cholesky_threaded(&w, threads).map_err(Into::into)
}

/// Re-damp `fact` at `lambda` and solve `v`, retrying with a ×10 λ
/// backoff on Cholesky breakdown (up to `max_retries` times) — the
/// Levenberg–Marquardt-style rescue shared by the NGD optimizer and the
/// SR driver. Each retry refactors the session's cached Gram in O(n³);
/// the O(n²m) Gram stage is never repeated. Returns `(x, λ_used,
/// retries)`.
pub fn solve_with_backoff(
    fact: &mut dyn Factorization,
    v: &[f64],
    lambda: f64,
    max_retries: usize,
) -> Result<(Vec<f64>, f64, usize), SolveError> {
    let mut lambda = lambda;
    let mut retries = 0usize;
    loop {
        match fact.redamp(lambda).and_then(|()| fact.solve(v)) {
            Ok(x) => return Ok((x, lambda, retries)),
            Err(SolveError::NotPositiveDefinite(_)) if retries < max_retries => {
                retries += 1;
                lambda *= 10.0;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Fallback session for backends with no separable factorization: every
/// `solve_into` performs one full one-shot solve. Used by the default
/// [`DampedSolver::begin`] (e.g. the PJRT fixed-shape executable).
pub struct OneShot<'s, S: DampedSolver + ?Sized> {
    solver: &'s S,
    s: &'s Mat,
    lambda: f64,
}

impl<'s, S: DampedSolver + ?Sized> OneShot<'s, S> {
    pub fn new(solver: &'s S, s: &'s Mat) -> Self {
        OneShot { solver, s, lambda: 0.0 }
    }
}

impl<S: DampedSolver + ?Sized> Factorization for OneShot<'_, S> {
    fn name(&self) -> &'static str {
        self.solver.name()
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        assert_eq!(x.len(), self.s.cols(), "x must be m-dimensional");
        let r = self.solver.solve(self.s, v, self.lambda)?;
        x.copy_from_slice(&r);
        Ok(())
    }
}

/// Arithmetic precision of the direct sessions' factor/solve stages
/// (`solver.precision`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Everything in f64 — the seed arithmetic and the default.
    #[default]
    F64,
    /// f32 Gram + Cholesky + triangular solves (≈2× kernel throughput,
    /// half the factor footprint), recovered to f64 accuracy by
    /// iterative refinement of every right-hand side against the f64
    /// matvec until the true residual meets `solver.tol`. Implemented
    /// by the `chol` and `rvb` sessions; any other kind rejects it at
    /// validation time. Refinement converges when κ(W)·u₃₂ ≪ 1
    /// (u₃₂ ≈ 6e-8); on stagnation, or on an f32 overflow/subnormal
    /// Gram, the session falls back to the f64 path automatically.
    Mixed,
}

impl Precision {
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }

    /// Parse a config/CLI spelling. `None` for unknown spellings (the
    /// caller renders the hard error with the known set).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" => Some(Precision::F64),
            "mixed" => Some(Precision::Mixed),
            _ => None,
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Per-solver tunables, settable from the `[solver]` config section or
/// `--set solver.key=value` CLI overrides. Unknown keys are hard errors
/// (the CLI's no-silent-ignore policy).
#[derive(Debug, Clone, PartialEq)]
pub struct SolverOptions {
    /// Worker threads for every dense stage of the direct solvers —
    /// Gram SYRK, the blocked Cholesky (λ-resweeps included), the
    /// multi-RHS TRSM and the session panel GEMMs all partition across
    /// this many kernel-pool jobs. Threaded results are bit-identical
    /// to serial at every count (within a fixed ISA tier).
    pub threads: usize,
    /// ISA tier override (`solver.isa = scalar|avx2|avx512|neon|auto`)
    /// for the dense kernels. `None`/`auto` (the default) dispatches on
    /// the process tier — CPUID detection or the `DNGD_KERNEL` env
    /// override. Honored by the chol and rvb sessions (the Algorithm-1
    /// pipeline); the remaining solvers always follow the process tier.
    /// Requesting a tier this CPU cannot run is a hard error at
    /// option-parse time, not a silent fallback.
    pub isa: Option<KernelIsa>,
    /// CG relative-residual tolerance ‖r‖/‖v‖.
    pub cg_tol: f64,
    /// CG iteration cap.
    pub cg_max_iters: usize,
    /// Accept CG solves that hit the iteration cap with a true residual
    /// within 100×`cg_tol` (`solver.cg_loose_accept`; default false —
    /// the PR-5 bugfix made the pre-existing silent leniency explicit,
    /// and this key is the config-surface opt-in back into it).
    pub cg_loose_accept: bool,
    /// Modeled device-memory budget in GB for `svda`/`naive`
    /// (0 = the paper's 80 GB A100).
    pub budget_gb: f64,
    /// RVB `v = Sᵀf` reconstruction tolerance (relative).
    pub rvb_tol: f64,
    /// Sliding-window size for the streaming NGD mode (`solver.window`;
    /// 0 = disabled). When set, the trainer's optimizer maintains a
    /// window of the last `window` score rows and rotates each step's
    /// batch through it with [`Factorization::update_rows`] — O(knm +
    /// kn²) per step instead of the O(n²m + n³) cold factor.
    pub window: usize,
    /// Rotations between full streaming refactors
    /// (`solver.refresh_every`; 0 = never) — the drift backstop that
    /// bounds rounding accumulation in the O(n²) factor rotations.
    pub refresh_every: usize,
    /// Factor/solve arithmetic for the direct sessions
    /// (`solver.precision = f64|mixed`; see [`Precision`]).
    pub precision: Precision,
    /// Relative true-residual target `‖v − (W)x‖/‖v‖` for the
    /// mixed-precision refinement loop (`solver.tol`). Each sweep
    /// contracts the error by ≈κ(W)·u₃₂, so well-conditioned damped
    /// systems reach this in 1–3 sweeps; stagnation before reaching it
    /// triggers the f64 fallback. Ignored by `precision = f64`.
    pub tol: f64,
    /// Block count for the structured kinds (`solver.blocks`; 0 = one
    /// block, the exact dense limit). Used by
    /// `blockdiag`/`kpsvd`/`hybrid` to split the parameter axis into
    /// this many near-equal contiguous column groups
    /// ([`super::BlockPartition::uniform`]); rejected at config
    /// validation for every other kind (see
    /// [`crate::config::Config::validate`]).
    pub blocks: usize,
    /// Per-block inner session kind for `blockdiag`/`hybrid`
    /// (`solver.block_kind = auto|chol|rvb`; `auto` picks by the cost
    /// model per block).
    pub block_kind: BlockKind,
    /// Relative true-residual tolerance for the hybrid PCG loop
    /// (`solver.hybrid_tol`).
    pub hybrid_tol: f64,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions {
            threads: 1,
            isa: None,
            cg_tol: 1e-10,
            cg_max_iters: 10_000,
            cg_loose_accept: false,
            budget_gb: 0.0,
            rvb_tol: 1e-6,
            window: 0,
            refresh_every: 64,
            precision: Precision::F64,
            tol: 1e-10,
            blocks: 0,
            block_kind: BlockKind::Auto,
            hybrid_tol: 1e-10,
        }
    }
}

impl SolverOptions {
    /// Range validation — the single source of truth shared by
    /// [`SolverOptions::apply`] (CLI `--set`) and the TOML config path.
    pub fn validate(&self) -> Result<(), String> {
        if self.cg_tol <= 0.0 {
            return Err(format!("solver.cg_tol must be > 0, got {}", self.cg_tol));
        }
        if self.cg_max_iters == 0 {
            return Err("solver.cg_max_iters must be ≥ 1".to_string());
        }
        if self.budget_gb < 0.0 {
            return Err(format!("solver.budget_gb must be ≥ 0, got {}", self.budget_gb));
        }
        if self.rvb_tol <= 0.0 {
            return Err(format!("solver.rvb_tol must be > 0, got {}", self.rvb_tol));
        }
        if self.window == 1 {
            return Err(
                "solver.window must be 0 (disabled) or ≥ 2: a one-row window has no overlap \
                 to amortize"
                    .to_string(),
            );
        }
        if !(self.tol > 0.0 && self.tol.is_finite()) {
            return Err(format!("solver.tol must be a finite value > 0, got {}", self.tol));
        }
        if !(self.hybrid_tol > 0.0 && self.hybrid_tol.is_finite()) {
            return Err(format!(
                "solver.hybrid_tol must be a finite value > 0, got {}",
                self.hybrid_tol
            ));
        }
        Ok(())
    }

    /// Kind-dependent validation: `solver.precision = mixed` is
    /// implemented by the sessions with a cached Cholesky factor —
    /// `chol` and `rvb` directly, and `blockdiag`/`hybrid` by
    /// composition through their inner per-block chol/rvb sessions.
    /// Requesting it for any other kind (including `kpsvd`, whose
    /// eigendecomposition path has no f32 twin) is a hard error — never
    /// a silent f64 fallback. Config (`cfg.validate()`) and the CLI
    /// both funnel through this.
    pub fn validate_for(&self, kind: SolverKind) -> Result<(), String> {
        self.validate()?;
        if self.precision == Precision::Mixed
            && !matches!(
                kind,
                SolverKind::Chol | SolverKind::Rvb | SolverKind::BlockDiag | SolverKind::Hybrid
            )
        {
            return Err(format!(
                "solver.precision=mixed is not supported by solver.kind={} (supported kinds: \
                 chol, rvb, blockdiag, hybrid); drop the precision override or switch kinds",
                kind.as_str()
            ));
        }
        Ok(())
    }

    /// Set one option by key. Unknown keys, unparsable values and
    /// out-of-range values are hard errors; on error the options are
    /// left unchanged.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn parse<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, String> {
            value.parse().map_err(|_| format!("solver.{key}: cannot parse {value:?}"))
        }
        let mut next = self.clone();
        match key {
            "threads" => next.threads = parse::<usize>(key, value)?.max(1),
            "isa" => {
                next.isa = match value {
                    "auto" => None,
                    spec => {
                        let isa = KernelIsa::parse(spec).ok_or_else(|| {
                            format!(
                                "solver.isa: unknown tier {spec:?} (known: scalar, avx2, avx512, \
                                 neon, auto)"
                            )
                        })?;
                        if !isa.supported() {
                            return Err(format!(
                                "solver.isa={spec} is not supported by this CPU (supported: {})",
                                KernelIsa::supported_tiers()
                                    .iter()
                                    .map(|i| i.as_str())
                                    .collect::<Vec<_>>()
                                    .join(", ")
                            ));
                        }
                        Some(isa)
                    }
                }
            }
            "cg_tol" => next.cg_tol = parse(key, value)?,
            "cg_max_iters" => next.cg_max_iters = parse(key, value)?,
            "cg_loose_accept" => next.cg_loose_accept = parse(key, value)?,
            "budget_gb" => next.budget_gb = parse(key, value)?,
            "rvb_tol" => next.rvb_tol = parse(key, value)?,
            "window" => next.window = parse(key, value)?,
            "refresh_every" => next.refresh_every = parse(key, value)?,
            "precision" => {
                next.precision = Precision::parse(value).ok_or_else(|| {
                    format!("solver.precision: unknown mode {value:?} (known: f64, mixed)")
                })?
            }
            "tol" => next.tol = parse(key, value)?,
            "blocks" => next.blocks = parse(key, value)?,
            "block_kind" => {
                next.block_kind = BlockKind::parse(value).ok_or_else(|| {
                    format!(
                        "solver.block_kind: unknown kind {value:?} (known: auto, chol, rvb)"
                    )
                })?
            }
            "hybrid_tol" => next.hybrid_tol = parse(key, value)?,
            other => {
                return Err(format!(
                    "unknown solver option {other:?} (known: threads, isa, cg_tol, cg_max_iters, \
                     cg_loose_accept, budget_gb, rvb_tol, window, refresh_every, precision, tol, \
                     blocks, block_kind, hybrid_tol)"
                ))
            }
        }
        next.validate()?;
        *self = next;
        Ok(())
    }

    /// Parse `solver.key=value` overrides (the CLI `--set` form). Keys
    /// outside the `solver.` namespace are hard errors.
    pub fn from_overrides(overrides: &[String]) -> Result<SolverOptions, String> {
        let mut opts = SolverOptions::default();
        for ov in overrides {
            let eq =
                ov.find('=').ok_or_else(|| format!("override {ov:?} is not key=value"))?;
            let key = ov[..eq].trim();
            let value = ov[eq + 1..].trim();
            let Some(skey) = key.strip_prefix("solver.") else {
                return Err(format!(
                    "override {key:?} is not a solver option (expected solver.<key>)"
                ));
            };
            opts.apply(skey, value)?;
        }
        Ok(opts)
    }

    /// The kernel configuration implied by these options.
    pub fn kernel(&self) -> KernelConfig {
        KernelConfig::with_threads(self.threads).with_isa(self.isa)
    }

    /// The modeled device budget (`budget_gb`, defaulting to the paper's
    /// 80 GB A100 when unset).
    pub fn budget(&self) -> super::MemoryBudget {
        if self.budget_gb > 0.0 {
            super::MemoryBudget::bytes_for_test((self.budget_gb * 1e9) as u64)
        } else {
            super::MemoryBudget::a100_80gb()
        }
    }
}

/// Builds boxed solvers/sessions from a [`SolverKind`] plus
/// [`SolverOptions`] — the one place config, CLI and the trainer funnel
/// solver construction through.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolverRegistry {
    pub opts: SolverOptions,
}

impl SolverRegistry {
    pub fn new(opts: SolverOptions) -> SolverRegistry {
        SolverRegistry { opts }
    }

    /// Registry from CLI `--set solver.key=value` overrides.
    pub fn from_overrides(overrides: &[String]) -> Result<SolverRegistry, String> {
        Ok(SolverRegistry { opts: SolverOptions::from_overrides(overrides)? })
    }

    /// Build a boxed solver of `kind` with this registry's options.
    pub fn build(&self, kind: SolverKind) -> Box<dyn DampedSolver + Send + Sync> {
        match kind {
            SolverKind::Chol => Box::new(
                super::CholSolver::with_config(self.opts.kernel())
                    .with_precision(self.opts.precision, self.opts.tol),
            ),
            SolverKind::Eigh => Box::new(super::EighSolver { threads: self.opts.threads }),
            SolverKind::Svda => Box::new(super::SvdaSolver {
                budget: self.opts.budget(),
                threads: self.opts.threads,
            }),
            SolverKind::Naive => Box::new(super::NaiveSolver {
                budget: self.opts.budget(),
                threads: self.opts.threads,
            }),
            SolverKind::Cg => Box::new(
                super::CgSolver::new(self.opts.cg_tol, self.opts.cg_max_iters)
                    .with_loose_accept(self.opts.cg_loose_accept),
            ),
            SolverKind::Rvb => Box::new(
                super::RvbSolver::with_config(self.opts.kernel())
                    .with_recovery_tol(self.opts.rvb_tol)
                    .with_precision(self.opts.precision, self.opts.tol),
            ),
            SolverKind::BlockDiag => Box::new(
                super::BlockDiagSolver::with_config(self.opts.kernel())
                    .with_precision(self.opts.precision, self.opts.tol)
                    .with_recovery_tol(self.opts.rvb_tol)
                    .with_blocks(self.opts.blocks, self.opts.block_kind),
            ),
            SolverKind::KpSvd => Box::new(
                super::KpSvdSolver::with_config(self.opts.kernel())
                    .with_blocks(self.opts.blocks),
            ),
            SolverKind::Hybrid => Box::new(
                super::HybridCgSolver::new(self.opts.hybrid_tol, self.opts.cg_max_iters)
                    .with_config(self.opts.kernel())
                    .with_precision(self.opts.precision, self.opts.tol)
                    .with_recovery_tol(self.opts.rvb_tol)
                    .with_blocks(self.opts.blocks, self.opts.block_kind)
                    .with_loose_accept(self.opts.cg_loose_accept),
            ),
        }
    }

    /// Build a [`SolverPlan`] pinned to problem shape (n, m).
    pub fn plan(&self, kind: SolverKind, n: usize, m: usize) -> SolverPlan {
        SolverPlan { kind, n, m, opts: self.opts.clone(), solver: self.build(kind) }
    }
}

/// A reusable solve plan: solver kind + options + problem shape, built
/// once (e.g. per training run) and used to open per-step sessions. The
/// plan validates shapes up front so a mis-wired consumer fails with a
/// [`SolveError::BadInput`] instead of a kernel assert.
pub struct SolverPlan {
    kind: SolverKind,
    n: usize,
    m: usize,
    opts: SolverOptions,
    solver: Box<dyn DampedSolver + Send + Sync>,
}

impl SolverPlan {
    /// Plan with default options (tests / examples).
    pub fn new(kind: SolverKind, n: usize, m: usize) -> SolverPlan {
        SolverRegistry::default().plan(kind, n, m)
    }

    pub fn kind(&self) -> SolverKind {
        self.kind
    }

    /// The (n, m) shape this plan was built for.
    pub fn shape(&self) -> (usize, usize) {
        (self.n, self.m)
    }

    pub fn name(&self) -> &'static str {
        self.solver.name()
    }

    pub fn options(&self) -> &SolverOptions {
        &self.opts
    }

    /// The underlying solver (escape hatch for one-shot call sites).
    pub fn solver(&self) -> &(dyn DampedSolver + Send + Sync) {
        self.solver.as_ref()
    }

    fn check_shape(&self, s: &Mat) -> Result<(), SolveError> {
        if s.shape() != (self.n, self.m) {
            return Err(SolveError::BadInput(format!(
                "plan built for shape ({}, {}), got S {:?}",
                self.n,
                self.m,
                s.shape()
            )));
        }
        Ok(())
    }

    /// Open an un-damped session against `s` (shape-checked).
    pub fn begin<'s>(&'s self, s: &'s Mat) -> Result<Box<dyn Factorization + 's>, SolveError> {
        self.check_shape(s)?;
        Ok(self.solver.begin(s))
    }

    /// Stage the factorization for (`s`, `lambda`) — the session entry
    /// point consumers call once per step / per λ-sweep.
    pub fn factor<'s>(
        &'s self,
        s: &'s Mat,
        lambda: f64,
    ) -> Result<Box<dyn Factorization + 's>, SolveError> {
        self.check_shape(s)?;
        self.solver.factor(s, lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::residual_norm;

    #[test]
    fn options_reject_unknown_keys_and_bad_values() {
        let mut o = SolverOptions::default();
        assert!(o.apply("bogus", "1").is_err());
        assert!(o.apply("cg_tol", "not-a-number").is_err());
        assert!(o.apply("cg_tol", "0").is_err());
        assert!(o.apply("cg_max_iters", "0").is_err());
        assert!(o.apply("budget_gb", "-1").is_err());
        o.apply("cg_tol", "1e-8").unwrap();
        o.apply("cg_max_iters", "500").unwrap();
        o.apply("threads", "4").unwrap();
        assert_eq!(o.cg_tol, 1e-8);
        assert_eq!(o.cg_max_iters, 500);
        assert_eq!(o.threads, 4);
        // The CG cap-leniency opt-in is config-reachable (PR 5) and a
        // hard error on non-boolean values.
        assert!(!o.cg_loose_accept);
        o.apply("cg_loose_accept", "true").unwrap();
        assert!(o.cg_loose_accept);
        assert!(o.apply("cg_loose_accept", "definitely").is_err());
    }

    #[test]
    fn streaming_window_options_parse_and_validate() {
        let mut o = SolverOptions::default();
        assert_eq!(o.window, 0, "streaming is off by default");
        assert_eq!(o.refresh_every, 64);
        o.apply("window", "256").unwrap();
        o.apply("refresh_every", "16").unwrap();
        assert_eq!(o.window, 256);
        assert_eq!(o.refresh_every, 16);
        // refresh_every = 0 disables the periodic backstop; window = 1
        // is rejected (no overlap to amortize), window = 0 disables.
        o.apply("refresh_every", "0").unwrap();
        o.apply("window", "0").unwrap();
        assert!(o.apply("window", "1").is_err());
        assert!(o.apply("window", "-3").is_err());
        assert_eq!(o.window, 0, "failed apply leaves options unchanged");
        // And the --set path reaches the registry.
        let reg = SolverRegistry::from_overrides(&["solver.window=128".into()]).unwrap();
        assert_eq!(reg.opts.window, 128);
    }

    #[test]
    fn isa_option_parses_validates_and_reaches_kernel_config() {
        use crate::linalg::KernelIsa;
        let mut o = SolverOptions::default();
        assert_eq!(o.isa, None);
        assert!(o.apply("isa", "sse9").is_err(), "unknown tier is a hard error");
        // Scalar is supported everywhere; auto restores the default.
        o.apply("isa", "scalar").unwrap();
        assert_eq!(o.isa, Some(KernelIsa::Scalar));
        assert_eq!(o.kernel().isa, Some(KernelIsa::Scalar));
        assert_eq!(o.kernel().resolved_isa(), KernelIsa::Scalar);
        o.apply("isa", "auto").unwrap();
        assert_eq!(o.isa, None);
        // Every supported tier is accepted; an unsupported one is a
        // hard error, not a silent fallback.
        for tier in KernelIsa::supported_tiers() {
            o.apply("isa", tier.as_str()).unwrap();
            assert_eq!(o.isa, Some(tier));
        }
        for tier in [KernelIsa::Avx2, KernelIsa::Avx512, KernelIsa::Neon] {
            if !tier.supported() {
                assert!(o.apply("isa", tier.as_str()).is_err());
            }
        }
        // And the --set path reaches the registry.
        let reg = SolverRegistry::from_overrides(&["solver.isa=scalar".into()]).unwrap();
        assert_eq!(reg.opts.isa, Some(KernelIsa::Scalar));
    }

    #[test]
    fn precision_option_parses_validates_and_reaches_solvers() {
        let mut o = SolverOptions::default();
        assert_eq!(o.precision, Precision::F64, "pure f64 is the default");
        assert_eq!(o.tol, 1e-10);
        o.apply("precision", "mixed").unwrap();
        assert_eq!(o.precision, Precision::Mixed);
        o.apply("precision", "f64").unwrap();
        assert_eq!(o.precision, Precision::F64);
        // Unknown modes are hard errors naming the known set.
        let err = o.apply("precision", "f16").unwrap_err();
        assert!(err.contains("f64") && err.contains("mixed"), "{err}");
        assert_eq!(o.precision, Precision::F64, "failed apply leaves options unchanged");
        // The refinement target is validated like every other tolerance.
        o.apply("tol", "1e-12").unwrap();
        assert_eq!(o.tol, 1e-12);
        assert!(o.apply("tol", "0").is_err());
        assert!(o.apply("tol", "nan").is_err());
        // --set reaches the registry, and the built chol/rvb solvers
        // carry the mode.
        let reg = SolverRegistry::from_overrides(&[
            "solver.precision=mixed".into(),
            "solver.tol=1e-9".into(),
        ])
        .unwrap();
        assert_eq!(reg.opts.precision, Precision::Mixed);
        assert_eq!(reg.opts.tol, 1e-9);
        let mut rng = Rng::seed_from(503);
        let s = Mat::randn(8, 40, &mut rng);
        let f: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        for kind in [SolverKind::Chol, SolverKind::Rvb] {
            let mf0 = crate::solver::mixed_counters::mixed_factors();
            let x = reg.build(kind).solve(&s, &v, 0.1).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
            assert!(
                crate::solver::mixed_counters::mixed_factors() > mf0,
                "{} did not route through the f32 factor",
                kind.as_str()
            );
        }
    }

    #[test]
    fn mixed_precision_rejected_for_unsupported_kinds() {
        let mut o = SolverOptions::default();
        o.apply("precision", "mixed").unwrap();
        for kind in [SolverKind::Chol, SolverKind::Rvb] {
            o.validate_for(kind).unwrap();
        }
        for kind in [
            SolverKind::Eigh,
            SolverKind::Svda,
            SolverKind::Naive,
            SolverKind::Cg,
            SolverKind::KpSvd,
        ] {
            let err = o.validate_for(kind).unwrap_err();
            assert!(
                err.contains("precision=mixed") && err.contains(kind.as_str()),
                "error must name the setting and the kind: {err}"
            );
            assert!(err.contains("chol") && err.contains("rvb"), "{err}");
        }
        // Pure f64 is valid everywhere.
        o.apply("precision", "f64").unwrap();
        for &kind in SolverKind::all() {
            o.validate_for(kind).unwrap();
        }
    }

    #[test]
    fn structured_options_parse_and_validate() {
        let mut o = SolverOptions::default();
        assert_eq!(o.blocks, 0, "one block (the exact dense limit) is the default");
        assert_eq!(o.block_kind, BlockKind::Auto);
        assert_eq!(o.hybrid_tol, 1e-10);
        o.apply("blocks", "16").unwrap();
        o.apply("block_kind", "chol").unwrap();
        o.apply("hybrid_tol", "1e-8").unwrap();
        assert_eq!(o.blocks, 16);
        assert_eq!(o.block_kind, BlockKind::Chol);
        assert_eq!(o.hybrid_tol, 1e-8);
        // Unknown block kinds and degenerate tolerances are hard errors
        // that leave the options unchanged.
        let err = o.apply("block_kind", "kfac").unwrap_err();
        assert!(err.contains("auto") && err.contains("chol") && err.contains("rvb"), "{err}");
        assert!(o.apply("hybrid_tol", "0").is_err());
        assert!(o.apply("hybrid_tol", "nan").is_err());
        assert_eq!(o.block_kind, BlockKind::Chol);
        assert_eq!(o.hybrid_tol, 1e-8);
        // Mixed precision composes through the inner block sessions of
        // blockdiag/hybrid, and is rejected by name for kpsvd.
        o.apply("precision", "mixed").unwrap();
        o.validate_for(SolverKind::BlockDiag).unwrap();
        o.validate_for(SolverKind::Hybrid).unwrap();
        let err = o.validate_for(SolverKind::KpSvd).unwrap_err();
        assert!(err.contains("kpsvd") && err.contains("precision=mixed"), "{err}");
        // The --set path reaches the registry.
        let reg = SolverRegistry::from_overrides(&[
            "solver.blocks=4".into(),
            "solver.block_kind=rvb".into(),
            "solver.hybrid_tol=1e-9".into(),
        ])
        .unwrap();
        assert_eq!(reg.opts.blocks, 4);
        assert_eq!(reg.opts.block_kind, BlockKind::Rvb);
        assert_eq!(reg.opts.hybrid_tol, 1e-9);
    }

    #[test]
    fn overrides_require_solver_namespace() {
        assert!(SolverOptions::from_overrides(&["solver.cg_tol=1e-9".into()]).is_ok());
        assert!(SolverOptions::from_overrides(&["train.steps=5".into()]).is_err());
        assert!(SolverOptions::from_overrides(&["solver.nope=1".into()]).is_err());
        assert!(SolverOptions::from_overrides(&["no_equals".into()]).is_err());
    }

    #[test]
    fn plan_rejects_wrong_shape() {
        let mut rng = Rng::seed_from(500);
        let plan = SolverPlan::new(SolverKind::Chol, 8, 32);
        let wrong = Mat::randn(8, 33, &mut rng);
        assert!(matches!(plan.factor(&wrong, 0.1), Err(SolveError::BadInput(_))));
        let right = Mat::randn(8, 32, &mut rng);
        assert!(plan.factor(&right, 0.1).is_ok());
    }

    #[test]
    fn plan_session_solves_and_resweeps() {
        let mut rng = Rng::seed_from(501);
        let (n, m) = (10usize, 50usize);
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let plan = SolverPlan::new(SolverKind::Chol, n, m);
        let mut fact = plan.factor(&s, 0.5).unwrap();
        let x1 = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x1, &v, 0.5) < 1e-8);
        fact.redamp(0.01).unwrap();
        let x2 = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x2, &v, 0.01) < 1e-8);
    }

    #[test]
    fn undamped_session_refuses_to_solve() {
        let mut rng = Rng::seed_from(502);
        let s = Mat::randn(4, 12, &mut rng);
        let plan = SolverPlan::new(SolverKind::Chol, 4, 12);
        let mut fact = plan.begin(&s).unwrap();
        let v = vec![1.0; 12];
        let mut x = vec![0.0; 12];
        assert!(matches!(fact.solve_into(&v, &mut x), Err(SolveError::BadInput(_))));
        assert!(matches!(fact.redamp(0.0), Err(SolveError::BadInput(_))));
        fact.redamp(0.1).unwrap();
        fact.solve_into(&v, &mut x).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.1) < 1e-8);
    }

    #[test]
    fn registry_builds_every_kind() {
        let reg = SolverRegistry::default();
        for &kind in SolverKind::all() {
            let solver = reg.build(kind);
            assert_eq!(solver.name(), kind.as_str());
        }
    }
}
