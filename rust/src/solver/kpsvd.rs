//! Kronecker-product-SVD structured Fisher sessions (PR 10).
//!
//! Koroko et al. (2201.10285) approximate each layer's Fisher block by
//! its **nearest Kronecker product**: for a block Gram `G: m_b×m_b`
//! with `m_b = p·q`, find `A: p×p`, `B: q×q` minimizing
//! `‖G − A⊗B‖_F`. Van Loan–Pitsianis reduces this to the dominant
//! singular triple of the *rearrangement* `R(G): p²×q²` with
//! `R[i·p+j, k·q+l] = G[i·q+k, j·q+l]` — vec(A) and vec(B) are the
//! leading left/right singular vectors scaled by σ₁. The damped solve
//! against `A⊗B + λI` is then two small eigendecompositions
//! (λ-independent, cached) plus reshape-multiplies per right-hand
//! side:
//!
//! ```text
//! A = U_A diag(α) U_Aᵀ,  B = U_B diag(β) U_Bᵀ
//! (A⊗B + λI)⁻¹ v  =  vec⁻¹( U_A [ (U_Aᵀ V U_B) ⊘ (αβᵀ + λ) ] U_Bᵀ )
//! ```
//!
//! so a λ-resweep is **O(1)** — the division happens at solve time —
//! and the per-RHS cost is O(p·q·(p+q)). The factor stage costs the
//! O(m_b²·n) block Gram plus the rearranged power iteration; this only
//! pays off when many λ/RHS hit the same window (the trainer's backoff
//! chains, serving). Like K-FAC it is *approximate* unless the block
//! Gram is exactly Kronecker (pinned by a test on `S = S_A ⊗ S_B`);
//! EXPERIMENTS.md §Structured quantifies the gap and the hybrid session
//! ([`super::hybrid`]) closes it by CG-correcting against the exact
//! system.
//!
//! `p` is chosen as the largest divisor of `m_b` with `p ≤ √m_b`; a
//! prime `m_b` degenerates to `p = 1`, where `A⊗B = B = G` and the
//! session is an *exact* damped eigendecomposition of the block Gram.

use super::blockdiag::{resolve_partition, BlockPartition};
use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::gemm::gemm_tn_threaded;
use crate::linalg::mat::norm2;
use crate::linalg::{eigh, KernelConfig, Mat};

/// Fixed power-iteration count for the dominant singular triple of the
/// rearranged block. Deterministic (fixed start vector, fixed count),
/// and ample: the iterate error contracts like (σ₂/σ₁)² per step.
const POWER_ITERS: usize = 40;

/// The Kronecker-product-SVD structured solver ("kpsvd").
#[derive(Debug, Clone)]
pub struct KpSvdSolver {
    cfg: KernelConfig,
    blocks: usize,
    partition: Option<BlockPartition>,
}

impl Default for KpSvdSolver {
    fn default() -> Self {
        KpSvdSolver { cfg: KernelConfig::with_threads(1), blocks: 0, partition: None }
    }
}

impl KpSvdSolver {
    pub fn new() -> Self {
        KpSvdSolver::default()
    }

    /// Kernel configuration — threads reach the O(m_b²·n) block-Gram
    /// GEMMs (the dominant factor cost).
    pub fn with_config(cfg: KernelConfig) -> Self {
        KpSvdSolver { cfg, ..KpSvdSolver::default() }
    }

    /// Uniform block count (`solver.blocks`; 0 = one block).
    pub fn with_blocks(mut self, blocks: usize) -> Self {
        self.blocks = blocks;
        self
    }

    /// Explicit (non-uniform) partition.
    pub fn with_partition(mut self, partition: BlockPartition) -> Self {
        self.partition = Some(partition);
        self
    }

    fn open(&self, s: &Mat) -> KpSvdFactor {
        match resolve_partition(self.partition.as_ref(), self.blocks, s.cols()) {
            Ok(partition) => {
                let shards = partition
                    .ranges()
                    .iter()
                    .map(|&(c0, c1)| s.slice_cols(c0, c1))
                    .collect();
                KpSvdFactor {
                    partition,
                    shards,
                    threads: self.cfg.threads.max(1),
                    kron: Vec::new(),
                    m: s.cols(),
                    lambda: 0.0,
                    poisoned: None,
                }
            }
            Err(e) => KpSvdFactor {
                partition: BlockPartition::uniform(1, 1).expect("trivial partition"),
                shards: Vec::new(),
                threads: 1,
                kron: Vec::new(),
                m: s.cols(),
                lambda: 0.0,
                poisoned: Some(e),
            },
        }
    }
}

impl DampedSolver for KpSvdSolver {
    fn name(&self) -> &'static str {
        "kpsvd"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(self.open(s))
    }

    // No `begin_window` override: the Kronecker caches have no O(kn²)
    // row-rotation update, so streaming drivers fall back to a cold
    // refactor per rotation (the optimizer handles `None` natively).
}

/// The cached λ-independent Kronecker eigenstructure of one block.
struct KronBlock {
    p: usize,
    q: usize,
    /// Eigenvalues of the nearest-Kronecker factors, clamped ≥ 0 (the
    /// rank-1 truncation can leave tiny negative dust; the damped
    /// denominator `α·β + λ` must stay ≥ λ).
    alpha: Vec<f64>,
    beta: Vec<f64>,
    ua: Mat,
    ub: Mat,
}

/// A staged KP-SVD factorization: per-block nearest-Kronecker
/// eigendecompositions, computed once on the first
/// [`Factorization::redamp`] and reused by every λ-resweep (`redamp` is
/// O(1) — the damping enters at solve time as `⊘ (αβᵀ + λ)`).
pub struct KpSvdFactor {
    partition: BlockPartition,
    shards: Vec<Mat>,
    threads: usize,
    kron: Vec<KronBlock>,
    m: usize,
    lambda: f64,
    poisoned: Option<SolveError>,
}

impl KpSvdFactor {
    fn check_poisoned(&self) -> Result<(), SolveError> {
        match &self.poisoned {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Largest divisor of `m_b` that is ≤ √m_b (1 for primes).
    fn split_dim(mb: usize) -> usize {
        let mut best = 1;
        let mut d = 1;
        while d * d <= mb {
            if mb % d == 0 {
                best = d;
            }
            d += 1;
        }
        best
    }

    /// Van Loan–Pitsianis rearrangement `R(G): p²×q²`,
    /// `R[i·p+j, k·q+l] = G[i·q+k, j·q+l]`.
    fn rearrange(g: &Mat, p: usize, q: usize) -> Mat {
        let mut r = Mat::zeros(p * p, q * q);
        for i in 0..p {
            for j in 0..p {
                let row = r.row_mut(i * p + j);
                for k in 0..q {
                    for l in 0..q {
                        row[k * q + l] = g[(i * q + k, j * q + l)];
                    }
                }
            }
        }
        r
    }

    /// Nearest Kronecker factors of one block Gram, as their damped
    /// eigenstructure.
    fn kron_block(&self, g: &Mat) -> KronBlock {
        let mb = g.rows();
        let p = Self::split_dim(mb);
        let q = mb / p;
        if p == 1 {
            // Degenerate split: A⊗B = B = G exactly — the session is an
            // exact damped eigendecomposition of the block Gram.
            let (beta, ub) = eigh(g);
            let beta = beta.into_iter().map(|b| b.max(0.0)).collect();
            let mut ua = Mat::zeros(1, 1);
            ua[(0, 0)] = 1.0;
            return KronBlock { p, q, alpha: vec![1.0], beta, ua, ub };
        }
        let r = Self::rearrange(g, p, q);
        // Dominant right singular vector by deterministic power
        // iteration on RᵀR, started from vec(I_q) (symmetric, never
        // orthogonal to the leading triple of a PSD Gram's
        // rearrangement in practice).
        let mut v = vec![0.0; q * q];
        for k in 0..q {
            v[k * q + k] = 1.0;
        }
        let vnorm = norm2(&v);
        for e in &mut v {
            *e /= vnorm;
        }
        for _ in 0..POWER_ITERS {
            let u = r.matvec(&v);
            let w = r.t_matvec(&u);
            let wnorm = norm2(&w);
            if wnorm <= 0.0 {
                break; // zero Gram: factors stay zero, solve is v/λ
            }
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / wnorm;
            }
        }
        let u = r.matvec(&v); // = σ₁·u₁, absorbing the singular value into A
        let mut a = Mat::zeros(p, p);
        for i in 0..p {
            for j in 0..p {
                a[(i, j)] = u[i * p + j];
            }
        }
        let mut b = Mat::zeros(q, q);
        for k in 0..q {
            for l in 0..q {
                b[(k, l)] = v[k * q + l];
            }
        }
        // G is symmetric, so the nearest Kronecker factors are too up
        // to rounding — symmetrize, and fix the joint sign so B (hence
        // A, since G is PSD) has non-negative trace: (−A)⊗(−B) = A⊗B.
        symmetrize(&mut a);
        symmetrize(&mut b);
        let tb: f64 = (0..q).map(|k| b[(k, k)]).sum();
        if tb < 0.0 {
            for e in a.as_mut_slice() {
                *e = -*e;
            }
            for e in b.as_mut_slice() {
                *e = -*e;
            }
        }
        let (alpha, ua) = eigh(&a);
        let (beta, ub) = eigh(&b);
        KronBlock {
            p,
            q,
            alpha: alpha.into_iter().map(|x| x.max(0.0)).collect(),
            beta: beta.into_iter().map(|x| x.max(0.0)).collect(),
            ua,
            ub,
        }
    }

    fn build_caches(&mut self) {
        if !self.kron.is_empty() {
            return;
        }
        let mut kron = Vec::with_capacity(self.shards.len());
        for sb in &self.shards {
            let mb = sb.cols();
            // Block Gram G_b = S_bᵀS_b — the O(m_b²·n) stage, threaded.
            let mut g = Mat::zeros(mb, mb);
            gemm_tn_threaded(1.0, sb, sb, 0.0, &mut g, self.threads);
            kron.push(self.kron_block(&g));
        }
        self.kron = kron;
    }
}

fn symmetrize(a: &mut Mat) {
    let n = a.rows();
    for i in 0..n {
        for j in 0..i {
            let s = 0.5 * (a[(i, j)] + a[(j, i)]);
            a[(i, j)] = s;
            a[(j, i)] = s;
        }
    }
}

/// `C = Aᵀ·B` for small dense blocks (serial — these are p×q-sized).
fn small_gemm_tn(a: &Mat, b: &Mat) -> Mat {
    let (k, p) = a.shape();
    let (k2, q) = b.shape();
    assert_eq!(k, k2);
    let mut c = Mat::zeros(p, q);
    for t in 0..k {
        let arow = a.row(t);
        let brow = b.row(t);
        for i in 0..p {
            let ai = arow[i];
            if ai == 0.0 {
                continue;
            }
            let crow = c.row_mut(i);
            for j in 0..q {
                crow[j] += ai * brow[j];
            }
        }
    }
    c
}

/// `C = A·B` for small dense blocks.
fn small_gemm(a: &Mat, b: &Mat) -> Mat {
    let (p, k) = a.shape();
    let (k2, q) = b.shape();
    assert_eq!(k, k2);
    let mut c = Mat::zeros(p, q);
    for i in 0..p {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (t, &at) in arow.iter().enumerate() {
            if at == 0.0 {
                continue;
            }
            let brow = b.row(t);
            for j in 0..q {
                crow[j] += at * brow[j];
            }
        }
    }
    c
}

/// `C = A·Bᵀ` for small dense blocks.
fn small_gemm_nt(a: &Mat, b: &Mat) -> Mat {
    let (p, k) = a.shape();
    let (q, k2) = b.shape();
    assert_eq!(k, k2);
    let mut c = Mat::zeros(p, q);
    for i in 0..p {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..q {
            let brow = b.row(j);
            let mut acc = 0.0;
            for t in 0..k {
                acc += arow[t] * brow[t];
            }
            crow[j] = acc;
        }
    }
    c
}

impl Factorization for KpSvdFactor {
    fn name(&self) -> &'static str {
        "kpsvd"
    }

    fn dim(&self) -> usize {
        self.m
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        self.check_poisoned()?;
        check_lambda(lambda)?;
        self.build_caches();
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        self.check_poisoned()?;
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        assert_eq!(v.len(), self.m, "v must be m-dimensional");
        assert_eq!(x.len(), self.m, "x must be m-dimensional");
        let lambda = self.lambda;
        for (b, &(c0, _c1)) in self.partition.ranges().iter().enumerate() {
            let kb = &self.kron[b];
            let (p, q) = (kb.p, kb.q);
            // vec⁻¹: V[i,k] = v[c0 + i·q + k].
            let mut vmat = Mat::zeros(p, q);
            for i in 0..p {
                vmat.row_mut(i).copy_from_slice(&v[c0 + i * q..c0 + (i + 1) * q]);
            }
            // W = U_Aᵀ V U_B, damped-divide, X = U_A W U_Bᵀ.
            let mut w = small_gemm(&small_gemm_tn(&kb.ua, &vmat), &kb.ub);
            for a in 0..p {
                let alpha = kb.alpha[a];
                let wrow = w.row_mut(a);
                for (bb, wv) in wrow.iter_mut().enumerate() {
                    *wv /= alpha * kb.beta[bb] + lambda;
                }
            }
            let xmat = small_gemm_nt(&small_gemm(&kb.ua, &w), &kb.ub);
            for i in 0..p {
                x[c0 + i * q..c0 + (i + 1) * q].copy_from_slice(xmat.row(i));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::CholSolver;

    /// Kronecker product of two score matrices: columns indexed
    /// (i, k) → i·q + k, matching the session's reshape convention.
    fn kron(a: &Mat, b: &Mat) -> Mat {
        let (na, p) = a.shape();
        let (nb, q) = b.shape();
        let mut out = Mat::zeros(na * nb, p * q);
        for ra in 0..na {
            for rb in 0..nb {
                let row = out.row_mut(ra * nb + rb);
                for i in 0..p {
                    for k in 0..q {
                        row[i * q + k] = a[(ra, i)] * b[(rb, k)];
                    }
                }
            }
        }
        out
    }

    #[test]
    fn split_dim_prefers_near_square() {
        assert_eq!(KpSvdFactor::split_dim(16), 4);
        assert_eq!(KpSvdFactor::split_dim(12), 3);
        assert_eq!(KpSvdFactor::split_dim(15), 3);
        assert_eq!(KpSvdFactor::split_dim(13), 1); // prime → degenerate
        assert_eq!(KpSvdFactor::split_dim(1), 1);
    }

    #[test]
    fn exact_on_kronecker_structured_scores() {
        // S = S_A ⊗ S_B ⇒ SᵀS = (S_AᵀS_A)⊗(S_BᵀS_B): the nearest
        // Kronecker factor is exact and kpsvd must agree with chol.
        let mut rng = Rng::seed_from(1101);
        let sa = Mat::randn(3, 4, &mut rng);
        let sb = Mat::randn(4, 5, &mut rng);
        let s = kron(&sa, &sb); // 12×20, m_b = 20 → p=4, q=5
        let v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        for lambda in [1.0, 0.1, 0.01] {
            let x = KpSvdSolver::new().solve(&s, &v, lambda).unwrap();
            let xc = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let scale = norm2(&xc).max(1.0);
            for (a, b) in x.iter().zip(&xc) {
                assert!((a - b).abs() < 1e-8 * scale, "kpsvd vs chol at λ={lambda}");
            }
        }
    }

    #[test]
    fn prime_block_width_is_exact_eigh() {
        // m_b = 13 (prime) degenerates to the exact eigendecomposition.
        let mut rng = Rng::seed_from(1102);
        let s = Mat::randn(6, 13, &mut rng);
        let v: Vec<f64> = (0..13).map(|_| rng.normal()).collect();
        let x = KpSvdSolver::new().solve(&s, &v, 0.05).unwrap();
        let xc = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        for (a, b) in x.iter().zip(&xc) {
            assert!((a - b).abs() < 1e-9, "degenerate kpsvd must be exact");
        }
    }

    #[test]
    fn resweep_reuses_caches_and_streaming_is_rejected() {
        let mut rng = Rng::seed_from(1103);
        let s = Mat::randn(8, 24, &mut rng);
        let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let solver = KpSvdSolver::new().with_blocks(2);
        let mut fact = solver.factor(&s, 0.5).unwrap();
        let x1 = fact.solve(&v).unwrap();
        fact.redamp(0.05).unwrap(); // O(1): division happens at solve time
        let x2 = fact.solve(&v).unwrap();
        assert!(x1.iter().zip(&x2).any(|(a, b)| a != b));
        // No native rotation/refresh — streaming drivers must refactor.
        let added = Mat::randn(1, 24, &mut rng);
        assert!(matches!(fact.update_rows(&[0], &added), Err(SolveError::BadInput(_))));
        assert!(matches!(fact.refresh(), Err(SolveError::BadInput(_))));
        assert!(solver.begin_window(s.clone()).is_none());
    }
}
