//! The RVB+23 method — Appendix B.
//!
//! When the gradient has least-squares structure `v = Sᵀf` (f ∈ ℝⁿ),
//! Rende et al. solve
//!
//! ```text
//! x_rvb = Sᵀ (SSᵀ + λĨ)⁻¹ f
//! ```
//!
//! Appendix B proves `x_rvb ≡ x_chol` in that case. This module implements
//! the method (with Cholesky solve, as the paper suggests) both to serve
//! as the least-squares fast path and to regenerate the Appendix-B
//! equivalence as an executable test. Its *limitation* — it requires
//! `v ∈ rowspace(S)` and "prevents the use of regularization" on the loss
//! — is surfaced as a checked precondition ([`SolveError::BadInput`]),
//! reachable from configs and the CLI via `SolverKind::Rvb` since PR 2.
//!
//! Session note (PR 2): [`RvbFactor`] caches *two* λ-independent objects —
//! the un-damped Gram `SSᵀ` (shared with the damped factor) and the
//! tiny-ridge recovery factor used to reconstruct `f` from `v` — so both
//! λ-resweeps and repeated right-hand sides skip all O(n²m) work.

use super::session::{check_lambda, refactor_damped, undamped_err};
use super::{CholSolver, DampedSolver, Factorization, SolveError};
use crate::linalg::gemm::{syrk, syrk_parallel};
use crate::linalg::{cholesky_threaded, solve_lower, solve_lower_transpose, KernelConfig, Mat};

/// RVB+23 least-squares solver.
#[derive(Debug, Clone)]
pub struct RvbSolver {
    inner: CholSolver,
    /// Relative tolerance for the `v = Sᵀf` reconstruction check.
    pub recovery_tol: f64,
}

impl Default for RvbSolver {
    fn default() -> Self {
        RvbSolver { inner: CholSolver::default(), recovery_tol: 1e-6 }
    }
}

impl RvbSolver {
    pub fn with_threads(threads: usize) -> Self {
        RvbSolver { inner: CholSolver::with_threads(threads), recovery_tol: 1e-6 }
    }

    /// Construct from the shared kernel configuration — threads and the
    /// PR-4 ISA tier override both flow through to every dense stage.
    pub fn with_config(cfg: KernelConfig) -> Self {
        RvbSolver { inner: CholSolver::with_config(cfg), recovery_tol: 1e-6 }
    }

    /// Override the `v = Sᵀf` reconstruction tolerance
    /// (`solver.rvb_tol` in configs).
    pub fn with_recovery_tol(mut self, tol: f64) -> Self {
        self.recovery_tol = tol;
        self
    }

    /// Solve given the least-squares coefficient vector `f` directly:
    /// `x = Sᵀ(SSᵀ + λĨ)⁻¹ f`. This is the method's native entry point.
    pub fn solve_ls(&self, s: &Mat, f: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(f.len(), s.rows(), "f must be n-dimensional");
        check_lambda(lambda)?;
        let l = self.inner.gram_factor(s, lambda)?;
        self.inner.kernel_config().run(|| {
            let y = solve_lower(&l, f);
            let u = solve_lower_transpose(&l, &y);
            Ok(s.t_matvec(&u))
        })
    }

    /// Recover `f` from `v = Sᵀf` by solving the (well-damped) consistency
    /// system `SSᵀ f = S v`, then verify the reconstruction. Returns
    /// `BadInput` if `v` is not in the row space of `S` — the structural
    /// limitation §3 calls out.
    pub fn recover_f(&self, s: &Mat, v: &[f64], tol: f64) -> Result<Vec<f64>, SolveError> {
        self.inner.kernel_config().run(|| {
            let sv = s.matvec(v);
            // SSᵀ may be singular; tiny ridge for the recovery only.
            let w = if self.inner.threads > 1 {
                syrk_parallel(s, recovery_ridge(s), self.inner.threads)
            } else {
                syrk(s, recovery_ridge(s))
            };
            let l = cholesky_threaded(&w, self.inner.threads)?;
            let f = solve_lower_transpose(&l, &solve_lower(&l, &sv));
            verify_reconstruction(s, v, &f, tol)?;
            Ok(f)
        })
    }
}

/// Ridge used to regularize the (possibly singular) recovery system.
fn recovery_ridge(s: &Mat) -> f64 {
    let f = s.fro_norm();
    (1e-12 * f * f).max(1e-300)
}

/// Check `v ≈ Sᵀf`; error with the §3 limitation message otherwise.
fn verify_reconstruction(s: &Mat, v: &[f64], f: &[f64], tol: f64) -> Result<(), SolveError> {
    let recon = s.t_matvec(f);
    let vnorm = crate::linalg::mat::norm2(v).max(f64::MIN_POSITIVE);
    let err: f64 = v
        .iter()
        .zip(&recon)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    if err > tol * vnorm {
        return Err(SolveError::BadInput(format!(
            "v is not in rowspace(S): relative reconstruction error {:.3e} — the RVB method \
             requires least-squares structure v = Sᵀf (paper §3)",
            err / vnorm
        )));
    }
    Ok(())
}

/// RVB session: un-damped Gram + λ-independent recovery factor cached.
pub struct RvbFactor<'s> {
    s: &'s Mat,
    cfg: KernelConfig,
    recovery_tol: f64,
    lambda: f64,
    /// Cached `SSᵀ` (no damping).
    gram: Option<Mat>,
    /// `Chol(SSᵀ + λĨ)` for the current λ.
    l: Option<Mat>,
    /// `Chol(SSᵀ + εĨ)` for the f-recovery (λ-independent).
    recovery_l: Option<Mat>,
}

impl<'s> RvbFactor<'s> {
    fn new(s: &'s Mat, cfg: KernelConfig, recovery_tol: f64) -> Self {
        RvbFactor {
            s,
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            recovery_tol,
            lambda: 0.0,
            gram: None,
            l: None,
            recovery_l: None,
        }
    }

    fn ensure_gram(&mut self) -> &Mat {
        if self.gram.is_none() {
            let threads = self.cfg.threads;
            let g = self.cfg.run(|| {
                if threads > 1 {
                    syrk_parallel(self.s, 0.0, threads)
                } else {
                    syrk(self.s, 0.0)
                }
            });
            self.gram = Some(g);
        }
        self.gram.as_ref().unwrap()
    }

    fn ensure_recovery(&mut self) -> Result<(), SolveError> {
        if self.recovery_l.is_none() {
            let ridge = recovery_ridge(self.s);
            let cfg = self.cfg;
            self.ensure_gram();
            let rl =
                cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), ridge, cfg.threads))?;
            self.recovery_l = Some(rl);
        }
        Ok(())
    }
}

impl Factorization for RvbFactor<'_> {
    fn name(&self) -> &'static str {
        "rvb"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        let cfg = self.cfg;
        self.ensure_gram();
        match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        if self.l.is_none() {
            return Err(undamped_err());
        }
        self.ensure_recovery()?;
        let s = self.s;
        let recovery_tol = self.recovery_tol;
        let rl = self.recovery_l.as_ref().unwrap();
        let l = self.l.as_ref().unwrap();
        self.cfg.run(|| {
            // Recover f (rejecting v ∉ rowspace(S) — the precondition
            // the registry surfaces as BadInput).
            let sv = s.matvec(v);
            let f = solve_lower_transpose(rl, &solve_lower(rl, &sv));
            verify_reconstruction(s, v, &f, recovery_tol)?;
            // x = Sᵀ(SSᵀ + λĨ)⁻¹ f through the cached damped factor.
            let y = solve_lower(l, &f);
            let u = solve_lower_transpose(l, &y);
            s.t_matvec_into(&u, x);
            Ok(())
        })
    }
}

impl DampedSolver for RvbSolver {
    fn name(&self) -> &'static str {
        "rvb"
    }

    /// General-v session: recovers `f` per right-hand side (rejecting
    /// v ∉ rowspace(S)), then applies the least-squares identity against
    /// the cached factors.
    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(RvbFactor::new(s, self.inner.kernel_config(), self.recovery_tol))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::CholSolver;

    /// Appendix B, executable: x_rvb == x_chol when v = Sᵀf.
    #[test]
    fn appendix_b_equivalence() {
        let mut rng = Rng::seed_from(160);
        for &(n, m, lambda) in &[(3usize, 12usize, 0.5f64), (10, 80, 1e-2), (24, 300, 1e-4)] {
            let s = Mat::randn(n, m, &mut rng);
            let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v = s.t_matvec(&f);
            let x_rvb = RvbSolver::default().solve_ls(&s, &f, lambda).unwrap();
            let x_chol = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let scale = crate::linalg::mat::norm2(&x_chol).max(1.0);
            for (a, b) in x_rvb.iter().zip(&x_chol) {
                assert!(
                    (a - b).abs() < 1e-9 * scale,
                    "Appendix-B equivalence broken at ({n},{m},λ={lambda})"
                );
            }
        }
    }

    #[test]
    fn rejects_v_outside_rowspace() {
        // Random v with m ≫ n is almost surely not Sᵀf for any f — the
        // limitation that motivates Algorithm 1's generality.
        let mut rng = Rng::seed_from(161);
        let s = Mat::randn(4, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        match RvbSolver::default().solve(&s, &v, 0.1) {
            Err(SolveError::BadInput(msg)) => assert!(msg.contains("rowspace")),
            other => panic!("expected rowspace rejection, got {other:?}"),
        }
    }

    #[test]
    fn accepts_v_inside_rowspace_via_general_entry() {
        let mut rng = Rng::seed_from(162);
        let s = Mat::randn(6, 50, &mut rng);
        let f: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let x = RvbSolver::default().solve(&s, &v, 0.05).unwrap();
        let x_ref = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn session_resweep_matches_cold_and_keeps_precondition() {
        let mut rng = Rng::seed_from(163);
        let s = Mat::randn(5, 30, &mut rng);
        let f: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let solver = RvbSolver::default();
        let mut fact = solver.factor(&s, 0.2).unwrap();
        fact.redamp(0.02).unwrap();
        let warm = fact.solve(&v).unwrap();
        let cold = solver.solve(&s, &v, 0.02).unwrap();
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a - b).abs() < 1e-12);
        }
        // The precondition survives the session path too.
        let bad: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        assert!(matches!(fact.solve(&bad), Err(SolveError::BadInput(_))));
    }
}
