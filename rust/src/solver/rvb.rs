//! The RVB+23 method — Appendix B.
//!
//! When the gradient has least-squares structure `v = Sᵀf` (f ∈ ℝⁿ),
//! Rende et al. solve
//!
//! ```text
//! x_rvb = Sᵀ (SSᵀ + λĨ)⁻¹ f
//! ```
//!
//! Appendix B proves `x_rvb ≡ x_chol` in that case. This module implements
//! the method (with Cholesky solve, as the paper suggests) both to serve
//! as the least-squares fast path and to regenerate the Appendix-B
//! equivalence as an executable test. Its *limitation* — it requires
//! `v ∈ rowspace(S)` and "prevents the use of regularization" on the loss
//! — is surfaced as a checked precondition ([`SolveError::BadInput`]),
//! reachable from configs and the CLI via `SolverKind::Rvb` since PR 2.
//!
//! Session note (PR 2): [`RvbFactor`] caches *two* λ-independent objects —
//! the un-damped Gram `SSᵀ` (shared with the damped factor) and the
//! tiny-ridge recovery factor used to reconstruct `f` from `v` — so both
//! λ-resweeps and repeated right-hand sides skip all O(n²m) work.

use super::chol::{mixed_counters, rotate_gram_session, MixedGramSolve};
use super::session::{check_lambda, refactor_damped, undamped_err, Precision};
use super::{CholSolver, DampedSolver, Factorization, SolveError};
use crate::linalg::gemm::{syrk, syrk_parallel};
use crate::linalg::{cholesky_threaded, solve_lower, solve_lower_transpose, KernelConfig, Mat};

/// RVB+23 least-squares solver.
#[derive(Debug, Clone)]
pub struct RvbSolver {
    inner: CholSolver,
    /// Relative tolerance for the `v = Sᵀf` reconstruction check.
    pub recovery_tol: f64,
}

impl Default for RvbSolver {
    fn default() -> Self {
        RvbSolver { inner: CholSolver::default(), recovery_tol: 1e-6 }
    }
}

impl RvbSolver {
    pub fn with_threads(threads: usize) -> Self {
        RvbSolver { inner: CholSolver::with_threads(threads), recovery_tol: 1e-6 }
    }

    /// Construct from the shared kernel configuration — threads and the
    /// PR-4 ISA tier override both flow through to every dense stage.
    pub fn with_config(cfg: KernelConfig) -> Self {
        RvbSolver { inner: CholSolver::with_config(cfg), recovery_tol: 1e-6 }
    }

    /// Override the `v = Sᵀf` reconstruction tolerance
    /// (`solver.rvb_tol` in configs).
    pub fn with_recovery_tol(mut self, tol: f64) -> Self {
        self.recovery_tol = tol;
        self
    }

    /// Select the damped factor/solve arithmetic (`solver.precision` /
    /// `solver.tol`, PR 6). Under `mixed` the λ-independent recovery
    /// factor stays f64 (its tiny ridge makes the recovery system far
    /// too ill-conditioned for f32 refinement) — only the damped factor
    /// and its triangular solves move to f32, refined per RHS against
    /// the f64 Gram residual.
    pub fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.inner = self.inner.with_precision(precision, tol);
        self
    }

    /// Solve given the least-squares coefficient vector `f` directly:
    /// `x = Sᵀ(SSᵀ + λĨ)⁻¹ f`. This is the method's native entry point.
    pub fn solve_ls(&self, s: &Mat, f: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(f.len(), s.rows(), "f must be n-dimensional");
        check_lambda(lambda)?;
        let l = self.inner.gram_factor(s, lambda)?;
        self.inner.kernel_config().run(|| {
            let y = solve_lower(&l, f);
            let u = solve_lower_transpose(&l, &y);
            Ok(s.t_matvec(&u))
        })
    }

    /// Recover `f` from `v = Sᵀf` by solving the (well-damped) consistency
    /// system `SSᵀ f = S v`, then verify the reconstruction. Returns
    /// `BadInput` if `v` is not in the row space of `S` — the structural
    /// limitation §3 calls out.
    pub fn recover_f(&self, s: &Mat, v: &[f64], tol: f64) -> Result<Vec<f64>, SolveError> {
        let ridge = recovery_ridge(s)?;
        self.inner.kernel_config().run(|| {
            let sv = s.matvec(v);
            // SSᵀ may be singular; tiny ridge for the recovery only.
            let w = if self.inner.threads > 1 {
                syrk_parallel(s, ridge, self.inner.threads)
            } else {
                syrk(s, ridge)
            };
            let l = cholesky_threaded(&w, self.inner.threads)?;
            let f = solve_lower_transpose(&l, &solve_lower(&l, &sv));
            verify_reconstruction(s, v, &f, tol)?;
            Ok(f)
        })
    }
}

/// Ridge used to regularize the (possibly singular) recovery system.
///
/// A degenerate scale is rejected up front (PR-5 bugfix): for an
/// all-zero or subnormally-scaled score matrix the old
/// `max(1e-12·‖S‖²_F, 1e-300)` floor still fed Cholesky a numerically
/// zero pivot, so the user saw an unactionable `NotPositiveDefinite`
/// ("increase damping") instead of the real problem — the scores
/// themselves. The threshold is `f64::MIN_POSITIVE`: any normal ridge
/// passes, a zero/subnormal one names the score matrix.
fn recovery_ridge(s: &Mat) -> Result<f64, SolveError> {
    let f = s.fro_norm();
    let ridge = 1e-12 * f * f;
    if !ridge.is_finite() {
        return Err(SolveError::BadInput(format!(
            "score matrix is not finite (‖S‖_F = {f:.3e}) — the RVB recovery system SSᵀf = Sv \
             cannot be formed"
        )));
    }
    if ridge < f64::MIN_POSITIVE {
        return Err(SolveError::BadInput(format!(
            "score matrix is zero or ill-scaled (‖S‖_F = {f:.3e}): the RVB recovery system \
             SSᵀf = Sv is numerically singular — rescale the scores or use a general solver \
             (chol)"
        )));
    }
    Ok(ridge)
}

/// Check `v ≈ Sᵀf`; error with the §3 limitation message otherwise.
fn verify_reconstruction(s: &Mat, v: &[f64], f: &[f64], tol: f64) -> Result<(), SolveError> {
    let recon = s.t_matvec(f);
    let vnorm = crate::linalg::mat::norm2(v).max(f64::MIN_POSITIVE);
    let err: f64 = v
        .iter()
        .zip(&recon)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    if err > tol * vnorm {
        return Err(SolveError::BadInput(format!(
            "v is not in rowspace(S): relative reconstruction error {:.3e} — the RVB method \
             requires least-squares structure v = Sᵀf (paper §3)",
            err / vnorm
        )));
    }
    Ok(())
}

/// RVB session: un-damped Gram + λ-independent recovery factor cached.
///
/// Like [`CholFactor`](super::chol::CholFactor) it supports both the
/// borrowed per-step mode and the PR-5 owned-window streaming mode; a
/// rotation patches the shared Gram once and rotates **both** cached
/// factors (damped + recovery) in O(kn²). The recovery ridge is frozen
/// at its first computation so rotations stay consistent — it is a pure
/// regularizer, and the periodic [`Factorization::refresh`] re-derives
/// it from the current window.
pub struct RvbFactor<'s> {
    /// Borrowed score matrix; `None` in owned-window mode.
    s: Option<&'s Mat>,
    /// Owned sliding window; populated in streaming mode.
    window: Option<Mat>,
    cfg: KernelConfig,
    recovery_tol: f64,
    lambda: f64,
    /// Cached `SSᵀ` (no damping).
    gram: Option<Mat>,
    /// `Chol(SSᵀ + λĨ)` for the current λ.
    l: Option<Mat>,
    /// `Chol(SSᵀ + εĨ)` for the f-recovery (λ-independent).
    recovery_l: Option<Mat>,
    /// The ε of the recovery factor, frozen when first computed so
    /// streaming rotations append with a consistent diagonal.
    ridge: Option<f64>,
    /// Damped-solve arithmetic (PR 6); the recovery factor is always
    /// f64.
    precision: Precision,
    /// Mixed-refinement relative-residual target.
    tol: f64,
    /// f32 state of the damped factor when the mixed path is live.
    mixed: Option<MixedGramSolve>,
    /// Latched after any precision fallback.
    mixed_off: bool,
}

impl<'s> RvbFactor<'s> {
    fn new(s: &'s Mat, cfg: KernelConfig, recovery_tol: f64) -> Self {
        RvbFactor {
            s: Some(s),
            window: None,
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            recovery_tol,
            lambda: 0.0,
            gram: None,
            l: None,
            recovery_l: None,
            ridge: None,
            precision: Precision::F64,
            tol: 1e-10,
            mixed: None,
            mixed_off: false,
        }
    }

    /// Streaming session owning its score window.
    fn from_window(window: Mat, cfg: KernelConfig, recovery_tol: f64) -> RvbFactor<'static> {
        RvbFactor {
            s: None,
            window: Some(window),
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            recovery_tol,
            lambda: 0.0,
            gram: None,
            l: None,
            recovery_l: None,
            ridge: None,
            precision: Precision::F64,
            tol: 1e-10,
            mixed: None,
            mixed_off: false,
        }
    }

    fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.precision = precision;
        self.tol = tol;
        self
    }

    fn mixed_enabled(&self) -> bool {
        self.precision == Precision::Mixed && !self.mixed_off
    }

    fn mixed_factored(&self) -> bool {
        self.mixed_enabled() && self.mixed.as_ref().is_some_and(|m| m.factored())
    }

    /// Drop the f32 damped factor and latch the session onto the f64
    /// path, refactoring at the current λ so in-flight solves continue.
    fn latch_f64(&mut self) -> Result<(), SolveError> {
        self.mixed = None;
        self.mixed_off = true;
        if self.lambda > 0.0 && self.l.is_none() {
            let cfg = self.cfg;
            let lambda = self.lambda;
            self.ensure_gram();
            match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
                Ok(l) => self.l = Some(l),
                Err(e) => {
                    self.lambda = 0.0;
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    fn score(&self) -> &Mat {
        match &self.window {
            Some(w) => w,
            None => self.s.expect("session has a score matrix"),
        }
    }

    fn ensure_gram(&mut self) -> &Mat {
        if self.gram.is_none() {
            let threads = self.cfg.threads;
            let cfg = self.cfg;
            let s = match &self.window {
                Some(w) => w,
                None => self.s.expect("session has a score matrix"),
            };
            let g = cfg.run(|| {
                if threads > 1 {
                    syrk_parallel(s, 0.0, threads)
                } else {
                    syrk(s, 0.0)
                }
            });
            self.gram = Some(g);
        }
        self.gram.as_ref().unwrap()
    }

    fn ensure_recovery(&mut self) -> Result<(), SolveError> {
        if self.recovery_l.is_none() {
            let ridge = match self.ridge {
                Some(r) => r,
                None => {
                    let r = recovery_ridge(self.score())?;
                    self.ridge = Some(r);
                    r
                }
            };
            let cfg = self.cfg;
            self.ensure_gram();
            let rl =
                cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), ridge, cfg.threads))?;
            self.recovery_l = Some(rl);
        }
        Ok(())
    }
}

impl Factorization for RvbFactor<'_> {
    fn name(&self) -> &'static str {
        "rvb"
    }

    fn dim(&self) -> usize {
        self.score().cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        // Streaming fast path — a rotation keeps the factor damped at
        // the current λ (see the chol session).
        if lambda == self.lambda && (self.l.is_some() || self.mixed_factored()) {
            return Ok(());
        }
        let cfg = self.cfg;
        self.ensure_gram();
        if self.mixed_enabled() {
            // Mixed path: factor the (already-cached f64) Gram + λĨ in
            // f32; the f64 Gram is needed for the recovery factor
            // regardless, so only the O(n³) factor and the triangular
            // solves move to single precision here.
            if self.mixed.is_none() {
                self.mixed = Some(MixedGramSolve::new(self.tol));
            }
            let ok = {
                let RvbFactor { gram, mixed, .. } = self;
                let gram = gram.as_ref().unwrap();
                let st = mixed.as_mut().unwrap();
                cfg.run(|| st.factor(gram, lambda))
            };
            if ok {
                self.l = None;
                self.lambda = lambda;
                return Ok(());
            }
            // f32 breakdown/overflow (fallback recorded) — latch f64.
            self.mixed = None;
            self.mixed_off = true;
        }
        match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.score().cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        if self.l.is_none() && !self.mixed_factored() {
            return Err(undamped_err());
        }
        self.ensure_recovery()?;
        // Stage 1 (always f64): recover f, rejecting v ∉ rowspace(S) —
        // the precondition the registry surfaces as BadInput.
        let f = {
            let s = self.score();
            let recovery_tol = self.recovery_tol;
            let rl = self.recovery_l.as_ref().unwrap();
            self.cfg.run(|| {
                let sv = s.matvec(v);
                let f = solve_lower_transpose(rl, &solve_lower(rl, &sv));
                verify_reconstruction(s, v, &f, recovery_tol)?;
                Ok::<_, SolveError>(f)
            })?
        };
        // Stage 2: u = (SSᵀ + λĨ)⁻¹ f — f32 + f64 refinement on the
        // mixed path, the cached f64 factor otherwise.
        if self.mixed_factored() {
            let mut u = vec![0.0; f.len()];
            let done = {
                let RvbFactor { gram, mixed, cfg, lambda, .. } = self;
                let gram = gram.as_ref().unwrap();
                let st = mixed.as_mut().unwrap();
                let lambda = *lambda;
                cfg.run(|| st.solve(gram, lambda, &f, &mut u))
            };
            if done {
                let s = self.score();
                self.cfg.run(|| s.t_matvec_into(&u, x));
                return Ok(());
            }
            // Refinement stagnated (fallback recorded): latch f64 and
            // finish this RHS through the f64 factor below.
            self.latch_f64()?;
        }
        let s = self.score();
        let l = self.l.as_ref().unwrap();
        self.cfg.run(|| {
            // x = Sᵀ(SSᵀ + λĨ)⁻¹ f through the cached damped factor.
            let y = solve_lower(l, &f);
            let u = solve_lower_transpose(l, &y);
            s.t_matvec_into(&u, x);
        });
        Ok(())
    }

    /// Streaming row rotation: the shared Gram is patched once and
    /// **both** cached factors (damped at λ, recovery at the frozen ε)
    /// rotate in O(kn²); breakdowns refactor from the patched Gram.
    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        if self.mixed_enabled() {
            // Rotations patch the f64 Gram and rotate the f64 factor;
            // the f32 factor has no incremental update — latch f64
            // (counted as a precision fallback, like the chol session).
            mixed_counters::record_fallback();
            self.latch_f64()?;
        }
        self.ensure_gram();
        if self.window.is_none() {
            self.window = Some(self.s.expect("session has a score matrix").clone());
        }
        let cfg = self.cfg;
        let lambda = self.lambda;
        let ridge = self.ridge.unwrap_or(0.0);
        let window = self.window.as_mut().unwrap();
        let gram = self.gram.as_mut().unwrap();
        rotate_gram_session(
            window,
            gram,
            &mut [(&mut self.l, lambda), (&mut self.recovery_l, ridge)],
            removed,
            added,
            cfg,
        )?;
        if self.l.is_none() && lambda > 0.0 {
            match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
                Ok(l) => self.l = Some(l),
                Err(e) => {
                    self.lambda = 0.0;
                    return Err(e);
                }
            }
        }
        // A broken-down recovery factor just rebuilds lazily (ridge is
        // kept frozen; `refresh` re-derives it from the live window).
        Ok(())
    }

    fn refresh(&mut self) -> Result<(), SolveError> {
        self.gram = None;
        self.l = None;
        self.recovery_l = None;
        self.ridge = None;
        // The f32 factor rebuilds from the fresh Gram on redamp
        // (sessions that latched f64 stay latched).
        if let Some(st) = self.mixed.as_mut() {
            st.invalidate();
        }
        let lambda = self.lambda;
        self.lambda = 0.0;
        self.ensure_gram();
        if lambda > 0.0 {
            self.redamp(lambda)?;
        }
        Ok(())
    }
}

impl DampedSolver for RvbSolver {
    fn name(&self) -> &'static str {
        "rvb"
    }

    /// General-v session: recovers `f` per right-hand side (rejecting
    /// v ∉ rowspace(S)), then applies the least-squares identity against
    /// the cached factors.
    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(
            RvbFactor::new(s, self.inner.kernel_config(), self.recovery_tol)
                .with_precision(self.inner.precision, self.inner.tol),
        )
    }

    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        Some(Box::new(
            RvbFactor::from_window(window, self.inner.kernel_config(), self.recovery_tol)
                .with_precision(self.inner.precision, self.inner.tol),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::CholSolver;

    /// Appendix B, executable: x_rvb == x_chol when v = Sᵀf.
    #[test]
    fn appendix_b_equivalence() {
        let mut rng = Rng::seed_from(160);
        for &(n, m, lambda) in &[(3usize, 12usize, 0.5f64), (10, 80, 1e-2), (24, 300, 1e-4)] {
            let s = Mat::randn(n, m, &mut rng);
            let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v = s.t_matvec(&f);
            let x_rvb = RvbSolver::default().solve_ls(&s, &f, lambda).unwrap();
            let x_chol = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let scale = crate::linalg::mat::norm2(&x_chol).max(1.0);
            for (a, b) in x_rvb.iter().zip(&x_chol) {
                assert!(
                    (a - b).abs() < 1e-9 * scale,
                    "Appendix-B equivalence broken at ({n},{m},λ={lambda})"
                );
            }
        }
    }

    #[test]
    fn rejects_v_outside_rowspace() {
        // Random v with m ≫ n is almost surely not Sᵀf for any f — the
        // limitation that motivates Algorithm 1's generality.
        let mut rng = Rng::seed_from(161);
        let s = Mat::randn(4, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        match RvbSolver::default().solve(&s, &v, 0.1) {
            Err(SolveError::BadInput(msg)) => assert!(msg.contains("rowspace")),
            other => panic!("expected rowspace rejection, got {other:?}"),
        }
    }

    #[test]
    fn accepts_v_inside_rowspace_via_general_entry() {
        let mut rng = Rng::seed_from(162);
        let s = Mat::randn(6, 50, &mut rng);
        let f: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let x = RvbSolver::default().solve(&s, &v, 0.05).unwrap();
        let x_ref = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_or_ill_scaled_scores_surface_as_bad_input_not_npd() {
        // PR-5 bugfix: an all-zero (or subnormal) score matrix used to
        // reach Cholesky with a 1e-300 ridge and fail as
        // NotPositiveDefinite ("increase damping") — misdirecting the
        // user from the real problem. It must name the score matrix.
        let zero = Mat::zeros(4, 20);
        let v = vec![0.0; 20];
        match RvbSolver::default().solve(&zero, &v, 0.1) {
            Err(SolveError::BadInput(msg)) => {
                assert!(msg.contains("zero or ill-scaled"), "{msg}")
            }
            other => panic!("expected BadInput naming the scores, got {other:?}"),
        }
        // Subnormal scale: ‖S‖²_F underflows the ridge.
        let mut tiny = Mat::zeros(4, 20);
        tiny[(0, 0)] = 1e-155;
        match RvbSolver::default().solve(&tiny, &v, 0.1) {
            Err(SolveError::BadInput(msg)) => {
                assert!(msg.contains("zero or ill-scaled"), "{msg}")
            }
            other => panic!("expected BadInput naming the scores, got {other:?}"),
        }
        // The one-shot ls entry hits the damped factor directly and is
        // unaffected; a healthy matrix still solves.
        let mut rng = Rng::seed_from(164);
        let s = Mat::randn(4, 20, &mut rng);
        let f: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        RvbSolver::default().solve(&s, &v, 0.1).unwrap();
    }

    #[test]
    fn streaming_rotation_matches_cold_session() {
        // Rotate two rows through an rvb window session: both cached
        // factors (damped + recovery) rotate, and the result matches a
        // cold session on the rotated window.
        let mut rng = Rng::seed_from(165);
        let (n, m) = (10usize, 60usize);
        let s = Mat::randn(n, m, &mut rng);
        let solver = RvbSolver::default();
        let mut fact = solver
            .begin_window(s.clone())
            .expect("rvb has an owned-window session");
        fact.redamp(0.05).unwrap();
        let added = Mat::randn(2, m, &mut rng);
        fact.update_rows(&[0, 3], &added).unwrap();
        // Rotated window: rows {1,2,4..n} then the two added rows.
        let kept: Vec<usize> = (0..n).filter(|&i| i != 0 && i != 3).collect();
        let mut rotated = Mat::zeros(n, m);
        for (i, &oi) in kept.iter().enumerate() {
            rotated.row_mut(i).copy_from_slice(s.row(oi));
        }
        for j in 0..2 {
            rotated.row_mut(n - 2 + j).copy_from_slice(added.row(j));
        }
        let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v = rotated.t_matvec(&f);
        let warm = fact.solve(&v).unwrap();
        let cold = solver.solve(&rotated, &v, 0.05).unwrap();
        let scale = crate::linalg::mat::norm2(&cold).max(1.0);
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a - b).abs() < 1e-9 * scale);
        }
    }

    #[test]
    fn mixed_precision_rvb_matches_f64_without_falling_back() {
        let mut rng = Rng::seed_from(166);
        let (n, m) = (12usize, 90usize);
        let s = Mat::randn(n, m, &mut rng);
        let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let fb0 = mixed_counters::fallbacks();
        let mf0 = mixed_counters::mixed_factors();
        let solver = RvbSolver::default().with_precision(Precision::Mixed, 1e-10);
        let mut fact = solver.factor(&s, 0.05).unwrap();
        let x = fact.solve(&v).unwrap();
        let x64 = RvbSolver::default().solve(&s, &v, 0.05).unwrap();
        let scale = crate::linalg::mat::norm2(&x64).max(1.0);
        for (a, b) in x.iter().zip(&x64) {
            assert!((a - b).abs() < 1e-8 * scale, "mixed rvb vs f64: {a} vs {b}");
        }
        assert_eq!(mixed_counters::fallbacks(), fb0);
        assert!(mixed_counters::mixed_factors() > mf0);
        // λ-resweep stays on the f32 factor.
        fact.redamp(0.5).unwrap();
        let x2 = fact.solve(&v).unwrap();
        let x2_64 = RvbSolver::default().solve(&s, &v, 0.5).unwrap();
        let scale2 = crate::linalg::mat::norm2(&x2_64).max(1.0);
        for (a, b) in x2.iter().zip(&x2_64) {
            assert!((a - b).abs() < 1e-8 * scale2);
        }
        // The rowspace precondition still rejects under mixed.
        let bad: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        assert!(matches!(fact.solve(&bad), Err(SolveError::BadInput(_))));
    }

    #[test]
    fn session_resweep_matches_cold_and_keeps_precondition() {
        let mut rng = Rng::seed_from(163);
        let s = Mat::randn(5, 30, &mut rng);
        let f: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let solver = RvbSolver::default();
        let mut fact = solver.factor(&s, 0.2).unwrap();
        fact.redamp(0.02).unwrap();
        let warm = fact.solve(&v).unwrap();
        let cold = solver.solve(&s, &v, 0.02).unwrap();
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a - b).abs() < 1e-12);
        }
        // The precondition survives the session path too.
        let bad: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        assert!(matches!(fact.solve(&bad), Err(SolveError::BadInput(_))));
    }
}
