//! The RVB+23 method — Appendix B.
//!
//! When the gradient has least-squares structure `v = Sᵀf` (f ∈ ℝⁿ),
//! Rende et al. solve
//!
//! ```text
//! x_rvb = Sᵀ (SSᵀ + λĨ)⁻¹ f
//! ```
//!
//! Appendix B proves `x_rvb ≡ x_chol` in that case. This module implements
//! the method (with Cholesky solve, as the paper suggests) both to serve
//! as the least-squares fast path and to regenerate the Appendix-B
//! equivalence as an executable test. Its *limitation* — it requires
//! `v ∈ rowspace(S)` and "prevents the use of regularization" on the loss
//! — is surfaced as a checked precondition.

use super::{CholSolver, DampedSolver, SolveError};
use crate::linalg::{solve_lower, solve_lower_transpose, Mat};

/// RVB+23 least-squares solver.
#[derive(Debug, Clone, Default)]
pub struct RvbSolver {
    inner: CholSolver,
}

impl RvbSolver {
    pub fn with_threads(threads: usize) -> Self {
        RvbSolver { inner: CholSolver::with_threads(threads) }
    }

    /// Solve given the least-squares coefficient vector `f` directly:
    /// `x = Sᵀ(SSᵀ + λĨ)⁻¹ f`. This is the method's native entry point.
    pub fn solve_ls(&self, s: &Mat, f: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(f.len(), s.rows(), "f must be n-dimensional");
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let l = self.inner.factor(s, lambda)?;
        let y = solve_lower(&l, f);
        let u = solve_lower_transpose(&l, &y);
        Ok(s.t_matvec(&u))
    }

    /// Recover `f` from `v = Sᵀf` by solving the (well-damped) consistency
    /// system `SSᵀ f = S v`, then verify the reconstruction. Returns
    /// `BadInput` if `v` is not in the row space of `S` — the structural
    /// limitation §3 calls out.
    pub fn recover_f(&self, s: &Mat, v: &[f64], tol: f64) -> Result<Vec<f64>, SolveError> {
        let sv = s.matvec(v);
        // SSᵀ may be singular; tiny ridge for the recovery only.
        let w = crate::linalg::gemm::syrk(s, 1e-12 * frob2(s).max(1e-300));
        let l = crate::linalg::cholesky(&w)?;
        let f = solve_lower_transpose(&l, &solve_lower(&l, &sv));
        // Verify v ≈ Sᵀ f.
        let recon = s.t_matvec(&f);
        let vnorm = crate::linalg::mat::norm2(v).max(f64::MIN_POSITIVE);
        let err: f64 = v
            .iter()
            .zip(&recon)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        if err > tol * vnorm {
            return Err(SolveError::BadInput(format!(
                "v is not in rowspace(S): relative reconstruction error {:.3e} — the RVB method \
                 requires least-squares structure v = Sᵀf (paper §3)",
                err / vnorm
            )));
        }
        Ok(f)
    }
}

fn frob2(s: &Mat) -> f64 {
    let f = s.fro_norm();
    f * f
}

impl DampedSolver for RvbSolver {
    fn name(&self) -> &'static str {
        "rvb"
    }

    /// General-v entry point: recovers `f` (rejecting v ∉ rowspace(S)),
    /// then applies the least-squares identity.
    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        let f = self.recover_f(s, v, 1e-6)?;
        self.solve_ls(s, &f, lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::CholSolver;

    /// Appendix B, executable: x_rvb == x_chol when v = Sᵀf.
    #[test]
    fn appendix_b_equivalence() {
        let mut rng = Rng::seed_from(160);
        for &(n, m, lambda) in &[(3usize, 12usize, 0.5f64), (10, 80, 1e-2), (24, 300, 1e-4)] {
            let s = Mat::randn(n, m, &mut rng);
            let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v = s.t_matvec(&f);
            let x_rvb = RvbSolver::default().solve_ls(&s, &f, lambda).unwrap();
            let x_chol = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let scale = crate::linalg::mat::norm2(&x_chol).max(1.0);
            for (a, b) in x_rvb.iter().zip(&x_chol) {
                assert!(
                    (a - b).abs() < 1e-9 * scale,
                    "Appendix-B equivalence broken at ({n},{m},λ={lambda})"
                );
            }
        }
    }

    #[test]
    fn rejects_v_outside_rowspace() {
        // Random v with m ≫ n is almost surely not Sᵀf for any f — the
        // limitation that motivates Algorithm 1's generality.
        let mut rng = Rng::seed_from(161);
        let s = Mat::randn(4, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        match RvbSolver::default().solve(&s, &v, 0.1) {
            Err(SolveError::BadInput(msg)) => assert!(msg.contains("rowspace")),
            other => panic!("expected rowspace rejection, got {other:?}"),
        }
    }

    #[test]
    fn accepts_v_inside_rowspace_via_general_entry() {
        let mut rng = Rng::seed_from(162);
        let s = Mat::randn(6, 50, &mut rng);
        let f: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let v = s.t_matvec(&f);
        let x = RvbSolver::default().solve(&s, &v, 0.05).unwrap();
        let x_ref = CholSolver::default().solve(&s, &v, 0.05).unwrap();
        for (a, b) in x.iter().zip(&x_ref) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
