//! The `"svda"` baseline — Appendix C with the SVD computed by a
//! one-sided Jacobi routine, standing in for the CUDA `gesvda` kernel.
//!
//! `gesvda` (cuSOLVER's "approximate SVD for tall matrices") is a blocked
//! one-sided-Jacobi method; [`crate::linalg::svd_jacobi`] is the same
//! algorithm family, preserving the benchmark-relevant behaviour: several
//! O(n²m) sweeps instead of Algorithm 1's single O(n²m) pass, making it
//! the slowest method in Table 1 — and the first to exhaust device
//! memory (the `N/A` cell at shape (4096, 100000)).
//!
//! The memory exhaustion is reproduced with an explicit [`MemoryBudget`]
//! model (see [`super::cost`]): the paper's A100 had 80 GB; `gesvda`'s
//! workspace grows superlinearly in n and overflows it first.

use super::cost::{memory_bytes, MemoryBudget};
use super::{DampedSolver, SolveError, SolverKind};
use crate::linalg::svd::svd_jacobi;
use crate::linalg::Mat;

/// Jacobi-SVD solver ("svda") with a modeled device-memory budget.
#[derive(Debug, Clone)]
pub struct SvdaSolver {
    /// Simulated device memory (defaults to the paper's 80 GB A100).
    pub budget: MemoryBudget,
}

impl Default for SvdaSolver {
    fn default() -> Self {
        SvdaSolver { budget: MemoryBudget::a100_80gb() }
    }
}

impl SvdaSolver {
    /// Solver with an unlimited budget (tests that only care about math).
    pub fn unlimited() -> Self {
        SvdaSolver { budget: MemoryBudget::unlimited() }
    }
}

impl DampedSolver for SvdaSolver {
    fn name(&self) -> &'static str {
        "svda"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let (n, m) = s.shape();
        let required = memory_bytes(SolverKind::Svda, n, m);
        if !self.budget.fits(required) {
            return Err(SolveError::OutOfMemory {
                required_bytes: required,
                budget_bytes: self.budget.bytes(),
            });
        }
        let svd = svd_jacobi(s);
        Ok(super::EighSolver::apply_svd(&svd, v, lambda))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver, DampedSolver};

    #[test]
    fn matches_chol() {
        let mut rng = Rng::seed_from(130);
        let s = Mat::randn(14, 90, &mut rng);
        let v: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let xc = CholSolver::default().solve(&s, &v, 0.02).unwrap();
        let xs = SvdaSolver::default().solve(&s, &v, 0.02).unwrap();
        for (a, b) in xc.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-7);
        }
        assert!(residual_norm(&s, &xs, &v, 0.02) < 1e-7);
    }

    #[test]
    fn reproduces_the_paper_na_cell() {
        // Table 1: svda is N/A at (4096, 100000) on an 80 GB A100 but fine
        // at (2048, 200000) — same n·m product, so the blow-up is in n.
        let budget = MemoryBudget::a100_80gb();
        assert!(!budget.fits(memory_bytes(SolverKind::Svda, 4096, 100_000)));
        assert!(budget.fits(memory_bytes(SolverKind::Svda, 2048, 200_000)));
        // chol and eigh fit everywhere in Table 1.
        for &(n, m) in &[(4096usize, 100_000usize), (2048, 200_000)] {
            assert!(budget.fits(memory_bytes(SolverKind::Chol, n, m)));
            assert!(budget.fits(memory_bytes(SolverKind::Eigh, n, m)));
        }
    }

    #[test]
    fn oom_error_is_reported_not_panicked() {
        // A tiny synthetic budget forces the OOM path on a small matrix.
        let solver = SvdaSolver { budget: MemoryBudget::bytes_for_test(1024) };
        let mut rng = Rng::seed_from(131);
        let s = Mat::randn(8, 64, &mut rng);
        let v = vec![1.0; 64];
        match solver.solve(&s, &v, 0.1) {
            Err(SolveError::OutOfMemory { required_bytes, budget_bytes }) => {
                assert!(required_bytes > budget_bytes);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }
}
