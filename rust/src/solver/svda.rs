//! The `"svda"` baseline — Appendix C with the SVD computed by a
//! one-sided Jacobi routine, standing in for the CUDA `gesvda` kernel.
//!
//! `gesvda` (cuSOLVER's "approximate SVD for tall matrices") is a blocked
//! one-sided-Jacobi method; [`crate::linalg::svd_jacobi`] is the same
//! algorithm family, preserving the benchmark-relevant behaviour: several
//! O(n²m) sweeps instead of Algorithm 1's single O(n²m) pass, making it
//! the slowest method in Table 1 — and the first to exhaust device
//! memory (the `N/A` cell at shape (4096, 100000)).
//!
//! The memory exhaustion is reproduced with an explicit [`MemoryBudget`]
//! model (see [`super::cost`]): the paper's A100 had 80 GB; `gesvda`'s
//! workspace grows superlinearly in n and overflows it first.
//!
//! Session note (PR 2): like `eigh`, the Jacobi SVD is λ-independent, so
//! the [`super::eigh_svd::SvdFactor`] session pays the sweeps once and
//! λ-resweeps / extra right-hand sides are O(nm) each.

use super::cost::MemoryBudget;
use super::eigh_svd::{SvdFactor, SvdMethod};
use super::{DampedSolver, Factorization};
use crate::linalg::Mat;

/// Jacobi-SVD solver ("svda") with a modeled device-memory budget.
#[derive(Debug, Clone)]
pub struct SvdaSolver {
    /// Simulated device memory (defaults to the paper's 80 GB A100).
    pub budget: MemoryBudget,
    /// Accepted for registry parity with the other direct methods; the
    /// Jacobi sweeps are rotation-sequential (each 2×2 rotation feeds
    /// the next), so the SVD stage itself cannot be pool-partitioned —
    /// only the session's per-RHS O(nm) passes would benefit, and those
    /// are bandwidth-bound.
    pub threads: usize,
}

impl Default for SvdaSolver {
    fn default() -> Self {
        SvdaSolver { budget: MemoryBudget::a100_80gb(), threads: 1 }
    }
}

impl SvdaSolver {
    /// Solver with an unlimited budget (tests that only care about math).
    pub fn unlimited() -> Self {
        SvdaSolver { budget: MemoryBudget::unlimited(), threads: 1 }
    }
}

impl DampedSolver for SvdaSolver {
    fn name(&self) -> &'static str {
        "svda"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(SvdFactor::new(s, SvdMethod::Jacobi { budget: self.budget }, "svda"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{
        memory_bytes, residual_norm, CholSolver, DampedSolver, SolveError, SolverKind,
    };

    #[test]
    fn matches_chol() {
        let mut rng = Rng::seed_from(130);
        let s = Mat::randn(14, 90, &mut rng);
        let v: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let xc = CholSolver::default().solve(&s, &v, 0.02).unwrap();
        let xs = SvdaSolver::default().solve(&s, &v, 0.02).unwrap();
        for (a, b) in xc.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-7);
        }
        assert!(residual_norm(&s, &xs, &v, 0.02) < 1e-7);
    }

    #[test]
    fn reproduces_the_paper_na_cell() {
        // Table 1: svda is N/A at (4096, 100000) on an 80 GB A100 but fine
        // at (2048, 200000) — same n·m product, so the blow-up is in n.
        let budget = MemoryBudget::a100_80gb();
        assert!(!budget.fits(memory_bytes(SolverKind::Svda, 4096, 100_000)));
        assert!(budget.fits(memory_bytes(SolverKind::Svda, 2048, 200_000)));
        // chol and eigh fit everywhere in Table 1.
        for &(n, m) in &[(4096usize, 100_000usize), (2048, 200_000)] {
            assert!(budget.fits(memory_bytes(SolverKind::Chol, n, m)));
            assert!(budget.fits(memory_bytes(SolverKind::Eigh, n, m)));
        }
    }

    #[test]
    fn oom_error_is_reported_not_panicked() {
        // A tiny synthetic budget forces the OOM path on a small matrix.
        let solver = SvdaSolver { budget: MemoryBudget::bytes_for_test(1024), threads: 1 };
        let mut rng = Rng::seed_from(131);
        let s = Mat::randn(8, 64, &mut rng);
        let v = vec![1.0; 64];
        match solver.solve(&s, &v, 0.1) {
            Err(SolveError::OutOfMemory { required_bytes, budget_bytes }) => {
                assert!(required_bytes > budget_bytes);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn session_resweep_reuses_the_jacobi_svd() {
        let mut rng = Rng::seed_from(132);
        let s = Mat::randn(6, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let solver = SvdaSolver::unlimited();
        let mut fact = solver.factor(&s, 0.4).unwrap();
        for &lambda in &[0.4, 0.01] {
            fact.redamp(lambda).unwrap();
            let warm = fact.solve(&v).unwrap();
            let cold = solver.solve(&s, &v, lambda).unwrap();
            for (a, b) in warm.iter().zip(&cold) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }
}
