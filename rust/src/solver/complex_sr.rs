//! Complex stochastic-reconfiguration variants (§3).
//!
//! In variational quantum Monte Carlo the score matrix comes from an
//! *unnormalized* wavefunction and must be centered,
//! `S = (O − Ō)/√n`, and when ψ is complex so is S. Two Fisher-matrix
//! conventions exist:
//!
//! * **full complex** `F = S†S` → replace every transpose in Algorithm 1
//!   with a Hermitian conjugate ([`solve_sr_complex`]);
//! * **real part** `F = ℜ[S†S]` (the common choice) → replace
//!   `S ← Concat[ℜS, ℑS]` along the sample axis and run the *real*
//!   Algorithm 1 unchanged ([`solve_sr_real_part`]).

//! Session note (PR 2): [`ComplexSrFactor`] is the complex counterpart of
//! the real [`Factorization`](super::Factorization) sessions — it caches
//! the un-damped Hermitian Gram `SS†` so the SR driver's λ-backoff
//! retries repeat only the O(n³) complex Cholesky.

use super::session::check_lambda;
use super::{DampedSolver, SolveError};
use crate::linalg::complex::{cholesky_complex, solve_lower_c, solve_lower_dagger_c, c64, CMat};
use crate::linalg::Mat;

/// Center and scale a raw log-derivative matrix `O` (n×p) into the SR
/// score matrix `S = (O − Ō)/√n` where `Ō` is the per-column sample mean.
pub fn center_scores(o: &CMat) -> CMat {
    let (n, p) = o.shape();
    let mut mean = vec![c64::ZERO; p];
    for i in 0..n {
        let row = o.row(i);
        for j in 0..p {
            mean[j] += row[j];
        }
    }
    let inv_n = 1.0 / n as f64;
    for m in &mut mean {
        *m = *m * inv_n;
    }
    let scale = 1.0 / (n as f64).sqrt();
    CMat::from_fn(n, p, |i, j| (o[(i, j)] - mean[j]) * scale)
}

/// Complex Algorithm-1 session: `W = SS†` cached un-damped, re-damped and
/// re-factored in O(n³) per λ, solved in O(nm) per force vector.
pub struct ComplexSrFactor<'s> {
    s: &'s CMat,
    lambda: f64,
    gram: Option<CMat>,
    l: Option<CMat>,
}

impl<'s> ComplexSrFactor<'s> {
    pub fn new(s: &'s CMat) -> Self {
        ComplexSrFactor { s, lambda: 0.0, gram: None, l: None }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// (Re-)damp with `lambda`, reusing the cached Hermitian Gram.
    pub fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        if self.gram.is_none() {
            self.gram = Some(self.s.herk(0.0));
        }
        let mut w = self.gram.as_ref().unwrap().clone();
        w.add_diag(lambda);
        match cholesky_complex(&w) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                self.l = None;
                self.lambda = 0.0;
                Err(e.into())
            }
        }
    }

    /// `x = (v − S†L⁻†L⁻¹Sv)/λ` against the cached factor.
    pub fn solve(&self, v: &[c64]) -> Result<Vec<c64>, SolveError> {
        assert_eq!(v.len(), self.s.cols());
        let l = self
            .l
            .as_ref()
            .ok_or_else(super::session::undamped_err)?;
        let u = self.s.matvec(v);
        let y = solve_lower_c(l, &u);
        let z = solve_lower_dagger_c(l, &y);
        let t = self.s.dagger_matvec(&z);
        let inv = 1.0 / self.lambda;
        Ok(v.iter().zip(&t).map(|(vi, ti)| (*vi - *ti) * inv).collect())
    }
}

/// Full-complex SR: solve `(S†S + λI) x = v` for complex `S: n×m`,
/// `v ∈ ℂᵐ`. Algorithm 1 with Hermitian conjugates:
/// `W = SS† + λĨ`, `W = LL†`, `x = (v − S†L⁻†L⁻¹Sv)/λ`.
/// One-shot shim over [`ComplexSrFactor`].
pub fn solve_sr_complex(s: &CMat, v: &[c64], lambda: f64) -> Result<Vec<c64>, SolveError> {
    assert_eq!(v.len(), s.cols());
    let mut fact = ComplexSrFactor::new(s);
    fact.redamp(lambda)?;
    fact.solve(v)
}

/// The §3 concatenation trick: `ℜ[S†S] = S̃ᵀS̃` with `S̃ = Concat[ℜS, ℑS]`
/// stacked along the sample axis — the one place the real-part Fisher is
/// constructed (shared by [`solve_sr_real_part`] and the SR driver's
/// session path).
pub fn stack_real_part(s: &CMat) -> Mat {
    Mat::vstack(&s.real(), &s.imag())
}

/// Real-part SR: solve `(ℜ[S†S] + λI) x = v` for complex `S`, real `v`,
/// via [`stack_real_part`], then the real Algorithm 1 verbatim.
pub fn solve_sr_real_part(s: &CMat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
    let stacked = stack_real_part(s);
    super::CholSolver::default().solve(&stacked, v, lambda).map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    /// Dense oracle: materialize F = S†S + λI and Gaussian-eliminate.
    fn dense_complex_solve(s: &CMat, v: &[c64], lambda: f64) -> Vec<c64> {
        let (n, m) = s.shape();
        let mut f = CMat::zeros(m, m);
        for a in 0..m {
            for b in 0..m {
                let mut acc = c64::ZERO;
                for i in 0..n {
                    acc += s[(i, a)].conj() * s[(i, b)];
                }
                f[(a, b)] = acc;
            }
        }
        for a in 0..m {
            f[(a, a)] += c64::from_re(lambda);
        }
        // Gaussian elimination with partial pivoting.
        let mut aug = f;
        let mut x = v.to_vec();
        for col in 0..m {
            let mut piv = col;
            for r in col + 1..m {
                if aug[(r, col)].abs() > aug[(piv, col)].abs() {
                    piv = r;
                }
            }
            if piv != col {
                for j in 0..m {
                    let tmp = aug[(col, j)];
                    aug[(col, j)] = aug[(piv, j)];
                    aug[(piv, j)] = tmp;
                }
                x.swap(col, piv);
            }
            let d = aug[(col, col)];
            for r in 0..m {
                if r == col {
                    continue;
                }
                let factor = aug[(r, col)] / d;
                for j in col..m {
                    let v = aug[(col, j)];
                    let cur = aug[(r, j)];
                    aug[(r, j)] = cur - factor * v;
                }
                let xc = x[col];
                x[r] -= factor * xc;
            }
        }
        (0..m).map(|i| x[i] / aug[(i, i)]).collect()
    }

    #[test]
    fn complex_variant_matches_dense_oracle() {
        let mut rng = Rng::seed_from(170);
        for &(n, m) in &[(2usize, 5usize), (6, 14), (10, 24)] {
            let s = CMat::randn(n, m, &mut rng);
            let v: Vec<c64> = (0..m).map(|_| c64::new(rng.normal(), rng.normal())).collect();
            let x = solve_sr_complex(&s, &v, 0.3).unwrap();
            let oracle = dense_complex_solve(&s, &v, 0.3);
            for (a, b) in x.iter().zip(&oracle) {
                assert!((*a - *b).abs() < 1e-8, "({n},{m})");
            }
        }
    }

    #[test]
    fn real_part_variant_matches_dense_real_oracle() {
        let mut rng = Rng::seed_from(171);
        let (n, m) = (5usize, 12usize);
        let s = CMat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let x = solve_sr_real_part(&s, &v, 0.2).unwrap();
        // Oracle: F = ℜ[S†S] + λI, solved densely in ℝ.
        let mut f = Mat::zeros(m, m);
        for a in 0..m {
            for b in 0..m {
                let mut acc = c64::ZERO;
                for i in 0..n {
                    acc += s[(i, a)].conj() * s[(i, b)];
                }
                f[(a, b)] = acc.re;
            }
        }
        f.add_diag(0.2);
        let l = crate::linalg::cholesky(&f).unwrap();
        let oracle = crate::linalg::solve_lower_transpose(&l, &crate::linalg::solve_lower(&l, &v));
        for (a, b) in x.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn centering_removes_mean_and_scales() {
        let mut rng = Rng::seed_from(172);
        let o = CMat::randn(40, 7, &mut rng);
        let s = center_scores(&o);
        // Column means ≈ 0.
        for j in 0..7 {
            let mut mean = c64::ZERO;
            for i in 0..40 {
                mean += s[(i, j)];
            }
            assert!(mean.abs() < 1e-12);
        }
        // Variance scaling: ‖s_col‖² = sample-var(o_col)·(n·(1/√n)²)/... :
        // S†S is the covariance estimate; check one column against the
        // direct formula cov = Σ|o−ō|²/n.
        let j = 3;
        let mut mean = c64::ZERO;
        for i in 0..40 {
            mean += o[(i, j)];
        }
        mean = mean / 40.0;
        let direct: f64 = (0..40).map(|i| (o[(i, j)] - mean).norm_sqr()).sum::<f64>() / 40.0;
        let via_s: f64 = (0..40).map(|i| s[(i, j)].norm_sqr()).sum();
        assert!((direct - via_s).abs() < 1e-12);
    }

    #[test]
    fn real_s_reduces_complex_to_real_algorithm() {
        // With purely real S, solve_sr_complex must agree with CholSolver.
        let mut rng = Rng::seed_from(173);
        let (n, m) = (6usize, 20usize);
        let sr = Mat::randn(n, m, &mut rng);
        let s = CMat::from_fn(n, m, |i, j| c64::from_re(sr[(i, j)]));
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let vc: Vec<c64> = v.iter().map(|&x| c64::from_re(x)).collect();
        let xc = solve_sr_complex(&s, &vc, 0.15).unwrap();
        let xr = crate::solver::CholSolver::default()
            .solve(&sr, &v, 0.15)
            .unwrap();
        for (a, b) in xc.iter().zip(&xr) {
            assert!((a.re - b).abs() < 1e-9);
            assert!(a.im.abs() < 1e-9);
        }
    }
}
