//! Cost models: FLOPs and device-memory footprints per method.
//!
//! Two consumers:
//!
//! 1. The **bench harness** overlays Fig. 1's dotted "ideal scaling" lines
//!    using [`flops`], and fits measured times against them
//!    (see [`crate::metrics::fit_power_law`]).
//! 2. The **memory budget model** reproduces Table 1's `N/A` cell: the
//!    paper's svda run is out-of-memory at shape (4096, 100000) on an
//!    80 GB A100 yet fine at (2048, 200000) — the *same* n·m product —
//!    so the footprint must grow superlinearly in n. cuSOLVER's
//!    `gesvdaStridedBatched` workspace indeed scales with an O(n³)
//!    term; we model `svda` as `2nm·w + 0.15·n³·w` (w = 8 bytes), with
//!    the coefficient calibrated so exactly the paper's cell overflows.

use super::session::Precision;
use super::SolverKind;

/// Bytes per scalar in the modeled device arrays (f64).
const W: f64 = 8.0;

/// Modeled throughput advantage of the f32 kernel path over f64: every
/// SIMD tier (AVX2, AVX-512, NEON) packs twice the f32 lanes per
/// register, and the packed panels halve their cache footprint. Real
/// measurements land between 1.5× and 2× (EXPERIMENTS.md §Precision);
/// the model uses the lane-count bound.
const F32_SPEEDUP: f64 = 2.0;

/// Modeled *time-proportional* FLOP count of one solve at `threads`
/// kernel-pool jobs: the GEMM-shaped and factorization terms (Gram
/// products, Cholesky/eigh/SVD sweeps — everything the PR-3 threaded
/// engine partitions) divide by the thread count, while the O(nm)
/// streaming passes stay serial (they are memory-bandwidth-bound, and
/// the per-RHS matvecs run on the caller). This is what a
/// registry/backend choosing between kinds at a given `solver.threads`
/// should compare — the unthreaded [`flops`] would overstate the cost
/// of factorization-heavy kinds on a multi-core box and bias selection
/// toward iterative methods that cannot use the pool. Today's consumer
/// is the thread bench's ideal-scaling overlay
/// (`bench_tables::thread_bench_report`).
pub fn flops_threaded(kind: SolverKind, n: usize, m: usize, threads: usize) -> f64 {
    let t = threads.max(1) as f64;
    let nf = n as f64;
    let mf = m as f64;
    // Serial remainder per kind: the streaming O(nm)-class passes, plus
    // everything rotation- or iteration-sequential.
    let serial = match kind {
        SolverKind::Chol => 4.0 * nf * mf,
        // The Jacobi eigendecomposition (9n³) is rotation-sequential
        // and stays on the caller — only the two O(n²m) passes thread.
        SolverKind::Eigh => 9.0 * nf * nf * nf + 6.0 * nf * mf,
        // One-sided Jacobi: each rotation feeds the next — no partition.
        SolverKind::Svda => flops(SolverKind::Svda, n, m),
        SolverKind::Naive => 0.0,
        // CG is a chain of dependent matvecs — nothing partitions.
        SolverKind::Cg => flops(SolverKind::Cg, n, m),
        SolverKind::Rvb => 6.0 * nf * mf,
        // Per-block sessions thread their Gram/factor stages like chol;
        // the O(nm) per-RHS streaming passes stay serial.
        SolverKind::BlockDiag => 4.0 * nf * mf,
        // Only the O(m²n) block-Gram GEMM threads; the rearranged power
        // iteration and the small eigendecompositions are sequential.
        SolverKind::KpSvd => flops(SolverKind::KpSvd, n, m) - mf * mf * nf,
        // The PCG loop is a chain of dependent matvec/backsolve pairs;
        // only the preconditioner's block Gram/factor stage threads.
        SolverKind::Hybrid => {
            30.0 * (6.0 * nf * mf + 10.0 * mf) + 4.0 * nf * mf
        }
    };
    serial + (flops(kind, n, m) - serial) / t
}

/// Modeled FLOP count of one **sliding-window step** (PR 5): rotate `k`
/// of the window's `n` sample rows, then solve one right-hand side
/// against the updated factor.
///
/// For the streaming-capable kinds (`chol`, `rvb` — the sessions with
/// O(kn²)-rotatable Cholesky factors) the cost is
///
/// ```text
/// 2knm + k²m     cross-product Gram patch (panel GEMMs; NO n²m SYRK)
/// + 4kn²         factor rotation: Givens delete sweeps + bordered appends
/// + 4nm + 2n²    the per-RHS Algorithm-1 line-4 passes
/// ```
///
/// versus the cold `flops(kind, n, m)` ≈ n²m + n³/3 per step — an
/// amortization factor of ≈ n/2k when k ≪ n (the `benches/streaming.rs`
/// acceptance bar). Kinds with no separable update (eigh/svda/naive/cg)
/// pay the full cold cost every step, which is what this model returns
/// for them — keeping cross-kind comparisons honest when a registry
/// weighs streaming against its alternatives.
pub fn flops_streaming(kind: SolverKind, n: usize, m: usize, k: usize) -> f64 {
    let nf = n as f64;
    let mf = m as f64;
    let kf = k.min(n) as f64;
    match kind {
        // blockdiag/hybrid inherit the native rotation from their inner
        // chol/rvb block sessions (PR 10): the same Gram-patch + factor
        // rotation terms, summed over blocks, telescope back to these
        // totals (Σ_b 2knm_b = 2knm, …).
        SolverKind::Chol | SolverKind::Rvb | SolverKind::BlockDiag | SolverKind::Hybrid => {
            2.0 * kf * nf * mf + kf * kf * mf + 4.0 * kf * nf * nf + 4.0 * nf * mf + 2.0 * nf * nf
        }
        _ => flops(kind, n, m),
    }
}

/// Modeled FLOP count of one solve. Leading-order terms only; used for
/// ideal-scaling overlays, not for timing claims.
pub fn flops(kind: SolverKind, n: usize, m: usize) -> f64 {
    let n = n as f64;
    let m = m as f64;
    match kind {
        // SYRK n²m + Chol n³/3 + two O(nm) passes + two O(n²) solves.
        SolverKind::Chol => n * n * m + n * n * n / 3.0 + 4.0 * n * m,
        // Gram n²m + Jacobi eigh ~9n³ + V = SᵀUΣ⁻¹ another n²m + Eq.5 passes.
        SolverKind::Eigh => 2.0 * n * n * m + 9.0 * n * n * n + 6.0 * n * m,
        // One-sided Jacobi: ~8 sweeps × 6 flops × n(n−1)/2 pairs × m.
        SolverKind::Svda => 24.0 * n * n * m,
        // Form SᵀS (m²n) + Cholesky m³/3 + solves.
        SolverKind::Naive => m * m * n + m * m * m / 3.0,
        // Per iteration 4nm + 10m; iterations depend on conditioning —
        // assume √κ ≈ 30 for the overlay.
        SolverKind::Cg => 30.0 * (4.0 * n * m + 10.0 * m),
        // Like chol plus the recovery factorization (second n³/3) and the
        // extra O(nm) reconstruction-check passes.
        SolverKind::Rvb => n * n * m + 2.0 * n * n * n / 3.0 + 6.0 * n * m,
        // Single-block limit (= chol); [`flops_blocked`] is the
        // block-aware model this signature cannot express.
        SolverKind::BlockDiag => n * n * m + n * n * n / 3.0 + 4.0 * n * m,
        // Block Gram SᵀS (m²n) + ~40 power iterations × 2 matvecs on
        // the m²-entry rearrangement + eigh of the p/q factors
        // (~9(p³+q³) ≈ 18·m^1.5 at the near-square split).
        SolverKind::KpSvd => m * m * n + 80.0 * m * m + 18.0 * m.powf(1.5),
        // Preconditioner factor (single-block limit) + ~30 PCG
        // iterations; [`flops_blocked`] parameterizes both.
        SolverKind::Hybrid => flops_blocked(n, m, 1, 30),
    }
}

/// Modeled FLOP count of one **structured** solve over `blocks`
/// contiguous column groups, plus `cg_iters` hybrid PCG iterations
/// (0 for a pure `blockdiag` solve) — the ablation number behind the
/// paper's §1 exact-vs-approximate claim and the
/// `dngd bench --structured` overlay.
///
/// ```text
/// Σ_b  n²·m_b + n³/3 + 4n·m_b     per-block chol session (m_b ≈ m/k)
///    = n²m + k·n³/3 + 4nm          (the Gram work is k-invariant; the
///                                   k·n³/3 factor term is the price of
///                                   k independent blocks)
/// + iters · (6nm + 10m)            PCG: Fisher matvec pair (4nm) +
///                                   block back-substitution (2nm) +
///                                   vector updates
/// ```
///
/// The structured win is therefore *not* in raw FLOPs at large m (the
/// n²m Gram dominates and is k-invariant) but in the per-block
/// independence: k sessions of footprint O(n·m/k + n²) that factor,
/// stream and shard independently — and, for `hybrid`, in trading the
/// κ-driven iteration count of plain CG for the few preconditioned
/// iterations a near-block-diagonal Fisher needs.
pub fn flops_blocked(n: usize, m: usize, blocks: usize, cg_iters: usize) -> f64 {
    let k = blocks.max(1) as f64;
    let nf = n as f64;
    let mf = m as f64;
    let iters = cg_iters as f64;
    nf * nf * mf + k * nf * nf * nf / 3.0 + 4.0 * nf * mf + iters * (6.0 * nf * mf + 10.0 * mf)
}

/// Modeled *time-proportional* FLOP count of one solve under a
/// [`Precision`] mode (PR 6). For `Precision::F64` — and for every kind
/// without a mixed path — this is exactly [`flops`]. For
/// `Precision::Mixed` on the session kinds (`chol`, `rvb`) the
/// single-precision stages count at `1/F32_SPEEDUP` of their f64 cost
/// (twice the SIMD lanes, half the packed-panel bytes), while the f64
/// refinement loop **adds** `refine_sweeps` true-residual passes:
///
/// ```text
/// chol  (SYRK n²m + Chol n³/3 + TRSM 2n²) / 2   f32 factor + solves
///       + 4nm                                    f64 Sv / Sᵀz casts per RHS
///       + sweeps · (4nm + 2n² + 4nm)             f64 residual + f32 correction
/// rvb   recovery factor stays f64 (n²m + n³/3 unchanged — its tiny
///       ridge is far too ill-conditioned for f32); only the damped
///       n³/3 factor and the 2n² solves halve, refinement adds
///       sweeps · (2n² + 2n²) Gram-matvec residual passes.
/// ```
///
/// `refine_sweeps` is the expected sweep count — ≈ log(tol)/log(κ·u₃₂),
/// typically 1–3 for the κ ≲ 10⁵ Grams the mixed mode targets; feed the
/// measured [`super::chol::mixed_counters::refine_sweeps`] back in for
/// post-hoc accounting. The model keeps cross-kind *and* cross-mode
/// comparisons honest: mixed only wins while the O(n²m + n³) f32
/// savings dominate the O(sweeps·nm) f64 refinement tax.
pub fn flops_precision(
    kind: SolverKind,
    n: usize,
    m: usize,
    precision: Precision,
    refine_sweeps: usize,
) -> f64 {
    let nf = n as f64;
    let mf = m as f64;
    let sweeps = refine_sweeps as f64;
    match (kind, precision) {
        (SolverKind::Chol, Precision::Mixed) => {
            let f32_part = (nf * nf * mf + nf * nf * nf / 3.0 + 2.0 * nf * nf) / F32_SPEEDUP;
            let f64_rhs = 4.0 * nf * mf;
            let per_sweep = 4.0 * nf * mf + 2.0 * nf * nf + 4.0 * nf * mf;
            f32_part + f64_rhs + sweeps * per_sweep
        }
        (SolverKind::Rvb, Precision::Mixed) => {
            // Recovery path (full f64): Gram reuse n²m + ridge factor
            // n³/3 + the O(nm) reconstruction checks.
            let f64_part = nf * nf * mf + nf * nf * nf / 3.0 + 6.0 * nf * mf;
            let f32_part = (nf * nf * nf / 3.0 + 2.0 * nf * nf) / F32_SPEEDUP;
            let per_sweep = 4.0 * nf * nf;
            f64_part + f32_part + sweeps * per_sweep
        }
        _ => flops(kind, n, m),
    }
}

/// Modeled peak device-memory footprint in bytes.
pub fn memory_bytes(kind: SolverKind, n: usize, m: usize) -> u64 {
    let n = n as f64;
    let m = m as f64;
    let bytes = match kind {
        // S + W + L + vectors.
        SolverKind::Chol => 1.0 * n * m * W + 2.0 * n * n * W + 4.0 * m * W,
        // S + V (n×m) + Gram/eigvecs.
        SolverKind::Eigh => 2.0 * n * m * W + 3.0 * n * n * W + 4.0 * m * W,
        // S + rotated copy + U, plus the gesvda workspace O(n³) term
        // (calibrated: (4096,1e5) overflows 80 GB, (2048,2e5) does not).
        SolverKind::Svda => 2.0 * n * m * W + 0.15 * n * n * n * W + 4.0 * m * W,
        // SᵀS is m×m.
        SolverKind::Naive => m * m * W + n * m * W,
        SolverKind::Cg => n * m * W + 6.0 * m * W,
        // chol's footprint plus the cached recovery factor (one more n×n).
        SolverKind::Rvb => 1.0 * n * m * W + 3.0 * n * n * W + 4.0 * m * W,
        // Block shards total nm; per-block n×n Gram + factor pairs
        // (modeled at the single-block limit — more blocks *shrink*
        // nothing here but add (k−1)·2n², negligible in m ≫ n).
        SolverKind::BlockDiag => 1.0 * n * m * W + 2.0 * n * n * W + 4.0 * m * W,
        // Shards + the m_b×m_b block Gram (single-block limit m²) +
        // the small Kronecker eigen caches.
        SolverKind::KpSvd => n * m * W + m * m * W + 4.0 * m * W,
        // Owned window copy + preconditioner shards (2nm) + block
        // factors + the PCG workspace vectors.
        SolverKind::Hybrid => 2.0 * n * m * W + 2.0 * n * n * W + 10.0 * m * W,
    };
    bytes as u64
}

/// Simulated device-memory budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryBudget(u64);

impl MemoryBudget {
    /// The paper's testbed: one NVIDIA A100 with 80 GB.
    pub fn a100_80gb() -> Self {
        MemoryBudget(80_000_000_000)
    }

    pub fn unlimited() -> Self {
        MemoryBudget(u64::MAX)
    }

    /// Arbitrary budget (tests).
    pub fn bytes_for_test(b: u64) -> Self {
        MemoryBudget(b)
    }

    pub fn bytes(&self) -> u64 {
        self.0
    }

    pub fn fits(&self, required: u64) -> bool {
        required <= self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chol_flops_beat_naive_when_tall_skinny() {
        // m ≫ n: Algorithm 1 wins by ~ (m/n)² (paper §2).
        let f_chol = flops(SolverKind::Chol, 1000, 1_000_000);
        let f_naive = flops(SolverKind::Naive, 1000, 1_000_000);
        assert!(f_naive / f_chol > 1e5);
    }

    #[test]
    fn chol_cheapest_of_the_direct_methods() {
        for &(n, m) in &[(256usize, 100_000usize), (2048, 100_000), (4096, 100_000)] {
            let c = flops(SolverKind::Chol, n, m);
            assert!(c < flops(SolverKind::Eigh, n, m));
            assert!(c < flops(SolverKind::Svda, n, m));
        }
    }

    #[test]
    fn chol_memory_linear_in_m() {
        // O(nm) not O(m²): ratio of footprints at 2× m is ~2×.
        let a = memory_bytes(SolverKind::Chol, 512, 100_000) as f64;
        let b = memory_bytes(SolverKind::Chol, 512, 200_000) as f64;
        assert!((b / a - 2.0).abs() < 0.1);
        // while naive is ~4×.
        let an = memory_bytes(SolverKind::Naive, 512, 100_000) as f64;
        let bn = memory_bytes(SolverKind::Naive, 512, 200_000) as f64;
        assert!((bn / an - 4.0).abs() < 0.1);
    }

    #[test]
    fn threaded_model_divides_parallel_work_only() {
        let (n, m) = (1024usize, 100_000usize);
        for &kind in &[SolverKind::Chol, SolverKind::Eigh, SolverKind::Naive, SolverKind::Rvb] {
            let f1 = flops_threaded(kind, n, m, 1);
            assert_eq!(f1, flops(kind, n, m), "{kind:?} at 1 thread");
            let f8 = flops_threaded(kind, n, m, 8);
            // Dominated by parallel terms at this shape: close to /8 but
            // strictly above it (the serial streaming passes remain).
            assert!(f8 < f1 / 4.0, "{kind:?} should scale");
            assert!(f8 >= f1 / 8.0, "{kind:?} cannot beat ideal");
        }
        // CG is sequential: threads change nothing.
        assert_eq!(
            flops_threaded(SolverKind::Cg, n, m, 8),
            flops(SolverKind::Cg, n, m)
        );
        // Kind selection stays honest: chol remains the cheapest direct
        // method at the paper's shapes for every thread count.
        for &t in &[1usize, 2, 8] {
            let c = flops_threaded(SolverKind::Chol, 2048, 100_000, t);
            assert!(c < flops_threaded(SolverKind::Eigh, 2048, 100_000, t));
            assert!(c < flops_threaded(SolverKind::Svda, 2048, 100_000, t));
            assert!(c < flops_threaded(SolverKind::Naive, 2048, 100_000, t));
        }
    }

    #[test]
    fn streaming_model_amortizes_small_rotations() {
        let (n, m) = (512usize, 100_000usize);
        for &kind in &[SolverKind::Chol, SolverKind::Rvb] {
            let cold = flops(kind, n, m);
            let stream = flops_streaming(kind, n, m, n / 10);
            assert!(cold / stream > 4.0, "{kind:?}: {}", cold / stream);
            // The bench acceptance bar (≥5× end-to-end at ≤10%
            // rotation) must be reachable in the model: the harness
            // rotates n/16 of the window.
            let bench = flops_streaming(kind, n, m, n / 16);
            assert!(cold / bench > 5.0, "{kind:?}: {}", cold / bench);
            // Monotone in k, and a full rotation stops being a win.
            assert!(flops_streaming(kind, n, m, 8) < stream);
            assert!(flops_streaming(kind, n, m, n) > cold, "{kind:?} full rotation");
        }
        // Non-streaming kinds pay the cold cost every step.
        for &kind in &[SolverKind::Eigh, SolverKind::Svda, SolverKind::Naive, SolverKind::Cg] {
            assert_eq!(flops_streaming(kind, n, m, 8), flops(kind, n, m));
        }
    }

    #[test]
    fn precision_model_discounts_mixed_and_charges_refinement() {
        let (n, m) = (2048usize, 100_000usize);
        // f64 mode is exactly the base model for every kind.
        for &kind in SolverKind::all() {
            assert_eq!(flops_precision(kind, n, m, Precision::F64, 2), flops(kind, n, m));
        }
        // Kinds without a mixed path never get a discount.
        for &kind in &[SolverKind::Eigh, SolverKind::Svda, SolverKind::Naive, SolverKind::Cg] {
            assert_eq!(flops_precision(kind, n, m, Precision::Mixed, 2), flops(kind, n, m));
        }
        // chol mixed: the f32 factor dominates — a clear win at few
        // sweeps, bounded below by the ideal 2× lane speedup.
        let f64_cost = flops(SolverKind::Chol, n, m);
        let mixed = flops_precision(SolverKind::Chol, n, m, Precision::Mixed, 2);
        assert!(mixed < 0.7 * f64_cost, "mixed should win: {mixed:.3e} vs {f64_cost:.3e}");
        assert!(mixed > f64_cost / 2.0, "cannot beat the lane bound");
        // Each refinement sweep charges O(nm) f64 work — monotone, and
        // enough sweeps erase the win entirely.
        let s1 = flops_precision(SolverKind::Chol, n, m, Precision::Mixed, 1);
        let s5 = flops_precision(SolverKind::Chol, n, m, Precision::Mixed, 5);
        assert!(s1 < s5);
        assert!(flops_precision(SolverKind::Chol, n, m, Precision::Mixed, 2000) > f64_cost);
        // rvb mixed: the recovery factor stays f64, so the saving is
        // real but strictly smaller than chol's.
        let rvb64 = flops(SolverKind::Rvb, n, m);
        let rvb_mixed = flops_precision(SolverKind::Rvb, n, m, Precision::Mixed, 2);
        assert!(rvb_mixed < rvb64);
        assert!(rvb64 / rvb_mixed < f64_cost / mixed, "rvb saves less than chol");
    }

    #[test]
    fn blocked_model_tracks_blocks_and_iterations() {
        let (n, m) = (1024usize, 100_000usize);
        // Single block, zero iterations is exactly the chol model.
        assert_eq!(flops_blocked(n, m, 1, 0), flops(SolverKind::Chol, n, m));
        // More blocks add k·n³/3 factor work, never Gram work.
        assert!(flops_blocked(n, m, 16, 0) > flops_blocked(n, m, 1, 0));
        let delta = flops_blocked(n, m, 2, 0) - flops_blocked(n, m, 1, 0);
        let n3 = (n as f64).powi(3) / 3.0;
        assert!((delta / n3 - 1.0).abs() < 1e-9, "block increment must be one factor");
        // PCG iterations charge linearly on top.
        assert!(flops_blocked(n, m, 4, 30) > flops_blocked(n, m, 4, 0));
        assert_eq!(flops(SolverKind::Hybrid, n, m), flops_blocked(n, m, 1, 30));
        // The structured kinds stay consistent across the threaded and
        // memory models: 1 thread = base model, 8 threads strictly
        // cheaper, footprints positive.
        for &kind in &[SolverKind::BlockDiag, SolverKind::KpSvd, SolverKind::Hybrid] {
            let ratio = flops_threaded(kind, n, m, 1) / flops(kind, n, m);
            assert!((ratio - 1.0).abs() < 1e-12, "{kind:?} at 1 thread");
            assert!(flops_threaded(kind, n, m, 8) < flops(kind, n, m), "{kind:?}");
            assert!(memory_bytes(kind, n, m) > 0);
        }
        // blockdiag's single-block model coincides with chol (the
        // bit-identity limit) and kpsvd's redamp is O(1) — its cost is
        // all in the λ-independent factor stage, so the model must not
        // depend on iteration-style terms.
        assert_eq!(flops(SolverKind::BlockDiag, n, m), flops(SolverKind::Chol, n, m));
    }

    #[test]
    fn scaling_exponents_of_the_model() {
        // flops(chol) should scale ~n² at fixed m and ~m at fixed n — the
        // dotted lines of Fig. 1.
        let n_ratio = flops(SolverKind::Chol, 2048, 100_000) / flops(SolverKind::Chol, 1024, 100_000);
        assert!((n_ratio.log2() - 2.0).abs() < 0.3, "n-exponent {}", n_ratio.log2());
        let m_ratio = flops(SolverKind::Chol, 2048, 200_000) / flops(SolverKind::Chol, 2048, 100_000);
        assert!((m_ratio.log2() - 1.0).abs() < 0.3, "m-exponent {}", m_ratio.log2());
    }
}
