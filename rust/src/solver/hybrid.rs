//! Structured-preconditioned CG on the exact damped system (PR 10).
//!
//! The structured sessions ([`super::blockdiag`], [`super::kpsvd`]) are
//! cheap but *approximate*: they drop cross-block curvature. The paper's
//! dense path is exact but pays O(n²m + n³) per window. The hybrid
//! splits the difference — run the PR-5-fixed true-residual CG on the
//! **exact** system `(SᵀS + λI)x = v`, but precondition every iterate
//! with the block-diagonal factor `M = blockdiag(SᵀS) + λI`:
//!
//! ```text
//! M⁻¹(SᵀS + λI) has clustered spectrum  ⇒  PCG iterations ≈ O(√κ(M⁻¹A))
//! ```
//!
//! When the true Fisher is nearly block-diagonal (the K-FAC premise),
//! κ(M⁻¹A) ≈ 1 and PCG converges in a handful of iterations — strictly
//! fewer than plain CG on the same system (pinned by
//! `rust/tests/structured.rs` and reported per block count in
//! `BENCH_PR10.json`) — while still solving the *exact* system to
//! `solver.hybrid_tol`, unlike the purely structured kinds. Each
//! iteration costs one O(nm) Fisher matvec pair plus one O(Σ n·m_b)
//! block back-substitution; [`super::cost::flops_blocked`] is the
//! matching cost model.
//!
//! Convergence follows the PR-5 discipline exactly: the recurrence
//! residual is verified against the recomputed **true** residual before
//! declaring success, drift triggers a residual-replacement restart
//! (re-preconditioned), and an iteration cap surfaces
//! [`SolveError::DidNotConverge`] unless `solver.cg_loose_accept`
//! admits a true residual within 100×tol. Iteration counts are exposed
//! through [`CgStats`], like the plain CG session.

use super::blockdiag::{BlockDiagFactor, BlockDiagSolver, BlockKind, BlockPartition};
use super::cg::CgStats;
use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, Precision, SolveError};
use crate::linalg::mat::{dot, norm2};
use crate::linalg::{KernelConfig, Mat};
use std::sync::{Arc, Mutex};

/// The structured-preconditioned CG solver ("hybrid").
#[derive(Debug, Clone)]
pub struct HybridCgSolver {
    /// Relative true-residual tolerance ‖r‖/‖v‖ (`solver.hybrid_tol`).
    pub tol: f64,
    /// Iteration cap (`solver.cg_max_iters`).
    pub max_iters: usize,
    /// Accept capped solves within 100×tol (`solver.cg_loose_accept`).
    pub loose_accept: bool,
    /// The block-diagonal preconditioner factory — carries kernel
    /// config, precision, block count/kind and explicit partition.
    inner: BlockDiagSolver,
    last_stats: Arc<Mutex<CgStats>>,
}

impl Default for HybridCgSolver {
    fn default() -> Self {
        HybridCgSolver::new(1e-10, 10_000)
    }
}

impl HybridCgSolver {
    pub fn new(tol: f64, max_iters: usize) -> Self {
        HybridCgSolver {
            tol,
            max_iters,
            loose_accept: false,
            inner: BlockDiagSolver::new(),
            last_stats: Arc::new(Mutex::new(CgStats::default())),
        }
    }

    /// Kernel configuration for the preconditioner's block sessions.
    pub fn with_config(mut self, cfg: KernelConfig) -> Self {
        self.inner = self.inner.with_kernel(cfg);
        self
    }

    /// Arithmetic mode for the preconditioner's inner block factors
    /// (mixed composes through them; the CG loop itself stays f64 —
    /// a preconditioner only needs to be *spectrally* close).
    pub fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.inner = self.inner.with_precision(precision, tol);
        self
    }

    /// RVB recovery tolerance for rvb-backed preconditioner blocks.
    pub fn with_recovery_tol(mut self, tol: f64) -> Self {
        self.inner = self.inner.with_recovery_tol(tol);
        self
    }

    /// Preconditioner block structure (`solver.blocks`,
    /// `solver.block_kind`).
    pub fn with_blocks(mut self, blocks: usize, block_kind: BlockKind) -> Self {
        self.inner = self.inner.with_blocks(blocks, block_kind);
        self
    }

    /// Explicit (non-uniform) preconditioner partition.
    pub fn with_partition(mut self, partition: BlockPartition) -> Self {
        self.inner = self.inner.with_partition(partition);
        self
    }

    /// Opt into accepting capped solves within 100×tol.
    pub fn with_loose_accept(mut self, loose: bool) -> Self {
        self.loose_accept = loose;
        self
    }

    /// Stats from the most recently completed solve on any session of
    /// this solver (per-session records live on
    /// [`HybridCgFactor::stats`], mirroring the CG session discipline).
    pub fn stats(&self) -> CgStats {
        *self.last_stats.lock().unwrap()
    }

    fn open(&self, window: Mat) -> HybridCgFactor {
        let pre = self.inner.open_window(&window);
        let (n, m) = window.shape();
        HybridCgFactor {
            tol: self.tol,
            max_iters: self.max_iters,
            loose_accept: self.loose_accept,
            s: window,
            pre,
            lambda: 0.0,
            stats: CgStats::default(),
            shared: Arc::clone(&self.last_stats),
            r: vec![0.0; m],
            z: vec![0.0; m],
            p: vec![0.0; m],
            ap: vec![0.0; m],
            sp: vec![0.0; n],
        }
    }
}

impl DampedSolver for HybridCgSolver {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(self.open(s.clone()))
    }

    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        Some(Box::new(self.open(window)))
    }
}

/// A staged hybrid session: an owned score window, the block-diagonal
/// preconditioner factor over the same window, and the preallocated
/// PCG workspace. `redamp` re-damps the preconditioner (O(Σ m_b³)
/// block refactors against cached block Grams — zero Gram GEMMs);
/// `update_rows` rotates both the owned window and the preconditioner's
/// inner sessions natively, so the hybrid streams like chol/rvb.
pub struct HybridCgFactor {
    tol: f64,
    max_iters: usize,
    loose_accept: bool,
    s: Mat,
    pre: BlockDiagFactor,
    lambda: f64,
    stats: CgStats,
    shared: Arc<Mutex<CgStats>>,
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    sp: Vec<f64>,
}

impl HybridCgFactor {
    /// Convergence record of this session's most recent solve.
    pub fn stats(&self) -> CgStats {
        self.stats
    }

    /// `ap = (SᵀS + λI)·p` without forming the Fisher matrix.
    fn fisher_apply(&mut self) {
        self.s.matvec_into(&self.p, &mut self.sp);
        self.s.t_matvec_into(&self.sp, &mut self.ap);
        for (o, pi) in self.ap.iter_mut().zip(&self.p) {
            *o += self.lambda * pi;
        }
    }

    /// Recompute the **true** residual `r = v − (SᵀS + λI)x` into the
    /// session's `r` buffer and return its norm (PR-5 discipline).
    fn true_residual(&mut self, v: &[f64], x: &[f64]) -> f64 {
        self.s.matvec_into(x, &mut self.sp);
        self.s.t_matvec_into(&self.sp, &mut self.ap);
        let lambda = self.lambda;
        for j in 0..x.len() {
            self.r[j] = v[j] - self.ap[j] - lambda * x[j];
        }
        norm2(&self.r)
    }

    /// `z = M⁻¹·r` through the block-diagonal factor — the structured
    /// solve that clusters the preconditioned spectrum.
    fn precondition(&mut self) -> Result<(), SolveError> {
        let r = std::mem::take(&mut self.r);
        let result = self.pre.solve_into(&r, &mut self.z);
        self.r = r;
        result
    }

    fn record(&mut self, iterations: usize, final_residual: f64) {
        self.stats = CgStats { iterations, final_residual };
        *self.shared.lock().unwrap() = self.stats;
    }
}

impl Factorization for HybridCgFactor {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        // The preconditioner is damped at the *same* λ as the exact
        // system: per-block exact solves of blockdiag(SᵀS) + λI.
        self.pre.redamp(lambda)?;
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        let tol = self.tol;
        let max_iters = self.max_iters;
        let vnorm = norm2(v).max(f64::MIN_POSITIVE);
        x.fill(0.0);
        self.r.copy_from_slice(v); // r = v − A·0
        self.precondition()?; // z = M⁻¹r
        self.p.copy_from_slice(&self.z);
        let mut rz = dot(&self.r, &self.z);

        for it in 0..max_iters {
            // Convergence is judged on the residual of the *exact*
            // system, never the preconditioned quantity rz.
            if norm2(&self.r) <= tol * vnorm {
                let true_res = self.true_residual(v, x);
                if true_res <= tol * vnorm {
                    self.record(it, true_res / vnorm);
                    return Ok(());
                }
                // Drift: residual-replacement restart from the true
                // residual (`r` already holds it), re-preconditioned.
                self.precondition()?;
                self.p.copy_from_slice(&self.z);
                rz = dot(&self.r, &self.z);
            }
            self.fisher_apply();
            let alpha = rz / dot(&self.p, &self.ap);
            for j in 0..m {
                x[j] += alpha * self.p[j];
                self.r[j] -= alpha * self.ap[j];
            }
            self.precondition()?;
            let rz_new = dot(&self.r, &self.z);
            let beta = rz_new / rz;
            rz = rz_new;
            for j in 0..m {
                self.p[j] = self.z[j] + beta * self.p[j];
            }
        }
        // Iteration cap: judge by the true residual (PR-5 discipline).
        let final_residual = self.true_residual(v, x) / vnorm;
        self.record(max_iters, final_residual);
        if final_residual <= tol {
            return Ok(());
        }
        if self.loose_accept && final_residual <= tol * 100.0 {
            return Ok(());
        }
        Err(SolveError::DidNotConverge { iterations: max_iters, residual: final_residual })
    }

    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        let (n, m) = self.s.shape();
        assert_eq!(added.cols(), m, "added rows must be m-dimensional");
        for &i in removed {
            if i >= n {
                return Err(SolveError::BadInput(format!(
                    "update_rows: removed index {i} out of range for a {n}-row window"
                )));
            }
        }
        // Rotate the preconditioner's inner sessions first (native
        // O(kn²) factor rotations); only then mutate the owned window
        // copy, so a rotation failure leaves the session consistent.
        self.pre.update_rows(removed, added)?;
        let mut keep = vec![true; n];
        for &i in removed {
            keep[i] = false;
        }
        let kept: Vec<usize> = (0..n).filter(|&i| keep[i]).collect();
        let mut next = Mat::zeros(kept.len() + added.rows(), m);
        for (dst, &src) in kept.iter().enumerate() {
            next.row_mut(dst).copy_from_slice(self.s.row(src));
        }
        for a in 0..added.rows() {
            next.row_mut(kept.len() + a).copy_from_slice(added.row(a));
        }
        let n_new = next.rows();
        self.s = next;
        if self.sp.len() != n_new {
            self.sp = vec![0.0; n_new];
        }
        Ok(())
    }

    fn refresh(&mut self) -> Result<(), SolveError> {
        self.pre.refresh()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CgSolver};

    /// A synthetic Fisher with strong block structure: disjoint row
    /// supports (SᵀS exactly block-diagonal) and per-block scales
    /// spanning ~10^1.5, so plain CG grinds on the κ spread while the
    /// block preconditioner is exact. The spread is deliberately capped:
    /// at tol·‖v‖ targets, f64's attainable true residual scales with
    /// ε·κ(SᵀS+λI), so a wilder spread would put the tolerance below
    /// what *any* correctly-rounded iteration can reach (verified by
    /// `python/oracle_structured.py`).
    fn blocked_scores(n_per: usize, blocks: usize, width: usize, rng: &mut Rng) -> Mat {
        let mut s = Mat::zeros(n_per * blocks, blocks * width);
        for b in 0..blocks {
            let scale = 10f64.powf(b as f64 / 2.0);
            for r in 0..n_per {
                let row = s.row_mut(b * n_per + r);
                for c in 0..width {
                    row[b * width + c] = scale * rng.normal();
                }
            }
        }
        s
    }

    #[test]
    fn exact_preconditioner_converges_in_few_iterations() {
        let mut rng = Rng::seed_from(1201);
        let s = blocked_scores(4, 4, 6, &mut rng); // 16×24, live spectrum spans ~1e3
        let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let lambda = 1e-3;
        // Shared tol 1e-7: loose enough to sit well above the f64
        // attainable-residual floor (~ε·κ·‖v‖) for this κ, tight enough
        // that plain CG still has to work through the spread spectrum.
        let hybrid = HybridCgSolver::new(1e-7, 10_000).with_blocks(4, BlockKind::Chol);
        let x = hybrid.solve(&s, &v, lambda).unwrap();
        assert!(residual_norm(&s, &x, &v, lambda) < 1e-5);
        let pcg_iters = hybrid.stats().iterations;
        let cg = CgSolver::new(1e-7, 10_000);
        cg.solve(&s, &v, lambda).unwrap();
        let cg_iters = cg.stats().iterations;
        // SᵀS is exactly block-diagonal here, so M⁻¹A + λ-scaling is
        // near-identity: a handful of PCG iterations vs CG's κ-driven
        // grind.
        assert!(
            pcg_iters < cg_iters,
            "hybrid ({pcg_iters}) must beat plain CG ({cg_iters})"
        );
        assert!(pcg_iters <= 5, "exact preconditioner should converge almost at once");
    }

    #[test]
    fn solves_exactly_even_with_cross_block_mass() {
        // Dense random S: the preconditioner is *approximate* but the
        // hybrid still solves the exact system to tolerance.
        let mut rng = Rng::seed_from(1202);
        let s = Mat::randn(10, 28, &mut rng);
        let v: Vec<f64> = (0..28).map(|_| rng.normal()).collect();
        let hybrid = HybridCgSolver::new(1e-10, 10_000).with_blocks(4, BlockKind::Auto);
        let x = hybrid.solve(&s, &v, 0.05).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.05) < 1e-7);
        let xc = crate::solver::CholSolver::default().solve(&s, &v, 0.05).unwrap();
        for (a, b) in x.iter().zip(&xc) {
            assert!((a - b).abs() < 1e-7, "hybrid must match the exact solve");
        }
    }

    #[test]
    fn session_resweeps_and_rotates() {
        let mut rng = Rng::seed_from(1203);
        let s = Mat::randn(12, 20, &mut rng);
        let v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        let solver = HybridCgSolver::default().with_blocks(2, BlockKind::Chol);
        let mut fact = solver.begin_window(s.clone()).expect("hybrid owns windows");
        fact.redamp(0.5).unwrap();
        let x1 = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x1, &v, 0.5) < 1e-7);
        fact.redamp(0.01).unwrap();
        let x2 = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x2, &v, 0.01) < 1e-7);
        // Rotate two rows and check against a cold solve on the rotated
        // window.
        let added = Mat::randn(2, 20, &mut rng);
        fact.update_rows(&[0, 5], &added).unwrap();
        fact.redamp(0.01).unwrap();
        let x3 = fact.solve(&v).unwrap();
        let rows: Vec<usize> = (0..12).filter(|&i| i != 0 && i != 5).collect();
        let mut rotated = Mat::zeros(12, 20);
        for (dst, &src) in rows.iter().enumerate() {
            rotated.row_mut(dst).copy_from_slice(s.row(src));
        }
        rotated.row_mut(10).copy_from_slice(added.row(0));
        rotated.row_mut(11).copy_from_slice(added.row(1));
        assert!(residual_norm(&rotated, &x3, &v, 0.01) < 1e-7);
    }
}
