//! **Algorithm 1** — the paper's contribution.
//!
//! ```text
//! Input: S (n×m), v (m), λ
//! 1: W ← SSᵀ + λĨ                  (SYRK, O(n²m))
//! 2: L ← Chol(W)                   (O(n³))
//! 3: Q ← L⁻¹S                      (NOT materialized — see below)
//! 4: x ← (v − QᵀQv)/λ
//! ```
//!
//! Following the paper's implementation note, line 3 is inlined into
//! line 4: `QᵀQv = SᵀL⁻ᵀL⁻¹Sv` is evaluated right-to-left as
//! matvec → forward solve → backward solve → transposed matvec, which
//! avoids the O(n²m) cost and O(nm) extra memory of forming `Q`.

use super::{DampedSolver, SolveError};
use crate::linalg::gemm::{syrk, syrk_parallel};
use crate::linalg::{cholesky, solve_lower, solve_lower_transpose, KernelConfig, Mat};

/// Algorithm-1 solver ("chol").
#[derive(Debug, Clone)]
pub struct CholSolver {
    /// Worker threads for the SYRK (Gram) step, the only O(n²m) kernel.
    /// 1 = serial (deterministic default). Threaded SYRK runs on the
    /// persistent kernel pool and is bit-identical to serial — the
    /// paper's parallelization strategy (shared with RVB+23) shards this
    /// product; within one process we thread it.
    pub threads: usize,
}

impl Default for CholSolver {
    fn default() -> Self {
        CholSolver { threads: 1 }
    }
}

impl CholSolver {
    pub fn with_threads(threads: usize) -> Self {
        CholSolver { threads: threads.max(1) }
    }

    /// Construct from the shared kernel configuration (CLI / TOML /
    /// coordinator plumbing all funnel through [`KernelConfig`]).
    pub fn with_config(cfg: KernelConfig) -> Self {
        CholSolver::with_threads(cfg.threads)
    }

    /// The kernel configuration this solver dispatches with.
    pub fn kernel_config(&self) -> KernelConfig {
        KernelConfig::with_threads(self.threads)
    }

    /// The factorized form: returns `(L, u = Sv)` so callers solving many
    /// right-hand sides against the same S (e.g. the KFAC-vs-exact
    /// ablation) can reuse the factor.
    pub fn factor(&self, s: &Mat, lambda: f64) -> Result<Mat, SolveError> {
        let w = if self.threads > 1 {
            syrk_parallel(s, lambda, self.threads)
        } else {
            syrk(s, lambda)
        };
        Ok(cholesky(&w)?)
    }

    /// Apply Algorithm 1 line 4 given a precomputed factor `L`.
    pub fn solve_with_factor(
        &self,
        s: &Mat,
        l: &Mat,
        v: &[f64],
        lambda: f64,
    ) -> Vec<f64> {
        // u = S v                       O(nm)
        let u = s.matvec(v);
        // y = L⁻¹ u,  z = L⁻ᵀ y         O(n²)
        let y = solve_lower(l, &u);
        let z = solve_lower_transpose(l, &y);
        // t = Sᵀ z                      O(nm)
        let t = s.t_matvec(&z);
        // x = (v − t)/λ
        let inv = 1.0 / lambda;
        v.iter().zip(&t).map(|(vi, ti)| inv * (vi - ti)).collect()
    }
}

impl DampedSolver for CholSolver {
    fn name(&self) -> &'static str {
        "chol"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols(), "v must be m-dimensional");
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let l = self.factor(s, lambda)?;
        Ok(self.solve_with_factor(s, &l, v, lambda))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::qr::ridge_qr_oracle;
    use crate::solver::residual_norm;

    #[test]
    fn solves_normal_equations_exactly() {
        let mut rng = Rng::seed_from(110);
        for &(n, m, lambda) in &[
            (1usize, 1usize, 1.0f64),
            (2, 10, 0.5),
            (8, 100, 1e-2),
            (32, 500, 1e-3),
            (64, 64, 0.1), // square edge case (n = m)
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let r = residual_norm(&s, &x, &v, lambda);
            let vnorm = crate::linalg::mat::norm2(&v);
            assert!(r < 1e-8 * vnorm.max(1.0), "residual {r} at ({n},{m},λ={lambda})");
        }
    }

    #[test]
    fn matches_qr_oracle() {
        let mut rng = Rng::seed_from(111);
        let s = Mat::randn(12, 80, &mut rng);
        let v: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 0.07).unwrap();
        let oracle = ridge_qr_oracle(&s, &v, 0.07);
        for (a, b) in x.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let mut rng = Rng::seed_from(112);
        let s = Mat::randn(100, 700, &mut rng);
        let v: Vec<f64> = (0..700).map(|_| rng.normal()).collect();
        let serial = CholSolver::default().solve(&s, &v, 1e-3).unwrap();
        let par = CholSolver::with_threads(4).solve(&s, &v, 1e-3).unwrap();
        for (a, b) in serial.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn factor_reuse_across_rhs() {
        let mut rng = Rng::seed_from(113);
        let s = Mat::randn(16, 120, &mut rng);
        let solver = CholSolver::default();
        let l = solver.factor(&s, 0.02).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
            let x = solver.solve_with_factor(&s, &l, &v, 0.02);
            assert!(residual_norm(&s, &x, &v, 0.02) < 1e-8);
        }
    }

    #[test]
    fn rejects_nonpositive_lambda() {
        let mut rng = Rng::seed_from(114);
        let s = Mat::randn(3, 9, &mut rng);
        let v = vec![1.0; 9];
        assert!(matches!(
            CholSolver::default().solve(&s, &v, 0.0),
            Err(SolveError::BadInput(_))
        ));
        assert!(matches!(
            CholSolver::default().solve(&s, &v, -1.0),
            Err(SolveError::BadInput(_))
        ));
    }

    #[test]
    fn rank_deficient_s_is_fine_with_damping() {
        // n > rank: duplicate rows. SSᵀ singular but +λĨ saves it — this is
        // exactly the "damping becomes essential" claim of §1.
        let mut rng = Rng::seed_from(115);
        let mut s = Mat::randn(6, 50, &mut rng);
        let r0 = s.row(0).to_vec();
        s.row_mut(5).copy_from_slice(&r0);
        let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 1e-4).unwrap();
        assert!(residual_norm(&s, &x, &v, 1e-4) < 1e-6);
    }

    #[test]
    fn tiny_lambda_still_accurate() {
        let mut rng = Rng::seed_from(116);
        let s = Mat::randn(10, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let lambda = 1e-10;
        let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
        // Relative residual stays small even at extreme damping ratios —
        // the x = (v − SᵀL⁻ᵀL⁻¹Sv)/λ form is stable because the numerator
        // lies in the λ-scaled complement.
        // κ(W) ≈ σ_max²/λ ≈ 10¹² here, so ~1e-4 relative residual is the
        // f64 floor; the point is no *catastrophic* loss of accuracy.
        let r = residual_norm(&s, &x, &v, lambda);
        let vnorm = crate::linalg::mat::norm2(&v);
        assert!(r < 1e-3 * vnorm, "residual {r}");
    }
}
