//! **Algorithm 1** — the paper's contribution.
//!
//! ```text
//! Input: S (n×m), v (m), λ
//! 1: W ← SSᵀ + λĨ                  (SYRK, O(n²m))
//! 2: L ← Chol(W)                   (O(n³))
//! 3: Q ← L⁻¹S                      (NOT materialized — see below)
//! 4: x ← (v − QᵀQv)/λ
//! ```
//!
//! Following the paper's implementation note, line 3 is inlined into
//! line 4: `QᵀQv = SᵀL⁻ᵀL⁻¹Sv` is evaluated right-to-left as
//! matvec → forward solve → backward solve → transposed matvec, which
//! avoids the O(n²m) cost and O(nm) extra memory of forming `Q`.
//!
//! Since PR 2 the primary surface is the session path: [`CholFactor`]
//! caches the *un-damped* Gram `SSᵀ` so a λ-resweep (the optimizer's
//! Levenberg–Marquardt backoff) repeats only the O(n³) Cholesky — zero
//! Gram GEMMs, pinned by a kernel-counter test — and multi-RHS solves go
//! through the blocked TRSM instead of a loop of vector substitutions.
//!
//! Since PR 6 the session has a **mixed-precision mode**
//! (`solver.precision = mixed`): the Gram SYRK, the Cholesky and the
//! triangular solves run in f32 (≈2× kernel throughput, half the
//! factor footprint), the damped diagonal is accumulated in f64, and
//! every right-hand side is refined against the f64 true residual
//! `r = v − (SᵀS + λI)x` until it meets `solver.tol` — recovering full
//! f64 accuracy whenever κ(W)·u₃₂ ≪ 1. Outside that regime (f32
//! overflow/subnormal Gram, factorization breakdown, refinement
//! stagnation) the session *latches back onto the f64 path*, observable
//! through [`mixed_counters::fallbacks`].

use super::session::{check_lambda, refactor_damped, undamped_err, Precision};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::chol_update::UpdatableChol;
use crate::linalg::gemm::{
    gemm_nt_threaded, gemm_tn_threaded, syrk, syrk_parallel, syrk_parallel_f32,
};
use crate::linalg::mat::norm2;
use crate::linalg::trisolve::{bwd_multi_core_f32, fwd_multi_core_f32};
use crate::linalg::{
    cholesky_in_place_f32, cholesky_threaded, solve_lower, solve_lower_f32,
    solve_lower_multi_threaded, solve_lower_transpose, solve_lower_transpose_f32,
    solve_lower_transpose_multi_threaded, KernelConfig, KernelIsa, Mat,
};

/// Relative pivot floor for the streaming bordered append: a pivot
/// `δ² ≤ 1e-10·d` is numerically meaningless after the O(n²) rotation
/// arithmetic, so the session treats it as a breakdown and falls back
/// to the full refactor of the patched Gram (which decides PD-ness with
/// the blocked factorization's own criterion). Legitimate damped pivots
/// sit at δ²/d ≳ λ/‖row‖², far above this floor for any λ a consumer
/// would run.
const APPEND_REL_FLOOR: f64 = 1e-10;

/// Shared streaming-rotation engine for the Gram-caching sessions
/// (`chol` here, `rvb` via re-use): validates the rotation, patches the
/// cached un-damped Gram with O(knm) panel products (zero full-Gram
/// SYRKs), rotates `damped` factors in O(kn²) (delete sweeps + bordered
/// appends at `lambda` extra diagonal), and returns the rotated window.
/// A bordered-append breakdown clears the broken factor's slot in
/// `damped` — the caller refactors it from the patched Gram.
///
/// `window`/`gram` are replaced by their rotated versions; factors in
/// `damped` are `(factor_slot, extra_diagonal)` pairs rotated in place.
///
/// Cost note: the window/Gram are rebuilt into fresh buffers and each
/// factor round-trips through an [`UpdatableChol`] copy — O(nm + n²)
/// bytes of copy per rotation, deliberately traded for simplicity.
/// That is bandwidth-bound noise against the O(knm) patch FLOPs at the
/// bench shapes; if a profile ever shows otherwise, the fix is to keep
/// a persistent `UpdatableChol` (and ring-ordered window) in the
/// session, which the fixed-leading-dimension layout already supports.
pub(crate) fn rotate_gram_session(
    window: &mut Mat,
    gram: &mut Mat,
    damped: &mut [(&mut Option<Mat>, f64)],
    removed: &[usize],
    added: &Mat,
    cfg: KernelConfig,
) -> Result<(), SolveError> {
    let (n_old, m) = window.shape();
    let k_add = added.rows();
    if k_add > 0 && added.cols() != m {
        return Err(SolveError::BadInput(format!(
            "update_rows: added rows have {} columns, window has {m}",
            added.cols()
        )));
    }
    let mut rem: Vec<usize> = removed.to_vec();
    rem.sort_unstable();
    if rem.windows(2).any(|w| w[0] == w[1]) {
        return Err(SolveError::BadInput(
            "update_rows: duplicate removal index".to_string(),
        ));
    }
    if rem.last().is_some_and(|&r| r >= n_old) {
        return Err(SolveError::BadInput(format!(
            "update_rows: removal index {} out of range (window has {n_old} rows)",
            rem.last().unwrap()
        )));
    }
    let n_kept = n_old - rem.len();
    let n_new = n_kept + k_add;
    if n_new == 0 {
        return Err(SolveError::BadInput(
            "update_rows: rotation would leave an empty window".to_string(),
        ));
    }
    let kept: Vec<usize> = {
        let mut drop = vec![false; n_old];
        for &r in &rem {
            drop[r] = true;
        }
        (0..n_old).filter(|&i| !drop[i]).collect()
    };

    // Rotated window: kept rows in order, added rows at the end.
    let mut new_window = Mat::zeros(n_new, m);
    for (i, &oi) in kept.iter().enumerate() {
        new_window.row_mut(i).copy_from_slice(window.row(oi));
    }
    for j in 0..k_add {
        new_window.row_mut(n_kept + j).copy_from_slice(added.row(j));
    }

    // Patched Gram: kept entries copied, new cross/diagonal blocks from
    // panel products on the packed engine — O(knm + k²m), no SYRK.
    let mut new_gram = Mat::zeros(n_new, n_new);
    for (i, &oi) in kept.iter().enumerate() {
        let dst = new_gram.row_mut(i);
        let src = gram.row(oi);
        for (j, &oj) in kept.iter().enumerate() {
            dst[j] = src[oj];
        }
    }
    if k_add > 0 {
        let (cross, block) = cfg.run(|| {
            let mut cross = Mat::zeros(n_kept, k_add);
            if n_kept > 0 {
                let kept_rows = new_window.slice_rows(0, n_kept);
                gemm_nt_threaded(1.0, &kept_rows, added, 0.0, &mut cross, cfg.threads);
            }
            let mut block = Mat::zeros(k_add, k_add);
            gemm_nt_threaded(1.0, added, added, 0.0, &mut block, cfg.threads);
            (cross, block)
        });
        for i in 0..n_kept {
            for j in 0..k_add {
                new_gram[(i, n_kept + j)] = cross[(i, j)];
                new_gram[(n_kept + j, i)] = cross[(i, j)];
            }
        }
        for i in 0..k_add {
            for j in 0..k_add {
                new_gram[(n_kept + i, n_kept + j)] = block[(i, j)];
            }
        }
    }

    // Rotate each damped factor: deletes descending (indices stay
    // valid), then bordered appends reading the patched Gram columns.
    for (slot, extra) in damped.iter_mut() {
        let Some(mut l) = slot.take() else { continue };
        let mut upd = UpdatableChol::from_factor(&l, n_old.max(n_new));
        for &r in rem.iter().rev() {
            upd.delete_row(r);
        }
        let mut broke = false;
        for j in 0..k_add {
            let cur = n_kept + j;
            let col: Vec<f64> = (0..cur).map(|i| new_gram[(i, cur)]).collect();
            let diag = new_gram[(cur, cur)] + *extra;
            if upd.append_row(&col, diag, APPEND_REL_FLOOR).is_err() {
                broke = true;
                break;
            }
        }
        if broke {
            // Breakdown backstop: leave the slot empty — the caller
            // refactors it from the (exact) patched Gram.
            continue;
        }
        upd.write_to(&mut l);
        **slot = Some(l);
    }

    *window = new_window;
    *gram = new_gram;
    Ok(())
}

/// Mixed-precision session telemetry (PR 6) — thread-local, in the
/// style of [`kernel::counters`](crate::linalg::kernel::counters).
///
/// The fallback counter is the *observable* for the mixed-precision
/// escape hatches: an f32 overflow/subnormal Gram, an f32 factorization
/// breakdown, a stagnating refinement loop and a streaming rotation all
/// latch the session back onto the f64 path and bump it (pinned by
/// `rust/tests/precision.rs`).
pub mod mixed_counters {
    use std::cell::Cell;

    thread_local! {
        static FALLBACKS: Cell<u64> = const { Cell::new(0) };
        static FACTORS: Cell<u64> = const { Cell::new(0) };
        static REFINE_SWEEPS: Cell<u64> = const { Cell::new(0) };
    }

    /// Times a mixed-precision session on this thread fell back to the
    /// f64 path (overflow/subnormal Gram, f32 breakdown, refinement
    /// stagnation, streaming rotation).
    pub fn fallbacks() -> u64 {
        FALLBACKS.with(|c| c.get())
    }

    pub(crate) fn record_fallback() {
        FALLBACKS.with(|c| c.set(c.get() + 1));
    }

    /// Completed f32 factorizations.
    pub fn mixed_factors() -> u64 {
        FACTORS.with(|c| c.get())
    }

    pub(crate) fn record_mixed_factor() {
        FACTORS.with(|c| c.set(c.get() + 1));
    }

    /// Total refinement correction sweeps applied by converged mixed
    /// solves (a converged solve that needed no correction adds 0).
    pub fn refine_sweeps() -> u64 {
        REFINE_SWEEPS.with(|c| c.get())
    }

    pub(crate) fn record_refine_sweeps(k: u64) {
        REFINE_SWEEPS.with(|c| c.set(c.get() + k));
    }
}

use mixed_counters::{record_fallback, record_mixed_factor, record_refine_sweeps};

/// Iterative-refinement sweep budget. Each sweep contracts the error by
/// ≈κ(W)·u₃₂, so anything that converges at all converges well inside
/// this; the stagnation check below usually fires long before the cap.
const MAX_REFINE_SWEEPS: usize = 40;

/// A sweep must shrink the true residual by at least this factor or the
/// loop is declared stagnant (κ(W)·u₃₂ too close to 1) and the session
/// falls back to f64. Legitimate slow contractions near the κ ≈ 1e7
/// boundary sit around 0.3–0.6; beyond 0.7 the remaining sweeps would
/// be noise.
const STAGNATION_FACTOR: f64 = 0.7;

/// f32 state of a `solver.precision = mixed` chol session: the f32
/// score copy, un-damped f32 Gram, f64-accumulated Gram diagonal, and
/// the current f32 factor, plus persistent refinement scratch (the
/// solve hot path stays allocation-free once shapes are warm).
///
/// Numerics: the factorization carries f32 rounding (u₃₂ ≈ 6e-8), so a
/// single Woodbury pass through the f32 factor has relative error
/// O(κ(W)·u₃₂). Refinement against the **f64** matvec residual
/// `r = v − (SᵀS + λI)x` contracts that error by the same factor per
/// sweep, recovering full f64 accuracy whenever κ(W)·u₃₂ ≪ 1; the
/// stagnation check catches the other side of the boundary.
struct MixedState {
    tol: f64,
    /// Row-major n×m f32 copy of the score window.
    s32: Vec<f32>,
    /// Un-damped f32 Gram `S₃₂S₃₂ᵀ` (n×n).
    w32: Vec<f32>,
    /// diag(SSᵀ) accumulated in f64 — the damped diagonal
    /// `diag[i] + λ` is formed in f64 and rounded once, so the damping
    /// term is never lost to single-precision cancellation.
    diag: Vec<f64>,
    /// `Chol₃₂(W₃₂ + (diag+λ)Ĩ)` for the current λ (valid iff
    /// `factored`).
    l32: Vec<f32>,
    factored: bool,
    ready: bool,
    // Persistent scratch: n-sized f32/f64 solve vectors, m-sized
    // residual/correction vectors.
    un: Vec<f32>,
    zn: Vec<f64>,
    sx: Vec<f64>,
    rm: Vec<f64>,
    dm: Vec<f64>,
}

impl MixedState {
    fn new(tol: f64) -> Self {
        MixedState {
            tol,
            s32: Vec::new(),
            w32: Vec::new(),
            diag: Vec::new(),
            l32: Vec::new(),
            factored: false,
            ready: false,
            un: Vec::new(),
            zn: Vec::new(),
            sx: Vec::new(),
            rm: Vec::new(),
            dm: Vec::new(),
        }
    }

    /// Form the f32 score copy, the f32 Gram (threaded SYRK) and the
    /// f64 Gram diagonal. Returns `false` — recording a fallback — when
    /// the scores or the Gram overflow f32, or the Gram diagonal
    /// degenerates to subnormal/zero in f32 (either way the f32 factor
    /// would be meaningless). Call inside the session's kernel scope.
    fn prepare(&mut self, s: &Mat, threads: usize) -> bool {
        if self.ready {
            return true;
        }
        let (n, m) = s.shape();
        self.s32.clear();
        self.s32.extend(s.as_slice().iter().map(|&x| x as f32));
        if self.s32.iter().any(|x| !x.is_finite()) {
            record_fallback();
            return false;
        }
        self.w32.resize(n * n, 0.0);
        let MixedState { s32, w32, .. } = self;
        syrk_parallel_f32(s32, n, m, 0.0, w32, threads);
        self.diag.clear();
        self.diag.extend((0..n).map(|i| s.row(i).iter().map(|&x| x * x).sum::<f64>()));
        let bad = self.w32.iter().any(|x| !x.is_finite())
            || self.diag.iter().any(|&d| {
                d > f32::MAX as f64 || (d > 0.0 && (d as f32) < f32::MIN_POSITIVE)
            });
        if bad {
            record_fallback();
            return false;
        }
        self.ready = true;
        true
    }

    /// Factor `W₃₂ + (diag + λ)Ĩ` in f32. `false` (fallback recorded)
    /// on a damped diagonal outside f32 normal range or a Cholesky
    /// breakdown — a breakdown here may be an f32 artifact, so the
    /// caller retries in f64 rather than surfacing NPD directly.
    fn factor(&mut self, lambda: f64, n: usize) -> bool {
        debug_assert!(self.ready);
        self.factored = false;
        self.l32.clear();
        self.l32.extend_from_slice(&self.w32);
        for i in 0..n {
            let d = (self.diag[i] + lambda) as f32;
            if !d.is_finite() || d < f32::MIN_POSITIVE {
                record_fallback();
                return false;
            }
            self.l32[i * n + i] = d;
        }
        if cholesky_in_place_f32(&mut self.l32, n).is_err() {
            record_fallback();
            return false;
        }
        record_mixed_factor();
        self.factored = true;
        true
    }

    /// One Woodbury pass through the f32 factor:
    /// `out = (b − SᵀL₃₂⁻ᵀL₃₂⁻¹Sb)/λ ≈ (SᵀS + λI)⁻¹b`. The matvecs
    /// stay in f64; only the n-dimensional triangular solves run in
    /// f32.
    fn apply_inverse(&mut self, s: &Mat, lambda: f64, b: &[f64], out: &mut [f64]) {
        let n = s.rows();
        self.sx.resize(n, 0.0);
        s.matvec_into(b, &mut self.sx);
        self.un.clear();
        self.un.extend(self.sx.iter().map(|&x| x as f32));
        solve_lower_f32(&self.l32, n, &mut self.un);
        solve_lower_transpose_f32(&self.l32, n, &mut self.un);
        self.zn.clear();
        self.zn.extend(self.un.iter().map(|&x| x as f64));
        let MixedState { zn, .. } = self;
        s.t_matvec_into(zn, out);
        let inv = 1.0 / lambda;
        for (o, bj) in out.iter_mut().zip(b) {
            *o = inv * (bj - *o);
        }
    }

    /// Refine `x` in place against the **f64** true residual
    /// `r = v − λx − Sᵀ(Sx)` until `‖r‖ ≤ tol·‖v‖`. `false` (fallback
    /// recorded) on stagnation, a non-finite residual, or sweep-budget
    /// exhaustion.
    fn refine(&mut self, s: &Mat, lambda: f64, v: &[f64], x: &mut [f64]) -> bool {
        let (n, m) = s.shape();
        let vnorm = norm2(v).max(f64::MIN_POSITIVE);
        let mut prev = f64::INFINITY;
        self.rm.resize(m, 0.0);
        self.dm.resize(m, 0.0);
        for sweep in 0..MAX_REFINE_SWEEPS {
            self.sx.resize(n, 0.0);
            s.matvec_into(x, &mut self.sx);
            {
                let MixedState { sx, rm, .. } = self;
                s.t_matvec_into(sx, rm);
            }
            for j in 0..m {
                self.rm[j] = v[j] - lambda * x[j] - self.rm[j];
            }
            let rnorm = norm2(&self.rm);
            if !rnorm.is_finite() {
                record_fallback();
                return false;
            }
            if rnorm <= self.tol * vnorm {
                record_refine_sweeps(sweep as u64);
                return true;
            }
            if rnorm >= STAGNATION_FACTOR * prev {
                record_fallback();
                return false;
            }
            prev = rnorm;
            // d = Â⁻¹r through the f32 factor, then x ← x + d. The
            // residual/correction buffers move out for the call so
            // apply_inverse can reborrow the shared scratch —
            // allocation-free once warm.
            let rhs = std::mem::take(&mut self.rm);
            let mut d = std::mem::take(&mut self.dm);
            self.apply_inverse(s, lambda, &rhs, &mut d);
            for j in 0..m {
                x[j] += d[j];
            }
            self.rm = rhs;
            self.dm = d;
        }
        record_fallback();
        false
    }

    /// Full mixed solve: initial f32 Woodbury pass + refinement.
    fn solve_refined(&mut self, s: &Mat, lambda: f64, v: &[f64], x: &mut [f64]) -> bool {
        debug_assert!(self.factored);
        self.apply_inverse(s, lambda, v, x);
        self.refine(s, lambda, v, x)
    }
}

/// Mixed-precision solve of a cached **n×n** damped system
/// `(G + λI)u = f` where `G` is an f64 Gram the session holds anyway —
/// the rvb session's inner solve (its λ-independent recovery factor
/// needs the f64 Gram regardless, so only the damped factor and the
/// triangular solves move to f32 there). Residuals for refinement come
/// from the f64 `G·u` matvec directly (O(n²) per sweep); the same
/// κ·u₃₂ convergence condition and fallback rules as [`MixedState`]
/// apply.
pub(crate) struct MixedGramSolve {
    tol: f64,
    l32: Vec<f32>,
    factored: bool,
    un: Vec<f32>,
    gu: Vec<f64>,
    rn: Vec<f64>,
}

impl MixedGramSolve {
    pub(crate) fn new(tol: f64) -> Self {
        MixedGramSolve {
            tol,
            l32: Vec::new(),
            factored: false,
            un: Vec::new(),
            gu: Vec::new(),
            rn: Vec::new(),
        }
    }

    pub(crate) fn factored(&self) -> bool {
        self.factored
    }

    pub(crate) fn invalidate(&mut self) {
        self.factored = false;
    }

    /// Factor `G + λI` in f32; the damped diagonal is accumulated in
    /// f64 before the single rounding. `false` (fallback recorded) on
    /// f32 overflow/subnormal entries or a factorization breakdown.
    pub(crate) fn factor(&mut self, gram: &Mat, lambda: f64) -> bool {
        let n = gram.rows();
        self.factored = false;
        self.l32.clear();
        self.l32.extend(gram.as_slice().iter().map(|&x| x as f32));
        if self.l32.iter().any(|x| !x.is_finite()) {
            record_fallback();
            return false;
        }
        for i in 0..n {
            let d = (gram[(i, i)] + lambda) as f32;
            if !d.is_finite() || d < f32::MIN_POSITIVE {
                record_fallback();
                return false;
            }
            self.l32[i * n + i] = d;
        }
        if cholesky_in_place_f32(&mut self.l32, n).is_err() {
            record_fallback();
            return false;
        }
        record_mixed_factor();
        self.factored = true;
        true
    }

    /// Solve `(G + λI)u = f` through the f32 factor with f64
    /// refinement. `false` (fallback recorded) on stagnation.
    pub(crate) fn solve(&mut self, gram: &Mat, lambda: f64, f: &[f64], u: &mut [f64]) -> bool {
        debug_assert!(self.factored);
        let n = gram.rows();
        // Initial pass: u₀ = L₃₂⁻ᵀL₃₂⁻¹f.
        self.un.clear();
        self.un.extend(f.iter().map(|&x| x as f32));
        solve_lower_f32(&self.l32, n, &mut self.un);
        solve_lower_transpose_f32(&self.l32, n, &mut self.un);
        for (uj, &w) in u.iter_mut().zip(&self.un) {
            *uj = w as f64;
        }
        let fnorm = norm2(f).max(f64::MIN_POSITIVE);
        let mut prev = f64::INFINITY;
        self.gu.resize(n, 0.0);
        self.rn.resize(n, 0.0);
        for sweep in 0..MAX_REFINE_SWEEPS {
            {
                let MixedGramSolve { gu, .. } = self;
                gram.matvec_into(u, gu);
            }
            for i in 0..n {
                self.rn[i] = f[i] - lambda * u[i] - self.gu[i];
            }
            let rnorm = norm2(&self.rn);
            if !rnorm.is_finite() {
                record_fallback();
                return false;
            }
            if rnorm <= self.tol * fnorm {
                record_refine_sweeps(sweep as u64);
                return true;
            }
            if rnorm >= STAGNATION_FACTOR * prev {
                record_fallback();
                return false;
            }
            prev = rnorm;
            self.un.clear();
            self.un.extend(self.rn.iter().map(|&x| x as f32));
            solve_lower_f32(&self.l32, n, &mut self.un);
            solve_lower_transpose_f32(&self.l32, n, &mut self.un);
            for i in 0..n {
                u[i] += self.un[i] as f64;
            }
        }
        record_fallback();
        false
    }
}

/// Algorithm-1 solver ("chol").
#[derive(Debug, Clone)]
pub struct CholSolver {
    /// Worker threads for the whole dense pipeline: the Gram SYRK
    /// (line 1), the blocked Cholesky (line 2, lookahead-pipelined),
    /// and the multi-RHS TRSM + panel GEMMs of the session's
    /// `solve_many` (lines 3–4). 1 = serial (deterministic default);
    /// every threaded stage runs on the persistent kernel pool and is
    /// bit-identical to serial — the paper's parallelization strategy
    /// (shared with RVB+23) shards the Gram across devices; within one
    /// process we thread every stage so Amdahl's law does not cap the
    /// end-to-end solve at the SYRK fraction.
    pub threads: usize,
    /// ISA tier override for the dense pipeline (`solver.isa` plumbing,
    /// PR 4). `None` dispatches on the process tier; `Some(tier)`
    /// scopes every kernel this solver (and its sessions) runs to that
    /// tier — results are bit-identical across thread counts within
    /// the tier, only tolerance-equal across tiers.
    pub isa: Option<KernelIsa>,
    /// Factor/solve arithmetic (`solver.precision`, PR 6): `F64` is the
    /// seed path; `Mixed` runs the Gram, Cholesky and triangular solves
    /// in f32 and refines every right-hand side in f64 (see
    /// [`MixedState`]), falling back to f64 automatically when the f32
    /// path cannot deliver `tol`.
    pub precision: Precision,
    /// Relative true-residual target of the mixed-precision refinement
    /// (`solver.tol`); unused under `Precision::F64`.
    pub tol: f64,
}

impl Default for CholSolver {
    fn default() -> Self {
        CholSolver { threads: 1, isa: None, precision: Precision::F64, tol: 1e-10 }
    }
}

impl CholSolver {
    pub fn with_threads(threads: usize) -> Self {
        CholSolver { threads: threads.max(1), ..CholSolver::default() }
    }

    /// Construct from the shared kernel configuration (CLI / TOML /
    /// coordinator plumbing all funnel through [`KernelConfig`]).
    pub fn with_config(cfg: KernelConfig) -> Self {
        CholSolver { threads: cfg.threads.max(1), isa: cfg.isa, ..CholSolver::default() }
    }

    /// Select the factor/solve arithmetic (registry plumbing for
    /// `solver.precision` / `solver.tol`).
    pub fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.precision = precision;
        self.tol = tol;
        self
    }

    /// The kernel configuration this solver dispatches with.
    pub fn kernel_config(&self) -> KernelConfig {
        KernelConfig::with_threads(self.threads).with_isa(self.isa)
    }

    /// The raw factor `L = Chol(SSᵀ + λĨ)`. Prefer the session path
    /// ([`DampedSolver::factor`]) which additionally caches the un-damped
    /// Gram for λ-resweeps; this remains for call sites that want the
    /// triangular factor itself. (Named `gram_factor` so the session
    /// trait's `factor` is not shadowed on concrete solvers.)
    pub fn gram_factor(&self, s: &Mat, lambda: f64) -> Result<Mat, SolveError> {
        self.kernel_config().run(|| {
            let w = if self.threads > 1 {
                syrk_parallel(s, lambda, self.threads)
            } else {
                syrk(s, lambda)
            };
            Ok(cholesky_threaded(&w, self.threads)?)
        })
    }

    /// Apply Algorithm 1 line 4 given a precomputed factor `L`.
    pub fn solve_with_factor(&self, s: &Mat, l: &Mat, v: &[f64], lambda: f64) -> Vec<f64> {
        self.kernel_config().run(|| {
            // u = S v                       O(nm)
            let u = s.matvec(v);
            // y = L⁻¹ u,  z = L⁻ᵀ y         O(n²)
            let y = solve_lower(l, &u);
            let z = solve_lower_transpose(l, &y);
            // t = Sᵀ z                      O(nm)
            let t = s.t_matvec(&z);
            // x = (v − t)/λ
            let inv = 1.0 / lambda;
            v.iter().zip(&t).map(|(vi, ti)| inv * (vi - ti)).collect()
        })
    }
}

/// Session-native Algorithm-1 factorization: un-damped Gram cached across
/// λ-resweeps, preallocated O(n) scratch reused across right-hand sides.
///
/// Two ownership modes share the implementation (PR 5):
///
/// * **borrowed** ([`CholFactor::new`]) — the classic per-step session
///   against a caller-owned score matrix;
/// * **owned window** ([`CholFactor::from_window`], lifetime
///   `'static`) — the streaming session: the factor owns its sliding
///   window and rotates rows through [`Factorization::update_rows`]
///   (Gram patched with panel products, factor rotated in O(kn²) by
///   the [`chol_update`](crate::linalg::chol_update) primitives). A
///   borrowed session switches to an owned window automatically on its
///   first `update_rows` (one O(nm) clone).
pub struct CholFactor<'s> {
    /// Borrowed score matrix; `None` in owned-window mode.
    s: Option<&'s Mat>,
    /// Owned sliding window; populated in streaming mode.
    window: Option<Mat>,
    cfg: KernelConfig,
    lambda: f64,
    /// Cached `SSᵀ` (no damping) — computed once, λ-independent,
    /// patched (never re-formed) by window rotations.
    gram: Option<Mat>,
    /// `Chol(SSᵀ + λĨ)` for the current λ (the f64 path; `None` while
    /// the mixed-precision path is active).
    l: Option<Mat>,
    /// n-sized scratch for `u = Sv`.
    u: Vec<f64>,
    /// Factor/solve arithmetic (PR 6).
    precision: Precision,
    /// Mixed-refinement relative-residual target.
    tol: f64,
    /// f32 state when `precision == Mixed` and the f32 path is live.
    mixed: Option<MixedState>,
    /// Latched after any precision fallback: the session continues on
    /// the f64 path for its remaining lifetime.
    mixed_off: bool,
}

impl<'s> CholFactor<'s> {
    pub fn new(s: &'s Mat, cfg: KernelConfig) -> Self {
        CholFactor {
            s: Some(s),
            window: None,
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            lambda: 0.0,
            gram: None,
            l: None,
            u: vec![0.0; s.rows()],
            precision: Precision::F64,
            tol: 1e-10,
            mixed: None,
            mixed_off: false,
        }
    }

    /// Streaming session owning its score window (no borrow — can be
    /// held across training steps and rotated in place).
    pub fn from_window(window: Mat, cfg: KernelConfig) -> CholFactor<'static> {
        let rows = window.rows();
        CholFactor {
            s: None,
            window: Some(window),
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            lambda: 0.0,
            gram: None,
            l: None,
            u: vec![0.0; rows],
            precision: Precision::F64,
            tol: 1e-10,
            mixed: None,
            mixed_off: false,
        }
    }

    /// Select the factor/solve arithmetic (`solver.precision` /
    /// `solver.tol` plumbing).
    pub fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.precision = precision;
        self.tol = tol;
        self
    }

    /// Whether the session is currently running the f32 path.
    pub fn mixed_active(&self) -> bool {
        self.mixed_enabled()
    }

    fn mixed_enabled(&self) -> bool {
        self.precision == Precision::Mixed && !self.mixed_off
    }

    fn mixed_factored(&self) -> bool {
        self.mixed_enabled() && self.mixed.as_ref().is_some_and(|m| m.factored)
    }

    /// Drop the f32 state and latch the session onto the f64 path,
    /// building the f64 factor at the current λ so in-flight solves can
    /// continue. (Numeric fallbacks record their counter bump at the
    /// detection site; structural ones — streaming rotations — record
    /// it at the call site.)
    fn latch_f64(&mut self) -> Result<(), SolveError> {
        self.mixed = None;
        self.mixed_off = true;
        if self.lambda > 0.0 && self.l.is_none() {
            let cfg = self.cfg;
            let lambda = self.lambda;
            self.ensure_gram();
            match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
                Ok(l) => self.l = Some(l),
                Err(e) => {
                    self.lambda = 0.0;
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Blocked mixed multi-RHS solve: f64 panel GEMMs around the
    /// blocked **f32** TRSM pair, then per-row f64 refinement. `None`
    /// if any row's refinement stagnates — the caller latches f64 and
    /// re-solves the whole block.
    fn solve_many_mixed(&mut self, vs: &Mat) -> Option<Mat> {
        let CholFactor { s, window, mixed, cfg, lambda, .. } = self;
        let s: &Mat = match window.as_ref() {
            Some(w) => w,
            None => s.expect("session has a score matrix"),
        };
        let st = mixed.as_mut().expect("mixed_factored checked by caller");
        let (n, m) = s.shape();
        assert_eq!(vs.cols(), m, "each row of vs must be m-dimensional");
        let k = vs.rows();
        let threads = cfg.threads;
        let lambda = *lambda;
        cfg.run(|| {
            // U = S·Vᵀ (n×k, f64), cast once to f32.
            let mut u = Mat::zeros(n, k);
            gemm_nt_threaded(1.0, s, vs, 0.0, &mut u, threads);
            let mut u32: Vec<f32> = u.as_slice().iter().map(|&x| x as f32).collect();
            // Z = L₃₂⁻ᵀ(L₃₂⁻¹U) — the blocked f32 TRSM pair.
            fwd_multi_core_f32(&st.l32, n, n, &mut u32, k);
            bwd_multi_core_f32(&st.l32, n, n, &mut u32, k);
            let mut z = Mat::zeros(n, k);
            for (zd, &w) in z.as_mut_slice().iter_mut().zip(&u32) {
                *zd = w as f64;
            }
            // T = Sᵀ·Z (m×k).
            let mut t = Mat::zeros(m, k);
            gemm_tn_threaded(1.0, s, &z, 0.0, &mut t, threads);
            // X = (V − Tᵀ)/λ, each row refined in f64.
            let inv = 1.0 / lambda;
            let mut x = Mat::zeros(k, m);
            for r in 0..k {
                let vrow = vs.row(r);
                let xrow = x.row_mut(r);
                for j in 0..m {
                    xrow[j] = inv * (vrow[j] - t[(j, r)]);
                }
                if !st.refine(s, lambda, vrow, xrow) {
                    return None;
                }
            }
            Some(x)
        })
    }

    /// The active score matrix: the owned window when streaming, the
    /// borrowed matrix otherwise.
    pub fn score(&self) -> &Mat {
        match &self.window {
            Some(w) => w,
            None => self.s.expect("session has a score matrix"),
        }
    }

    /// The cached damped factor, if the session is currently damped
    /// (tests and the streaming bench compare it against a cold
    /// `gram_factor` of the rotated window).
    pub fn cached_factor(&self) -> Option<&Mat> {
        self.l.as_ref()
    }

    fn ensure_gram(&mut self) -> &Mat {
        if self.gram.is_none() {
            let threads = self.cfg.threads;
            let cfg = self.cfg;
            let s = match &self.window {
                Some(w) => w,
                None => self.s.expect("session has a score matrix"),
            };
            let g = cfg.run(|| {
                if threads > 1 {
                    syrk_parallel(s, 0.0, threads)
                } else {
                    syrk(s, 0.0)
                }
            });
            self.gram = Some(g);
        }
        self.gram.as_ref().unwrap()
    }
}

impl Factorization for CholFactor<'_> {
    fn name(&self) -> &'static str {
        "chol"
    }

    fn dim(&self) -> usize {
        self.score().cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        // Streaming fast path: a window rotation keeps the damped
        // factor current, so re-damping at the unchanged λ (the
        // trainer's per-step redamp) must not pay the O(n³) refactor.
        if lambda == self.lambda && (self.l.is_some() || self.mixed_factored()) {
            return Ok(());
        }
        let cfg = self.cfg;
        if self.mixed_enabled() {
            // Mixed path: f32 Gram (formed once, λ-independent) + f32
            // factor with the damped diagonal accumulated in f64. On
            // any f32 failure (fallback recorded inside MixedState)
            // the session latches onto the f64 path below.
            if self.mixed.is_none() {
                self.mixed = Some(MixedState::new(self.tol));
            }
            let ok = {
                let CholFactor { s, window, mixed, .. } = self;
                let s: &Mat = match window.as_ref() {
                    Some(w) => w,
                    None => s.expect("session has a score matrix"),
                };
                let st = mixed.as_mut().unwrap();
                let n = s.rows();
                cfg.run(|| st.prepare(s, cfg.threads) && st.factor(lambda, n))
            };
            if ok {
                self.l = None;
                self.lambda = lambda;
                return Ok(());
            }
            self.mixed = None;
            self.mixed_off = true;
        }
        self.ensure_gram();
        match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                // Gram stays cached: the caller's λ backoff retries in
                // O(n³) without re-touching S.
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        if self.mixed_factored() {
            let m = self.score().cols();
            assert_eq!(v.len(), m, "v must be m-dimensional");
            assert_eq!(x.len(), m, "x must be m-dimensional");
            let done = {
                let CholFactor { s, window, mixed, cfg, lambda, .. } = self;
                let s: &Mat = match window.as_ref() {
                    Some(w) => w,
                    None => s.expect("session has a score matrix"),
                };
                let st = mixed.as_mut().unwrap();
                let lambda = *lambda;
                cfg.run(|| st.solve_refined(s, lambda, v, x))
            };
            if done {
                return Ok(());
            }
            // Refinement stagnated (fallback recorded): latch onto the
            // f64 path and re-solve this RHS through the f64 factor.
            self.latch_f64()?;
        }
        let CholFactor { s, window, l, u, cfg, lambda, .. } = self;
        let s: &Mat = match window.as_ref() {
            Some(w) => w,
            None => s.expect("session has a score matrix"),
        };
        let m = s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let l = l.as_ref().ok_or_else(undamped_err)?;
        if u.len() != s.rows() {
            u.resize(s.rows(), 0.0);
        }
        cfg.run(|| {
            s.matvec_into(v, u);
            let y = solve_lower(l, u);
            let z = solve_lower_transpose(l, &y);
            s.t_matvec_into(&z, x);
        });
        let inv = 1.0 / *lambda;
        for (xj, vj) in x.iter_mut().zip(v) {
            *xj = inv * (vj - *xj);
        }
        Ok(())
    }

    /// Blocked multi-RHS Algorithm 1: one `S·Vᵀ` panel GEMM, the blocked
    /// TRSM pair, one `Sᵀ·Z` panel GEMM — O(n²k) at GEMM speed instead of
    /// k separate vector substitutions. Every stage partitions across
    /// the session's `threads` pool jobs (bit-identical to serial).
    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        if self.mixed_factored() {
            match self.solve_many_mixed(vs) {
                Some(x) => return Ok(x),
                // A row's refinement stagnated (fallback recorded):
                // latch f64 and re-solve the whole block below.
                None => self.latch_f64()?,
            }
        }
        let s = match &self.window {
            Some(w) => w,
            None => self.s.expect("session has a score matrix"),
        };
        let (n, m) = s.shape();
        assert_eq!(vs.cols(), m, "each row of vs must be m-dimensional");
        let l = self.l.as_ref().ok_or_else(undamped_err)?;
        let k = vs.rows();
        let threads = self.cfg.threads;
        let t = self.cfg.run(|| {
            // U = S·Vᵀ  (n×k)
            let mut u = Mat::zeros(n, k);
            gemm_nt_threaded(1.0, s, vs, 0.0, &mut u, threads);
            // Z = L⁻ᵀ(L⁻¹U) — the blocked TRSM pair, RHS columns paneled
            // across the pool.
            let y = solve_lower_multi_threaded(l, &u, threads);
            let z = solve_lower_transpose_multi_threaded(l, &y, threads);
            // T = Sᵀ·Z  (m×k)
            let mut t = Mat::zeros(m, k);
            gemm_tn_threaded(1.0, s, &z, 0.0, &mut t, threads);
            t
        });
        // X = (V − Tᵀ)/λ  (k×m, rows are solutions)
        let inv = 1.0 / self.lambda;
        let mut x = Mat::zeros(k, m);
        for r in 0..k {
            let vrow = vs.row(r);
            let xrow = x.row_mut(r);
            for j in 0..m {
                xrow[j] = inv * (vrow[j] - t[(j, r)]);
            }
        }
        Ok(x)
    }

    /// Streaming row rotation: O(knm) Gram patch + O(kn²) factor
    /// rotation, zero full-Gram SYRKs (pinned by a kernel-counter
    /// test). A bordered-append breakdown falls back to an O(n³)
    /// refactor of the patched Gram; only if that also breaks down does
    /// the error surface (and the session stays redampable at a larger
    /// λ — the usual Levenberg–Marquardt rescue).
    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        if self.mixed_enabled() {
            // Streaming rotations patch the f64 Gram and rotate the
            // f64 factor in O(kn²); the f32 path has no incremental
            // update, so the session latches onto f64 — counted as a
            // precision fallback so it is observable.
            record_fallback();
            self.latch_f64()?;
        }
        self.ensure_gram();
        if self.window.is_none() {
            // First rotation on a borrowed session: switch to an owned
            // window (one O(nm) clone, then never again).
            self.window = Some(self.s.expect("session has a score matrix").clone());
        }
        let cfg = self.cfg;
        let lambda = self.lambda;
        let window = self.window.as_mut().unwrap();
        let gram = self.gram.as_mut().unwrap();
        rotate_gram_session(
            window,
            gram,
            &mut [(&mut self.l, lambda)],
            removed,
            added,
            cfg,
        )?;
        if self.l.is_none() && lambda > 0.0 {
            // Rotation breakdown backstop: refactor the patched Gram.
            match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
                Ok(l) => self.l = Some(l),
                Err(e) => {
                    self.lambda = 0.0;
                    return Err(e);
                }
            }
        }
        self.u.resize(self.gram.as_ref().unwrap().rows(), 0.0);
        Ok(())
    }

    /// Streaming drift backstop: drop the patched Gram and rotated
    /// factor, recompute both from the current window from scratch.
    fn refresh(&mut self) -> Result<(), SolveError> {
        self.gram = None;
        self.l = None;
        // The f32 state re-forms from the live window on the next
        // redamp (mixed sessions that latched f64 stay latched).
        self.mixed = None;
        let lambda = self.lambda;
        self.lambda = 0.0;
        if !self.mixed_enabled() {
            self.ensure_gram();
        }
        if lambda > 0.0 {
            self.redamp(lambda)?;
        }
        Ok(())
    }
}

impl DampedSolver for CholSolver {
    fn name(&self) -> &'static str {
        "chol"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(
            CholFactor::new(s, self.kernel_config()).with_precision(self.precision, self.tol),
        )
    }

    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        Some(Box::new(
            CholFactor::from_window(window, self.kernel_config())
                .with_precision(self.precision, self.tol),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::qr::ridge_qr_oracle;
    use crate::solver::residual_norm;

    #[test]
    fn solves_normal_equations_exactly() {
        let mut rng = Rng::seed_from(110);
        for &(n, m, lambda) in &[
            (1usize, 1usize, 1.0f64),
            (2, 10, 0.5),
            (8, 100, 1e-2),
            (32, 500, 1e-3),
            (64, 64, 0.1), // square edge case (n = m)
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let r = residual_norm(&s, &x, &v, lambda);
            let vnorm = crate::linalg::mat::norm2(&v);
            assert!(r < 1e-8 * vnorm.max(1.0), "residual {r} at ({n},{m},λ={lambda})");
        }
    }

    #[test]
    fn matches_qr_oracle() {
        let mut rng = Rng::seed_from(111);
        let s = Mat::randn(12, 80, &mut rng);
        let v: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 0.07).unwrap();
        let oracle = ridge_qr_oracle(&s, &v, 0.07);
        for (a, b) in x.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let mut rng = Rng::seed_from(112);
        let s = Mat::randn(100, 700, &mut rng);
        let v: Vec<f64> = (0..700).map(|_| rng.normal()).collect();
        let serial = CholSolver::default().solve(&s, &v, 1e-3).unwrap();
        let par = CholSolver::with_threads(4).solve(&s, &v, 1e-3).unwrap();
        for (a, b) in serial.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn factor_reuse_across_rhs() {
        let mut rng = Rng::seed_from(113);
        let s = Mat::randn(16, 120, &mut rng);
        let solver = CholSolver::default();
        let l = solver.gram_factor(&s, 0.02).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
            let x = solver.solve_with_factor(&s, &l, &v, 0.02);
            assert!(residual_norm(&s, &x, &v, 0.02) < 1e-8);
        }
    }

    #[test]
    fn session_matches_one_shot_across_rhs_and_lambdas() {
        let mut rng = Rng::seed_from(117);
        let s = Mat::randn(20, 150, &mut rng);
        let solver = CholSolver::default();
        let mut fact = solver.factor(&s, 0.3).unwrap();
        for &lambda in &[0.3, 0.05, 1e-3] {
            fact.redamp(lambda).unwrap();
            for _ in 0..2 {
                let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
                let warm = fact.solve(&v).unwrap();
                let cold = solver.solve(&s, &v, lambda).unwrap();
                for (a, b) in warm.iter().zip(&cold) {
                    assert!((a - b).abs() < 1e-12, "λ={lambda}");
                }
            }
        }
    }

    #[test]
    fn rejects_nonpositive_lambda() {
        let mut rng = Rng::seed_from(114);
        let s = Mat::randn(3, 9, &mut rng);
        let v = vec![1.0; 9];
        assert!(matches!(
            CholSolver::default().solve(&s, &v, 0.0),
            Err(SolveError::BadInput(_))
        ));
        assert!(matches!(
            CholSolver::default().solve(&s, &v, -1.0),
            Err(SolveError::BadInput(_))
        ));
    }

    #[test]
    fn rank_deficient_s_is_fine_with_damping() {
        // n > rank: duplicate rows. SSᵀ singular but +λĨ saves it — this is
        // exactly the "damping becomes essential" claim of §1.
        let mut rng = Rng::seed_from(115);
        let mut s = Mat::randn(6, 50, &mut rng);
        let r0 = s.row(0).to_vec();
        s.row_mut(5).copy_from_slice(&r0);
        let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 1e-4).unwrap();
        assert!(residual_norm(&s, &x, &v, 1e-4) < 1e-6);
    }

    #[test]
    fn mixed_precision_session_matches_f64_without_falling_back() {
        let mut rng = Rng::seed_from(170);
        let (n, m) = (24usize, 160usize);
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let fb0 = mixed_counters::fallbacks();
        let mf0 = mixed_counters::mixed_factors();
        let solver = CholSolver::default().with_precision(Precision::Mixed, 1e-10);
        let mut fact = solver.factor(&s, 0.5).unwrap();
        for &lambda in &[0.5f64, 1e-2] {
            fact.redamp(lambda).unwrap();
            let x = fact.solve(&v).unwrap();
            let x64 = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let scale = crate::linalg::mat::norm2(&x64).max(1.0);
            for (a, b) in x.iter().zip(&x64) {
                assert!(
                    (a - b).abs() < 2e-10 * scale,
                    "mixed vs f64 at λ={lambda}: {a} vs {b}"
                );
            }
            assert!(residual_norm(&s, &x, &v, lambda) < 1e-9);
        }
        assert_eq!(mixed_counters::fallbacks(), fb0, "well-conditioned solve must not fall back");
        assert!(mixed_counters::mixed_factors() > mf0, "the f32 factor path must have run");
    }

    #[test]
    fn mixed_precision_multi_rhs_matches_f64() {
        let mut rng = Rng::seed_from(171);
        let (n, m, k) = (20usize, 120usize, 5usize);
        let s = Mat::randn(n, m, &mut rng);
        let vs = Mat::randn(k, m, &mut rng);
        let solver = CholSolver::default().with_precision(Precision::Mixed, 1e-10);
        let mut fact = solver.factor(&s, 0.1).unwrap();
        let x = fact.solve_many(&vs).unwrap();
        let mut f64_fact = CholSolver::default().factor(&s, 0.1).unwrap();
        let x64 = f64_fact.solve_many(&vs).unwrap();
        for r in 0..k {
            let scale = crate::linalg::mat::norm2(x64.row(r)).max(1.0);
            for (a, b) in x.row(r).iter().zip(x64.row(r)) {
                assert!((a - b).abs() < 2e-10 * scale, "row {r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tiny_lambda_still_accurate() {
        let mut rng = Rng::seed_from(116);
        let s = Mat::randn(10, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let lambda = 1e-10;
        let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
        // Relative residual stays small even at extreme damping ratios —
        // the x = (v − SᵀL⁻ᵀL⁻¹Sv)/λ form is stable because the numerator
        // lies in the λ-scaled complement.
        // κ(W) ≈ σ_max²/λ ≈ 10¹² here, so ~1e-4 relative residual is the
        // f64 floor; the point is no *catastrophic* loss of accuracy.
        let r = residual_norm(&s, &x, &v, lambda);
        let vnorm = crate::linalg::mat::norm2(&v);
        assert!(r < 1e-3 * vnorm, "residual {r}");
    }
}
