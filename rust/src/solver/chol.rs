//! **Algorithm 1** — the paper's contribution.
//!
//! ```text
//! Input: S (n×m), v (m), λ
//! 1: W ← SSᵀ + λĨ                  (SYRK, O(n²m))
//! 2: L ← Chol(W)                   (O(n³))
//! 3: Q ← L⁻¹S                      (NOT materialized — see below)
//! 4: x ← (v − QᵀQv)/λ
//! ```
//!
//! Following the paper's implementation note, line 3 is inlined into
//! line 4: `QᵀQv = SᵀL⁻ᵀL⁻¹Sv` is evaluated right-to-left as
//! matvec → forward solve → backward solve → transposed matvec, which
//! avoids the O(n²m) cost and O(nm) extra memory of forming `Q`.
//!
//! Since PR 2 the primary surface is the session path: [`CholFactor`]
//! caches the *un-damped* Gram `SSᵀ` so a λ-resweep (the optimizer's
//! Levenberg–Marquardt backoff) repeats only the O(n³) Cholesky — zero
//! Gram GEMMs, pinned by a kernel-counter test — and multi-RHS solves go
//! through the blocked TRSM instead of a loop of vector substitutions.

use super::session::{check_lambda, refactor_damped, undamped_err};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::chol_update::UpdatableChol;
use crate::linalg::gemm::{gemm_nt_threaded, gemm_tn_threaded, syrk, syrk_parallel};
use crate::linalg::{
    cholesky_threaded, solve_lower, solve_lower_multi_threaded, solve_lower_transpose,
    solve_lower_transpose_multi_threaded, KernelConfig, KernelIsa, Mat,
};

/// Relative pivot floor for the streaming bordered append: a pivot
/// `δ² ≤ 1e-10·d` is numerically meaningless after the O(n²) rotation
/// arithmetic, so the session treats it as a breakdown and falls back
/// to the full refactor of the patched Gram (which decides PD-ness with
/// the blocked factorization's own criterion). Legitimate damped pivots
/// sit at δ²/d ≳ λ/‖row‖², far above this floor for any λ a consumer
/// would run.
const APPEND_REL_FLOOR: f64 = 1e-10;

/// Shared streaming-rotation engine for the Gram-caching sessions
/// (`chol` here, `rvb` via re-use): validates the rotation, patches the
/// cached un-damped Gram with O(knm) panel products (zero full-Gram
/// SYRKs), rotates `damped` factors in O(kn²) (delete sweeps + bordered
/// appends at `lambda` extra diagonal), and returns the rotated window.
/// A bordered-append breakdown clears the broken factor's slot in
/// `damped` — the caller refactors it from the patched Gram.
///
/// `window`/`gram` are replaced by their rotated versions; factors in
/// `damped` are `(factor_slot, extra_diagonal)` pairs rotated in place.
///
/// Cost note: the window/Gram are rebuilt into fresh buffers and each
/// factor round-trips through an [`UpdatableChol`] copy — O(nm + n²)
/// bytes of copy per rotation, deliberately traded for simplicity.
/// That is bandwidth-bound noise against the O(knm) patch FLOPs at the
/// bench shapes; if a profile ever shows otherwise, the fix is to keep
/// a persistent `UpdatableChol` (and ring-ordered window) in the
/// session, which the fixed-leading-dimension layout already supports.
pub(crate) fn rotate_gram_session(
    window: &mut Mat,
    gram: &mut Mat,
    damped: &mut [(&mut Option<Mat>, f64)],
    removed: &[usize],
    added: &Mat,
    cfg: KernelConfig,
) -> Result<(), SolveError> {
    let (n_old, m) = window.shape();
    let k_add = added.rows();
    if k_add > 0 && added.cols() != m {
        return Err(SolveError::BadInput(format!(
            "update_rows: added rows have {} columns, window has {m}",
            added.cols()
        )));
    }
    let mut rem: Vec<usize> = removed.to_vec();
    rem.sort_unstable();
    if rem.windows(2).any(|w| w[0] == w[1]) {
        return Err(SolveError::BadInput(
            "update_rows: duplicate removal index".to_string(),
        ));
    }
    if rem.last().is_some_and(|&r| r >= n_old) {
        return Err(SolveError::BadInput(format!(
            "update_rows: removal index {} out of range (window has {n_old} rows)",
            rem.last().unwrap()
        )));
    }
    let n_kept = n_old - rem.len();
    let n_new = n_kept + k_add;
    if n_new == 0 {
        return Err(SolveError::BadInput(
            "update_rows: rotation would leave an empty window".to_string(),
        ));
    }
    let kept: Vec<usize> = {
        let mut drop = vec![false; n_old];
        for &r in &rem {
            drop[r] = true;
        }
        (0..n_old).filter(|&i| !drop[i]).collect()
    };

    // Rotated window: kept rows in order, added rows at the end.
    let mut new_window = Mat::zeros(n_new, m);
    for (i, &oi) in kept.iter().enumerate() {
        new_window.row_mut(i).copy_from_slice(window.row(oi));
    }
    for j in 0..k_add {
        new_window.row_mut(n_kept + j).copy_from_slice(added.row(j));
    }

    // Patched Gram: kept entries copied, new cross/diagonal blocks from
    // panel products on the packed engine — O(knm + k²m), no SYRK.
    let mut new_gram = Mat::zeros(n_new, n_new);
    for (i, &oi) in kept.iter().enumerate() {
        let dst = new_gram.row_mut(i);
        let src = gram.row(oi);
        for (j, &oj) in kept.iter().enumerate() {
            dst[j] = src[oj];
        }
    }
    if k_add > 0 {
        let (cross, block) = cfg.run(|| {
            let mut cross = Mat::zeros(n_kept, k_add);
            if n_kept > 0 {
                let kept_rows = new_window.slice_rows(0, n_kept);
                gemm_nt_threaded(1.0, &kept_rows, added, 0.0, &mut cross, cfg.threads);
            }
            let mut block = Mat::zeros(k_add, k_add);
            gemm_nt_threaded(1.0, added, added, 0.0, &mut block, cfg.threads);
            (cross, block)
        });
        for i in 0..n_kept {
            for j in 0..k_add {
                new_gram[(i, n_kept + j)] = cross[(i, j)];
                new_gram[(n_kept + j, i)] = cross[(i, j)];
            }
        }
        for i in 0..k_add {
            for j in 0..k_add {
                new_gram[(n_kept + i, n_kept + j)] = block[(i, j)];
            }
        }
    }

    // Rotate each damped factor: deletes descending (indices stay
    // valid), then bordered appends reading the patched Gram columns.
    for (slot, extra) in damped.iter_mut() {
        let Some(mut l) = slot.take() else { continue };
        let mut upd = UpdatableChol::from_factor(&l, n_old.max(n_new));
        for &r in rem.iter().rev() {
            upd.delete_row(r);
        }
        let mut broke = false;
        for j in 0..k_add {
            let cur = n_kept + j;
            let col: Vec<f64> = (0..cur).map(|i| new_gram[(i, cur)]).collect();
            let diag = new_gram[(cur, cur)] + *extra;
            if upd.append_row(&col, diag, APPEND_REL_FLOOR).is_err() {
                broke = true;
                break;
            }
        }
        if broke {
            // Breakdown backstop: leave the slot empty — the caller
            // refactors it from the (exact) patched Gram.
            continue;
        }
        upd.write_to(&mut l);
        **slot = Some(l);
    }

    *window = new_window;
    *gram = new_gram;
    Ok(())
}

/// Algorithm-1 solver ("chol").
#[derive(Debug, Clone)]
pub struct CholSolver {
    /// Worker threads for the whole dense pipeline: the Gram SYRK
    /// (line 1), the blocked Cholesky (line 2, lookahead-pipelined),
    /// and the multi-RHS TRSM + panel GEMMs of the session's
    /// `solve_many` (lines 3–4). 1 = serial (deterministic default);
    /// every threaded stage runs on the persistent kernel pool and is
    /// bit-identical to serial — the paper's parallelization strategy
    /// (shared with RVB+23) shards the Gram across devices; within one
    /// process we thread every stage so Amdahl's law does not cap the
    /// end-to-end solve at the SYRK fraction.
    pub threads: usize,
    /// ISA tier override for the dense pipeline (`solver.isa` plumbing,
    /// PR 4). `None` dispatches on the process tier; `Some(tier)`
    /// scopes every kernel this solver (and its sessions) runs to that
    /// tier — results are bit-identical across thread counts within
    /// the tier, only tolerance-equal across tiers.
    pub isa: Option<KernelIsa>,
}

impl Default for CholSolver {
    fn default() -> Self {
        CholSolver { threads: 1, isa: None }
    }
}

impl CholSolver {
    pub fn with_threads(threads: usize) -> Self {
        CholSolver { threads: threads.max(1), isa: None }
    }

    /// Construct from the shared kernel configuration (CLI / TOML /
    /// coordinator plumbing all funnel through [`KernelConfig`]).
    pub fn with_config(cfg: KernelConfig) -> Self {
        CholSolver { threads: cfg.threads.max(1), isa: cfg.isa }
    }

    /// The kernel configuration this solver dispatches with.
    pub fn kernel_config(&self) -> KernelConfig {
        KernelConfig::with_threads(self.threads).with_isa(self.isa)
    }

    /// The raw factor `L = Chol(SSᵀ + λĨ)`. Prefer the session path
    /// ([`DampedSolver::factor`]) which additionally caches the un-damped
    /// Gram for λ-resweeps; this remains for call sites that want the
    /// triangular factor itself. (Named `gram_factor` so the session
    /// trait's `factor` is not shadowed on concrete solvers.)
    pub fn gram_factor(&self, s: &Mat, lambda: f64) -> Result<Mat, SolveError> {
        self.kernel_config().run(|| {
            let w = if self.threads > 1 {
                syrk_parallel(s, lambda, self.threads)
            } else {
                syrk(s, lambda)
            };
            Ok(cholesky_threaded(&w, self.threads)?)
        })
    }

    /// Apply Algorithm 1 line 4 given a precomputed factor `L`.
    pub fn solve_with_factor(&self, s: &Mat, l: &Mat, v: &[f64], lambda: f64) -> Vec<f64> {
        self.kernel_config().run(|| {
            // u = S v                       O(nm)
            let u = s.matvec(v);
            // y = L⁻¹ u,  z = L⁻ᵀ y         O(n²)
            let y = solve_lower(l, &u);
            let z = solve_lower_transpose(l, &y);
            // t = Sᵀ z                      O(nm)
            let t = s.t_matvec(&z);
            // x = (v − t)/λ
            let inv = 1.0 / lambda;
            v.iter().zip(&t).map(|(vi, ti)| inv * (vi - ti)).collect()
        })
    }
}

/// Session-native Algorithm-1 factorization: un-damped Gram cached across
/// λ-resweeps, preallocated O(n) scratch reused across right-hand sides.
///
/// Two ownership modes share the implementation (PR 5):
///
/// * **borrowed** ([`CholFactor::new`]) — the classic per-step session
///   against a caller-owned score matrix;
/// * **owned window** ([`CholFactor::from_window`], lifetime
///   `'static`) — the streaming session: the factor owns its sliding
///   window and rotates rows through [`Factorization::update_rows`]
///   (Gram patched with panel products, factor rotated in O(kn²) by
///   the [`chol_update`](crate::linalg::chol_update) primitives). A
///   borrowed session switches to an owned window automatically on its
///   first `update_rows` (one O(nm) clone).
pub struct CholFactor<'s> {
    /// Borrowed score matrix; `None` in owned-window mode.
    s: Option<&'s Mat>,
    /// Owned sliding window; populated in streaming mode.
    window: Option<Mat>,
    cfg: KernelConfig,
    lambda: f64,
    /// Cached `SSᵀ` (no damping) — computed once, λ-independent,
    /// patched (never re-formed) by window rotations.
    gram: Option<Mat>,
    /// `Chol(SSᵀ + λĨ)` for the current λ.
    l: Option<Mat>,
    /// n-sized scratch for `u = Sv`.
    u: Vec<f64>,
}

impl<'s> CholFactor<'s> {
    pub fn new(s: &'s Mat, cfg: KernelConfig) -> Self {
        CholFactor {
            s: Some(s),
            window: None,
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            lambda: 0.0,
            gram: None,
            l: None,
            u: vec![0.0; s.rows()],
        }
    }

    /// Streaming session owning its score window (no borrow — can be
    /// held across training steps and rotated in place).
    pub fn from_window(window: Mat, cfg: KernelConfig) -> CholFactor<'static> {
        let rows = window.rows();
        CholFactor {
            s: None,
            window: Some(window),
            cfg: KernelConfig::with_threads(cfg.threads).with_isa(cfg.isa),
            lambda: 0.0,
            gram: None,
            l: None,
            u: vec![0.0; rows],
        }
    }

    /// The active score matrix: the owned window when streaming, the
    /// borrowed matrix otherwise.
    pub fn score(&self) -> &Mat {
        match &self.window {
            Some(w) => w,
            None => self.s.expect("session has a score matrix"),
        }
    }

    /// The cached damped factor, if the session is currently damped
    /// (tests and the streaming bench compare it against a cold
    /// `gram_factor` of the rotated window).
    pub fn cached_factor(&self) -> Option<&Mat> {
        self.l.as_ref()
    }

    fn ensure_gram(&mut self) -> &Mat {
        if self.gram.is_none() {
            let threads = self.cfg.threads;
            let cfg = self.cfg;
            let s = match &self.window {
                Some(w) => w,
                None => self.s.expect("session has a score matrix"),
            };
            let g = cfg.run(|| {
                if threads > 1 {
                    syrk_parallel(s, 0.0, threads)
                } else {
                    syrk(s, 0.0)
                }
            });
            self.gram = Some(g);
        }
        self.gram.as_ref().unwrap()
    }
}

impl Factorization for CholFactor<'_> {
    fn name(&self) -> &'static str {
        "chol"
    }

    fn dim(&self) -> usize {
        self.score().cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        // Streaming fast path: a window rotation keeps the damped
        // factor current, so re-damping at the unchanged λ (the
        // trainer's per-step redamp) must not pay the O(n³) refactor.
        if lambda == self.lambda && self.l.is_some() {
            return Ok(());
        }
        let cfg = self.cfg;
        self.ensure_gram();
        match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                // Gram stays cached: the caller's λ backoff retries in
                // O(n³) without re-touching S.
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let CholFactor { s, window, l, u, cfg, lambda, .. } = self;
        let s: &Mat = match window.as_ref() {
            Some(w) => w,
            None => s.expect("session has a score matrix"),
        };
        let m = s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let l = l.as_ref().ok_or_else(undamped_err)?;
        if u.len() != s.rows() {
            u.resize(s.rows(), 0.0);
        }
        cfg.run(|| {
            s.matvec_into(v, u);
            let y = solve_lower(l, u);
            let z = solve_lower_transpose(l, &y);
            s.t_matvec_into(&z, x);
        });
        let inv = 1.0 / *lambda;
        for (xj, vj) in x.iter_mut().zip(v) {
            *xj = inv * (vj - *xj);
        }
        Ok(())
    }

    /// Blocked multi-RHS Algorithm 1: one `S·Vᵀ` panel GEMM, the blocked
    /// TRSM pair, one `Sᵀ·Z` panel GEMM — O(n²k) at GEMM speed instead of
    /// k separate vector substitutions. Every stage partitions across
    /// the session's `threads` pool jobs (bit-identical to serial).
    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        let s = match &self.window {
            Some(w) => w,
            None => self.s.expect("session has a score matrix"),
        };
        let (n, m) = s.shape();
        assert_eq!(vs.cols(), m, "each row of vs must be m-dimensional");
        let l = self.l.as_ref().ok_or_else(undamped_err)?;
        let k = vs.rows();
        let threads = self.cfg.threads;
        let t = self.cfg.run(|| {
            // U = S·Vᵀ  (n×k)
            let mut u = Mat::zeros(n, k);
            gemm_nt_threaded(1.0, s, vs, 0.0, &mut u, threads);
            // Z = L⁻ᵀ(L⁻¹U) — the blocked TRSM pair, RHS columns paneled
            // across the pool.
            let y = solve_lower_multi_threaded(l, &u, threads);
            let z = solve_lower_transpose_multi_threaded(l, &y, threads);
            // T = Sᵀ·Z  (m×k)
            let mut t = Mat::zeros(m, k);
            gemm_tn_threaded(1.0, s, &z, 0.0, &mut t, threads);
            t
        });
        // X = (V − Tᵀ)/λ  (k×m, rows are solutions)
        let inv = 1.0 / self.lambda;
        let mut x = Mat::zeros(k, m);
        for r in 0..k {
            let vrow = vs.row(r);
            let xrow = x.row_mut(r);
            for j in 0..m {
                xrow[j] = inv * (vrow[j] - t[(j, r)]);
            }
        }
        Ok(x)
    }

    /// Streaming row rotation: O(knm) Gram patch + O(kn²) factor
    /// rotation, zero full-Gram SYRKs (pinned by a kernel-counter
    /// test). A bordered-append breakdown falls back to an O(n³)
    /// refactor of the patched Gram; only if that also breaks down does
    /// the error surface (and the session stays redampable at a larger
    /// λ — the usual Levenberg–Marquardt rescue).
    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        self.ensure_gram();
        if self.window.is_none() {
            // First rotation on a borrowed session: switch to an owned
            // window (one O(nm) clone, then never again).
            self.window = Some(self.s.expect("session has a score matrix").clone());
        }
        let cfg = self.cfg;
        let lambda = self.lambda;
        let window = self.window.as_mut().unwrap();
        let gram = self.gram.as_mut().unwrap();
        rotate_gram_session(
            window,
            gram,
            &mut [(&mut self.l, lambda)],
            removed,
            added,
            cfg,
        )?;
        if self.l.is_none() && lambda > 0.0 {
            // Rotation breakdown backstop: refactor the patched Gram.
            match cfg.run(|| refactor_damped(self.gram.as_ref().unwrap(), lambda, cfg.threads)) {
                Ok(l) => self.l = Some(l),
                Err(e) => {
                    self.lambda = 0.0;
                    return Err(e);
                }
            }
        }
        self.u.resize(self.gram.as_ref().unwrap().rows(), 0.0);
        Ok(())
    }

    /// Streaming drift backstop: drop the patched Gram and rotated
    /// factor, recompute both from the current window from scratch.
    fn refresh(&mut self) -> Result<(), SolveError> {
        self.gram = None;
        self.l = None;
        let lambda = self.lambda;
        self.lambda = 0.0;
        self.ensure_gram();
        if lambda > 0.0 {
            self.redamp(lambda)?;
        }
        Ok(())
    }
}

impl DampedSolver for CholSolver {
    fn name(&self) -> &'static str {
        "chol"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(CholFactor::new(s, self.kernel_config()))
    }

    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        Some(Box::new(CholFactor::from_window(window, self.kernel_config())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::linalg::qr::ridge_qr_oracle;
    use crate::solver::residual_norm;

    #[test]
    fn solves_normal_equations_exactly() {
        let mut rng = Rng::seed_from(110);
        for &(n, m, lambda) in &[
            (1usize, 1usize, 1.0f64),
            (2, 10, 0.5),
            (8, 100, 1e-2),
            (32, 500, 1e-3),
            (64, 64, 0.1), // square edge case (n = m)
        ] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
            let r = residual_norm(&s, &x, &v, lambda);
            let vnorm = crate::linalg::mat::norm2(&v);
            assert!(r < 1e-8 * vnorm.max(1.0), "residual {r} at ({n},{m},λ={lambda})");
        }
    }

    #[test]
    fn matches_qr_oracle() {
        let mut rng = Rng::seed_from(111);
        let s = Mat::randn(12, 80, &mut rng);
        let v: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 0.07).unwrap();
        let oracle = ridge_qr_oracle(&s, &v, 0.07);
        for (a, b) in x.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let mut rng = Rng::seed_from(112);
        let s = Mat::randn(100, 700, &mut rng);
        let v: Vec<f64> = (0..700).map(|_| rng.normal()).collect();
        let serial = CholSolver::default().solve(&s, &v, 1e-3).unwrap();
        let par = CholSolver::with_threads(4).solve(&s, &v, 1e-3).unwrap();
        for (a, b) in serial.iter().zip(&par) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn factor_reuse_across_rhs() {
        let mut rng = Rng::seed_from(113);
        let s = Mat::randn(16, 120, &mut rng);
        let solver = CholSolver::default();
        let l = solver.gram_factor(&s, 0.02).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
            let x = solver.solve_with_factor(&s, &l, &v, 0.02);
            assert!(residual_norm(&s, &x, &v, 0.02) < 1e-8);
        }
    }

    #[test]
    fn session_matches_one_shot_across_rhs_and_lambdas() {
        let mut rng = Rng::seed_from(117);
        let s = Mat::randn(20, 150, &mut rng);
        let solver = CholSolver::default();
        let mut fact = solver.factor(&s, 0.3).unwrap();
        for &lambda in &[0.3, 0.05, 1e-3] {
            fact.redamp(lambda).unwrap();
            for _ in 0..2 {
                let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
                let warm = fact.solve(&v).unwrap();
                let cold = solver.solve(&s, &v, lambda).unwrap();
                for (a, b) in warm.iter().zip(&cold) {
                    assert!((a - b).abs() < 1e-12, "λ={lambda}");
                }
            }
        }
    }

    #[test]
    fn rejects_nonpositive_lambda() {
        let mut rng = Rng::seed_from(114);
        let s = Mat::randn(3, 9, &mut rng);
        let v = vec![1.0; 9];
        assert!(matches!(
            CholSolver::default().solve(&s, &v, 0.0),
            Err(SolveError::BadInput(_))
        ));
        assert!(matches!(
            CholSolver::default().solve(&s, &v, -1.0),
            Err(SolveError::BadInput(_))
        ));
    }

    #[test]
    fn rank_deficient_s_is_fine_with_damping() {
        // n > rank: duplicate rows. SSᵀ singular but +λĨ saves it — this is
        // exactly the "damping becomes essential" claim of §1.
        let mut rng = Rng::seed_from(115);
        let mut s = Mat::randn(6, 50, &mut rng);
        let r0 = s.row(0).to_vec();
        s.row_mut(5).copy_from_slice(&r0);
        let v: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let x = CholSolver::default().solve(&s, &v, 1e-4).unwrap();
        assert!(residual_norm(&s, &x, &v, 1e-4) < 1e-6);
    }

    #[test]
    fn tiny_lambda_still_accurate() {
        let mut rng = Rng::seed_from(116);
        let s = Mat::randn(10, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let lambda = 1e-10;
        let x = CholSolver::default().solve(&s, &v, lambda).unwrap();
        // Relative residual stays small even at extreme damping ratios —
        // the x = (v − SᵀL⁻ᵀL⁻¹Sv)/λ form is stable because the numerator
        // lies in the λ-scaled complement.
        // κ(W) ≈ σ_max²/λ ≈ 10¹² here, so ~1e-4 relative residual is the
        // f64 floor; the point is no *catastrophic* loss of accuracy.
        let r = residual_norm(&s, &x, &v, lambda);
        let vnorm = crate::linalg::mat::norm2(&v);
        assert!(r < 1e-3 * vnorm, "residual {r}");
    }
}
