//! The `"eigh"` baseline — Appendix C, Eq. 5.
//!
//! Thin SVD `S = U Σ Vᵀ` obtained from the eigendecomposition of the n×n
//! Gram matrix (`SSᵀ = U Σ² Uᵀ`, `V = SᵀUΣ⁻¹`), then
//!
//! ```text
//! x = V (Σ² + λĨ)⁻¹ Vᵀ v + (v − V Vᵀ v)/λ
//! ```
//!
//! This was "previously the fastest method in our experience" (paper §2).
//! Its extra cost over Algorithm 1 is the O(n²m) formation of `V` plus a
//! second O(nm) pass through `S`, which is where the ~3× gap in Table 1
//! comes from.
//!
//! Session note (PR 2): the thin SVD is entirely λ-independent, so
//! [`SvdFactor`] (shared with the `svda` solver) computes it once and a
//! λ-resweep is *free* — Eq. 5 just re-evaluates with the new λ.

use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::svd::{svd_eigh_threaded, svd_jacobi, ThinSvd};
use crate::linalg::Mat;

/// Eigh-SVD solver ("eigh").
#[derive(Debug, Clone, Default)]
pub struct EighSolver {
    /// Kernel-pool jobs for the two O(n²m) passes of the SVD stage (the
    /// Gram SYRK and the `V = SᵀUΣ⁻¹` tall GEMM). 0/1 = serial; any
    /// count is bit-identical.
    pub threads: usize,
}

impl EighSolver {
    /// Eq. 5 applied to a precomputed thin SVD — shared with [`super::SvdaSolver`].
    pub(crate) fn apply_svd(
        svd: &crate::linalg::svd::ThinSvd,
        v: &[f64],
        lambda: f64,
    ) -> Vec<f64> {
        let n = svd.sigma.len();
        // w = Vᵀ v  (rows of vt are the right singular vectors)
        let w = svd.vt.matvec(v);
        // a_k = w_k / (σ_k² + λ)
        let a: Vec<f64> = (0..n)
            .map(|k| w[k] / (svd.sigma[k] * svd.sigma[k] + lambda))
            .collect();
        // x = V a + (v − V w)/λ   — two transposed matvecs through vt.
        let va = svd.vt.t_matvec(&a);
        let vw = svd.vt.t_matvec(&w);
        let inv = 1.0 / lambda;
        (0..v.len()).map(|j| va[j] + inv * (v[j] - vw[j])).collect()
    }
}

/// Which backend computes the thin SVD for an [`SvdFactor`] session.
pub(crate) enum SvdMethod {
    /// Gram eigendecomposition (the `"eigh"` path) with its O(n²m)
    /// passes split across `threads` kernel-pool jobs.
    Eigh { threads: usize },
    /// One-sided Jacobi with the modeled device budget (the `"svda"`
    /// path; the budget is checked before the sweeps run). The sweeps
    /// are rotation-sequential, so no thread count here.
    Jacobi { budget: super::MemoryBudget },
}

/// Session for the SVD-based baselines: the thin SVD is computed on the
/// first `redamp` and cached — it is λ-independent, so resweeps cost
/// nothing and every RHS is two O(nm) passes through `Vᵀ`.
pub struct SvdFactor<'s> {
    s: &'s Mat,
    method: SvdMethod,
    label: &'static str,
    lambda: f64,
    svd: Option<ThinSvd>,
}

impl<'s> SvdFactor<'s> {
    pub(crate) fn new(s: &'s Mat, method: SvdMethod, label: &'static str) -> Self {
        SvdFactor { s, method, label, lambda: 0.0, svd: None }
    }
}

impl Factorization for SvdFactor<'_> {
    fn name(&self) -> &'static str {
        self.label
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        if self.svd.is_none() {
            match &self.method {
                SvdMethod::Eigh { threads } => {
                    self.svd = Some(svd_eigh_threaded(self.s, (*threads).max(1)))
                }
                SvdMethod::Jacobi { budget } => {
                    let (n, m) = self.s.shape();
                    let required = super::memory_bytes(super::SolverKind::Svda, n, m);
                    if !budget.fits(required) {
                        return Err(SolveError::OutOfMemory {
                            required_bytes: required,
                            budget_bytes: budget.bytes(),
                        });
                    }
                    self.svd = Some(svd_jacobi(self.s));
                }
            }
        }
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let svd = self.svd.as_ref().ok_or_else(undamped_err)?;
        let r = EighSolver::apply_svd(svd, v, self.lambda);
        x.copy_from_slice(&r);
        Ok(())
    }
}

impl DampedSolver for EighSolver {
    fn name(&self) -> &'static str {
        "eigh"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(SvdFactor::new(s, SvdMethod::Eigh { threads: self.threads }, "eigh"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver, DampedSolver};

    #[test]
    fn matches_chol_on_random_problems() {
        let mut rng = Rng::seed_from(120);
        for &(n, m) in &[(2, 6), (10, 80), (24, 240)] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let xc = CholSolver::default().solve(&s, &v, 0.03).unwrap();
            let xe = EighSolver::default().solve(&s, &v, 0.03).unwrap();
            for (a, b) in xc.iter().zip(&xe) {
                assert!((a - b).abs() < 1e-7, "({n},{m})");
            }
        }
    }

    #[test]
    fn session_resweep_reuses_the_svd() {
        let mut rng = Rng::seed_from(123);
        let s = Mat::randn(8, 40, &mut rng);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let solver = EighSolver::default();
        let mut fact = solver.factor(&s, 0.5).unwrap();
        for &lambda in &[0.5, 0.05, 1e-3] {
            fact.redamp(lambda).unwrap();
            let warm = fact.solve(&v).unwrap();
            let cold = solver.solve(&s, &v, lambda).unwrap();
            for (a, b) in warm.iter().zip(&cold) {
                assert!((a - b).abs() < 1e-12, "λ={lambda}");
            }
        }
    }

    #[test]
    fn rank_deficient_projection_branch() {
        // With rank-deficient S, the (v − VVᵀv)/λ branch carries the
        // null-space component — this exercises the zeroed-σ rows of vt.
        let mut rng = Rng::seed_from(121);
        let mut s = Mat::randn(5, 40, &mut rng);
        let r0 = s.row(0).to_vec();
        s.row_mut(4).copy_from_slice(&r0);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let x = EighSolver::default().solve(&s, &v, 1e-3).unwrap();
        assert!(residual_norm(&s, &x, &v, 1e-3) < 1e-7);
    }

    #[test]
    fn pure_null_space_input_scales_by_inverse_lambda() {
        // If v ⊥ row-space(S) then x = v/λ exactly.
        let mut rng = Rng::seed_from(122);
        let s = Mat::randn(3, 20, &mut rng);
        let mut v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        // Project v onto the orthogonal complement of S's rows (Gram–Schmidt).
        let svd = crate::linalg::svd::svd_eigh(&s);
        let w = svd.vt.matvec(&v);
        let proj = svd.vt.t_matvec(&w);
        for j in 0..20 {
            v[j] -= proj[j];
        }
        let lambda = 0.25;
        let x = EighSolver::default().solve(&s, &v, lambda).unwrap();
        for (xi, vi) in x.iter().zip(&v) {
            assert!((xi - vi / lambda).abs() < 1e-9);
        }
    }
}
