//! The `"eigh"` baseline — Appendix C, Eq. 5.
//!
//! Thin SVD `S = U Σ Vᵀ` obtained from the eigendecomposition of the n×n
//! Gram matrix (`SSᵀ = U Σ² Uᵀ`, `V = SᵀUΣ⁻¹`), then
//!
//! ```text
//! x = V (Σ² + λĨ)⁻¹ Vᵀ v + (v − V Vᵀ v)/λ
//! ```
//!
//! This was "previously the fastest method in our experience" (paper §2).
//! Its extra cost over Algorithm 1 is the O(n²m) formation of `V` plus a
//! second O(nm) pass through `S`, which is where the ~3× gap in Table 1
//! comes from.

use super::{DampedSolver, SolveError};
use crate::linalg::svd::svd_eigh;
use crate::linalg::Mat;

/// Eigh-SVD solver ("eigh").
#[derive(Debug, Clone, Default)]
pub struct EighSolver;

impl EighSolver {
    /// Eq. 5 applied to a precomputed thin SVD — shared with [`super::SvdaSolver`].
    pub(crate) fn apply_svd(
        svd: &crate::linalg::svd::ThinSvd,
        v: &[f64],
        lambda: f64,
    ) -> Vec<f64> {
        let n = svd.sigma.len();
        // w = Vᵀ v  (rows of vt are the right singular vectors)
        let w = svd.vt.matvec(v);
        // a_k = w_k / (σ_k² + λ)
        let a: Vec<f64> = (0..n)
            .map(|k| w[k] / (svd.sigma[k] * svd.sigma[k] + lambda))
            .collect();
        // x = V a + (v − V w)/λ   — two transposed matvecs through vt.
        let va = svd.vt.t_matvec(&a);
        let vw = svd.vt.t_matvec(&w);
        let inv = 1.0 / lambda;
        (0..v.len()).map(|j| va[j] + inv * (v[j] - vw[j])).collect()
    }
}

impl DampedSolver for EighSolver {
    fn name(&self) -> &'static str {
        "eigh"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let svd = svd_eigh(s);
        Ok(Self::apply_svd(&svd, v, lambda))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver, DampedSolver};

    #[test]
    fn matches_chol_on_random_problems() {
        let mut rng = Rng::seed_from(120);
        for &(n, m) in &[(2, 6), (10, 80), (24, 240)] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let xc = CholSolver::default().solve(&s, &v, 0.03).unwrap();
            let xe = EighSolver.solve(&s, &v, 0.03).unwrap();
            for (a, b) in xc.iter().zip(&xe) {
                assert!((a - b).abs() < 1e-7, "({n},{m})");
            }
        }
    }

    #[test]
    fn rank_deficient_projection_branch() {
        // With rank-deficient S, the (v − VVᵀv)/λ branch carries the
        // null-space component — this exercises the zeroed-σ rows of vt.
        let mut rng = Rng::seed_from(121);
        let mut s = Mat::randn(5, 40, &mut rng);
        let r0 = s.row(0).to_vec();
        s.row_mut(4).copy_from_slice(&r0);
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let x = EighSolver.solve(&s, &v, 1e-3).unwrap();
        assert!(residual_norm(&s, &x, &v, 1e-3) < 1e-7);
    }

    #[test]
    fn pure_null_space_input_scales_by_inverse_lambda() {
        // If v ⊥ row-space(S) then x = v/λ exactly.
        let mut rng = Rng::seed_from(122);
        let s = Mat::randn(3, 20, &mut rng);
        let mut v: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        // Project v onto the orthogonal complement of S's rows (Gram–Schmidt).
        let svd = crate::linalg::svd::svd_eigh(&s);
        let w = svd.vt.matvec(&v);
        let proj = svd.vt.t_matvec(&w);
        for j in 0..20 {
            v[j] -= proj[j];
        }
        let lambda = 0.25;
        let x = EighSolver.solve(&s, &v, lambda).unwrap();
        for (xi, vi) in x.iter().zip(&v) {
            assert!((xi - vi / lambda).abs() < 1e-9);
        }
    }
}
