//! Conjugate-gradient baseline (§3).
//!
//! The paper notes iterative methods "scale linearly with both n and m,
//! but the number of iterations increases significantly when the matrix
//! is ill-conditioned". CG on `(SᵀS + λI)x = v` needs one `Sᵀ(S·)`
//! matvec pair per iteration — O(nm) — and √κ-ish iterations; the
//! `cg_conditioning` bench reproduces the blow-up while `chol` stays flat.

use super::{DampedSolver, SolveError};
use crate::linalg::mat::{dot, norm2};
use crate::linalg::Mat;
use std::sync::Mutex;

/// CG solver with convergence statistics.
#[derive(Debug)]
pub struct CgSolver {
    /// Relative-residual tolerance ‖r‖/‖v‖.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    last_stats: Mutex<CgStats>,
}

/// Convergence record of the most recent solve.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CgStats {
    pub iterations: usize,
    pub final_residual: f64,
}

impl Default for CgSolver {
    fn default() -> Self {
        CgSolver { tol: 1e-10, max_iters: 10_000, last_stats: Mutex::new(CgStats::default()) }
    }
}

impl CgSolver {
    pub fn new(tol: f64, max_iters: usize) -> Self {
        CgSolver { tol, max_iters, last_stats: Mutex::new(CgStats::default()) }
    }

    /// Stats from the last `solve` call.
    pub fn stats(&self) -> CgStats {
        *self.last_stats.lock().unwrap()
    }

    /// `(SᵀS + λI)·p` without forming the Fisher matrix.
    #[inline]
    fn fisher_apply(s: &Mat, p: &[f64], lambda: f64, out: &mut Vec<f64>) {
        let sp = s.matvec(p);
        *out = s.t_matvec(&sp);
        for (o, pi) in out.iter_mut().zip(p) {
            *o += lambda * pi;
        }
    }
}

impl DampedSolver for CgSolver {
    fn name(&self) -> &'static str {
        "cg"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let m = s.cols();
        let vnorm = norm2(v).max(f64::MIN_POSITIVE);
        let mut x = vec![0.0; m];
        let mut r = v.to_vec(); // r = v − A·0
        let mut p = r.clone();
        let mut rr = dot(&r, &r);
        let mut ap = Vec::new();

        for it in 0..self.max_iters {
            let rnorm = rr.sqrt();
            if rnorm <= self.tol * vnorm {
                *self.last_stats.lock().unwrap() =
                    CgStats { iterations: it, final_residual: rnorm / vnorm };
                return Ok(x);
            }
            Self::fisher_apply(s, &p, lambda, &mut ap);
            let alpha = rr / dot(&p, &ap);
            for j in 0..m {
                x[j] += alpha * p[j];
                r[j] -= alpha * ap[j];
            }
            let rr_new = dot(&r, &r);
            let beta = rr_new / rr;
            rr = rr_new;
            for j in 0..m {
                p[j] = r[j] + beta * p[j];
            }
        }
        let final_residual = rr.sqrt() / vnorm;
        *self.last_stats.lock().unwrap() =
            CgStats { iterations: self.max_iters, final_residual };
        if final_residual <= self.tol * 100.0 {
            // Close enough to be useful — return with stats recording the cap.
            Ok(x)
        } else {
            Err(SolveError::DidNotConverge { iterations: self.max_iters, residual: final_residual })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn converges_on_well_conditioned() {
        let mut rng = Rng::seed_from(150);
        let s = Mat::randn(10, 100, &mut rng);
        let v: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let cg = CgSolver::default();
        let x = cg.solve(&s, &v, 1.0).unwrap();
        assert!(residual_norm(&s, &x, &v, 1.0) < 1e-7);
        assert!(cg.stats().iterations > 0);
        assert!(cg.stats().iterations < 200);
    }

    #[test]
    fn matches_chol() {
        let mut rng = Rng::seed_from(151);
        let s = Mat::randn(8, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let xc = CholSolver::default().solve(&s, &v, 0.5).unwrap();
        let xg = CgSolver::default().solve(&s, &v, 0.5).unwrap();
        for (a, b) in xc.iter().zip(&xg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn iteration_count_grows_with_condition_number() {
        // Scale rows of S geometrically to control κ(SᵀS + λI); CG
        // iterations must grow markedly as λ shrinks — the §3 remark.
        let mut rng = Rng::seed_from(152);
        let n = 24;
        let mut s = Mat::randn(n, 150, &mut rng);
        for i in 0..n {
            let scale = 10f64.powf(i as f64 / (n - 1) as f64 * 3.0); // σ spread 1e3
            for x in s.row_mut(i) {
                *x *= scale;
            }
        }
        let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-10, 100_000);
        cg.solve(&s, &v, 1e-1).unwrap();
        let well = cg.stats().iterations;
        cg.solve(&s, &v, 1e-7).unwrap();
        let ill = cg.stats().iterations;
        assert!(
            ill > 2 * well,
            "expected iteration blow-up: well-damped {well} vs ill-damped {ill}"
        );
    }

    #[test]
    fn reports_nonconvergence() {
        let mut rng = Rng::seed_from(153);
        let s = Mat::randn(6, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-14, 1); // absurd cap
        match cg.solve(&s, &v, 1e-9) {
            Err(SolveError::DidNotConverge { iterations, .. }) => assert_eq!(iterations, 1),
            other => panic!("expected DidNotConverge, got {other:?}"),
        }
    }
}
