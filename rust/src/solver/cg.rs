//! Conjugate-gradient baseline (§3).
//!
//! The paper notes iterative methods "scale linearly with both n and m,
//! but the number of iterations increases significantly when the matrix
//! is ill-conditioned". CG on `(SᵀS + λI)x = v` needs one `Sᵀ(S·)`
//! matvec pair per iteration — O(nm) — and √κ-ish iterations; the
//! `cg_conditioning` bench reproduces the blow-up while `chol` stays flat.
//!
//! Session note (PR 2): CG has no separable factorization, so its
//! "factorization" is the captured iteration workspace ([`CgFactor`]):
//! the r/p/Ap/Sp buffers are allocated once and reused across every
//! right-hand side and λ-resweep — the allocation-free counterpart of the
//! Gram cache in the direct methods.

use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::mat::{dot, norm2};
use crate::linalg::Mat;
use std::sync::Mutex;

/// CG solver with convergence statistics.
#[derive(Debug)]
pub struct CgSolver {
    /// Relative-residual tolerance ‖r‖/‖v‖.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    last_stats: Mutex<CgStats>,
}

/// Convergence record of the most recent solve.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CgStats {
    pub iterations: usize,
    pub final_residual: f64,
}

impl Default for CgSolver {
    fn default() -> Self {
        CgSolver { tol: 1e-10, max_iters: 10_000, last_stats: Mutex::new(CgStats::default()) }
    }
}

impl CgSolver {
    pub fn new(tol: f64, max_iters: usize) -> Self {
        CgSolver { tol, max_iters, last_stats: Mutex::new(CgStats::default()) }
    }

    /// Stats from the last `solve` call.
    pub fn stats(&self) -> CgStats {
        *self.last_stats.lock().unwrap()
    }
}

/// CG session: preallocated Krylov workspace bound to one score matrix.
pub struct CgFactor<'s> {
    solver: &'s CgSolver,
    s: &'s Mat,
    lambda: f64,
    // Iteration workspace, sized once at session open.
    r: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    /// n-sized intermediate `S·p`.
    sp: Vec<f64>,
}

impl<'s> CgFactor<'s> {
    fn new(solver: &'s CgSolver, s: &'s Mat) -> Self {
        let (n, m) = s.shape();
        CgFactor {
            solver,
            s,
            lambda: 0.0,
            r: vec![0.0; m],
            p: vec![0.0; m],
            ap: vec![0.0; m],
            sp: vec![0.0; n],
        }
    }

    /// `ap = (SᵀS + λI)·p` without forming the Fisher matrix,
    /// allocation-free through the session buffers.
    fn fisher_apply(&mut self) {
        self.s.matvec_into(&self.p, &mut self.sp);
        self.s.t_matvec_into(&self.sp, &mut self.ap);
        for (o, pi) in self.ap.iter_mut().zip(&self.p) {
            *o += self.lambda * pi;
        }
    }
}

impl Factorization for CgFactor<'_> {
    fn name(&self) -> &'static str {
        "cg"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        let tol = self.solver.tol;
        let max_iters = self.solver.max_iters;
        let vnorm = norm2(v).max(f64::MIN_POSITIVE);
        x.fill(0.0);
        self.r.copy_from_slice(v); // r = v − A·0
        self.p.copy_from_slice(v);
        let mut rr = dot(&self.r, &self.r);

        for it in 0..max_iters {
            let rnorm = rr.sqrt();
            if rnorm <= tol * vnorm {
                *self.solver.last_stats.lock().unwrap() =
                    CgStats { iterations: it, final_residual: rnorm / vnorm };
                return Ok(());
            }
            self.fisher_apply();
            let alpha = rr / dot(&self.p, &self.ap);
            for j in 0..m {
                x[j] += alpha * self.p[j];
                self.r[j] -= alpha * self.ap[j];
            }
            let rr_new = dot(&self.r, &self.r);
            let beta = rr_new / rr;
            rr = rr_new;
            for j in 0..m {
                self.p[j] = self.r[j] + beta * self.p[j];
            }
        }
        let final_residual = rr.sqrt() / vnorm;
        *self.solver.last_stats.lock().unwrap() =
            CgStats { iterations: max_iters, final_residual };
        if final_residual <= tol * 100.0 {
            // Close enough to be useful — return with stats recording the cap.
            Ok(())
        } else {
            Err(SolveError::DidNotConverge { iterations: max_iters, residual: final_residual })
        }
    }
}

impl DampedSolver for CgSolver {
    fn name(&self) -> &'static str {
        "cg"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(CgFactor::new(self, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn converges_on_well_conditioned() {
        let mut rng = Rng::seed_from(150);
        let s = Mat::randn(10, 100, &mut rng);
        let v: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let cg = CgSolver::default();
        let x = cg.solve(&s, &v, 1.0).unwrap();
        assert!(residual_norm(&s, &x, &v, 1.0) < 1e-7);
        assert!(cg.stats().iterations > 0);
        assert!(cg.stats().iterations < 200);
    }

    #[test]
    fn matches_chol() {
        let mut rng = Rng::seed_from(151);
        let s = Mat::randn(8, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let xc = CholSolver::default().solve(&s, &v, 0.5).unwrap();
        let xg = CgSolver::default().solve(&s, &v, 0.5).unwrap();
        for (a, b) in xc.iter().zip(&xg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn session_reuses_workspace_across_rhs() {
        let mut rng = Rng::seed_from(154);
        let s = Mat::randn(9, 45, &mut rng);
        let cg = CgSolver::default();
        let mut fact = cg.factor(&s, 0.3).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..45).map(|_| rng.normal()).collect();
            let x = fact.solve(&v).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.3) < 1e-7);
            assert!(cg.stats().iterations > 0);
        }
        // λ-resweep through the same session.
        fact.redamp(0.01).unwrap();
        let v: Vec<f64> = (0..45).map(|_| rng.normal()).collect();
        let x = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.01) < 1e-7);
    }

    #[test]
    fn iteration_count_grows_with_condition_number() {
        // Scale rows of S geometrically to control κ(SᵀS + λI); CG
        // iterations must grow markedly as λ shrinks — the §3 remark.
        let mut rng = Rng::seed_from(152);
        let n = 24;
        let mut s = Mat::randn(n, 150, &mut rng);
        for i in 0..n {
            let scale = 10f64.powf(i as f64 / (n - 1) as f64 * 3.0); // σ spread 1e3
            for x in s.row_mut(i) {
                *x *= scale;
            }
        }
        let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-10, 100_000);
        cg.solve(&s, &v, 1e-1).unwrap();
        let well = cg.stats().iterations;
        cg.solve(&s, &v, 1e-7).unwrap();
        let ill = cg.stats().iterations;
        assert!(
            ill > 2 * well,
            "expected iteration blow-up: well-damped {well} vs ill-damped {ill}"
        );
    }

    #[test]
    fn reports_nonconvergence() {
        let mut rng = Rng::seed_from(153);
        let s = Mat::randn(6, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-14, 1); // absurd cap
        match cg.solve(&s, &v, 1e-9) {
            Err(SolveError::DidNotConverge { iterations, .. }) => assert_eq!(iterations, 1),
            other => panic!("expected DidNotConverge, got {other:?}"),
        }
    }
}
