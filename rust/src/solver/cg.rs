//! Conjugate-gradient baseline (§3).
//!
//! The paper notes iterative methods "scale linearly with both n and m,
//! but the number of iterations increases significantly when the matrix
//! is ill-conditioned". CG on `(SᵀS + λI)x = v` needs one `Sᵀ(S·)`
//! matvec pair per iteration — O(nm) — and √κ-ish iterations; the
//! `cg_conditioning` bench reproduces the blow-up while `chol` stays flat.
//!
//! Session note (PR 2): CG has no separable factorization, so its
//! "factorization" is the captured iteration workspace ([`CgFactor`]):
//! the r/p/Ap/Sp buffers are allocated once and reused across every
//! right-hand side and λ-resweep — the allocation-free counterpart of the
//! Gram cache in the direct methods.

use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, SolveError};
use crate::linalg::mat::{dot, norm2};
use crate::linalg::Mat;
use std::sync::Mutex;

/// CG solver with convergence statistics.
#[derive(Debug)]
pub struct CgSolver {
    /// Relative-residual tolerance ‖r‖/‖v‖.
    pub tol: f64,
    /// Iteration cap.
    pub max_iters: usize,
    /// Accept a solve that hits the iteration cap with a **true**
    /// residual within 100×`tol` instead of erroring. Off by default
    /// (PR-5 bugfix): the old unconditional leniency silently returned
    /// approximate solutions — and judged them by the *recurrence*
    /// residual, which drifts from the truth on long ill-conditioned
    /// runs. The gate now always measures ‖v − (SᵀS+λI)x‖ directly.
    pub loose_accept: bool,
    last_stats: Mutex<CgStats>,
}

/// Convergence record of the most recent solve.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CgStats {
    pub iterations: usize,
    /// Relative **true** residual ‖v − (SᵀS+λI)x‖/‖v‖ — recomputed from
    /// the iterate, never the recurrence estimate (PR-5 bugfix).
    pub final_residual: f64,
}

impl Default for CgSolver {
    fn default() -> Self {
        CgSolver::new(1e-10, 10_000)
    }
}

impl CgSolver {
    pub fn new(tol: f64, max_iters: usize) -> Self {
        CgSolver {
            tol,
            max_iters,
            loose_accept: false,
            last_stats: Mutex::new(CgStats::default()),
        }
    }

    /// Opt into accepting capped solves whose true residual is within
    /// 100×`tol` (the pre-PR-5 behaviour, now explicit).
    pub fn with_loose_accept(mut self, loose: bool) -> Self {
        self.loose_accept = loose;
        self
    }

    /// Stats from the most recently **completed** solve on any session
    /// of this solver. Live sessions no longer clobber each other
    /// (PR-5 bugfix): per-solve stats live on [`CgFactor::stats`]; this
    /// accessor keeps the "most recent" convenience view.
    pub fn stats(&self) -> CgStats {
        *self.last_stats.lock().unwrap()
    }

    /// Open a concrete CG session (the trait-object path is
    /// [`DampedSolver::begin`]); exposes the per-session
    /// [`CgFactor::stats`] without downcasting.
    pub fn session<'s>(&'s self, s: &'s Mat) -> CgFactor<'s> {
        CgFactor::new(self, s)
    }
}

/// CG session: preallocated Krylov workspace bound to one score matrix.
pub struct CgFactor<'s> {
    solver: &'s CgSolver,
    s: &'s Mat,
    lambda: f64,
    /// Per-session convergence record (PR-5 bugfix: previously one
    /// solver-level `Mutex<CgStats>` was shared by every live session,
    /// so two sessions clobbered each other's `stats()`).
    stats: CgStats,
    // Iteration workspace, sized once at session open.
    r: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    /// n-sized intermediate `S·p`.
    sp: Vec<f64>,
}

impl<'s> CgFactor<'s> {
    fn new(solver: &'s CgSolver, s: &'s Mat) -> Self {
        let (n, m) = s.shape();
        CgFactor {
            solver,
            s,
            lambda: 0.0,
            stats: CgStats::default(),
            r: vec![0.0; m],
            p: vec![0.0; m],
            ap: vec![0.0; m],
            sp: vec![0.0; n],
        }
    }

    /// Convergence record of this session's most recent solve.
    pub fn stats(&self) -> CgStats {
        self.stats
    }

    /// `ap = (SᵀS + λI)·p` without forming the Fisher matrix,
    /// allocation-free through the session buffers.
    fn fisher_apply(&mut self) {
        self.s.matvec_into(&self.p, &mut self.sp);
        self.s.t_matvec_into(&self.sp, &mut self.ap);
        for (o, pi) in self.ap.iter_mut().zip(&self.p) {
            *o += self.lambda * pi;
        }
    }

    /// Recompute the **true** residual `r = v − (SᵀS + λI)x` into the
    /// session's `r` buffer (overwriting the recurrence residual — the
    /// caller either returns or restarts from it) and return its norm.
    /// O(nm): one Fisher application through the session buffers.
    fn true_residual(&mut self, v: &[f64], x: &[f64]) -> f64 {
        self.s.matvec_into(x, &mut self.sp);
        self.s.t_matvec_into(&self.sp, &mut self.ap);
        let lambda = self.lambda;
        for j in 0..x.len() {
            self.r[j] = v[j] - self.ap[j] - lambda * x[j];
        }
        norm2(&self.r)
    }

    /// Record a finished solve on the session and mirror it to the
    /// solver-level "most recently completed" accessor.
    fn record(&mut self, iterations: usize, final_residual: f64) {
        self.stats = CgStats { iterations, final_residual };
        *self.solver.last_stats.lock().unwrap() = self.stats;
    }
}

impl Factorization for CgFactor<'_> {
    fn name(&self) -> &'static str {
        "cg"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        let tol = self.solver.tol;
        let max_iters = self.solver.max_iters;
        let vnorm = norm2(v).max(f64::MIN_POSITIVE);
        x.fill(0.0);
        self.r.copy_from_slice(v); // r = v − A·0
        self.p.copy_from_slice(v);
        let mut rr = dot(&self.r, &self.r);

        for it in 0..max_iters {
            let rnorm = rr.sqrt();
            if rnorm <= tol * vnorm {
                // The recurrence residual drifts from ‖v − Ax‖ on long
                // runs (PR-5 bugfix): verify against the true residual
                // before declaring convergence…
                let true_res = self.true_residual(v, x);
                if true_res <= tol * vnorm {
                    self.record(it, true_res / vnorm);
                    return Ok(());
                }
                // …and on drift, restart from the true residual (`r`
                // already holds it) — the standard residual-replacement
                // rescue, still bounded by the iteration cap.
                rr = dot(&self.r, &self.r);
                self.p.copy_from_slice(&self.r);
            }
            self.fisher_apply();
            let alpha = rr / dot(&self.p, &self.ap);
            for j in 0..m {
                x[j] += alpha * self.p[j];
                self.r[j] -= alpha * self.ap[j];
            }
            let rr_new = dot(&self.r, &self.r);
            let beta = rr_new / rr;
            rr = rr_new;
            for j in 0..m {
                self.p[j] = self.r[j] + beta * self.p[j];
            }
        }
        // Iteration cap: judge by the true residual, never the
        // recurrence estimate.
        let final_residual = self.true_residual(v, x) / vnorm;
        self.record(max_iters, final_residual);
        if final_residual <= tol {
            return Ok(());
        }
        if self.solver.loose_accept && final_residual <= tol * 100.0 {
            // Explicitly-requested leniency: close enough to be useful,
            // stats record the cap and the measured residual.
            return Ok(());
        }
        Err(SolveError::DidNotConverge { iterations: max_iters, residual: final_residual })
    }
}

impl DampedSolver for CgSolver {
    fn name(&self) -> &'static str {
        "cg"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(CgFactor::new(self, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::{residual_norm, CholSolver};

    #[test]
    fn converges_on_well_conditioned() {
        let mut rng = Rng::seed_from(150);
        let s = Mat::randn(10, 100, &mut rng);
        let v: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let cg = CgSolver::default();
        let x = cg.solve(&s, &v, 1.0).unwrap();
        assert!(residual_norm(&s, &x, &v, 1.0) < 1e-7);
        assert!(cg.stats().iterations > 0);
        assert!(cg.stats().iterations < 200);
    }

    #[test]
    fn matches_chol() {
        let mut rng = Rng::seed_from(151);
        let s = Mat::randn(8, 60, &mut rng);
        let v: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let xc = CholSolver::default().solve(&s, &v, 0.5).unwrap();
        let xg = CgSolver::default().solve(&s, &v, 0.5).unwrap();
        for (a, b) in xc.iter().zip(&xg) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn session_reuses_workspace_across_rhs() {
        let mut rng = Rng::seed_from(154);
        let s = Mat::randn(9, 45, &mut rng);
        let cg = CgSolver::default();
        let mut fact = cg.factor(&s, 0.3).unwrap();
        for _ in 0..3 {
            let v: Vec<f64> = (0..45).map(|_| rng.normal()).collect();
            let x = fact.solve(&v).unwrap();
            assert!(residual_norm(&s, &x, &v, 0.3) < 1e-7);
            assert!(cg.stats().iterations > 0);
        }
        // λ-resweep through the same session.
        fact.redamp(0.01).unwrap();
        let v: Vec<f64> = (0..45).map(|_| rng.normal()).collect();
        let x = fact.solve(&v).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.01) < 1e-7);
    }

    #[test]
    fn iteration_count_grows_with_condition_number() {
        // Scale rows of S geometrically to control κ(SᵀS + λI); CG
        // iterations must grow markedly as λ shrinks — the §3 remark.
        let mut rng = Rng::seed_from(152);
        let n = 24;
        let mut s = Mat::randn(n, 150, &mut rng);
        for i in 0..n {
            let scale = 10f64.powf(i as f64 / (n - 1) as f64 * 3.0); // σ spread 1e3
            for x in s.row_mut(i) {
                *x *= scale;
            }
        }
        let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-10, 100_000);
        cg.solve(&s, &v, 1e-1).unwrap();
        let well = cg.stats().iterations;
        cg.solve(&s, &v, 1e-7).unwrap();
        let ill = cg.stats().iterations;
        assert!(
            ill > 2 * well,
            "expected iteration blow-up: well-damped {well} vs ill-damped {ill}"
        );
    }

    #[test]
    fn reports_nonconvergence() {
        let mut rng = Rng::seed_from(153);
        let s = Mat::randn(6, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-14, 1); // absurd cap
        match cg.solve(&s, &v, 1e-9) {
            Err(SolveError::DidNotConverge { iterations, .. }) => assert_eq!(iterations, 1),
            other => panic!("expected DidNotConverge, got {other:?}"),
        }
    }

    #[test]
    fn reported_residual_is_the_true_residual() {
        // PR-5 bugfix: stats().final_residual must equal the directly
        // measured ‖v − (SᵀS+λI)x‖/‖v‖, not the recurrence estimate.
        let mut rng = Rng::seed_from(155);
        let s = Mat::randn(12, 90, &mut rng);
        let v: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let cg = CgSolver::new(1e-9, 10_000);
        let x = cg.solve(&s, &v, 0.05).unwrap();
        let measured = residual_norm(&s, &x, &v, 0.05)
            / crate::linalg::mat::norm2(&v);
        let reported = cg.stats().final_residual;
        assert!(
            (reported - measured).abs() <= 1e-12 + 1e-6 * measured,
            "reported {reported:.3e} vs measured {measured:.3e}"
        );
        assert!(reported <= 1e-9, "declared convergence must be true convergence");
    }

    #[test]
    fn cap_leniency_requires_explicit_loose_accept() {
        // An iteration budget too small to converge, with the tolerance
        // placed (from a probe measurement) so the capped residual sits
        // mid-band at ≈ 50×tol ∈ (tol, 100·tol]: strict mode must error
        // (PR-5 bugfix — the old code silently accepted anything within
        // the band), loose_accept restores the old behaviour explicitly.
        let mut rng = Rng::seed_from(156);
        let n = 24;
        let mut s = Mat::randn(n, 150, &mut rng);
        for i in 0..n {
            let scale = 10f64.powf(i as f64 / (n - 1) as f64 * 2.0);
            for x in s.row_mut(i) {
                *x *= scale;
            }
        }
        let v: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
        let cap = 30;
        // Probe: an unreachable tolerance makes the run cap out and
        // report the true residual the iterate actually achieved.
        let probe = CgSolver::new(1e-300, cap);
        assert!(matches!(
            probe.solve(&s, &v, 1e-4),
            Err(SolveError::DidNotConverge { .. })
        ));
        let res = probe.stats().final_residual;
        assert!(res > 0.0 && res.is_finite());
        let tol = res / 50.0;
        // Same cap, band-placed tolerance: strict rejects…
        let strict = CgSolver::new(tol, cap);
        match strict.solve(&s, &v, 1e-4) {
            Err(SolveError::DidNotConverge { iterations, residual }) => {
                assert_eq!(iterations, cap);
                assert!(
                    residual > tol && residual <= 100.0 * tol,
                    "residual {residual:.3e} left the leniency band (tol {tol:.3e})"
                );
            }
            other => panic!("strict mode must reject a mid-band capped solve, got {other:?}"),
        }
        // …and the explicit knob accepts, recording the cap + residual.
        let loose = CgSolver::new(tol, cap).with_loose_accept(true);
        loose.solve(&s, &v, 1e-4).expect("loose_accept must accept within 100×tol");
        assert_eq!(loose.stats().iterations, cap);
        assert!(loose.stats().final_residual <= 100.0 * tol);
        // The leniency stays bounded: 200× outside the band still errs.
        let far = CgSolver::new(res / 200.0, cap).with_loose_accept(true);
        assert!(matches!(
            far.solve(&s, &v, 1e-4),
            Err(SolveError::DidNotConverge { .. })
        ));
    }

    #[test]
    fn per_session_stats_do_not_clobber_each_other() {
        // PR-5 bugfix: two live sessions used to share one
        // Mutex<CgStats>; each must now keep its own record.
        let mut rng = Rng::seed_from(157);
        let s1 = Mat::randn(6, 40, &mut rng);
        let s2 = Mat::randn(30, 40, &mut rng);
        let cg = CgSolver::default();
        let mut f1 = cg.session(&s1);
        let mut f2 = cg.session(&s2);
        f1.redamp(1.0).unwrap();
        f2.redamp(1e-4).unwrap();
        let v: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut x = vec![0.0; 40];
        f1.solve_into(&v, &mut x).unwrap();
        let stats1 = f1.stats();
        // The second session's solve must not disturb the first's view.
        f2.solve_into(&v, &mut x).unwrap();
        assert_eq!(f1.stats(), stats1);
        assert_ne!(f1.stats(), f2.stats(), "distinct problems, distinct records");
        // The solver-level accessor tracks the most recently completed.
        assert_eq!(cg.stats(), f2.stats());
    }
}
