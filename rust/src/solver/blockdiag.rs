//! Block-diagonal structured Fisher sessions (PR 10).
//!
//! The K-FAC family of approximations replaces the full Fisher
//! `F = SᵀS` with its block-diagonal restriction: parameters are split
//! into contiguous groups (layers), cross-block curvature is dropped,
//! and each diagonal block `F_b = S_bᵀS_b` (where `S_b` is the column
//! shard of the score matrix over block `b`) is damped and solved
//! independently. For `k` equal blocks the factor cost falls from
//! O(n²m + n³) to k·O(n²·(m/k) + n³/3…) per-block Gram work — see
//! [`super::cost::flops_blocked`] — at the price of an *approximate*
//! solve whenever the true Fisher has cross-block mass (the gap the
//! paper's §1 "approximations like KFAC … often fall short" claim is
//! about; EXPERIMENTS.md §Structured quantifies it).
//!
//! The refactor here makes the approximation *compositional* instead of
//! a dead-end: [`BlockDiagFactor`] is a [`Factorization`] that owns one
//! inner per-block session (`chol` or `rvb`, chosen per block by the
//! cost model when [`BlockKind::Auto`]), so redamp caching, `solve_many`
//! panels, kernel threading, mixed precision, and `update_rows`
//! streaming rotation are all inherited from the inner sessions rather
//! than reimplemented. The key soundness fact for the `rvb` inner kind:
//! if the global right-hand side satisfies `v = Sᵀf`, then every block
//! slice satisfies `v_b = S_bᵀf` with the *same* f (column slicing
//! commutes with the transpose product), so the RVB precondition holds
//! blockwise exactly when it holds globally.
//!
//! [`BlockPartition`] is the validated partition vocabulary shared by
//! this session, the Kronecker-SVD session ([`super::kpsvd`]), and the
//! structured-preconditioned CG hybrid ([`super::hybrid`]).

use super::session::{check_lambda, undamped_err};
use super::{DampedSolver, Factorization, Precision, SolveError, SolverKind};
use crate::linalg::{KernelConfig, Mat};

/// A validated partition of the parameter axis `0..m` into contiguous
/// half-open column ranges `[c0, c1)` — the block structure every
/// structured solver kind shares. Construction is the only way to get
/// one, so holders never re-validate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockPartition {
    ranges: Vec<(usize, usize)>,
    m: usize,
}

impl BlockPartition {
    /// Validate `ranges` as a partition of `0..m`: non-empty, each
    /// range non-degenerate, contiguous (no gaps, no overlaps), first
    /// starting at 0 and last ending at `m`. Degenerate inputs are a
    /// hard [`SolveError::BadInput`] — never silently repaired.
    pub fn new(ranges: Vec<(usize, usize)>, m: usize) -> Result<BlockPartition, SolveError> {
        if m == 0 {
            return Err(SolveError::BadInput(
                "block partition over m = 0 parameters is degenerate".to_string(),
            ));
        }
        if ranges.is_empty() {
            return Err(SolveError::BadInput(
                "block partition must contain at least one range".to_string(),
            ));
        }
        let mut cursor = 0usize;
        for (i, &(c0, c1)) in ranges.iter().enumerate() {
            if c0 != cursor {
                return Err(SolveError::BadInput(format!(
                    "block {i} starts at {c0}, expected {cursor} (partition must be contiguous \
                     with no gaps or overlaps)"
                )));
            }
            if c1 <= c0 {
                return Err(SolveError::BadInput(format!(
                    "block {i} range [{c0}, {c1}) is empty"
                )));
            }
            cursor = c1;
        }
        if cursor != m {
            return Err(SolveError::BadInput(format!(
                "partition covers [0, {cursor}) but the parameter dimension is {m}"
            )));
        }
        Ok(BlockPartition { ranges, m })
    }

    /// `k` near-equal contiguous blocks over `0..m` (the first `m mod k`
    /// blocks get one extra column). `m == 0`, `k == 0` or `k > m` are
    /// hard [`SolveError::BadInput`]s — the seed `kfac.rs` silently
    /// clamped `k`, which hid mis-sized configs (PR 10 bugfix).
    pub fn uniform(m: usize, k: usize) -> Result<BlockPartition, SolveError> {
        if m == 0 {
            return Err(SolveError::BadInput(
                "block partition over m = 0 parameters is degenerate".to_string(),
            ));
        }
        if k == 0 {
            return Err(SolveError::BadInput(
                "solver.blocks must be ≥ 1 (0 blocks is degenerate)".to_string(),
            ));
        }
        if k > m {
            return Err(SolveError::BadInput(format!(
                "solver.blocks = {k} exceeds the parameter dimension m = {m} (every block \
                 needs at least one column)"
            )));
        }
        let base = m / k;
        let rem = m % k;
        let mut ranges = Vec::with_capacity(k);
        let mut c0 = 0usize;
        for b in 0..k {
            let width = base + usize::from(b < rem);
            ranges.push((c0, c0 + width));
            c0 += width;
        }
        Ok(BlockPartition { ranges, m })
    }

    /// The validated `[c0, c1)` column ranges, in order.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Number of blocks.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// Never true (validated partitions have ≥ 1 block); present for
    /// the `len`/`is_empty` pairing convention.
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// The parameter dimension this partition covers.
    pub fn m(&self) -> usize {
        self.m
    }
}

/// Resolve the partition the structured solvers share: an explicit
/// [`BlockPartition`] (verified against `m`) wins over the uniform
/// `solver.blocks` split; `blocks == 0` means one block (the exact
/// dense limit).
pub(crate) fn resolve_partition(
    explicit: Option<&BlockPartition>,
    blocks: usize,
    m: usize,
) -> Result<BlockPartition, SolveError> {
    match explicit {
        Some(p) if p.m() != m => Err(SolveError::BadInput(format!(
            "block partition was built for m = {}, score matrix has m = {m}",
            p.m()
        ))),
        Some(p) => Ok(p.clone()),
        None => BlockPartition::uniform(m, if blocks == 0 { 1 } else { blocks }),
    }
}

/// Which session kind backs each block of a [`BlockDiagFactor`]
/// (`solver.block_kind = auto|chol|rvb`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlockKind {
    /// Pick per block by the cost model ([`super::cost::flops`] of
    /// `chol` vs `rvb` at the block shape) — deterministic, and in
    /// practice `chol` (rvb adds the recovery solve on top of the same
    /// Gram pipeline).
    #[default]
    Auto,
    /// Force the Algorithm-1 chol session per block.
    Chol,
    /// Force the RVB session per block — valid only when the global
    /// right-hand side is `v = Sᵀf` (then `v_b = S_bᵀf` holds per
    /// block; anything else is rejected by the inner sessions).
    Rvb,
}

impl BlockKind {
    pub fn as_str(self) -> &'static str {
        match self {
            BlockKind::Auto => "auto",
            BlockKind::Chol => "chol",
            BlockKind::Rvb => "rvb",
        }
    }

    /// Parse a config/CLI spelling. `None` for unknown spellings (the
    /// caller renders the hard error with the known set).
    pub fn parse(s: &str) -> Option<BlockKind> {
        match s {
            "auto" => Some(BlockKind::Auto),
            "chol" => Some(BlockKind::Chol),
            "rvb" => Some(BlockKind::Rvb),
            _ => None,
        }
    }
}

impl std::fmt::Display for BlockKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The block-diagonal structured solver ("blockdiag"): one inner
/// chol/rvb session per partition block.
#[derive(Debug, Clone)]
pub struct BlockDiagSolver {
    cfg: KernelConfig,
    precision: Precision,
    tol: f64,
    rvb_tol: f64,
    blocks: usize,
    block_kind: BlockKind,
    partition: Option<BlockPartition>,
}

impl Default for BlockDiagSolver {
    fn default() -> Self {
        BlockDiagSolver {
            cfg: KernelConfig::with_threads(1),
            precision: Precision::F64,
            tol: 1e-10,
            rvb_tol: 1e-6,
            blocks: 0,
            block_kind: BlockKind::Auto,
            partition: None,
        }
    }
}

impl BlockDiagSolver {
    pub fn new() -> Self {
        BlockDiagSolver::default()
    }

    /// Kernel configuration (threads + ISA tier) handed to every inner
    /// block session — the dense stages of each block deal to the same
    /// worker pool as a plain chol session.
    pub fn with_config(cfg: KernelConfig) -> Self {
        BlockDiagSolver { cfg, ..BlockDiagSolver::default() }
    }

    /// Replace the kernel configuration, keeping every other option —
    /// the setter the hybrid solver's builder chain composes through.
    pub fn with_kernel(mut self, cfg: KernelConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Arithmetic mode for the inner sessions (mixed composes through
    /// the per-block chol/rvb factor + refinement loops unchanged).
    pub fn with_precision(mut self, precision: Precision, tol: f64) -> Self {
        self.precision = precision;
        self.tol = tol;
        self
    }

    /// RVB `v_b = S_bᵀf` reconstruction tolerance for rvb-backed blocks.
    pub fn with_recovery_tol(mut self, tol: f64) -> Self {
        self.rvb_tol = tol;
        self
    }

    /// Uniform block count (`solver.blocks`; 0 means one block — the
    /// exact dense session) and the per-block session kind.
    pub fn with_blocks(mut self, blocks: usize, block_kind: BlockKind) -> Self {
        self.blocks = blocks;
        self.block_kind = block_kind;
        self
    }

    /// Explicit (non-uniform) partition, e.g. real layer boundaries.
    /// Overrides `with_blocks`' uniform split.
    pub fn with_partition(mut self, partition: BlockPartition) -> Self {
        self.partition = Some(partition);
        self
    }

    /// Resolve the partition for parameter dimension `m`.
    pub(crate) fn partition_for(&self, m: usize) -> Result<BlockPartition, SolveError> {
        resolve_partition(self.partition.as_ref(), self.blocks, m)
    }

    /// The session kind actually used for a block of shape (n, m_b).
    fn resolve_kind(&self, n: usize, mb: usize) -> BlockKind {
        match self.block_kind {
            BlockKind::Auto => {
                if super::cost::flops(SolverKind::Chol, n, mb)
                    <= super::cost::flops(SolverKind::Rvb, n, mb)
                {
                    BlockKind::Chol
                } else {
                    BlockKind::Rvb
                }
            }
            k => k,
        }
    }

    /// Open one owned inner session on a block's column shard.
    fn inner_session(
        &self,
        n: usize,
        shard: Mat,
    ) -> Result<Box<dyn Factorization>, SolveError> {
        let mb = shard.cols();
        match self.resolve_kind(n, mb) {
            BlockKind::Rvb => super::RvbSolver::with_config(self.cfg)
                .with_recovery_tol(self.rvb_tol)
                .with_precision(self.precision, self.tol)
                .begin_window(shard)
                .ok_or_else(|| {
                    SolveError::BadInput("rvb has no owned-window session".to_string())
                }),
            _ => super::CholSolver::with_config(self.cfg)
                .with_precision(self.precision, self.tol)
                .begin_window(shard)
                .ok_or_else(|| {
                    SolveError::BadInput("chol has no owned-window session".to_string())
                }),
        }
    }

    /// Build the composite factor over `window` (owned — each inner
    /// session owns its column shard, so the factor is `'static`).
    pub(crate) fn open_window(&self, window: &Mat) -> BlockDiagFactor {
        match self.try_open(window) {
            Ok(fact) => fact,
            Err(e) => BlockDiagFactor {
                ranges: Vec::new(),
                inners: Vec::new(),
                m: window.cols(),
                lambda: 0.0,
                poisoned: Some(e),
            },
        }
    }

    fn try_open(&self, window: &Mat) -> Result<BlockDiagFactor, SolveError> {
        let partition = self.partition_for(window.cols())?;
        let mut inners = Vec::with_capacity(partition.len());
        for &(c0, c1) in partition.ranges() {
            inners.push(self.inner_session(window.rows(), window.slice_cols(c0, c1))?);
        }
        Ok(BlockDiagFactor {
            ranges: partition.ranges().to_vec(),
            inners,
            m: partition.m(),
            lambda: 0.0,
            poisoned: None,
        })
    }
}

impl DampedSolver for BlockDiagSolver {
    fn name(&self) -> &'static str {
        "blockdiag"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(self.open_window(s))
    }

    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        Some(Box::new(self.open_window(&window)))
    }
}

/// A staged block-diagonal factorization: one inner [`Factorization`]
/// per partition block, each owning its column shard of the window.
/// With a single block this is *bit-identical* to the plain chol
/// session (same bytes, same kernel configuration, same arithmetic) on
/// factor, λ-resweep, `solve_many`, and streaming rotation — pinned by
/// `rust/tests/structured.rs`.
///
/// `begin` cannot fail by trait contract, so a degenerate partition
/// poisons the factor instead: every later call surfaces the stored
/// [`SolveError::BadInput`].
pub struct BlockDiagFactor {
    ranges: Vec<(usize, usize)>,
    inners: Vec<Box<dyn Factorization>>,
    m: usize,
    lambda: f64,
    poisoned: Option<SolveError>,
}

impl BlockDiagFactor {
    fn check_poisoned(&self) -> Result<(), SolveError> {
        match &self.poisoned {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Number of blocks.
    pub fn blocks(&self) -> usize {
        self.inners.len()
    }
}

impl Factorization for BlockDiagFactor {
    fn name(&self) -> &'static str {
        "blockdiag"
    }

    fn dim(&self) -> usize {
        self.m
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        self.check_poisoned()?;
        check_lambda(lambda)?;
        // Each inner redamp is the O(n³) refactor of that block's
        // cached Gram — zero Gram GEMMs, like every other session. On a
        // mid-sweep breakdown `self.lambda` stays put; the λ-backoff
        // retry re-damps every block (inner redamp is idempotent).
        for inner in &mut self.inners {
            inner.redamp(lambda)?;
        }
        self.lambda = lambda;
        Ok(())
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        self.check_poisoned()?;
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        assert_eq!(v.len(), self.m, "v must be m-dimensional");
        assert_eq!(x.len(), self.m, "x must be m-dimensional");
        for (b, &(c0, c1)) in self.ranges.iter().enumerate() {
            self.inners[b].solve_into(&v[c0..c1], &mut x[c0..c1])?;
        }
        Ok(())
    }

    fn solve_many(&mut self, vs: &Mat) -> Result<Mat, SolveError> {
        self.check_poisoned()?;
        if self.lambda <= 0.0 {
            return Err(undamped_err());
        }
        assert_eq!(vs.cols(), self.m, "each row of vs must be m-dimensional");
        let mut x = Mat::zeros(vs.rows(), vs.cols());
        // Per block: slice the RHS panel and run the inner session's
        // blocked multi-RHS path (panel GEMMs + TRSM), then scatter the
        // block solution back into the global panel.
        for (b, &(c0, c1)) in self.ranges.iter().enumerate() {
            let vb = vs.slice_cols(c0, c1);
            let xb = self.inners[b].solve_many(&vb)?;
            for r in 0..vs.rows() {
                x.row_mut(r)[c0..c1].copy_from_slice(xb.row(r));
            }
        }
        Ok(x)
    }

    fn update_rows(&mut self, removed: &[usize], added: &Mat) -> Result<(), SolveError> {
        self.check_poisoned()?;
        assert_eq!(added.cols(), self.m, "added rows must be m-dimensional");
        // Row rotation commutes with column slicing: rotate each inner
        // window with the matching column shard of the added rows. The
        // inner sessions do the O(knm_b + kn²) Gram patch + factor
        // rotation natively.
        for (b, &(c0, c1)) in self.ranges.iter().enumerate() {
            self.inners[b].update_rows(removed, &added.slice_cols(c0, c1))?;
        }
        Ok(())
    }

    fn refresh(&mut self) -> Result<(), SolveError> {
        self.check_poisoned()?;
        for inner in &mut self.inners {
            inner.refresh()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn partition_validates_hard() {
        assert!(matches!(BlockPartition::new(vec![], 4), Err(SolveError::BadInput(_))));
        assert!(matches!(BlockPartition::new(vec![(0, 4)], 0), Err(SolveError::BadInput(_))));
        // Gap, overlap, short coverage, empty range — all hard errors.
        assert!(BlockPartition::new(vec![(0, 2), (3, 4)], 4).is_err());
        assert!(BlockPartition::new(vec![(0, 3), (2, 4)], 4).is_err());
        assert!(BlockPartition::new(vec![(0, 2)], 4).is_err());
        assert!(BlockPartition::new(vec![(0, 2), (2, 2), (2, 4)], 4).is_err());
        assert!(BlockPartition::new(vec![(1, 4)], 4).is_err());
        let p = BlockPartition::new(vec![(0, 2), (2, 4)], 4).unwrap();
        assert_eq!(p.ranges(), &[(0, 2), (2, 4)]);
        assert_eq!((p.len(), p.m()), (2, 4));
    }

    #[test]
    fn uniform_split_matches_seed_shape_and_rejects_degenerate() {
        // The seed kfac.rs split: first m % k blocks get the extra column.
        let p = BlockPartition::uniform(10, 3).unwrap();
        assert_eq!(p.ranges(), &[(0, 4), (4, 7), (7, 10)]);
        assert_eq!(BlockPartition::uniform(8, 1).unwrap().ranges(), &[(0, 8)]);
        // No silent clamping (the PR-10 bugfix): degenerate is an error.
        assert!(matches!(BlockPartition::uniform(0, 2), Err(SolveError::BadInput(_))));
        assert!(matches!(BlockPartition::uniform(8, 0), Err(SolveError::BadInput(_))));
        assert!(matches!(BlockPartition::uniform(3, 5), Err(SolveError::BadInput(_))));
    }

    #[test]
    fn block_kind_parse_roundtrip() {
        for k in [BlockKind::Auto, BlockKind::Chol, BlockKind::Rvb] {
            assert_eq!(BlockKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(BlockKind::parse("kfac"), None);
        assert_eq!(BlockKind::default(), BlockKind::Auto);
    }

    #[test]
    fn mismatched_partition_poisons_the_session() {
        let mut rng = Rng::seed_from(1001);
        let s = Mat::randn(6, 20, &mut rng);
        let solver = BlockDiagSolver::new()
            .with_partition(BlockPartition::uniform(16, 2).unwrap());
        let mut fact = solver.begin(&s);
        assert!(matches!(fact.redamp(0.1), Err(SolveError::BadInput(_))));
        let v = vec![1.0; 20];
        let mut x = vec![0.0; 20];
        assert!(matches!(fact.solve_into(&v, &mut x), Err(SolveError::BadInput(_))));
    }

    #[test]
    fn blockwise_solve_matches_independent_chol_blocks() {
        let mut rng = Rng::seed_from(1002);
        let (n, m, k) = (8usize, 24usize, 3usize);
        let s = Mat::randn(n, m, &mut rng);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let lambda = 0.3;
        let solver = BlockDiagSolver::new().with_blocks(k, BlockKind::Chol);
        let x = solver.solve(&s, &v, lambda).unwrap();
        let part = BlockPartition::uniform(m, k).unwrap();
        for &(c0, c1) in part.ranges() {
            let sb = s.slice_cols(c0, c1);
            let xb = super::super::CholSolver::default()
                .solve(&sb, &v[c0..c1], lambda)
                .unwrap();
            for (a, b) in x[c0..c1].iter().zip(&xb) {
                assert!((a - b).abs() < 1e-12, "block [{c0},{c1}) differs");
            }
        }
    }
}
