//! Naive direct solver — the O(m³) reference the paper's complexity
//! analysis compares against (§2): form the m×m matrix `SᵀS + λI` and
//! Cholesky-solve it. Exact but "beyond capability" at the paper's scale
//! (m ~ 10⁶ ⇒ 8 TB for the matrix alone), so it carries the same
//! [`MemoryBudget`] model as svda and refuses paper-scale shapes.

use super::cost::{memory_bytes, MemoryBudget};
use super::{DampedSolver, SolveError, SolverKind};
use crate::linalg::{cholesky, gemm::gemm_tn, solve_lower, solve_lower_transpose, Mat};

/// Direct m×m solver.
#[derive(Debug, Clone)]
pub struct NaiveSolver {
    pub budget: MemoryBudget,
}

impl Default for NaiveSolver {
    fn default() -> Self {
        NaiveSolver { budget: MemoryBudget::a100_80gb() }
    }
}

impl DampedSolver for NaiveSolver {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        assert_eq!(v.len(), s.cols());
        if lambda <= 0.0 {
            return Err(SolveError::BadInput(format!("damping λ must be > 0, got {lambda}")));
        }
        let (n, m) = s.shape();
        let required = memory_bytes(SolverKind::Naive, n, m);
        if !self.budget.fits(required) {
            return Err(SolveError::OutOfMemory {
                required_bytes: required,
                budget_bytes: self.budget.bytes(),
            });
        }
        // F = SᵀS + λI  (m×m — the whole point of the paper is avoiding this)
        let mut f = Mat::zeros(m, m);
        gemm_tn(1.0, s, s, 0.0, &mut f);
        f.add_diag(lambda);
        let l = cholesky(&f)?;
        let y = solve_lower(&l, v);
        Ok(solve_lower_transpose(&l, &y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::residual_norm;

    #[test]
    fn exact_on_small_problems() {
        let mut rng = Rng::seed_from(140);
        let s = Mat::randn(5, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let x = NaiveSolver::default().solve(&s, &v, 0.5).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.5) < 1e-9);
    }

    #[test]
    fn refuses_paper_scale() {
        // m = 10⁶ ⇒ SᵀS alone is 8 TB; must OOM, not grind.
        let budget = MemoryBudget::a100_80gb();
        assert!(!budget.fits(memory_bytes(SolverKind::Naive, 1000, 1_000_000)));
    }

    #[test]
    fn works_without_data_rows_dominating() {
        // n = 1 extreme: rank-1 Fisher + damping.
        let mut rng = Rng::seed_from(141);
        let s = Mat::randn(1, 12, &mut rng);
        let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let x = NaiveSolver::default().solve(&s, &v, 0.1).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.1) < 1e-10);
    }
}
