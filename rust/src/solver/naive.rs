//! Naive direct solver — the O(m³) reference the paper's complexity
//! analysis compares against (§2): form the m×m matrix `SᵀS + λI` and
//! Cholesky-solve it. Exact but "beyond capability" at the paper's scale
//! (m ~ 10⁶ ⇒ 8 TB for the matrix alone), so it carries the same
//! [`MemoryBudget`] model as svda and refuses paper-scale shapes.
//!
//! Session note (PR 2): the m×m `SᵀS` is the (huge) λ-independent state;
//! [`NaiveFactor`] caches it so a λ-resweep repeats only the O(m³)
//! refactorization, mirroring the Algorithm-1 session at m×m scale.

use super::cost::{memory_bytes, MemoryBudget};
use super::session::{check_lambda, refactor_damped, undamped_err};
use super::{DampedSolver, Factorization, SolveError, SolverKind};
use crate::linalg::{gemm::gemm_tn_threaded, solve_lower, solve_lower_transpose, Mat};

/// Direct m×m solver.
#[derive(Debug, Clone)]
pub struct NaiveSolver {
    pub budget: MemoryBudget,
    /// Kernel-pool jobs for the m×m `SᵀS` GEMM and the m³ Cholesky
    /// (bit-identical to serial at every count).
    pub threads: usize,
}

impl Default for NaiveSolver {
    fn default() -> Self {
        NaiveSolver { budget: MemoryBudget::a100_80gb(), threads: 1 }
    }
}

/// Session for the naive method: cached un-damped m×m Fisher `SᵀS`.
pub struct NaiveFactor<'s> {
    s: &'s Mat,
    budget: MemoryBudget,
    threads: usize,
    lambda: f64,
    fisher: Option<Mat>,
    l: Option<Mat>,
}

impl<'s> NaiveFactor<'s> {
    fn new(s: &'s Mat, budget: MemoryBudget, threads: usize) -> Self {
        NaiveFactor { s, budget, threads, lambda: 0.0, fisher: None, l: None }
    }
}

impl Factorization for NaiveFactor<'_> {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn dim(&self) -> usize {
        self.s.cols()
    }

    fn lambda(&self) -> f64 {
        self.lambda
    }

    fn redamp(&mut self, lambda: f64) -> Result<(), SolveError> {
        check_lambda(lambda)?;
        if self.fisher.is_none() {
            let (n, m) = self.s.shape();
            let required = memory_bytes(SolverKind::Naive, n, m);
            if !self.budget.fits(required) {
                return Err(SolveError::OutOfMemory {
                    required_bytes: required,
                    budget_bytes: self.budget.bytes(),
                });
            }
            // F = SᵀS  (m×m — the whole point of the paper is avoiding this)
            let mut f = Mat::zeros(m, m);
            gemm_tn_threaded(1.0, self.s, self.s, 0.0, &mut f, self.threads);
            self.fisher = Some(f);
        }
        match refactor_damped(self.fisher.as_ref().unwrap(), lambda, self.threads) {
            Ok(l) => {
                self.l = Some(l);
                self.lambda = lambda;
                Ok(())
            }
            Err(e) => {
                self.l = None;
                self.lambda = 0.0;
                Err(e)
            }
        }
    }

    fn solve_into(&mut self, v: &[f64], x: &mut [f64]) -> Result<(), SolveError> {
        let m = self.s.cols();
        assert_eq!(v.len(), m, "v must be m-dimensional");
        assert_eq!(x.len(), m, "x must be m-dimensional");
        let l = self.l.as_ref().ok_or_else(undamped_err)?;
        let y = solve_lower(l, v);
        let z = solve_lower_transpose(l, &y);
        x.copy_from_slice(&z);
        Ok(())
    }
}

impl DampedSolver for NaiveSolver {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(NaiveFactor::new(s, self.budget, self.threads.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::solver::residual_norm;

    #[test]
    fn exact_on_small_problems() {
        let mut rng = Rng::seed_from(140);
        let s = Mat::randn(5, 30, &mut rng);
        let v: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let x = NaiveSolver::default().solve(&s, &v, 0.5).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.5) < 1e-9);
    }

    #[test]
    fn refuses_paper_scale() {
        // m = 10⁶ ⇒ SᵀS alone is 8 TB; must OOM, not grind.
        let budget = MemoryBudget::a100_80gb();
        assert!(!budget.fits(memory_bytes(SolverKind::Naive, 1000, 1_000_000)));
    }

    #[test]
    fn tiny_budget_surfaces_oom_through_the_session() {
        let mut rng = Rng::seed_from(142);
        let solver = NaiveSolver { budget: MemoryBudget::bytes_for_test(64), threads: 1 };
        let s = Mat::randn(4, 16, &mut rng);
        let v = vec![1.0; 16];
        assert!(matches!(
            solver.solve(&s, &v, 0.1),
            Err(SolveError::OutOfMemory { .. })
        ));
    }

    #[test]
    fn works_without_data_rows_dominating() {
        // n = 1 extreme: rank-1 Fisher + damping.
        let mut rng = Rng::seed_from(141);
        let s = Mat::randn(1, 12, &mut rng);
        let v: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let x = NaiveSolver::default().solve(&s, &v, 0.1).unwrap();
        assert!(residual_norm(&s, &x, &v, 0.1) < 1e-10);
    }

    #[test]
    fn session_resweep_matches_cold() {
        let mut rng = Rng::seed_from(143);
        let s = Mat::randn(5, 24, &mut rng);
        let v: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let solver = NaiveSolver::default();
        let mut fact = solver.factor(&s, 0.8).unwrap();
        fact.redamp(0.05).unwrap();
        let warm = fact.solve(&v).unwrap();
        let cold = solver.solve(&s, &v, 0.05).unwrap();
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
