//! Damped Fisher-system solvers: the paper's Algorithm 1 and every
//! baseline its evaluation compares against.
//!
//! All solvers compute `x` with `(SᵀS + λI) x = v` for a score matrix
//! `S: n×m` in the tall-skinny regime `m ≫ n`:
//!
//! | solver | paper label | complexity | memory | source |
//! |--------|-------------|------------|--------|--------|
//! | [`CholSolver`]  | "chol" | O(n³ + n²m) | O(nm) | Algorithm 1 (the contribution) |
//! | [`EighSolver`]  | "eigh" | O(n³ + n²m), larger constant | O(nm) | Appendix C, previously fastest |
//! | [`SvdaSolver`]  | "svda" | O(n²m·sweeps) | O(nm)+gesvda workspace | Appendix C, CUDA gesvda stand-in |
//! | [`NaiveSolver`] | —      | O(m³) | O(m²) | §2 "naive" reference |
//! | [`CgSolver`]    | —      | O(nm·iters) | O(m) | §3 iterative baseline |
//! | [`RvbSolver`]   | —      | O(n³ + n²m) | O(nm) | RVB+23 identity (Appendix B), needs `v = Sᵀf` |
//!
//! Complex stochastic-reconfiguration variants (§3) live in [`complex_sr`]:
//! the full-complex Fisher `F = S†S` and the real-part Fisher
//! `F = ℜ[S†S]` via `S ← Concat[ℜS, ℑS]`.

pub mod cg;
pub mod chol;
pub mod complex_sr;
pub mod cost;
pub mod eigh_svd;
pub mod naive;
pub mod rvb;
pub mod svda;

pub use cg::{CgSolver, CgStats};
pub use chol::CholSolver;
pub use complex_sr::{center_scores, solve_sr_complex, solve_sr_real_part};
pub use cost::{flops, memory_bytes, MemoryBudget};
pub use eigh_svd::EighSolver;
pub use naive::NaiveSolver;
pub use rvb::RvbSolver;
pub use svda::SvdaSolver;

use crate::linalg::{CholeskyError, Mat};

/// Solver failure modes.
#[derive(Debug, Clone, PartialEq)]
pub enum SolveError {
    /// Cholesky breakdown — λ too small for the sample Gram matrix.
    NotPositiveDefinite(CholeskyError),
    /// The method's modeled device-memory footprint exceeds the budget
    /// (mirrors the paper's `N/A` cell for svda at (4096, 100000)).
    OutOfMemory { required_bytes: u64, budget_bytes: u64 },
    /// Iterative method failed to reach tolerance.
    DidNotConverge { iterations: usize, residual: f64 },
    /// Structural precondition violated (e.g. RVB without `v = Sᵀf`).
    BadInput(String),
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::NotPositiveDefinite(e) => write!(f, "{e}"),
            SolveError::OutOfMemory { required_bytes, budget_bytes } => write!(
                f,
                "modeled footprint {:.2} GB exceeds device budget {:.2} GB",
                *required_bytes as f64 / 1e9,
                *budget_bytes as f64 / 1e9
            ),
            SolveError::DidNotConverge { iterations, residual } => {
                write!(f, "no convergence after {iterations} iterations (residual {residual:.3e})")
            }
            SolveError::BadInput(msg) => write!(f, "bad input: {msg}"),
        }
    }
}

impl std::error::Error for SolveError {}

impl From<CholeskyError> for SolveError {
    fn from(e: CholeskyError) -> Self {
        SolveError::NotPositiveDefinite(e)
    }
}

/// Common interface: solve `(SᵀS + λI) x = v`.
pub trait DampedSolver {
    /// Paper-facing label ("chol", "eigh", "svda", …).
    fn name(&self) -> &'static str;

    /// Solve for one right-hand side.
    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError>;
}

/// Solver selection for configs / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Chol,
    Eigh,
    Svda,
    Naive,
    Cg,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        Some(match s {
            "chol" => SolverKind::Chol,
            "eigh" => SolverKind::Eigh,
            "svda" => SolverKind::Svda,
            "naive" => SolverKind::Naive,
            "cg" => SolverKind::Cg,
            _ => return None,
        })
    }

    pub fn all() -> &'static [SolverKind] {
        &[SolverKind::Chol, SolverKind::Eigh, SolverKind::Svda, SolverKind::Naive, SolverKind::Cg]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SolverKind::Chol => "chol",
            SolverKind::Eigh => "eigh",
            SolverKind::Svda => "svda",
            SolverKind::Naive => "naive",
            SolverKind::Cg => "cg",
        }
    }
}

/// Instantiate a boxed solver by kind with default settings.
pub fn make_solver(kind: SolverKind) -> Box<dyn DampedSolver + Send + Sync> {
    match kind {
        SolverKind::Chol => Box::new(CholSolver::default()),
        SolverKind::Eigh => Box::new(EighSolver::default()),
        SolverKind::Svda => Box::new(SvdaSolver::default()),
        SolverKind::Naive => Box::new(NaiveSolver::default()),
        SolverKind::Cg => Box::new(CgSolver::default()),
    }
}

/// Residual `‖(SᵀS + λI)x − v‖₂` — the acceptance metric used across the
/// test suite and the bench harness.
pub fn residual_norm(s: &Mat, x: &[f64], v: &[f64], lambda: f64) -> f64 {
    let sx = s.matvec(x);
    let mut r = s.t_matvec(&sx);
    let mut acc = 0.0;
    for j in 0..x.len() {
        let rj = r[j] + lambda * x[j] - v[j];
        r[j] = rj;
        acc += rj * rj;
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    /// Every solver must agree with every other one (and with the QR
    /// oracle) on well-conditioned random problems.
    #[test]
    fn all_solvers_agree_cross_method() {
        let mut rng = Rng::seed_from(100);
        for &(n, m) in &[(4, 9), (16, 64), (32, 200)] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let lambda = 0.05;
            let oracle = crate::linalg::qr::ridge_qr_oracle(&s, &v, lambda);
            for &kind in SolverKind::all() {
                let solver = make_solver(kind);
                let x = solver.solve(&s, &v, lambda).unwrap();
                let vnorm = crate::linalg::mat::norm2(&v);
                for (a, b) in x.iter().zip(&oracle) {
                    assert!(
                        (a - b).abs() < 1e-6 * vnorm.max(1.0),
                        "{} disagrees with QR oracle at ({n},{m})",
                        solver.name()
                    );
                }
                assert!(residual_norm(&s, &x, &v, lambda) < 1e-6 * vnorm.max(1.0));
            }
        }
    }

    #[test]
    fn solver_kind_parse_roundtrip() {
        for &k in SolverKind::all() {
            assert_eq!(SolverKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(SolverKind::parse("bogus"), None);
    }

    #[test]
    fn residual_norm_zero_for_exact_solution() {
        let mut rng = Rng::seed_from(101);
        let s = Mat::randn(3, 7, &mut rng);
        // x=0, v=0 is exact.
        assert_eq!(residual_norm(&s, &vec![0.0; 7], &vec![0.0; 7], 1.0), 0.0);
    }
}
