//! Damped Fisher-system solvers: the paper's Algorithm 1 and every
//! baseline its evaluation compares against, behind the PR-2
//! **plan → factor → solve** session API.
//!
//! All solvers compute `x` with `(SᵀS + λI) x = v` for a score matrix
//! `S: n×m` in the tall-skinny regime `m ≫ n`:
//!
//! | solver | paper label | complexity (factor / per-RHS) | memory | precision | source |
//! |--------|-------------|-------------------------------|--------|-----------|--------|
//! | [`CholSolver`]  | "chol"  | O(n²m + n³) / O(nm) | O(nm) | f64, mixed | Algorithm 1 (the contribution) |
//! | [`EighSolver`]  | "eigh"  | O(n²m + n³), larger constant / O(nm) | O(nm) | f64 | Appendix C, previously fastest |
//! | [`SvdaSolver`]  | "svda"  | O(n²m·sweeps) / O(nm) | O(nm)+gesvda workspace | f64 | Appendix C, CUDA gesvda stand-in |
//! | [`NaiveSolver`] | —       | O(m²n + m³) / O(m²) | O(m²) | f64 | §2 "naive" reference |
//! | [`CgSolver`]    | —       | none / O(nm·iters) | O(m) | f64 | §3 iterative baseline |
//! | [`RvbSolver`]   | "rvb"   | O(n²m + n³) / O(nm) | O(nm) | f64, mixed | RVB+23 identity (Appendix B), needs `v = Sᵀf` |
//! | [`BlockDiagSolver`] | "blockdiag" | k·O(n²·m/k + n³/3) / O(nm) | O(nm) | f64, mixed | K-FAC block-diagonal approximation (§1's "approximations like KFAC") |
//! | [`KpSvdSolver`] | "kpsvd" | O(m_b²·n + m_b^1.5) per block / O(pq(p+q)) | O(nm + Σm_b²) | f64 | Kronecker-product SVD, Koroko et al. 2201.10285 |
//! | [`HybridCgSolver`] | "hybrid" | blockdiag factor / O(nm·iters) | O(nm) | f64, mixed (preconditioner) | structured-preconditioned CG on the exact system |
//!
//! The *precision* column is `solver.precision` (PR 6): every kind runs
//! the default pure-`f64` pipeline, and the two session kinds with a
//! cached Cholesky factor (`chol`, `rvb`) additionally accept `mixed` —
//! f32 Gram/factor/triangular-solves with f64 iterative refinement of
//! each right-hand side against the true residual, converging to
//! `solver.tol` when κ(W)·u₃₂ ≪ 1 and latching back to the f64 path
//! otherwise (see [`chol::mixed_counters`]). Requesting `mixed` on any
//! other kind is a validation error, not a silent downgrade.
//!
//! ## The session API (PR 2)
//!
//! The expensive part of every direct method — forming the n×n Gram
//! matrix (O(n²m)) and factoring it (O(n³)) — is separable from the
//! cheap O(nm) back-substitution per right-hand side, and the Gram is
//! λ-independent. The [`Factorization`] session makes both amortizations
//! first-class:
//!
//! ```rust
//! use dngd::data::rng::Rng;
//! use dngd::linalg::Mat;
//! use dngd::solver::{CholSolver, DampedSolver};
//!
//! let mut rng = Rng::seed_from(7);
//! let s = Mat::randn(16, 256, &mut rng);
//! let solver = CholSolver::default();
//! // Stage once: Gram + Cholesky.
//! let mut fact = solver.factor(&s, 1e-2).unwrap();
//! // Many cheap solves against the same factor…
//! let v: Vec<f64> = (0..256).map(|_| rng.normal()).collect();
//! let x1 = fact.solve(&v).unwrap();
//! // …and λ-resweeps that reuse the cached Gram (no O(n²m) rework).
//! fact.redamp(1e-4).unwrap();
//! let x2 = fact.solve(&v).unwrap();
//! assert!(x2.iter().zip(&x1).any(|(a, b)| a != b));
//! ```
//!
//! [`SolverRegistry`] builds solvers from a [`SolverKind`] plus
//! [`SolverOptions`] (config / `--set solver.key=value`), and
//! [`SolverPlan`] pins a registry-built solver to a problem shape for
//! reuse across training steps. The pre-PR-2 one-shot
//! [`DampedSolver::solve`] survives as a default-method shim
//! (factor → solve_into), so old call sites keep working — now routed
//! through the session path.
//!
//! ## Threading (PR 3): `solver.threads` reaches every stage
//!
//! `solver.threads` (TOML `[solver] threads = T`, CLI `--threads T` /
//! `--set solver.threads=T`, env `DNGD_THREADS` for the bench harness)
//! is no longer a SYRK-only knob. A registry-built solver partitions
//! **every** dense stage across that many persistent kernel-pool jobs:
//!
//! | stage | where it threads |
//! |-------|------------------|
//! | Gram `SSᵀ` (line 1) | `syrk_parallel` MC-row panels |
//! | `Chol(W)` (line 2), incl. every λ-resweep | lookahead-pipelined blocked Cholesky (`linalg::cholesky_threaded`) |
//! | multi-RHS TRSM (lines 3–4) | RHS column panels (`linalg::solve_lower_multi_threaded`) |
//! | session panel GEMMs (`S·Vᵀ`, `Sᵀ·Z`, `SᵀS`, eigh's `V = SᵀUΣ⁻¹`) | `dgemm_threaded` MC-row bands |
//! | sharded coordinator's leader-local resweep | same threaded Cholesky on the leader |
//!
//! Every threaded kernel is **bit-identical to its serial result at
//! every thread count within a fixed ISA tier** (pinned by
//! `rust/tests/threading.rs` and `rust/tests/isa_dispatch.rs`), so
//! `threads` is a pure throughput knob: runs reproduce exactly across
//! machines with the same tier. Since PR 4 the tier itself is explicit
//! — runtime-dispatched AVX2/AVX-512/NEON micro-kernels with a scalar
//! fallback ([`linalg::simd`](crate::linalg::simd)), selected per
//! process (`DNGD_KERNEL`) or per solver (`solver.isa`, honored by the
//! chol/rvb sessions); cross-tier results are only tolerance-equal. [`flops_threaded`] is the
//! matching cost model — it divides only the partitionable GEMM/factor
//! terms by the thread count, keeping cross-kind comparisons honest at
//! a configured thread count; the thread bench prints it as the
//! ideal-scaling overlay next to the measured speedups. Measured
//! scaling lives in EXPERIMENTS.md §Threading
//! (`dngd bench --threads` → `BENCH_PR3.json`).
//!
//! ## Streaming (PR 5): sliding-window row rotation
//!
//! The separability argument extends across *steps*: successive
//! minibatches of an online consumer overlap in all but k of their n
//! sample rows, so the new Gram differs by k symmetric row/column
//! deletions + k bordered appends — both with O(n²) factor updates
//! ([`linalg::chol_update`](crate::linalg::chol_update)). The session
//! trait exposes this as [`Factorization::update_rows`] (plus the
//! [`Factorization::refresh`] drift backstop), and
//! [`DampedSolver::begin_window`] opens a session that *owns* its
//! window so a trainer can hold it across steps:
//!
//! | mode | per-step cost | Gram SYRKs | sessions |
//! |------|---------------|------------|----------|
//! | cold factor (pre-PR-5) | O(n²m + n³) | 1 | every kind |
//! | `update_rows` rotation | O(knm + kn²) | **0** (patched) | `chol`, `rvb` (native) |
//! | rotated-window refactor fallback | O(n²m + n³) | 1 | every other kind |
//!
//! Config: `solver.window` (sliding-window size, 0 = off) and
//! `solver.refresh_every` (rotations per full refactor, 0 = never);
//! the NGD trainer wires both through
//! [`NaturalGradient::with_window`](crate::ngd::NaturalGradient::with_window).
//! A bordered-append breakdown (the hyperbolic-downdate failure mode)
//! falls back to an O(n³) refactor of the patched Gram and only then
//! surfaces as [`SolveError::NotPositiveDefinite`]; [`flops_streaming`]
//! is the matching cost model and `dngd bench --streaming` →
//! `BENCH_PR5.json` the measured table (EXPERIMENTS.md §Streaming).
//!
//! ## Serving (PR 7): many tenants, one backend
//!
//! The amortizations above are per-session; [`crate::serve`] applies
//! them *across* concurrent consumers. A [`crate::serve::Server`]
//! multiplexes tenant sessions onto one sharded backend behind the
//! pluggable [`crate::serve::ShardTransport`] (in-process channels or
//! out-of-process Unix sockets, bit-identical):
//!
//! | serving concern | policy |
//! |-----------------|--------|
//! | session lifecycle | connect (tenant slot) → `open_session` (score matrix → cached staging, charged against the memory model) or `attach` → `solve`/`rotate`× → `close_session` (releases shards + charge) |
//! | coalescing | per dispatch tick (`serve.tick_ms`): rotations first in arrival order, then solves grouped by (session, λ-bits) into **one** `solve_many` panel each — k tenant requests cost one `MatvecMany`/TRSM/`ApplyMany` round instead of k |
//! | admission | bounded everywhere: tenant slots (`serve.tenants`), dispatch queue (`serve.queue_depth` → `Overloaded` + retry-after), session memory ([`memory_bytes`] vs `serve.budget_gb` → `OverBudget`) — reject-with-hint, never OOM |
//! | faults | transport faults surface as [`SolveError::Backend`] with an explicit retryable/fatal split; retryables get capped-exponential backoff with jitter inside the request deadline (`serve.deadline_ms`, `serve.max_retries`); fatals hand off to the supervisor |
//! | recovery (PR 8) | the supervisor respawns dead channel workers / reconnects dead sockets, then re-materializes affected sessions from a durable `SessionRecord` (score snapshot + rotation log at `serve.snapshot_every` cadence): **replay** through `update_rows` (same arithmetic as the unfailed run) or a **cold refactor** when the log is unusable |
//! | degradation (PR 8) | recovery that can't beat the deadline falls back to a **leader-local** `chol` solve of the recorded window — every path is pinned in `ServeStats` (`worker_respawns`, `session_replays`, `session_refactors`, `local_fallbacks`); expired requests get a typed `DeadlineExceeded` with elapsed/retry progress, never a hang |
//!
//! `dngd serve --self-test` round-trips both transports against the
//! serial solver (add `--inject-kill` to force a mid-workload
//! recovery); `dngd chaos` runs the scripted fault schedules;
//! `dngd bench --serving` → `BENCH_PR7.json` measures requests/sec and
//! p50/p99 latency at 1/4/16 tenants, coalesced vs serial, and
//! `dngd bench --recovery` → `BENCH_PR8.json` the recovery-latency tax
//! under injected kills (EXPERIMENTS.md §Serving, §Fault-tolerance).
//!
//! ## Durability (PR 9): crash-safe training with bit-identical resume
//!
//! The trainer snapshots its *complete* state at checkpoint boundaries
//! (atomic rename + dir fsync) and a killed run resumes from the latest
//! durable checkpoint onto the unfailed trajectory **bit for bit**.
//! What each solve mode must persist and how it is rebuilt:
//!
//! | mode | durable session state | restore path |
//! |------|----------------------|--------------|
//! | classic (chol/eigh/svda/cg/rvb, sharded or serial) | none — a fresh factor per step | params + momentum + λ + RNG cursor suffice |
//! | streaming window, fallback kinds | window fill matrix | refactor cold next step (same arithmetic as a refresh) |
//! | streaming window, owned `chol`/`rvb` session | window snapshot + rotation log + per-solve (λ_first, retries) backoff chains + mixed-latch flag | replay: `begin_window` → re-rotate → re-damp the *exact* λ chains (a rotated factor differs bitwise from a refactored one) |
//!
//! [`crate::ngd::NaturalGradient::export_state`] /
//! [`restore_state`](crate::ngd::NaturalGradient::restore_state) carry
//! that log ([`crate::ngd::SessionLog`]); the health sentinel and
//! recovery scan live in [`crate::coordinator::trainer`], and
//! `dngd chaos --target train` plus `rust/tests/durability.rs` pin the
//! kill-anywhere guarantee (EXPERIMENTS.md §Durability).
//!
//! ## Structured kinds (PR 10): blockdiag, kpsvd, hybrid
//!
//! The K-FAC family trades exactness for per-block cost: the Fisher is
//! restricted to its block-diagonal (`solver.blocks` contiguous column
//! groups, [`blockdiag::BlockPartition`]), each block backed by one
//! inner chol/rvb session ([`blockdiag::BlockDiagFactor`]) so redamp
//! caching, `solve_many` panels, threading, mixed precision and
//! `update_rows` streaming all compose through. [`KpSvdSolver`] goes
//! further per Koroko et al. (2201.10285): each block Gram is replaced
//! by its nearest Kronecker product `A⊗B` (SVD of the rearranged
//! block), making λ-resweeps O(1) and per-RHS solves O(pq(p+q)).
//! [`HybridCgSolver`] closes the approximation gap: true-residual CG on
//! the **exact** damped system, preconditioned by the block-diagonal
//! factor — exact answers at structured per-iteration cost.
//!
//! When to prefer which (cost-model crossover in
//! [`flops_blocked`] / `dngd bench --structured` → `BENCH_PR10.json`):
//!
//! | regime | kind |
//! |--------|------|
//! | dense cross-block curvature, m modest | `chol` (exact, the paper's path) |
//! | near-block-diagonal Fisher, many blocks | `blockdiag` (k× cheaper factor, approximate) |
//! | many λ-resweeps / RHS on static blocks | `kpsvd` (O(1) redamp, approximate) |
//! | exact answer needed, Fisher near-structured | `hybrid` (few PCG iterations, exact) |
//!
//! A single-block `blockdiag` session is **bit-identical** to the plain
//! chol session on factor, λ-resweep, `solve_many` and rotation (pinned
//! by `rust/tests/structured.rs`), so the structured family degrades
//! gracefully to the exact dense path.
//!
//! Complex stochastic-reconfiguration variants (§3) live in
//! [`complex_sr`]: the full-complex Fisher `F = S†S` and the real-part
//! Fisher `F = ℜ[S†S]` via `S ← Concat[ℜS, ℑS]`, with the same
//! Gram-caching session shape ([`complex_sr::ComplexSrFactor`]).

pub mod blockdiag;
pub mod cg;
pub mod chol;
pub mod complex_sr;
pub mod cost;
pub mod eigh_svd;
pub mod hybrid;
pub mod kpsvd;
pub mod naive;
pub mod rvb;
pub mod session;
pub mod svda;

pub use blockdiag::{BlockDiagSolver, BlockKind, BlockPartition};
pub use cg::{CgSolver, CgStats};
pub use chol::{mixed_counters, CholSolver};
pub use complex_sr::{
    center_scores, solve_sr_complex, solve_sr_real_part, stack_real_part, ComplexSrFactor,
};
pub use cost::{
    flops, flops_blocked, flops_precision, flops_streaming, flops_threaded, memory_bytes,
    MemoryBudget,
};
pub use eigh_svd::EighSolver;
pub use hybrid::HybridCgSolver;
pub use kpsvd::KpSvdSolver;
pub use naive::NaiveSolver;
pub use rvb::RvbSolver;
pub use session::{
    solve_with_backoff, Factorization, OneShot, Precision, SolverOptions, SolverPlan,
    SolverRegistry,
};
pub use svda::SvdaSolver;

use crate::linalg::{CholeskyError, Mat};

/// Solver failure modes.
#[derive(Debug, Clone, PartialEq)]
pub enum SolveError {
    /// Cholesky breakdown — λ too small for the sample Gram matrix.
    NotPositiveDefinite(CholeskyError),
    /// The method's modeled device-memory footprint exceeds the budget
    /// (mirrors the paper's `N/A` cell for svda at (4096, 100000)).
    OutOfMemory { required_bytes: u64, budget_bytes: u64 },
    /// Iterative method failed to reach tolerance.
    DidNotConverge { iterations: usize, residual: f64 },
    /// Structural precondition violated (e.g. RVB without `v = Sᵀf`).
    BadInput(String),
    /// A distributed backend fault (PR 7): the shard transport lost a
    /// worker or hit back-pressure. `retryable` splits transient
    /// conditions (full worker mailbox — back off and resubmit) from
    /// fatal ones (dead worker / closed connection). A retryable fault
    /// never poisons the session: the staged state survives and the
    /// same call can be retried.
    Backend { retryable: bool, detail: String },
}

impl std::fmt::Display for SolveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolveError::NotPositiveDefinite(e) => write!(f, "{e}"),
            SolveError::OutOfMemory { required_bytes, budget_bytes } => write!(
                f,
                "modeled footprint {:.2} GB exceeds device budget {:.2} GB",
                *required_bytes as f64 / 1e9,
                *budget_bytes as f64 / 1e9
            ),
            SolveError::DidNotConverge { iterations, residual } => {
                write!(f, "no convergence after {iterations} iterations (residual {residual:.3e})")
            }
            SolveError::BadInput(msg) => write!(f, "bad input: {msg}"),
            SolveError::Backend { retryable: true, detail } => {
                write!(f, "backend busy (retryable): {detail}")
            }
            SolveError::Backend { retryable: false, detail } => {
                write!(f, "backend failed: {detail}")
            }
        }
    }
}

impl std::error::Error for SolveError {}

impl From<CholeskyError> for SolveError {
    fn from(e: CholeskyError) -> Self {
        SolveError::NotPositiveDefinite(e)
    }
}

/// Common interface: solve `(SᵀS + λI) x = v`.
///
/// Since PR 2 the primary entry point is the session path —
/// [`DampedSolver::begin`] / [`DampedSolver::factor`] return a
/// [`Factorization`] that amortizes the O(n²m) Gram and O(n³) factor
/// across right-hand sides and λ-resweeps — and [`DampedSolver::solve`]
/// is a default-method shim over it.
///
/// Implementors **must override at least one** of `begin` or `solve`:
/// the default `solve` routes through `begin`, and the default `begin`
/// falls back to a one-shot session that calls `solve` per right-hand
/// side (for backends with no separable factorization, e.g. a compiled
/// fixed-function PJRT executable).
pub trait DampedSolver {
    /// Paper-facing label ("chol", "eigh", "svda", …).
    fn name(&self) -> &'static str;

    /// Open a session against `s`. Cheap: no numerical work happens
    /// until the first [`Factorization::redamp`], which computes the
    /// λ-independent state (Gram matrix, SVD, shard distribution) once
    /// and caches it for every later re-damping.
    fn begin<'s>(&'s self, s: &'s Mat) -> Box<dyn Factorization + 's> {
        Box::new(OneShot::new(self, s))
    }

    /// Open a session that **owns** its score window — the streaming
    /// entry point (PR 5). The returned factorization has no borrow of
    /// the caller's matrices, so an online consumer (the NGD trainer's
    /// sliding-window mode) can hold it across steps and rotate rows
    /// through [`Factorization::update_rows`]. `None` means this kind
    /// has no owned-window session; streaming drivers then fall back
    /// to a cold refactor per rotation. Implemented by `chol` and
    /// `rvb` (the kinds with O(kn²)-rotatable factors).
    fn begin_window(&self, window: Mat) -> Option<Box<dyn Factorization>> {
        let _ = window;
        None
    }

    /// Stage the factorization for (`s`, `lambda`): [`DampedSolver::begin`]
    /// plus the first [`Factorization::redamp`].
    fn factor<'s>(
        &'s self,
        s: &'s Mat,
        lambda: f64,
    ) -> Result<Box<dyn Factorization + 's>, SolveError> {
        let mut fact = self.begin(s);
        fact.redamp(lambda)?;
        Ok(fact)
    }

    /// One-shot solve for a single right-hand side — the pre-PR-2 API,
    /// now a thin shim over the session path.
    fn solve(&self, s: &Mat, v: &[f64], lambda: f64) -> Result<Vec<f64>, SolveError> {
        let mut fact = self.factor(s, lambda)?;
        let mut x = vec![0.0; s.cols()];
        fact.solve_into(v, &mut x)?;
        Ok(x)
    }
}

/// Solver selection for configs / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Chol,
    Eigh,
    Svda,
    Naive,
    Cg,
    /// RVB+23 least-squares method — requires `v = Sᵀf` (rejected as
    /// [`SolveError::BadInput`] otherwise).
    Rvb,
    /// K-FAC-style block-diagonal Fisher (PR 10): per-block inner
    /// chol/rvb sessions over a [`BlockPartition`]. **Approximate**
    /// unless the Fisher is truly block-diagonal (or one block, where
    /// it is bit-identical to `chol`).
    BlockDiag,
    /// Kronecker-product-SVD approximation per block (PR 10, Koroko et
    /// al. 2201.10285). **Approximate**; O(1) λ-resweeps.
    KpSvd,
    /// Structured-preconditioned CG on the exact damped system
    /// (PR 10): exact answers, block-diagonal preconditioner.
    Hybrid,
}

impl SolverKind {
    pub fn parse(s: &str) -> Option<SolverKind> {
        Some(match s {
            "chol" => SolverKind::Chol,
            "eigh" => SolverKind::Eigh,
            "svda" => SolverKind::Svda,
            "naive" => SolverKind::Naive,
            "cg" => SolverKind::Cg,
            "rvb" => SolverKind::Rvb,
            "blockdiag" => SolverKind::BlockDiag,
            "kpsvd" => SolverKind::KpSvd,
            "hybrid" => SolverKind::Hybrid,
            _ => return None,
        })
    }

    /// Every selectable solver, including the structurally-restricted
    /// `rvb` (which only accepts `v ∈ rowspace(S)`) and the PR-10
    /// structured kinds (`blockdiag`/`kpsvd` are *approximate* on
    /// Fishers with cross-block mass).
    pub fn all() -> &'static [SolverKind] {
        &[
            SolverKind::Chol,
            SolverKind::Eigh,
            SolverKind::Svda,
            SolverKind::Naive,
            SolverKind::Cg,
            SolverKind::Rvb,
            SolverKind::BlockDiag,
            SolverKind::KpSvd,
            SolverKind::Hybrid,
        ]
    }

    /// The solvers that produce the **exact** solution for an arbitrary
    /// right-hand side (excludes `rvb`, whose precondition `v = Sᵀf`
    /// fails for random v, and the approximate structured kinds —
    /// `hybrid` is exact but its convergence is iterative, so it is
    /// validated separately in `rust/tests/structured.rs`).
    pub fn general() -> &'static [SolverKind] {
        &[SolverKind::Chol, SolverKind::Eigh, SolverKind::Svda, SolverKind::Naive, SolverKind::Cg]
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SolverKind::Chol => "chol",
            SolverKind::Eigh => "eigh",
            SolverKind::Svda => "svda",
            SolverKind::Naive => "naive",
            SolverKind::Cg => "cg",
            SolverKind::Rvb => "rvb",
            SolverKind::BlockDiag => "blockdiag",
            SolverKind::KpSvd => "kpsvd",
            SolverKind::Hybrid => "hybrid",
        }
    }
}

/// Instantiate a boxed solver by kind with default settings. Use
/// [`SolverRegistry`] to build with per-solver options.
pub fn make_solver(kind: SolverKind) -> Box<dyn DampedSolver + Send + Sync> {
    SolverRegistry::default().build(kind)
}

/// Residual `‖(SᵀS + λI)x − v‖₂` — the acceptance metric used across the
/// test suite and the bench harness.
pub fn residual_norm(s: &Mat, x: &[f64], v: &[f64], lambda: f64) -> f64 {
    let sx = s.matvec(x);
    let mut r = s.t_matvec(&sx);
    let mut acc = 0.0;
    for j in 0..x.len() {
        let rj = r[j] + lambda * x[j] - v[j];
        r[j] = rj;
        acc += rj * rj;
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    /// Every general-RHS solver must agree with every other one (and with
    /// the QR oracle) on well-conditioned random problems; `rvb` is
    /// checked on structured `v = Sᵀf` where its precondition holds.
    #[test]
    fn all_solvers_agree_cross_method() {
        let mut rng = Rng::seed_from(100);
        for &(n, m) in &[(4, 9), (16, 64), (32, 200)] {
            let s = Mat::randn(n, m, &mut rng);
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let lambda = 0.05;
            let oracle = crate::linalg::qr::ridge_qr_oracle(&s, &v, lambda);
            for &kind in SolverKind::general() {
                let solver = make_solver(kind);
                let x = solver.solve(&s, &v, lambda).unwrap();
                let vnorm = crate::linalg::mat::norm2(&v);
                for (a, b) in x.iter().zip(&oracle) {
                    assert!(
                        (a - b).abs() < 1e-6 * vnorm.max(1.0),
                        "{} disagrees with QR oracle at ({n},{m})",
                        solver.name()
                    );
                }
                assert!(residual_norm(&s, &x, &v, lambda) < 1e-6 * vnorm.max(1.0));
            }
            // rvb on its native structured input.
            let f: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v_ls = s.t_matvec(&f);
            let x_rvb = make_solver(SolverKind::Rvb).solve(&s, &v_ls, lambda).unwrap();
            let x_ref = make_solver(SolverKind::Chol).solve(&s, &v_ls, lambda).unwrap();
            let scale = crate::linalg::mat::norm2(&x_ref).max(1.0);
            for (a, b) in x_rvb.iter().zip(&x_ref) {
                assert!((a - b).abs() < 1e-7 * scale, "rvb vs chol at ({n},{m})");
            }
        }
    }

    #[test]
    fn solver_kind_parse_roundtrip() {
        for &k in SolverKind::all() {
            assert_eq!(SolverKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(SolverKind::parse("bogus"), None);
        // rvb is reachable from the string side too (the PR-2 bug fix).
        assert_eq!(SolverKind::parse("rvb"), Some(SolverKind::Rvb));
        assert!(SolverKind::all().contains(&SolverKind::Rvb));
        assert!(!SolverKind::general().contains(&SolverKind::Rvb));
    }

    #[test]
    fn residual_norm_zero_for_exact_solution() {
        let mut rng = Rng::seed_from(101);
        let s = Mat::randn(3, 7, &mut rng);
        // x=0, v=0 is exact.
        assert_eq!(residual_norm(&s, &vec![0.0; 7], &vec![0.0; 7], 1.0), 0.0);
    }
}
