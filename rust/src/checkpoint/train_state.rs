//! Full training-state checkpoints (PR 9).
//!
//! [`TrainState`] is the versioned container for *everything* a killed
//! trainer needs to resume bit-identically: parameters, optimizer
//! state (momentum, damping scalar, step counters, and — in streaming
//! mode — the replayable window log built on PR-8's
//! [`SessionRecord`] snapshot+rotation machinery), and the data-stream
//! position (the batch RNG state *is* the data cursor). It rides
//! inside the flat-tensor [`Checkpoint`] container, so it inherits the
//! atomic-rename + dir-fsync durability and checksum trailer.
//!
//! [`recover_latest`] is the startup scan: newest `step_*.ckpt` first,
//! corrupt/truncated files are quarantined (renamed `*.corrupt`, never
//! loaded), files from a newer format generation are *skipped in
//! place* (they are healthy — a rollback of the binary must not
//! destroy a newer binary's checkpoints).

use std::path::{Path, PathBuf};

use super::{Checkpoint, CheckpointError};
use crate::ngd::{NgdState, SessionLog, WindowLog};
use crate::serve::SessionRecord;

/// Schema version of the [`TrainState`] payload (independent of the
/// container format version — the container can round-trip tensors it
/// does not understand; this guards the *meaning* of the tensors).
pub const TRAIN_STATE_VERSION: u32 = 1;

/// Everything the trainer evolves across steps, captured at a step
/// boundary. `step` is the number of completed steps — resume begins
/// at step `step`.
#[derive(Debug, Clone)]
pub struct TrainState {
    /// Completed steps.
    pub step: usize,
    /// Flat parameter vector.
    pub params: Vec<f64>,
    /// Batch-RNG xoshiro words ([`crate::data::Rng::state`]).
    pub rng_words: [u64; 4],
    /// Cached Box–Muller spare of the batch RNG.
    pub rng_cached: Option<f64>,
    /// Optimizer-specific state.
    pub optimizer: OptimizerState,
}

/// Which optimizer the run uses, with its evolving state.
#[derive(Debug, Clone)]
pub enum OptimizerState {
    /// First-order baseline: momentum buffer only.
    Sgd(SgdState),
    /// Damped NGD ([`crate::ngd::NaturalGradient::export_state`]).
    Ngd(NgdState),
}

/// SGD baseline state.
#[derive(Debug, Clone)]
pub struct SgdState {
    /// Momentum buffer (empty before the first step).
    pub velocity: Vec<f64>,
}

/// Canonical checkpoint file path for a step boundary.
pub fn checkpoint_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("step_{step}.ckpt"))
}

fn flag(b: bool) -> f64 {
    if b {
        1.0
    } else {
        0.0
    }
}

fn opt_pair(v: Option<f64>) -> Vec<f64> {
    match v {
        Some(x) => vec![1.0, x],
        None => vec![0.0, 0.0],
    }
}

fn tensor<'a>(ck: &'a Checkpoint, name: &str) -> Result<&'a [f64], CheckpointError> {
    ck.get(name).ok_or_else(|| CheckpointError::Corrupt(format!("missing tensor {name:?}")))
}

fn tensor_exact<'a>(
    ck: &'a Checkpoint,
    name: &str,
    len: usize,
) -> Result<&'a [f64], CheckpointError> {
    let t = tensor(ck, name)?;
    if t.len() != len {
        return Err(CheckpointError::Corrupt(format!(
            "tensor {name:?}: expected {len} values, found {}",
            t.len()
        )));
    }
    Ok(t)
}

/// A non-negative integer that rode through the f64 encoding.
fn as_count(v: f64, what: &str) -> Result<usize, CheckpointError> {
    if !v.is_finite() || v < 0.0 || v.fract() != 0.0 || v > 2f64.powi(53) {
        return Err(CheckpointError::Corrupt(format!("{what}: not a count: {v}")));
    }
    Ok(v as usize)
}

fn as_flag(v: f64, what: &str) -> Result<bool, CheckpointError> {
    match v {
        x if x == 0.0 => Ok(false),
        x if x == 1.0 => Ok(true),
        _ => Err(CheckpointError::Corrupt(format!("{what}: not a 0/1 flag: {v}"))),
    }
}

fn opt_from_pair(t: &[f64], what: &str) -> Result<Option<f64>, CheckpointError> {
    Ok(as_flag(t[0], what)?.then_some(t[1]))
}

const RECORD_PREFIX: &str = "train.ngd.window.session.record.";

impl TrainState {
    /// Encode into the flat-tensor container. The RNG's `u64` words
    /// ride as raw bit patterns (`f64::from_bits`) — serialization is
    /// a byte copy end to end, so any pattern (NaN payloads included)
    /// round-trips exactly.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("train.meta", vec![TRAIN_STATE_VERSION as f64, self.step as f64]);
        ck.insert("train.params", self.params.clone());
        ck.insert("train.rng.s", self.rng_words.iter().map(|&w| f64::from_bits(w)).collect());
        ck.insert("train.rng.cached", opt_pair(self.rng_cached));
        match &self.optimizer {
            OptimizerState::Sgd(s) => {
                ck.insert("train.opt.kind", vec![0.0]);
                ck.insert("train.sgd.velocity", s.velocity.clone());
            }
            OptimizerState::Ngd(n) => {
                ck.insert("train.opt.kind", vec![1.0]);
                ck.insert("train.ngd.velocity", n.velocity.clone());
                let mut meta = vec![n.steps as f64, n.lambda];
                meta.extend(opt_pair(n.last_loss));
                meta.push(flag(n.window.is_some()));
                ck.insert("train.ngd.meta", meta);
                if let Some(w) = &n.window {
                    ck.insert_mat("train.ngd.window.fill", &w.fill);
                    ck.insert(
                        "train.ngd.window.meta",
                        vec![flag(w.fallback), w.rotations as f64, flag(w.session.is_some())],
                    );
                    if let Some(sl) = &w.session {
                        let mut meta = opt_pair(sl.cold_refresh_lambda);
                        meta.push(sl.cold_retries as f64);
                        meta.push(flag(sl.ever_rotated));
                        meta.push(sl.redamps.len() as f64);
                        ck.insert("train.ngd.window.session.meta", meta);
                        let mut redamps = Vec::with_capacity(sl.redamps.len() * 2);
                        for &(l, r) in &sl.redamps {
                            redamps.push(l);
                            redamps.push(r as f64);
                        }
                        ck.insert("train.ngd.window.session.redamps", redamps);
                        // Embed the PR-8 record by prefix-merging its
                        // own checkpoint tensors.
                        for (name, data) in sl.record.to_checkpoint().tensors {
                            ck.insert(&format!("{RECORD_PREFIX}{name}"), data);
                        }
                    }
                }
            }
        }
        ck
    }

    /// Decode, validating the schema version and every structural
    /// invariant the trainer's restore path relies on.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TrainState, CheckpointError> {
        let meta = tensor_exact(ck, "train.meta", 2)?;
        let version = as_count(meta[0], "train.meta version")? as u32;
        if version != TRAIN_STATE_VERSION {
            return Err(CheckpointError::UnsupportedVersion {
                found: version,
                supported: TRAIN_STATE_VERSION,
            });
        }
        let step = as_count(meta[1], "train.meta step")?;
        let params = tensor(ck, "train.params")?.to_vec();
        let s = tensor_exact(ck, "train.rng.s", 4)?;
        let rng_words = [s[0].to_bits(), s[1].to_bits(), s[2].to_bits(), s[3].to_bits()];
        let rng_cached = opt_from_pair(tensor_exact(ck, "train.rng.cached", 2)?, "train.rng.cached")?;
        let kind = tensor_exact(ck, "train.opt.kind", 1)?[0];
        let optimizer = match as_count(kind, "train.opt.kind")? {
            0 => OptimizerState::Sgd(SgdState {
                velocity: tensor(ck, "train.sgd.velocity")?.to_vec(),
            }),
            1 => {
                let velocity = tensor(ck, "train.ngd.velocity")?.to_vec();
                let meta = tensor_exact(ck, "train.ngd.meta", 5)?;
                let steps = as_count(meta[0], "train.ngd.meta steps")?;
                let lambda = meta[1];
                let last_loss = opt_from_pair(&meta[2..4], "train.ngd.meta last_loss")?;
                let window = if as_flag(meta[4], "train.ngd.meta has_window")? {
                    let fill = ck.get_mat("train.ngd.window.fill")?;
                    let wmeta = tensor_exact(ck, "train.ngd.window.meta", 3)?;
                    let fallback = as_flag(wmeta[0], "window fallback")?;
                    let rotations = as_count(wmeta[1], "window rotations")?;
                    let session = if as_flag(wmeta[2], "window has_session")? {
                        let smeta = tensor_exact(ck, "train.ngd.window.session.meta", 5)?;
                        let cold_refresh_lambda =
                            opt_from_pair(&smeta[0..2], "session cold_refresh_lambda")?;
                        let cold_retries = as_count(smeta[2], "session cold_retries")?;
                        let ever_rotated = as_flag(smeta[3], "session ever_rotated")?;
                        let n_redamps = as_count(smeta[4], "session n_redamps")?;
                        let flat =
                            tensor_exact(ck, "train.ngd.window.session.redamps", n_redamps * 2)?;
                        let mut redamps = Vec::with_capacity(n_redamps);
                        for pair in flat.chunks_exact(2) {
                            redamps.push((pair[0], as_count(pair[1], "redamp retries")?));
                        }
                        let mut sub = Checkpoint::new();
                        for (name, data) in &ck.tensors {
                            if let Some(rest) = name.strip_prefix(RECORD_PREFIX) {
                                sub.insert(rest, data.clone());
                            }
                        }
                        let record = SessionRecord::from_checkpoint(&sub)?;
                        if redamps.len() != record.log().len() {
                            return Err(CheckpointError::Corrupt(format!(
                                "window log has {} rotations but {} redamp entries",
                                record.log().len(),
                                redamps.len()
                            )));
                        }
                        Some(SessionLog {
                            record,
                            cold_refresh_lambda,
                            cold_retries,
                            ever_rotated,
                            redamps,
                        })
                    } else {
                        None
                    };
                    Some(WindowLog { fill, fallback, rotations, session })
                } else {
                    None
                };
                OptimizerState::Ngd(NgdState { velocity, last_loss, steps, lambda, window })
            }
            k => {
                return Err(CheckpointError::Corrupt(format!(
                    "train.opt.kind: unknown optimizer tag {k}"
                )))
            }
        };
        Ok(TrainState { step, params, rng_words, rng_cached, optimizer })
    }

    /// Atomic durable write (tmp + fsync + rename + dir fsync).
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        self.to_checkpoint().save(path)
    }

    pub fn load(path: &Path) -> Result<TrainState, CheckpointError> {
        TrainState::from_checkpoint(&Checkpoint::load(path)?)
    }
}

/// Result of a startup recovery scan over a checkpoint directory.
#[derive(Debug, Default)]
pub struct RecoveryScan {
    /// Newest loadable state and the file it came from.
    pub state: Option<(TrainState, PathBuf)>,
    /// Corrupt/truncated files, renamed `<name>.corrupt` so they are
    /// never considered again.
    pub quarantined: Vec<PathBuf>,
    /// Healthy files from a different format generation, skipped *in
    /// place* (a binary rollback must not destroy them).
    pub skipped_versions: Vec<PathBuf>,
}

/// Scan `dir` for `step_*.ckpt` files, newest step first, and return
/// the first one that loads cleanly. Corrupt files are quarantined
/// (renamed, never loaded); version-skewed files are skipped without
/// renaming. A missing directory is an empty scan, not an error (first
/// run).
pub fn recover_latest(dir: &Path) -> Result<RecoveryScan, CheckpointError> {
    let mut scan = RecoveryScan::default();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(scan),
        Err(e) => return Err(e.into()),
    };
    let mut candidates: Vec<(usize, PathBuf)> = Vec::new();
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(step) = name
            .strip_prefix("step_")
            .and_then(|r| r.strip_suffix(".ckpt"))
            .and_then(|s| s.parse::<usize>().ok())
        else {
            continue;
        };
        candidates.push((step, path));
    }
    // Newest first; the step number in the name is authoritative for
    // ordering (the payload's own step is verified on load).
    candidates.sort_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in candidates {
        match TrainState::load(&path) {
            Ok(state) => {
                scan.state = Some((state, path));
                break;
            }
            Err(CheckpointError::Corrupt(_)) => {
                let mut name = path.file_name().expect("candidate has a name").to_os_string();
                name.push(".corrupt");
                let q = path.with_file_name(name);
                std::fs::rename(&path, &q)?;
                scan.quarantined.push(q);
            }
            Err(CheckpointError::UnsupportedVersion { .. }) => {
                scan.skipped_versions.push(path);
            }
            Err(CheckpointError::Io(e)) => return Err(e.into()),
        }
    }
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn synthetic_ngd_state(with_session: bool) -> NgdState {
        let window = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let session = with_session.then(|| {
            let mut record = SessionRecord::new(&window, 0.25, u32::MAX as usize);
            let added = Mat::from_vec(1, 3, vec![7.0, -8.0, 9.5]);
            record.record_rotation(&[0], &added, &window);
            SessionLog {
                record,
                cold_refresh_lambda: Some(0.125),
                cold_retries: 2,
                redamps: vec![(0.25, 1)],
                ever_rotated: true,
            }
        });
        NgdState {
            velocity: vec![0.5, -1.5, 2.5],
            last_loss: Some(3.75),
            steps: 11,
            lambda: 0.03125,
            window: Some(WindowLog {
                fill: Mat::zeros(0, 3),
                fallback: false,
                rotations: 1,
                session,
            }),
        }
    }

    fn assert_ngd_eq(a: &NgdState, b: &NgdState) {
        assert_eq!(a.velocity, b.velocity);
        assert_eq!(a.last_loss.map(f64::to_bits), b.last_loss.map(f64::to_bits));
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.lambda.to_bits(), b.lambda.to_bits());
        match (&a.window, &b.window) {
            (None, None) => {}
            (Some(wa), Some(wb)) => {
                assert_eq!(wa.fill.shape(), wb.fill.shape());
                assert_eq!(wa.fill.as_slice(), wb.fill.as_slice());
                assert_eq!(wa.fallback, wb.fallback);
                assert_eq!(wa.rotations, wb.rotations);
                match (&wa.session, &wb.session) {
                    (None, None) => {}
                    (Some(sa), Some(sb)) => {
                        assert_eq!(sa.record, sb.record);
                        assert_eq!(sa.cold_refresh_lambda, sb.cold_refresh_lambda);
                        assert_eq!(sa.cold_retries, sb.cold_retries);
                        assert_eq!(sa.redamps, sb.redamps);
                        assert_eq!(sa.ever_rotated, sb.ever_rotated);
                    }
                    _ => panic!("session presence mismatch"),
                }
            }
            _ => panic!("window presence mismatch"),
        }
    }

    #[test]
    fn full_ngd_state_roundtrips_bit_exactly() {
        for with_session in [false, true] {
            let st = TrainState {
                step: 7,
                params: vec![1.0, f64::MIN_POSITIVE, -3e100],
                // Include a word whose f64 view is a NaN payload: the
                // encoding must be a pure byte copy.
                rng_words: [0x7FF8_0000_0000_0001, 0, u64::MAX, 0xDEAD_BEEF_CAFE_F00D],
                rng_cached: Some(-0.75),
                optimizer: OptimizerState::Ngd(synthetic_ngd_state(with_session)),
            };
            let back = TrainState::from_checkpoint(&Checkpoint::from_bytes(
                &st.to_checkpoint().to_bytes(),
            )
            .unwrap())
            .unwrap();
            assert_eq!(back.step, st.step);
            assert_eq!(back.params, st.params);
            assert_eq!(back.rng_words, st.rng_words);
            assert_eq!(back.rng_cached.map(f64::to_bits), st.rng_cached.map(f64::to_bits));
            match (&back.optimizer, &st.optimizer) {
                (OptimizerState::Ngd(a), OptimizerState::Ngd(b)) => assert_ngd_eq(a, b),
                _ => panic!("optimizer kind changed in roundtrip"),
            }
        }
    }

    #[test]
    fn sgd_state_roundtrips() {
        let st = TrainState {
            step: 3,
            params: vec![0.0; 5],
            rng_words: [1, 2, 3, 4],
            rng_cached: None,
            optimizer: OptimizerState::Sgd(SgdState { velocity: vec![1.0, 2.0] }),
        };
        let back =
            TrainState::from_checkpoint(&st.to_checkpoint()).unwrap();
        match back.optimizer {
            OptimizerState::Sgd(s) => assert_eq!(s.velocity, vec![1.0, 2.0]),
            _ => panic!("kind changed"),
        }
        assert_eq!(back.rng_cached, None);
    }

    #[test]
    fn state_schema_skew_is_typed() {
        let st = TrainState {
            step: 0,
            params: vec![],
            rng_words: [0; 4],
            rng_cached: None,
            optimizer: OptimizerState::Sgd(SgdState { velocity: vec![] }),
        };
        let mut ck = st.to_checkpoint();
        ck.insert("train.meta", vec![(TRAIN_STATE_VERSION + 1) as f64, 0.0]);
        match TrainState::from_checkpoint(&ck) {
            Err(CheckpointError::UnsupportedVersion { found, supported }) => {
                assert_eq!(found, TRAIN_STATE_VERSION + 1);
                assert_eq!(supported, TRAIN_STATE_VERSION);
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn recovery_scan_quarantines_corrupt_and_skips_skew() {
        let dir = std::env::temp_dir().join("dngd_test_recover_latest");
        std::fs::remove_dir_all(&dir).ok();
        let mk = |step: usize| TrainState {
            step,
            params: vec![step as f64],
            rng_words: [step as u64; 4],
            rng_cached: None,
            optimizer: OptimizerState::Sgd(SgdState { velocity: vec![] }),
        };
        mk(2).save(&checkpoint_path(&dir, 2)).unwrap();
        mk(3).save(&checkpoint_path(&dir, 3)).unwrap();
        // step 4: corrupt (flip a payload byte).
        let p4 = checkpoint_path(&dir, 4);
        let mut bytes = mk(4).to_checkpoint().to_bytes();
        bytes[24] ^= 0xFF;
        std::fs::write(&p4, &bytes).unwrap();
        // step 6: truncated.
        let p6 = checkpoint_path(&dir, 6);
        let full = mk(6).to_checkpoint().to_bytes();
        std::fs::write(&p6, &full[..full.len() / 3]).unwrap();
        // step 5: healthy but a newer container format.
        let p5 = checkpoint_path(&dir, 5);
        std::fs::write(
            &p5,
            mk(5).to_checkpoint().to_bytes_with_version(Checkpoint::format_version() + 1),
        )
        .unwrap();

        let scan = recover_latest(&dir).unwrap();
        let (state, from) = scan.state.expect("step 3 must recover");
        assert_eq!(state.step, 3);
        assert_eq!(from, checkpoint_path(&dir, 3));
        assert_eq!(scan.quarantined.len(), 2, "steps 4 and 6 quarantined");
        assert!(!p4.exists() && !p6.exists(), "corrupt originals renamed away");
        for q in &scan.quarantined {
            assert!(q.to_string_lossy().ends_with(".corrupt"));
            assert!(q.exists());
        }
        assert_eq!(scan.skipped_versions, vec![p5.clone()]);
        assert!(p5.exists(), "version-skewed file must be left in place");
        // A second scan no longer sees the quarantined files as
        // candidates and lands on the same state.
        let again = recover_latest(&dir).unwrap();
        assert_eq!(again.state.unwrap().0.step, 3);
        assert!(again.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_scan_of_missing_dir_is_empty() {
        let dir = std::env::temp_dir().join("dngd_test_recover_nothing_here");
        std::fs::remove_dir_all(&dir).ok();
        let scan = recover_latest(&dir).unwrap();
        assert!(scan.state.is_none());
        assert!(scan.quarantined.is_empty() && scan.skipped_versions.is_empty());
    }
}
