//! Binary checkpoint format: save/load/resume of training state.
//!
//! Layout (little-endian):
//! ```text
//! magic  "DNGD"          4 bytes
//! version u32            4
//! n_tensors u32          4
//! per tensor:
//!   name_len u32, name utf-8 bytes
//!   len u64, f64 data (len × 8 bytes)
//! trailer crc64 (xor-folded FNV-1a over everything before it)  8
//! ```

pub mod train_state;

pub use train_state::{
    checkpoint_path, recover_latest, OptimizerState, RecoveryScan, SgdState, TrainState,
    TRAIN_STATE_VERSION,
};

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DNGD";
const VERSION: u32 = 1;

/// A checkpoint: named f64 tensors (flat).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Vec<f64>>,
}

/// Checkpoint I/O errors.
#[derive(Debug)]
pub enum CheckpointError {
    Io(std::io::Error),
    Corrupt(String),
    /// The file is intact (checksum verified) but written by a
    /// different format version — distinguishable from corruption so
    /// recovery scans can *skip* newer-format files instead of
    /// quarantining them.
    UnsupportedVersion { found: u32, supported: u32 },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint I/O: {e}"),
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
            CheckpointError::UnsupportedVersion { found, supported } => write!(
                f,
                "unsupported checkpoint format version {found} (this build reads version \
                 {supported})"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn new() -> Self {
        Checkpoint::default()
    }

    pub fn insert(&mut self, name: &str, data: Vec<f64>) {
        self.tensors.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Option<&[f64]> {
        self.tensors.get(name).map(|v| v.as_slice())
    }

    /// Store a matrix as one tensor: `[rows, cols, row-major data…]`.
    /// The shape header rides inside the f64 stream (exact for any
    /// realistic dimension — f64 integers are exact below 2⁵³), so the
    /// container format stays flat-tensor-only.
    pub fn insert_mat(&mut self, name: &str, m: &crate::linalg::Mat) {
        let mut data = Vec::with_capacity(2 + m.rows() * m.cols());
        data.push(m.rows() as f64);
        data.push(m.cols() as f64);
        data.extend_from_slice(m.as_slice());
        self.insert(name, data);
    }

    /// Read back a matrix stored by [`Checkpoint::insert_mat`],
    /// validating the embedded shape header against the payload length.
    pub fn get_mat(&self, name: &str) -> Result<crate::linalg::Mat, CheckpointError> {
        let data = self
            .get(name)
            .ok_or_else(|| CheckpointError::Corrupt(format!("missing tensor {name:?}")))?;
        if data.len() < 2 {
            return Err(CheckpointError::Corrupt(format!("tensor {name:?} has no shape header")));
        }
        let (rows, cols) = (data[0], data[1]);
        if rows < 0.0 || cols < 0.0 || rows.fract() != 0.0 || cols.fract() != 0.0 {
            return Err(CheckpointError::Corrupt(format!(
                "tensor {name:?} has a non-integral shape header ({rows}, {cols})"
            )));
        }
        let (rows, cols) = (rows as usize, cols as usize);
        if data.len() - 2 != rows * cols {
            return Err(CheckpointError::Corrupt(format!(
                "tensor {name:?}: shape ({rows}, {cols}) wants {} values, payload has {}",
                rows * cols,
                data.len() - 2
            )));
        }
        Ok(crate::linalg::Mat::from_vec(rows, cols, data[2..].to_vec()))
    }

    /// The container format version this build writes and reads.
    pub fn format_version() -> u32 {
        VERSION
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        self.to_bytes_with_version(VERSION)
    }

    /// Serialize with an explicit format-version header. Only useful
    /// for version-skew testing and migration tooling — the checksum is
    /// computed normally, so readers see a *valid* file from another
    /// format generation, not a corrupt one.
    pub fn to_bytes_with_version(&self, version: u32) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&version.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, data) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = fnv1a64(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf
    }

    /// Parse from bytes, verifying magic, version and checksum.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint, CheckpointError> {
        if buf.len() < 20 {
            return Err(CheckpointError::Corrupt("truncated header".into()));
        }
        let (body, trailer) = buf.split_at(buf.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a64(body) != stored {
            return Err(CheckpointError::Corrupt("checksum mismatch".into()));
        }
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8], CheckpointError> {
            if *pos + n > body.len() {
                return Err(CheckpointError::Corrupt("truncated body".into()));
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            return Err(CheckpointError::Corrupt("bad magic".into()));
        }
        let version = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        if version != VERSION {
            // The checksum already passed: this is a healthy file from
            // another format generation, not corruption.
            return Err(CheckpointError::UnsupportedVersion { found: version, supported: VERSION });
        }
        let n_tensors = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let mut tensors = BTreeMap::new();
        for _ in 0..n_tensors {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|_| CheckpointError::Corrupt("non-utf8 tensor name".into()))?;
            let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
            let raw = take(&mut pos, len * 8)?;
            let data: Vec<f64> = raw
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.insert(name, data);
        }
        if pos != body.len() {
            return Err(CheckpointError::Corrupt("trailing bytes".into()));
        }
        Ok(Checkpoint { tensors })
    }

    /// Write atomically: tmp + fsync + rename + directory fsync. The
    /// final fsync makes the *rename itself* durable — without it a
    /// crash after rename can roll the directory entry back to the old
    /// file (or nothing), which is exactly the window full-state
    /// training checkpoints must not have.
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        #[cfg(unix)]
        if let Some(dir) = path.parent() {
            let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
            std::fs::File::open(dir)?.sync_all()?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        Checkpoint::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let mut ck = Checkpoint::new();
        ck.insert("params", vec![1.0, -2.5, 3.25]);
        ck.insert("velocity", vec![0.0; 7]);
        ck.insert("step", vec![42.0]);
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn detects_corruption() {
        let mut ck = Checkpoint::new();
        ck.insert("x", vec![1.0, 2.0]);
        let mut bytes = ck.to_bytes();
        bytes[10] ^= 0xFF;
        match Checkpoint::from_bytes(&bytes) {
            Err(CheckpointError::Corrupt(m)) => assert!(m.contains("checksum")),
            other => panic!("expected corruption error, got {other:?}"),
        }
    }

    #[test]
    fn detects_truncation() {
        let mut ck = Checkpoint::new();
        ck.insert("x", vec![1.0; 100]);
        let bytes = ck.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..4]).is_err());
    }

    #[test]
    fn version_skew_is_typed_not_corrupt() {
        let mut ck = Checkpoint::new();
        ck.insert("x", vec![1.0, 2.0]);
        let bytes = ck.to_bytes_with_version(Checkpoint::format_version() + 1);
        match Checkpoint::from_bytes(&bytes) {
            Err(CheckpointError::UnsupportedVersion { found, supported }) => {
                assert_eq!(found, Checkpoint::format_version() + 1);
                assert_eq!(supported, Checkpoint::format_version());
            }
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
        // A corrupted skewed file is still reported as corruption — the
        // checksum gate runs first, so the version field is only trusted
        // on an intact file.
        let mut bad = ck.to_bytes_with_version(99);
        bad[10] ^= 0xFF;
        assert!(matches!(Checkpoint::from_bytes(&bad), Err(CheckpointError::Corrupt(_))));
    }

    #[test]
    fn file_roundtrip_atomic() {
        let dir = std::env::temp_dir().join("dngd_test_ckpt");
        let path = dir.join("model.ckpt");
        let mut ck = Checkpoint::new();
        ck.insert("p", (0..1000).map(|i| i as f64 * 0.5).collect());
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert!(!path.with_extension("tmp").exists(), "tmp file must be renamed away");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_checkpoint_roundtrips() {
        let ck = Checkpoint::new();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert!(back.tensors.is_empty());
    }

    #[test]
    fn mat_roundtrip_is_bit_exact() {
        use crate::data::rng::Rng;
        let mut rng = Rng::seed_from(808);
        let m = crate::linalg::Mat::randn(5, 7, &mut rng);
        let mut ck = Checkpoint::new();
        ck.insert_mat("window", &m);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let got = back.get_mat("window").unwrap();
        assert_eq!(got.shape(), (5, 7));
        for (a, b) in got.as_slice().iter().zip(m.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Degenerate shapes survive too.
        let empty = crate::linalg::Mat::zeros(0, 4);
        ck.insert_mat("empty", &empty);
        assert_eq!(ck.get_mat("empty").unwrap().shape(), (0, 4));
    }

    #[test]
    fn mat_shape_mismatch_is_typed_corruption() {
        let mut ck = Checkpoint::new();
        ck.insert("bad", vec![2.0, 3.0, 1.0]); // claims 2×3, has 1 value
        assert!(matches!(ck.get_mat("bad"), Err(CheckpointError::Corrupt(_))));
        ck.insert("frac", vec![1.5, 2.0, 1.0, 2.0, 3.0]);
        assert!(matches!(ck.get_mat("frac"), Err(CheckpointError::Corrupt(_))));
        assert!(matches!(ck.get_mat("absent"), Err(CheckpointError::Corrupt(_))));
    }
}
