//! Train-target chaos harness (PR 9): pin the kill-anywhere guarantee.
//!
//! Each scenario trains a tiny model to completion (the reference
//! trajectory), then repeatedly "crashes" a second run at a randomized
//! step boundary — the trainer is dropped on the floor, exactly like a
//! `kill -9` between steps — and resumes a *fresh* trainer from the
//! latest durable checkpoint. The resumed trajectory must reproduce the
//! reference parameters **bit for bit**: same losses, same λ backoffs,
//! same streaming-window rotations, same data order. Two recovery
//! drills ride along: a corrupt newest checkpoint must be quarantined
//! (fall back to the older good one, still bit-identical), and a
//! version-skewed checkpoint must be skipped in place.
//!
//! The scenario matrix covers the solve modes with distinct durable
//! state: classic sharded chol, streaming-window chol and rvb (owned
//! sessions with rotation/redamp replay logs), and the mixed-precision
//! path (f32 factor with an f64 latch that the replay must reproduce).
//!
//! Driven by `dngd chaos --target train`; the exhaustive
//! kill-at-every-boundary matrix lives in `tests/durability.rs`.

use crate::checkpoint::Checkpoint;
use crate::config::Config;
use crate::coordinator::trainer::{OptimizerChoice, Trainer, TRAIN_LOG_COLUMNS};
use crate::data::Rng;
use crate::metrics::MetricsLog;
use crate::solver::{Precision, SolverKind};
use std::path::PathBuf;

/// Options for a train-target chaos run.
#[derive(Debug, Clone)]
pub struct TrainChaosOptions {
    /// Seed for the randomized kill points.
    pub seed: u64,
    /// Kill/resume cycles per scenario.
    pub kills: usize,
}

impl Default for TrainChaosOptions {
    fn default() -> Self {
        TrainChaosOptions { seed: 17, kills: 3 }
    }
}

/// Outcome of one chaos scenario.
#[derive(Debug, Clone)]
pub struct TrainChaosReport {
    pub scenario: &'static str,
    /// Kill/resume cycles exercised.
    pub kills: usize,
    /// Cycles that actually resumed from a checkpoint (a kill before
    /// the first checkpoint restarts from scratch — also covered).
    pub resumes: usize,
    /// Corrupt checkpoints quarantined during recovery scans.
    pub quarantined: usize,
    /// Version-skewed checkpoints skipped in place.
    pub version_skipped: usize,
    pub passed: bool,
    pub detail: String,
}

const SCENARIOS: &[(&str, fn(&mut Config))] = &[
    ("classic-chol-sharded", |cfg| {
        cfg.coordinator.workers = 2;
    }),
    ("windowed-chol", |cfg| {
        cfg.coordinator.workers = 1;
        cfg.solver.window = 48;
        cfg.solver.refresh_every = 3;
    }),
    ("windowed-rvb", |cfg| {
        cfg.coordinator.workers = 1;
        cfg.solver.kind = SolverKind::Rvb;
        cfg.solver.window = 48;
        cfg.solver.refresh_every = 3;
    }),
    ("mixed-classic", |cfg| {
        cfg.coordinator.workers = 1;
        cfg.solver.precision = Precision::Mixed;
    }),
    ("mixed-windowed", |cfg| {
        cfg.coordinator.workers = 1;
        cfg.solver.precision = Precision::Mixed;
        cfg.solver.window = 48;
        cfg.solver.refresh_every = 3;
    }),
];

fn base_config(dir: &std::path::Path) -> Config {
    let mut cfg = Config::from_toml_str(
        r#"
[model]
dim = 8
heads = 2
layers = 1
context = 8
mlp_hidden = 16

[train]
steps = 6
batch_size = 16
learning_rate = 0.3
corpus_len = 4000
seed = 11
checkpoint_every = 2

[solver]
lambda = 0.01

[coordinator]
workers = 1
use_artifacts = false
"#,
        &[],
    )
    .expect("chaos base config is valid");
    cfg.train.checkpoint_dir = dir.to_string_lossy().to_string();
    cfg
}

fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dngd_train_chaos_{}_{tag}", std::process::id()))
}

/// Train `cfg` start to finish in `dir` and return the final params.
fn reference_run(cfg: &Config) -> Result<Vec<f64>, String> {
    let mut trainer = Trainer::new(cfg, OptimizerChoice::Ngd)?;
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    trainer.run(&mut log).map_err(|e| format!("reference run: {e}"))?;
    Ok(trainer.params.clone())
}

fn first_param_mismatch(a: &[f64], b: &[f64]) -> Option<usize> {
    a.iter().zip(b).position(|(x, y)| x.to_bits() != y.to_bits())
}

/// One kill/resume cycle: run `kill_at` steps, "crash", resume fresh,
/// finish, and compare against the reference bit for bit.
fn kill_resume_cycle(
    cfg: &Config,
    kill_at: usize,
    reference: &[f64],
) -> Result<bool, String> {
    let dir = PathBuf::from(&cfg.train.checkpoint_dir);
    std::fs::remove_dir_all(&dir).ok();
    let mut killed = Trainer::new(cfg, OptimizerChoice::Ngd)?;
    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
    killed.run_partial(&mut log, kill_at).map_err(|e| format!("pre-kill run: {e}"))?;
    drop(killed); // the crash: no flush, no farewell

    let mut resumed = Trainer::new(cfg, OptimizerChoice::Ngd)?;
    let at =
        resumed.resume_latest().map_err(|e| format!("recovery after kill@{kill_at}: {e}"))?;
    let mut log2 = MetricsLog::new(TRAIN_LOG_COLUMNS);
    resumed.run(&mut log2).map_err(|e| format!("resumed run (kill@{kill_at}): {e}"))?;
    if let Some(j) = first_param_mismatch(reference, &resumed.params) {
        return Err(format!(
            "kill@{kill_at} resume@{at:?}: param {j} diverged ({:e} vs {:e})",
            reference[j], resumed.params[j]
        ));
    }
    Ok(at.is_some())
}

/// Run one named scenario: randomized kill/resume cycles, then the
/// corrupt-quarantine and version-skew recovery drills.
pub fn run_scenario(
    name: &'static str,
    mutate: fn(&mut Config),
    opts: &TrainChaosOptions,
) -> Result<TrainChaosReport, String> {
    let dir = scratch_dir(name);
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = base_config(&dir);
    mutate(&mut cfg);
    cfg.validate()?;

    let mut report = TrainChaosReport {
        scenario: name,
        kills: 0,
        resumes: 0,
        quarantined: 0,
        version_skipped: 0,
        passed: true,
        detail: String::new(),
    };
    fn fail(report: &mut TrainChaosReport, msg: String) {
        report.passed = false;
        if !report.detail.is_empty() {
            report.detail.push_str("; ");
        }
        report.detail.push_str(&msg);
    }

    let reference = reference_run(&cfg)?;

    // Randomized kill boundaries (1 ≤ kill_at < steps). A kill before
    // the first checkpoint cadence resumes from nothing — a fresh
    // deterministic start, which must also land on the reference.
    let mut rng = Rng::seed_from(opts.seed ^ name.len() as u64);
    for _ in 0..opts.kills {
        let kill_at = 1 + rng.below(cfg.train.steps - 1);
        report.kills += 1;
        match kill_resume_cycle(&cfg, kill_at, &reference) {
            Ok(resumed) => {
                if resumed {
                    report.resumes += 1;
                }
            }
            Err(e) => fail(&mut report, e),
        }
    }

    // Drill 1: corrupt the newest checkpoint — recovery must quarantine
    // it, fall back to the older good one, and still match bit-exactly.
    std::fs::remove_dir_all(&dir).ok();
    {
        let mut t = Trainer::new(&cfg, OptimizerChoice::Ngd)?;
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        t.run_partial(&mut log, 5).map_err(|e| format!("drill setup: {e}"))?;
    }
    let newest = dir.join("step_4.ckpt");
    match std::fs::read(&newest) {
        Ok(mut bytes) => {
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xFF;
            std::fs::write(&newest, &bytes).map_err(|e| format!("corrupt drill write: {e}"))?;
            let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd)?;
            match resumed.resume_latest() {
                Ok(Some(2)) => {
                    report.quarantined += resumed.stats().quarantined;
                    if resumed.stats().quarantined != 1 {
                        fail(
                            &mut report,
                            format!(
                                "corrupt drill quarantined {} files, wanted 1",
                                resumed.stats().quarantined
                            ),
                        );
                    }
                    let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
                    match resumed.run(&mut log) {
                        Ok(_) => {
                            if let Some(j) = first_param_mismatch(&reference, &resumed.params) {
                                fail(
                                    &mut report,
                                    format!("corrupt drill: param {j} diverged after fallback"),
                                );
                            }
                        }
                        Err(e) => fail(&mut report, format!("corrupt drill run: {e}")),
                    }
                }
                Ok(other) => {
                    fail(&mut report, format!("corrupt drill resumed at {other:?}, wanted 2"))
                }
                Err(e) => fail(&mut report, format!("corrupt drill recovery: {e}")),
            }
        }
        Err(e) => fail(&mut report, format!("corrupt drill: read step_4.ckpt: {e}")),
    }

    // Drill 2: a checkpoint from a future container format generation
    // (valid checksum, newer version) must be skipped *in place* — not
    // quarantined, not loaded.
    std::fs::remove_dir_all(&dir).ok();
    {
        let mut t = Trainer::new(&cfg, OptimizerChoice::Ngd)?;
        let mut log = MetricsLog::new(TRAIN_LOG_COLUMNS);
        t.run_partial(&mut log, 5).map_err(|e| format!("skew drill setup: {e}"))?;
    }
    let newest = dir.join("step_4.ckpt");
    match Checkpoint::load(&newest) {
        Ok(ck) => {
            let skewed = ck.to_bytes_with_version(Checkpoint::format_version() + 1);
            std::fs::write(&newest, &skewed).map_err(|e| format!("skew drill write: {e}"))?;
            let mut resumed = Trainer::new(&cfg, OptimizerChoice::Ngd)?;
            match resumed.resume_latest() {
                Ok(Some(2)) => {
                    report.version_skipped += resumed.stats().version_skipped;
                    if resumed.stats().version_skipped != 1 || !newest.exists() {
                        fail(&mut report, "skew drill: file must be skipped in place".into());
                    }
                }
                Ok(other) => {
                    fail(&mut report, format!("skew drill resumed at {other:?}, wanted 2"))
                }
                Err(e) => fail(&mut report, format!("skew drill recovery: {e}")),
            }
        }
        Err(e) => fail(&mut report, format!("skew drill: reload step_4.ckpt: {e}")),
    }

    std::fs::remove_dir_all(&dir).ok();
    Ok(report)
}

/// Run the whole scenario matrix.
pub fn run_all(opts: &TrainChaosOptions) -> Result<Vec<TrainChaosReport>, String> {
    let mut out = Vec::new();
    for &(name, mutate) in SCENARIOS {
        out.push(run_scenario(name, mutate, opts)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_scenario_windowed_chol_passes() {
        // One representative scenario in-test (the full matrix runs via
        // `dngd chaos --target train` and tests/durability.rs).
        let (name, mutate) =
            SCENARIOS.iter().find(|(n, _)| *n == "windowed-chol").copied().unwrap();
        let opts = TrainChaosOptions { seed: 5, kills: 2 };
        let r = run_scenario(name, mutate, &opts).unwrap();
        assert!(r.passed, "{}", r.detail);
        assert_eq!(r.kills, 2);
        assert_eq!(r.quarantined, 1);
        assert_eq!(r.version_skipped, 1);
    }
}
