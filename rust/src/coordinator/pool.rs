//! Persistent worker pool with bounded channels.
//!
//! Each worker is an OS thread owning column shards `S_k` of score
//! matrices, keyed by **session id** — since PR 7 a worker holds one
//! shard *per live session*, so many tenants' sessions can be in flight
//! on one pool at once. The leader talks to workers over `sync_channel`s
//! of configurable depth — a full queue blocks [`WorkerPool::send`]
//! (backpressure) or surfaces as the retryable [`PoolError::QueueFull`]
//! from [`WorkerPool::try_send`]. Fault injection
//! (`ShardRequest::Stall`, `ShardRequest::Die`) lets tests exercise
//! straggler and crash behaviour without real bad hardware.
//!
//! The request vocabulary and the compute path live in
//! [`crate::serve::transport`] ([`ShardRequest`] / `execute_request`),
//! shared with the socket transport so in-process and out-of-process
//! workers are bit-identical.

use crate::linalg::{KernelConfig, Mat};
use crate::serve::transport::{execute_request, ShardRequest, ShardResponse};
use std::collections::HashMap;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Mutex, PoisonError, RwLock};

/// Messages the leader sends to a worker.
pub enum Job {
    /// One shard request; the worker answers on `reply` (demuxed per
    /// request, so concurrent leader threads never interleave replies).
    Request { req: ShardRequest, reply: Sender<ShardResponse> },
    /// Drain barrier: replies the worker's processed count once every
    /// job enqueued before this one has been executed.
    Flush { reply: Sender<u64> },
    Shutdown,
}

/// Pool-level failures, split by whether a retry on this pool can ever
/// succeed (the serving layer's reject-with-retry-after vs tear-down
/// decision rides on this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolError {
    /// The worker thread is gone — its mailbox is closed. Fatal for the
    /// request in flight; [`WorkerPool::respawn`] can replace the thread
    /// (with an **empty** shard map — sessions must be re-staged).
    WorkerGone(usize),
    /// The worker's bounded mailbox is full (only from
    /// [`WorkerPool::try_send`]). Retryable: back off and resubmit.
    QueueFull(usize),
}

impl PoolError {
    pub fn is_retryable(&self) -> bool {
        matches!(self, PoolError::QueueFull(_))
    }
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::WorkerGone(w) => write!(f, "worker {w} disconnected"),
            PoolError::QueueFull(w) => write!(f, "worker {w} queue full"),
        }
    }
}

impl std::error::Error for PoolError {}

struct WorkerHandle {
    tx: SyncSender<Job>,
    join: Option<std::thread::JoinHandle<u64>>,
}

/// Leader-side pool handle.
pub struct WorkerPool {
    /// Per-slot handle behind an `RwLock`: requests take a read lock,
    /// [`WorkerPool::respawn`] swaps the handle under a write lock.
    workers: Vec<RwLock<WorkerHandle>>,
    queue_depth: usize,
    kernel: KernelConfig,
    /// Join handles of replaced (dead) incarnations; their processed
    /// counts are folded into the owning slot at drain time so the
    /// shutdown accounting stays cumulative per worker index.
    graveyard: Mutex<Vec<(usize, std::thread::JoinHandle<u64>)>>,
}

impl WorkerPool {
    /// Spawn `workers` threads with `queue_depth`-bounded mailboxes,
    /// each running its kernels serially (deterministic default).
    pub fn spawn(workers: usize, queue_depth: usize) -> WorkerPool {
        WorkerPool::spawn_with_kernel(workers, queue_depth, KernelConfig::serial())
    }

    /// Spawn with an explicit kernel configuration: each worker's Gram
    /// product dispatches with `kernel.threads` threads on the shared
    /// persistent kernel pool (useful when workers ≪ cores).
    pub fn spawn_with_kernel(
        workers: usize,
        queue_depth: usize,
        kernel: KernelConfig,
    ) -> WorkerPool {
        assert!(workers > 0 && queue_depth > 0);
        let handles = (0..workers)
            .map(|id| RwLock::new(Self::spawn_worker(id, queue_depth, kernel)))
            .collect();
        WorkerPool { workers: handles, queue_depth, kernel, graveyard: Mutex::new(Vec::new()) }
    }

    fn spawn_worker(id: usize, queue_depth: usize, kernel: KernelConfig) -> WorkerHandle {
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let join = std::thread::Builder::new()
            .name(format!("dngd-worker-{id}"))
            .spawn(move || worker_loop(rx, kernel))
            .expect("spawn worker");
        WorkerHandle { tx, join: Some(join) }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Send a job to worker `w` (blocks when its queue is full —
    /// backpressure).
    pub fn send(&self, w: usize, job: Job) -> Result<(), PoolError> {
        let h = self.workers[w].read().unwrap_or_else(PoisonError::into_inner);
        h.tx.send(job).map_err(|_| PoolError::WorkerGone(w))
    }

    /// Non-blocking [`WorkerPool::send`]: a full mailbox surfaces as the
    /// retryable [`PoolError::QueueFull`] instead of blocking.
    pub fn try_send(&self, w: usize, job: Job) -> Result<(), PoolError> {
        let h = self.workers[w].read().unwrap_or_else(PoisonError::into_inner);
        h.tx.try_send(job).map_err(|e| match e {
            TrySendError::Full(_) => PoolError::QueueFull(w),
            TrySendError::Disconnected(_) => PoolError::WorkerGone(w),
        })
    }

    /// Replace the (presumed dead) thread in slot `w` with a freshly
    /// spawned worker. The new incarnation starts with an **empty**
    /// shard map — every session staged on the old worker must be
    /// re-distributed before it can serve again (the serving layer's
    /// supervisor does that via session re-materialization). If the old
    /// thread is somehow still alive, dropping its sender lets it drain
    /// its mailbox and exit; either way its processed count is folded
    /// into slot `w`'s total at shutdown.
    pub fn respawn(&self, w: usize) {
        let fresh = Self::spawn_worker(w, self.queue_depth, self.kernel);
        let old = {
            let mut slot = self.workers[w].write().unwrap_or_else(PoisonError::into_inner);
            std::mem::replace(&mut *slot, fresh)
        };
        if let Some(join) = old.join {
            self.graveyard.lock().unwrap_or_else(PoisonError::into_inner).push((w, join));
        }
        // `old.tx` drops here: the retired thread (if alive) sees a
        // closed mailbox after draining and exits.
    }

    /// Drain barrier: returns once every job enqueued before the call
    /// has been processed on every worker (mailboxes are FIFO).
    pub fn flush(&self) -> Result<(), PoolError> {
        let mut waits = Vec::with_capacity(self.workers.len());
        for (w, h) in self.workers.iter().enumerate() {
            let (tx, rx) = channel();
            let h = h.read().unwrap_or_else(PoisonError::into_inner);
            h.tx.send(Job::Flush { reply: tx }).map_err(|_| PoolError::WorkerGone(w))?;
            waits.push((w, rx));
        }
        for (w, rx) in waits {
            rx.recv().map_err(|_| PoolError::WorkerGone(w))?;
        }
        Ok(())
    }

    /// Graceful shutdown; drains all in-flight jobs (explicit
    /// [`WorkerPool::flush`] barrier), then stops the workers and
    /// returns per-worker processed-job counts (cumulative across
    /// respawned incarnations of the same slot).
    pub fn shutdown(mut self) -> Vec<u64> {
        // A dead worker fails the flush — ignore and join what's left.
        let _ = self.flush();
        self.drain()
    }

    fn drain(&mut self) -> Vec<u64> {
        for h in &self.workers {
            let h = h.read().unwrap_or_else(PoisonError::into_inner);
            let _ = h.tx.send(Job::Shutdown);
        }
        let mut counts: Vec<u64> = self
            .workers
            .iter_mut()
            .map(|h| {
                let h = h.get_mut().unwrap_or_else(PoisonError::into_inner);
                h.join.take().map(|j| j.join().unwrap_or(0)).unwrap_or(0)
            })
            .collect();
        let graveyard = self.graveyard.get_mut().unwrap_or_else(PoisonError::into_inner);
        for (w, join) in graveyard.drain(..) {
            counts[w] += join.join().unwrap_or(0);
        }
        counts
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.drain();
    }
}

fn worker_loop(rx: Receiver<Job>, kernel: KernelConfig) -> u64 {
    let mut shards: HashMap<u64, Mat> = HashMap::new();
    let mut processed: u64 = 0;
    while let Ok(job) = rx.recv() {
        processed += 1;
        match job {
            // Crash simulation: exit without replying — queued jobs drop
            // with the mailbox, which closes their reply channels and
            // fails their tickets instead of hanging them.
            Job::Request { req: ShardRequest::Die, .. } => break,
            Job::Request { req, reply } => {
                // A dropped ticket (fire-and-forget caller) is fine.
                let _ = reply.send(execute_request(&mut shards, req, kernel));
            }
            Job::Flush { reply } => {
                let _ = reply.send(processed);
            }
            Job::Shutdown => break,
        }
    }
    processed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn request(pool: &WorkerPool, w: usize, req: ShardRequest) -> Receiver<ShardResponse> {
        let (tx, rx) = channel();
        pool.send(w, Job::Request { req, reply: tx }).unwrap();
        rx
    }

    #[test]
    fn gram_roundtrip_and_job_accounting() {
        let mut rng = Rng::seed_from(420);
        let pool = WorkerPool::spawn(3, 2);
        let s = Mat::randn(6, 12, &mut rng);
        // Install thirds under one session id.
        for w in 0..3 {
            let rx = request(&pool, w, ShardRequest::SetShard {
                sid: 1,
                shard: s.slice_cols(w * 4, (w + 1) * 4),
            });
            assert_eq!(rx.recv().unwrap(), ShardResponse::Ack);
        }
        // Partial Grams must sum to the full Gram.
        let mut total = Mat::zeros(6, 6);
        for w in 0..3 {
            let rx = request(&pool, w, ShardRequest::Gram { sid: 1 });
            match rx.recv().unwrap() {
                ShardResponse::Mat(part) => total.axpy(1.0, &part),
                other => panic!("unexpected response {other:?}"),
            }
        }
        let full = crate::linalg::gemm::syrk(&s, 0.0);
        for (a, b) in total.as_slice().iter().zip(full.as_slice()) {
            assert!((a - b).abs() < 1e-10);
        }
        let counts = pool.shutdown();
        assert_eq!(counts.len(), 3);
        // Every worker processed SetShard + Gram + the shutdown drain's
        // Flush barrier + Shutdown.
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn two_sessions_coexist_on_one_worker() {
        let mut rng = Rng::seed_from(424);
        let pool = WorkerPool::spawn(1, 4);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(3, 6, &mut rng);
        request(&pool, 0, ShardRequest::SetShard { sid: 1, shard: a.clone() })
            .recv()
            .unwrap();
        request(&pool, 0, ShardRequest::SetShard { sid: 2, shard: b.clone() })
            .recv()
            .unwrap();
        // Session 1's Gram is still a's Gram — sid 2 did not clobber it.
        let ga = request(&pool, 0, ShardRequest::Gram { sid: 1 }).recv().unwrap();
        let gb = request(&pool, 0, ShardRequest::Gram { sid: 2 }).recv().unwrap();
        assert_eq!(ga, ShardResponse::Mat(crate::linalg::gemm::syrk(&a, 0.0)));
        assert_eq!(gb, ShardResponse::Mat(crate::linalg::gemm::syrk(&b, 0.0)));
        // Dropping sid 1 leaves sid 2 intact.
        request(&pool, 0, ShardRequest::DropShard { sid: 1 }).recv().unwrap();
        let gone = request(&pool, 0, ShardRequest::Gram { sid: 1 }).recv().unwrap();
        assert!(matches!(gone, ShardResponse::Err(_)));
        let still = request(&pool, 0, ShardRequest::Gram { sid: 2 }).recv().unwrap();
        assert!(matches!(still, ShardResponse::Mat(_)));
        pool.shutdown();
    }

    #[test]
    fn stall_injection_slows_but_does_not_break() {
        let mut rng = Rng::seed_from(421);
        let pool = WorkerPool::spawn(2, 2);
        let s = Mat::randn(4, 8, &mut rng);
        request(&pool, 0, ShardRequest::SetShard { sid: 1, shard: s.slice_cols(0, 4) })
            .recv()
            .unwrap();
        request(&pool, 1, ShardRequest::SetShard { sid: 1, shard: s.slice_cols(4, 8) })
            .recv()
            .unwrap();
        // Worker 1 is a straggler.
        let _ = request(&pool, 1, ShardRequest::Stall { ms: 30 });
        let t0 = std::time::Instant::now();
        let ones = Mat::from_vec(1, 4, vec![1.0; 4]);
        let r0 = request(&pool, 0, ShardRequest::MatvecMany { sid: 1, v_k: ones.clone() });
        let r1 = request(&pool, 1, ShardRequest::MatvecMany { sid: 1, v_k: ones });
        assert!(matches!(r0.recv().unwrap(), ShardResponse::Mat(_)));
        assert!(matches!(r1.recv().unwrap(), ShardResponse::Mat(_)));
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25));
        pool.shutdown();
    }

    #[test]
    fn missing_shard_is_a_typed_error_not_a_crash() {
        let pool = WorkerPool::spawn(1, 1);
        let resp = request(&pool, 0, ShardRequest::Gram { sid: 9 }).recv().unwrap();
        match resp {
            ShardResponse::Err(msg) => assert!(msg.contains("session 9"), "{msg}"),
            other => panic!("unexpected response {other:?}"),
        }
        pool.shutdown();
    }

    #[test]
    fn try_send_full_queue_is_retryable_queuefull() {
        let pool = WorkerPool::spawn(1, 1);
        let (tx, _rx) = channel();
        // Occupy the worker, then fill its depth-1 mailbox.
        pool.send(0, Job::Request { req: ShardRequest::Stall { ms: 60 }, reply: tx.clone() })
            .unwrap();
        // The worker may or may not have dequeued the stall yet; keep
        // try-sending until the mailbox is observably full.
        let mut full_err = None;
        for _ in 0..8 {
            match pool.try_send(0, Job::Request {
                req: ShardRequest::Ping,
                reply: tx.clone(),
            }) {
                Ok(()) => continue,
                Err(e) => {
                    full_err = Some(e);
                    break;
                }
            }
        }
        let e = full_err.expect("mailbox never filled");
        assert_eq!(e, PoolError::QueueFull(0));
        assert!(e.is_retryable());
        pool.shutdown();
    }

    #[test]
    fn dead_worker_is_fatal_workergone_and_fails_tickets() {
        let pool = WorkerPool::spawn(2, 2);
        let (tx, dead_rx) = channel();
        pool.send(0, Job::Request { req: ShardRequest::Die, reply: tx }).unwrap();
        // The Die never replies: its channel must close, not hang.
        assert!(dead_rx.recv().is_err());
        // Subsequent sends surface the fatal WorkerGone.
        let (tx2, _rx2) = channel();
        let mut gone = None;
        for _ in 0..50 {
            match pool.send(0, Job::Request { req: ShardRequest::Ping, reply: tx2.clone() }) {
                Err(e) => {
                    gone = Some(e);
                    break;
                }
                // The mailbox may buffer a few sends before the thread
                // exit is observable.
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(1)),
            }
        }
        let e = gone.expect("dead worker never surfaced");
        assert_eq!(e, PoolError::WorkerGone(0));
        assert!(!e.is_retryable());
        // Worker 1 still serves.
        let ok = request(&pool, 1, ShardRequest::Ping).recv().unwrap();
        assert_eq!(ok, ShardResponse::Ack);
        pool.shutdown();
    }

    #[test]
    fn respawned_worker_serves_again_with_an_empty_shard_map() {
        let mut rng = Rng::seed_from(428);
        let pool = WorkerPool::spawn(1, 2);
        let s = Mat::randn(4, 8, &mut rng);
        request(&pool, 0, ShardRequest::SetShard { sid: 1, shard: s.clone() })
            .recv()
            .unwrap();
        let (tx, _rx) = channel();
        pool.send(0, Job::Request { req: ShardRequest::Die, reply: tx }).unwrap();
        // Wait until the death is observable from the leader side.
        let (tx2, _rx2) = channel();
        let mut died = false;
        for _ in 0..200 {
            match pool.send(0, Job::Request { req: ShardRequest::Ping, reply: tx2.clone() }) {
                Err(PoolError::WorkerGone(0)) => {
                    died = true;
                    break;
                }
                Err(e) => panic!("unexpected error {e:?}"),
                Ok(()) => std::thread::sleep(std::time::Duration::from_millis(1)),
            }
        }
        assert!(died, "worker death never became observable");
        pool.respawn(0);
        // The fresh incarnation serves, but the old session's shard is
        // gone — a typed missing-session error, not stale data.
        let ok = request(&pool, 0, ShardRequest::Ping).recv().unwrap();
        assert_eq!(ok, ShardResponse::Ack);
        let gone = request(&pool, 0, ShardRequest::Gram { sid: 1 }).recv().unwrap();
        assert!(matches!(gone, ShardResponse::Err(_)), "{gone:?}");
        // Shutdown folds the dead incarnation's count (SetShard + Die
        // = 2) into the slot: + Ping + Gram + Flush + Shutdown = 6.
        let counts = pool.shutdown();
        assert_eq!(counts, vec![6]);
    }

    #[test]
    fn backpressure_blocks_sender() {
        // queue_depth 1 + a stalled worker: the 3rd send must block until
        // the worker drains — observe via a helper thread + timing.
        let pool = std::sync::Arc::new(WorkerPool::spawn(1, 1));
        let (tx, _rx) = channel();
        let stall =
            |t: &Sender<ShardResponse>, ms| Job::Request {
                req: ShardRequest::Stall { ms },
                reply: t.clone(),
            };
        pool.send(0, stall(&tx, 50)).unwrap(); // being processed
        pool.send(0, stall(&tx, 1)).unwrap(); // fills queue
        let p2 = pool.clone();
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            let (tx2, _rx2) = channel();
            p2.send(0, Job::Request { req: ShardRequest::Stall { ms: 1 }, reply: tx2 })
                .unwrap(); // must wait
            t0.elapsed()
        });
        let waited = h.join().unwrap();
        assert!(
            waited >= std::time::Duration::from_millis(30),
            "sender did not backpressure: {waited:?}"
        );
    }

    #[test]
    fn flush_drains_before_shutdown_counts() {
        let pool = WorkerPool::spawn(1, 4);
        let (tx, _rx) = channel();
        for _ in 0..3 {
            pool.send(0, Job::Request { req: ShardRequest::Stall { ms: 5 }, reply: tx.clone() })
                .unwrap();
        }
        pool.flush().unwrap();
        // After the barrier all 3 stalls + the Flush are processed.
        let counts = pool.shutdown();
        // 3 stalls + first Flush + shutdown's own Flush + Shutdown.
        assert_eq!(counts, vec![6]);
    }
}
